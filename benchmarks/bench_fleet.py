"""Fleet-scale scenario-sweep benchmark: aggregate env-steps/sec of the
vmapped twin (``run_fleet``) vs replica count, with heterogeneous grid
scenarios (the workload the ROADMAP's "as many scenarios as you can
imagine" north-star asks for).

``bench_fleet_sharded`` adds the device-sharded path (``run_fleet(mesh=
...)``): the same macro fleet on 8 host devices vs single-device vmap,
including a lockstep-ADVERSARIAL workload — one contiguous shard of
cap-event-dense replicas whose quiet horizons collapse to tens of ticks
while everyone else fast-forwards — where the vmapped while-loop pays the
busy replicas' trip count for every lane and sharding confines it to one
device. Every sharded row carries a ``match_vmapped`` derived field
(bitwise final-state equality, asserted). When the current process has
fewer than 2 devices the bench re-execs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (device count is
locked at first jax init, same trick as tests/test_multidevice.py)."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import List, Tuple

import jax

Row = Tuple[str, float, str]


def bench_fleet() -> List[Row]:
    import numpy as np

    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_fleet
    from repro.data import synth_workload
    from repro.scenarios import sample_scenarios

    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 32, 900.0, seed=0)
    statics = build_statics(cfg, bank)
    st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    n_steps = 200

    rows: List[Row] = []
    base_sps = None
    for R in (1, 16, 64, 256):
        scns = sample_scenarios(cfg, R, seed=R)

        def run(state):
            return run_fleet(cfg, statics, state, n_steps, "fcfs",
                             scenarios=scns)

        fs, _ = run(st)  # compile
        jax.block_until_ready(fs.t)
        t0 = time.perf_counter()
        n_rep = 3
        for _ in range(n_rep):
            fs, _ = run(st)
        jax.block_until_ready(fs.t)
        dt = (time.perf_counter() - t0) / n_rep

        sps = n_steps * R / dt
        if base_sps is None:
            base_sps = sps
        n_capped = int(np.sum(np.asarray(scns.power_cap.cap_w).max(-1) > 0))
        rows.append((
            f"fleet_{R}replicas", dt / n_steps * 1e6,
            f"agg_steps_per_s={sps:,.0f};speedup_vs_1={sps/base_sps:.1f}x;"
            f"dr_scenarios={n_capped}/{R}",
        ))

    # constant-memory telemetry: summary_only carries windowed reductions in
    # the scan instead of stacking 16 StepOut fields x n_steps x R
    R, long_steps = 64, 2000
    scns = sample_scenarios(cfg, R, seed=R)

    def run_summary(state):
        return run_fleet(cfg, statics, state, long_steps, "fcfs",
                         scenarios=scns, summary_only=True)

    fs, tel = run_summary(st)
    jax.block_until_ready(fs.t)
    t0 = time.perf_counter()
    fs, tel = run_summary(st)
    jax.block_until_ready(fs.t)
    dt = time.perf_counter() - t0
    out_floats = sum(int(np.size(np.asarray(x))) for x in tel)
    rows.append((
        f"fleet_{R}replicas_summary_only_{long_steps}steps",
        dt / long_steps * 1e6,
        f"agg_steps_per_s={long_steps*R/dt:,.0f};"
        f"telemetry_floats={out_floats} (vs {long_steps*R*16} stacked)",
    ))
    return rows


def _sharded_rows(smoke: bool = False) -> List[Row]:
    """Body of ``bench_fleet_sharded``; needs >=2 jax devices."""
    import numpy as np

    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_fleet
    from repro.data import synth_workload
    from repro.launch.mesh import make_fleet_mesh
    from repro.scenarios import sample_scenarios
    from repro.scenarios.events import cap_events
    from repro.scenarios.scenario import default_scenario, stack_scenarios

    D = min(8, len(jax.devices()))
    mesh = make_fleet_mesh(D)
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 32, 900.0, seed=0)
    statics = build_statics(cfg, bank)
    st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    R = 2 * D if smoke else 8 * D
    n_steps = 600 if smoke else 3000
    n_rep = 2 if smoke else 3

    def timed(fn):
        fs, _ = fn()                         # compile
        jax.block_until_ready(fs.t)
        t0 = time.perf_counter()
        for _ in range(n_rep):
            fs, tel = fn()
        jax.block_until_ready(fs.t)
        return (time.perf_counter() - t0) / n_rep, fs, tel

    def match(a, b):
        for f in a._fields:
            x, y = getattr(a, f), getattr(b, f)
            if f == "key":
                x, y = jax.random.key_data(x), jax.random.key_data(y)
            if not np.array_equal(np.asarray(x), np.asarray(y)):
                return False
        return True

    rows: List[Row] = []
    workloads = [
        # heterogeneous-but-benign sweep: horizons vary mildly
        ("uniform", sample_scenarios(cfg, R, seed=11)),
    ]
    # lockstep-adversarial: the LAST R/D replicas (= exactly one contiguous
    # shard under the replica-axis NamedSharding) carry a cap edge every
    # 20 simulated seconds, so their macro quiet horizons collapse to ~10
    # ticks while everyone else's span arrival gaps and the episode tail.
    # Under vmap every lane pays the busy trip count; sharded, only one
    # device does.
    edges = np.arange(10.0, n_steps * cfg.dt - 20.0, 20.0)
    busy = default_scenario(cfg)._replace(power_cap=cap_events(
        edges, edges + 10.0, [cfg.nameplate_it_w * 1.3 * 0.7] * len(edges),
        base_cap_w=cfg.power_cap_w))
    quiet = default_scenario(cfg)
    workloads.append((
        "adversarial",
        stack_scenarios([quiet] * (R - R // D) + [busy] * (R // D))))

    for tag, scns in workloads:
        def vmapped(scns=scns):
            return run_fleet(cfg, statics, st, n_steps, "fcfs",
                             scenarios=scns, macro=True, summary_only=True)

        def sharded(scns=scns):
            return run_fleet(cfg, statics, st, n_steps, "fcfs",
                             scenarios=scns, macro=True, summary_only=True,
                             mesh=mesh)

        dt_v, fs_v, _ = timed(vmapped)
        dt_s, fs_s, _ = timed(sharded)
        ok = match(fs_v, fs_s)
        assert ok, f"sharded fleet diverged from vmapped on {tag} workload"
        suffix = "" if not smoke else "_smoke"
        rows.append((
            f"fleet_vmapped_{R}replicas_macro_{tag}{suffix}",
            dt_v / n_steps * 1e6,
            f"agg_steps_per_s={n_steps*R/dt_v:,.0f}",
        ))
        rows.append((
            f"fleet_sharded_{R}replicas_macro_{tag}{suffix}",
            dt_s / n_steps * 1e6,
            f"agg_steps_per_s={n_steps*R/dt_s:,.0f};devices={D};"
            f"speedup_vs_vmapped={dt_v/dt_s:.2f}x;match_vmapped={ok}",
        ))
    return rows


def bench_fleet_sharded(smoke: bool = False) -> List[Row]:
    if len(jax.devices()) >= 2:
        return _sharded_rows(smoke)
    # device count is locked at first jax init — re-exec with forced host
    # devices and relay the rows (same pattern as tests/test_multidevice)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), env.get("PYTHONPATH")) if p)
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__)]
        + (["--smoke"] if smoke else []),
        capture_output=True, text=True, env=env, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(
            f"sharded fleet sub-bench failed\nSTDOUT:\n{r.stdout}\n"
            f"STDERR:\n{r.stderr}")
    payload = json.loads(r.stdout.strip().splitlines()[-1])
    return [tuple(row) for row in payload]


if __name__ == "__main__":
    # subprocess entry for bench_fleet_sharded: emit rows as one JSON line
    print(json.dumps(_sharded_rows(smoke="--smoke" in sys.argv[1:])))
