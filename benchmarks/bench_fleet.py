"""Fleet-scale scenario-sweep benchmark: aggregate env-steps/sec of the
vmapped twin (``run_fleet``) vs replica count, with heterogeneous grid
scenarios (the workload the ROADMAP's "as many scenarios as you can
imagine" north-star asks for)."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax

Row = Tuple[str, float, str]


def bench_fleet() -> List[Row]:
    import numpy as np

    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_fleet
    from repro.data import synth_workload
    from repro.scenarios import sample_scenarios

    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 32, 900.0, seed=0)
    statics = build_statics(cfg, bank)
    st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    n_steps = 200

    rows: List[Row] = []
    base_sps = None
    for R in (1, 16, 64, 256):
        scns = sample_scenarios(cfg, R, seed=R)

        def run(state):
            return run_fleet(cfg, statics, state, n_steps, "fcfs",
                             scenarios=scns)

        fs, _ = run(st)  # compile
        jax.block_until_ready(fs.t)
        t0 = time.perf_counter()
        n_rep = 3
        for _ in range(n_rep):
            fs, _ = run(st)
        jax.block_until_ready(fs.t)
        dt = (time.perf_counter() - t0) / n_rep

        sps = n_steps * R / dt
        if base_sps is None:
            base_sps = sps
        n_capped = int(np.sum(np.asarray(scns.power_cap.cap_w).max(-1) > 0))
        rows.append((
            f"fleet_{R}replicas", dt / n_steps * 1e6,
            f"agg_steps_per_s={sps:,.0f};speedup_vs_1={sps/base_sps:.1f}x;"
            f"dr_scenarios={n_capped}/{R}",
        ))

    # constant-memory telemetry: summary_only carries windowed reductions in
    # the scan instead of stacking 16 StepOut fields x n_steps x R
    R, long_steps = 64, 2000
    scns = sample_scenarios(cfg, R, seed=R)

    def run_summary(state):
        return run_fleet(cfg, statics, state, long_steps, "fcfs",
                         scenarios=scns, summary_only=True)

    fs, tel = run_summary(st)
    jax.block_until_ready(fs.t)
    t0 = time.perf_counter()
    fs, tel = run_summary(st)
    jax.block_until_ready(fs.t)
    dt = time.perf_counter() - t0
    out_floats = sum(int(np.size(np.asarray(x))) for x in tel)
    rows.append((
        f"fleet_{R}replicas_summary_only_{long_steps}steps",
        dt / long_steps * 1e6,
        f"agg_steps_per_s={long_steps*R/dt:,.0f};"
        f"telemetry_floats={out_floats} (vs {long_steps*R*16} stacked)",
    ))
    return rows
