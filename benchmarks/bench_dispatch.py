"""Dispatch/placement microbenchmarks — the simulator's per-step hot path.

Rows:
  dispatch_first_fit_*      sort-free cumsum placement vs the legacy argsort
                            path, vmapped over a batch of random states
  placement_<strategy>_*    the two-stage engine's placement strategies
                            (best_fit/spread/partition/green vs first_fit),
                            vmapped over the same batch
  dispatch_wavefront_jaxpr  jaxpr size of the fori_loop dispatch wavefront
                            vs attempts (stays ~constant; the unrolled loop
                            grew linearly)
  power_scatter_fused       fused job-table -> node-power Pallas pass vs the
                            two-pass scatter + node-power path
  policy_grid_*             (bench_policy_grid) the policy-as-data engine:
                            a full selection x placement grid through ONE
                            compiled run_fleet call vs one jit compile per
                            eager policy pair

``smoke=True`` shrinks every size so the whole bench runs in seconds (the
CI benchmark smoke job).
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _timeit(fn, *args, n=10):
    jax.block_until_ready(fn(*args))  # compile + flush async dispatch
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def _random_states(cfg, statics, st, B, n_jobs):
    """Batch of states with randomized free pools / clocks (queue churn)."""
    keys = jax.random.split(jax.random.key(1), B)

    def perturb(s, key):
        k1, k2 = jax.random.split(key)
        return s._replace(
            free=s.free * jax.random.uniform(k1, s.free.shape),
            t=jax.random.uniform(k2, (), minval=0.0, maxval=3600.0),
        )

    states = jax.vmap(perturb, in_axes=(None, 0))(st, keys)
    jobs = jax.random.randint(jax.random.key(2), (B,), 0, n_jobs)
    return states, jobs


def bench_dispatch(smoke: bool = False) -> List[Row]:
    from repro.configs.sim import tiny_cluster, tx_gaia
    from repro.core import build_statics, init_state, load_jobs, make_step
    from repro.core import schedulers as sched
    from repro.data import synth_workload

    if smoke:
        cfg = tiny_cluster()
        B, n_jobs, n_iter = 8, 16, 2
    else:
        cfg = tx_gaia(max_jobs=256, max_nodes_per_job=16)
        B, n_jobs, n_iter = 256, 200, 20
    jobs, bank = synth_workload(cfg, n_jobs, 3600.0, seed=0)
    statics = build_statics(cfg, bank)
    st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    states, jobsel = _random_states(cfg, statics, st, B, n_jobs)
    K = cfg.max_nodes_per_job

    ff_old = jax.jit(jax.vmap(
        lambda s, j: sched.first_fit_argsort(s, j, K)))
    ff_new = jax.jit(jax.vmap(lambda s, j: sched.first_fit(s, j, K)))
    dt_old = _timeit(ff_old, states, jobsel, n=n_iter)
    dt_new = _timeit(ff_new, states, jobsel, n=n_iter)
    r_old, ok_old = ff_old(states, jobsel)
    r_new, ok_new = ff_new(states, jobsel)
    equal = bool(
        (np.asarray(r_old) == np.asarray(r_new)).all()
        and (np.asarray(ok_old) == np.asarray(ok_new)).all()
    )
    rows: List[Row] = [
        (f"dispatch_first_fit_argsort_B{B}_N{cfg.n_nodes}", dt_old * 1e6,
         f"placements_per_s={B/dt_old:,.0f}"),
        (f"dispatch_first_fit_cumsum_B{B}_N{cfg.n_nodes}", dt_new * 1e6,
         f"placements_per_s={B/dt_new:,.0f};speedup_vs_argsort="
         f"{dt_old/dt_new:.2f}x;bit_equal={equal}"),
    ]

    # placement-strategy microbench: every strategy of the two-stage
    # engine, vmapped over the same randomized batch
    from repro.core import placement as plc

    for pname, pfn in plc.PLACEMENTS.items():
        pf = jax.jit(jax.vmap(
            lambda s, j, pfn=pfn: pfn(s, statics, j)))
        dt_p = _timeit(pf, states, jobsel, n=n_iter)
        rows.append((
            f"placement_{pname}_B{B}_N{cfg.n_nodes}", dt_p * 1e6,
            f"placements_per_s={B/dt_p:,.0f};"
            f"vs_first_fit={dt_p/dt_new:.2f}x",
        ))

    # jaxpr growth vs dispatch attempts (fori_loop wavefront => ~constant)
    sizes = []
    for spp in (1, 8):
        step = make_step(cfg, statics, "fcfs", starts_per_step=spp)
        sizes.append(len(jax.make_jaxpr(step)(st, jnp.int32(-1)).jaxpr.eqns))
    rows.append((
        "dispatch_wavefront_jaxpr", 0.0,
        f"eqns_1_attempt={sizes[0]};eqns_8_attempts={sizes[1]};"
        f"growth={sizes[1]/max(sizes[0],1):.2f}x",
    ))

    # fused power-scatter kernel vs the two-pass scatter + power path
    from repro.core.power import compute_power

    s_mid, _ = jax.jit(
        lambda s: jax.lax.scan(
            lambda c, _: (step(c, jnp.int32(-1))[0], None), s, None,
            length=10 if smoke else 100)
    )(st)
    two_pass = jax.jit(
        lambda s: compute_power(cfg, s, statics, use_kernel=False).node_it_w)
    fused = jax.jit(
        lambda s: compute_power(cfg, s, statics, use_kernel=True).node_it_w)
    dt_2p = _timeit(two_pass, s_mid, n=n_iter)
    dt_f = _timeit(fused, s_mid, n=n_iter)
    err = float(jnp.max(jnp.abs(two_pass(s_mid) - fused(s_mid))))
    rows.append((
        f"power_scatter_fused_N{cfg.n_nodes}", dt_f * 1e6,
        f"two_pass_us={dt_2p*1e6:.1f};max_err={err:.1e}",
    ))
    return rows


def bench_policy_grid(smoke: bool = False) -> List[Row]:
    """Policy-as-data vs per-policy recompiles — the refactor's headline.

    Sweeps the FULL selection x placement grid two ways, timed COLD
    (compile included, because compile time is exactly what the
    policy-as-data engine amortizes):

      - single-compile: all P policies as traced (select_id, place_id)
        int32s down one vmapped ``run_fleet`` call (one executable);
      - per-policy: one eager ``make_step``/``run_episode`` jit per
        (selection, placement) pair — P compilations.

    A third row times both paths WARM (executables cached): under vmap
    the ``lax.switch`` engine executes every selection/placement branch
    per lane, so its steady-state step is costlier than an eager
    single-policy step — the row exposes that branch overhead so the
    cold speedup is never mistaken for a steady-state one.
    """
    from repro.configs.sim import NodeType, SimConfig, tiny_cluster
    from repro.core import (
        PLACEMENTS,
        SCHEDULERS,
        build_statics,
        init_state,
        load_jobs,
        policy_grid,
        run_episode,
        run_fleet,
    )
    from repro.data import synth_workload

    if smoke:
        cfg = tiny_cluster()
        n_jobs, n_steps = 16, 20
        selects, places = ["fcfs", "sjf"], ["first_fit", "best_fit", "green"]
    else:
        # a TX-GAIA rack pair (same scale as bench_sim's scheduler table)
        cfg = SimConfig(
            name="tx-gaia-racks",
            node_types=(
                NodeType("txg-v100", 48, 40, 2, 384.0, 240.0, 260.0, 55.0,
                         245.0, 17_900.0),
                NodeType("xeon-p8", 16, 48, 0, 192.0, 160.0, 330.0, 0.0, 0.0,
                         3_300.0),
            ),
            max_jobs=256, max_nodes_per_job=16,
        )
        n_jobs, n_steps = 180, 240
        selects, places = list(SCHEDULERS), list(PLACEMENTS)
    jobs, bank = synth_workload(cfg, n_jobs, 900.0, seed=3)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)

    names, grid = policy_grid(selects, places)
    P = len(names)

    # --- single compile: the whole grid is one vmapped jitted call
    t0 = time.perf_counter()
    fs, tel = run_fleet(cfg, statics, state, n_steps, policies=grid,
                        summary_only=True)
    jax.block_until_ready(tel)
    dt_grid = time.perf_counter() - t0

    # --- per-policy eager: one fresh executable per (select, place) pair
    t0 = time.perf_counter()
    eager_runs = []
    for name in names:
        sel, pl = name.split("+")
        run = jax.jit(lambda s, sel=sel, pl=pl: run_episode(
            cfg, statics, s, n_steps, sel, placement=pl, summary_only=True))
        jax.block_until_ready(run(state))
        eager_runs.append(run)
    dt_eager = time.perf_counter() - t0

    # --- warm steady state: cached executables, same sweeps again
    t0 = time.perf_counter()
    _, tel2 = run_fleet(cfg, statics, state, n_steps, policies=grid,
                        summary_only=True)
    jax.block_until_ready(tel2)
    warm_grid = time.perf_counter() - t0
    t0 = time.perf_counter()
    for run in eager_runs:
        jax.block_until_ready(run(state))
    warm_eager = time.perf_counter() - t0

    return [
        (f"policy_grid_single_compile_P{P}", dt_grid / P * 1e6,
         f"policies={P};steps={n_steps};wall_s={dt_grid:.2f};"
         f"compiles=1;cold=TRUE"),
        (f"policy_grid_per_policy_recompile_P{P}", dt_eager / P * 1e6,
         f"wall_s={dt_eager:.2f};compiles={P};"
         f"single_compile_speedup={dt_eager/dt_grid:.2f}x;cold=TRUE"),
        (f"policy_grid_warm_P{P}",
         warm_grid / P / n_steps * 1e6,
         f"us_per_policy_step_grid={warm_grid/P/n_steps*1e6:.1f};"
         f"us_per_policy_step_eager={warm_eager/P/n_steps*1e6:.1f};"
         f"switch_branch_overhead={warm_grid/max(warm_eager,1e-9):.2f}x;"
         f"cold=FALSE"),
    ]
