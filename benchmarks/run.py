# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one bench per paper table/figure:

  replay_tx_gaia_1h        Fig 2 top-left  (throughput/energy during replay)
  sched_*                  RAPS scheduler table (+ Fan et al. 45% reference)
  ppo_scheduler            Fig 2 top-right (PPO reward curve)
  power_prediction_replay  Fig 2 bottom    (power prediction from replay)
  congestion_bw_*          network-congestion model [14]
  vmapped_sim_*            beyond-paper: vectorized-twin RL throughput
  fleet_*replicas          beyond-paper: scenario-sweep fleet throughput
  pallas_*                 kernel microbenches vs oracles
  train/decode_reduced_*   LM substrate throughput (reduced configs)
  roofline_flops_crosscheck  analytic perfmodel vs compiled dry-run
"""

import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks.bench_fleet import bench_fleet
    from benchmarks.bench_kernels import bench_kernels
    from benchmarks.bench_lm import (
        bench_decode_reduced,
        bench_roofline_crosscheck,
        bench_train_reduced,
    )
    from benchmarks.bench_sim import (
        bench_congestion_model,
        bench_power_prediction,
        bench_replay_throughput,
        bench_rl_training,
        bench_scheduler_comparison,
        bench_vectorized_envs,
    )

    benches = [
        bench_replay_throughput,
        bench_scheduler_comparison,
        bench_power_prediction,
        bench_congestion_model,
        bench_rl_training,
        bench_vectorized_envs,
        bench_fleet,
        bench_kernels,
        bench_train_reduced,
        bench_decode_reduced,
        bench_roofline_crosscheck,
    ]
    print("name,us_per_call,derived")
    failed = []
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append(bench.__name__)
            print(f"{bench.__name__},nan,FAILED:{e!r}", flush=True)
    if failed:
        raise SystemExit(f"benches failed: {failed}")


if __name__ == "__main__":
    main()
