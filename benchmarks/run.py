# One function per paper table. Print ``name,us_per_call,derived`` CSV and
# write a BENCH_<n>.json perf-trajectory artifact.
"""Benchmark harness — one bench per paper table/figure:

  replay_tx_gaia_1h        Fig 2 top-left  (throughput/energy during replay)
  sched_*                  RAPS scheduler table (+ Fan et al. 45% reference)
  ppo_scheduler            Fig 2 top-right (PPO reward curve)
  power_prediction_replay  Fig 2 bottom    (power prediction from replay)
  congestion_bw_*          network-congestion model [14]
  vmapped_sim_*            beyond-paper: vectorized-twin RL throughput
  rollout_* / ppo_iteration  lightweight-state RL rollout engine (BENCH_4)
  replay_tx_gaia_1h_faults[_macro] / faults_smoke_*  resilience twin:
                           event-sampled fault clocks under macro (BENCH_7)
  serving_diurnal_day_* / serving_smoke_* / serving_ppo_slo  serving twin:
                           SLO-aware overload ladder under macro (BENCH_9)
  fleet_*replicas          beyond-paper: scenario-sweep fleet throughput
  fleet_sharded_* / fleet_vmapped_*  device-sharded fleet (run_fleet mesh=)
                           vs single-device vmap, incl. the lockstep-
                           adversarial macro workload (BENCH_8)
  replay_snapshot_*        durable twin: segmented snapshot/resume driver
                           overhead vs vanilla replay (BENCH_10)
  dispatch_* / power_scatter_*  sort-free placement + fused power kernel
  pallas_*                 kernel microbenches vs oracles
  train/decode_reduced_*   LM substrate throughput (reduced configs)
  roofline_flops_crosscheck  analytic perfmodel vs compiled dry-run

Every run appends to the perf trajectory: results land in
``benchmarks/BENCH_<n>.json`` (n = 1 + highest existing), so successive
PRs can diff hot-path numbers against the recorded baseline. See
``docs/performance.md`` for how to read the artifact.

Usage:
  python benchmarks/run.py            # full suite
  python benchmarks/run.py --smoke    # tiny configs, seconds (CI gate)
  python benchmarks/run.py --out P    # write the artifact to path P
  python benchmarks/run.py --compare BENCH_a.json BENCH_b.json
                                      # per-row speedup table a -> b;
                                      # exits non-zero on >20% regressions
"""

import argparse
import glob
import json
import os
import re
import sys
import threading
import time
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)   # so `benchmarks.*` imports work as a script

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))


def _trajectory_numbers() -> list:
    return sorted(
        int(m.group(1))
        for p in glob.glob(os.path.join(BENCH_DIR, "BENCH_*.json"))
        if (m := re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(p)))
    )


def _warn_trajectory_gaps() -> list:
    """LOUDLY report holes in the numbered BENCH_<n> trajectory (e.g. a PR
    that referenced an artifact which never landed in-tree). The rule
    (docs/performance.md): numbering is always 1 + highest existing — gaps
    are never silently backfilled, because BENCH_<n> is read as "the
    artifact PR n produced" and a late write would masquerade as history.
    """
    nums = _trajectory_numbers()
    missing = sorted(set(range(1, max(nums, default=0) + 1)) - set(nums))
    if missing:
        print(
            f"# WARNING: perf trajectory has gaps — missing "
            f"{', '.join(f'BENCH_{n}.json' for n in missing)}; "
            "numbering continues from the highest existing artifact and "
            "gaps stay empty (see docs/performance.md)", file=sys.stderr)
    return missing


def _next_artifact_path() -> str:
    return os.path.join(
        BENCH_DIR, f"BENCH_{max(_trajectory_numbers(), default=0) + 1}.json")


def _named(fn, name, **kw):
    def run():
        return fn(**kw)

    run.__name__ = name
    return run


def _benches(smoke: bool):
    from benchmarks.bench_dispatch import bench_dispatch, bench_policy_grid
    from benchmarks.bench_rl import bench_rl

    if smoke:
        from benchmarks.bench_fleet import bench_fleet_sharded
        from benchmarks.bench_serving import bench_serving_smoke
        from benchmarks.bench_sim import (
            bench_faults_smoke,
            bench_macro_smoke,
            bench_snapshot_overhead,
            bench_thermal_smoke,
            bench_vectorized_envs,
        )

        return [
            _named(bench_dispatch, "bench_dispatch", smoke=True),
            bench_vectorized_envs,
            bench_macro_smoke,
            bench_thermal_smoke,
            bench_faults_smoke,
            bench_serving_smoke,
            bench_snapshot_overhead,
            _named(bench_policy_grid, "bench_policy_grid", smoke=True),
            _named(bench_rl, "bench_rl", smoke=True),
            _named(bench_fleet_sharded, "bench_fleet_sharded", smoke=True),
        ]

    from benchmarks.bench_fleet import bench_fleet, bench_fleet_sharded
    from benchmarks.bench_kernels import bench_kernels
    from benchmarks.bench_serving import bench_serving, bench_serving_smoke
    from benchmarks.bench_lm import (
        bench_decode_reduced,
        bench_roofline_crosscheck,
        bench_train_reduced,
    )
    from benchmarks.bench_sim import (
        bench_congestion_model,
        bench_faults,
        bench_faults_smoke,
        bench_macro_smoke,
        bench_power_prediction,
        bench_replay_throughput,
        bench_rl_training,
        bench_scheduler_comparison,
        bench_snapshot_overhead,
        bench_thermal,
        bench_thermal_smoke,
        bench_vectorized_envs,
    )

    return [
        bench_replay_throughput,
        bench_thermal,
        bench_faults,
        bench_macro_smoke,
        bench_thermal_smoke,
        bench_faults_smoke,
        bench_serving,
        bench_serving_smoke,
        bench_snapshot_overhead,
        bench_scheduler_comparison,
        bench_power_prediction,
        bench_congestion_model,
        bench_rl_training,
        bench_vectorized_envs,
        bench_rl,
        bench_dispatch,
        bench_policy_grid,
        bench_fleet,
        bench_fleet_sharded,
        bench_kernels,
        bench_train_reduced,
        bench_decode_reduced,
        bench_roofline_crosscheck,
    ]


REGRESSION_THRESHOLD = 1.20   # >20% slower counts as a regression


def compare_artifacts(path_a: str, path_b: str,
                      threshold: float = REGRESSION_THRESHOLD) -> int:
    """Print a per-row speedup table between two BENCH artifacts and
    return the number of rows regressing beyond ``threshold`` (b slower
    than a). Rows are matched by name; unmatched, failed (nan) and
    zero-time rows are listed but never counted as regressions — the
    trajectory must stay diffable even when a bench set changes shape."""
    num = lambda p: (m := re.fullmatch(r"BENCH_(\d+)\.json",
                                       os.path.basename(p))) and int(m.group(1))
    na_n, nb_n = num(path_a), num(path_b)
    if na_n and nb_n and abs(nb_n - na_n) > 1:
        skipped = [f"BENCH_{i}.json"
                   for i in range(min(na_n, nb_n) + 1, max(na_n, nb_n))
                   if not os.path.exists(os.path.join(BENCH_DIR,
                                                      f"BENCH_{i}.json"))]
        if skipped:
            print(f"# NOTE: comparing across a trajectory gap — "
                  f"{', '.join(skipped)} never landed; deltas span more "
                  "than one PR (see docs/performance.md)", file=sys.stderr)
    a = json.load(open(path_a))
    b = json.load(open(path_b))
    rows_a = {r["name"]: r for r in a["rows"]}
    rows_b = {r["name"]: r for r in b["rows"]}
    na, nb = os.path.basename(path_a), os.path.basename(path_b)
    width = max([len(n) for n in rows_a] + [len(n) for n in rows_b] + [4])
    print(f"{'name':<{width}}  {na:>14}  {nb:>14}  {'speedup':>8}  verdict")
    regressions = []
    for name in list(rows_a) + [n for n in rows_b if n not in rows_a]:
        ra, rb = rows_a.get(name), rows_b.get(name)
        if ra is None or rb is None:
            tag = "only in " + (nb if ra is None else na)
            us = (rb or ra)["us_per_call"]
            print(f"{name:<{width}}  {'-' if ra is None else us:>14}  "
                  f"{'-' if rb is None else us:>14}  {'-':>8}  {tag}")
            continue
        ua, ub = ra["us_per_call"], rb["us_per_call"]
        bad = lambda u: (not isinstance(u, (int, float)) or u != u or u <= 0)
        if bad(ua) or bad(ub):
            if bad(ua) != bad(ub):
                # failed on exactly one side: likely a REAL breakage (or
                # fix) introduced between the two artifacts — warn loudly,
                # but never count it as a perf regression
                side = na if bad(ua) else nb
                print(f"# WARNING: {name!r} failed/timed out only in "
                      f"{side} — investigate before trusting this diff",
                      file=sys.stderr)
                tag = f"skipped (failed only in {side})"
            else:
                tag = "skipped (failed/zero-time row)"
            print(f"{name:<{width}}  {ua!s:>14}  {ub!s:>14}  {'-':>8}  {tag}")
            continue
        speedup = ua / ub
        verdict = "ok"
        if ub > ua * threshold:
            verdict = f"REGRESSION (>{(threshold - 1) * 100:.0f}%)"
            regressions.append(name)
        elif speedup >= threshold:
            verdict = "improved"
        print(f"{name:<{width}}  {ua:>14.1f}  {ub:>14.1f}  "
              f"{speedup:>7.2f}x  {verdict}")
    if regressions:
        print(f"# {len(regressions)} regression(s): {regressions}",
              file=sys.stderr)
    return len(regressions)


def _run_bench_guarded(bench, timeout_s: float):
    """Run one bench on a daemon worker thread. Returns
    (result_rows | None, exception | None, timed_out). On timeout the
    worker keeps running detached (XLA compiles are not interruptible
    from Python) — the harness moves on and records the row as timed
    out instead of hanging the whole suite."""
    out = {"rows": None, "exc": None}

    def work():
        try:
            out["rows"] = list(bench())
        except BaseException as e:  # noqa: BLE001 - reported per-row
            out["exc"] = e

    th = threading.Thread(target=work, daemon=True)
    th.start()
    th.join(timeout_s if timeout_s and timeout_s > 0 else None)
    if th.is_alive():
        return None, None, True
    return out["rows"], out["exc"], False


RETRY_BACKOFF_S = 2.0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs only (CI benchmark smoke gate)")
    ap.add_argument("--out", default=None,
                    help="artifact path (default: benchmarks/BENCH_<n>.json)")
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filter on bench function "
                         "names (e.g. --only policy_grid,dispatch)")
    ap.add_argument("--timeout", type=float, default=0.0,
                    help="per-bench wall-clock budget in seconds (0 = none); "
                         "a bench over budget gets one retry, then its row "
                         "is recorded with timed_out=true and the suite "
                         "moves on")
    ap.add_argument("--compare", nargs=2, metavar=("A.json", "B.json"),
                    default=None,
                    help="diff two BENCH artifacts row-by-row instead of "
                         "running benches; exit non-zero on >20%% regressions")
    args = ap.parse_args(argv)

    if args.compare:
        n_reg = compare_artifacts(*args.compare)
        if n_reg:
            raise SystemExit(1)
        return

    benches = _benches(args.smoke)
    if args.only:
        pats = [p.strip() for p in args.only.split(",") if p.strip()]
        benches = [
            b for b in benches
            if any(p in getattr(b, "__name__", repr(b)) for p in pats)
        ]
        if not benches:
            raise SystemExit(f"--only {args.only!r} matched no benches")

    print("name,us_per_call,derived")
    rows, failed = [], []
    for bench in benches:
        bench_name = getattr(bench, "__name__", repr(bench))
        # transient failures (thread-pool races, flaky first compile) get
        # ONE retry with a short backoff; a second strike is recorded
        retries = 0
        while True:
            result, exc, timed_out = _run_bench_guarded(bench, args.timeout)
            if result is not None or retries >= 1:
                break
            retries += 1
            what = "timed out" if timed_out else f"failed ({exc!r})"
            print(f"# {bench_name} {what}; retrying once in "
                  f"{RETRY_BACKOFF_S:.0f}s", file=sys.stderr, flush=True)
            time.sleep(RETRY_BACKOFF_S)
        if result is not None:
            for name, us, derived in result:
                print(f"{name},{us:.1f},{derived}", flush=True)
                rows.append(
                    {"name": name, "us_per_call": round(us, 1),
                     "derived": derived, "retries": retries,
                     "timed_out": False})
        else:
            if exc is not None:
                traceback.print_exception(type(exc), exc, exc.__traceback__)
            failed.append(bench_name)
            detail = (f"TIMEOUT>{args.timeout:.0f}s" if timed_out
                      else f"FAILED:{exc!r}")
            print(f"{bench_name},nan,{detail}", flush=True)
            rows.append(
                {"name": bench_name, "us_per_call": None, "derived": detail,
                 "retries": retries, "timed_out": bool(timed_out)})

    # smoke numbers (tiny configs) and --only subsets must not claim a
    # numbered BENCH_<n> trajectory slot by default: numbered artifacts are
    # diffed row-by-row across PRs, so partial row sets break the
    # comparison (pass --out explicitly to place one deliberately).
    # --only wins over --smoke so a filtered smoke run can never overwrite
    # the full-row BENCH_smoke.json either.
    if args.out:
        out = args.out
    elif args.only:
        out = os.path.join(BENCH_DIR, "BENCH_partial.json")
    elif args.smoke:
        out = os.path.join(BENCH_DIR, "BENCH_smoke.json")
    else:
        _warn_trajectory_gaps()
        out = _next_artifact_path()
    with open(out, "w") as f:
        json.dump({
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "mode": "smoke" if args.smoke else "full",
            "only": args.only,
            "failed": failed,
            "rows": rows,
        }, f, indent=1)
    print(f"# perf artifact -> {out}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benches failed: {failed}")


if __name__ == "__main__":
    main()
