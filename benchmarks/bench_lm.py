"""LM-side benchmarks: reduced-config train/decode throughput per arch
family + analytic-vs-compiled roofline cross-check.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def bench_train_reduced() -> List[Row]:
    from repro.configs import get_arch, reduced
    from repro.data.synth_lm import lm_batch_at
    from repro.models import init_params
    from repro.optim import AdamW
    from repro.train.train_step import make_train_step

    rows: List[Row] = []
    for arch in ("qwen3-4b", "mixtral-8x22b", "jamba-1.5-large-398b",
                 "xlstm-125m", "whisper-small"):
        cfg = reduced(get_arch(arch))
        params = init_params(cfg, jax.random.key(0))
        opt = AdamW(lr=1e-3)
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.int32(0)}
        step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
        extras = {}
        if cfg.n_vision_tokens:
            extras["vision"] = (cfg.n_vision_tokens, cfg.d_model)
        if cfg.enc_dec:
            extras["audio"] = (cfg.n_audio_frames, cfg.d_model)
        B, S = 4, 128
        batch = lm_batch_at(0, vocab=cfg.vocab, batch=B, seq_len=S,
                            extras=extras or None)
        state, m = step(state, batch)       # compile
        t0 = time.perf_counter()
        n = 3
        losses = []
        for i in range(1, n + 1):
            b = lm_batch_at(i, vocab=cfg.vocab, batch=B, seq_len=S,
                            extras=extras or None)
            state, m = step(state, b)
            losses.append(float(m["loss"]))
        dt = (time.perf_counter() - t0) / n
        rows.append((
            f"train_reduced_{arch}", dt * 1e6,
            f"tok_per_s={B*S/dt:,.0f};loss={losses[-1]:.3f}",
        ))
    return rows


def bench_decode_reduced() -> List[Row]:
    from repro.configs import get_arch, reduced
    from repro.models import init_cache, init_params
    from repro.models.model import decode_step

    rows: List[Row] = []
    for arch in ("gemma3-1b", "jamba-1.5-large-398b", "xlstm-125m"):
        cfg = reduced(get_arch(arch))
        params = init_params(cfg, jax.random.key(0))
        B, S = 4, 256
        cache = init_cache(cfg, B, S)
        tok = jnp.ones((B, 1), jnp.int32)

        @jax.jit
        def many(params, cache):
            def body(carry, i):
                tok, cache = carry
                logits, cache = decode_step(params, cache, tok,
                                            i + 10, cfg)
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
                return (nxt, cache), None
            (tok2, cache), _ = jax.lax.scan(body, (tok, cache),
                                            jnp.arange(32))
            return tok2

        out = many(params, cache)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = many(params, cache)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / 32
        rows.append((f"decode_reduced_{arch}", dt * 1e6,
                     f"tok_per_s={B/dt:,.0f}"))
    return rows


def bench_roofline_crosscheck() -> List[Row]:
    """Analytic perfmodel vs compiled dry-run probes (when artifacts exist)."""
    import glob
    import json
    import os

    from repro.configs import SHAPES, get_arch
    from repro.perfmodel import analytic_roofline

    rows: List[Row] = []
    art = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "artifacts")
    files = sorted(glob.glob(os.path.join(art, "*__single.json")))
    n_ok = 0
    ratios = []
    for f in files[:40]:
        d = json.load(open(f))
        if d.get("status") != "OK" or not d.get("probe"):
            continue
        cfg = get_arch(d["arch"])
        est = analytic_roofline(cfg, SHAPES[d["shape"]], n_chips=256)
        got = d["hlo_flops_per_dev"]
        if got > 0 and est.flops_per_dev > 0:
            ratios.append(got / est.flops_per_dev)
            n_ok += 1
    if ratios:
        rows.append((
            "roofline_flops_crosscheck", 0.0,
            f"n={n_ok};median_compiled_over_analytic="
            f"{float(np.median(ratios)):.2f};"
            f"p90={float(np.percentile(ratios, 90)):.2f}",
        ))
    else:
        rows.append(("roofline_flops_crosscheck", 0.0, "no_artifacts_yet"))
    return rows
