"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run JSON artifacts.

  PYTHONPATH=src python -m benchmarks.roofline_table [--artifacts DIR]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MESHES = ("single", "multi")


def load(artifacts: str, tag: str = ""):
    cells = {}
    suffix = f"__{tag}.json" if tag else ".json"
    for f in sorted(glob.glob(os.path.join(artifacts, f"*{suffix}"))):
        base = os.path.basename(f)[: -len(".json")]
        parts = base.split("__")
        if tag and (len(parts) != 4 or parts[3] != tag):
            continue
        if not tag and len(parts) != 3:
            continue
        arch, shape, mesh = parts[:3]
        cells[(arch, shape, mesh)] = json.load(open(f))
    return cells


def fmt_si(x, unit=""):
    if x == 0:
        return "0"
    for div, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(x) >= div:
            return f"{x/div:.2f}{suf}{unit}"
    return f"{x:.2f}{unit}"


def dryrun_table(cells, mesh="single"):
    from repro.configs import SHAPES, arch_names

    lines = [
        "| arch | shape | status | bytes/dev (arg+temp) | FLOPs/dev | "
        "coll bytes/dev | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in arch_names():
        for shape in SHAPES:
            d = cells.get((arch, shape, mesh))
            if d is None:
                lines.append(f"| {arch} | {shape} | MISSING | | | | |")
                continue
            if d["status"] == "SKIP":
                lines.append(
                    f"| {arch} | {shape} | SKIP | {d['reason'][:46]} | | | |")
                continue
            if d["status"] != "OK":
                lines.append(
                    f"| {arch} | {shape} | FAIL | {d.get('error','')[:46]} | | | |")
                continue
            m = d["memory"]
            mem = f"{(m['argument_bytes'])/2**30:.2f}+{m['temp_bytes']/2**30:.2f} GiB"
            lines.append(
                f"| {arch} | {shape} | OK | {mem} | "
                f"{fmt_si(d['hlo_flops_per_dev'])} | "
                f"{fmt_si(d['collective_bytes_per_dev'])}B | "
                f"{d['compile_s']:.0f} |"
            )
    return "\n".join(lines)


def roofline_table(cells):
    from repro.configs import SHAPES, arch_names

    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS/HLO_FLOPS | roofline util | one-liner |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    for arch in arch_names():
        for shape in SHAPES:
            d = cells.get((arch, shape, "single"))
            if d is None or d["status"] != "OK":
                status = "SKIP" if d and d["status"] == "SKIP" else "—"
                lines.append(f"| {arch} | {shape} | {status} | | | | | | |")
                continue
            step = max(d["compute_s"], d["memory_s"], d["collective_s"])
            util = d["compute_s"] / step if step else 0.0
            mfr = d["model_flops_ratio"]
            dom = d["dominant"].replace("_s", "")
            hint = {
                "compute": "raise MFU: fuse/skip redundant FLOPs (remat policy, "
                           "windowed-attn skipping, O(n) scan kernel)",
                "memory": "cut HBM traffic: fuse ops, lower remat, bf16 "
                          "opt-state reads, smaller logit chunks",
                "collective": "cut comms: bigger per-chip batch, 2D-shard "
                              "weight gathers, overlap via scan unroll",
            }[dom]
            rows.append((arch, shape, util, dom))
            lines.append(
                f"| {arch} | {shape} | {d['compute_s']*1e3:.2f}m | "
                f"{d['memory_s']*1e3:.2f}m | {d['collective_s']*1e3:.2f}m | "
                f"{dom} | {mfr:.3f} | {util:.2f} | {hint} |"
            )
    return "\n".join(lines), rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="experiments/artifacts")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    cells = load(args.artifacts, args.tag)
    n_ok = sum(1 for d in cells.values() if d["status"] == "OK")
    n_skip = sum(1 for d in cells.values() if d["status"] == "SKIP")
    n_fail = len(cells) - n_ok - n_skip
    print(f"## cells: {n_ok} OK / {n_skip} SKIP / {n_fail} FAIL\n")
    print("### Dry-run (single-pod 16x16)\n")
    print(dryrun_table(cells, "single"))
    print("\n### Dry-run (multi-pod 2x16x16)\n")
    print(dryrun_table(cells, "multi"))
    print("\n### Roofline (single-pod)\n")
    tbl, rows = roofline_table(cells)
    print(tbl)
    if rows:
        worst = min(rows, key=lambda r: r[2])
        coll = [r for r in rows if r[3] == "collective"]
        print(f"\nworst roofline util: {worst[0]} x {worst[1]} ({worst[2]:.2f})")
        if coll:
            print(f"collective-bound cells: {[(r[0], r[1]) for r in coll]}")


if __name__ == "__main__":
    main()
