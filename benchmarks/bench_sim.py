"""Benchmarks for the simulator-side paper figures.

Each function mirrors one paper table/figure and returns
(name, us_per_call, derived) rows.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _timeit(fn, *args, n=3):
    # block on the warm-up: otherwise async dispatch/compile of the first
    # call leaks into the first timed iteration
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def bench_replay_throughput() -> List[Row]:
    """Paper Fig. 2 (top-left): simulation runtime stats — job throughput
    and energy under trace replay of a TX-GAIA-like workload.

    Three rows share one workload: the stacked per-tick baseline
    (``replay_tx_gaia_1h``, comparable across BENCH artifacts — the
    macro-off row proving the per-tick path is unregressed), the per-tick
    run with summary telemetry (apples-to-apples timing basis), and the
    macro-stepping engine (``macro=True``) whose derived values must match
    the per-tick rows (completed exactly; energy/pue to print precision)."""
    from repro.configs.sim import tx_gaia
    from repro.core import build_statics, init_state, load_jobs, run_episode, summary
    from repro.data import synth_workload

    cfg = tx_gaia(max_jobs=256, max_nodes_per_job=16)
    jobs, bank = synth_workload(cfg, 200, 3600.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    n_steps = 3600

    run = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay"))
    dt = _timeit(run, state, n=2)
    fs, _ = run(state)
    s = summary(fs)
    us_per_step = dt / n_steps * 1e6
    rows = [(
        "replay_tx_gaia_1h", us_per_step,
        f"completed={s['completed']:.0f};energy_kwh={s['energy_kwh']:.1f};"
        f"mean_power_kw={s['mean_power_w']/1e3:.1f};pue={s['avg_pue']:.3f};"
        f"steps_per_s={n_steps/dt:,.0f}",
    )]

    run_s = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay",
                                          summary_only=True))
    dt_s = _timeit(run_s, state, n=2)
    rows.append((
        "replay_tx_gaia_1h_summary", dt_s / n_steps * 1e6,
        f"steps_per_s={n_steps/dt_s:,.0f}",
    ))

    run_m = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay",
                                          macro=True))
    dt_m = _timeit(run_m, state, n=2)
    fs_m, tel_m = run_m(state)
    sm = summary(fs_m, tel_m)
    rows.append((
        "replay_tx_gaia_1h_macro", dt_m / n_steps * 1e6,
        f"completed={sm['completed']:.0f};energy_kwh={sm['energy_kwh']:.1f};"
        f"mean_power_kw={sm['mean_power_w']/1e3:.1f};pue={sm['avg_pue']:.3f};"
        f"steps_per_s={n_steps/dt_m:,.0f};"
        f"speedup_vs_pertick={dt/dt_m:.2f}x;"
        f"speedup_vs_summary={dt_s/dt_m:.2f}x;"
        f"skip_ratio={sm['macro_skip_ratio']:.1f};"
        f"match_pertick={sm['completed'] == s['completed'] and abs(sm['energy_kwh'] - s['energy_kwh']) < 0.05}",
    ))
    return rows


def bench_scheduler_comparison() -> List[Row]:
    """Paper §RAPS schedulers (+ Fan et al. [15] 45% slowdown bar): mean
    job slowdown per policy on a CONTENDED system (a TX-GAIA rack-pair:
    demand ~3x capacity, heavy-tailed durations, node-exclusive jobs)."""
    from repro.configs.sim import NodeType, SimConfig
    from repro.core import build_statics, init_state, load_jobs, run_episode, summary
    from repro.data import synth_workload

    cfg = SimConfig(
        name="tx-gaia-racks",
        node_types=(
            NodeType("txg-v100", 48, 40, 2, 384.0, 240.0, 260.0, 55.0,
                     245.0, 17_900.0),
            NodeType("xeon-p8", 16, 48, 0, 192.0, 160.0, 330.0, 0.0, 0.0,
                     3_300.0),
        ),
        max_jobs=256, max_nodes_per_job=16,
    )
    jobs, bank = synth_workload(cfg, 180, 900.0, seed=3, mean_dur_s=1200.0,
                                arrival="burst")
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)

    rows: List[Row] = []
    results = {}
    for sched in ("fcfs", "sjf", "easy", "priority"):
        run = jax.jit(lambda s, sc=sched: run_episode(cfg, statics, s, 7200, sc))
        dt = _timeit(run, state, n=1)
        fs, _ = run(state)
        s = summary(fs)
        results[sched] = s
        rows.append((
            f"sched_{sched}", dt / 7200 * 1e6,
            f"slowdown={s['mean_slowdown']:.2f};wait_s={s['mean_wait_s']:.0f};"
            f"completed={s['completed']:.0f};energy_kwh={s['energy_kwh']:.1f}",
        ))
    base = results["fcfs"]["mean_slowdown"]
    best = min(r["mean_slowdown"] for r in results.values())
    rows.append((
        "sched_best_vs_fcfs", 0.0,
        f"slowdown_improvement_pct={(base-best)/base*100:.1f} "
        f"(Fan_et_al_reference=45%)",
    ))
    return rows


def bench_rl_training() -> List[Row]:
    """Paper Fig. 2 (top-right): PPO episodic reward over iterations.

    Smoke-budget caveat: 16 iterations x 8 envs x 16-step rollouts is two
    orders of magnitude below the paper's training budget, so whether the
    `improved` flag trips is seed-sensitive at this scale (a sweep showed
    2/4 seeds improving at lr=1e-3, none at the PPO default 3e-4 — the
    advantage signal is dominated by the energy/queue penalty baseline
    until the value head settles). The pinned (seed=0, lr=1e-3) config
    learns reproducibly (-18.3 -> -16.5) and is what this row tracks;
    treat it as "the training loop descends", not a convergence claim —
    see docs/performance.md "PPO smoke row"."""
    from repro.configs.sim import tiny_cluster
    from repro.data import synth_workload
    from repro.envs import SchedEnv
    from repro.rl import PPOConfig, ppo_train

    cfg = tiny_cluster(sched_max_candidates=4)
    wls = [synth_workload(cfg, 32, 1200.0, seed=s) for s in range(3)]
    env = SchedEnv(cfg, wls, episode_steps=16, sim_steps_per_action=10)
    t0 = time.perf_counter()
    n_iter = 16
    _, hist = ppo_train(
        env, cfg=PPOConfig(n_envs=8, rollout_len=16, lr=1e-3),
        n_iterations=n_iter, seed=0,
    )
    dt = time.perf_counter() - t0
    first = np.mean([h["mean_episode_return"] for h in hist[:3]])
    last = np.mean([h["mean_episode_return"] for h in hist[-3:]])
    return [(
        "ppo_scheduler", dt / n_iter * 1e6,
        f"ep_return_first3={first:.2f};ep_return_last3={last:.2f};"
        f"improved={last > first}",
    )]


def bench_power_prediction() -> List[Row]:
    """Paper Fig. 2 (bottom): system power prediction from trace replay.

    Protocol: (1) run FCFS once to obtain a *feasible* recorded schedule
    (start times), (2) reconstruct the ground-truth IT-power trace
    directly from that schedule + per-job telemetry (pure numpy, no
    simulator), (3) REPLAY the recorded schedule in the twin and compare
    power traces (MAPE) and dynamic energy."""
    import numpy as np

    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_episode
    from repro.data import synth_workload

    cfg = tiny_cluster()
    n_jobs, steps = 24, 2400
    jobs, bank = synth_workload(cfg, n_jobs, 1200.0, seed=9)
    statics = build_statics(cfg, bank)
    st0 = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)

    # (1) feasible recorded schedule
    fs, _ = jax.jit(lambda s: run_episode(cfg, statics, s, steps, "fcfs"))(st0)
    starts = np.asarray(fs.start_t)[:n_jobs]

    # (2) ground-truth reconstruction on the sim grid
    caps = np.asarray(statics.capacity)
    idle = float(np.asarray(statics.idle_w).sum())
    cdyn = np.asarray(statics.cpu_dyn_w)
    gdyn = np.asarray(statics.gpu_dyn_w)
    # single-node-type approximation of placement: use mean coefficients of
    # feasible nodes (jobs with gpus -> gpu nodes)
    t_grid = np.arange(1, steps + 1, dtype=np.float32) * cfg.dt
    truth = np.full(steps, idle, np.float32)
    gpu_type, cpu_type = cfg.node_types[0], cfg.node_types[-1]
    for j in range(n_jobs):
        active = (t_grid >= starts[j]) & (t_grid < starts[j] + jobs["dur"][j])
        qi = np.clip(((t_grid - starts[j]) / cfg.trace_quanta).astype(int),
                     0, bank["cpu"].shape[1] - 1)
        is_gpu = jobs["req"][1, j] > 0
        ntype = gpu_type if is_gpu else cpu_type
        cpu_frac = jobs["req"][0, j] / ntype.cpu_cores
        pw = (
            cpu_frac * bank["cpu"][j, qi] * ntype.cpu_dyn_w
            + jobs["req"][1, j] * bank["gpu"][j, qi] * ntype.gpu_dyn_w
        ) * jobs["n_nodes"][j]
        truth += np.where(active, pw, 0.0).astype(np.float32)

    # (3) replay the recorded schedule
    jobs_replay = dict(jobs)
    jobs_replay["priority"] = starts.astype(np.float32)
    st1 = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs_replay)
    run = jax.jit(lambda s: run_episode(cfg, statics, s, steps, "replay"))
    dt = _timeit(run, st1, n=1)
    fs2, outs = run(st1)
    sim_trace = np.asarray(outs.it_w)

    active_mask = truth > idle + 1.0
    mape = float(np.mean(np.abs(sim_trace - truth)[active_mask]
                         / truth[active_mask])) * 100
    sim_dyn = float((sim_trace - idle).sum()) / 3600
    truth_dyn = float((truth - idle).sum()) / 3600
    e_err = abs(sim_dyn - truth_dyn) / max(truth_dyn, 1e-9) * 100
    return [(
        "power_prediction_replay", dt / steps * 1e6,
        f"power_trace_mape_pct={mape:.2f};dyn_energy_err_pct={e_err:.2f};"
        f"sim_Wh={sim_dyn:.0f};truth_Wh={truth_dyn:.0f}",
    )]


def bench_congestion_model() -> List[Row]:
    """Paper: 'RAPS can be used to model network congestion [14]' —
    completion-time stretch vs bisection bandwidth."""
    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_episode
    from repro.data import synth_workload

    rows = []
    base_completed = None
    for bw in (1e9, 100.0, 20.0):
        cfg = tiny_cluster(bisection_gbps=bw, congestion_knee=0.1)
        jobs, bank = synth_workload(cfg, 32, 900.0, seed=4,
                                    net_heavy_fraction=0.8)
        statics = build_statics(cfg, bank)
        st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
        run = jax.jit(lambda s: run_episode(cfg, statics, s, 3000, "fcfs"))
        dt = _timeit(run, st, n=1)
        fs, _ = run(st)
        if base_completed is None:
            base_completed = float(fs.n_completed)
        rows.append((
            f"congestion_bw_{bw:g}", dt / 3000 * 1e6,
            f"completed={float(fs.n_completed):.0f};"
            f"vs_uncongested={float(fs.n_completed)/max(base_completed,1):.2f}",
        ))
    return rows


def bench_macro_smoke() -> List[Row]:
    """CI smoke for the macro-stepping engine: a quiet-heavy replay on the
    tiny cluster, per-tick vs ``macro=True``. The derived field carries
    the speedup and an equivalence check (identical completed count and
    energy to 1e-3 kWh) so the CI gate can assert both without rerunning."""
    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_episode, summary
    from repro.data import synth_workload

    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 12, 1800.0, seed=2)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    n_steps = 1800

    run_p = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay",
                                          summary_only=True))
    run_m = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay",
                                          macro=True))
    dt_p = _timeit(run_p, state, n=2)
    dt_m = _timeit(run_m, state, n=2)
    fs_p, tel_p = run_p(state)
    fs_m, tel_m = run_m(state)
    sp, sm = summary(fs_p, tel_p), summary(fs_m, tel_m)
    match = (sm["completed"] == sp["completed"]
             and abs(sm["energy_kwh"] - sp["energy_kwh"]) < 1e-3)
    return [
        ("replay_macro_smoke_pertick", dt_p / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_p:,.0f};completed={sp['completed']:.0f}"),
        ("replay_macro_smoke", dt_m / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_m:,.0f};completed={sm['completed']:.0f};"
         f"speedup_vs_pertick={dt_p/dt_m:.2f}x;"
         f"skip_ratio={sm['macro_skip_ratio']:.1f};match_pertick={match}"),
    ]


def bench_thermal() -> List[Row]:
    """Thermal-state twin (docs/thermal.md): the TX-GAIA replay hour with
    the rack RC cooling loop in the scan carry, per-tick vs ``macro=True``
    (thermal trip crossings join the breakpoint set). Comparable against
    the thermal-off ``replay_tx_gaia_1h[_macro]`` rows in the same
    artifact: the delta IS the cost of carrying thermal state."""
    from repro.configs.sim import tx_gaia
    from repro.core import build_statics, init_state, load_jobs, run_episode, summary
    from repro.data import synth_workload

    cfg = tx_gaia(max_jobs=256, max_nodes_per_job=16, thermal_enabled=True)
    jobs, bank = synth_workload(cfg, 200, 3600.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    n_steps = 3600

    run_p = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay",
                                          summary_only=True))
    run_m = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay",
                                          macro=True))
    dt_p = _timeit(run_p, state, n=2)
    dt_m = _timeit(run_m, state, n=2)
    fs_p, tel_p = run_p(state)
    fs_m, tel_m = run_m(state)
    sp, sm = summary(fs_p, tel_p), summary(fs_m, tel_m)
    match = (sm["completed"] == sp["completed"]
             and abs(sm["energy_kwh"] - sp["energy_kwh"]) < 0.05)
    return [
        ("replay_tx_gaia_1h_thermal", dt_p / n_steps * 1e6,
         f"completed={sp['completed']:.0f};energy_kwh={sp['energy_kwh']:.1f};"
         f"pue={sp['avg_pue']:.3f};peak_rack_c={sp['peak_rack_outlet_c']:.1f};"
         f"mean_cop={sp['mean_cop']:.2f};steps_per_s={n_steps/dt_p:,.0f}"),
        ("replay_tx_gaia_1h_thermal_macro", dt_m / n_steps * 1e6,
         f"completed={sm['completed']:.0f};energy_kwh={sm['energy_kwh']:.1f};"
         f"pue={sm['avg_pue']:.3f};peak_rack_c={sm['peak_rack_outlet_c']:.1f};"
         f"steps_per_s={n_steps/dt_m:,.0f};"
         f"speedup_vs_pertick={dt_p/dt_m:.2f}x;"
         f"skip_ratio={sm['macro_skip_ratio']:.1f};match_pertick={match}"),
    ]


def bench_thermal_smoke() -> List[Row]:
    """CI smoke for the thermal twin: a stress-tuned tiny cluster whose
    racks cross the dispatch trip mid-episode, per-tick vs macro. The
    derived field asserts the macro run matched per-tick (completed count,
    energy, peak rack temperature) so CI gates exactness, not just
    runnability."""
    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_episode, summary
    from repro.data import synth_workload

    cfg = tiny_cluster(thermal_enabled=True, rack_tau_s=120.0,
                       thermal_trip_c=22.0, throttle_start_c=20.0,
                       throttle_full_c=30.0)
    jobs, bank = synth_workload(cfg, 24, 600.0, seed=8)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    n_steps = 1500

    run_p = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "fcfs",
                                          summary_only=True))
    run_m = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "fcfs",
                                          macro=True))
    dt_p = _timeit(run_p, state, n=2)
    dt_m = _timeit(run_m, state, n=2)
    fs_p, tel_p = run_p(state)
    fs_m, tel_m = run_m(state)
    sp, sm = summary(fs_p, tel_p), summary(fs_m, tel_m)
    match = (sm["completed"] == sp["completed"]
             and abs(sm["energy_kwh"] - sp["energy_kwh"]) < 1e-3
             and abs(sm["peak_rack_outlet_c"] - sp["peak_rack_outlet_c"]) < 1e-4)
    tripped = sp["peak_rack_outlet_c"] >= cfg.thermal_trip_c
    return [
        ("thermal_smoke_pertick", dt_p / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_p:,.0f};completed={sp['completed']:.0f};"
         f"peak_rack_c={sp['peak_rack_outlet_c']:.2f};tripped={tripped}"),
        ("thermal_smoke_macro", dt_m / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_m:,.0f};completed={sm['completed']:.0f};"
         f"speedup_vs_pertick={dt_p/dt_m:.2f}x;"
         f"skip_ratio={sm['macro_skip_ratio']:.1f};match_pertick={match}"),
    ]


def bench_faults() -> List[Row]:
    """Resilience twin (docs/resilience.md): the TX-GAIA replay hour with
    event-sampled node + rack fault clocks, checkpoint/restart and retry
    budgets on, per-tick vs ``macro=True`` (fault crossings join the
    breakpoint set). The old per-tick Bernoulli engine forfeited the
    macro speedup whenever MTBF was finite — the speedup in the macro
    row's derived field is what the clock formulation buys back."""
    from repro.configs.sim import tx_gaia
    from repro.core import build_statics, init_state, load_jobs, run_episode, summary
    from repro.data import synth_workload

    cfg = tx_gaia(max_jobs=256, max_nodes_per_job=16,
                  node_mtbf_hours=6.0, node_repair_hours=0.5,
                  rack_mtbf_hours=48.0, rack_repair_hours=1.0,
                  ckpt_interval_s=900.0, ckpt_overhead_s=30.0,
                  max_job_retries=4)
    jobs, bank = synth_workload(cfg, 200, 3600.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    n_steps = 3600

    run_p = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay",
                                          summary_only=True))
    run_m = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay",
                                          macro=True))
    dt_p = _timeit(run_p, state, n=2)
    dt_m = _timeit(run_m, state, n=2)
    fs_p, tel_p = run_p(state)
    fs_m, tel_m = run_m(state)
    sp, sm = summary(fs_p, tel_p), summary(fs_m, tel_m)
    match = (sm["completed"] == sp["completed"]
             and sm["killed_by_failures"] == sp["killed_by_failures"]
             and abs(sm["energy_kwh"] - sp["energy_kwh"]) < 0.05)
    return [
        ("replay_tx_gaia_1h_faults", dt_p / n_steps * 1e6,
         f"completed={sp['completed']:.0f};killed={sp['killed_by_failures']:.0f};"
         f"lost_node_s={sp['lost_node_seconds']:.0f};"
         f"goodput_frac={sp['goodput_frac']:.3f};"
         f"steps_per_s={n_steps/dt_p:,.0f}"),
        ("replay_tx_gaia_1h_faults_macro", dt_m / n_steps * 1e6,
         f"completed={sm['completed']:.0f};killed={sm['killed_by_failures']:.0f};"
         f"steps_per_s={n_steps/dt_m:,.0f};"
         f"speedup_vs_pertick={dt_p/dt_m:.2f}x;"
         f"skip_ratio={sm['macro_skip_ratio']:.1f};match_pertick={match}"),
    ]


def bench_faults_smoke() -> List[Row]:
    """CI smoke for the fault engine: short-MTBF tiny cluster with rack
    faults + checkpointing, per-tick vs macro. The derived field asserts
    macro matched per-tick (completed, kill count, lost node-seconds,
    energy) AND that faults actually fired, so CI gates the exactness of
    the event-sampled clocks — the property the macro speedup rests on."""
    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_episode, summary
    from repro.data import synth_workload

    cfg = tiny_cluster(node_mtbf_hours=0.5, node_repair_hours=0.2,
                       rack_mtbf_hours=1.5, rack_repair_hours=0.3,
                       ckpt_interval_s=240.0, ckpt_overhead_s=20.0,
                       max_job_retries=3)
    jobs, bank = synth_workload(cfg, 24, 1500.0, seed=3)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    n_steps = 2000

    run_p = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "fcfs",
                                          summary_only=True))
    run_m = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "fcfs",
                                          macro=True))
    dt_p = _timeit(run_p, state, n=2)
    dt_m = _timeit(run_m, state, n=2)
    fs_p, tel_p = run_p(state)
    fs_m, tel_m = run_m(state)
    sp, sm = summary(fs_p, tel_p), summary(fs_m, tel_m)
    match = (sm["completed"] == sp["completed"]
             and sm["killed_by_failures"] == sp["killed_by_failures"]
             and abs(sm["lost_node_seconds"] - sp["lost_node_seconds"]) < 1e-2
             and abs(sm["energy_kwh"] - sp["energy_kwh"]) < 1e-3)
    killed = sp["killed_by_failures"] > 0
    return [
        ("faults_smoke_pertick", dt_p / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_p:,.0f};completed={sp['completed']:.0f};"
         f"killed={sp['killed_by_failures']:.0f};"
         f"goodput_frac={sp['goodput_frac']:.3f};faults_fired={killed}"),
        ("faults_smoke_macro", dt_m / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_m:,.0f};completed={sm['completed']:.0f};"
         f"speedup_vs_pertick={dt_p/dt_m:.2f}x;"
         f"skip_ratio={sm['macro_skip_ratio']:.1f};match_pertick={match}"),
    ]


def bench_vectorized_envs() -> List[Row]:
    """Beyond-paper: the JAX rewrite's RL-scale win — vmapped datacenters."""
    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, make_step
    from repro.data import synth_workload

    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 32, 900.0, seed=0)
    statics = build_statics(cfg, bank)
    st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    step = make_step(cfg, statics, "fcfs")

    rows = []
    for n_envs in (1, 64):
        states = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_envs,) + a.shape), st)
        vstep = jax.jit(jax.vmap(lambda s: step(s, jnp.int32(-1))))

        def run200(states):
            def body(s, _):
                s, out = vstep(s)
                return s, out.facility_w
            return jax.lax.scan(body, states, None, length=200)

        runj = jax.jit(run200)
        dt = _timeit(runj, states, n=2)
        rows.append((
            f"vmapped_sim_{n_envs}envs", dt / 200 * 1e6,
            f"env_steps_per_s={200*n_envs/dt:,.0f}",
        ))
    return rows


def bench_snapshot_overhead() -> List[Row]:
    """Durable-twin cost model (docs/robustness.md): the same
    summary-only replay with snapshotting OFF, at an infinite interval
    (segmented driver, zero disk writes besides the final snapshot) and
    at a finite interval. Snapshot-off must be free — the traced step
    gains no work; the finite-interval row measures what a real
    crash-window buys and costs (host sync + atomic write per segment)."""
    import shutil
    import tempfile

    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_episode
    from repro.data import synth_workload

    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 12, 1800.0, seed=2)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    n_steps = 1800

    run_off = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps,
                                            "replay", summary_only=True))
    dt_off = _timeit(run_off, state, n=2)

    def run_at(every, write):
        d = tempfile.mkdtemp(prefix="bench_snap_") if write else None
        try:
            t0 = time.perf_counter()
            fs, _ = run_episode(cfg, statics, state, n_steps, "replay",
                                summary_only=True, snapshot_every_s=every,
                                snapshot_dir=d)
            jax.block_until_ready(fs.t)
            return time.perf_counter() - t0
        finally:
            if d is not None:
                shutil.rmtree(d, ignore_errors=True)

    # inf + no dir = the segmented driver with zero disk writes: measures
    # the claim that snapshotting adds no work to the traced step
    run_at(float("inf"), write=False)        # compile the segment driver
    dt_inf = min(run_at(float("inf"), write=False) for _ in range(2))
    interval_s = n_steps * float(cfg.dt) / 8  # 8 snapshots per episode
    run_at(interval_s, write=True)
    dt_fin = min(run_at(interval_s, write=True) for _ in range(2))
    return [
        ("replay_snapshot_off", dt_off / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_off:,.0f}"),
        ("replay_snapshot_inf", dt_inf / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_inf:,.0f};"
         f"overhead_vs_off={dt_inf/dt_off - 1:+.1%};"
         f"fixed_ms_per_episode={(dt_inf - dt_off)*1e3:.1f}"),
        ("replay_snapshot_8x", dt_fin / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_fin:,.0f};interval_s={interval_s:.0f};"
         f"overhead_vs_off={dt_fin/dt_off - 1:+.1%};"
         f"us_per_snapshot={(dt_fin - dt_inf)/8*1e6:,.0f}"),
    ]
