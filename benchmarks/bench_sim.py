"""Benchmarks for the simulator-side paper figures.

Each function mirrors one paper table/figure and returns
(name, us_per_call, derived) rows.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _timeit(fn, *args, n=3):
    # block on the warm-up: otherwise async dispatch/compile of the first
    # call leaks into the first timed iteration
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def bench_replay_throughput() -> List[Row]:
    """Paper Fig. 2 (top-left): simulation runtime stats — job throughput
    and energy under trace replay of a TX-GAIA-like workload."""
    from repro.configs.sim import tx_gaia
    from repro.core import build_statics, init_state, load_jobs, run_episode, summary
    from repro.data import synth_workload

    cfg = tx_gaia(max_jobs=256, max_nodes_per_job=16)
    jobs, bank = synth_workload(cfg, 200, 3600.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    n_steps = 3600

    run = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay"))
    dt = _timeit(run, state, n=2)
    fs, _ = run(state)
    s = summary(fs)
    us_per_step = dt / n_steps * 1e6
    derived = (f"completed={s['completed']:.0f};energy_kwh={s['energy_kwh']:.1f};"
               f"mean_power_kw={s['mean_power_w']/1e3:.1f};pue={s['avg_pue']:.3f};"
               f"steps_per_s={n_steps/dt:,.0f}")
    return [("replay_tx_gaia_1h", us_per_step, derived)]


def bench_scheduler_comparison() -> List[Row]:
    """Paper §RAPS schedulers (+ Fan et al. [15] 45% slowdown bar): mean
    job slowdown per policy on a CONTENDED system (a TX-GAIA rack-pair:
    demand ~3x capacity, heavy-tailed durations, node-exclusive jobs)."""
    from repro.configs.sim import NodeType, SimConfig
    from repro.core import build_statics, init_state, load_jobs, run_episode, summary
    from repro.data import synth_workload

    cfg = SimConfig(
        name="tx-gaia-racks",
        node_types=(
            NodeType("txg-v100", 48, 40, 2, 384.0, 240.0, 260.0, 55.0,
                     245.0, 17_900.0),
            NodeType("xeon-p8", 16, 48, 0, 192.0, 160.0, 330.0, 0.0, 0.0,
                     3_300.0),
        ),
        max_jobs=256, max_nodes_per_job=16,
    )
    jobs, bank = synth_workload(cfg, 180, 900.0, seed=3, mean_dur_s=1200.0,
                                arrival="burst")
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)

    rows: List[Row] = []
    results = {}
    for sched in ("fcfs", "sjf", "easy", "priority"):
        run = jax.jit(lambda s, sc=sched: run_episode(cfg, statics, s, 7200, sc))
        dt = _timeit(run, state, n=1)
        fs, _ = run(state)
        s = summary(fs)
        results[sched] = s
        rows.append((
            f"sched_{sched}", dt / 7200 * 1e6,
            f"slowdown={s['mean_slowdown']:.2f};wait_s={s['mean_wait_s']:.0f};"
            f"completed={s['completed']:.0f};energy_kwh={s['energy_kwh']:.1f}",
        ))
    base = results["fcfs"]["mean_slowdown"]
    best = min(r["mean_slowdown"] for r in results.values())
    rows.append((
        "sched_best_vs_fcfs", 0.0,
        f"slowdown_improvement_pct={(base-best)/base*100:.1f} "
        f"(Fan_et_al_reference=45%)",
    ))
    return rows


def bench_rl_training() -> List[Row]:
    """Paper Fig. 2 (top-right): PPO episodic reward over iterations."""
    from repro.configs.sim import tiny_cluster
    from repro.data import synth_workload
    from repro.envs import SchedEnv
    from repro.rl import PPOConfig, ppo_train

    cfg = tiny_cluster(sched_max_candidates=4)
    wls = [synth_workload(cfg, 32, 1200.0, seed=s) for s in range(3)]
    env = SchedEnv(cfg, wls, episode_steps=16, sim_steps_per_action=10)
    t0 = time.perf_counter()
    n_iter = 12
    _, hist = ppo_train(
        env, cfg=PPOConfig(n_envs=8, rollout_len=16), n_iterations=n_iter,
        seed=1,
    )
    dt = time.perf_counter() - t0
    first = np.mean([h["mean_episode_return"] for h in hist[:3]])
    last = np.mean([h["mean_episode_return"] for h in hist[-3:]])
    return [(
        "ppo_scheduler", dt / n_iter * 1e6,
        f"ep_return_first3={first:.2f};ep_return_last3={last:.2f};"
        f"improved={last > first}",
    )]


def bench_power_prediction() -> List[Row]:
    """Paper Fig. 2 (bottom): system power prediction from trace replay.

    Protocol: (1) run FCFS once to obtain a *feasible* recorded schedule
    (start times), (2) reconstruct the ground-truth IT-power trace
    directly from that schedule + per-job telemetry (pure numpy, no
    simulator), (3) REPLAY the recorded schedule in the twin and compare
    power traces (MAPE) and dynamic energy."""
    import numpy as np

    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_episode
    from repro.data import synth_workload

    cfg = tiny_cluster()
    n_jobs, steps = 24, 2400
    jobs, bank = synth_workload(cfg, n_jobs, 1200.0, seed=9)
    statics = build_statics(cfg, bank)
    st0 = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)

    # (1) feasible recorded schedule
    fs, _ = jax.jit(lambda s: run_episode(cfg, statics, s, steps, "fcfs"))(st0)
    starts = np.asarray(fs.start_t)[:n_jobs]

    # (2) ground-truth reconstruction on the sim grid
    caps = np.asarray(statics.capacity)
    idle = float(np.asarray(statics.idle_w).sum())
    cdyn = np.asarray(statics.cpu_dyn_w)
    gdyn = np.asarray(statics.gpu_dyn_w)
    # single-node-type approximation of placement: use mean coefficients of
    # feasible nodes (jobs with gpus -> gpu nodes)
    t_grid = np.arange(1, steps + 1, dtype=np.float32) * cfg.dt
    truth = np.full(steps, idle, np.float32)
    gpu_type, cpu_type = cfg.node_types[0], cfg.node_types[-1]
    for j in range(n_jobs):
        active = (t_grid >= starts[j]) & (t_grid < starts[j] + jobs["dur"][j])
        qi = np.clip(((t_grid - starts[j]) / cfg.trace_quanta).astype(int),
                     0, bank["cpu"].shape[1] - 1)
        is_gpu = jobs["req"][1, j] > 0
        ntype = gpu_type if is_gpu else cpu_type
        cpu_frac = jobs["req"][0, j] / ntype.cpu_cores
        pw = (
            cpu_frac * bank["cpu"][j, qi] * ntype.cpu_dyn_w
            + jobs["req"][1, j] * bank["gpu"][j, qi] * ntype.gpu_dyn_w
        ) * jobs["n_nodes"][j]
        truth += np.where(active, pw, 0.0).astype(np.float32)

    # (3) replay the recorded schedule
    jobs_replay = dict(jobs)
    jobs_replay["priority"] = starts.astype(np.float32)
    st1 = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs_replay)
    run = jax.jit(lambda s: run_episode(cfg, statics, s, steps, "replay"))
    dt = _timeit(run, st1, n=1)
    fs2, outs = run(st1)
    sim_trace = np.asarray(outs.it_w)

    active_mask = truth > idle + 1.0
    mape = float(np.mean(np.abs(sim_trace - truth)[active_mask]
                         / truth[active_mask])) * 100
    sim_dyn = float((sim_trace - idle).sum()) / 3600
    truth_dyn = float((truth - idle).sum()) / 3600
    e_err = abs(sim_dyn - truth_dyn) / max(truth_dyn, 1e-9) * 100
    return [(
        "power_prediction_replay", dt / steps * 1e6,
        f"power_trace_mape_pct={mape:.2f};dyn_energy_err_pct={e_err:.2f};"
        f"sim_Wh={sim_dyn:.0f};truth_Wh={truth_dyn:.0f}",
    )]


def bench_congestion_model() -> List[Row]:
    """Paper: 'RAPS can be used to model network congestion [14]' —
    completion-time stretch vs bisection bandwidth."""
    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_episode
    from repro.data import synth_workload

    rows = []
    base_completed = None
    for bw in (1e9, 100.0, 20.0):
        cfg = tiny_cluster(bisection_gbps=bw, congestion_knee=0.1)
        jobs, bank = synth_workload(cfg, 32, 900.0, seed=4,
                                    net_heavy_fraction=0.8)
        statics = build_statics(cfg, bank)
        st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
        run = jax.jit(lambda s: run_episode(cfg, statics, s, 3000, "fcfs"))
        dt = _timeit(run, st, n=1)
        fs, _ = run(st)
        if base_completed is None:
            base_completed = float(fs.n_completed)
        rows.append((
            f"congestion_bw_{bw:g}", dt / 3000 * 1e6,
            f"completed={float(fs.n_completed):.0f};"
            f"vs_uncongested={float(fs.n_completed)/max(base_completed,1):.2f}",
        ))
    return rows


def bench_vectorized_envs() -> List[Row]:
    """Beyond-paper: the JAX rewrite's RL-scale win — vmapped datacenters."""
    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, make_step
    from repro.data import synth_workload

    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 32, 900.0, seed=0)
    statics = build_statics(cfg, bank)
    st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    step = make_step(cfg, statics, "fcfs")

    rows = []
    for n_envs in (1, 64):
        states = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_envs,) + a.shape), st)
        vstep = jax.jit(jax.vmap(lambda s: step(s, jnp.int32(-1))))

        def run200(states):
            def body(s, _):
                s, out = vstep(s)
                return s, out.facility_w
            return jax.lax.scan(body, states, None, length=200)

        runj = jax.jit(run200)
        dt = _timeit(runj, states, n=2)
        rows.append((
            f"vmapped_sim_{n_envs}envs", dt / 200 * 1e6,
            f"env_steps_per_s={200*n_envs/dt:,.0f}",
        ))
    return rows
