"""Kernel microbenchmarks (interpret mode on CPU — correctness + call cost;
the BlockSpec tiling is what matters for the TPU target, see §Roofline).
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _timeit(fn, *args, n=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def bench_kernels() -> List[Row]:
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    rows: List[Row] = []

    # flash attention 1k ctx
    b, s, h, kv, hd = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, True, 0, 256, 256))
    dt = _timeit(fa, q, k, v)
    want = ref.attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(fa(q, k, v) - want)))
    flops = 4 * b * h * hd * s * s / 2
    rows.append(("pallas_flash_attn_1k", dt * 1e6,
                 f"max_err={err:.1e};gflop={flops/1e9:.1f}"))

    # selective scan
    ba, s2, di, ds = 2, 512, 512, 16
    x = jnp.asarray(rng.normal(size=(ba, s2, di)), jnp.float32)
    dtv = jnp.asarray(rng.uniform(1e-3, 0.1, (ba, s2, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2, (di, ds)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(ba, s2, ds)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(ba, s2, ds)), jnp.float32)
    ss = jax.jit(lambda *a: ops.selective_scan(*a, 64))
    dt2 = _timeit(ss, x, dtv, A, B, C)
    y2, _ = ss(x, dtv, A, B, C)
    yr, _ = ref.selective_scan_ref(x, dtv, A, B, C, chunk=64)
    rows.append(("pallas_selective_scan_512", dt2 * 1e6,
                 f"max_err={float(jnp.max(jnp.abs(y2-yr))):.1e}"))

    # node power (the sim hot loop, batched 64 envs x 672 nodes)
    e, n = 64, 672
    cpu = jnp.asarray(rng.uniform(0, 1, (e, n)), jnp.float32)
    gpu = jnp.asarray(rng.uniform(0, 1, (e, n)), jnp.float32)
    up = jnp.ones((e, n))
    idle = jnp.full((n,), 240.0)
    cd = jnp.full((n,), 260.0)
    gd = jnp.full((n,), 490.0)
    mx = idle + cd + gd
    kw = dict(rect_peak=0.965, rect_load=0.55, rect_curv=0.12, conv_eff=0.975)
    np_k = jax.jit(lambda *a: ops.node_power(*a, **kw))
    dt3 = _timeit(np_k, cpu, gpu, idle, cd, gd, up, mx)
    it, _ = np_k(cpu, gpu, idle, cd, gd, up, mx)
    it2, _ = ref.node_power_ref(cpu, gpu, idle, cd, gd, up, mx, **kw)
    rows.append(("pallas_node_power_64x672", dt3 * 1e6,
                 f"max_err={float(jnp.max(jnp.abs(it-it2))):.1e}"))
    return rows
