"""Serving-twin benchmarks (docs/serving.md).

``bench_serving_smoke`` (CI gate): a bursty overload episode on the tiny
cluster, per-tick vs ``macro=True``. The macro row's derived field
asserts bit-exact agreement on the whole SLO ledger (arrived, completed,
shed, dropped, retried) plus energy — the exactness property the
traffic-burst/timeout/wake breakpoints buy — and the per-tick row
asserts the overload ladder genuinely fired.

``bench_serving`` (full): the diurnal-peak replay — a day-cycle traffic
signal riding on a batch replay, sized from the roofline serving profile
so the pool only saturates around the peak. Macro must BEAT per-tick
here (the trough is quiet), and a PPO smoke row checks the
``w_slo``-weighted return improves when the agent holds the autoscale +
admission knobs.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

Row = Tuple[str, float, str]

from benchmarks.bench_sim import _timeit


def _ladder_cfg():
    from repro.configs.sim import tiny_cluster

    return tiny_cluster(serving_enabled=True, serving_nodes=4,
                        serving_concurrency=4.0, serving_service_s=3.0,
                        serving_queue_cap=60.0, serving_timeout_s=20.0,
                        serving_slo_s=6.0, serving_wake_s=90.0,
                        serving_max_retries=2, serving_backoff_s=5.0)


def bench_serving_smoke() -> List[Row]:
    from repro.configs.sim import tiny_cluster  # noqa: F401 (doc pointer)
    from repro.core import build_statics, init_state, load_jobs, run_episode, summary
    from repro.data import synth_workload
    from repro.scenarios import diurnal_serving

    cfg = _ladder_cfg()
    scn = diurnal_serving(cfg, peak_rps=8.0, base_frac=0.05,
                          period_s=1800.0, burst_start_s=600.0,
                          burst_len_s=200.0, burst_mult=4.0)
    jobs, bank = synth_workload(cfg, 24, 900.0, seed=7)
    statics = build_statics(cfg, bank, scenario=scn)
    state = load_jobs(init_state(cfg, statics, jax.random.key(1)), jobs)
    n_steps = 1800

    run_p = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "fcfs",
                                          summary_only=True))
    run_m = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "fcfs",
                                          macro=True))
    dt_p = _timeit(run_p, state, n=2)
    dt_m = _timeit(run_m, state, n=2)
    fs_p, tel_p = run_p(state)
    fs_m, tel_m = run_m(state)
    sp, sm = summary(fs_p, tel_p), summary(fs_m, tel_m)
    # tiny cluster = shared (dense-scatter) power path -> the whole SLO
    # ledger must agree bit-exactly, energy to float-print precision
    match = all(sm[k] == sp[k] for k in
                ("srv_arrived", "srv_completed", "srv_shed", "srv_dropped",
                 "srv_retried", "completed")) \
        and abs(sm["energy_kwh"] - sp["energy_kwh"]) < 1e-3
    shed = sp["srv_shed"] > 0 and sp["srv_dropped"] > 0 \
        and sp["srv_retried"] > 0
    return [
        ("serving_smoke_pertick", dt_p / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_p:,.0f};arrived={sp['srv_arrived']:.0f};"
         f"completed={sp['srv_completed']:.0f};"
         f"viol_frac={sp['srv_slo_violation_frac']:.3f};shed={shed}"),
        ("serving_smoke_macro", dt_m / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_m:,.0f};"
         f"speedup_vs_pertick={dt_p/dt_m:.2f}x;"
         f"skip_ratio={sm['macro_skip_ratio']:.1f};match_pertick={match}"),
    ]


def bench_serving() -> List[Row]:
    from repro.configs.sim import tiny_cluster
    from repro.core import build_statics, init_state, load_jobs, run_episode, summary
    from repro.data import synth_workload
    from repro.envs import SchedEnv
    from repro.perfmodel import serving_profile
    from repro.rl import PPOConfig, ppo_train
    from repro.scenarios import diurnal_serving

    # size the pool from the roofline serving profile so the diurnal peak
    # just saturates it: quiet troughs (macro skips), loud peak (ladder)
    prof = serving_profile("gemma3-1b", n_chips=16, gen_tokens=256)
    cap_rps = (4 * prof["serving_concurrency"]
               / max(prof["serving_service_s"], 1e-9))
    # the trough must be deeply quiet for macro to win: the crossing
    # horizon is headroom / peak-rate, so a long timeout window and a
    # deep queue keep the bound tens of ticks wide off-peak while the
    # peak still (briefly) saturates the pool
    cfg = tiny_cluster(
        serving_enabled=True, serving_nodes=4, **prof,
        serving_queue_cap=60.0 * cap_rps,
        serving_timeout_s=20.0 * prof["serving_service_s"],
        serving_slo_s=3.0 * prof["serving_service_s"],
        serving_backoff_s=4.0 * prof["serving_service_s"])
    scn = diurnal_serving(cfg, peak_rps=1.05 * cap_rps, base_frac=0.1,
                          period_s=21600.0,
                          burst_start_s=12000.0, burst_len_s=900.0,
                          burst_mult=1.5)
    jobs, bank = synth_workload(cfg, 48, 7200.0, seed=2)
    statics = build_statics(cfg, bank, scenario=scn)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    n_steps = 21600                                  # one full day cycle

    run_p = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay",
                                          summary_only=True))
    run_m = jax.jit(lambda s: run_episode(cfg, statics, s, n_steps, "replay",
                                          macro=True))
    dt_p = _timeit(run_p, state, n=2)
    dt_m = _timeit(run_m, state, n=2)
    fs_p, tel_p = run_p(state)
    fs_m, tel_m = run_m(state)
    sp, sm = summary(fs_p, tel_p), summary(fs_m, tel_m)
    match = all(sm[k] == sp[k] for k in
                ("srv_arrived", "srv_completed", "srv_shed", "completed")) \
        and abs(sm["energy_kwh"] - sp["energy_kwh"]) < 0.05
    rows = [
        ("serving_diurnal_day_pertick", dt_p / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_p:,.0f};arrived={sp['srv_arrived']:.0f};"
         f"completed={sp['srv_completed']:.0f};shed={sp['srv_shed']:.0f};"
         f"p99_x_slo={sp['srv_p99_latency_x_slo']:.1f};"
         f"viol_frac={sp['srv_slo_violation_frac']:.3f}"),
        ("serving_diurnal_day_macro", dt_m / n_steps * 1e6,
         f"steps_per_s={n_steps/dt_m:,.0f};"
         f"speedup_vs_pertick={dt_p/dt_m:.2f}x;"
         f"skip_ratio={sm['macro_skip_ratio']:.1f};match_pertick={match}"),
    ]
    assert match, "macro diverged from per-tick on the serving ledger"
    assert dt_m < dt_p, (
        f"macro ({dt_m:.3f}s) must beat per-tick ({dt_p:.3f}s) on the "
        "diurnal-peak day")

    # PPO smoke with the autoscale + admission actions and a dominant SLO
    # penalty: the w_slo-weighted return must improve (same caveats as
    # the ppo_scheduler row: descent, not convergence)
    env_cfg = _ladder_cfg()
    env_scn = diurnal_serving(env_cfg, peak_rps=10.0, period_s=1800.0,
                              burst_start_s=600.0, burst_len_s=300.0,
                              burst_mult=2.0)
    wls = [synth_workload(env_cfg, 16, 1200.0, seed=s) for s in range(2)]
    env = SchedEnv(env_cfg, wls, episode_steps=16, sim_steps_per_action=10,
                   scenario=env_scn,
                   reward_weights=(1.0, 1.0, 1.0, 0.05, 0.0, 0.0, 5.0))
    t0 = time.perf_counter()
    n_iter = 16
    _, hist = ppo_train(
        env, cfg=PPOConfig(n_envs=8, rollout_len=16, lr=1e-3),
        n_iterations=n_iter, seed=0,
    )
    dt = time.perf_counter() - t0
    first = np.mean([h["mean_episode_return"] for h in hist[:3]])
    last = np.mean([h["mean_episode_return"] for h in hist[-3:]])
    rows.append((
        "serving_ppo_slo", dt / n_iter * 1e6,
        f"ep_return_first3={first:.2f};ep_return_last3={last:.2f};"
        f"improved={last > first}"))
    return rows
