"""RL rollout-engine benchmarks (BENCH_4 `rollout` family).

The paper's headline use case is PPO experimentation over the twin, so the
benchmarked unit here is the *env transition* (one agent decision =
``sim_steps_per_action`` sim steps) inside a full jitted rollout —
``vmap`` over envs, ``lax.scan`` over time, auto-reset included — plus one
``ppo_iteration`` row for the end-to-end train step.

``rollout_256envs_prepr_baseline`` re-creates the pre-PR4 rollout layout
(`_HeavyEnv`): a per-env ``Statics`` copy in the env state (so every
vmapped env carries its own (J, Q) trace-bank slice, auto-reset gathers a
fresh slice per env per rollout step, and the rollout's done-select copies
the whole batched bank), ``make_step`` rebuilt on every ``step`` call, and
the dispatch stage forced through every idle sub-step. Diffing it against
``rollout_256envs`` inside the same artifact is the PR's perf claim.
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from benchmarks.bench_sim import _timeit, Row


def _make_env(n_jobs=32, horizon=3600.0, spa=5, episode_steps=16):
    from repro.configs.sim import tiny_cluster
    from repro.data import synth_workload
    from repro.envs import SchedEnv

    cfg = tiny_cluster(sched_max_candidates=4)
    wls = [synth_workload(cfg, n_jobs, horizon, seed=s) for s in range(4)]
    return SchedEnv(cfg, wls, episode_steps=episode_steps,
                    sim_steps_per_action=spa)


# --------------------------------------------------------------- baseline
class _HeavyState(NamedTuple):
    sim: object
    statics: object           # per-env Statics copy (the pre-PR4 layout)
    step_count: jax.Array


class _HeavyEnv:
    """Pre-PR4 rollout layout around the same twin (see module docstring).
    Dynamics-equivalent to ``SchedEnv`` — only the data layout and the
    per-sub-step dispatch differ — so the us/env-transition diff isolates
    the engine change."""

    def __init__(self, env):
        from repro.core.sim import make_step

        self._env = env
        self.cfg = env.cfg
        self.k, self.n_actions = env.k, env.n_actions
        self.obs_dim = env.obs_dim
        self.episode_steps = env.episode_steps
        self.sim_steps_per_action = env.sim_steps_per_action
        self._make_step = make_step

    def reset(self, key):
        env = self._env
        from repro.core.state import QUEUED, init_state

        kw, ks = jax.random.split(key)
        w = jax.random.randint(kw, (), 0, env.n_workloads)
        bank = env.statics
        statics = bank._replace(                 # per-env bank slice gather
            cpu_trace=bank.cpu_trace[w],
            gpu_trace=bank.gpu_trace[w],
            net_tx=bank.net_tx[w],
        )
        sim = init_state(env.cfg, statics, ks)
        jobs = env._jobs
        n = jobs["n_valid"][w]
        valid = jnp.arange(env.cfg.max_jobs) < n
        sim = sim._replace(
            jstate=jnp.where(valid, QUEUED, 0).astype(jnp.int32),
            submit_t=jobs["submit_t"][w],
            dur_est=jobs["dur"][w], work_left=jobs["dur"][w],
            n_nodes=jnp.where(valid, jobs["n_nodes"][w], 0).astype(jnp.int32),
            req=jobs["req"][w],
            part=jnp.where(valid, jobs["part"][w], -1).astype(jnp.int32),
            priority=jobs["priority"][w],
        )
        st = _HeavyState(sim=sim, statics=statics, step_count=jnp.int32(0))
        return st, self.observe(st)

    def step(self, st, action):
        env = self._env
        # pre-PR4: step fn rebuilt per call, dispatch runs in EVERY sub-step
        step_fn = self._make_step(env.cfg, st.statics, "rl",
                                  placement=env.placement,
                                  reward_weights=env.reward_weights)

        def sub(carry, i):
            s, acc = carry
            a = jnp.where(i == 0, action, jnp.int32(self.n_actions - 1))
            s, out = step_fn(s, a)
            acc = {
                "reward": acc["reward"] + out.reward,
                "completed": acc["completed"] + out.completed_now,
                "energy_kwh": acc["energy_kwh"] + out.energy_kwh_step,
                "carbon_kg": acc["carbon_kg"] + out.carbon_kg_step,
                "facility_w": out.facility_w, "queue_len": out.queue_len,
            }
            return (s, acc), None

        z = jnp.float32(0.0)
        acc0 = {"reward": z, "completed": z, "energy_kwh": z,
                "carbon_kg": z, "facility_w": z, "queue_len": z}
        (sim, acc), _ = jax.lax.scan(
            sub, (st.sim, acc0), jnp.arange(self.sim_steps_per_action))
        st = _HeavyState(sim=sim, statics=st.statics,
                         step_count=st.step_count + 1)
        done = st.step_count >= self.episode_steps
        info = {k: acc[k] for k in
                ("facility_w", "queue_len", "completed", "energy_kwh",
                 "carbon_kg")}
        return st, self.observe(st), acc["reward"], done, info

    def observe(self, st):
        # pre-PR4 feature path: python per-(type, resource) loop of scalar
        # reductions + per-candidate feasibility with the backend mask
        # recomputed inside the vmap
        from repro.core import placement as plc
        from repro.core import schedulers as sched
        from repro.core.state import RUNNING
        from repro.scenarios import eval_signal, power_cap_at

        env = self._env
        cfg, sim, statics = env.cfg, st.sim, st.statics
        day = 2 * jnp.pi * sim.t / cfg.day_seconds
        queued = jnp.sum(sched.queued_mask(sim)).astype(jnp.float32)
        running = jnp.sum(sim.jstate == RUNNING).astype(jnp.float32)
        scn = statics.scenario
        co2 = eval_signal(scn.carbon, sim.t) / max(cfg.carbon_mean, 1.0)
        price = eval_signal(scn.price, sim.t) / max(cfg.price_mean_usd_kwh, 1e-6)
        cap_w = power_cap_at(scn.power_cap, sim.t)
        nameplate = jnp.maximum(jnp.sum(statics.node_max_w), 1.0)
        cap_frac = jnp.where(cap_w > 0, jnp.minimum(cap_w / nameplate, 1.0), 1.0)
        glob = jnp.stack([
            jnp.sin(day), jnp.cos(day), co2, price, cap_frac,
            queued / cfg.max_jobs, running / cfg.max_jobs,
            jnp.sum(sim.node_up) / cfg.n_nodes,
            sim.t / cfg.day_seconds,
            st.step_count.astype(jnp.float32) / max(self.episode_steps, 1),
        ])
        per_type = []
        for ti in range(cfg.n_types):
            m = (statics.node_type == ti).astype(jnp.float32)
            for r in range(3):
                cap = jnp.sum(statics.capacity[r] * m)
                free = jnp.sum(sim.free[r] * m * sim.node_up)
                per_type.append(free / jnp.maximum(cap, 1e-6))
        per_type = jnp.stack(per_type)
        cands = sched.rl_candidates(cfg, sim)
        safe = jnp.maximum(cands, 0)
        valid = (cands >= 0).astype(jnp.float32)
        wait = jnp.maximum(sim.t - sim.submit_t[safe], 0.0) / 3600.0
        dur = sim.dur_est[safe] / 3600.0
        nn = sim.n_nodes[safe].astype(jnp.float32) / cfg.max_nodes_per_job
        reqf = sim.req[:, safe] / jnp.maximum(
            jnp.max(statics.capacity, axis=1, keepdims=True), 1e-6)
        eproxy = nn * dur
        feasible = jax.vmap(
            lambda j: jnp.sum(
                plc.feasible_under(env.placement, sim, statics, j))
        )(safe).astype(jnp.float32) / cfg.n_nodes
        cand_feats = jnp.concatenate([
            valid, wait * valid, dur * valid, nn * valid,
            reqf[0] * valid, reqf[1] * valid, eproxy * valid,
            feasible * valid,
        ])
        return jnp.concatenate(
            [glob, env._place_onehot, per_type, cand_feats]
        ).astype(jnp.float32)


# -------------------------------------------------------------- rollouts
def _time_rollout(env, n_envs: int, rollout_len: int) -> Tuple[float, float]:
    """Returns (us per env-transition, env-transitions per second)."""
    from repro.rl import ActorCritic
    from repro.rl.ppo import PPOConfig, make_rollout

    policy = ActorCritic(env.obs_dim, env.n_actions, hidden=(64, 64))
    params = policy.init(jax.random.key(0))
    cfg = PPOConfig(n_envs=n_envs, rollout_len=rollout_len)
    rollout = jax.jit(make_rollout(env, policy, cfg))
    states, _ = jax.jit(jax.vmap(env.reset))(
        jax.random.split(jax.random.key(1), n_envs))
    dt = _timeit(lambda s: rollout(params, s, jax.random.key(2)), states, n=2)
    n_tr = n_envs * rollout_len
    return dt / n_tr * 1e6, n_tr / dt


def bench_rl(smoke: bool = False) -> List[Row]:
    """`rollout_<n>envs` (us per env-transition, auto-reset included), the
    pre-PR4 heavy-state baseline at 256 envs, and `ppo_iteration`."""
    env = _make_env()
    rows: List[Row] = []
    sizes = (16,) if smoke else (16, 256, 1024)
    for n_envs in sizes:
        us, tps = _time_rollout(env, n_envs, rollout_len=8)
        rows.append((f"rollout_{n_envs}envs", us,
                     f"env_transitions_per_s={tps:,.0f};"
                     f"sim_steps_per_transition={env.sim_steps_per_action}"))
    if smoke:
        return rows

    us, tps = _time_rollout(_HeavyEnv(env), 256, rollout_len=8)
    rows.append(("rollout_256envs_prepr_baseline", us,
                 f"env_transitions_per_s={tps:,.0f};"
                 "layout=per_env_statics+per_substep_dispatch"))

    # one full PPO iteration (rollout + GAE + minibatched epochs)
    from repro.rl import ActorCritic
    from repro.rl.ppo import PPOConfig, make_train_iteration

    pcfg = PPOConfig(n_envs=64, rollout_len=16, n_epochs=2, n_minibatches=4)
    policy = ActorCritic(env.obs_dim, env.n_actions, hidden=(64, 64))
    iteration, opt = make_train_iteration(env, policy, pcfg)
    it_jit = jax.jit(iteration)
    params = policy.init(jax.random.key(0))
    opt_state = opt.init(params)
    states, _ = jax.jit(jax.vmap(env.reset))(
        jax.random.split(jax.random.key(1), pcfg.n_envs))
    z = jnp.zeros((pcfg.n_envs,), jnp.float32)
    zi = jnp.zeros((pcfg.n_envs,), jnp.int32)
    ep = {"ret": z, "len": zi, "fin_ret": z, "fin_len": zi}
    dt = _timeit(
        lambda p, o, s: it_jit(p, o, s, ep, jax.random.key(2), jnp.int32(0)),
        params, opt_state, states, n=2)
    n_tr = pcfg.n_envs * pcfg.rollout_len
    rows.append(("ppo_iteration", dt * 1e6,
                 f"n_envs={pcfg.n_envs};rollout_len={pcfg.rollout_len};"
                 f"env_transitions_per_s={n_tr / dt:,.0f}"))
    return rows
