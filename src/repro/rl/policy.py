"""MLP actor-critic (shared torso, categorical policy head + value head)."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ActorCritic:
    def __init__(self, obs_dim: int, n_actions: int, hidden=(128, 128)):
        self.obs_dim = obs_dim
        self.n_actions = n_actions
        self.hidden = tuple(hidden)

    def init(self, key: jax.Array) -> Dict[str, Any]:
        sizes = (self.obs_dim,) + self.hidden
        params: Dict[str, Any] = {}
        keys = jax.random.split(key, len(sizes) + 2)
        for i in range(len(sizes) - 1):
            std = np.sqrt(2.0 / sizes[i])
            params[f"w{i}"] = std * jax.random.normal(keys[i], (sizes[i], sizes[i + 1]))
            params[f"b{i}"] = jnp.zeros((sizes[i + 1],))
        params["w_pi"] = 0.01 * jax.random.normal(keys[-2], (sizes[-1], self.n_actions))
        params["b_pi"] = jnp.zeros((self.n_actions,))
        params["w_v"] = 1.0 * jax.random.normal(keys[-1], (sizes[-1], 1)) / np.sqrt(sizes[-1])
        params["b_v"] = jnp.zeros((1,))
        return jax.tree.map(lambda x: x.astype(jnp.float32), params)

    def apply(self, params, obs: jax.Array) -> Tuple[jax.Array, jax.Array]:
        h = obs
        for i in range(len(self.hidden)):
            h = jnp.tanh(h @ params[f"w{i}"] + params[f"b{i}"])
        logits = h @ params["w_pi"] + params["b_pi"]
        value = (h @ params["w_v"] + params["b_v"])[..., 0]
        return logits, value

    def act(self, params, obs, key):
        logits, value = self.apply(params, obs)
        action = jax.random.categorical(key, logits)
        logp = jax.nn.log_softmax(logits)
        lp = jnp.take_along_axis(logp, action[..., None], axis=-1)[..., 0]
        return action, lp, value
