"""PPO (clipped surrogate) implemented from scratch in JAX, end-to-end
jitted: vectorized env rollouts (vmap over N parallel datacenters with
auto-reset), GAE, minibatched clipped-objective epochs, AdamW — the
paper's "initial RL infrastructure" (SB3 PPO) rebuilt JAX-native so the
entire train iteration — including the simulator — is one XLA program.

``ppo_train`` fuses iterations into ``lax.scan`` chunks: the Python loop
used to dispatch one jitted iteration at a time and then ``float()`` every
stat — a host sync per iteration. Now ``sync_every`` iterations run as one
XLA program and ONE ``device_get`` drains the chunk's stacked stats, so
the host touches the device once per log window.

``data_axis`` optionally shard_maps the rollout+update across the mesh
(distributed PPO: per-shard rollouts, psum'd gradients).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import AdamW
from repro.rl.gae import gae
from repro.rl.policy import ActorCritic


@dataclass(frozen=True)
class PPOConfig:
    n_envs: int = 16
    rollout_len: int = 64
    n_epochs: int = 4
    n_minibatches: int = 4
    gamma: float = 0.99
    lam: float = 0.95
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    lr: float = 3e-4
    max_grad_norm: float = 0.5


class Transition(NamedTuple):
    obs: jax.Array
    action: jax.Array
    logp: jax.Array
    value: jax.Array
    reward: jax.Array
    done: jax.Array


def make_rollout(env, policy: ActorCritic, cfg: PPOConfig):
    """Returns rollout(params, env_states, key, ep=None) ->
    (env_states, batch, last_val, ep). ``ep`` is the per-env episode
    accumulator {ret, len, fin_ret, fin_len} (running return/length plus
    the last FINISHED episode's return/length); thread it across rollout
    calls — as ``ppo_train``'s iteration carry does — so episodes spanning
    rollout windows report their true totals. ``None`` starts from zeros
    (window-local stats).

    Auto-reset is cheap by construction: ``EnvState`` is sim-state only
    (the trace bank lives in ONE shared Statics indexed by the traced
    workload id), so the per-step ``v_reset`` moves O(n_envs x sim-state),
    never O(n_envs x bank)."""

    v_step = jax.vmap(env.step)
    v_reset = jax.vmap(env.reset)
    v_obs = jax.vmap(env.observe)

    def rollout(params, env_states, key, ep=None):
        obs0 = v_obs(env_states)
        if ep is None:
            # zero-inits derived from obs0 keep their VMA type under
            # shard_map; without a threaded carry the episode stats are
            # window-local (an episode spanning rollouts reports only the
            # steps/reward inside the window that finished it)
            z = obs0[:, 0] * 0.0
            ep = {"ret": z, "len": z.astype(jnp.int32),
                  "fin_ret": z, "fin_len": z.astype(jnp.int32)}

        def one(carry, _):
            states, obs, key, ep_ret, ep_len, fin_ret, fin_len = carry
            key, ka, kr = jax.random.split(key, 3)
            logits, values = policy.apply(params, obs)
            actions = jax.vmap(
                lambda l, k: jax.random.categorical(k, l)
            )(logits, jax.random.split(ka, cfg.n_envs))
            logp_all = jax.nn.log_softmax(logits)
            logps = jnp.take_along_axis(logp_all, actions[:, None], axis=-1)[:, 0]
            states, nobs, rew, done, info = v_step(states, actions)
            ep_ret = ep_ret + rew
            ep_len = ep_len + 1
            fin_ret = jnp.where(done, ep_ret, fin_ret)
            fin_len = jnp.where(done, ep_len, fin_len)
            # auto-reset finished envs
            rkeys = jax.random.split(kr, cfg.n_envs)
            fresh_states, fresh_obs = v_reset(rkeys)
            states = jax.tree.map(
                lambda f, s: jnp.where(
                    done.reshape((-1,) + (1,) * (s.ndim - 1)), f, s
                ), fresh_states, states,
            )
            nobs = jnp.where(done[:, None], fresh_obs, nobs)
            ep_ret = jnp.where(done, 0.0, ep_ret)
            ep_len = jnp.where(done, 0, ep_len)
            tr = Transition(obs, actions, logps, values, rew, done)
            return (states, nobs, key, ep_ret, ep_len, fin_ret, fin_len), tr

        init = (env_states, obs0, key,
                ep["ret"], ep["len"], ep["fin_ret"], ep["fin_len"])
        (states, obs, _, ep_ret, ep_len, fin_ret, fin_len), batch = \
            jax.lax.scan(one, init, None, length=cfg.rollout_len)
        _, last_val = policy.apply(params, obs)
        ep = {"ret": ep_ret, "len": ep_len,
              "fin_ret": fin_ret, "fin_len": fin_len}
        return states, batch, last_val, ep

    return rollout


def ppo_loss(policy, params, batch: Transition, adv, ret, cfg: PPOConfig):
    logits, value = policy.apply(params, batch.obs)
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, batch.action[..., None], axis=-1)[..., 0]
    ratio = jnp.exp(logp - batch.logp)
    adv_n = (adv - adv.mean()) / (adv.std() + 1e-8)
    pg1 = ratio * adv_n
    pg2 = jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv_n
    pg_loss = -jnp.mean(jnp.minimum(pg1, pg2))
    v_clip = batch.value + jnp.clip(value - batch.value, -cfg.clip_eps, cfg.clip_eps)
    v_loss = 0.5 * jnp.mean(
        jnp.maximum(jnp.square(value - ret), jnp.square(v_clip - ret))
    )
    ent = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1))
    total = pg_loss + cfg.vf_coef * v_loss - cfg.ent_coef * ent
    approx_kl = jnp.mean(batch.logp - logp)
    return total, {"pg_loss": pg_loss, "v_loss": v_loss, "entropy": ent,
                   "approx_kl": approx_kl}


def make_train_iteration(env, policy: ActorCritic, cfg: PPOConfig):
    """One fully-jitted PPO iteration: rollout -> GAE -> epochs of
    minibatched updates."""
    opt = AdamW(lr=cfg.lr, b2=0.999, weight_decay=0.0)
    rollout = make_rollout(env, policy, cfg)

    def iteration(params, opt_state, env_states, ep, key, step):
        key, kroll, kperm = jax.random.split(key, 3)
        env_states, batch, last_val, ep = rollout(params, env_states, kroll,
                                                  ep)
        adv, ret = gae(batch.reward, batch.value, batch.done, last_val,
                       gamma=cfg.gamma, lam=cfg.lam)

        # flatten (T, N) -> (T*N,)
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batch)
        adv_f = adv.reshape(-1)
        ret_f = ret.reshape(-1)
        B = adv_f.shape[0]
        mb = B // cfg.n_minibatches

        def epoch(carry, ke):
            params, opt_state = carry
            perm = jax.random.permutation(ke, B)

            def minibatch(carry, i):
                params, opt_state = carry
                idx = jax.lax.dynamic_slice_in_dim(perm, i * mb, mb)
                mb_batch = jax.tree.map(lambda x: x[idx], flat)
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: ppo_loss(policy, p, mb_batch, adv_f[idx],
                                       ret_f[idx], cfg), has_aux=True
                )(params)
                from repro.optim.base import clip_by_global_norm

                grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
                params, opt_state = opt.update(grads, opt_state, params, step)
                return (params, opt_state), metrics

            (params, opt_state), metrics = jax.lax.scan(
                minibatch, (params, opt_state), jnp.arange(cfg.n_minibatches)
            )
            return (params, opt_state), metrics

        (params, opt_state), metrics = jax.lax.scan(
            epoch, (params, opt_state), jax.random.split(kperm, cfg.n_epochs)
        )
        stats = {
            "mean_reward": jnp.mean(batch.reward),
            "mean_episode_return": jnp.mean(ep["fin_ret"]),
            "mean_episode_len": jnp.mean(ep["fin_len"].astype(jnp.float32)),
            "mean_value": jnp.mean(batch.value),
            **{k: jnp.mean(v) for k, v in
               jax.tree.map(lambda x: x, metrics).items()},
        }
        return params, opt_state, env_states, ep, key, stats

    return iteration, opt


def ppo_train(
    env,
    *,
    cfg: PPOConfig = PPOConfig(),
    n_iterations: int = 20,
    seed: int = 0,
    hidden=(128, 128),
    log: Optional[Callable[[int, Dict[str, float]], None]] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 10,
    resume: bool = False,
    sync_every: Optional[int] = None,
):
    """Train a PPO scheduler on `env`. Returns (params, history).

    Iterations are fused into ``lax.scan`` chunks of ``sync_every`` (default:
    ``checkpoint_every`` when checkpointing, else min(n_iterations, 8)):
    per-iteration stats stack inside the scan and ONE ``device_get`` drains
    each chunk — the per-iteration Python dispatch + ``float()``-per-stat
    host sync is gone. ``log`` still fires once per iteration (from host
    data, after its chunk completes) and checkpoints land at exactly the
    iterations the unfused loop produced. Each distinct chunk length is
    one compilation: 2 in the common case (full + remainder); when
    ``sync_every`` does not divide ``checkpoint_every`` the
    checkpoint-boundary cuts can add a couple more.

    Checkpoints cover the FULL training state — params, optimizer, the
    vectorized env fleet states, episode accumulators and the PRNG key —
    plus a run fingerprint, so ``resume=True`` continues bit-identically
    to the uninterrupted run (a fingerprint mismatch raises a typed
    ``CheckpointError``; legacy params-only checkpoints resume warm with
    fresh envs)."""
    policy = ActorCritic(env.obs_dim, env.n_actions, hidden)
    iteration, opt = make_train_iteration(env, policy, cfg)

    def chunk(params, opt_state, env_states, ep, key, steps):
        def body(carry, step):
            params, opt_state, env_states, ep, key = carry
            params, opt_state, env_states, ep, key, stats = iteration(
                params, opt_state, env_states, ep, key, step)
            return (params, opt_state, env_states, ep, key), stats

        (params, opt_state, env_states, ep, key), stats = jax.lax.scan(
            body, (params, opt_state, env_states, ep, key), steps)
        return params, opt_state, env_states, ep, key, stats

    chunk_jit = jax.jit(chunk)

    key = jax.random.key(seed)
    key, kp, ke = jax.random.split(key, 3)
    params = policy.init(kp)
    opt_state = opt.init(params)
    env_states, _ = jax.vmap(env.reset)(jax.random.split(ke, cfg.n_envs))
    # episode accumulators persist across iterations (and chunks), so
    # episodes spanning rollout windows report true returns/lengths
    z = jnp.zeros((cfg.n_envs,), jnp.float32)
    zi = jnp.zeros((cfg.n_envs,), jnp.int32)
    ep = {"ret": z, "len": zi, "fin_ret": z, "fin_len": zi}
    start_iter = 0

    fingerprint = _train_fingerprint(env, cfg, seed, hidden, n_iterations)

    if checkpoint_dir and resume:
        from repro.checkpoint import latest_step, restore
        from repro.checkpoint.ckpt import read_meta
        from repro.checkpoint.episode import check_fingerprint

        step0 = latest_step(checkpoint_dir)
        if step0 is not None:
            meta = read_meta(checkpoint_dir, step0)
            saved_fp = meta.get("extra", {}).get("fingerprint")
            if saved_fp is not None:
                check_fingerprint(saved_fp, fingerprint, checkpoint_dir)
            full = any(k.startswith("env_states")
                       for k in meta.get("leaves", {}))
            if full:
                payload = restore(
                    checkpoint_dir, step0,
                    {"params": params, "opt": opt_state,
                     "env_states": env_states, "ep": ep, "key": key})
                params, opt_state = payload["params"], payload["opt"]
                env_states, ep = payload["env_states"], payload["ep"]
                key = payload["key"]
            else:
                # legacy params-only checkpoint: warm resume (fresh envs/
                # key — learning continues but is not bit-exact)
                payload = restore(checkpoint_dir, step0,
                                  {"params": params, "opt": opt_state})
                params, opt_state = payload["params"], payload["opt"]
            start_iter = step0 + 1

    if sync_every is None:
        # cap the default: the chunk body is a full PPO iteration, so an
        # uncapped checkpoint_every would trace (and risk losing, on
        # interrupt) that many iterations per program; the boundary cut
        # below keeps checkpoints aligned regardless
        sync_every = min(checkpoint_every if checkpoint_dir else n_iterations,
                         8)
    sync_every = max(1, sync_every)

    history = []
    it = start_iter
    while it < n_iterations:
        n = min(sync_every, n_iterations - it)
        if checkpoint_dir:
            # cut the chunk at the next checkpoint boundary so saves happen
            # at the same iterations as the unfused loop did
            n = min(n, ((it // checkpoint_every) + 1) * checkpoint_every - it)
        steps = jnp.arange(it, it + n, dtype=jnp.int32)
        params, opt_state, env_states, ep, key, stats = chunk_jit(
            params, opt_state, env_states, ep, key, steps)
        host = jax.device_get(stats)              # ONE sync per chunk
        for i in range(n):
            s = {k: float(v[i]) for k, v in host.items()}
            history.append(s)
            if log:
                log(it + i, s)
        it += n
        if checkpoint_dir and it % checkpoint_every == 0:
            from repro.checkpoint import save

            save(checkpoint_dir, it - 1,
                 {"params": params, "opt": opt_state,
                  "env_states": env_states, "ep": ep, "key": key},
                 extra_meta={"iteration": it - 1,
                             "fingerprint": fingerprint})
    return params, history


def _train_fingerprint(env, cfg: PPOConfig, seed, hidden,
                       n_iterations) -> Dict[str, Any]:
    """Launch-argument fingerprint stored in PPO checkpoint manifests.

    ``n_iterations`` is deliberately excluded: extending a finished run
    ("train 50 more iterations from the latest checkpoint") is a
    legitimate resume, while a different env/config/seed is not.
    """
    import hashlib

    dig = lambda s: hashlib.sha256(s.encode()).hexdigest()[:16]
    return {
        "kind": "ppo",
        "ppo_cfg": dig(repr(cfg)),
        "seed": int(seed),
        "hidden": list(hidden),
        "env": dig(f"{type(env).__name__}/{env.obs_dim}/{env.n_actions}/"
                   f"{repr(getattr(env, 'cfg', None))}"),
    }
