"""PPO (clipped surrogate) implemented from scratch in JAX, end-to-end
jitted: vectorized env rollouts (vmap over N parallel datacenters with
auto-reset), GAE, minibatched clipped-objective epochs, AdamW — the
paper's "initial RL infrastructure" (SB3 PPO) rebuilt JAX-native so the
entire train iteration — including the simulator — is one XLA program.

``data_axis`` optionally shard_maps the rollout+update across the mesh
(distributed PPO: per-shard rollouts, psum'd gradients).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import AdamW
from repro.rl.gae import gae
from repro.rl.policy import ActorCritic


@dataclass(frozen=True)
class PPOConfig:
    n_envs: int = 16
    rollout_len: int = 64
    n_epochs: int = 4
    n_minibatches: int = 4
    gamma: float = 0.99
    lam: float = 0.95
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    lr: float = 3e-4
    max_grad_norm: float = 0.5


class Transition(NamedTuple):
    obs: jax.Array
    action: jax.Array
    logp: jax.Array
    value: jax.Array
    reward: jax.Array
    done: jax.Array


def make_rollout(env, policy: ActorCritic, cfg: PPOConfig):
    """Returns rollout(params, env_states, key) -> (env_states, batch, last_val, ep_stats)."""

    v_step = jax.vmap(env.step)
    v_reset = jax.vmap(env.reset)
    v_obs = jax.vmap(env.observe)

    def rollout(params, env_states, key):
        obs0 = v_obs(env_states)

        def one(carry, _):
            states, obs, key, ep_ret, ep_len, fin_ret = carry
            key, ka, kr = jax.random.split(key, 3)
            logits, values = policy.apply(params, obs)
            actions = jax.vmap(
                lambda l, k: jax.random.categorical(k, l)
            )(logits, jax.random.split(ka, cfg.n_envs))
            logp_all = jax.nn.log_softmax(logits)
            logps = jnp.take_along_axis(logp_all, actions[:, None], axis=-1)[:, 0]
            states, nobs, rew, done, info = v_step(states, actions)
            ep_ret = ep_ret + rew
            ep_len = ep_len + 1
            fin_ret = jnp.where(done, ep_ret, fin_ret)
            # auto-reset finished envs
            rkeys = jax.random.split(kr, cfg.n_envs)
            fresh_states, fresh_obs = v_reset(rkeys)
            states = jax.tree.map(
                lambda f, s: jnp.where(
                    done.reshape((-1,) + (1,) * (s.ndim - 1)), f, s
                ), fresh_states, states,
            )
            nobs = jnp.where(done[:, None], fresh_obs, nobs)
            ep_ret = jnp.where(done, 0.0, ep_ret)
            ep_len = jnp.where(done, 0, ep_len)
            tr = Transition(obs, actions, logps, values, rew, done)
            return (states, nobs, key, ep_ret, ep_len, fin_ret), tr

        # zero-inits derived from obs0 keep their VMA type under shard_map
        z = obs0[:, 0] * 0.0
        init = (env_states, obs0, key, z, z.astype(jnp.int32), z)
        (states, obs, _, _, _, fin_ret), batch = jax.lax.scan(
            one, init, None, length=cfg.rollout_len
        )
        _, last_val = policy.apply(params, obs)
        return states, batch, last_val, fin_ret

    return rollout


def ppo_loss(policy, params, batch: Transition, adv, ret, cfg: PPOConfig):
    logits, value = policy.apply(params, batch.obs)
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, batch.action[..., None], axis=-1)[..., 0]
    ratio = jnp.exp(logp - batch.logp)
    adv_n = (adv - adv.mean()) / (adv.std() + 1e-8)
    pg1 = ratio * adv_n
    pg2 = jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv_n
    pg_loss = -jnp.mean(jnp.minimum(pg1, pg2))
    v_clip = batch.value + jnp.clip(value - batch.value, -cfg.clip_eps, cfg.clip_eps)
    v_loss = 0.5 * jnp.mean(
        jnp.maximum(jnp.square(value - ret), jnp.square(v_clip - ret))
    )
    ent = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1))
    total = pg_loss + cfg.vf_coef * v_loss - cfg.ent_coef * ent
    approx_kl = jnp.mean(batch.logp - logp)
    return total, {"pg_loss": pg_loss, "v_loss": v_loss, "entropy": ent,
                   "approx_kl": approx_kl}


def make_train_iteration(env, policy: ActorCritic, cfg: PPOConfig):
    """One fully-jitted PPO iteration: rollout -> GAE -> epochs of
    minibatched updates."""
    opt = AdamW(lr=cfg.lr, b2=0.999, weight_decay=0.0)
    rollout = make_rollout(env, policy, cfg)

    def iteration(params, opt_state, env_states, key, step):
        key, kroll, kperm = jax.random.split(key, 3)
        env_states, batch, last_val, fin_ret = rollout(params, env_states, kroll)
        adv, ret = gae(batch.reward, batch.value, batch.done, last_val,
                       gamma=cfg.gamma, lam=cfg.lam)

        # flatten (T, N) -> (T*N,)
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batch)
        adv_f = adv.reshape(-1)
        ret_f = ret.reshape(-1)
        B = adv_f.shape[0]
        mb = B // cfg.n_minibatches

        def epoch(carry, ke):
            params, opt_state = carry
            perm = jax.random.permutation(ke, B)

            def minibatch(carry, i):
                params, opt_state = carry
                idx = jax.lax.dynamic_slice_in_dim(perm, i * mb, mb)
                mb_batch = jax.tree.map(lambda x: x[idx], flat)
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: ppo_loss(policy, p, mb_batch, adv_f[idx],
                                       ret_f[idx], cfg), has_aux=True
                )(params)
                from repro.optim.base import clip_by_global_norm

                grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
                params, opt_state = opt.update(grads, opt_state, params, step)
                return (params, opt_state), metrics

            (params, opt_state), metrics = jax.lax.scan(
                minibatch, (params, opt_state), jnp.arange(cfg.n_minibatches)
            )
            return (params, opt_state), metrics

        (params, opt_state), metrics = jax.lax.scan(
            epoch, (params, opt_state), jax.random.split(kperm, cfg.n_epochs)
        )
        stats = {
            "mean_reward": jnp.mean(batch.reward),
            "mean_episode_return": jnp.mean(fin_ret),
            "mean_value": jnp.mean(batch.value),
            **{k: jnp.mean(v) for k, v in
               jax.tree.map(lambda x: x, metrics).items()},
        }
        return params, opt_state, env_states, key, stats

    return iteration, opt


def ppo_train(
    env,
    *,
    cfg: PPOConfig = PPOConfig(),
    n_iterations: int = 20,
    seed: int = 0,
    hidden=(128, 128),
    log: Optional[Callable[[int, Dict[str, float]], None]] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 10,
    resume: bool = False,
):
    """Train a PPO scheduler on `env`. Returns (params, history)."""
    policy = ActorCritic(env.obs_dim, env.n_actions, hidden)
    iteration, opt = make_train_iteration(env, policy, cfg)
    it_jit = jax.jit(iteration)

    key = jax.random.key(seed)
    key, kp, ke = jax.random.split(key, 3)
    params = policy.init(kp)
    opt_state = opt.init(params)
    env_states, _ = jax.vmap(env.reset)(jax.random.split(ke, cfg.n_envs))
    start_iter = 0

    if checkpoint_dir and resume:
        from repro.checkpoint import latest_step, restore

        step0 = latest_step(checkpoint_dir)
        if step0 is not None:
            payload = restore(checkpoint_dir, step0,
                              {"params": params, "opt": opt_state})
            params, opt_state = payload["params"], payload["opt"]
            start_iter = step0 + 1

    history = []
    for it in range(start_iter, n_iterations):
        step = jnp.int32(it)
        params, opt_state, env_states, key, stats = it_jit(
            params, opt_state, env_states, key, step
        )
        stats = {k: float(v) for k, v in stats.items()}
        history.append(stats)
        if log:
            log(it, stats)
        if checkpoint_dir and (it + 1) % checkpoint_every == 0:
            from repro.checkpoint import save

            save(checkpoint_dir, it, {"params": params, "opt": opt_state})
    return params, history
