"""Distributed PPO: shard_map data parallelism over a mesh axis with
int8-compressed gradient all-reduce (error feedback).

Each shard rolls out its own slice of the vectorized environments and
computes local PPO gradients; the only cross-shard communication is the
compressed psum (4x fewer bytes on the wire than fp32 — the knob the
brief calls "gradient compression"). Params stay replicated.

Fleet wiring: ``envs.SchedEnv`` is a pure pytree env, so handing
``distributed_ppo_train`` the 1-D fleet mesh from
``launch.mesh.make_fleet_mesh()`` shards the ``n_envs`` datacenter
replicas across devices exactly like ``core.fleet.run_fleet(mesh=...)``
does for plain sweeps — each device rolls out its own block of
simulators (macro while-loops lockstep only within the shard) and only
gradients cross the wire. The default ``axis`` is the mesh's sole/first
axis name, so the same mesh object works for both entry points.

The outer loop is the scanned single-compile shape ``ppo_train`` uses:
``sync_every`` iterations fuse into one ``lax.scan`` program (optimizer
update included) and ONE ``device_get`` drains each chunk's stacked
stats — the old per-iteration ``step_jit`` dispatch + ``float()``-per-
stat host sync (and the deprecated ``with mesh:`` context it needed) is
gone. ``history`` carries the same per-iteration keys as ``ppo_train``
(plus ``loss``), so benches can diff the two trainers row for row.

Note the VMA detail: params enter the shard_map replicated, so they are
pcast to "varying" before jax.grad — otherwise shard_map's AD inserts its
own fp32 psum and the reduction (and the bytes) happen twice. On the
pinned jax floor (no ``pcast``) the ``sharding.specs`` compat shims run
shard_map with replication checking off, which has the same effect.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import AdamW
from repro.optim.base import clip_by_global_norm
from repro.optim.compress import compressed_psum
from repro.rl.gae import gae
from repro.rl.policy import ActorCritic
from repro.rl.ppo import PPOConfig, _train_fingerprint, make_rollout, ppo_loss
from repro.sharding.specs import pcast_varying, shard_map_compat
from repro.utils.errors import ConfigError


def make_distributed_grad_step(
    env, policy: ActorCritic, cfg: PPOConfig, mesh, *, axis: str = "data",
    compress: bool = True,
):
    """Returns grad_step(params, env_states, key, error) ->
    (grads, env_states, new_error, stats); rollout+GAE+grad run per shard,
    gradients cross the wire int8-compressed. ``stats`` carries the
    ``ppo_train`` stat set (pmean'd across shards) plus the total loss."""
    n_shards = mesh.shape[axis]
    if cfg.n_envs % n_shards:
        raise ConfigError(
            f"{cfg.n_envs} envs do not divide across {n_shards} {axis!r}"
            "-axis devices — pick n_envs as a multiple of the mesh size")
    local_cfg = PPOConfig(**{**cfg.__dict__, "n_envs": cfg.n_envs // n_shards})
    rollout = make_rollout(env, policy, local_cfg)

    def local(params, env_states, key, error):
        key = key[0]          # (1,) shard slice of the per-shard key array
        error = jax.tree.map(lambda e: e[0], error)
        params = pcast_varying(params, axis)
        env_states, batch, last_val, ep = rollout(params, env_states, key)
        adv, ret = gae(batch.reward, batch.value, batch.done, last_val,
                       gamma=cfg.gamma, lam=cfg.lam)
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batch)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: ppo_loss(policy, p, flat, adv.reshape(-1),
                               ret.reshape(-1), cfg), has_aux=True
        )(params)
        if compress:
            grads, error = compressed_psum(grads, axis, error)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
        # window-local (ep not threaded across grad steps here; see
        # make_rollout's docstring)
        pm = lambda x: jax.lax.pmean(x, axis)
        stats = {
            "loss": pm(loss),
            "mean_reward": pm(jnp.mean(batch.reward)),
            "mean_episode_return": pm(jnp.mean(ep["fin_ret"])),
            "mean_episode_len": pm(
                jnp.mean(ep["fin_len"].astype(jnp.float32))),
            "mean_value": pm(jnp.mean(batch.value)),
            **{k: pm(v) for k, v in metrics.items()},
        }
        return grads, env_states, jax.tree.map(lambda e: e[None], error), stats

    def spec_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def grad_step(params, env_states, keys, error):
        return shard_map_compat(
            local,
            mesh,
            in_specs=(spec_like(params, P()),
                      spec_like(env_states, P(axis)),
                      P(axis),
                      spec_like(error, P(axis))),
            out_specs=(spec_like(params, P()),
                       spec_like(env_states, P(axis)),
                       spec_like(error, P(axis)),
                       P()),
        )(params, env_states, keys, error)

    return grad_step


def distributed_ppo_train(
    env, mesh, *, cfg: PPOConfig = PPOConfig(), n_iterations: int = 10,
    seed: int = 0, compress: bool = True, axis: Optional[str] = None,
    log: Optional[Callable[[int, Dict[str, float]], None]] = None,
    sync_every: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 10,
    resume: bool = False,
) -> Tuple[Any, list]:
    """End-to-end distributed PPO (used on multi-host topologies; exercised
    on fake devices in tests). Returns (params, history) with the same
    history interface as ``ppo_train``: one dict of per-iteration floats
    per iteration, drained chunk-wise (``sync_every`` iterations per
    compiled program, one ``device_get`` per chunk). ``axis`` defaults to
    the mesh's first axis name, so a ``make_fleet_mesh()`` works as-is.

    Checkpoints mirror ``ppo_train``: full training state (params,
    optimizer, env fleet, per-shard error-feedback accumulators, PRNG
    key) plus a run fingerprint, so ``resume=True`` continues
    bit-identically on the same mesh size. The mesh itself is not
    fingerprinted, but the error-feedback leaves carry the shard count
    in their shapes, so resuming on a different mesh fails with a loud
    typed ``CheckpointError`` rather than silently rescaling."""
    if axis is None:
        axis = mesh.axis_names[0]
    policy = ActorCritic(env.obs_dim, env.n_actions)
    opt = AdamW(lr=cfg.lr, b2=0.999, weight_decay=0.0)
    key = jax.random.key(seed)
    key, kp, ke = jax.random.split(key, 3)
    params = policy.init(kp)
    opt_state = opt.init(params)
    env_states, _ = jax.vmap(env.reset)(jax.random.split(ke, cfg.n_envs))
    n_shards = mesh.shape[axis]
    # per-shard error-feedback state: leading axis = shard
    error = jax.tree.map(
        lambda p: jnp.zeros((n_shards,) + p.shape, jnp.float32), params)

    grad_step = make_distributed_grad_step(
        env, policy, cfg, mesh, axis=axis, compress=compress)

    def iteration(carry, step):
        params, opt_state, env_states, error, key = carry
        key, kr = jax.random.split(key)
        keys = jax.random.split(kr, n_shards)
        grads, env_states, error, stats = grad_step(
            params, env_states, keys, error)
        grads, _ = clip_by_global_norm(grads, cfg.max_grad_norm)
        params, opt_state = opt.update(grads, opt_state, params, step)
        return (params, opt_state, env_states, error, key), stats

    def chunk(carry, steps):
        return jax.lax.scan(iteration, carry, steps)

    chunk_jit = jax.jit(chunk)

    start_iter = 0
    fingerprint = dict(
        _train_fingerprint(env, cfg, seed, (), n_iterations),
        kind="ppo-dist", compress=bool(compress))
    if checkpoint_dir and resume:
        from repro.checkpoint import latest_step, restore
        from repro.checkpoint.ckpt import read_meta
        from repro.checkpoint.episode import check_fingerprint

        step0 = latest_step(checkpoint_dir)
        if step0 is not None:
            meta = read_meta(checkpoint_dir, step0)
            saved_fp = meta.get("extra", {}).get("fingerprint")
            if saved_fp is not None:
                check_fingerprint(saved_fp, fingerprint, checkpoint_dir)
            payload = restore(
                checkpoint_dir, step0,
                {"params": params, "opt": opt_state,
                 "env_states": env_states, "error": error, "key": key})
            params, opt_state = payload["params"], payload["opt"]
            env_states, error = payload["env_states"], payload["error"]
            key = payload["key"]
            start_iter = step0 + 1

    if sync_every is None:
        sync_every = min(checkpoint_every if checkpoint_dir else n_iterations,
                         8)
    sync_every = max(1, sync_every)

    history = []
    carry = (params, opt_state, env_states, error, key)
    it = start_iter
    while it < n_iterations:
        n = min(sync_every, n_iterations - it)
        if checkpoint_dir:
            # cut at checkpoint boundaries so saves land at the same
            # iterations the unfused loop produced
            n = min(n, ((it // checkpoint_every) + 1) * checkpoint_every - it)
        steps = jnp.arange(it, it + n, dtype=jnp.int32)
        carry, stats = chunk_jit(carry, steps)
        host = jax.device_get(stats)              # ONE sync per chunk
        for i in range(n):
            s = {k: float(v[i]) for k, v in host.items()}
            history.append(s)
            if log:
                log(it + i, s)
        it += n
        if checkpoint_dir and it % checkpoint_every == 0:
            from repro.checkpoint import save

            params, opt_state, env_states, error, key = carry
            save(checkpoint_dir, it - 1,
                 {"params": params, "opt": opt_state,
                  "env_states": env_states, "error": error, "key": key},
                 extra_meta={"iteration": it - 1,
                             "fingerprint": fingerprint})
    return carry[0], history
