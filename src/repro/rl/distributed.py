"""Distributed PPO: shard_map data parallelism over the mesh 'data' axis
with int8-compressed gradient all-reduce (error feedback).

Each shard rolls out its own slice of the vectorized environments and
computes local PPO gradients; the only cross-shard communication is the
compressed psum (4x fewer bytes on the wire than fp32 — the knob the
brief calls "gradient compression"). Params stay replicated.

Note the VMA detail: params enter the shard_map replicated, so they are
pcast to "varying" before jax.grad — otherwise shard_map's AD inserts its
own fp32 psum and the reduction (and the bytes) happen twice.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import AdamW
from repro.optim.base import clip_by_global_norm
from repro.optim.compress import compressed_psum
from repro.rl.gae import gae
from repro.rl.policy import ActorCritic
from repro.rl.ppo import PPOConfig, Transition, make_rollout, ppo_loss


def make_distributed_grad_step(
    env, policy: ActorCritic, cfg: PPOConfig, mesh, *, axis: str = "data",
    compress: bool = True,
):
    """Returns grad_step(params, env_states, key, error) ->
    (grads, env_states, new_error, stats); rollout+GAE+grad run per shard,
    gradients cross the wire int8-compressed."""
    n_shards = mesh.shape[axis]
    assert cfg.n_envs % n_shards == 0
    local_cfg = PPOConfig(**{**cfg.__dict__, "n_envs": cfg.n_envs // n_shards})
    rollout = make_rollout(env, policy, local_cfg)

    def local(params, env_states, key, error):
        key = key[0]          # (1,) shard slice of the per-shard key array
        error = jax.tree.map(lambda e: e[0], error)
        params = jax.tree.map(
            lambda x: jax.lax.pcast(x, axis, to="varying"), params
        )
        env_states, batch, last_val, ep = rollout(params, env_states, key)
        adv, ret = gae(batch.reward, batch.value, batch.done, last_val,
                       gamma=cfg.gamma, lam=cfg.lam)
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batch)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: ppo_loss(policy, p, flat, adv.reshape(-1),
                               ret.reshape(-1), cfg), has_aux=True
        )(params)
        if compress:
            grads, error = compressed_psum(grads, axis, error)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
        # window-local (ep not threaded across grad steps here; see
        # make_rollout's docstring)
        stats = {
            "loss": jax.lax.pmean(loss, axis),
            "mean_episode_return": jax.lax.pmean(
                jnp.mean(ep["fin_ret"]), axis),
            "mean_episode_len": jax.lax.pmean(
                jnp.mean(ep["fin_len"].astype(jnp.float32)), axis),
        }
        return grads, env_states, jax.tree.map(lambda e: e[None], error), stats

    def spec_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def grad_step(params, env_states, keys, error):
        return jax.shard_map(
            local,
            mesh=mesh,
            in_specs=(P(), spec_like(env_states, P(axis)), P(axis),
                      spec_like(error, P(axis))),
            out_specs=(P(), spec_like(env_states, P(axis)),
                       spec_like(error, P(axis)), P()),
        )(params, env_states, keys, error)

    return grad_step


def distributed_ppo_train(
    env, mesh, *, cfg: PPOConfig = PPOConfig(), n_iterations: int = 10,
    seed: int = 0, compress: bool = True, axis: str = "data",
):
    """End-to-end distributed PPO (used on multi-host topologies; exercised
    on fake devices in tests)."""
    policy = ActorCritic(env.obs_dim, env.n_actions)
    opt = AdamW(lr=cfg.lr, b2=0.999, weight_decay=0.0)
    key = jax.random.key(seed)
    key, kp, ke = jax.random.split(key, 3)
    params = policy.init(kp)
    opt_state = opt.init(params)
    env_states, _ = jax.vmap(env.reset)(jax.random.split(ke, cfg.n_envs))
    n_shards = mesh.shape[axis]
    # per-shard error-feedback state: leading axis = shard
    error = jax.tree.map(
        lambda p: jnp.zeros((n_shards,) + p.shape, jnp.float32), params)

    grad_step = make_distributed_grad_step(
        env, policy, cfg, mesh, axis=axis, compress=compress)

    history = []
    with mesh:
        step_jit = jax.jit(grad_step)
        for it in range(n_iterations):
            key, kr = jax.random.split(key)
            keys = jax.random.split(kr, n_shards)
            grads, env_states, error, stats = step_jit(
                params, env_states, keys, error)
            grads, _ = clip_by_global_norm(grads, cfg.max_grad_norm)
            params, opt_state = opt.update(grads, opt_state, params,
                                           jnp.int32(it))
            history.append({k: float(v) for k, v in stats.items()})
    return params, history
