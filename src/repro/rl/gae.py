"""Generalized Advantage Estimation (reverse scan)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gae(rewards, values, dones, last_value, *, gamma=0.99, lam=0.95):
    """All inputs (T, N). Returns (advantages, returns) each (T, N)."""

    def body(carry, inp):
        adv_next, v_next = carry
        r, v, d = inp
        nonterm = 1.0 - d
        delta = r + gamma * v_next * nonterm - v
        adv = delta + gamma * lam * nonterm * adv_next
        return (adv, v), adv

    # derive from last_value so the carry keeps its VMA type under shard_map
    zeros = last_value * 0.0
    (_, _), advs = jax.lax.scan(
        body, (zeros, last_value), (rewards, values, dones.astype(jnp.float32)),
        reverse=True,
    )
    return advs, advs + values
