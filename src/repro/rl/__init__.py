from repro.rl.policy import ActorCritic
from repro.rl.ppo import PPOConfig, ppo_train
