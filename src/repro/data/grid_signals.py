"""Grid-signal trace IO + synthesis for the scenario engine.

Trace CSVs follow the common grid-operator export shape (e.g. electricityMap
/ WattTime / ISO day-ahead feeds, simplified to a uniform grid):

    timestamp_s,value
    0.0,412.5
    300.0,408.1
    ...

``load_signal_csv`` parses one into a ``scenarios.Signal`` (trace family,
linear interpolation at ``state.t``); ``write_signal_csv`` emits the same
schema so synthetic feeds round-trip through the parser. ``synth_grid_trace``
generates offline stand-ins for real feeds: carbon [gCO2/kWh] with a solar
trough + ramps, price [$/kWh] duck curve with evening spikes, wetbulb [degC]
diurnal weather with a mid-horizon heat event.
"""

from __future__ import annotations

import csv
import os
from typing import Tuple

import numpy as np

from repro.data.validate import validate_signal_samples
from repro.scenarios.signals import Signal, from_trace

SIGNAL_COLS = ["timestamp_s", "value"]


def _parses(x) -> bool:
    try:
        float(x)
        return True
    except (TypeError, ValueError):
        return False


def write_signal_csv(path: str, values: np.ndarray, dt: float,
                     t0: float = 0.0) -> str:
    """Write a uniform-grid signal trace CSV. Returns ``path``."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    v = np.asarray(values, np.float32).reshape(-1)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(SIGNAL_COLS)
        for i, x in enumerate(v):
            w.writerow([f"{t0 + i * dt:.3f}", f"{x:.6g}"])
    return path


def load_signal_csv(path: str, *, validate: str = "strict",
                    return_report: bool = False):
    """Parse a ``timestamp_s,value`` CSV into a trace Signal.

    Timestamps must be uniformly spaced (tolerance 1e-3 of the step);
    resample upstream if your feed is irregular. Validation is LOUD by
    default: non-finite values, non-monotone or non-uniform timestamps,
    and too-short feeds raise a typed
    :class:`~repro.utils.errors.SignalValidationError` naming the
    offending rows — a NaN in a carbon/price feed would otherwise
    propagate silently through ``jnp.interp`` into every accumulator.
    ``validate="repair"`` interpolates non-finite values over the uniform
    grid instead; ``return_report=True`` appends the
    :class:`~repro.data.validate.IngestionReport`.
    """
    ts, vs = [], []
    with open(path) as f:
        for i, row in enumerate(csv.DictReader(f)):
            try:
                ts.append(float(row["timestamp_s"]))
                vs.append(float(row["value"]))
            except (KeyError, TypeError, ValueError):
                # unparseable cells become NaN so the validator's repair
                # path (interpolate) / strict path (raise with row index)
                # both see them; a bad timestamp is structural -> raise
                if _parses(row.get("timestamp_s")):
                    ts.append(float(row["timestamp_s"]))
                    vs.append(float("nan"))
                else:
                    from repro.utils.errors import SignalValidationError
                    raise SignalValidationError(
                        f"{path}: unparseable timestamp_s="
                        f"{row.get('timestamp_s')!r} at row {i}") from None
    t, v, rep = validate_signal_samples(
        ts, vs, mode=validate, source=path)
    dt = float(np.median(np.diff(t))) if len(t) >= 2 else 1.0
    sig = from_trace(v, dt, t0=float(t[0]))
    return (sig, rep) if return_report else sig


def synth_grid_trace(
    kind: str,
    horizon_s: float,
    dt: float = 300.0,
    seed: int = 0,
) -> Tuple[np.ndarray, float]:
    """Synthesize a grid feed: kind in {'carbon','price','wetbulb'}.

    Returns (values, dt) ready for ``write_signal_csv`` / ``from_trace``.
    """
    rng = np.random.default_rng(seed)
    n = max(int(np.ceil(horizon_s / dt)) + 1, 2)
    t = np.arange(n) * dt
    day = 2 * np.pi * t / 86_400.0
    # smooth AR(1) weather/grid-mix wander shared by all kinds
    wander = np.zeros(n)
    for i in range(1, n):
        wander[i] = 0.97 * wander[i - 1] + rng.normal(0, 0.25)

    if kind == "carbon":
        # night-heavy baseline, midday solar trough, morning/evening ramps
        v = 420.0 + 130.0 * np.cos(day) - 90.0 * np.exp(
            -0.5 * ((t % 86_400.0 - 43_200.0) / 7_200.0) ** 2
        ) + 18.0 * wander
        v = np.clip(v, 40.0, 900.0)
    elif kind == "price":
        # duck curve + sparse evening spike events (scarcity pricing)
        v = 0.10 + 0.05 * np.sin(day - np.pi) + 0.004 * wander
        hour = (t % 86_400.0) / 3600.0
        evening = (hour > 17.0) & (hour < 21.0)
        spikes = evening & (rng.random(n) < 0.02)
        v = np.where(spikes, v * rng.uniform(3.0, 8.0, n), v)
        v = np.clip(v, 0.005, 2.0)
    elif kind == "wetbulb":
        # diurnal weather + a 6h heat event centered mid-horizon
        v = 16.0 - 6.0 * np.cos(day) + 1.2 * wander
        v += 7.0 * np.exp(-0.5 * ((t - horizon_s / 2) / (3 * 3600.0)) ** 2)
    else:
        raise KeyError(f"unknown grid signal kind {kind!r}")
    return v.astype(np.float32), dt
