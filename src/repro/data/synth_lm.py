"""Deterministic, host-shardable synthetic LM token pipeline.

Tokens are drawn from a Zipfian distribution with a deterministic counter-
based RNG keyed on (seed, step, host) — so restarts resume exactly at any
step on any host topology (fault tolerance / elasticity), with no state to
checkpoint beyond the step number.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _zipf_logits(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks**alpha
    return np.log(p / p.sum()).astype(np.float32)


def lm_batch_at(
    step: int,
    *,
    vocab: int,
    batch: int,
    seq_len: int,
    seed: int = 0,
    host_id: int = 0,
    n_hosts: int = 1,
    extras: Optional[Dict[str, tuple]] = None,
) -> Dict[str, jax.Array]:
    """The (deterministic) global batch slice owned by `host_id` at `step`."""
    assert batch % n_hosts == 0
    local = batch // n_hosts
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.key(seed), step), host_id
    )
    logits = jnp.asarray(_zipf_logits(vocab))
    toks = jax.random.categorical(key, logits, shape=(local, seq_len + 1))
    out = {
        "tokens": toks[:, :-1].astype(jnp.int32),
        "labels": toks[:, 1:].astype(jnp.int32),
    }
    if extras:
        for name, shape in extras.items():
            ek = jax.random.fold_in(key, hash(name) % (2**31))
            out[name] = 0.02 * jax.random.normal(ek, (local,) + tuple(shape))
    return out


def lm_batches(
    start_step: int = 0,
    **kw,
) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield lm_batch_at(step, **kw)
        step += 1
