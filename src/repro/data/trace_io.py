"""MIT SuperCloud dataset IO (schema-faithful, Samsi et al. HPEC'21).

The dataset ships as CSVs:
  scheduler-log.csv : job_id,time_submit,time_start,time_end,nodes_alloc,
                      cpus_req,gpus_req,mem_req_gb,partition,state
  cpu-telemetry.csv : timestamp,node,job_id,cpu_util   (10 s quanta)
  gpu-telemetry.csv : timestamp,node,gpu_index,job_id,util_pct,power_w
                      (100 ms quanta)

``load_supercloud`` parses these into the simulator workload + trace bank,
band-averaging telemetry onto the sim's trace quanta exactly as RAPS does.
``write_supercloud_csvs`` emits synthetic data in the same schema so the
parser is exercised end-to-end offline (see DESIGN.md assumption table).
"""

from __future__ import annotations

import csv
import os
from typing import Dict, Tuple

import numpy as np

from repro.configs.sim import SimConfig, partition_type_indices
from repro.data.validate import (
    IngestionReport,
    check_telemetry_row,
    validate_sched_rows,
)
from repro.utils.errors import TraceValidationError

SCHED_COLS = [
    "job_id", "time_submit", "time_start", "time_end", "nodes_alloc",
    "cpus_req", "gpus_req", "mem_req_gb", "partition", "state",
]
CPU_COLS = ["timestamp", "node", "job_id", "cpu_util"]
GPU_COLS = ["timestamp", "node", "gpu_index", "job_id", "util_pct", "power_w"]


def write_supercloud_csvs(
    path: str,
    cfg: SimConfig,
    n_jobs: int,
    horizon_s: float,
    seed: int = 0,
    *,
    cpu_quanta_s: float = 10.0,
    gpu_quanta_s: float = 0.1,
    gpu_telemetry_stride: int = 100,   # write every k-th 100ms sample
) -> str:
    """Generate a synthetic dataset in the SuperCloud schema. Returns path."""
    from repro.data.synth_trace import synth_workload

    os.makedirs(path, exist_ok=True)
    jobs, bank = synth_workload(cfg, n_jobs, horizon_s, seed)
    J = n_jobs

    with open(os.path.join(path, "scheduler-log.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(SCHED_COLS)
        for j in range(J):
            start = jobs["submit_t"][j] + abs(
                np.random.default_rng(seed + j).normal(20, 10)
            )
            w.writerow([
                j + 1,
                f"{jobs['submit_t'][j]:.1f}",
                f"{start:.1f}",
                f"{start + jobs['dur'][j]:.1f}",
                int(jobs["n_nodes"][j]),
                int(jobs["req"][0, j]),
                int(jobs["req"][1, j]),
                f"{jobs['req'][2, j]:.1f}",
                "xeon-g6" if jobs["req"][1, j] > 0 else "xeon-p8",
                "COMPLETED",
            ])

    # telemetry: per-job time series (node attribution simplified to rank 0)
    with open(os.path.join(path, "cpu-telemetry.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(CPU_COLS)
        for j in range(J):
            Q = bank["cpu"].shape[1]
            for q in range(0, Q, max(1, int(cpu_quanta_s / cfg.trace_quanta))):
                if q * cfg.trace_quanta > jobs["dur"][j]:
                    break
                w.writerow([f"{q * cfg.trace_quanta:.1f}", f"n{j % cfg.n_nodes:04d}",
                            j + 1, f"{bank['cpu'][j, q]:.4f}"])

    with open(os.path.join(path, "gpu-telemetry.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(GPU_COLS)
        step = gpu_telemetry_stride
        for j in range(J):
            if jobs["req"][1, j] == 0:
                continue
            Q = bank["gpu"].shape[1]
            for q in range(0, Q, step):
                if q * gpu_quanta_s * step > jobs["dur"][j]:
                    break
                u = bank["gpu"][j, min(int(q * gpu_quanta_s * step / cfg.trace_quanta), Q - 1)]
                w.writerow([
                    f"{q * gpu_quanta_s * step:.1f}", f"n{j % cfg.n_nodes:04d}", 0,
                    j + 1, f"{100 * u:.2f}", f"{55 + 245 * u:.1f}",
                ])
    return path


def load_supercloud(
    path: str,
    cfg: SimConfig,
    *,
    validate: str = "repair",
    return_report: bool = False,
):
    """Parse SuperCloud-schema CSVs -> (jobs dict, trace bank).

    Telemetry is averaged onto ``cfg.trace_quanta`` bands (RAPS trace
    quanta); jobs without telemetry fall back to a constant 70% profile.

    ``validate`` (see :mod:`repro.data.validate`): ``"repair"`` (default)
    quarantines corrupt rows and keeps going; ``"strict"`` raises
    :class:`~repro.utils.errors.TraceValidationError` /
    ``SignalValidationError`` naming the offending rows; ``"off"`` trusts
    the input. With ``return_report=True`` the return value grows a third
    element: ``{"scheduler": IngestionReport, "cpu_telemetry": ...,
    "gpu_telemetry": ...}`` accounting for every dropped row.
    """
    sched_file = os.path.join(path, "scheduler-log.csv")
    rows = []
    with open(sched_file) as f:
        for row in csv.DictReader(f):
            rows.append(row)
    rows, sched_rep = validate_sched_rows(
        rows, cfg, mode=validate, source=sched_file)
    J = len(rows)
    if J > cfg.max_jobs:
        sched_rep.warnings.append({
            "row": cfg.max_jobs, "check": "truncated",
            "detail": f"{J - cfg.max_jobs} valid job(s) beyond "
                      f"cfg.max_jobs={cfg.max_jobs} dropped"})
        rows = rows[: cfg.max_jobs]
        J = cfg.max_jobs

    submit = np.array([float(r["time_submit"]) for r in rows], np.float32)
    start = np.array([float(r["time_start"]) for r in rows], np.float32)
    end = np.array([float(r["time_end"]) for r in rows], np.float32)
    dur = np.maximum(end - start, 1.0)
    n_nodes = np.array([int(r["nodes_alloc"]) for r in rows], np.int32)
    req = np.stack([
        np.array([float(r["cpus_req"]) for r in rows], np.float32),
        np.array([float(r["gpus_req"]) for r in rows], np.float32),
        np.array([float(r["mem_req_gb"]) for r in rows], np.float32),
    ])
    job_ids = {int(r["job_id"]): i for i, r in enumerate(rows)}

    Q = max(int(np.ceil(dur.max() / cfg.trace_quanta)) + 1, 8)
    Jmax = cfg.max_jobs
    cpu = np.zeros((Jmax, Q), np.float32)
    gpu = np.zeros((Jmax, Q), np.float32)
    cpu_n = np.zeros((Jmax, Q), np.float32)
    gpu_n = np.zeros((Jmax, Q), np.float32)

    def accumulate(fname, util_col, target, counts, scale, hi):
        fpath = os.path.join(path, fname)
        rep = IngestionReport(source=fpath, kind="telemetry", mode=validate)
        if not os.path.exists(fpath):
            return rep
        with open(fpath) as f:
            for i, row in enumerate(csv.DictReader(f)):
                rep.n_input += 1
                if validate == "off":
                    parsed = (int(row["job_id"]), float(row["timestamp"]),
                              float(row[util_col]))
                else:
                    parsed = check_telemetry_row(
                        row, util_col=util_col, lo=0.0, hi=hi,
                        rownum=i, report=rep)
                    if parsed is None:
                        continue
                jid, t, u = parsed
                rep.n_ok += 1
                if jid not in job_ids:
                    # jobs beyond max_jobs / quarantined jobs: skippable,
                    # counted (not corrupt — the job just isn't loaded)
                    rep.n_skipped_unknown_id += 1
                    continue
                j = job_ids[jid]
                q = min(int(t / cfg.trace_quanta), Q - 1)
                target[j, q] += u * scale
                counts[j, q] += 1.0
        if validate == "strict":
            rep.raise_if_dirty(TraceValidationError)
        return rep

    cpu_rep = accumulate("cpu-telemetry.csv", "cpu_util", cpu, cpu_n,
                         1.0, 1.0)
    gpu_rep = accumulate("gpu-telemetry.csv", "util_pct", gpu, gpu_n,
                         0.01, 100.0)
    cpu = np.where(cpu_n > 0, cpu / np.maximum(cpu_n, 1), 0.0)
    gpu = np.where(gpu_n > 0, gpu / np.maximum(gpu_n, 1), 0.0)
    # fill forward within each job's duration; default 0.7 when absent
    for j in range(J):
        qmax = min(int(dur[j] / cfg.trace_quanta) + 1, Q)
        if cpu[j, :qmax].max() == 0:
            cpu[j, :qmax] = 0.7
        if req[1, j] > 0 and gpu[j, :qmax].max() == 0:
            gpu[j, :qmax] = 0.7

    # partition tag: match the CSV partition name against cfg node-type
    # names; unknown names fall back to "needs GPUs -> first GPU type,
    # else first CPU-only type" so TX-GAIA semantics survive renames, and
    # to -1 (any node) when the config has no type of that kind — a made-up
    # single-type confinement would silently skew utilization results
    type_names = {t.name: i for i, t in enumerate(cfg.node_types)}
    gpu_ti, cpu_ti = partition_type_indices(cfg)
    part = np.array([
        type_names.get(r.get("partition", ""),
                       gpu_ti if req[1, i] > 0 else cpu_ti)
        for i, r in enumerate(rows)
    ], np.int32)

    jobs = {
        "submit_t": submit, "dur": dur.astype(np.float32), "n_nodes": n_nodes,
        "req": req, "priority": start,  # replay dispatches at recorded starts
        "part": part,
    }
    bank = {"cpu": cpu, "gpu": gpu, "net_tx": np.zeros((Jmax,), np.float32)}
    if return_report:
        report = {"scheduler": sched_rep, "cpu_telemetry": cpu_rep,
                  "gpu_telemetry": gpu_rep}
        return jobs, bank, report
    return jobs, bank
