"""Synthetic MIT-SuperCloud-like workloads.

The real dataset cannot be downloaded offline, so we synthesize workloads
with its statistical character (paper §: heterogeneity + multi-tenancy):
Poisson arrivals; lognormal durations; a GPU partition (1-2 GPU jobs,
fractional-node CPU usage) and a CPU partition (multi-tenant, fractional
cores); per-job utilization profiles quantized at the trace quanta (10 s
CPU / 100 ms GPU in the dataset; we band-average onto the sim quanta as
RAPS does); per-job network traffic for the congestion model.

``synth_workload`` returns (jobs dict for ``load_jobs``, trace bank for
``build_statics``).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.configs.sim import SimConfig, partition_type_indices


def synth_workload(
    cfg: SimConfig,
    n_jobs: int,
    horizon_s: float,
    seed: int = 0,
    *,
    gpu_fraction: float = 0.55,
    mean_dur_s: float = 1200.0,
    arrival: str = "poisson",      # 'poisson' | 'burst'
    net_heavy_fraction: float = 0.2,
) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    assert n_jobs <= cfg.max_jobs
    rng = np.random.default_rng(seed)
    J = n_jobs

    if arrival == "poisson":
        gaps = rng.exponential(horizon_s / max(n_jobs, 1), J)
        submit = np.clip(np.cumsum(gaps) - gaps[0], 0, horizon_s * 0.9)
    else:  # bursty: jobs arrive in waves (shift-change pattern)
        waves = rng.integers(0, 4, J) * (horizon_s / 4)
        submit = np.sort(waves + rng.exponential(60.0, J))

    dur = np.clip(rng.lognormal(np.log(mean_dur_s), 0.9, J), 30.0, horizon_s)
    is_gpu = rng.random(J) < gpu_fraction

    # derive the partition types from the config (first GPU-bearing type,
    # first CPU-only type) instead of assuming a gpu-first ordering;
    # -1 tags = any node when the config lacks that kind
    gpu_ti, cpu_ti = partition_type_indices(cfg)
    gpu_type = cfg.node_types[gpu_ti if gpu_ti >= 0 else 0]
    cpu_type = cfg.node_types[cpu_ti if cpu_ti >= 0 else -1]
    n_nodes = np.where(
        is_gpu,
        np.minimum(2 ** rng.integers(0, 3, J), cfg.max_nodes_per_job),
        1,
    ).astype(np.int32)

    # per-node demand: GPU jobs take 1..gpus GPUs + some cores; CPU jobs are
    # multi-tenant fractional (cores only)
    gpus_req = np.where(is_gpu, rng.integers(1, gpu_type.gpus + 1, J), 0)
    cores_req = np.where(
        is_gpu,
        rng.integers(4, max(gpu_type.cpu_cores // 2, 5), J),
        rng.integers(1, max(cpu_type.cpu_cores // 2, 2), J),
    )
    mem_req = np.where(
        is_gpu,
        rng.uniform(16, gpu_type.mem_gb / 2, J),
        rng.uniform(2, cpu_type.mem_gb / 4, J),
    )
    req = np.stack([cores_req, gpus_req, mem_req]).astype(np.float32)

    # utilization profiles at sim quanta
    Q = max(int(np.ceil(dur.max() / cfg.trace_quanta)) + 1, 8)
    tgrid = np.arange(Q)[None, :] * cfg.trace_quanta
    base_cpu = rng.uniform(0.25, 0.95, J)[:, None]
    base_gpu = np.where(is_gpu, rng.uniform(0.35, 0.98, J), 0.0)[:, None]
    wob = 0.08 * np.sin(2 * np.pi * tgrid / rng.uniform(120, 900, J)[:, None])
    noise = rng.normal(0, 0.03, (J, Q))
    ramp = np.clip(tgrid / 60.0, 0, 1)   # 1-minute startup ramp
    cpu_trace = np.clip((base_cpu + wob + noise) * ramp, 0, 1).astype(np.float32)
    gpu_trace = np.clip((base_gpu + wob + noise) * ramp, 0, 1).astype(np.float32)

    net_tx = np.where(
        rng.random(J) < net_heavy_fraction,
        rng.uniform(5.0, 40.0, J),     # GB/s per node: comm-heavy (training)
        rng.uniform(0.0, 0.5, J),
    ).astype(np.float32)

    jobs = {
        "submit_t": submit.astype(np.float32),
        "dur": dur.astype(np.float32),
        "n_nodes": n_nodes,
        "req": req,
        "priority": submit.astype(np.float32),   # replay: start ~ submit
        "is_gpu": is_gpu,
        # partition tag = node-type index (mirroring TX-GAIA's xeon-g6 /
        # xeon-p8 split); consumed by load_jobs -> `partition` placement
        "part": np.where(is_gpu, gpu_ti, cpu_ti).astype(np.int32),
    }
    # pad trace bank to max_jobs
    Jmax = cfg.max_jobs
    bank = {
        "cpu": np.zeros((Jmax, Q), np.float32),
        "gpu": np.zeros((Jmax, Q), np.float32),
        "net_tx": np.zeros((Jmax,), np.float32),
    }
    bank["cpu"][:J] = cpu_trace
    bank["gpu"][:J] = gpu_trace
    bank["net_tx"][:J] = net_tx
    return jobs, bank


def replay_priorities(jobs: Dict[str, np.ndarray], recorded_start: np.ndarray):
    """For replay mode, priority carries the recorded start times."""
    out = dict(jobs)
    out["priority"] = recorded_start.astype(np.float32)
    return out
