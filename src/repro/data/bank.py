"""Workload-bank plumbing for the bank-indexed rollout engine.

``stack_workloads`` turns a list of ``(jobs, bank)`` tuples (as produced by
``synth_trace.synth_workload`` / ``trace_io.load_supercloud`` /
``perfmodel.lm_jobs_workload``) into

- one *banked* trace bank — ``cpu``/``gpu`` stacked to (W, J, Qmax) with
  the quanta axis padded to the longest workload (holding each job's last
  value, so long jobs keep their final utilization), ``net_tx`` to (W, J);
- one stacked job table — every ``load_jobs``-style field padded to
  ``cfg.max_jobs`` with a leading W axis, plus ``n_valid`` (W,) int32.

The banked bank feeds ``build_statics`` directly and is shared by every
vmapped env/replica: a ``SimState.workload`` int32 selects the slice at
trace-lookup time (``core.power.job_utilization``), so per-env memory is
O(sim state), not O(bank) — the invariant the lightweight-state RL rollout
engine is built on.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.sim import SimConfig

# job-table fields installed per env at reset; everything else in a jobs
# dict (e.g. the helper field ``is_gpu``) is loader-internal and dropped
JOB_FIELDS = ("submit_t", "dur", "n_nodes", "req", "priority", "part")


def _pad_quanta(a: np.ndarray, J: int, qmax: int) -> np.ndarray:
    out = np.zeros((J, qmax), np.float32)
    out[: a.shape[0], : a.shape[1]] = a[:J]
    # hold last value so long jobs keep their final utilization
    out[: a.shape[0], a.shape[1]:] = a[:J, -1:]
    return out


def _pad_jobs(jobs: Dict[str, np.ndarray], J: int) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    n = len(jobs["submit_t"])
    for name in JOB_FIELDS:
        if name not in jobs:
            continue
        arr = np.asarray(jobs[name])
        shape = (arr.shape[0], J) if name == "req" else (J,) + arr.shape[1:]
        buf = np.zeros(shape, arr.dtype)
        if name == "req":
            buf[:, :n] = arr
        else:
            buf[:n] = arr
        out[name] = buf
    out["n_valid"] = np.int32(n)
    return out


def stack_workloads(
    cfg: SimConfig, workloads: Sequence[Tuple[Dict, Dict]]
) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    """[(jobs, bank), ...] -> (stacked jobs (leading W axis), banked trace
    bank {"cpu": (W, J, Qmax), "gpu": (W, J, Qmax), "net_tx": (W, J)})."""
    if not workloads:
        raise ValueError("stack_workloads needs at least one workload")
    J = cfg.max_jobs
    qmax = max(b["cpu"].shape[1] for _, b in workloads)
    def pad_net(a):
        out = np.zeros((J,), np.float32)
        out[: min(len(a), J)] = np.asarray(a, np.float32)[:J]
        return out

    bank = {
        "cpu": np.stack([_pad_quanta(b["cpu"], J, qmax) for _, b in workloads]),
        "gpu": np.stack([_pad_quanta(b["gpu"], J, qmax) for _, b in workloads]),
        "net_tx": np.stack([pad_net(b["net_tx"]) for _, b in workloads]),
    }
    padded: List[Dict[str, np.ndarray]] = [_pad_jobs(j, J) for j, _ in workloads]
    jobs = {name: np.stack([p[name] for p in padded]) for name in padded[0]}
    return jobs, bank
