"""Structured validation for trace / signal / jobs-dict ingestion.

Real SuperCloud exports (and grid-operator CSV feeds) arrive with the
usual defects: unparseable cells, NaN/Inf columns, end-before-start
timestamps, duplicate job ids, partitions that no longer exist. This
module is the single validation pass wired into
``trace_io.load_supercloud``, ``grid_signals.load_signal_csv`` and the
jobs-dict path (``core.state.load_jobs``):

- ``strict`` mode raises a typed error (`TraceValidationError` /
  `SignalValidationError`) whose message names every failed check and
  the offending row indices, with the full machine-readable report
  attached as ``err.report``;
- ``repair`` mode quarantines bad rows (interpolates bad samples, for
  uniform-grid signals) and returns an `IngestionReport` that accounts
  for **every** dropped row: ``n_input == n_ok + n_quarantined`` always
  holds, so downstream tooling can audit exactly what was discarded;
- ``off`` skips validation (trusted in-memory synthetic data).

Checks that cannot be repaired row-wise (a signal feed with too few
samples or a non-uniform time grid) raise in both modes — there is no
sound repair, and silently resampling would fabricate data.
"""

from __future__ import annotations

import dataclasses
import math
from collections import Counter
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.utils.errors import (
    ConfigError,
    SignalValidationError,
    TraceValidationError,
)

MODES = ("strict", "repair", "off")

# columns of scheduler-log.csv that must parse as finite numbers
_SCHED_NUMERIC = (
    "job_id", "time_submit", "time_start", "time_end",
    "nodes_alloc", "cpus_req", "gpus_req", "mem_req_gb",
)


def _check_mode(mode: str) -> str:
    if mode not in MODES:
        raise ConfigError(
            f"validation mode must be one of {MODES}, got {mode!r}")
    return mode


@dataclasses.dataclass
class IngestionReport:
    """Machine-readable account of one validation pass.

    ``quarantined`` holds one entry per dropped/repaired row:
    ``{"row": <0-based index>, "check": <check name>, "detail": <str>}``
    (plus ``"job_id"`` where applicable). ``warnings`` are advisory —
    the row was kept (e.g. unknown partition name resolved through the
    documented type fallback). The invariant every consumer may rely on:
    ``n_input == n_ok + n_quarantined``.
    """

    source: str
    kind: str                      # "trace" | "telemetry" | "signal" | "jobs"
    mode: str
    n_input: int = 0
    n_ok: int = 0
    quarantined: List[dict] = dataclasses.field(default_factory=list)
    warnings: List[dict] = dataclasses.field(default_factory=list)
    n_skipped_unknown_id: int = 0   # telemetry rows for ids outside the log

    @property
    def n_quarantined(self) -> int:
        return len(self.quarantined)

    @property
    def clean(self) -> bool:
        return not self.quarantined

    def counts(self) -> Dict[str, int]:
        """``{check name: number of quarantined rows}``."""
        return dict(Counter(e["check"] for e in self.quarantined))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["n_quarantined"] = self.n_quarantined
        d["counts"] = self.counts()
        return d

    def describe(self, max_rows: int = 8) -> str:
        """One-line actionable summary naming checks and row indices."""
        if self.clean:
            return f"{self.source}: {self.n_ok}/{self.n_input} rows ok"
        parts = []
        by_check: Dict[str, List[int]] = {}
        for e in self.quarantined:
            by_check.setdefault(e["check"], []).append(e["row"])
        for check, idxs in by_check.items():
            shown = ", ".join(str(i) for i in idxs[:max_rows])
            more = f", +{len(idxs) - max_rows} more" if len(idxs) > max_rows \
                else ""
            parts.append(f"{check}: {len(idxs)} row(s) [{shown}{more}]")
        return (f"{self.source}: {self.n_quarantined}/{self.n_input} row(s) "
                f"failed validation — " + "; ".join(parts))

    def raise_if_dirty(self, exc_cls) -> None:
        if self.quarantined:
            raise exc_cls(self.describe(), report=self)


# ---------------------------------------------------------------- scheduler

def validate_sched_rows(
    rows: List[dict],
    cfg=None,
    *,
    mode: str = "repair",
    source: str = "scheduler-log.csv",
) -> Tuple[List[dict], IngestionReport]:
    """Validate raw ``csv.DictReader`` rows of a scheduler log.

    Checks, in order per row: required columns present and parseable,
    finite values, ``submit <= start <= end`` (monotone per-job
    timestamps), non-negative submit time, ``nodes_alloc >= 1``,
    non-negative resource requests, unique job ids (first occurrence
    wins). Unknown partition names are a *warning*, never a quarantine —
    the documented fallback (GPU jobs -> first GPU type, else first
    CPU-only type) is load-bearing for renamed-partition traces.
    """
    _check_mode(mode)
    rep = IngestionReport(source=source, kind="trace", mode=mode,
                          n_input=len(rows))
    if mode == "off":
        rep.n_ok = len(rows)
        return rows, rep

    type_names = {t.name for t in cfg.node_types} if cfg is not None else None
    kept: List[dict] = []
    seen_ids: set = set()
    prev_submit = -math.inf
    for i, r in enumerate(rows):
        vals = {}
        bad: Optional[Tuple[str, str]] = None
        for col in _SCHED_NUMERIC:
            raw = r.get(col)
            if raw is None or raw == "":
                bad = ("missing_column", f"column {col!r} absent/empty")
                break
            try:
                vals[col] = float(raw)
            except (TypeError, ValueError):
                bad = ("unparseable", f"{col}={raw!r}")
                break
        if bad is None:
            if not all(math.isfinite(vals[c]) for c in _SCHED_NUMERIC):
                cols = [c for c in _SCHED_NUMERIC
                        if not math.isfinite(vals[c])]
                bad = ("non_finite", f"NaN/Inf in {cols}")
            elif not (vals["time_submit"] <= vals["time_start"]
                      <= vals["time_end"]):
                bad = ("non_monotone_times",
                       f"submit={vals['time_submit']} start="
                       f"{vals['time_start']} end={vals['time_end']}")
            elif vals["time_submit"] < 0:
                bad = ("negative_time", f"time_submit={vals['time_submit']}")
            elif vals["nodes_alloc"] < 1:
                bad = ("bad_node_count", f"nodes_alloc={vals['nodes_alloc']}")
            elif min(vals["cpus_req"], vals["gpus_req"],
                     vals["mem_req_gb"]) < 0:
                bad = ("negative_request",
                       f"cpus={vals['cpus_req']} gpus={vals['gpus_req']} "
                       f"mem_gb={vals['mem_req_gb']}")
            elif int(vals["job_id"]) in seen_ids:
                bad = ("duplicate_job_id", f"job_id={int(vals['job_id'])} "
                       "already seen (first occurrence kept)")
        if bad is not None:
            rep.quarantined.append({
                "row": i, "job_id": r.get("job_id"),
                "check": bad[0], "detail": bad[1]})
            continue
        seen_ids.add(int(vals["job_id"]))
        if vals["time_submit"] < prev_submit and not any(
                w["check"] == "unsorted_submit" for w in rep.warnings):
            rep.warnings.append({
                "row": i, "check": "unsorted_submit",
                "detail": "submit column not globally sorted (harmless: "
                          "replay dispatches at recorded starts)"})
        prev_submit = max(prev_submit, vals["time_submit"])
        if type_names is not None:
            pname = r.get("partition", "")
            if pname not in type_names:
                rep.warnings.append({
                    "row": i, "check": "unknown_partition",
                    "detail": f"partition={pname!r} -> documented type "
                              "fallback"})
        kept.append(r)
    rep.n_ok = len(kept)
    if mode == "strict":
        rep.raise_if_dirty(TraceValidationError)
    return kept, rep


# ---------------------------------------------------------------- telemetry

def check_telemetry_row(
    row: dict,
    *,
    util_col: str,
    lo: float,
    hi: float,
    rownum: int,
    report: IngestionReport,
) -> Optional[Tuple[int, float, float]]:
    """Parse + validate one telemetry row; ``None`` means quarantined.

    Utilization must land in ``[lo, hi]`` (cpu_util in [0,1], gpu
    util_pct in [0,100]); timestamps must be finite and non-negative.
    """
    try:
        jid = int(float(row["job_id"]))
        t = float(row["timestamp"])
        u = float(row[util_col])
    except (KeyError, TypeError, ValueError) as e:
        report.quarantined.append({
            "row": rownum, "job_id": row.get("job_id"),
            "check": "unparseable", "detail": repr(e)})
        return None
    if not (math.isfinite(t) and math.isfinite(u)):
        report.quarantined.append({
            "row": rownum, "job_id": row.get("job_id"),
            "check": "non_finite",
            "detail": f"timestamp={t} {util_col}={u}"})
        return None
    if t < 0:
        report.quarantined.append({
            "row": rownum, "job_id": row.get("job_id"),
            "check": "negative_time", "detail": f"timestamp={t}"})
        return None
    if not (lo <= u <= hi):
        report.quarantined.append({
            "row": rownum, "job_id": row.get("job_id"),
            "check": "util_out_of_range",
            "detail": f"{util_col}={u} outside [{lo}, {hi}]"})
        return None
    return jid, t, u


# ---------------------------------------------------------------- jobs dict

_JOBS_REQUIRED = ("submit_t", "dur", "n_nodes", "req")


def validate_jobs(
    jobs: Dict[str, np.ndarray],
    *,
    mode: str = "strict",
    source: str = "jobs dict",
    n_types: Optional[int] = None,
) -> Tuple[Dict[str, np.ndarray], IngestionReport]:
    """Validate an in-memory jobs dict (the ``load_jobs`` input shape).

    Per-job checks: finite values everywhere, ``dur > 0``,
    ``submit_t >= 0``, ``n_nodes >= 1``, ``req >= 0``, and (when present)
    ``part`` in ``[-1, n_types)``. Structural defects — missing keys or
    mismatched column lengths — raise in every mode: a column-length
    mismatch cannot be repaired row-wise because row identity is
    ambiguous. Repair mode drops bad jobs from every column coherently.
    """
    _check_mode(mode)
    rep = IngestionReport(source=source, kind="jobs", mode=mode)
    if mode == "off":
        rep.n_input = rep.n_ok = len(np.atleast_1d(jobs["submit_t"]))
        return jobs, rep

    missing = [k for k in _JOBS_REQUIRED if k not in jobs]
    if missing:
        raise TraceValidationError(
            f"{source}: missing required key(s) {missing}", report=rep)
    arrs = {k: np.asarray(v) for k, v in jobs.items()}
    J = arrs["submit_t"].shape[0]
    rep.n_input = J
    for k, v in arrs.items():
        n = v.shape[-1] if k == "req" else v.shape[0]
        if n != J:
            raise TraceValidationError(
                f"{source}: column {k!r} has {n} jobs, expected {J} "
                "(mismatched column lengths are not row-repairable)",
                report=rep)
    if arrs["req"].ndim != 2 or arrs["req"].shape[0] != 3:
        raise TraceValidationError(
            f"{source}: req must have shape (3, J), got "
            f"{arrs['req'].shape}", report=rep)

    checks = [
        ("non_finite", ~np.all(
            [np.isfinite(np.asarray(v, np.float64)).reshape(-1, J).all(0)
             for v in arrs.values()], axis=0)),
        ("non_positive_duration", np.asarray(arrs["dur"]) <= 0),
        ("negative_time", np.asarray(arrs["submit_t"]) < 0),
        ("bad_node_count", np.asarray(arrs["n_nodes"]) < 1),
        ("negative_request", (np.asarray(arrs["req"]) < 0).any(axis=0)),
    ]
    if "part" in arrs:
        part = np.asarray(arrs["part"])
        bad_part = part < -1
        if n_types is not None:
            bad_part |= part >= n_types
        checks.append(("bad_partition", bad_part))

    bad = np.zeros(J, bool)
    for check, mask in checks:
        mask = np.asarray(mask, bool) & ~bad   # first failing check wins
        for j in np.nonzero(mask)[0]:
            rep.quarantined.append({
                "row": int(j), "check": check,
                "detail": f"job index {int(j)}"})
        bad |= mask
    rep.n_ok = int(J - bad.sum())
    if mode == "strict":
        rep.raise_if_dirty(TraceValidationError)
    if bad.any():
        keep = ~bad
        jobs = {k: (v[:, keep] if k == "req" else v[keep])
                for k, v in arrs.items()}
    return jobs, rep


# ------------------------------------------------------------------ signals

def validate_signal_samples(
    t: np.ndarray,
    v: np.ndarray,
    *,
    mode: str = "strict",
    source: str = "signal",
    min_len: int = 2,
) -> Tuple[np.ndarray, np.ndarray, IngestionReport]:
    """Validate a ``(timestamps, values)`` signal feed.

    Structural checks (raise `SignalValidationError` in every mode — no
    sound row-wise repair exists): at least ``min_len`` samples, finite
    strictly-increasing timestamps, uniform spacing (tolerance 1e-3 of
    the median step). Value checks: finite everywhere; ``repair`` mode
    linearly interpolates non-finite samples over the uniform grid
    (keeping feed length — dropping rows would break uniformity) and
    records each repaired index in the report.
    """
    _check_mode(mode)
    t = np.asarray(t, np.float64).reshape(-1)
    v = np.asarray(v, np.float64).reshape(-1)
    rep = IngestionReport(source=source, kind="signal", mode=mode,
                          n_input=len(t))
    if mode == "off":
        rep.n_ok = len(t)
        return t, v.astype(np.float32), rep

    if len(t) != len(v):
        raise SignalValidationError(
            f"{source}: {len(t)} timestamps vs {len(v)} values", report=rep)
    if len(t) < min_len:
        raise SignalValidationError(
            f"{source}: need >= {min_len} samples, got {len(t)}", report=rep)
    if not np.isfinite(t).all():
        idx = np.nonzero(~np.isfinite(t))[0]
        raise SignalValidationError(
            f"{source}: non-finite timestamp(s) at row(s) "
            f"{idx[:8].tolist()}", report=rep)
    dts = np.diff(t)
    if (dts <= 0).any():
        idx = int(np.nonzero(dts <= 0)[0][0])
        raise SignalValidationError(
            f"{source}: timestamps not strictly increasing at row "
            f"{idx + 1} (t[{idx}]={t[idx]} -> t[{idx + 1}]={t[idx + 1]})",
            report=rep)
    dt = float(np.median(dts))
    off_grid = np.abs(dts - dt) > 1e-3 * max(dt, 1.0)
    if off_grid.any():
        idx = int(np.nonzero(off_grid)[0][0])
        raise SignalValidationError(
            f"{source}: timestamps not uniformly spaced (median step "
            f"{dt:.6g}, step {idx}->{idx + 1} is {dts[idx]:.6g}); "
            "resample upstream", report=rep)

    bad = ~np.isfinite(v)
    for i in np.nonzero(bad)[0]:
        rep.quarantined.append({
            "row": int(i), "check": "non_finite_value",
            "detail": f"value[{int(i)}]={v[int(i)]!r}"})
    rep.n_ok = int(len(v) - bad.sum())
    if mode == "strict":
        rep.raise_if_dirty(SignalValidationError)
    if bad.any():
        if bad.all():
            raise SignalValidationError(
                f"{source}: every value is non-finite; nothing to "
                "interpolate from", report=rep)
        good = np.nonzero(~bad)[0]
        v = v.copy()
        v[bad] = np.interp(np.nonzero(bad)[0], good, v[good])
    return t, v.astype(np.float32), rep


__all__ = [
    "MODES",
    "IngestionReport",
    "validate_sched_rows",
    "check_telemetry_row",
    "validate_jobs",
    "validate_signal_samples",
]
