from repro.data.grid_signals import (
    load_signal_csv,
    synth_grid_trace,
    write_signal_csv,
)
from repro.data.bank import stack_workloads
from repro.data.synth_trace import synth_workload
from repro.data.trace_io import load_supercloud, write_supercloud_csvs
from repro.data.synth_lm import lm_batches, lm_batch_at
from repro.data.validate import (
    IngestionReport,
    validate_jobs,
    validate_sched_rows,
    validate_signal_samples,
)
