from repro.sharding.ctx import ShardCtx
from repro.sharding.specs import (
    FLEET_AXIS,
    fleet_pspecs,
    fleet_shardings,
    param_pspecs,
    pcast_varying,
    replicated_pspecs,
    shard_map_compat,
    train_state_pspecs,
)
