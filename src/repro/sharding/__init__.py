from repro.sharding.ctx import ShardCtx
from repro.sharding.specs import param_pspecs, train_state_pspecs
