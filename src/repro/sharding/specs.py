"""PartitionSpecs for BOTH halves of the repo.

Sim half (the digital twin): replica-batched fleet pytrees
(``SimState``/``Scenario``/``Policy``/``TelemetrySummary``) shard their
leading replica axis across a 1-D fleet mesh — ``fleet_pspecs`` /
``replicated_pspecs`` / ``fleet_shardings`` below, consumed by
``core.fleet.run_fleet(..., mesh=...)`` and ``rl.distributed``. The
module also hosts the ``shard_map``/``pcast`` compat shims so every
sharded caller works on the pinned jax floor (``jax.experimental.
shard_map``, no ``pcast`` — replication checking is disabled there,
which is exactly what keeps ``jax.grad`` local inside a shard).

LM half: parameter PartitionSpecs derived from param *names* and shapes.

Megatron-style TP over the 'model' axis + ZeRO-3/FSDP over the data axes:

  emb (V, D)            -> P(tp, fsdp)     vocab-parallel embedding
  head (D, V)           -> P(fsdp, tp)
  wq/wk/wv (D, H*hd)    -> P(fsdp, tp)     column-parallel
  wo (H*hd, D)          -> P(tp, fsdp)     row-parallel
  wi/wg (D, F)          -> P(fsdp, tp)
  wo2 (F, D)            -> P(tp, fsdp)
  router (D, E)         -> P(fsdp, None)
  experts (E, D, F)     -> P(tp, fsdp, None) when E % |tp| == 0 (EP)
                           else P(None, fsdp, tp) (TP inside experts)
  mamba in_proj (D,2di) -> P(fsdp, tp); out_proj (di, D) -> P(tp, fsdp)
  scalars/norms/biases  -> replicated
  stacked layer leading axis (superblock repeats) -> None prepended
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import spec as S
from repro.sharding.ctx import ShardCtx
from repro.utils.tree import tree_map_with_path_names

# --------------------------------------------------------------- sim half
FLEET_AXIS = "replica"   # canonical fleet-mesh axis name (launch.mesh)


def fleet_pspecs(tree: Any, axis: str = FLEET_AXIS) -> Any:
    """PartitionSpec pytree sharding every leaf's LEADING axis over the
    fleet mesh axis — the spec for replica-batched sim pytrees (batched
    ``SimState``/``Scenario``/``Policy``, per-replica PRNG keys, fleet
    telemetry). Leaves are uniform on the replica axis by construction
    (``run_fleet`` broadcasts/stacks them), so one rule covers the tree."""
    return jax.tree.map(lambda _: P(axis), tree)


def replicated_pspecs(tree: Any) -> Any:
    """Fully-replicated PartitionSpec pytree — for ``Statics`` (node
    tables, trace bank, scenario defaults) and other shared constants
    every shard reads but none owns."""
    return jax.tree.map(lambda _: P(), tree)


def fleet_shardings(mesh, tree: Any, axis: str = FLEET_AXIS) -> Any:
    """NamedSharding pytree for ``jax.device_put``-ing a replica-batched
    fleet pytree onto ``mesh`` (see ``core.fleet.shard_fleet``)."""
    from jax.sharding import NamedSharding

    return jax.tree.map(lambda _: NamedSharding(mesh, P(axis)), tree)


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` where available, else the ``jax.experimental``
    one with replication checking off (the pinned floor has no ``pcast``
    to mark closed-over/replicated values varying, and ``check_rep=False``
    is what keeps AD from inserting its own psum around ``jax.grad``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def pcast_varying(tree: Any, axis: str) -> Any:
    """Mark a replicated pytree shard-varying along ``axis`` (VMA) so
    ``jax.grad`` inside a shard_map stays local. No-op on the jax floor:
    there ``shard_map_compat`` already runs with ``check_rep=False``,
    under which everything is treated as varying."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return tree
    return jax.tree.map(lambda x: pcast(x, axis, to="varying"), tree)


# ---------------------------------------------------------------- LM half
# param base-name -> (logical axes per dim), for unstacked shapes
_COL = ("fsdp", "tp")   # (in, out-sharded)
_ROW = ("tp", "fsdp")   # (in-sharded, out)
_RULES: Dict[str, tuple] = {
    "emb": ("tp", "fsdp"),
    "head": _COL,
    "wq": _COL, "wk": _COL, "wv": _COL, "wo": _ROW,
    "xq": _COL, "xk": _COL, "xv": _COL, "xo": _ROW,
    "wi": _COL, "wg": _COL, "wo2": _ROW,
    "router": ("fsdp", None),
    "in_proj": _COL,
    "out_proj": _ROW,
    "x_proj": ("tp", None),
    "dt_w": (None, "tp"),
    "A_log": ("tp", None),
    "conv_w": (None, "tp"),
    "up": _COL,
    "down": _ROW,
    "w": ("fsdp", None),
    "r": (None, None, None),
}
# per-di vectors live on the tp axis
_TP_VECTORS = {"conv_b", "dt_b", "D_skip", "ln_inner_mamba"}


def _dims_divisible(shape, axes, ctx: ShardCtx, mesh_axis_sizes) -> bool:
    for dim, ax in zip(shape, axes):
        if ax is None:
            continue
        size = mesh_axis_sizes[ax]
        if dim % size != 0:
            return False
    return True


def _expert_rule(cfg: ModelConfig, name: str, tp_size: int):
    ep = cfg.moe.n_experts % max(tp_size, 1) == 0 and cfg.moe.n_experts >= tp_size
    if name in ("e_wg", "e_wi"):
        return ("tp", "fsdp", None) if ep else (None, "fsdp", "tp")
    if name == "e_wo":
        return ("tp", None, "fsdp") if ep else (None, "tp", "fsdp")
    raise KeyError(name)


def param_pspecs(cfg: ModelConfig, ctx: ShardCtx, mesh=None) -> Any:
    """Pytree of PartitionSpec mirroring ``model_param_specs(cfg)``."""
    specs = S.model_param_specs(cfg)
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    else:
        sizes = None
    tp_size = sizes["model"] if sizes and "model" in sizes else 16

    def logical_for(name: str, shape) -> tuple:
        base = name.rsplit("/", 1)[-1]
        if base in ("e_wg", "e_wi", "e_wo"):
            return _expert_rule(cfg, base, tp_size)
        if base in _TP_VECTORS and len(shape) == 1:
            return ("tp",)
        rule = _RULES.get(base)
        if rule is None or len(rule) != len(shape):
            return tuple(None for _ in shape)
        return rule

    def one(name: str, sds) -> P:
        shape = sds.shape
        stacked = (
            name.startswith(("body/", "xattn_body/"))
            or "/layers/" in name
            or name.startswith("encoder/layers")
        )
        core_shape = shape[1:] if stacked else shape
        logical = logical_for(name, core_shape)
        if not ctx.fsdp:
            logical = tuple(None if a == "fsdp" else a for a in logical)
        if not ctx.expert_parallel and name.rsplit("/", 1)[-1].startswith("e_w"):
            logical = tuple(None if a == "tp" and i == 0 else a
                            for i, a in enumerate(logical))
        axes = [ctx.axis(a) for a in logical]
        # drop shardings that do not divide (keeps XLA from padding params)
        if sizes is not None:
            for i, (dim, ax) in enumerate(zip(core_shape, axes)):
                if ax is None:
                    continue
                n = int(np.prod([sizes[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
                if dim % n != 0:
                    axes[i] = None
        if stacked:
            axes = [None] + axes
        return P(*axes)

    return tree_map_with_path_names(one, specs)


def batch_pspec(ctx: ShardCtx) -> P:
    return P(ctx.axis("dp"))


def batch_pspecs(cfg: ModelConfig, shape, ctx: ShardCtx):
    """PartitionSpecs mirroring models.batch_specs(cfg, shape)."""
    dp = ctx.axis("dp")
    if shape.mode in ("train", "prefill"):
        out = {"tokens": P(dp, None)}
        if shape.mode == "train":
            out["labels"] = P(dp, None)
        if cfg.n_vision_tokens:
            out["vision"] = P(dp, None, None)
        if cfg.enc_dec:
            out["audio"] = P(dp, None, None)
        return out
    small_batch = ctx.decode_kv_shard == "seq2d"
    return {
        "tokens": P(None if small_batch else dp, None),
        "cache": cache_pspecs(cfg, ctx),
        "cache_len": P(),
    }


def cache_pspecs(cfg: ModelConfig, ctx: ShardCtx):
    """PartitionSpec tree mirroring models.cache_specs (decode caches)."""
    from repro.models.model import cache_specs

    template = cache_specs(cfg, 8, 64)   # structure only; shapes irrelevant
    kv = ctx.kv_cache_pspec()
    dp = None if ctx.decode_kv_shard == "seq2d" else ctx.axis("dp")
    tp = ctx.tp if ctx.enabled else None

    def one(name, sds):
        base = name.rsplit("/", 1)[-1]
        stacked = name.startswith("body/")
        nd = len(sds.shape) - (1 if stacked else 0)
        if base in ("k", "v"):
            spec = list(kv) + [None] * (4 - len(kv))
        elif base in ("xk", "xv"):
            spec = [dp, None, None, None]
        elif base == "conv":
            spec = [dp, None, tp]
        elif base == "ssm":
            spec = [dp, tp, None]
        elif base in ("C", "n"):
            spec = [dp] + [None] * (nd - 1)
        else:   # m, c, h and other small per-batch states
            spec = [dp] + [None] * (nd - 1)
        spec = spec[:nd] + [None] * (nd - len(spec))
        if stacked:
            spec = [None] + spec
        return P(*spec)

    return tree_map_with_path_names(one, template)


def train_state_pspecs(cfg: ModelConfig, ctx: ShardCtx, optimizer, mesh=None):
    """PartitionSpecs for a TrainState built by repro.train.state."""
    p_specs = param_pspecs(cfg, ctx, mesh)
    opt_specs = optimizer.state_pspecs(S.model_param_specs(cfg), p_specs)
    return {
        "params": p_specs,
        "opt": opt_specs,
        "step": P(),
    }
