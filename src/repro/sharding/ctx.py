"""Logical-axis sharding context threaded through the model code.

Model code annotates activations with *logical* axes ('dp', 'tp', 'sp',
'fsdp', None); the context maps them onto physical mesh axes and emits
``with_sharding_constraint`` — or nothing when running unsharded (CPU
smoke tests), so the same model code serves both worlds.

Physical mapping (production mesh):
  dp   -> ('pod', 'data')   batch
  tp   -> 'model'           heads / d_ff / experts / vocab
  sp   -> 'model'           sequence parallelism for the residual stream
  fsdp -> ('pod', 'data')   parameter & optimizer-state sharding (ZeRO-3)
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardCtx:
    enabled: bool = False
    dp: Tuple[str, ...] = ("data",)
    tp: Optional[str] = "model"
    # feature flags (hillclimb knobs)
    seq_parallel: bool = True        # shard residual stream's seq dim over tp
    fsdp: bool = True                # shard params over dp axes
    expert_parallel: bool = True     # shard MoE experts over tp when divisible
    decode_kv_shard: str = "seq"     # 'seq' | 'seq2d' | 'head' | 'none'
    attention_impl: str = "auto"     # 'auto' | 'full' | 'chunked' | 'pallas'
    tp_size: int = 16                # |model| axis (for divisibility checks)
    dp_size: int = 1                 # |data(*pod)| product (MoE groups)
    force_unroll: bool = False       # unroll layer scans (cost probes)
    cast_params_bf16: bool = True    # cast-then-gather: FSDP gathers move
                                     # bf16, halving ICI bytes + live temps
    block_q: int = 512
    block_k: int = 1024
    logit_chunk: int = 1024          # seq-chunked loss for big vocabs
    scan_unroll: int = 1             # layer-scan unroll (overlap knob)
    remat: str = "block"             # 'none' | 'block' (superblock) | 'layer'
                                     # 'layer': per-layer checkpoints inside
                                     # the scan body — FSDP-gathered weights
                                     # of only ~1 layer live at a time

    def axis(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "dp":
            return self.dp if len(self.dp) > 1 else self.dp[0]
        if logical == "fsdp":
            return self.dp if len(self.dp) > 1 else self.dp[0]
        if logical == "sp":
            return self.tp if self.seq_parallel else None
        if logical == "tp":
            return self.tp
        raise ValueError(f"unknown logical axis {logical}")

    def pspec(self, *logical) -> P:
        return P(*[self.axis(a) for a in logical])

    def constrain(self, x: jax.Array, *logical) -> jax.Array:
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, self.pspec(*logical))

    def constrain_raw(self, x: jax.Array, spec: P) -> jax.Array:
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, spec)

    def kv_cache_pspec(self) -> P:
        """PartitionSpec for a (B, S, Kv, hd) decode KV cache."""
        if not self.enabled or self.decode_kv_shard == "none":
            return P()
        if self.decode_kv_shard == "seq":
            return P(self.axis("dp"), self.tp, None, None)
        if self.decode_kv_shard == "seq2d":
            # batch too small to shard: spread the sequence over every axis
            return P(None, tuple(self.dp) + (self.tp,), None, None)
        if self.decode_kv_shard == "head":
            return P(self.axis("dp"), None, self.tp, None)
        raise ValueError(self.decode_kv_shard)

    def with_(self, **kw) -> "ShardCtx":
        return replace(self, **kw)

    def heads_axis(self, n_heads: int):
        """'model' if the head count divides evenly, else None (replicate)."""
        return self.tp if (self.tp and n_heads % max(self.tp_size, 1) == 0) else None


UNSHARDED = ShardCtx(enabled=False)


def make_ctx(multi_pod: bool, **kw) -> ShardCtx:
    dp = ("pod", "data") if multi_pod else ("data",)
    return ShardCtx(enabled=True, dp=dp, tp="model", **kw)
