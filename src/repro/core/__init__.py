"""The paper's primary contribution: the ExaDigiT/RAPS-style datacenter
digital twin — trace replay, rescheduling, power/cooling/carbon chain,
network congestion, failures — as a pure-JAX vectorized simulator.
"""

from repro.core.faults import (
    LVL_DRAIN,
    LVL_EVICT,
    LVL_GATE,
    LVL_NORMAL,
    LVL_THROTTLE,
    apply_faults,
    effective_level,
    next_fault_event,
)
from repro.core.fleet import (
    fleet_summary,
    policy_scenario_grid,
    run_fleet,
    shard_fleet,
)
from repro.core.placement import (
    PLACE_IDS,
    PLACEMENTS,
    Policy,
    make_policy,
    policy_grid,
    stack_policies,
)
from repro.core.schedulers import SCHEDULERS, SELECT_IDS
from repro.core.serving import (
    apply_serving,
    next_serving_event,
    retry_backoff,
    serving_crossing_horizon,
    serving_flow,
    serving_power,
    serving_trigger,
)
from repro.core.thermal import (
    cooling_cop,
    node_trip_ok,
    rack_throttle,
    rack_thermal_update,
    supply_temp,
    thermal_alpha,
    thermal_crossing_horizon,
)
from repro.core.sim import (
    StepOut,
    TelemetrySummary,
    make_macro_step,
    make_step,
    quiet_horizon,
    run_episode,
    summary,
    summary_columns,
)
from repro.core.state import (
    DONE,
    EMPTY,
    FAILED,
    QUEUED,
    RUNNING,
    SimState,
    Statics,
    build_statics,
    init_state,
    load_jobs,
)
