"""The paper's primary contribution: the ExaDigiT/RAPS-style datacenter
digital twin — trace replay, rescheduling, power/cooling/carbon chain,
network congestion, failures — as a pure-JAX vectorized simulator.
"""

from repro.core.fleet import fleet_summary, run_fleet
from repro.core.sim import (
    StepOut,
    TelemetrySummary,
    make_step,
    run_episode,
    summary,
)
from repro.core.state import (
    DONE,
    EMPTY,
    QUEUED,
    RUNNING,
    SimState,
    Statics,
    build_statics,
    init_state,
    load_jobs,
)
