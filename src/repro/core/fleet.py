"""Fleet runner: N datacenter replicas, heterogeneous grid scenarios,
heterogeneous scheduling policies AND heterogeneous workload telemetry
(per-replica ids into one shared banked trace), one compiled call —
vmapped on one device, or shard_map-partitioned across a device mesh.

``run_fleet`` broadcasts one initial ``SimState``/``Statics`` across R
replicas, installs a per-replica ``Scenario`` (batched pytree from
``scenarios.stack_scenarios`` / ``sample_scenarios``) and optionally a
per-replica ``placement.Policy`` (batched (select_id, place_id) int32s
from ``placement.stack_policies`` / ``policy_grid``), splits the PRNG key
per replica, and runs ``vmap(lax.scan(step))`` under a single ``jit`` —
the policy x scenario sweep engine for the paper's sustainability-policy
studies. Because policies are data (ids resolved by ``lax.switch`` inside
the step), the whole grid costs ONE compilation, not one per policy.

Memory notes: the replica-batched state and key buffers are DONATED to the
compiled call (XLA reuses them for the final states), and the telemetry
knobs (``telemetry_every`` / ``summary_only``, forwarded to
``run_episode``) replace the O(R * n_steps * 16) stacked ``StepOut`` with
windowed or O(R * 16) episode-wide reductions — fleet-sweep memory then no
longer scales with ``n_steps``.

Device sharding (``mesh=``): a single-device ``vmap`` runs every
replica's macro-stepping while-loop in LOCKSTEP — the loop condition
reduces over all R lanes, so one event-busy replica drags every
fast-forwarding replica back to per-tick speed AND per-tick cost (the
full event tick is computed for all lanes on every iteration). Passing a
1-D fleet mesh (``launch.mesh.make_fleet_mesh``) partitions the replica
axis across devices via ``shard_map`` with the same ``vmap`` INSIDE each
shard: lockstep shrinks to R/D lanes, shards with quiet replicas retire
their episodes in a handful of outer iterations regardless of what other
shards are doing (no collectives inside, so each device's while-loops
run their own trip counts), and state/key donation hands XLA per-device
buffers. The per-replica computation — including the PRNG
``split``/``fold_in`` schedule, which happens on the host BEFORE the
compiled call and is shared by both paths — is identical, so sharded
final states / streams / telemetry are bit-identical to the vmapped
path (pinned by ``tests/test_multidevice.py``).
"""

from __future__ import annotations

from functools import partial
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.configs.sim import SimConfig
from repro.core.placement import Policy, make_policy, stack_policies
from repro.core.sim import (
    StepOut,
    TelemetrySummary,
    run_episode,
    run_segment,
    summary_columns,
)
from repro.core.state import SimState, Statics
from repro.scenarios.scenario import Scenario, n_replicas, stack_scenarios
from repro.sharding.specs import (
    FLEET_AXIS,
    fleet_pspecs,
    fleet_shardings,
    replicated_pspecs,
    shard_map_compat,
)
from repro.utils import invariants
from repro.utils.errors import ConfigError


def _ensure_batched(scenarios) -> Scenario:
    # NB: Scenario is itself a (Named)tuple — test for it first
    if isinstance(scenarios, Scenario):
        return scenarios
    return stack_scenarios(list(scenarios))


def _as_policy(p) -> Policy:
    # NB: Policy is itself a (Named)tuple — test for it before the
    # (select, place) name-tuple form
    if isinstance(p, Policy):
        return p
    return make_policy(*p)


def _policy_list(policies) -> List[Policy]:
    """Normalize any accepted policies input — a single Policy, a batched
    Policy (leading replica axis, e.g. from ``policy_grid``), or a list of
    Policies / (select, place) name tuples — to a list of scalar
    Policies."""
    if isinstance(policies, Policy):
        if jnp.ndim(policies.select) == 0:
            return [policies]
        return [jax.tree.map(lambda a: a[i], policies)
                for i in range(int(jnp.shape(policies.select)[0]))]
    return [_as_policy(p) for p in policies]


def _ensure_batched_policies(policies) -> Policy:
    if isinstance(policies, Policy) and jnp.ndim(policies.select) == 1:
        return policies
    return stack_policies(_policy_list(policies))


def _scenario_list(scenarios) -> List[Scenario]:
    """Normalize a single Scenario, a batched Scenario (leading replica
    axis), or an iterable of Scenarios to a list of unbatched Scenarios —
    iterating a Scenario NamedTuple directly would yield its FIELDS, not
    its replicas."""
    if isinstance(scenarios, Scenario):
        if jnp.ndim(scenarios.carbon.mean) == 0:
            return [scenarios]
        return [jax.tree.map(lambda a: a[i], scenarios)
                for i in range(n_replicas(scenarios))]
    return list(scenarios)


def policy_scenario_grid(
    policies, scenarios: Scenario | Sequence[Scenario]
) -> Tuple[Policy, Scenario]:
    """Cross P policies x S scenarios -> (batched Policy, batched Scenario)
    of length P*S, ready for ``run_fleet`` (replica i = policy i // S with
    scenario i % S). ``policies``: an already-batched Policy (e.g. from
    ``policy_grid``), Policy instances, or (select, place) name tuples;
    ``scenarios``: an already-batched Scenario (e.g. from
    ``sample_scenarios``) or a list of Scenarios."""
    pols = _policy_list(policies)
    scns = _scenario_list(scenarios)
    crossed = stack_policies([p for p in pols for _ in scns])
    return crossed, stack_scenarios(scns * len(pols))


# Module-level so repeated run_fleet calls with the same static config reuse
# the compiled executable (cfg is a frozen dataclass => hashable; statics /
# scenarios / policies / state / keys are traced). ``state``/``keys``
# arrive replica-batched and are donated: XLA reuses their buffers for the
# final states.
@partial(jax.jit, static_argnames=("cfg", "n_steps", "scheduler", "kw_items"),
         donate_argnames=("state", "keys"))
def _fleet(cfg, statics, scenarios, policies, state, keys, n_steps,
           scheduler, kw_items):
    kw = dict(kw_items)

    def one(scn: Scenario, pol, key: jax.Array, st: SimState):
        st = st._replace(key=key)
        stt = statics._replace(scenario=scn)
        who = scheduler if pol is None else pol
        return run_episode(cfg, stt, st, n_steps, who, **kw)

    return jax.vmap(one)(scenarios, policies, keys, state)


# Sharded twin of ``_fleet``: the same per-replica ``one`` under the same
# inner ``vmap``, but partitioned across ``mesh``'s fleet axis by shard_map
# so each device's R/D-lane while-loops run their own trip counts (no
# collectives inside => no cross-shard lockstep). ``mesh`` is hashable and
# rides the jit static cache alongside cfg; state/keys donation is
# per-device buffer reuse here.
@partial(jax.jit,
         static_argnames=("cfg", "n_steps", "scheduler", "kw_items", "mesh",
                          "axis"),
         donate_argnames=("state", "keys"))
def _fleet_sharded(cfg, statics, scenarios, policies, state, keys, n_steps,
                   scheduler, kw_items, mesh, axis):
    kw = dict(kw_items)

    def shard(statics, scenarios, policies, keys, state):
        def one(scn: Scenario, pol, key: jax.Array, st: SimState):
            st = st._replace(key=key)
            stt = statics._replace(scenario=scn)
            who = scheduler if pol is None else pol
            return run_episode(cfg, stt, st, n_steps, who, **kw)

        return jax.vmap(one)(scenarios, policies, keys, state)

    # per-leaf spec pytrees from sharding.specs: statics replicate, every
    # replica-batched operand splits its leading axis; the output prefix
    # spec P(axis) matches (SimState, StepOut|TelemetrySummary) alike
    return shard_map_compat(
        shard, mesh,
        in_specs=(replicated_pspecs(statics),
                  fleet_pspecs(scenarios, axis), fleet_pspecs(policies, axis),
                  fleet_pspecs(keys, axis), fleet_pspecs(state, axis)),
        out_specs=PartitionSpec(axis),
    )(statics, scenarios, policies, keys, state)


# Segment twins of ``_fleet``/``_fleet_sharded`` for snapshot/resume
# (checkpoint.episode): the same per-replica program cut at a tick
# boundary, threading a RAW TelemetrySummary accumulator instead of
# zero-init + finalize — keys are pre-installed in ``state`` (split/
# fold_in happens ONCE per run, not per segment, so resumed PRNG streams
# continue exactly where the uninterrupted run would be).
@partial(jax.jit, static_argnames=("cfg", "n_ticks", "scheduler", "kw_items"),
         donate_argnames=("state", "acc"))
def _fleet_segment(cfg, statics, scenarios, policies, state, acc, n_ticks,
                   scheduler, kw_items):
    kw = dict(kw_items)
    macro = bool(kw.pop("macro", False))
    kw.pop("summary_only", None)
    kw.pop("telemetry_every", None)

    def one(scn: Scenario, pol, st: SimState, a):
        stt = statics._replace(scenario=scn)
        who = scheduler if pol is None else pol
        return run_segment(cfg, stt, st, a, n_ticks, who, macro=macro, **kw)

    return jax.vmap(one)(scenarios, policies, state, acc)


@partial(jax.jit,
         static_argnames=("cfg", "n_ticks", "scheduler", "kw_items", "mesh",
                          "axis"),
         donate_argnames=("state", "acc"))
def _fleet_segment_sharded(cfg, statics, scenarios, policies, state, acc,
                           n_ticks, scheduler, kw_items, mesh, axis):
    kw = dict(kw_items)
    macro = bool(kw.pop("macro", False))
    kw.pop("summary_only", None)
    kw.pop("telemetry_every", None)

    def shard(statics, scenarios, policies, state, acc):
        def one(scn: Scenario, pol, st: SimState, a):
            stt = statics._replace(scenario=scn)
            who = scheduler if pol is None else pol
            return run_segment(cfg, stt, st, a, n_ticks, who,
                               macro=macro, **kw)

        return jax.vmap(one)(scenarios, policies, state, acc)

    return shard_map_compat(
        shard, mesh,
        in_specs=(replicated_pspecs(statics),
                  fleet_pspecs(scenarios, axis), fleet_pspecs(policies, axis),
                  fleet_pspecs(state, axis), fleet_pspecs(acc, axis)),
        out_specs=PartitionSpec(axis),
    )(statics, scenarios, policies, state, acc)


def shard_fleet(tree, mesh, axis: str = FLEET_AXIS):
    """``device_put`` a replica-batched fleet pytree (batched ``SimState``
    / ``Scenario`` / ``Policy`` / per-replica keys) onto ``mesh``, leading
    replica axis split in contiguous blocks across the ``axis`` devices —
    replica i lands on device i // (R / D). Optional for ``run_fleet(...,
    mesh=...)`` (jit reshards automatically) but placing inputs up front
    skips the initial all-to-device scatter on repeated/chained sweeps."""
    return jax.device_put(tree, fleet_shardings(mesh, tree, axis))


def run_fleet(
    cfg: SimConfig,
    statics: Statics,
    state: SimState,
    n_steps: int,
    scheduler: str | None = None,
    *,
    scenarios: Scenario | Sequence[Scenario] | None = None,
    policies: Policy | Sequence[Policy | Tuple[str, str]] | None = None,
    workloads: Sequence[int] | jnp.ndarray | None = None,
    mesh=None,
    mesh_axis: str = FLEET_AXIS,
    snapshot_every_s: float | None = None,
    snapshot_dir: str | None = None,
    resume_from: str | None = None,
    snapshot_keep: int = 3,
    **kw,
) -> Tuple[SimState, StepOut | TelemetrySummary]:
    """Simulate R replicas of the twin for ``n_steps`` in one jitted call.

    ``scheduler``: eager selection-policy name every replica runs
    (default 'fcfs'); mutually exclusive with ``policies`` (which carry
    the selection stage per replica — passing both is a loud error, not
    a silent override).
    ``scenarios``: batched Scenario (leading replica axis), a list of
    Scenarios (stacked here), or None (the statics' own scenario).
    ``policies``: the per-replica POLICY axis — a batched ``Policy``, a
    list of Policies or (select, place) name tuples, or None (every
    replica runs the eager ``scheduler`` string). When both axes are
    given their lengths must already match; build the cross product with
    ``policy_scenario_grid`` (or ``placement.policy_grid`` + scenario
    tiling). Policies are traced data, so ANY mix of selection x
    placement rides the same compiled executable.
    All other statics (node constants, telemetry bank) are shared and
    broadcast; each replica gets its own PRNG stream.

    ``workloads``: per-replica TELEMETRY axis — int32 ids (length R) into
    a *banked* Statics trace ((W, J, Q) ``cpu_trace``, e.g. from
    ``data.stack_workloads``); each replica's trace lookups gather through
    its id, so heterogeneous utilization profiles share ONE bank with no
    per-replica copy. The job *table* still comes from ``state`` (broadcast
    or pre-batched) — ids switch telemetry, not the submitted jobs.
    ``state`` may be a single SimState (broadcast to R replicas here) or
    an already replica-batched one — e.g. the final states of a previous
    ``run_fleet`` call for chained sweeps. A batched state's buffers are
    donated to the compiled call and must not be reused afterwards.

    ``mesh``: a 1-D fleet mesh (``launch.mesh.make_fleet_mesh``) switches
    execution to the device-sharded path — the replica axis splits in
    contiguous blocks across ``mesh_axis`` via shard_map with the same
    per-shard ``vmap`` inside, so macro while-loops lockstep only within
    a shard (see module docstring) and memory/donation happen per device.
    R must divide evenly by the mesh size (loud error otherwise — a
    silent pad would fabricate replicas whose summaries leak into sweep
    statistics). Results are bit-identical to ``mesh=None``.

    ``**kw`` forwards to ``run_episode``/``make_step`` — in particular
    ``summary_only=True`` returns per-replica ``TelemetrySummary`` with
    peak memory independent of ``n_steps``, ``telemetry_every=k`` stacks
    one windowed summary per k steps, and ``macro=True`` switches every
    replica to the macro-stepping engine: each replica fast-forwards its
    own quiet segments through the same traced computation (no host
    sync; under ``vmap`` the while-loops run lockstep, so replicas on
    event ticks overlap with replicas fast-forwarding).

    Durability: ``snapshot_every_s`` / ``snapshot_dir`` / ``resume_from``
    mirror ``run_episode``'s snapshot semantics at fleet granularity —
    one crash-atomic snapshot of the whole replica-batched state (keys
    installed, so resumed streams continue exactly) plus raw telemetry
    accumulators; resume is bit-identical to the uninterrupted sweep.
    Requires ``summary_only=True`` or ``macro=True``.

    Returns (final_states, outs) with a leading replica axis on every leaf.
    """
    if policies is not None and scheduler is not None:
        raise ConfigError(
            f"both scheduler={scheduler!r} and policies= given — policies "
            "carry the selection stage, so the scheduler name would be "
            "silently ignored; pass exactly one")
    if scheduler is None:
        scheduler = "fcfs"
    if policies is not None:
        policies = _ensure_batched_policies(policies)
        P = int(jnp.shape(policies.select)[0])
        if scenarios is None:
            scenarios = stack_scenarios([statics.scenario] * P)
        else:
            scenarios = _ensure_batched(scenarios)
            if n_replicas(scenarios) != P:
                raise ConfigError(
                    f"{P} policies vs {n_replicas(scenarios)} scenarios — "
                    "axes must match; build the cross product with "
                    "policy_scenario_grid(policies, scenarios)")
    elif scenarios is None:
        scenarios = stack_scenarios([statics.scenario])
    else:
        scenarios = _ensure_batched(scenarios)
    R = n_replicas(scenarios)
    if jnp.ndim(state.t) == 0:
        keys = jax.random.split(state.key, R)
        state = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (R,) + jnp.shape(a)), state)
    else:
        if int(jnp.shape(state.t)[0]) != R:
            raise ConfigError(
                f"batched state has {jnp.shape(state.t)[0]} replicas, "
                f"scenarios have {R}")
        # advance each replica's stream into a FRESH buffer: state and keys
        # are both donated, so aliasing keys to the state.key leaf would
        # donate one buffer twice
        keys = jax.vmap(lambda k: jax.random.fold_in(k, 1))(state.key)
    if workloads is not None:
        if jnp.ndim(statics.cpu_trace) != 3:
            raise ConfigError(
                "workloads= needs a banked Statics trace ((W, J, Q) "
                "cpu_trace, e.g. from data.stack_workloads); this statics "
                "carries a single unbatched workload")
        ids_host = np.asarray(workloads, np.int32)   # host data: check here
        if ids_host.shape != (R,):
            raise ConfigError(
                f"workloads has shape {ids_host.shape}, expected ({R},) — "
                "one bank id per replica")
        W = statics.cpu_trace.shape[0]
        lo, hi = int(ids_host.min()), int(ids_host.max())
        if lo < 0 or hi >= W:
            raise ConfigError(
                f"workload ids must be in [0, {W}) for this bank; got "
                f"[{lo}, {hi}] — an out-of-range id would silently clamp "
                "to the edge slice")
        state = state._replace(workload=jnp.asarray(ids_host))
    kw_items = tuple(sorted(kw.items()))
    if snapshot_every_s is not None or resume_from is not None \
            or snapshot_dir is not None:
        from repro.checkpoint.episode import run_fleet_snapshotted

        out = run_fleet_snapshotted(
            cfg, statics, scenarios, policies, state, keys, n_steps,
            scheduler, kw, mesh=mesh, mesh_axis=mesh_axis,
            snapshot_every_s=snapshot_every_s, snapshot_dir=snapshot_dir,
            resume_from=resume_from, snapshot_keep=snapshot_keep)
        if invariants.enabled():
            invariants.check_state(cfg, statics, out[0])
        return out
    if mesh is not None:
        if mesh_axis not in mesh.shape:
            raise ConfigError(
                f"mesh has axes {tuple(mesh.shape)}, no {mesh_axis!r} — "
                "build a fleet mesh with launch.mesh.make_fleet_mesh()")
        n_shards = int(mesh.shape[mesh_axis])
        if R % n_shards:
            raise ConfigError(
                f"{R} replicas do not divide across {n_shards} "
                f"{mesh_axis!r}-axis devices — a silent pad would "
                "fabricate replicas; pick R as a multiple of the mesh "
                "size or shrink the mesh (make_fleet_mesh(n_devices=...))")
        out = _fleet_sharded(cfg, statics, scenarios, policies, state, keys,
                             n_steps, scheduler, kw_items, mesh, mesh_axis)
    else:
        out = _fleet(cfg, statics, scenarios, policies, state, keys, n_steps,
                     scheduler, kw_items)
    if invariants.enabled():
        # post-hoc eager audit of every replica's final state (the checks
        # broadcast over the leading replica axis); the per-step checkify
        # suite only instruments un-traced run_episode calls, so this is
        # what REPRO_CHECKIFY buys on the vmapped fleet path
        invariants.check_state(cfg, statics, out[0])
    return out


def fleet_summary(
    final_states: SimState,
    telemetry: TelemetrySummary | None = None,
) -> List[Dict[str, float]]:
    """Per-replica ``summary`` dicts from batched final states. Pass the
    per-replica ``TelemetrySummary`` (``summary_only=True`` output) to also
    surface the macro-stepping skip accounting (``ticks_simulated`` /
    ``macro_steps_taken`` / ``macro_skip_ratio``) per replica.

    All reductions run vectorized over the replica axis in
    ``sim.summary_columns`` (one device->host transfer, numpy column
    math); only the final dict-of-floats fan-out is Python, so the host
    tail of a 1024-replica sweep is milliseconds, not the former
    per-replica ``summary`` loop."""
    cols = summary_columns(final_states, telemetry)
    R = int(np.shape(cols["t_end_s"])[0])
    return [{k: float(v[i]) for k, v in cols.items()} for i in range(R)]
