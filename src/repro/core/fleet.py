"""Vmapped fleet runner: N datacenter replicas, heterogeneous grid
scenarios, one compiled call.

``run_fleet`` broadcasts one initial ``SimState``/``Statics`` across R
replicas, installs a per-replica ``Scenario`` (batched pytree from
``scenarios.stack_scenarios`` / ``sample_scenarios``), splits the PRNG key
per replica, and runs ``vmap(lax.scan(step))`` under a single ``jit`` —
the scenario-sweep engine for the paper's sustainability-policy studies.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.configs.sim import SimConfig
from repro.core.sim import StepOut, run_episode, summary
from repro.core.state import SimState, Statics
from repro.scenarios.scenario import Scenario, n_replicas, stack_scenarios


def _ensure_batched(scenarios) -> Scenario:
    # NB: Scenario is itself a (Named)tuple — test for it first
    if isinstance(scenarios, Scenario):
        return scenarios
    return stack_scenarios(list(scenarios))


# Module-level so repeated run_fleet calls with the same static config reuse
# the compiled executable (cfg is a frozen dataclass => hashable; statics /
# scenarios / state / keys are traced).
@partial(jax.jit, static_argnames=("cfg", "n_steps", "scheduler", "kw_items"))
def _fleet(cfg, statics, scenarios, state, keys, n_steps, scheduler, kw_items):
    kw = dict(kw_items)

    def one(scn: Scenario, key: jax.Array):
        st = state._replace(key=key)
        stt = statics._replace(scenario=scn)
        return run_episode(cfg, stt, st, n_steps, scheduler, **kw)

    return jax.vmap(one)(scenarios, keys)


def run_fleet(
    cfg: SimConfig,
    statics: Statics,
    state: SimState,
    n_steps: int,
    scheduler: str = "fcfs",
    *,
    scenarios: Scenario | Sequence[Scenario] | None = None,
    **kw,
) -> Tuple[SimState, StepOut]:
    """Simulate R replicas of the twin for ``n_steps`` in one jitted call.

    ``scenarios``: batched Scenario (leading replica axis), a list of
    Scenarios (stacked here), or None (R=1, the statics' own scenario).
    All other statics (node constants, telemetry bank) and the initial
    state are shared and broadcast; each replica gets its own PRNG stream.

    Returns (final_states, outs) with a leading replica axis on every leaf.
    """
    if scenarios is None:
        scenarios = stack_scenarios([statics.scenario])
    else:
        scenarios = _ensure_batched(scenarios)
    R = n_replicas(scenarios)
    keys = jax.random.split(state.key, R)
    kw_items = tuple(sorted(kw.items()))
    return _fleet(cfg, statics, scenarios, state, keys, n_steps, scheduler,
                  kw_items)


def fleet_summary(final_states: SimState) -> List[Dict[str, float]]:
    """Per-replica ``summary`` dicts from batched final states."""
    host = jax.device_get(final_states)        # one transfer, not R x fields
    R = int(np.shape(host.t)[0])
    return [summary(jax.tree.map(lambda a: a[i], host)) for i in range(R)]
