"""Vmapped fleet runner: N datacenter replicas, heterogeneous grid
scenarios, one compiled call.

``run_fleet`` broadcasts one initial ``SimState``/``Statics`` across R
replicas, installs a per-replica ``Scenario`` (batched pytree from
``scenarios.stack_scenarios`` / ``sample_scenarios``), splits the PRNG key
per replica, and runs ``vmap(lax.scan(step))`` under a single ``jit`` —
the scenario-sweep engine for the paper's sustainability-policy studies.

Memory notes: the replica-batched state and key buffers are DONATED to the
compiled call (XLA reuses them for the final states), and the telemetry
knobs (``telemetry_every`` / ``summary_only``, forwarded to
``run_episode``) replace the O(R * n_steps * 16) stacked ``StepOut`` with
windowed or O(R * 16) episode-wide reductions — fleet-sweep memory then no
longer scales with ``n_steps``.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sim import SimConfig
from repro.core.sim import StepOut, TelemetrySummary, run_episode, summary
from repro.core.state import SimState, Statics
from repro.scenarios.scenario import Scenario, n_replicas, stack_scenarios


def _ensure_batched(scenarios) -> Scenario:
    # NB: Scenario is itself a (Named)tuple — test for it first
    if isinstance(scenarios, Scenario):
        return scenarios
    return stack_scenarios(list(scenarios))


# Module-level so repeated run_fleet calls with the same static config reuse
# the compiled executable (cfg is a frozen dataclass => hashable; statics /
# scenarios / state / keys are traced). ``state``/``keys`` arrive replica-
# batched and are donated: XLA reuses their buffers for the final states.
@partial(jax.jit, static_argnames=("cfg", "n_steps", "scheduler", "kw_items"),
         donate_argnames=("state", "keys"))
def _fleet(cfg, statics, scenarios, state, keys, n_steps, scheduler, kw_items):
    kw = dict(kw_items)

    def one(scn: Scenario, key: jax.Array, st: SimState):
        st = st._replace(key=key)
        stt = statics._replace(scenario=scn)
        return run_episode(cfg, stt, st, n_steps, scheduler, **kw)

    return jax.vmap(one)(scenarios, keys, state)


def run_fleet(
    cfg: SimConfig,
    statics: Statics,
    state: SimState,
    n_steps: int,
    scheduler: str = "fcfs",
    *,
    scenarios: Scenario | Sequence[Scenario] | None = None,
    **kw,
) -> Tuple[SimState, StepOut | TelemetrySummary]:
    """Simulate R replicas of the twin for ``n_steps`` in one jitted call.

    ``scenarios``: batched Scenario (leading replica axis), a list of
    Scenarios (stacked here), or None (R=1, the statics' own scenario).
    All other statics (node constants, telemetry bank) are shared and
    broadcast; each replica gets its own PRNG stream.

    ``state`` may be a single SimState (broadcast to R replicas here) or
    an already replica-batched one — e.g. the final states of a previous
    ``run_fleet`` call for chained sweeps. A batched state's buffers are
    donated to the compiled call and must not be reused afterwards.

    ``**kw`` forwards to ``run_episode``/``make_step`` — in particular
    ``summary_only=True`` returns per-replica ``TelemetrySummary`` with
    peak memory independent of ``n_steps``, and ``telemetry_every=k``
    stacks one windowed summary per k steps.

    Returns (final_states, outs) with a leading replica axis on every leaf.
    """
    if scenarios is None:
        scenarios = stack_scenarios([statics.scenario])
    else:
        scenarios = _ensure_batched(scenarios)
    R = n_replicas(scenarios)
    if jnp.ndim(state.t) == 0:
        keys = jax.random.split(state.key, R)
        state = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (R,) + jnp.shape(a)), state)
    else:
        if int(jnp.shape(state.t)[0]) != R:
            raise ValueError(
                f"batched state has {jnp.shape(state.t)[0]} replicas, "
                f"scenarios have {R}")
        # advance each replica's stream into a FRESH buffer: state and keys
        # are both donated, so aliasing keys to the state.key leaf would
        # donate one buffer twice
        keys = jax.vmap(lambda k: jax.random.fold_in(k, 1))(state.key)
    kw_items = tuple(sorted(kw.items()))
    return _fleet(cfg, statics, scenarios, state, keys, n_steps, scheduler,
                  kw_items)


def fleet_summary(final_states: SimState) -> List[Dict[str, float]]:
    """Per-replica ``summary`` dicts from batched final states."""
    host = jax.device_get(final_states)        # one transfer, not R x fields
    R = int(np.shape(host.t)[0])
    return [summary(jax.tree.map(lambda a: a[i], host)) for i in range(R)]
