"""Fluid online-inference serving plane (docs/serving.md).

The "heavy traffic from millions of users" workload: requests are
continuous MASS, not job-table entities — a deterministic fluid
approximation of an M/G/c queue driven by ``Scenario.traffic`` (request
rate [req/s]) scaled by ``Scenario.bursts`` flash-crowd windows. A pool
of ``cfg.serving_nodes`` inference nodes — disjoint from the batch
fleet, power injected into the shared plant chain — serves the mass
with a prefill/decode-blended utilization profile derived from the
roofline model (``perfmodel.workload_gen.serving_profile``).

Two code paths, split exactly like the fault engine (``core.faults``):

- the CONTINUOUS flow — arrivals, admission into service, completions,
  the fluid latency estimator and SLO accounting (``serving_flow``) and
  the pool's power draw (``serving_power``) — runs in the shared
  accounting tail every tick (``core.sim._make_tail``), so macro fast
  ticks reproduce it bit-identically;
- the DISCRETE overload ladder (``apply_serving``) — autoscale
  wake/sleep, retry re-injection, queue timeouts, admission control,
  hard load shedding — runs on full event ticks ONLY, and every phase
  is a bitwise fixpoint when untriggered.

Overload ladder (first resort first):

1. admission control: queue mass above ``srv_admit_thresh *
   serving_queue_cap`` (a schedulable threshold) bounces to a
   backoff-retry bucket instead of waiting;
2. per-request timeout: queue mass that cannot reach service within
   ``serving_timeout_s`` at the pool's full rate times out into the
   same retry path;
3. capped exponential-backoff retry: mass bounced from attempt tier r
   waits ``retry_backoff(cfg, r+1)`` (the PR 7 requeue rule applied to
   request tiers) and re-enters the queue at the absolute time stored
   in ``srv_retry_t`` — an exact macro breakpoint; mass bounced out of
   the top tier has exhausted its retry budget and is DROPPED
   (terminal);
4. hard shedding: queue mass above ``serving_queue_cap`` is SHED
   terminally — the bound that keeps the admission queue finite;
5. autoscale: ``srv_target`` (an RL action) wakes/sleeps pool nodes.
   Wakes take ``serving_wake_s`` (absolute completion time
   ``srv_wake_t`` — another exact breakpoint); scale-down is instant
   but DRAINS (already-admitted mass completes; only new admissions
   need awake capacity); asleep nodes burn ``serving_sleep_w`` — the
   SPARS power-management tradeoff.

Macro-exactness contract (the PR 6/7 bar):

- TIME-type events (wake completions, retry re-injections, burst-window
  edges) are absolute times folded into the quiet-horizon min via
  ``next_serving_event`` — fast ticks never run the discrete sweep, so
  a segment must end strictly before any of them fire;
- THRESHOLD-type events (the queue crossing the admission/timeout/shed
  bounds as arrivals accumulate) are detected authoritatively on each
  committed fast tick (``serving_trigger``; the thermal ``was_hot``
  pattern). Stopping AFTER the crossing tick is exact because the sweep
  reads predecessor-committed state — on the crossing tick itself the
  per-tick path's sweep was still a fixpoint;
- ``serving_crossing_horizon`` additionally bounds segment length by
  the worst-case arrival rate (traffic-signal envelope x largest burst
  multiplier), belt to the per-tick detection's suspenders. Sustained
  overload degrades to per-tick stepping by construction (every tick
  triggers) — the correct regime: overload IS the event.

Zero PRNG draws anywhere — the serving plane is deterministic fluid
flow, so the key stream is untouched and macro bit-identity holds
trivially on the PRNG side.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.sim import SimConfig
from repro.core.state import SimState, Statics
from repro.scenarios.events import burst_mult_at, next_burst_event
from repro.scenarios.signals import eval_signal, signal_bounds

_INF = jnp.float32(jnp.inf)


def retry_backoff(cfg: SimConfig, attempt) -> jax.Array:
    """Backoff [s] before a request's ``attempt``-th try (attempt >= 1):
    ``base * mult**(attempt-1)``, capped at ``serving_backoff_cap_s`` —
    strictly increasing until the cap (tests/test_serving.py pins both
    properties)."""
    a = jnp.maximum(jnp.asarray(attempt, jnp.float32) - 1.0, 0.0)
    b = jnp.float32(cfg.serving_backoff_s) * jnp.power(
        jnp.float32(cfg.serving_backoff_mult), a)
    return jnp.minimum(b, jnp.float32(cfg.serving_backoff_cap_s))


def _allowed_queue(
    cfg: SimConfig, active: jax.Array, admit_thresh: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """(allowed, q_cap): the admission-control bound — the schedulable
    threshold, tightened by the timeout-reach capacity (queue mass the
    pool can start within ``serving_timeout_s`` at full clock) — and the
    hard-shed bound. ``serving_trigger`` mirrors these expressions
    exactly so threshold crossings can never be missed."""
    q_cap = jnp.float32(cfg.serving_queue_cap)
    allowed = admit_thresh * q_cap
    if cfg.serving_timeout_s > 0:
        svc = max(cfg.serving_service_s, 1e-9)
        serve_full = active * jnp.float32(cfg.serving_concurrency / svc)
        reach = serve_full * jnp.float32(
            max(cfg.serving_timeout_s - svc, 0.0))
        allowed = jnp.minimum(allowed, reach)
    return allowed, q_cap


def apply_serving(
    cfg: SimConfig, state: SimState, statics: Statics
) -> Tuple[SimState, jax.Array, jax.Array, jax.Array]:
    """One discrete serving sweep (full event ticks only): autoscale
    wake completion + target reconciliation, retry re-injection, then
    the shed/timeout/admission cascade. Returns
    ``(state, shed_now, dropped_now, retried_now)`` in request mass.

    Invariants the macro engine relies on:

    - on a tick where no wake/retry clock is due and the queue is under
      every threshold, the whole update is a bitwise fixpoint (adds of
      0.0, multiplies by 1.0, untaken wheres);
    - every clock left behind is strictly future or +inf, so the
      ``> t`` guard in ``next_serving_event`` never hides a pending one;
    - no PRNG use.
    """
    t = state.t
    f32 = jnp.float32

    # --- (5) autoscale: wake completion, then target reconciliation.
    # Scale-down is instant (drain semantics) and cancels any in-flight
    # wake; a new wake batch starts only when none is in flight — the
    # full tick after a completion (a breakpoint) picks up any deficit
    # left, so one scalar wake clock suffices.
    woke = t >= state.srv_wake_t
    active = jnp.where(woke, state.srv_active + state.srv_wake_n,
                       state.srv_active)
    wake_n = jnp.where(woke, 0.0, state.srv_wake_n)
    wake_t = jnp.where(woke, _INF, state.srv_wake_t)
    target = jnp.clip(state.srv_target, 0.0, f32(cfg.serving_nodes))
    down = target < active
    wake_n = jnp.where(down, 0.0, wake_n)
    wake_t = jnp.where(down, _INF, wake_t)
    active = jnp.where(down, target, active)
    deficit = jnp.maximum(target - active - wake_n, 0.0)
    start = (deficit > 0.0) & (wake_n <= 0.0)
    wake_n = jnp.where(start, deficit, wake_n)
    wake_t = jnp.where(start, t + f32(cfg.serving_wake_s), wake_t)

    # --- (3) retry re-injection: due buckets pour back into their
    # attempt tier at the absolute time the backoff rule scheduled.
    due = t >= state.srv_retry_t
    queue = state.srv_queue + jnp.where(due, state.srv_retry_q, 0.0)
    retry_q = jnp.where(due, 0.0, state.srv_retry_q)
    retry_t = jnp.where(due, _INF, state.srv_retry_t)

    # --- (4) hard shed first (the queue bound is absolute), then
    # (1)+(2) the admission/timeout bounce. Mass leaves every tier
    # proportionally; tier r bounces into retry bucket r+1 (the attempt
    # counter) and the top tier — out of retry budget — drops.
    q_tot = jnp.sum(queue)
    allowed, q_cap = _allowed_queue(cfg, active, state.srv_admit_thresh)
    eps = f32(1e-9)
    shed_now = jnp.maximum(q_tot - q_cap, 0.0)
    queue = queue * (1.0 - shed_now / jnp.maximum(q_tot, eps))
    q_kept = q_tot - shed_now
    bounce = jnp.maximum(q_kept - allowed, 0.0)
    bfrac = bounce / jnp.maximum(q_kept, eps)
    moved = queue * bfrac
    queue = queue * (1.0 - bfrac)
    inc = jnp.concatenate([jnp.zeros((1,), f32), moved[:-1]])
    dropped_now = moved[-1]
    retried_now = jnp.sum(moved[:-1])
    backoff = retry_backoff(cfg, jnp.arange(inc.shape[0]))
    got = inc > 0.0
    retry_t = jnp.where(got, jnp.minimum(retry_t, t + backoff), retry_t)
    retry_q = retry_q + inc

    state = state._replace(
        srv_queue=queue, srv_retry_q=retry_q, srv_retry_t=retry_t,
        srv_active=active, srv_wake_n=wake_n, srv_wake_t=wake_t,
        srv_target=target,
        srv_shed=state.srv_shed + shed_now,
        srv_dropped=state.srv_dropped + dropped_now,
        srv_retried=state.srv_retried + retried_now,
    )
    return state, shed_now, dropped_now, retried_now


def serving_power(
    cfg: SimConfig, state: SimState, cop: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """(it_w, input_w, cooling_w, idle_w) of the serving pool this tick,
    from pool state + current in-flight occupancy. Dynamic power blends
    the prefill/decode utilization profile and scales with occupancy;
    awake (and waking) nodes burn idle power at any clock — it joins
    the DVFS cap's unthrottleable floor — and asleep nodes burn the
    sleep wattage, the SPARS tradeoff."""
    f32 = jnp.float32
    cap_conc = state.srv_active * f32(cfg.serving_concurrency)
    occ = jnp.clip(state.srv_inflight / jnp.maximum(cap_conc, 1e-9),
                   0.0, 1.0)
    phase_util = (cfg.serving_prefill_frac * cfg.serving_prefill_util
                  + (1.0 - cfg.serving_prefill_frac)
                  * cfg.serving_decode_util)
    asleep = jnp.maximum(
        f32(cfg.serving_nodes) - state.srv_active - state.srv_wake_n, 0.0)
    idle_w = ((state.srv_active + state.srv_wake_n)
              * f32(cfg.serving_node_idle_w)
              + asleep * f32(cfg.serving_sleep_w))
    dyn_w = (state.srv_active * f32(cfg.serving_node_dyn_w)
             * f32(phase_util) * occ)
    it_w = idle_w + dyn_w
    input_w = it_w / f32(cfg.rect_eff_peak * cfg.conv_eff)
    cooling_w = input_w / cop
    return it_w, input_w, cooling_w, idle_w


def serving_flow(
    cfg: SimConfig, state: SimState, statics: Statics, throttle: jax.Array
):
    """One tick of the continuous request-mass flow — runs in the shared
    accounting tail, so macro fast ticks reproduce it bit-identically:
    arrivals from the traffic signal (x burst multiplier) into attempt
    tier 0, completions out of the in-flight mass at the (DVFS/thermal)
    throttled service rate, admission of queued mass into freed
    concurrency, and the fluid latency estimator feeding the SLO
    accounting. Returns ``(state, arrive, comp, viol, w_est, q_after,
    hist_step)``."""
    f32 = jnp.float32
    scn = statics.scenario
    lam = (jnp.maximum(eval_signal(scn.traffic, state.t), 0.0)
           * burst_mult_at(scn.bursts, state.t))
    arrive = lam * f32(cfg.dt)
    svc = f32(max(cfg.serving_service_s, 1e-9))
    cap_conc = state.srv_active * f32(cfg.serving_concurrency)
    comp = state.srv_inflight * jnp.clip(throttle * f32(cfg.dt) / svc,
                                         0.0, 1.0)
    inflight = state.srv_inflight - comp
    queue = state.srv_queue.at[0].add(arrive)
    q_tot = jnp.sum(queue)
    room = jnp.maximum(cap_conc - inflight, 0.0)
    admit = jnp.minimum(q_tot, room)
    queue = queue * (1.0 - admit / jnp.maximum(q_tot, f32(1e-9)))
    inflight = inflight + admit
    q_after = q_tot - admit
    # fluid sojourn estimate for mass completing this tick: residual
    # queue wait at the throttled full-pool service rate plus the
    # (clock-stretched) service time itself
    serve_rate = cap_conc * throttle / svc
    w_est = (q_after / jnp.maximum(serve_rate, 1e-9)
             + svc / jnp.maximum(throttle, 1e-9))
    viol = comp * (w_est > f32(cfg.serving_slo_s)).astype(f32)
    # log-2 latency histogram around the SLO: bucket i spans
    # serving_slo_s * [2^(i-4), 2^(i-3)); quantiles are reported at the
    # bucket upper edge in SLO units (core.sim.summary_columns)
    ratio = w_est / f32(max(cfg.serving_slo_s, 1e-9))
    idx = jnp.clip(
        jnp.floor(jnp.log2(jnp.maximum(ratio, 1e-9))).astype(jnp.int32) + 4,
        0, 7)
    hist_step = jnp.zeros((8,), f32).at[idx].add(comp)
    state = state._replace(
        srv_queue=queue,
        srv_inflight=inflight,
        srv_arrived=state.srv_arrived + arrive,
        srv_completed=state.srv_completed + comp,
        srv_slo_viol=state.srv_slo_viol + viol,
        srv_lat_sum=state.srv_lat_sum + comp * w_est,
        srv_lat_hist=state.srv_lat_hist + hist_step,
    )
    return state, arrive, comp, viol, w_est, q_after, hist_step


def serving_trigger(cfg: SimConfig, state: SimState) -> jax.Array:
    """Would the next full tick's ``apply_serving`` cascade move mass?
    THRESHOLD-type causes only (clock events are horizon breakpoints):
    queue mass strictly above the admission/timeout/shed bound.
    Evaluated on committed state after each macro fast tick (the
    thermal ``was_hot`` pattern): True ends the segment so the sweep
    runs on the following full tick exactly as the per-tick path would.
    False positives are safe (the sweep is then a fixpoint); the
    expression mirrors ``_allowed_queue`` so false negatives cannot
    happen."""
    allowed, q_cap = _allowed_queue(cfg, state.srv_active,
                                    state.srv_admit_thresh)
    return jnp.sum(state.srv_queue) > jnp.minimum(allowed, q_cap)


def next_serving_event(
    cfg: SimConfig, state: SimState, statics: Statics, t: jax.Array
) -> jax.Array:
    """Earliest serving TIME-type breakpoint strictly after ``t``
    (``inf`` when none): the autoscale wake completion, any pending
    retry re-injection, or a traffic-burst window edge — same contract
    as ``next_fault_event``. The discrete sweep runs on full ticks
    only, so the macro engine must never fast-forward past one."""
    nxt = jnp.where(state.srv_wake_t > t, state.srv_wake_t, _INF)
    nxt = jnp.minimum(nxt, jnp.min(
        jnp.where(state.srv_retry_t > t, state.srv_retry_t, _INF)))
    return jnp.minimum(nxt, next_burst_event(statics.scenario.bursts, t))


def serving_crossing_horizon(
    cfg: SimConfig, state: SimState, statics: Statics, max_ticks
) -> jax.Array:
    """Conservative tick count within which arrivals cannot push the
    queue across the nearest overload threshold: headroom / (worst-case
    rate x dt) minus one tick of float margin. Inside a quiet segment
    the queue only grows through arrivals (admission drains it; retry
    re-injections are clock breakpoints that already end the segment),
    and the arrival rate is bounded by the traffic signal's envelope
    times the burst multiplier in force at ``t`` — sound because burst
    edges are hard breakpoints (``next_serving_event``), so a segment
    never crosses a multiplier change. Belt to ``serving_trigger``'s
    suspenders, like ``thermal_crossing_horizon``.
    """
    scn = statics.scenario
    _, hi = signal_bounds(scn.traffic)
    lam_hi = jnp.maximum(hi, 0.0) * burst_mult_at(scn.bursts, state.t)
    allowed, q_cap = _allowed_queue(cfg, state.srv_active,
                                    state.srv_admit_thresh)
    headroom = jnp.maximum(
        jnp.minimum(allowed, q_cap) - jnp.sum(state.srv_queue), 0.0)
    per_tick = lam_hi * jnp.float32(cfg.dt)
    kf = jnp.float32(max_ticks)
    k = jnp.where(per_tick > 0.0,
                  jnp.floor(headroom / jnp.maximum(per_tick, 1e-9)) - 1.0,
                  kf)
    return jnp.clip(k, 0.0, kf).astype(jnp.int32)
