"""Rack thermal twin: the cooling loop as first-class simulation state.

The paper positions the twin as a power *and cooling* model; this module
supplies the cooling half (Brewer et al. 2410.05133's liquid-cooled twin
is the reference architecture). Per rack we carry one outlet temperature
with a first-order RC lag — rooms do not cool instantly:

    T[k+1] = T[k] + alpha * (T_ss - T[k]),  alpha = 1 - exp(-dt / tau)
    T_ss   = supply + R_th * heat_w
    supply = max(wetbulb + approach, supply_min)

``heat_w`` is the rack's total *input* power (IT + rectification and
conversion losses all end up as room heat). Feedback into the schedule is
two-fold, both computed from the PREVIOUS tick's outlet temps (a one-tick
control lag keeps the update explicit):

* continuous DVFS derating — ``rack_throttle`` ramps the clock from 1 at
  ``throttle_start_c`` down to ``thermal_throttle_floor`` at
  ``throttle_full_c`` (monotone non-increasing in temperature, a property
  test pins this), scaling each node's dynamic power and each resident
  job's progress;
* a binary dispatch trip — racks at/above ``thermal_trip_c`` accept no
  NEW placements (``node_trip_ok``). Only the trip is dispatch-relevant,
  which is what keeps the macro-stepping proof obligations finite: a
  quiet segment may end at a *trip crossing* and nowhere else
  (``thermal_crossing_horizon`` bounds those conservatively).

The cooling plant COP depends on wetbulb AND IT load (``cooling_cop``),
replacing the static wetbulb-only factor — PUE becomes a dynamic output.

Everything here is pure jnp on (cfg, arrays); the per-rack scatter + RC
update has a fused Pallas kernel (``kernels.rack_thermal``) with the
eager oracle in ``kernels.ref.rack_thermal_ref``.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Tuple

import jax
import jax.numpy as jnp

from repro.configs.sim import SimConfig
from repro.kernels.ref import rack_thermal_ref
from repro.scenarios.signals import signal_bounds

if TYPE_CHECKING:  # type hints only — state.py imports us (supply_temp)
    from repro.core.state import SimState, Statics


def thermal_alpha(cfg: SimConfig) -> float:
    """Per-tick RC relaxation factor, as a Python float so every code path
    (eager tail, macro fast tick, Pallas kernel static arg, NumPy oracle)
    bakes in the IDENTICAL constant."""
    return float(-math.expm1(-cfg.dt / max(cfg.rack_tau_s, 1e-6)))


def supply_temp(cfg: SimConfig, wetbulb_c: jax.Array) -> jax.Array:
    """Cooling supply-air temperature: wetbulb + tower/CDU approach,
    floored at the plant's minimum supply setpoint."""
    return jnp.maximum(wetbulb_c + cfg.cooling_approach_c,
                       cfg.cooling_supply_min_c)


def cooling_cop(cfg: SimConfig, wetbulb_c: jax.Array,
                load_frac: jax.Array) -> jax.Array:
    """COP(wetbulb, IT load): linear wetbulb derate (as before) plus a
    part-load penalty — plants run closest to design efficiency near rated
    load. Floored at ``cop_min``."""
    return jnp.maximum(
        cfg.cop_base
        + cfg.cop_wetbulb_coef * (wetbulb_c - cfg.wetbulb_ref_c)
        + cfg.cop_load_coef * (load_frac - cfg.cop_load_ref),
        cfg.cop_min,
    )


def rack_throttle(cfg: SimConfig, rack_outlet_c: jax.Array) -> jax.Array:
    """(R,) DVFS clock fraction per rack: 1 below ``throttle_start_c``,
    linear ramp to ``thermal_throttle_floor`` at ``throttle_full_c``.
    Monotone non-increasing in outlet temperature."""
    span = max(cfg.throttle_full_c - cfg.throttle_start_c, 1e-6)
    ramp = (rack_outlet_c - cfg.throttle_start_c) / span
    return jnp.clip(1.0 - (1.0 - cfg.thermal_throttle_floor) * ramp,
                    cfg.thermal_throttle_floor, 1.0)


def job_thermal_rate(state: "SimState", statics: "Statics",
                     node_th: jax.Array) -> jax.Array:
    """(J,) progress factor per job: the MIN clock over the job's placed
    nodes (synchronous apps run at the slowest rank). Unplaced slots
    contribute 1, so queued/done jobs are unaffected."""
    place = state.placement                                   # (J, K)
    valid = place >= 0
    slot_th = jnp.where(valid, node_th[jnp.where(valid, place, 0)], 1.0)
    return jnp.min(slot_th, axis=1)


def node_trip_ok(cfg: SimConfig, state: "SimState",
                 statics: "Statics") -> jax.Array:
    """(N,) bool: nodes whose rack is below the dispatch trip threshold —
    the thermal half of placement eligibility. The throttle stays
    continuous; only THIS boolean gates dispatch, so fast-forwarded
    segments need to stop only at trip crossings."""
    return (state.rack_outlet_c < cfg.thermal_trip_c)[statics.node_rack]


def rack_thermal_update(
    cfg: SimConfig,
    statics: "Statics",
    rack_outlet_c: jax.Array,     # (R,)
    node_heat_w: jax.Array,       # (N,) post-throttle input power
    supply_c: jax.Array,          # scalar
    *,
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """One RC step of every rack: scatter node heat onto racks and relax
    toward the steady state. Returns (new_outlet_c (R,), rack_heat_w (R,)).
    ``use_kernel`` swaps in the fused Pallas pass (kernels.rack_thermal);
    both paths share the one-hot-contraction math so they agree bitwise on
    CPU (tests/test_thermal.py pins this)."""
    alpha = thermal_alpha(cfg)
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.rack_thermal(
            node_heat_w, statics.node_rack, rack_outlet_c, supply_c,
            statics.rack_r_th, alpha=alpha)
    return rack_thermal_ref(node_heat_w, statics.node_rack, rack_outlet_c,
                            supply_c, statics.rack_r_th, alpha=alpha)


def thermal_crossing_horizon(cfg: SimConfig, statics: "Statics",
                             state: "SimState", max_ticks: int) -> jax.Array:
    """Conservative tick count guaranteed free of dispatch-trip crossings.

    The RC update is a contraction: every rack temperature stays inside
    the box [min(T, ss_lo), max(T, ss_hi)] spanned by its current value
    and the extreme steady states (wetbulb signal bounds x zero-to-max
    heat), and moves at most ``alpha * box_width`` per tick. A rack whose
    trip threshold lies outside its box can never cross; otherwise it
    needs at least ``distance / (alpha * width)`` ticks. The small margin
    subtracted before the floor absorbs float drift of the per-tick
    chain, mirroring the arrival-horizon margin in ``sim._horizon_parts``.
    """
    kf = jnp.float32(max_ticks)
    wb_lo, wb_hi = signal_bounds(statics.scenario.wetbulb)
    sup_lo = supply_temp(cfg, wb_lo)
    sup_hi = supply_temp(cfg, wb_hi)
    # max rack input power: nameplate IT through the worst-case chain
    # (load clip 1.2, rectifier eta floor 0.5) — matches power_from_fracs
    heat_hi = statics.rack_cap_w * 1.2 / (0.5 * cfg.conv_eff)
    ss_lo = sup_lo                                   # zero heat
    ss_hi = sup_hi + heat_hi * statics.rack_r_th     # (R,)
    T = state.rack_outlet_c
    lo = jnp.minimum(T, ss_lo)
    hi = jnp.maximum(T, ss_hi)
    width = jnp.maximum(hi - lo, 1e-6)
    alpha = thermal_alpha(cfg)
    trip = jnp.float32(cfg.thermal_trip_c)
    reachable = (trip >= lo) & (trip <= hi)
    dist = jnp.abs(T - trip)
    ticks = jnp.floor(dist / (alpha * width) - 1e-3)
    ticks = jnp.where(reachable, ticks, kf)
    return jnp.clip(jnp.min(ticks), 0.0, kf).astype(jnp.int32)
