"""Scheduling policies as pure selection functions.

Each policy looks at the job table and returns the index of the queued job
to attempt next (or -1). Placement (first-fit node selection) is shared.
The RL policy is external: its action picks among the top
``sched_max_candidates`` FCFS-ordered queue candidates (or no-op).

Policies mirror RAPS' production-Slurm-matching set [Maiterth et al. 2025]:
replay | fcfs | sjf | priority | easy (FCFS + EASY backfill).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.sim import SimConfig
from repro.core.state import QUEUED, RUNNING, NRES, SimState, Statics

BIG = 1e18


def queued_mask(state: SimState) -> jax.Array:
    return (state.jstate == QUEUED) & (state.submit_t <= state.t)


def feasible_nodes(state: SimState, job: jax.Array) -> jax.Array:
    """(N,) bool: nodes that can host one rank of `job` right now."""
    req = state.req[:, job]                       # (NRES,)
    ok = jnp.all(state.free >= req[:, None], axis=0)
    return ok & (state.node_up > 0.5)


def first_fit(state: SimState, job: jax.Array, K: int) -> Tuple[jax.Array, jax.Array]:
    """Choose `n_nodes[job]` lowest-index feasible nodes, sort-free.

    O(N + K log N) cumsum ranking instead of the O(N log N) argsort: the
    rank of a feasible node among feasible nodes is ``cumsum(ok) - 1``
    (feasibility order == index order), so the node filling placement slot
    ``s`` is the first index where the monotone cumsum reaches ``s + 1`` —
    a binary search, no sort and no scatter. Bit-equivalent to
    ``first_fit_argsort`` (property-tested).

    Returns (placement_row (K,), feasible bool).
    """
    ok = feasible_nodes(state, job)
    n_req = state.n_nodes[job]
    csum = jnp.cumsum(ok)
    slots = jnp.arange(K)
    idx = jnp.searchsorted(csum, slots + 1).astype(jnp.int32)
    row = jnp.where(slots < n_req, idx, -1)
    enough = csum[-1] >= n_req
    return jnp.where(enough, row, -1), enough


def first_fit_argsort(state: SimState, job: jax.Array, K: int) -> Tuple[jax.Array, jax.Array]:
    """Legacy argsort placement — kept as the equivalence oracle for
    ``first_fit`` (tests + ``benchmarks/bench_dispatch.py``)."""
    N = state.free.shape[1]
    ok = feasible_nodes(state, job)
    n_req = state.n_nodes[job]
    order = jnp.argsort(jnp.where(ok, 0, 1) * N + jnp.arange(N))  # feasible first
    slots = jnp.arange(K)
    row = jnp.where(slots < n_req, order[:K], -1)
    enough = jnp.sum(ok) >= n_req
    return jnp.where(enough, row, -1), enough


# --------------------------------------------------------------------------
# candidate orderings
def _masked_argmin(score: jax.Array, mask: jax.Array) -> jax.Array:
    s = jnp.where(mask, score, BIG)
    idx = jnp.argmin(s)
    return jnp.where(jnp.any(mask), idx, -1)


def select_fcfs(cfg: SimConfig, state: SimState) -> jax.Array:
    return _masked_argmin(state.submit_t, queued_mask(state))


def select_sjf(cfg: SimConfig, state: SimState) -> jax.Array:
    return _masked_argmin(state.dur_est, queued_mask(state))


def select_priority(cfg: SimConfig, state: SimState) -> jax.Array:
    return _masked_argmin(-state.priority, queued_mask(state))


def select_replay(cfg: SimConfig, state: SimState) -> jax.Array:
    """Replay: dispatch in recorded start order — priority carries the
    recorded start time; a job becomes eligible once t >= recorded start."""
    m = queued_mask(state) & (state.priority <= state.t)
    return _masked_argmin(state.priority, m)


def shadow_time(cfg: SimConfig, state: SimState, head: jax.Array) -> jax.Array:
    """EASY reservation: earliest time the head job could start, assuming
    running jobs release their nodes at their walltime estimates.

    Approximation (standard in queueing sims): sort running jobs' estimated
    end times; find when cumulative released *whole-node* count reaches the
    head job's requirement given currently-free feasible nodes.
    """
    running = state.jstate == RUNNING
    est_end = jnp.where(running, state.start_t + state.dur_est, BIG)
    # nodes each running job will release (count of valid placement slots)
    rel_nodes = jnp.sum(state.placement >= 0, axis=1).astype(jnp.float32)
    rel_nodes = jnp.where(running, rel_nodes, 0.0)
    order = jnp.argsort(est_end)
    cum = jnp.cumsum(rel_nodes[order])
    free_now = jnp.sum(feasible_nodes(state, head))
    need = jnp.maximum(state.n_nodes[head].astype(jnp.float32) - free_now, 0.0)
    reached = cum >= need
    first = jnp.argmax(reached)
    t_shadow = jnp.where(jnp.any(reached), est_end[order][first], BIG)
    return jnp.where(need > 0, t_shadow, state.t)


def select_easy(cfg: SimConfig, state: SimState) -> jax.Array:
    """FCFS head first; if head infeasible, backfill any queued job that (a)
    fits now and (b) finishes before the head's shadow time."""
    head = select_fcfs(cfg, state)

    def with_head(head):
        _, head_fits = first_fit(state, head, state.placement.shape[1])

        def backfill(_):
            t_sh = shadow_time(cfg, state, head)
            m = queued_mask(state)
            # candidate must fit before the reservation (and not be the head)
            fits_window = (state.t + state.dur_est) <= t_sh
            not_head = jnp.arange(m.shape[0]) != head
            cand = _masked_argmin(state.submit_t, m & fits_window & not_head)
            return cand

        return jax.lax.cond(head_fits, lambda _: head, backfill, None)

    return jax.lax.cond(head >= 0, with_head, lambda _: jnp.int32(-1),
                        jnp.int32(jnp.maximum(head, 0)))


SCHEDULERS = {
    "replay": select_replay,
    "fcfs": select_fcfs,
    "sjf": select_sjf,
    "priority": select_priority,
    "easy": select_easy,
}


def rl_candidates(cfg: SimConfig, state: SimState) -> jax.Array:
    """Top-k FCFS-ordered queued jobs the RL agent chooses among. (k,) int.

    ``lax.top_k`` (O(J log k)) instead of a full O(J log J) argsort; both
    break ties by lowest index, so the candidate order is unchanged.
    """
    k = cfg.sched_max_candidates
    m = queued_mask(state)
    score = jnp.where(m, state.submit_t, BIG)
    _, idx = jax.lax.top_k(-score, k)
    ok = jnp.take(m, idx)
    return jnp.where(ok, idx.astype(jnp.int32), -1)
