"""Job-selection policies — stage (a) of the two-stage policy engine.

Each policy looks at the job table and returns the index of the queued job
to attempt next (or -1). Node placement is the second, independent stage
(``repro.core.placement``): selection answers *which job*, placement
answers *which nodes*. The RL policy is external: its action picks among
the top ``sched_max_candidates`` FCFS-ordered queue candidates (or no-op).

Policies mirror RAPS' production-Slurm-matching set [Maiterth et al. 2025]:
replay | fcfs | sjf | priority | easy (FCFS + EASY backfill).

Policy-as-data: every selection carries an int32 id (``SELECT_IDS``) and
``select_job`` resolves a *traced* id via ``lax.switch`` — one compiled
``step`` then serves the whole selection grid (see ``core.placement`` for
the matching placement ids and the combined ``Policy`` encoding).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.sim import SimConfig
from repro.core.state import QUEUED, RUNNING, NRES, SimState, Statics

BIG = 1e18


def queued_mask(state: SimState) -> jax.Array:
    return (state.jstate == QUEUED) & (state.submit_t <= state.t)


def feasible_nodes(state: SimState, job: jax.Array) -> jax.Array:
    """(N,) bool: nodes that can host one rank of `job` right now."""
    req = state.req[:, job]                       # (NRES,)
    ok = jnp.all(state.free >= req[:, None], axis=0)
    return ok & (state.node_up > 0.5)


def capacity_feasible_nodes(state: SimState, statics: Statics,
                            job: jax.Array) -> jax.Array:
    """(N,) bool: up nodes whose *total* capacity can host one rank of
    `job` — i.e. nodes that could host it once their current tenants leave
    (a CPU node can never host a GPU job, busy or not)."""
    req = state.req[:, job]                       # (NRES,)
    ok = jnp.all(statics.capacity >= req[:, None], axis=0)
    return ok & (state.node_up > 0.5)


def partition_ok(part: jax.Array, node_type: jax.Array) -> jax.Array:
    """THE TX-GAIA partition rule (single source — placement and selection
    both derive from it): tag -1 = any node; otherwise the node type must
    match. Broadcasts (scalar tag vs (N,), or (J,1) vs (1,N))."""
    return (part < 0) | (node_type == part)


def partition_mask_all(state: SimState, statics: Statics) -> jax.Array:
    """(J, N) bool: per-job node eligibility under partition semantics.
    The batched form of ``placement.partition_mask``; ``make_step`` feeds
    it to selection as ``node_mask`` when the active placement enforces
    partitions, so EASY never picks a job placement will reject."""
    return partition_ok(state.part[:, None], statics.node_type[None, :])


def fits_now_mask(state: SimState,
                  node_mask: jax.Array | None = None) -> jax.Array:
    """(J,) bool: jobs whose whole-node request is satisfiable against the
    CURRENT free pool (enough feasible up nodes, optionally restricted to
    ``node_mask`` (J, N) — the placement backend's eligibility). Used to
    keep EASY's backfill from wasting a dispatch attempt on an infeasible
    candidate."""
    ok = jnp.all(state.free[:, None, :] >= state.req[:, :, None], axis=0)
    ok = ok & (state.node_up > 0.5)[None, :]                 # (J, N)
    if node_mask is not None:
        ok = ok & node_mask
    return jnp.sum(ok, axis=1) >= state.n_nodes


def first_fit(state: SimState, job: jax.Array, K: int) -> Tuple[jax.Array, jax.Array]:
    """Choose `n_nodes[job]` lowest-index feasible nodes, sort-free.

    O(N + K log N) cumsum ranking instead of the O(N log N) argsort: the
    rank of a feasible node among feasible nodes is ``cumsum(ok) - 1``
    (feasibility order == index order), so the node filling placement slot
    ``s`` is the first index where the monotone cumsum reaches ``s + 1`` —
    a binary search, no sort and no scatter. Bit-equivalent to
    ``first_fit_argsort`` (property-tested).

    Returns (placement_row (K,), feasible bool).
    """
    ok = feasible_nodes(state, job)
    n_req = state.n_nodes[job]
    csum = jnp.cumsum(ok)
    slots = jnp.arange(K)
    idx = jnp.searchsorted(csum, slots + 1).astype(jnp.int32)
    row = jnp.where(slots < n_req, idx, -1)
    enough = csum[-1] >= n_req
    return jnp.where(enough, row, -1), enough


def first_fit_argsort(state: SimState, job: jax.Array, K: int) -> Tuple[jax.Array, jax.Array]:
    """Legacy argsort placement — kept as the equivalence oracle for
    ``first_fit`` (tests + ``benchmarks/bench_dispatch.py``)."""
    N = state.free.shape[1]
    ok = feasible_nodes(state, job)
    n_req = state.n_nodes[job]
    order = jnp.argsort(jnp.where(ok, 0, 1) * N + jnp.arange(N))  # feasible first
    slots = jnp.arange(K)
    row = jnp.where(slots < n_req, order[:K], -1)
    enough = jnp.sum(ok) >= n_req
    return jnp.where(enough, row, -1), enough


# --------------------------------------------------------------------------
# candidate orderings — uniform signature (cfg, state, statics[, node_mask])
# -> job id. ``node_mask`` (J, N) is the placement backend's node
# eligibility (None = every node): only EASY consults it, but the uniform
# signature keeps the policy-as-data switch branches interchangeable.
def _masked_argmin(score: jax.Array, mask: jax.Array) -> jax.Array:
    s = jnp.where(mask, score, BIG)
    idx = jnp.argmin(s)
    return jnp.where(jnp.any(mask), idx, -1)


def select_fcfs(cfg: SimConfig, state: SimState, statics: Statics,
                node_mask: jax.Array | None = None) -> jax.Array:
    return _masked_argmin(state.submit_t, queued_mask(state))


def select_sjf(cfg: SimConfig, state: SimState, statics: Statics,
               node_mask: jax.Array | None = None) -> jax.Array:
    return _masked_argmin(state.dur_est, queued_mask(state))


def select_priority(cfg: SimConfig, state: SimState, statics: Statics,
                    node_mask: jax.Array | None = None) -> jax.Array:
    return _masked_argmin(-state.priority, queued_mask(state))


def select_replay(cfg: SimConfig, state: SimState, statics: Statics,
                  node_mask: jax.Array | None = None) -> jax.Array:
    """Replay: dispatch in recorded start order — priority carries the
    recorded start time; a job becomes eligible once t >= recorded start."""
    m = queued_mask(state) & (state.priority <= state.t)
    return _masked_argmin(state.priority, m)


def shadow_time(cfg: SimConfig, state: SimState, statics: Statics,
                head: jax.Array,
                node_mask: jax.Array | None = None) -> jax.Array:
    """EASY reservation: earliest time the head job could start, assuming
    running jobs release their nodes at their walltime estimates.

    Approximation (standard in queueing sims): sort running jobs' estimated
    end times; find when cumulative released *whole-node* count reaches the
    head job's requirement given currently-free feasible nodes. Only
    releases of HEAD-FEASIBLE nodes count: a CPU-node release can never
    satisfy a GPU head job, so crediting it (as the pre-fix code did)
    made the backfill window optimistically wrong on heterogeneous
    clusters.
    """
    running = state.jstate == RUNNING
    est_end = jnp.where(running, state.start_t + state.dur_est, BIG)
    head_ok = capacity_feasible_nodes(state, statics, head)   # (N,)
    free_ok = feasible_nodes(state, head)
    if node_mask is not None:
        head_ok = head_ok & node_mask[head]
        free_ok = free_ok & node_mask[head]
    # nodes each running job will release THAT COULD HOST THE HEAD
    valid = state.placement >= 0                              # (J, K)
    safe = jnp.where(valid, state.placement, 0)
    rel_nodes = jnp.sum(
        valid & jnp.take(head_ok, safe), axis=1).astype(jnp.float32)
    rel_nodes = jnp.where(running, rel_nodes, 0.0)
    order = jnp.argsort(est_end)
    cum = jnp.cumsum(rel_nodes[order])
    free_now = jnp.sum(free_ok)
    need = jnp.maximum(state.n_nodes[head].astype(jnp.float32) - free_now, 0.0)
    reached = cum >= need
    first = jnp.argmax(reached)
    t_shadow = jnp.where(jnp.any(reached), est_end[order][first], BIG)
    return jnp.where(need > 0, t_shadow, state.t)


def select_easy(cfg: SimConfig, state: SimState, statics: Statics,
                node_mask: jax.Array | None = None) -> jax.Array:
    """FCFS head first; if head infeasible, backfill any queued job that (a)
    fits NOW, and (b) finishes before the head's shadow time. Every
    feasibility check honors ``node_mask`` (the placement backend's node
    eligibility, e.g. partition) so EASY never selects a job the placement
    stage would reject — which would waste the dispatch attempt."""
    head = select_fcfs(cfg, state, statics)

    def with_head(head):
        head_ok = feasible_nodes(state, head)
        if node_mask is not None:
            head_ok = head_ok & node_mask[head]
        head_fits = jnp.sum(head_ok) >= state.n_nodes[head]

        def backfill(_):
            t_sh = shadow_time(cfg, state, statics, head, node_mask)
            # candidate must be currently feasible (an infeasible pick
            # turns the whole dispatch attempt into a no-op), fit before
            # the reservation, and not be the head
            m = queued_mask(state) & fits_now_mask(state, node_mask)
            fits_window = (state.t + state.dur_est) <= t_sh
            not_head = jnp.arange(m.shape[0]) != head
            cand = _masked_argmin(state.submit_t, m & fits_window & not_head)
            return cand

        return jax.lax.cond(head_fits, lambda _: head, backfill, None)

    return jax.lax.cond(head >= 0, with_head, lambda _: jnp.int32(-1),
                        jnp.int32(jnp.maximum(head, 0)))


SCHEDULERS = {
    "replay": select_replay,
    "fcfs": select_fcfs,
    "sjf": select_sjf,
    "priority": select_priority,
    "easy": select_easy,
}

# policy-as-data ids: position in SCHEDULERS (insertion-ordered) — the
# branch order of the `select_job` lax.switch
SELECT_IDS = {name: i for i, name in enumerate(SCHEDULERS)}


def select_job(cfg: SimConfig, state: SimState, statics: Statics,
               select_id: jax.Array,
               node_mask: jax.Array | None = None) -> jax.Array:
    """Resolve a *traced* int32 selection id to a job pick via
    ``lax.switch`` — every selection policy lives in ONE compiled step, so
    sweeping the selection axis costs zero recompiles. ``node_mask`` is
    the active placement backend's (J, N) node eligibility (or None)."""
    branches = tuple(
        (lambda fn: (lambda s: fn(cfg, s, statics, node_mask)))(fn)
        for fn in SCHEDULERS.values()
    )
    return jax.lax.switch(select_id, branches, state)


def rl_candidates(cfg: SimConfig, state: SimState) -> jax.Array:
    """Top-k FCFS-ordered queued jobs the RL agent chooses among. (k,) int.

    ``lax.top_k`` (O(J log k)) instead of a full O(J log J) argsort; both
    break ties by lowest index, so the candidate order is unchanged.
    """
    k = cfg.sched_max_candidates
    m = queued_mask(state)
    score = jnp.where(m, state.submit_t, BIG)
    _, idx = jax.lax.top_k(-score, k)
    ok = jnp.take(m, idx)
    return jnp.where(ok, idx.astype(jnp.int32), -1)
