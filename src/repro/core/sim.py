"""The RAPS trace-replay / rescheduling simulator step and episode runner.

``make_step(cfg, statics, scheduler)`` closes over the static datacenter
description and returns a pure jit-able ``step(state, action) ->
(state, StepOut)``; an episode is ``lax.scan`` over steps, so the whole
digital twin vmaps across thousands of parallel datacenters for RL.

Scheduling is a two-stage engine: job *selection*
(``core.schedulers``: replay/fcfs/sjf/priority/easy, or the external RL
action) x node *placement* (``core.placement``: first_fit/best_fit/
spread/partition/green). ``scheduler`` is either a policy name (eager,
one Python branch baked into the trace) or a ``placement.Policy`` of
traced (select_id, place_id) int32s resolved by ``lax.switch`` inside the
compiled step — pass the Policy as a jit *argument* and one compilation
serves the entire selection x placement grid.

Step order (matches RAPS' fixed-dt loop):
  1. node failures / repairs (MTBF process)       [optional]
  2. job completions -> free resources, stats
  3. scheduling: up to `starts_per_step` dispatch attempts via the policy
  4. progress running jobs (network-congestion-aware rate)
  5. power chain + energy/carbon/stat accumulation
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sim import SimConfig
from repro.core import faults as flt
from repro.core import placement as plc
from repro.core import schedulers as sched
from repro.core import serving as srv
from repro.core import thermal as thm
from repro.core.faults import release_jobs as _release
from repro.core.network import congestion_slowdown
from repro.core.placement import Policy
from repro.core.power import (
    PowerOut,
    compute_power,
    job_utilization,
    power_from_fracs,
    use_dense_scatter,
)
from repro.scenarios.events import next_cap_event, power_cap_at
from repro.scenarios.signals import eval_signal
from repro.core.state import (
    DONE,
    EMPTY,
    NRES,
    QUEUED,
    RUNNING,
    SimState,
    Statics,
)


class StepOut(NamedTuple):
    facility_w: jax.Array
    it_w: jax.Array
    pue: jax.Array
    util: jax.Array            # fraction of up-node cores|gpus busy
    queue_len: jax.Array
    running: jax.Array
    completed_now: jax.Array
    energy_kwh_step: jax.Array
    carbon_kg_step: jax.Array
    net_load: jax.Array
    reward: jax.Array
    # grid-signal telemetry (scenario engine)
    carbon_gkwh: jax.Array     # instantaneous grid carbon intensity
    price_usd_kwh: jax.Array   # instantaneous electricity price
    power_cap_w: jax.Array     # effective facility cap (0 = uncapped)
    cost_usd_step: jax.Array   # electricity cost of this step
    throttle: jax.Array        # DVFS clock fraction applied [floor, 1]
    # thermal twin telemetry (core.thermal); with thermal_enabled off these
    # report the static plant (constant rack temps, wetbulb-only COP, 0)
    rack_max_c: jax.Array      # hottest rack outlet this tick
    cop: jax.Array             # cooling plant COP in effect
    thermal_throttle_s_step: jax.Array  # dt if any rack was derated else 0
    # resilience twin telemetry (core.faults); zeros with resilience off
    killed_now: jax.Array      # jobs killed by node loss this tick
    lost_node_s_step: jax.Array  # node-seconds of progress destroyed
    degrade_level: jax.Array   # effective ladder level in force (f32)
    # serving twin telemetry (core.serving); None (empty pytree nodes)
    # with serving off so scan carries/stacked outputs are unchanged
    srv_arrived_step: jax.Array | None = None
    srv_completed_step: jax.Array | None = None
    srv_shed_step: jax.Array | None = None
    srv_dropped_step: jax.Array | None = None
    srv_retried_step: jax.Array | None = None
    srv_slo_viol_step: jax.Array | None = None
    srv_latency_s: jax.Array | None = None   # fluid sojourn estimate
    srv_queue_len: jax.Array | None = None   # post-flow queued mass
    srv_active_nodes: jax.Array | None = None
    srv_lat_hist_step: jax.Array | None = None  # (8,) per-tick histogram


def _parse_weights(reward_weights) -> Tuple[
        float, float, float, float, float, float, float]:
    if len(reward_weights) not in (4, 5, 6, 7):
        from repro.utils.errors import ConfigError

        raise ConfigError(
            "reward_weights must have 4 to 7 entries "
            "(w_thr, w_en, w_co2, w_q[, w_cost[, w_lost[, w_slo]]]); got "
            f"{len(reward_weights)}")
    w_thr, w_en, w_co2, w_q = reward_weights[:4]
    w_cost = reward_weights[4] if len(reward_weights) >= 5 else 0.0
    w_lost = reward_weights[5] if len(reward_weights) >= 6 else 0.0
    w_slo = reward_weights[6] if len(reward_weights) == 7 else 0.0
    return w_thr, w_en, w_co2, w_q, w_cost, w_lost, w_slo


def _make_tail(cfg: SimConfig, statics: Statics, reward_weights,
               use_thermal_kernel: bool = False):
    """The per-tick accounting tail shared by the full step and the
    macro-step fast tick: grid signals at ``state.t``, thermal derating +
    the rack RC update (when ``cfg.thermal_enabled``), the DVFS throttle,
    job progress, energy/carbon/cost accumulation, reward and ``StepOut``.

    Keeping this a single code path is what makes fast-forwarded ticks
    bit-identical to per-tick quiet ticks — both run EXACTLY these float
    ops in this order; they differ only in where the inputs (power chain,
    congestion rate, queue/util counts) come from. ``thermal_enabled``
    and ``resilience_on`` are Python bools, so with both off the tail
    compiles to byte-for-byte the legacy program.

    ``killed_now``/``lost_now`` are the fault engine's per-tick kill and
    lost-work scalars — the full step passes them through, fast ticks
    pass nothing (faults fire only on event ticks, so zeros are exact).
    """
    (w_thr, w_en, w_co2, w_q, w_cost, w_lost,
     w_slo) = _parse_weights(reward_weights)
    scn = statics.scenario
    nameplate = max(cfg.nameplate_it_w, 1.0)
    # serving reward scale: the pool's full-rate request budget per tick
    srv_rate_scale = max(
        cfg.serving_nodes * cfg.serving_concurrency
        / max(cfg.serving_service_s, 1e-9) * cfg.dt, 1e-9)

    def tail(
        state: SimState,
        p: PowerOut,
        rate: jax.Array,          # pre-throttle per-job progress rate (J,)
        net_load: jax.Array,
        n_done: jax.Array,        # int32 completions this tick
        queued: jax.Array,
        running: jax.Array,
        util: jax.Array,
        killed_now: jax.Array | None = None,
        lost_now: jax.Array | None = None,
        shed_now: jax.Array | None = None,
        dropped_now: jax.Array | None = None,
        retried_now: jax.Array | None = None,
    ) -> Tuple[SimState, StepOut]:
        if killed_now is None:
            killed_now = jnp.float32(0.0)
        if lost_now is None:
            lost_now = jnp.float32(0.0)
        if cfg.serving_on and shed_now is None:
            # fast ticks: the discrete sweep fires only on full event
            # ticks, so zeros are exact (core.serving)
            shed_now = dropped_now = retried_now = jnp.float32(0.0)
        # --- grid signals at t (scenario engine)
        carbon_g = eval_signal(scn.carbon, state.t)          # gCO2/kWh
        price = eval_signal(scn.price, state.t)              # $/kWh
        cap_w = power_cap_at(scn.power_cap, state.t)         # W; 0 = uncapped
        wb = eval_signal(scn.wetbulb, state.t)               # degC

        if cfg.thermal_enabled:
            # --- thermal feedback (core.thermal): derate from the PREVIOUS
            # tick's outlet temps (explicit one-tick control lag), then
            # re-close the plant chain with the dynamic COP(wetbulb, load).
            # Only the node DYNAMIC power throttles — idle power burns at
            # any clock — and input power scales with IT (the rectifier-eta
            # shift under derating is second-order; docs/thermal.md).
            th_r = thm.rack_throttle(cfg, state.rack_outlet_c)   # (R,)
            node_th = th_r[statics.node_rack]                    # (N,)
            node_idle = statics.idle_w * state.node_up
            node_dyn = jnp.maximum(p.node_it_w - node_idle, 0.0)
            node_it = node_idle + node_th * node_dyn
            node_input = p.node_input_w * (
                node_it / jnp.maximum(p.node_it_w, 1e-9))
            it_w = jnp.sum(node_it)
            input_w = jnp.sum(node_input)
            dyn_tot = jnp.sum(node_dyn)
            gscale = jnp.where(
                dyn_tot > 0.0,
                jnp.sum(node_th * node_dyn) / jnp.maximum(dyn_tot, 1e-9),
                1.0)
            cop = thm.cooling_cop(cfg, wb, it_w / nameplate)
            cooling_w = input_w / cop
            facility_w = input_w + cooling_w
            pue = jnp.where(it_w > 1.0,
                            facility_w / jnp.maximum(it_w, 1.0), 1.0)
            p = p._replace(
                node_it_w=node_it, node_input_w=node_input, it_w=it_w,
                input_w=input_w, cooling_w=cooling_w,
                facility_w=facility_w, pue=pue, gflops=p.gflops * gscale)
            # synchronous ranks run at the slowest clock over a job's nodes
            rate = rate * thm.job_thermal_rate(state, statics, node_th)
        else:
            # telemetry-only mirror of power.finish_power's static plant
            # (dead for the accumulators, so the legacy math is untouched)
            cop = jnp.maximum(
                cfg.cop_base + cfg.cop_wetbulb_coef * (wb - cfg.wetbulb_ref_c),
                cfg.cop_min)

        if cfg.resilience_on:
            # --- graceful-degradation ladder (core.faults): levels >=
            # THROTTLE clock-throttle dynamic power and progress exactly
            # like the DVFS cap does (idle power burns at any clock);
            # the periodic checkpoint-write cost drags per-job progress
            # while power keeps burning. Constant across a quiet macro
            # segment (outage edges are breakpoints, degrade_level only
            # changes at decision ticks), so fast ticks re-running this
            # are exact.
            dg_lvl = flt.effective_level(cfg, state, statics)
            dg = flt.degrade_clock(cfg, dg_lvl)
            dg_on = dg_lvl >= flt.LVL_THROTTLE
            idle_dg = jnp.sum(statics.idle_w * state.node_up)
            dyn_dg = jnp.maximum(p.it_w - idle_dg, 0.0)
            r_dg = (idle_dg + dg * dyn_dg) / jnp.maximum(p.it_w, 1.0)
            r_dg = jnp.where(dg_on, r_dg, 1.0)
            p = p._replace(
                it_w=p.it_w * r_dg, input_w=p.input_w * r_dg,
                cooling_w=p.cooling_w * r_dg, facility_w=p.facility_w * r_dg,
                gflops=p.gflops * jnp.where(dg_on, dg, 1.0),
            )
            rate = rate * jnp.where(dg_on, dg, 1.0)
            if cfg.ckpt_overhead_s > 0:
                rate = rate * flt.ckpt_drag(cfg, state)
            dg_level_f = dg_lvl.astype(jnp.float32)
        else:
            dg_level_f = jnp.float32(0.0)

        if cfg.serving_on:
            # --- serving-pool power (core.serving): joins the plant
            # chain BEFORE the DVFS cap so the cap throttles batch and
            # serving dynamic power together; the pool's awake-idle +
            # sleep floor joins the unthrottleable idle base below. The
            # pool rides the same plant COP but heats no batch rack
            # (the RC update stays on p.node_input_w).
            srv_it, srv_in, srv_cool, srv_idle = srv.serving_power(
                cfg, state, cop)
            it2 = p.it_w + srv_it
            fac2 = p.facility_w + srv_in + srv_cool
            p = p._replace(
                it_w=it2, input_w=p.input_w + srv_in,
                cooling_w=p.cooling_w + srv_cool, facility_w=fac2,
                pue=jnp.where(it2 > 1.0,
                              fac2 / jnp.maximum(it2, 1.0), 1.0))

        # --- demand response: DVFS-throttle to the facility power cap
        # (DCFlex-style [3]; linear dynamic-power/progress model). The cap
        # is a traced value so scheduled events switch inside one compiled
        # step; `capped` gates the rescale exactly off when uncapped.
        capped = cap_w > 0.0
        idle_total = jnp.sum(statics.idle_w * state.node_up)
        if cfg.serving_on:
            idle_total = idle_total + srv_idle
        dyn = jnp.maximum(p.it_w - idle_total, 0.0)
        # facility ~ it * overhead; solve idle + a*dyn <= cap/overhead
        overhead = p.facility_w / jnp.maximum(p.it_w, 1.0)
        cap_it = cap_w / jnp.maximum(overhead, 1e-6)
        throttle = jnp.clip(
            (cap_it - idle_total) / jnp.maximum(dyn, 1.0),
            cfg.throttle_floor, 1.0,
        )
        throttle = jnp.where(capped, throttle, 1.0)
        r = (idle_total + throttle * dyn) / jnp.maximum(p.it_w, 1.0)
        r = jnp.where(capped, r, 1.0)
        p = p._replace(
            it_w=p.it_w * r, input_w=p.input_w * r,
            cooling_w=p.cooling_w * r, facility_w=p.facility_w * r,
            gflops=p.gflops * throttle,
        )

        # --- progress (congestion- and throttle-aware)
        rate = rate * throttle
        state = state._replace(work_left=state.work_left - rate * cfg.dt)
        dt_h = cfg.dt / 3600.0
        e_step = p.facility_w * dt_h / 1000.0                # kWh
        it_step = p.it_w * dt_h / 1000.0
        loss_step = (p.input_w - p.it_w) * dt_h / 1000.0
        cool_step = p.cooling_w * dt_h / 1000.0
        co2_step = e_step * carbon_g / 1000.0                # kg
        cost_step = e_step * price                           # $

        state = state._replace(
            energy_kwh=state.energy_kwh + e_step,
            it_energy_kwh=state.it_energy_kwh + it_step,
            loss_energy_kwh=state.loss_energy_kwh + loss_step,
            cool_energy_kwh=state.cool_energy_kwh + cool_step,
            carbon_kg=state.carbon_kg + co2_step,
            elec_cost_usd=state.elec_cost_usd + cost_step,
            flops_integral=state.flops_integral + p.gflops * cfg.dt,
            sum_power_w=state.sum_power_w + p.facility_w,
            n_steps=state.n_steps + 1.0,
        )

        if cfg.serving_on:
            # --- continuous request-mass flow (core.serving): arrivals,
            # admission, completions, SLO accounting — every tick,
            # shared by fast ticks, so macro stays bit-identical
            (state, srv_arr, srv_comp, srv_viol, srv_w, srv_q,
             srv_hist) = srv.serving_flow(cfg, state, statics, throttle)

        if cfg.thermal_enabled:
            # --- rack RC update: post-cap per-node input power (IT plus
            # conversion losses, all of it room heat) relaxes each rack
            # toward its loaded steady state. Committed LAST, so this
            # tick's derate used the pre-update temps (the one-tick lag).
            new_t, _ = thm.rack_thermal_update(
                cfg, statics, state.rack_outlet_c, p.node_input_w * r,
                thm.supply_temp(cfg, wb), use_kernel=use_thermal_kernel)
            th_step = jnp.where(jnp.any(th_r < 1.0), cfg.dt, 0.0)
            state = state._replace(
                rack_outlet_c=new_t,
                thermal_throttle_s=state.thermal_throttle_s + th_step,
                peak_rack_c=jnp.maximum(state.peak_rack_c, jnp.max(new_t)))
            rack_max = jnp.max(new_t)
        else:
            rack_max = jnp.max(state.rack_outlet_c)
            th_step = jnp.float32(0.0)

        # reward: throughput-positive, energy/carbon/queue-negative,
        # normalized to O(1) per step; the lost-work penalty charges the
        # node-seconds a kill destroyed against the fleet's node-second
        # budget for the tick
        reward = (
            w_thr * n_done
            - w_en * e_step / jnp.maximum(cfg.n_nodes * 0.4 * dt_h, 1e-9) * 0.1
            - w_co2 * co2_step / jnp.maximum(cfg.n_nodes * 0.15 * dt_h, 1e-9) * 0.1
            - w_q * queued * 0.01
            - w_cost * cost_step
            / jnp.maximum(cfg.n_nodes * 0.4 * dt_h * cfg.price_mean_usd_kwh, 1e-9)
            * 0.1
            - w_lost * lost_now / jnp.maximum(cfg.n_nodes * cfg.dt, 1e-9)
        )

        srv_out = {}
        if cfg.serving_on:
            # SLO penalty normalized by the pool's full-rate request
            # budget for the tick; shed/dropped mass counts as violated —
            # a ladder that sheds its way out of latency trouble still
            # pays, so goodput is the objective the policy faces
            reward = reward - w_slo * (
                srv_viol + shed_now + dropped_now) / srv_rate_scale
            srv_out = dict(
                srv_arrived_step=srv_arr, srv_completed_step=srv_comp,
                srv_shed_step=shed_now, srv_dropped_step=dropped_now,
                srv_retried_step=retried_now, srv_slo_viol_step=srv_viol,
                srv_latency_s=srv_w, srv_queue_len=srv_q,
                srv_active_nodes=state.srv_active,
                srv_lat_hist_step=srv_hist,
            )

        out = StepOut(
            facility_w=p.facility_w, it_w=p.it_w, pue=p.pue, util=util,
            queue_len=queued, running=running, completed_now=n_done,
            energy_kwh_step=e_step, carbon_kg_step=co2_step,
            net_load=net_load, reward=reward,
            carbon_gkwh=carbon_g, price_usd_kwh=price, power_cap_w=cap_w,
            cost_usd_step=cost_step, throttle=throttle,
            rack_max_c=rack_max, cop=cop, thermal_throttle_s_step=th_step,
            killed_now=killed_now, lost_node_s_step=lost_now,
            degrade_level=dg_level_f,
            **srv_out,
        )
        return state, out

    return tail


def _counts_and_util(state: SimState, statics: Statics):
    """(queued, running, util) telemetry scalars — constant across a quiet
    segment, so the fast tick caches them at segment start."""
    running = jnp.sum(state.jstate == RUNNING).astype(jnp.float32)
    queued = jnp.sum(sched.queued_mask(state)).astype(jnp.float32)
    up = jnp.maximum(jnp.sum(state.node_up), 1.0)
    busy = jnp.sum(
        (statics.capacity[0] - state.free[0]) / jnp.maximum(statics.capacity[0], 1e-6)
        * state.node_up
    )
    return queued, running, busy / up


# ---------------------------------------------------------------------------
# Node failures/repairs, outages and the degradation ladder live in
# ``core.faults`` (event-sampled clocks — exact macro breakpoints, zero
# per-tick PRNG draws; the old inline Bernoulli sweep is gone, and with
# it the unclamped dt/mtbf probability it handed jax.random.bernoulli).
# ``_release`` is re-exported from there: dispatch/completions below and
# the fault engine's kill path must share one resource-return routine.


def _complete_jobs(cfg: SimConfig, state: SimState) -> Tuple[SimState, jax.Array]:
    done_now = (state.jstate == RUNNING) & (state.work_left <= 0.0)
    free = _release(state.free, state, done_now)
    wait = jnp.maximum(state.start_t - state.submit_t, 0.0)
    run = jnp.maximum(state.t - state.start_t, cfg.dt)
    slowdown = jnp.maximum((wait + run) / run, 1.0)
    n_done = jnp.sum(done_now)
    state = state._replace(
        free=free,
        jstate=jnp.where(done_now, DONE, state.jstate),
        end_t=jnp.where(done_now, state.t, state.end_t),
        placement=jnp.where(done_now[:, None], -1, state.placement),
        n_completed=state.n_completed + n_done,
        sum_wait=state.sum_wait + jnp.sum(jnp.where(done_now, wait, 0.0)),
        sum_slowdown=state.sum_slowdown + jnp.sum(jnp.where(done_now, slowdown, 0.0)),
    )
    return state, n_done


def _try_start(cfg: SimConfig, state: SimState, job: jax.Array,
               place_fn) -> SimState:
    """Attempt to place & start `job` via the placement stage `place_fn`
    (state, job) -> (row, ok); no-op when job < 0 or infeasible."""
    j = jnp.maximum(job, 0)
    row, ok = place_fn(state, j)
    ok = ok & (job >= 0) & (state.jstate[j] == QUEUED)
    valid = (row >= 0) & ok
    safe = jnp.where(valid, row, 0)
    amounts = state.req[:, j][:, None] * valid[None, :]      # (R,K)
    free = state.free.at[:, safe].add(-amounts, mode="drop")
    return state._replace(
        free=jnp.where(ok, free, state.free).reshape(state.free.shape),
        jstate=state.jstate.at[j].set(jnp.where(ok, RUNNING, state.jstate[j])),
        start_t=state.start_t.at[j].set(jnp.where(ok, state.t, state.start_t[j])),
        placement=state.placement.at[j].set(
            jnp.where(ok, jnp.where(valid, row, -1), state.placement[j])
        ),
    )


def make_step(
    cfg: SimConfig,
    statics: Statics,
    scheduler: str | Policy = "fcfs",
    *,
    placement: str | None = None,
    starts_per_step: int = 2,
    reward_weights: Tuple[float, ...] = (1.0, 1.0, 1.0, 0.05),
    use_power_kernel: bool = False,
    use_thermal_kernel: bool = False,
):
    """Returns step(state, action) -> (state, StepOut).

    ``scheduler``: a selection name ('replay'|'fcfs'|'sjf'|'priority'|
    'easy'), 'rl' (external action-driven selection), 'none' (no dispatch
    at all — failures/completions/progress/power only; the RL env's idle
    sub-steps between agent decisions, where the pre-split step paid a
    full candidate-ranking + placement pass per sub-step for a guaranteed
    no-op), or a ``placement.Policy`` of traced (select_id, place_id)
    int32s — the policy-as-data mode where ``lax.switch`` resolves both
    stages inside one compiled step (the Policy carries the placement id,
    so combining it with an explicit ``placement=`` is a loud error).
    ``placement``: node-placement strategy name (``core.placement``) for
    the eager string/'rl' modes; default 'first_fit'.
    ``action``: int32 — for the 'rl' scheduler, index into
    ``rl_candidates`` (k = no-op at index k); ignored otherwise.
    reward_weights = (w_throughput, w_energy, w_carbon, w_queue[, w_cost]);
    w_cost scales the electricity-price penalty (default 0 — off).
    """
    policy_mode = isinstance(scheduler, Policy)
    if not policy_mode and scheduler not in ("rl", "none") \
            and scheduler not in sched.SCHEDULERS:
        raise KeyError(f"unknown scheduler {scheduler}")
    if policy_mode and placement is not None:
        from repro.utils.errors import ConfigError

        raise ConfigError(
            f"both a Policy scheduler and placement={placement!r} given — "
            "the Policy carries the placement id, so the string would be "
            "silently ignored; pass exactly one")
    if placement is None:
        placement = "first_fit"
    if placement not in plc.PLACEMENTS:
        raise KeyError(f"unknown placement {placement}")
    tail = _make_tail(cfg, statics, reward_weights,
                      use_thermal_kernel=use_thermal_kernel)

    if cfg.thermal_enabled or cfg.resilience_on:
        # dispatch-only gates folded into node_up through ONE seam, so
        # every selection/placement feasibility check — all five
        # placement strategies, EASY's backfill window, fits_now_mask —
        # sees them while power/progress still run the nodes:
        # - thermal: tripped racks accept no NEW jobs
        #   (core.thermal.node_trip_ok; the continuous throttle handles
        #   hot-but-running racks);
        # - resilience: degradation-ladder levels >= LVL_GATE (RL drain/
        #   gate actions, outage brownouts) block all new dispatch.
        def _dispatch_view(s: SimState) -> SimState:
            nu = s.node_up
            if cfg.thermal_enabled:
                ok = thm.node_trip_ok(cfg, s, statics)
                nu = jnp.where(ok, nu, 0.0)
            if cfg.resilience_on:
                gated = flt.effective_level(cfg, s, statics) >= flt.LVL_GATE
                nu = jnp.where(gated, 0.0, nu)
            return s._replace(node_up=nu)
    else:
        def _dispatch_view(s: SimState) -> SimState:
            return s

    if policy_mode:
        def place_fn(s, j):
            return plc.place_job(_dispatch_view(s), statics, j,
                                 scheduler.place)
    else:
        eager_place = plc.PLACEMENTS[placement]

        def place_fn(s, j):
            return eager_place(_dispatch_view(s), statics, j)

    def step(state: SimState, action: jax.Array) -> Tuple[SimState, StepOut]:
        state = state._replace(t=state.t + cfg.dt)
        if cfg.resilience_on:
            state, killed_now, lost_now = flt.apply_faults(cfg, state,
                                                           statics)
        else:
            killed_now = lost_now = None
        if cfg.serving_on:
            # discrete overload ladder: autoscale, retry re-injection,
            # timeout/admission/shed cascade (full event ticks only;
            # bitwise fixpoint on quiet ticks — core.serving)
            state, shed_now, dropped_now, retried_now = srv.apply_serving(
                cfg, state, statics)
        else:
            shed_now = dropped_now = retried_now = None
        state, n_done = _complete_jobs(cfg, state)

        # --- dispatch
        if not policy_mode and scheduler == "none":
            pass    # idle sub-step: no selection, no placement
        elif not policy_mode and scheduler == "rl":
            cands = sched.rl_candidates(cfg, state)          # (k,)
            k = cands.shape[0]
            job = jnp.where(action < k, cands[jnp.clip(action, 0, k - 1)], -1)
            state = _try_start(cfg, state, job, place_fn)
        else:
            # single fori_loop wavefront: the jaxpr holds ONE copy of the
            # select+place body regardless of starts_per_step (the unrolled
            # loop grew trace size/compile time linearly with attempts).
            # Selection sees the placement backend's node eligibility
            # (PLACEMENT_MASKS registry, e.g. partition tags) so it never
            # picks a job placement rejects. Eligibility depends only on
            # part/node_type — loop-invariant, so it is computed once per
            # step, not per dispatch attempt.
            if policy_mode:
                node_mask = plc.placement_node_mask(state, statics,
                                                    scheduler.place)

                def select(c, s):
                    return sched.select_job(c, _dispatch_view(s), statics,
                                            scheduler.select, node_mask)
            else:
                eager_select = sched.SCHEDULERS[scheduler]
                mask_fn = plc.PLACEMENT_MASKS[placement]
                node_mask = None if mask_fn is None else mask_fn(state,
                                                                 statics)

                def select(c, s):
                    return eager_select(c, _dispatch_view(s), statics,
                                        node_mask)

            def dispatch(_, s: SimState) -> SimState:
                return _try_start(cfg, s, select(cfg, s), place_fn)

            state = jax.lax.fori_loop(0, starts_per_step, dispatch, state)

        # --- power chain (pre-throttle) + progress rate + telemetry counts;
        # the shared accounting tail does the rest (signals, throttle,
        # progress, accumulation, reward)
        p: PowerOut = compute_power(cfg, state, statics, use_kernel=use_power_kernel)
        rate, net_load = congestion_slowdown(cfg, state, statics)
        queued, running, util = _counts_and_util(state, statics)
        return tail(state, p, rate, net_load, n_done, queued, running, util,
                    killed_now, lost_now, shed_now, dropped_now, retried_now)

    return step


class TelemetrySummary(NamedTuple):
    """Windowed reductions of ``StepOut`` — the constant-memory telemetry
    carried through the scan instead of stacking 16 fields per step.

    Totals are sums over the window; ``mean_*`` are per-step means and
    ``max_*`` maxima. ``n_steps`` is the window length.
    """

    # additive totals
    completed: jax.Array
    energy_kwh: jax.Array
    carbon_kg: jax.Array
    cost_usd: jax.Array
    reward: jax.Array
    thermal_throttle_s: jax.Array  # seconds any rack was thermally derated
    killed: jax.Array          # jobs killed by node loss (core.faults)
    lost_node_s: jax.Array     # node-seconds of progress destroyed
    # serving twin (core.serving): windowed request-mass totals + the
    # log-2 latency histogram the SLO quantiles come from; None (empty
    # pytree nodes) with serving off
    srv_arrived: jax.Array
    srv_completed: jax.Array
    srv_shed: jax.Array
    srv_dropped: jax.Array
    srv_retried: jax.Array
    srv_slo_viol: jax.Array
    srv_lat_sum: jax.Array     # mass-weighted latency integral [req*s]
    srv_lat_hist: jax.Array    # (8,) completion mass per log-2 SLO bucket
    # per-step means
    mean_facility_w: jax.Array
    mean_it_w: jax.Array
    mean_pue: jax.Array
    mean_util: jax.Array
    mean_queue_len: jax.Array
    mean_running: jax.Array
    mean_net_load: jax.Array
    mean_carbon_gkwh: jax.Array
    mean_price_usd_kwh: jax.Array
    mean_throttle: jax.Array
    # with thermal_enabled, ``mean_pue`` above becomes the DYNAMIC PUE
    # (COP responds to wetbulb AND IT load) and these two activate:
    mean_cop: jax.Array        # cooling-plant COP (wetbulb x load aware)
    # extremes
    max_facility_w: jax.Array
    max_queue_len: jax.Array
    max_rack_c: jax.Array      # hottest rack outlet over the window
    n_steps: jax.Array
    # macro-stepping skip accounting: how many ticks ran the full event
    # step (dispatch/completions/failures machinery) vs. the fast-forward
    # path. Per-tick runs have macro_steps == n_steps (skip ratio 1); a
    # macro run's speedup potential is n_steps / macro_steps.
    macro_steps: jax.Array


_SRV_TELEM = ("srv_arrived", "srv_completed", "srv_shed", "srv_dropped",
              "srv_retried", "srv_slo_viol", "srv_lat_sum", "srv_lat_hist")


def _telem_zero(resilience_on: bool = True,
                serving_on: bool = False) -> TelemetrySummary:
    z = jnp.float32(0.0)
    acc = TelemetrySummary(*([z] * len(TelemetrySummary._fields)))
    if not resilience_on:
        # With the fault engine off the killed/lost accumulators would be
        # constant zeros — but even two dead loop-carried leaves perturb
        # XLA's scan-body codegen enough to shift float rounding elsewhere
        # in the step (observed: 1e-6 work_left drift on the thermal
        # macro-vs-per-tick bit-identity pin). ``None`` is an EMPTY pytree
        # node, so the compiled carry is leaf-for-leaf the legacy program;
        # ``_telem_finalize`` restores concrete zeros for consumers.
        acc = acc._replace(killed=None, lost_node_s=None)
    if serving_on:
        acc = acc._replace(srv_lat_hist=jnp.zeros((8,), jnp.float32))
    else:
        # same XLA-codegen hazard as killed/lost above: the serving
        # accumulators ride as empty nodes when the plane is off
        acc = acc._replace(**{f: None for f in _SRV_TELEM})
    return acc


def _telem_update(acc: TelemetrySummary, out: StepOut,
                  macro_inc: jax.Array | float = 1.0,
                  resilience_on: bool = True,
                  serving_on: bool = False) -> TelemetrySummary:
    # mean_* fields hold running sums until _telem_finalize divides by n.
    # The killed/lost (and serving) adds are Python-gated: with the engine
    # off the addends are constant zeros, but even dead adds perturb XLA's
    # scan-body codegen enough to shift float rounding elsewhere in the
    # step — gating keeps the legacy per-tick program (and its bit-pinned
    # outputs) intact.
    return TelemetrySummary(
        completed=acc.completed + out.completed_now,
        srv_arrived=acc.srv_arrived + out.srv_arrived_step
        if serving_on else acc.srv_arrived,
        srv_completed=acc.srv_completed + out.srv_completed_step
        if serving_on else acc.srv_completed,
        srv_shed=acc.srv_shed + out.srv_shed_step
        if serving_on else acc.srv_shed,
        srv_dropped=acc.srv_dropped + out.srv_dropped_step
        if serving_on else acc.srv_dropped,
        srv_retried=acc.srv_retried + out.srv_retried_step
        if serving_on else acc.srv_retried,
        srv_slo_viol=acc.srv_slo_viol + out.srv_slo_viol_step
        if serving_on else acc.srv_slo_viol,
        srv_lat_sum=acc.srv_lat_sum
        + out.srv_completed_step * out.srv_latency_s
        if serving_on else acc.srv_lat_sum,
        srv_lat_hist=acc.srv_lat_hist + out.srv_lat_hist_step
        if serving_on else acc.srv_lat_hist,
        energy_kwh=acc.energy_kwh + out.energy_kwh_step,
        carbon_kg=acc.carbon_kg + out.carbon_kg_step,
        cost_usd=acc.cost_usd + out.cost_usd_step,
        reward=acc.reward + out.reward,
        thermal_throttle_s=acc.thermal_throttle_s
        + out.thermal_throttle_s_step,
        killed=acc.killed + out.killed_now if resilience_on else acc.killed,
        lost_node_s=acc.lost_node_s + out.lost_node_s_step
        if resilience_on else acc.lost_node_s,
        mean_facility_w=acc.mean_facility_w + out.facility_w,
        mean_it_w=acc.mean_it_w + out.it_w,
        mean_pue=acc.mean_pue + out.pue,
        mean_util=acc.mean_util + out.util,
        mean_queue_len=acc.mean_queue_len + out.queue_len,
        mean_running=acc.mean_running + out.running,
        mean_net_load=acc.mean_net_load + out.net_load,
        mean_carbon_gkwh=acc.mean_carbon_gkwh + out.carbon_gkwh,
        mean_price_usd_kwh=acc.mean_price_usd_kwh + out.price_usd_kwh,
        mean_throttle=acc.mean_throttle + out.throttle,
        mean_cop=acc.mean_cop + out.cop,
        max_facility_w=jnp.maximum(acc.max_facility_w, out.facility_w),
        max_queue_len=jnp.maximum(acc.max_queue_len, out.queue_len),
        max_rack_c=jnp.maximum(acc.max_rack_c, out.rack_max_c),
        n_steps=acc.n_steps + 1.0,
        macro_steps=acc.macro_steps + macro_inc,
    )


def _telem_finalize(acc: TelemetrySummary) -> TelemetrySummary:
    n = jnp.maximum(acc.n_steps, 1.0)
    acc = acc._replace(**{
        f: getattr(acc, f) / n
        for f in TelemetrySummary._fields if f.startswith("mean_")
    })
    if acc.killed is None:   # resilience off: carried as empty nodes
        acc = acc._replace(killed=jnp.float32(0.0),
                           lost_node_s=jnp.float32(0.0))
    if acc.srv_arrived is None:  # serving off: carried as empty nodes
        acc = acc._replace(
            **{f: jnp.float32(0.0) for f in _SRV_TELEM[:-1]},
            srv_lat_hist=jnp.zeros((8,), jnp.float32))
    return acc


# ---------------------------------------------------------------------------
# Macro-stepping: fast-forward quiet ticks with exact segment accounting.
#
# A tick is QUIET when advancing it changes no machine state: no queued job
# becomes newly visible/eligible to selection, no running job completes, no
# node fails or returns from repair, no cap-schedule breakpoint is crossed,
# and the last dispatch attempt proved the current queue unservable. Across
# a quiet segment the running set, placement, free pool and congestion rate
# are all constant — only time, per-job remaining work, the trace-quanta
# utilization indices and the continuous grid signals move. The fast tick
# therefore re-runs ONLY the shared accounting tail (exact signal-grid
# integration through the nonlinear COP/throttle consumers, which is why a
# closed-form segment integral cannot replace it) plus a cheap utilization
# -> power refresh, and skips the dispatch wavefront, completion sweep and
# telemetry-count machinery entirely.

_BIG_T = jnp.float32(jnp.inf)

# SimState leaves a fast tick may change; everything else provably keeps
# its segment-start value, so the commit-select only touches these.
_FAST_FIELDS = (
    "t", "work_left", "energy_kwh", "it_energy_kwh", "loss_energy_kwh",
    "cool_energy_kwh", "carbon_kg", "elec_cost_usd", "flops_integral",
    "sum_power_w", "n_steps",
)


def _fast_fields(cfg: SimConfig) -> tuple:
    """Fast-tick-mutable SimState leaves for this config: the thermal
    carry joins only when the cooling loop is on (the thermal-off tail
    never writes it, and keeping the commit-select identical preserves the
    legacy program byte-for-byte); likewise the serving flow leaves only
    when the serving plane is on."""
    ff = _FAST_FIELDS
    if cfg.thermal_enabled:
        ff = ff + ("rack_outlet_c", "thermal_throttle_s", "peak_rack_c")
    if cfg.serving_on:
        ff = ff + ("srv_queue", "srv_inflight", "srv_arrived",
                   "srv_completed", "srv_slo_viol", "srv_lat_sum",
                   "srv_lat_hist")
    return ff


def _horizon_parts(cfg: SimConfig, state: SimState, statics: Statics,
                   rate: jax.Array, dispatch_on: bool, replay_gated: bool,
                   eligibility_vis: bool, max_ticks: int):
    """(next_event_t, visible_now, k_time, k_complete): the earliest
    deterministic breakpoint strictly after ``state.t``, whether a
    dispatch-visible queued job exists right now, and the conservative
    quiet-tick counts from time-events and from completions."""
    t = state.t
    q = state.jstate == QUEUED
    # arrivals: the queued count (telemetry + reward) changes when a
    # submit time is crossed; selection visibility changes with it
    next_t = jnp.min(jnp.where(q & (state.submit_t > t),
                               state.submit_t, _BIG_T))
    visible_now = jnp.bool_(False)
    if dispatch_on:
        vis_t = state.submit_t
        if eligibility_vis:
            # eager replay: a queued job is only dispatchable once BOTH
            # its submit and its recorded start (priority) are crossed
            vis_t = jnp.maximum(state.submit_t, state.priority)
        visible_now = jnp.any(q & (vis_t <= t))
    if dispatch_on and replay_gated:
        # replay eligibility: a queued job becomes dispatchable when its
        # recorded start (carried in `priority`) is crossed
        next_t = jnp.minimum(next_t, jnp.min(jnp.where(
            q & (state.priority > t), state.priority, _BIG_T)))
    # node repairs return capacity at recorded times
    next_t = jnp.minimum(next_t, jnp.min(jnp.where(
        state.node_up < 0.5, state.repair_t, _BIG_T)))
    # demand-response cap windows open/close at schedule breakpoints
    next_t = jnp.minimum(next_t, next_cap_event(statics.scenario.power_cap, t))
    if cfg.resilience_on:
        # event-sampled fault clocks + outage-window edges are exact
        # breakpoints (core.faults keeps every clock strictly future)
        next_t = jnp.minimum(
            next_t, flt.next_fault_event(cfg, state, statics, t))
    if cfg.serving_on:
        # serving clock breakpoints: autoscale wake completions, retry
        # re-injections, traffic-burst window edges (core.serving) —
        # the discrete sweep runs on full ticks only
        next_t = jnp.minimum(
            next_t, srv.next_serving_event(cfg, state, statics, t))

    kf = jnp.float32(max_ticks)
    k_time = jnp.where(jnp.isfinite(next_t),
                       jnp.floor((next_t - t) / cfg.dt - 1e-6), kf)
    # completions: per-tick progress never exceeds rate * dt (throttle <=
    # 1), so floor(work/(rate*dt)) - 1 ticks can never cross zero — the -1
    # margin also absorbs float drift of the per-tick subtraction chain
    run_m = state.jstate == RUNNING
    ticks_c = jnp.where(
        run_m,
        jnp.floor(state.work_left / (jnp.maximum(rate, 1e-9) * cfg.dt)) - 1.0,
        kf,
    )
    k_complete = jnp.min(ticks_c)
    return (next_t, visible_now,
            jnp.clip(k_time, 0.0, kf).astype(jnp.int32),
            jnp.clip(k_complete, 0.0, kf).astype(jnp.int32))


def quiet_horizon(
    cfg: SimConfig,
    statics: Statics,
    state: SimState,
    scheduler: str | Policy = "fcfs",
    *,
    max_ticks: int = 4096,
    assume_undispatchable: bool | jax.Array = False,
) -> jax.Array:
    """Number of ticks after ``state.t`` guaranteed quiet (int32 >= 0).

    The horizon is the min over the next arrival (submit crossing), next
    replay-eligibility crossing, next completion (conservative: assumes
    full-rate progress, minus one tick of float margin), next node repair,
    next cap-schedule breakpoint, and — with the fault engine on — the
    next event-sampled fault-clock crossing / outage-window edge
    (``core.faults.next_fault_event``), clamped to ``max_ticks``.
    Faults are EXACT breakpoints: the clocks are absolute times redrawn
    only when they fire, so fast-forwarded ticks consume no randomness
    and the PRNG stream stays bit-identical (the old per-tick Bernoulli
    model had to be replayed tick-by-tick during fast-forward, which
    forfeited the macro speedup whenever faults were enabled).

    ``assume_undispatchable``: queued-but-visible jobs normally force a
    zero horizon (selection might start one any tick). When the caller
    has just run a full dispatch tick that started NOTHING, the visible
    queue is proven unservable — every selection policy's pick is
    constant between events for a frozen machine state — and fast-forward
    may proceed; pass True (the macro engine does) to encode that proof.

    With ``cfg.thermal_enabled`` the trip gate makes dispatch eligibility
    temperature-dependent, so a *thermal breakpoint* joins the min: a
    conservative tick count within which no rack can cross
    ``thermal_trip_c`` (``core.thermal.thermal_crossing_horizon``; the
    RC update is a contraction, so the bound follows from the box the
    temperatures are confined to). The macro engine additionally detects
    actual crossings authoritatively per fast tick — this bound only
    keeps segments short enough that the detection stays cheap.
    """
    policy_mode = isinstance(scheduler, Policy)
    dispatch_on = policy_mode or scheduler != "none"
    replay_gated = policy_mode or scheduler == "replay"
    eligibility_vis = (not policy_mode) and scheduler == "replay"
    rate, _ = congestion_slowdown(cfg, state, statics)
    next_t, visible_now, k_time, k_complete = _horizon_parts(
        cfg, state, statics, rate, dispatch_on, replay_gated,
        eligibility_vis, max_ticks)
    blocked = visible_now & ~jnp.asarray(assume_undispatchable)
    horizon = jnp.where(blocked, 0, jnp.minimum(k_time, k_complete))
    if cfg.thermal_enabled and dispatch_on:
        horizon = jnp.minimum(horizon, thm.thermal_crossing_horizon(
            cfg, statics, state, max_ticks))
    if cfg.serving_on:
        # queue-threshold crossings: conservative arrival-envelope bound
        # + a zero horizon when the queue is already over a threshold
        # (core.serving; the macro engine also detects crossings
        # authoritatively per committed fast tick)
        horizon = jnp.minimum(horizon, srv.serving_crossing_horizon(
            cfg, state, statics, max_ticks))
        horizon = jnp.where(srv.serving_trigger(cfg, state), 0, horizon)
    return horizon


def make_macro_step(
    cfg: SimConfig,
    statics: Statics,
    scheduler: str | Policy = "fcfs",
    *,
    placement: str | None = None,
    starts_per_step: int = 2,
    reward_weights: Tuple[float, ...] = (1.0, 1.0, 1.0, 0.05),
    use_power_kernel: bool = False,
    use_thermal_kernel: bool = False,
    horizon_cap: int = 4096,
    chunk_ticks: int = 16,
    update=None,
):
    """Returns ``macro_step(state, acc, max_ticks) -> (state, acc, ticks)``:
    ONE full event tick (identical to ``make_step``'s, with action -1)
    followed by a fused fast-forward through the quiet segment, never past
    ``max_ticks`` total ticks (the caller's episode/telemetry-window/agent
    -decision boundary).

    Exactness: fast ticks advance time sequentially and re-run the SAME
    accounting tail as the full step, so job/queue state is bit-identical
    to per-tick stepping, fault clocks fire at exact breakpoint ticks
    with the identical PRNG stream (quiet ticks consume zero randomness;
    core.faults), and accumulators are bit-identical on configs where the
    power path is shared (the dense-scatter budget, i.e. every test-sized
    config). On
    larger configs the fast tick refreshes per-node loads through a
    per-segment job->node count matrix — one ``chunk_ticks``-wide gemm
    instead of a J*K scatter per tick; the different summation order
    leaves energy/cost/carbon within float-accumulation tolerance of the
    per-tick path (job/queue
    state stays exact whenever the facility is uncapped, since then
    throttle == 1.0 exactly and progress never consumes power terms).

    ``update(acc, out, macro_inc)`` folds each tick's ``StepOut`` into the
    caller's accumulator (default: ``TelemetrySummary`` update; the RL env
    passes its info-dict reducer). ``macro_inc`` is 1.0 for the event tick
    and 0.0 for fast ticks — the skip-ratio telemetry.
    """
    step = make_step(cfg, statics, scheduler, placement=placement,
                     starts_per_step=starts_per_step,
                     reward_weights=reward_weights,
                     use_power_kernel=use_power_kernel,
                     use_thermal_kernel=use_thermal_kernel)
    tail = _make_tail(cfg, statics, reward_weights,
                      use_thermal_kernel=use_thermal_kernel)
    policy_mode = isinstance(scheduler, Policy)
    dispatch_on = policy_mode or scheduler != "none"
    replay_gated = policy_mode or scheduler == "replay"
    eligibility_vis = (not policy_mode) and scheduler == "replay"
    # thermal breakpoints: the trip gate makes DISPATCH eligibility depend
    # on rack temps, which keep evolving across fast ticks. A segment must
    # therefore end the tick a rack crosses thermal_trip_c (either
    # direction): detection is authoritative — each committed fast tick
    # compares its pre/post trip sets — and stopping AFTER the crossing
    # tick is exact because a tick's dispatch reads the temps its
    # PREDECESSOR committed (the tail's one-tick control lag), so the
    # crossing tick itself was still quiet under the old trip set. Without
    # dispatch there is no trip consumer and thermals stay breakpoint-free.
    thermal_gate = cfg.thermal_enabled and dispatch_on
    trip_c = jnp.float32(cfg.thermal_trip_c)
    fast_fields = _fast_fields(cfg)
    N = cfg.n_nodes
    C = max(int(chunk_ticks), 1)
    # shared power path (bit-identical to the full step) whenever the
    # per-tick scatter is already the dense contraction; the chunked
    # count-matrix gemm otherwise (see docstring)
    shared_power = use_dense_scatter(cfg.max_jobs * cfg.max_nodes_per_job, N)
    if update is None:
        def update(acc, out, macro_inc=1.0):
            return _telem_update(acc, out, macro_inc,
                                 resilience_on=cfg.resilience_on,
                                 serving_on=cfg.serving_on)

    def power_chunk(s: SimState, cnt):
        """(ts, PowerOut-with-leading-C-axis) for the next C ticks under a
        frozen machine state: utilization only drifts through the
        trace-quanta index, so per-node loads for the whole chunk are ONE
        gemm against the per-segment job->node count matrix instead of C
        scatters — the arithmetic-intensity trick that makes fast ticks
        ~O(scalar). The chain itself is the shared ``power_from_fracs``
        (vmapped over the chunk), so the rectifier/COP model has a single
        source of truth."""
        ts = s.t + cfg.dt * jnp.arange(1, C + 1, dtype=jnp.float32)
        cpu_u, gpu_u = jax.vmap(
            lambda t: job_utilization(cfg, s._replace(t=t), statics)
        )(ts)                                                      # (C, J)
        loads = jnp.matmul(
            jnp.concatenate([cpu_u * s.req[0][None, :],
                             gpu_u * s.req[1][None, :]]),
            cnt, precision=jax.lax.Precision.HIGHEST)              # (2C, N)
        cpu_frac = jnp.clip(
            loads[:C] / jnp.maximum(statics.capacity[0], 1e-6), 0, 1)
        gpu_frac = jnp.clip(
            loads[C:] / jnp.maximum(statics.capacity[1], 1e-6), 0, 1)
        p = jax.vmap(
            lambda t, cf, gf: power_from_fracs(
                cfg, s._replace(t=t), statics, cf, gf)
        )(ts, cpu_frac, gpu_frac)
        return ts, p

    def macro_step(state: SimState, acc, max_ticks):
        was_queued = state.jstate == QUEUED
        state, out = step(state, jnp.int32(-1))
        acc = update(acc, out, 1.0)
        started = jnp.any(was_queued & (state.jstate == RUNNING))

        # --- segment constants (all provably frozen across quiet ticks).
        # NB net_load carries a cross-job reduction: XLA may fuse it
        # differently here than in the per-tick program, so telemetry
        # means can skew an ulp vs per-tick runs (the documented
        # float-accumulation tolerance); job/queue state never consumes it
        rate, net_load = congestion_slowdown(cfg, state, statics)
        next_event_t, visible_now, k_time, _ = _horizon_parts(
            cfg, state, statics, rate, dispatch_on, replay_gated,
            eligibility_vis, horizon_cap)
        # dispatch gate: if the full tick started something AND jobs are
        # still visible, the leftovers may now be servable — keep per-tick
        # stepping. A start that DRAINED the queue, or a no-start with a
        # visible queue (proven unservable: selection picks are
        # t-independent for a frozen machine state, EASY's backfill window
        # only shrinks, replay-eligibility crossings are event
        # boundaries), both allow fast-forward. Completions are peeked per
        # tick (authoritative), so the budget only carries the
        # deterministic time-event horizon.
        k_quiet = jnp.minimum(k_time, max_ticks - 1)
        if thermal_gate:
            # conservative thermal-crossing horizon (belt to the per-tick
            # detection's suspenders: keeps segments from even entering
            # the neighborhood of a trip crossing un-checked)
            k_quiet = jnp.minimum(k_quiet, thm.thermal_crossing_horizon(
                cfg, statics, state, horizon_cap))
        blocked = started & visible_now
        if cfg.serving_on:
            # arrival-envelope bound on queue-threshold crossings, and
            # stay per-tick while the queue sits over a threshold (the
            # next tick's sweep WILL move mass): overload IS the event
            k_quiet = jnp.minimum(k_quiet, srv.serving_crossing_horizon(
                cfg, state, statics, horizon_cap))
            blocked = blocked | srv.serving_trigger(cfg, state)
        budget = jnp.where(blocked, 0, k_quiet)
        queued, running, util = _counts_and_util(state, statics)

        def peek_stop(s, t_next):
            # authoritative, side-effect free: an event tick is NOT
            # committed here; the next full step replays it. Faults need
            # no peek at all — their clocks are deterministic absolute
            # times already folded into next_event_t, and quiet ticks
            # consume zero randomness (the Bernoulli replay that used to
            # run here per fast tick is gone; core.faults).
            stop = jnp.any((s.jstate == RUNNING) & (s.work_left <= 0.0))
            return stop | (t_next >= next_event_t)

        def commit(s, a, i, stop, t_next, p: PowerOut):
            ns, o = tail(s._replace(t=t_next), p, rate, net_load,
                         jnp.int32(0), queued, running, util)
            na = update(a, o, 0.0)
            s = s._replace(**{
                f: _where_leaf(stop, getattr(s, f), getattr(ns, f))
                for f in fast_fields
            })
            a = jax.tree.map(lambda old, new: jnp.where(stop, old, new),
                             a, na)
            return s, a, i + jnp.where(stop, 0, 1)

        if shared_power:
            # small configs: per-tick compute_power IS the full step's
            # dense-contraction path — bit-identical accumulators
            def body(c):
                s, a, i, _ = c
                t_next = s.t + cfg.dt
                stop = peek_stop(s, t_next)
                p = compute_power(cfg, s._replace(t=t_next), statics,
                                  use_kernel=use_power_kernel)
                was_hot = s.rack_outlet_c >= trip_c
                s, a, i = commit(s, a, i, stop, t_next, p)
                go = ~stop
                if thermal_gate:   # authoritative trip-crossing breakpoint
                    go &= ~jnp.any((s.rack_outlet_c >= trip_c) != was_hot)
                if cfg.serving_on:  # authoritative overload breakpoint
                    go &= ~srv.serving_trigger(cfg, s)
                return (s, a, i, go)

            state, acc, took, _ = jax.lax.while_loop(
                lambda c: c[3] & (c[2] < budget), body,
                (state, acc, jnp.int32(0), budget > 0))
            return state, acc, 1 + took

        # large configs: per-segment job->node count matrix + chunked
        # power precompute; the inner tick body is then O(scalar) + the
        # O(J) progress/peek ops
        J, K = state.placement.shape
        valid = state.placement >= 0
        safe = jnp.where(valid, state.placement, 0)
        cnt = jnp.zeros((J, N), jnp.float32).at[
            jnp.arange(J)[:, None], safe].add(valid.astype(jnp.float32))

        def inner_body(c):
            s, a, i, j, _, chk = c
            ts, pc = chk
            t_next = ts[j]
            stop = peek_stop(s, t_next)
            p = jax.tree.map(lambda x: x[j], pc)
            was_hot = s.rack_outlet_c >= trip_c
            s, a, i = commit(s, a, i, stop, t_next, p)
            go = ~stop
            if thermal_gate:       # authoritative trip-crossing breakpoint
                go &= ~jnp.any((s.rack_outlet_c >= trip_c) != was_hot)
            if cfg.serving_on:     # authoritative overload breakpoint
                go &= ~srv.serving_trigger(cfg, s)
            return (s, a, i, j + 1, go, chk)

        def outer_body(c):
            s, a, i, go = c
            chk = power_chunk(s, cnt)
            s, a, i, _, go, _ = jax.lax.while_loop(
                lambda c: c[4] & (c[2] < budget) & (c[3] < C), inner_body,
                (s, a, i, jnp.int32(0), go, chk))
            return (s, a, i, go)

        state, acc, took, _ = jax.lax.while_loop(
            lambda c: c[3] & (c[2] < budget), outer_body,
            (state, acc, jnp.int32(0), budget > 0))
        return state, acc, 1 + took

    return macro_step


def _where_leaf(pred, old, new):
    """jnp.where that also handles typed PRNG key arrays."""
    if jnp.issubdtype(jnp.result_type(old), jax.dtypes.prng_key):
        return jax.random.wrap_key_data(
            jnp.where(pred, jax.random.key_data(old),
                      jax.random.key_data(new)),
            impl=jax.random.key_impl(old))
    return jnp.where(pred, old, new)


def run_episode(
    cfg: SimConfig,
    statics: Statics,
    state: SimState,
    n_steps: int,
    scheduler: str | Policy = "fcfs",
    *,
    telemetry_every: int = 1,
    summary_only: bool = False,
    macro: bool = False,
    snapshot_every_s: float | None = None,
    snapshot_dir: str | None = None,
    resume_from: str | None = None,
    snapshot_keep: int = 3,
    **kw,
) -> Tuple[SimState, StepOut | TelemetrySummary]:
    """Scan `n_steps` of the twin under a non-RL policy.

    ``scheduler`` may be a policy name or a traced ``placement.Policy``
    (policy-as-data): jit a wrapper taking the Policy as an argument and
    the whole selection x placement grid shares ONE compiled executable.

    Telemetry modes (both static, so each compiles once):
      - default: stacked per-step ``StepOut`` — O(n_steps * 16) memory;
      - ``telemetry_every=k``: one ``TelemetrySummary`` per k-step window
        (stacked, length ``n_steps // k``) — O(n_steps/k) memory;
      - ``summary_only=True``: a single episode-wide ``TelemetrySummary``
        accumulated in the scan carry — O(1) memory in ``n_steps``.

    ``macro=True`` drives the episode with ``make_macro_step``: quiet
    ticks (no arrival/completion/dispatch/failure/cap breakpoint) are
    fast-forwarded with exact segment accounting — the big win for
    replay-shaped workloads (see docs/performance.md "Macro-stepping").
    Ticks can no longer be stacked per step, so telemetry is episode-wide
    (``summary_only`` is implied) or windowed via ``telemetry_every``;
    window edges clamp the fast-forward horizon, so windowed results stay
    tick-aligned with the per-tick path.

    With ``REPRO_CHECKIFY=1`` (``utils.invariants``; hard-enabled in CI)
    and an eager call (un-traced ``state``), every committed step runs
    the machine-invariant suite — resource conservation, placement/
    jstate consistency, finite accumulators, bounded rack temps — via
    ``checkify``, raising on the first violating tick. Traced callers
    (e.g. ``run_fleet``'s inner jit) skip the per-step harness; the
    fleet runner re-checks final states eagerly instead.

    Durability (``checkpoint.episode``): ``snapshot_every_s=T`` writes a
    crash-atomic snapshot (SimState + raw telemetry accumulator + run
    fingerprint) every ~T simulated seconds to ``snapshot_dir``;
    ``resume_from=dir`` resumes from the newest snapshot there —
    bit-identical to the uninterrupted run (fingerprint mismatch raises
    ``CheckpointError``). Requires an episode-wide summary
    (``summary_only=True`` or ``macro=True`` with ``telemetry_every<=1``)
    and an eager (un-jitted) call; with snapshotting off this path adds
    literally nothing to the traced step.
    """
    from repro.utils import invariants
    from repro.utils.errors import ConfigError

    if summary_only and telemetry_every > 1:
        raise ConfigError(
            "summary_only=True is episode-wide; it conflicts with "
            f"telemetry_every={telemetry_every} (pick one)"
        )
    if telemetry_every > 1 and n_steps % telemetry_every:
        raise ConfigError(
            f"n_steps={n_steps} not divisible by "
            f"telemetry_every={telemetry_every}"
        )
    if snapshot_every_s is not None or resume_from is not None \
            or snapshot_dir is not None:
        from repro.checkpoint.episode import run_episode_snapshotted

        return run_episode_snapshotted(
            cfg, statics, state, n_steps, scheduler,
            telemetry_every=telemetry_every, summary_only=summary_only,
            macro=macro, snapshot_every_s=snapshot_every_s,
            snapshot_dir=snapshot_dir, resume_from=resume_from,
            snapshot_keep=snapshot_keep, kw=kw)
    check_on = invariants.enabled() and not isinstance(
        state.t, jax.core.Tracer)

    if macro:
        mstep = make_macro_step(cfg, statics, scheduler, **kw)
        if check_on:
            raw_mstep = mstep

            def mstep(s, a, n):
                s, a, took = raw_mstep(s, a, n)
                invariants.check_state(cfg, statics, s)
                return s, a, took

        def run_window(state, n):
            def wcond(c):
                return c[2] < n

            def wbody(c):
                s, a, ticks = c
                s, a, took = mstep(s, a, n - ticks)
                return (s, a, ticks + took)

            s, a, _ = jax.lax.while_loop(
                wcond, wbody,
                (state, _telem_zero(cfg.resilience_on, cfg.serving_on),
                 jnp.int32(0)))
            return s, _telem_finalize(a)

        if telemetry_every <= 1:
            def go(state):
                return run_window(state, n_steps)
        else:
            def go(state):
                return jax.lax.scan(
                    lambda s, _: run_window(s, telemetry_every), state,
                    None, length=n_steps // telemetry_every)
    else:
        step = make_step(cfg, statics, scheduler, **kw)
        if check_on:
            raw_step = step

            def step(s, a):
                s, out = raw_step(s, a)
                invariants.check_state(cfg, statics, s)
                return s, out

        def body(s, _):
            return step(s, jnp.int32(-1))

        def accum_body(carry, _):
            s, acc = carry
            s, out = step(s, jnp.int32(-1))
            return (s, _telem_update(
                acc, out, resilience_on=cfg.resilience_on,
                serving_on=cfg.serving_on)), None

        if summary_only:
            def go(state):
                (fs, acc), _ = jax.lax.scan(
                    accum_body,
                    (state, _telem_zero(cfg.resilience_on, cfg.serving_on)),
                    None, length=n_steps)
                return fs, _telem_finalize(acc)
        elif telemetry_every <= 1:
            def go(state):
                return jax.lax.scan(body, state, None, length=n_steps)
        else:
            def window(s, _):
                (s, acc), _ = jax.lax.scan(
                    accum_body,
                    (s, _telem_zero(cfg.resilience_on, cfg.serving_on)),
                    None, length=telemetry_every)
                return s, _telem_finalize(acc)

            def go(state):
                return jax.lax.scan(window, state, None,
                                    length=n_steps // telemetry_every)

    if check_on:
        from jax.experimental import checkify

        err, out = checkify.checkify(go)(state)
        err.throw()
        return out
    return go(state)


def run_segment(
    cfg: SimConfig,
    statics: Statics,
    state: SimState,
    acc: TelemetrySummary,
    n_ticks: int,
    scheduler: str | Policy = "fcfs",
    *,
    macro: bool = False,
    **kw,
) -> Tuple[SimState, TelemetrySummary]:
    """Advance ``n_ticks`` carrying a RAW ``TelemetrySummary`` accumulator.

    This is ``run_episode(summary_only=True)`` (or ``macro=True``) cut at
    an arbitrary tick boundary: the scan/while bodies are the exact same
    compiled programs, but the accumulator enters un-zeroed and leaves
    un-finalized, so a sequence of segments threaded through
    ``(state, acc)`` reproduces the single-call episode bit-for-bit —
    the host-level primitive snapshot/resume (checkpoint.episode) is
    built on. Seed ``acc`` with ``_telem_zero(cfg.resilience_on,
    cfg.serving_on)`` and apply ``_telem_finalize`` once after the last
    segment. Segment edges clamp the macro fast-forward exactly like
    ``telemetry_every`` window edges, so job/queue state and the PRNG
    stream stay bit-identical to the uninterrupted run (the skip-
    accounting diagnostics ``n_steps``/``macro_steps`` count the forced
    boundary breakpoints, same as windowed telemetry).

    The ``REPRO_CHECKIFY=1`` invariant harness instruments eager calls
    per committed step, exactly as in ``run_episode``.
    """
    from repro.utils import invariants

    check_on = invariants.enabled() and not isinstance(
        state.t, jax.core.Tracer)

    if macro:
        mstep = make_macro_step(cfg, statics, scheduler, **kw)
        if check_on:
            raw_mstep = mstep

            def mstep(s, a, n):
                s, a, took = raw_mstep(s, a, n)
                invariants.check_state(cfg, statics, s)
                return s, a, took

        def go(state, acc):
            def wcond(c):
                return c[2] < n_ticks

            def wbody(c):
                s, a, ticks = c
                s, a, took = mstep(s, a, n_ticks - ticks)
                return (s, a, ticks + took)

            s, a, _ = jax.lax.while_loop(
                wcond, wbody, (state, acc, jnp.int32(0)))
            return s, a
    else:
        step = make_step(cfg, statics, scheduler, **kw)
        if check_on:
            raw_step = step

            def step(s, a):
                s, out = raw_step(s, a)
                invariants.check_state(cfg, statics, s)
                return s, out

        def accum_body(carry, _):
            s, acc = carry
            s, out = step(s, jnp.int32(-1))
            return (s, _telem_update(
                acc, out, resilience_on=cfg.resilience_on,
                serving_on=cfg.serving_on)), None

        def go(state, acc):
            (fs, acc), _ = jax.lax.scan(
                accum_body, (state, acc), None, length=n_ticks)
            return fs, acc

    if check_on:
        from jax.experimental import checkify

        err, out = checkify.checkify(go)(state, acc)
        err.throw()
        return out
    return go(state, acc)


def summary_columns(state: SimState,
                    telemetry: TelemetrySummary | None = None) -> dict:
    """Column-wise ``summary``: a dict of float64 numpy arrays with one
    entry per replica, from replica-batched final states (leading replica
    axis on every leaf, e.g. ``run_fleet`` output). Also accepts an
    unbatched state, where every column is 0-d — ``summary`` is that
    special case. ONE device->host transfer covers the whole batch, and
    all per-replica reductions happen as numpy array ops, so
    ``fleet_summary`` on a 1024-replica sweep no longer spends its tail
    in a host-side Python loop over replicas."""
    s = jax.device_get(state)
    batched = np.ndim(s.t) == 1

    def f(a):
        return np.asarray(a, np.float64)

    def reduce_tail(a, op=np.sum):
        # reduce every axis except the replica axis (all axes when
        # unbatched) — covers per-job state axes and telemetry windows
        x = f(a)
        return op(x, axis=tuple(range(1, x.ndim)) if batched else None)

    n = np.maximum(f(s.n_completed), 1.0)
    cols = {
        "t_end_s": f(s.t),
        "completed": f(s.n_completed),
        "killed_by_failures": f(s.n_killed),
        "energy_kwh": f(s.energy_kwh),
        "it_energy_kwh": f(s.it_energy_kwh),
        "loss_energy_kwh": f(s.loss_energy_kwh),
        "cooling_energy_kwh": f(s.cool_energy_kwh),
        "carbon_kg": f(s.carbon_kg),
        "elec_cost_usd": f(s.elec_cost_usd),
        "mean_power_w": f(s.sum_power_w) / np.maximum(f(s.n_steps), 1.0),
        "mean_wait_s": f(s.sum_wait) / n,
        "mean_slowdown": f(s.sum_slowdown) / n,
        "gflops_per_watt": (
            f(s.flops_integral) / 3600.0 / 1000.0
            / np.maximum(f(s.energy_kwh), 1e-9)
        ),
        "avg_pue": f(s.energy_kwh) / np.maximum(f(s.it_energy_kwh), 1e-9),
        # thermal twin (core.thermal); with thermal_enabled off these
        # report the supply-temperature initial condition and 0
        "peak_rack_outlet_c": f(s.peak_rack_c),
        "thermal_throttle_s": f(s.thermal_throttle_s),
    }
    # resilience twin (core.faults): goodput vs throughput. "Useful" work
    # is the node-seconds of completed jobs; lost_node_seconds is what
    # kills destroyed (since-last-checkpoint for retries, whole jobs for
    # terminal failures). goodput_frac = useful / (useful + lost) — the
    # fraction of delivered node-seconds that produced finished jobs.
    useful = reduce_tail(
        (np.asarray(s.jstate) == DONE) * f(s.dur_est) * f(s.n_nodes))
    lost = f(s.lost_node_s)
    cols["lost_node_seconds"] = lost
    cols["jobs_failed_terminal"] = f(s.n_failed)
    cols["goodput_node_s"] = useful
    cols["goodput_frac"] = useful / np.maximum(useful + lost, 1e-9)
    # serving twin (core.serving): request accounting from the state
    # accumulators (zeros with serving off) + SLO quantiles from the
    # episode latency histogram. goodput_requests = completed mass that
    # met the SLO; shed/dropped are the terminal overload-ladder losses.
    n_req = np.maximum(f(s.srv_completed), 1e-9)
    cols["srv_arrived"] = f(s.srv_arrived)
    cols["srv_completed"] = f(s.srv_completed)
    cols["srv_shed"] = f(s.srv_shed)
    cols["srv_dropped"] = f(s.srv_dropped)
    cols["srv_retried"] = f(s.srv_retried)
    cols["srv_mean_latency_s"] = f(s.srv_lat_sum) / n_req
    cols["srv_slo_violation_frac"] = f(s.srv_slo_viol) / n_req
    cols["srv_goodput_requests"] = f(s.srv_completed) - f(s.srv_slo_viol)
    hist = f(s.srv_lat_hist)                    # (..., 8)
    tot = np.maximum(hist.sum(-1, keepdims=True), 1e-9)
    c = np.cumsum(hist, -1) / tot
    # bucket i spans serving_slo_s * [2^(i-4), 2^(i-3)); quantiles are
    # reported at the upper edge in SLO units (the summary has no cfg)
    edge = 2.0 ** (np.arange(8, dtype=np.float64) - 3.0)
    any_req = hist.sum(-1) > 0.0                # no completions -> 0.0
    cols["srv_p50_latency_x_slo"] = np.where(
        any_req, edge[np.argmax(c >= 0.5, axis=-1)], 0.0)
    cols["srv_p99_latency_x_slo"] = np.where(
        any_req, edge[np.argmax(c >= 0.99, axis=-1)], 0.0)
    if telemetry is not None:
        # macro-stepping skip accounting (satellite of the macro engine):
        # how much of the episode the engine fast-forwarded. Windowed
        # telemetry (telemetry_every=k) arrives with a window axis after
        # the replica one — summing it recovers the episode totals.
        tl = jax.device_get(telemetry)
        ticks = reduce_tail(tl.n_steps)
        full = reduce_tail(tl.macro_steps)
        cols["ticks_simulated"] = ticks
        cols["macro_steps_taken"] = full
        cols["macro_skip_ratio"] = ticks / np.maximum(full, 1.0)
        # cooling-plant telemetry (tick-weighted across windows)
        cols["mean_cop"] = (
            reduce_tail(f(tl.mean_cop) * f(tl.n_steps))
            / np.maximum(ticks, 1.0))
        cols["max_rack_outlet_c"] = reduce_tail(tl.max_rack_c, op=np.max)
    return cols


def summary(state: SimState,
            telemetry: TelemetrySummary | None = None) -> dict:
    """Scalar episode summary of one (unbatched) final state — the 0-d
    special case of ``summary_columns``."""
    return {k: float(v)
            for k, v in summary_columns(state, telemetry).items()}
