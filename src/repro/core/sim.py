"""The RAPS trace-replay / rescheduling simulator step and episode runner.

``make_step(cfg, statics, scheduler)`` closes over the static datacenter
description and returns a pure jit-able ``step(state, action) ->
(state, StepOut)``; an episode is ``lax.scan`` over steps, so the whole
digital twin vmaps across thousands of parallel datacenters for RL.

Scheduling is a two-stage engine: job *selection*
(``core.schedulers``: replay/fcfs/sjf/priority/easy, or the external RL
action) x node *placement* (``core.placement``: first_fit/best_fit/
spread/partition/green). ``scheduler`` is either a policy name (eager,
one Python branch baked into the trace) or a ``placement.Policy`` of
traced (select_id, place_id) int32s resolved by ``lax.switch`` inside the
compiled step — pass the Policy as a jit *argument* and one compilation
serves the entire selection x placement grid.

Step order (matches RAPS' fixed-dt loop):
  1. node failures / repairs (MTBF process)       [optional]
  2. job completions -> free resources, stats
  3. scheduling: up to `starts_per_step` dispatch attempts via the policy
  4. progress running jobs (network-congestion-aware rate)
  5. power chain + energy/carbon/stat accumulation
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.sim import SimConfig
from repro.core import placement as plc
from repro.core import schedulers as sched
from repro.core.network import congestion_slowdown
from repro.core.placement import Policy
from repro.core.power import PowerOut, compute_power
from repro.scenarios.events import power_cap_at
from repro.scenarios.signals import eval_signal
from repro.core.state import (
    DONE,
    EMPTY,
    NRES,
    QUEUED,
    RUNNING,
    SimState,
    Statics,
)


class StepOut(NamedTuple):
    facility_w: jax.Array
    it_w: jax.Array
    pue: jax.Array
    util: jax.Array            # fraction of up-node cores|gpus busy
    queue_len: jax.Array
    running: jax.Array
    completed_now: jax.Array
    energy_kwh_step: jax.Array
    carbon_kg_step: jax.Array
    net_load: jax.Array
    reward: jax.Array
    # grid-signal telemetry (scenario engine)
    carbon_gkwh: jax.Array     # instantaneous grid carbon intensity
    price_usd_kwh: jax.Array   # instantaneous electricity price
    power_cap_w: jax.Array     # effective facility cap (0 = uncapped)
    cost_usd_step: jax.Array   # electricity cost of this step
    throttle: jax.Array        # DVFS clock fraction applied [floor, 1]


# ---------------------------------------------------------------------------
def _apply_failures(cfg: SimConfig, state: SimState) -> SimState:
    if cfg.node_mtbf_hours <= 0:
        return state
    key, k1 = jax.random.split(state.key)
    N = state.node_up.shape[0]
    p_fail = cfg.dt / (cfg.node_mtbf_hours * 3600.0)
    fails = jax.random.bernoulli(k1, p_fail, (N,)) & (state.node_up > 0.5)
    node_up = jnp.where(fails, 0.0, state.node_up)
    repair_t = jnp.where(fails, state.t + cfg.node_repair_hours * 3600.0,
                         state.repair_t)
    # repairs
    repaired = (node_up < 0.5) & (state.t >= repair_t)
    node_up = jnp.where(repaired, 1.0, node_up)

    # kill & requeue jobs touching failed nodes
    J, K = state.placement.shape
    place = state.placement
    on_failed = jnp.any(
        jnp.where(place >= 0, fails[jnp.where(place >= 0, place, 0)], False),
        axis=1,
    ) & (state.jstate == RUNNING)
    # release resources of killed jobs
    free = _release(state.free, state, on_failed)
    jstate = jnp.where(on_failed, QUEUED, state.jstate)
    work_left = jnp.where(on_failed, state.dur_est, state.work_left)
    placement = jnp.where(on_failed[:, None], -1, place)
    return state._replace(
        key=key, node_up=node_up, repair_t=repair_t, free=free,
        jstate=jstate, work_left=work_left, placement=placement,
        n_failures=state.n_failures + on_failed.astype(jnp.int32),
        n_killed=state.n_killed + jnp.sum(on_failed),
    )


def _release(free: jax.Array, state: SimState, mask: jax.Array) -> jax.Array:
    """Add back resources of jobs in `mask` (J,) to the free pool.

    Routed through ``power.scatter_add_nodes``: small configs get the
    dense one-hot contraction (under vmap the XLA scatter-add runs a
    generic per-env scatter loop on CPU, while the contraction is one
    batched matmul — this sits on the RL-rollout hot path, every
    completion sweep of every sub-step of every env)."""
    from repro.core.power import scatter_add_nodes

    place = state.placement
    valid = (place >= 0) & mask[:, None]
    amounts = state.req[:, :, None] * valid[None, :, :]      # (R,J,K)
    ids = jnp.where(valid, place, -1)
    return scatter_add_nodes(ids.reshape(-1), amounts.reshape(NRES, -1),
                             free.shape[1], base=free)


def _complete_jobs(cfg: SimConfig, state: SimState) -> Tuple[SimState, jax.Array]:
    done_now = (state.jstate == RUNNING) & (state.work_left <= 0.0)
    free = _release(state.free, state, done_now)
    wait = jnp.maximum(state.start_t - state.submit_t, 0.0)
    run = jnp.maximum(state.t - state.start_t, cfg.dt)
    slowdown = jnp.maximum((wait + run) / run, 1.0)
    n_done = jnp.sum(done_now)
    state = state._replace(
        free=free,
        jstate=jnp.where(done_now, DONE, state.jstate),
        end_t=jnp.where(done_now, state.t, state.end_t),
        placement=jnp.where(done_now[:, None], -1, state.placement),
        n_completed=state.n_completed + n_done,
        sum_wait=state.sum_wait + jnp.sum(jnp.where(done_now, wait, 0.0)),
        sum_slowdown=state.sum_slowdown + jnp.sum(jnp.where(done_now, slowdown, 0.0)),
    )
    return state, n_done


def _try_start(cfg: SimConfig, state: SimState, job: jax.Array,
               place_fn) -> SimState:
    """Attempt to place & start `job` via the placement stage `place_fn`
    (state, job) -> (row, ok); no-op when job < 0 or infeasible."""
    j = jnp.maximum(job, 0)
    row, ok = place_fn(state, j)
    ok = ok & (job >= 0) & (state.jstate[j] == QUEUED)
    valid = (row >= 0) & ok
    safe = jnp.where(valid, row, 0)
    amounts = state.req[:, j][:, None] * valid[None, :]      # (R,K)
    free = state.free.at[:, safe].add(-amounts, mode="drop")
    return state._replace(
        free=jnp.where(ok, free, state.free).reshape(state.free.shape),
        jstate=state.jstate.at[j].set(jnp.where(ok, RUNNING, state.jstate[j])),
        start_t=state.start_t.at[j].set(jnp.where(ok, state.t, state.start_t[j])),
        placement=state.placement.at[j].set(
            jnp.where(ok, jnp.where(valid, row, -1), state.placement[j])
        ),
    )


def make_step(
    cfg: SimConfig,
    statics: Statics,
    scheduler: str | Policy = "fcfs",
    *,
    placement: str | None = None,
    starts_per_step: int = 2,
    reward_weights: Tuple[float, ...] = (1.0, 1.0, 1.0, 0.05),
    use_power_kernel: bool = False,
):
    """Returns step(state, action) -> (state, StepOut).

    ``scheduler``: a selection name ('replay'|'fcfs'|'sjf'|'priority'|
    'easy'), 'rl' (external action-driven selection), 'none' (no dispatch
    at all — failures/completions/progress/power only; the RL env's idle
    sub-steps between agent decisions, where the pre-split step paid a
    full candidate-ranking + placement pass per sub-step for a guaranteed
    no-op), or a ``placement.Policy`` of traced (select_id, place_id)
    int32s — the policy-as-data mode where ``lax.switch`` resolves both
    stages inside one compiled step (the Policy carries the placement id,
    so combining it with an explicit ``placement=`` is a loud error).
    ``placement``: node-placement strategy name (``core.placement``) for
    the eager string/'rl' modes; default 'first_fit'.
    ``action``: int32 — for the 'rl' scheduler, index into
    ``rl_candidates`` (k = no-op at index k); ignored otherwise.
    reward_weights = (w_throughput, w_energy, w_carbon, w_queue[, w_cost]);
    w_cost scales the electricity-price penalty (default 0 — off).
    """
    policy_mode = isinstance(scheduler, Policy)
    if not policy_mode and scheduler not in ("rl", "none") \
            and scheduler not in sched.SCHEDULERS:
        raise KeyError(f"unknown scheduler {scheduler}")
    if policy_mode and placement is not None:
        raise ValueError(
            f"both a Policy scheduler and placement={placement!r} given — "
            "the Policy carries the placement id, so the string would be "
            "silently ignored; pass exactly one")
    if placement is None:
        placement = "first_fit"
    if placement not in plc.PLACEMENTS:
        raise KeyError(f"unknown placement {placement}")
    if len(reward_weights) not in (4, 5):
        raise ValueError("reward_weights must have 4 or 5 entries")
    w_thr, w_en, w_co2, w_q = reward_weights[:4]
    w_cost = reward_weights[4] if len(reward_weights) == 5 else 0.0

    if policy_mode:
        def place_fn(s, j):
            return plc.place_job(s, statics, j, scheduler.place)
    else:
        eager_place = plc.PLACEMENTS[placement]

        def place_fn(s, j):
            return eager_place(s, statics, j)

    def step(state: SimState, action: jax.Array) -> Tuple[SimState, StepOut]:
        state = state._replace(t=state.t + cfg.dt)
        state = _apply_failures(cfg, state)
        state, n_done = _complete_jobs(cfg, state)

        # --- dispatch
        if not policy_mode and scheduler == "none":
            pass    # idle sub-step: no selection, no placement
        elif not policy_mode and scheduler == "rl":
            cands = sched.rl_candidates(cfg, state)          # (k,)
            k = cands.shape[0]
            job = jnp.where(action < k, cands[jnp.clip(action, 0, k - 1)], -1)
            state = _try_start(cfg, state, job, place_fn)
        else:
            # single fori_loop wavefront: the jaxpr holds ONE copy of the
            # select+place body regardless of starts_per_step (the unrolled
            # loop grew trace size/compile time linearly with attempts).
            # Selection sees the placement backend's node eligibility
            # (PLACEMENT_MASKS registry, e.g. partition tags) so it never
            # picks a job placement rejects. Eligibility depends only on
            # part/node_type — loop-invariant, so it is computed once per
            # step, not per dispatch attempt.
            if policy_mode:
                node_mask = plc.placement_node_mask(state, statics,
                                                    scheduler.place)

                def select(c, s):
                    return sched.select_job(c, s, statics, scheduler.select,
                                            node_mask)
            else:
                eager_select = sched.SCHEDULERS[scheduler]
                mask_fn = plc.PLACEMENT_MASKS[placement]
                node_mask = None if mask_fn is None else mask_fn(state,
                                                                 statics)

                def select(c, s):
                    return eager_select(c, s, statics, node_mask)

            def dispatch(_, s: SimState) -> SimState:
                return _try_start(cfg, s, select(cfg, s), place_fn)

            state = jax.lax.fori_loop(0, starts_per_step, dispatch, state)

        # --- power chain (pre-throttle)
        p: PowerOut = compute_power(cfg, state, statics, use_kernel=use_power_kernel)

        # --- grid signals at t (scenario engine)
        scn = statics.scenario
        carbon_g = eval_signal(scn.carbon, state.t)          # gCO2/kWh
        price = eval_signal(scn.price, state.t)              # $/kWh
        cap_w = power_cap_at(scn.power_cap, state.t)         # W; 0 = uncapped

        # --- demand response: DVFS-throttle to the facility power cap
        # (DCFlex-style [3]; linear dynamic-power/progress model). The cap
        # is a traced value so scheduled events switch inside one compiled
        # step; `capped` gates the rescale exactly off when uncapped.
        capped = cap_w > 0.0
        idle_total = jnp.sum(statics.idle_w * state.node_up)
        dyn = jnp.maximum(p.it_w - idle_total, 0.0)
        # facility ~ it * overhead; solve idle + a*dyn <= cap/overhead
        overhead = p.facility_w / jnp.maximum(p.it_w, 1.0)
        cap_it = cap_w / jnp.maximum(overhead, 1e-6)
        throttle = jnp.clip(
            (cap_it - idle_total) / jnp.maximum(dyn, 1.0),
            cfg.throttle_floor, 1.0,
        )
        throttle = jnp.where(capped, throttle, 1.0)
        r = (idle_total + throttle * dyn) / jnp.maximum(p.it_w, 1.0)
        r = jnp.where(capped, r, 1.0)
        p = p._replace(
            it_w=p.it_w * r, input_w=p.input_w * r,
            cooling_w=p.cooling_w * r, facility_w=p.facility_w * r,
            gflops=p.gflops * throttle,
        )

        # --- progress (congestion- and throttle-aware)
        rate, net_load = congestion_slowdown(cfg, state, statics)
        rate = rate * throttle
        state = state._replace(work_left=state.work_left - rate * cfg.dt)
        dt_h = cfg.dt / 3600.0
        e_step = p.facility_w * dt_h / 1000.0                # kWh
        it_step = p.it_w * dt_h / 1000.0
        loss_step = (p.input_w - p.it_w) * dt_h / 1000.0
        cool_step = p.cooling_w * dt_h / 1000.0
        co2_step = e_step * carbon_g / 1000.0                # kg
        cost_step = e_step * price                           # $

        running = jnp.sum(state.jstate == RUNNING).astype(jnp.float32)
        queued = jnp.sum(sched.queued_mask(state)).astype(jnp.float32)
        up = jnp.maximum(jnp.sum(state.node_up), 1.0)
        busy = jnp.sum(
            (statics.capacity[0] - state.free[0]) / jnp.maximum(statics.capacity[0], 1e-6)
            * state.node_up
        )
        util = busy / up

        state = state._replace(
            energy_kwh=state.energy_kwh + e_step,
            it_energy_kwh=state.it_energy_kwh + it_step,
            loss_energy_kwh=state.loss_energy_kwh + loss_step,
            cool_energy_kwh=state.cool_energy_kwh + cool_step,
            carbon_kg=state.carbon_kg + co2_step,
            elec_cost_usd=state.elec_cost_usd + cost_step,
            flops_integral=state.flops_integral + p.gflops * cfg.dt,
            sum_power_w=state.sum_power_w + p.facility_w,
            n_steps=state.n_steps + 1.0,
        )

        # reward: throughput-positive, energy/carbon/queue-negative,
        # normalized to O(1) per step
        reward = (
            w_thr * n_done
            - w_en * e_step / jnp.maximum(cfg.n_nodes * 0.4 * dt_h, 1e-9) * 0.1
            - w_co2 * co2_step / jnp.maximum(cfg.n_nodes * 0.15 * dt_h, 1e-9) * 0.1
            - w_q * queued * 0.01
            - w_cost * cost_step
            / jnp.maximum(cfg.n_nodes * 0.4 * dt_h * cfg.price_mean_usd_kwh, 1e-9)
            * 0.1
        )

        out = StepOut(
            facility_w=p.facility_w, it_w=p.it_w, pue=p.pue, util=util,
            queue_len=queued, running=running, completed_now=n_done,
            energy_kwh_step=e_step, carbon_kg_step=co2_step,
            net_load=net_load, reward=reward,
            carbon_gkwh=carbon_g, price_usd_kwh=price, power_cap_w=cap_w,
            cost_usd_step=cost_step, throttle=throttle,
        )
        return state, out

    return step


class TelemetrySummary(NamedTuple):
    """Windowed reductions of ``StepOut`` — the constant-memory telemetry
    carried through the scan instead of stacking 16 fields per step.

    Totals are sums over the window; ``mean_*`` are per-step means and
    ``max_*`` maxima. ``n_steps`` is the window length.
    """

    # additive totals
    completed: jax.Array
    energy_kwh: jax.Array
    carbon_kg: jax.Array
    cost_usd: jax.Array
    reward: jax.Array
    # per-step means
    mean_facility_w: jax.Array
    mean_it_w: jax.Array
    mean_pue: jax.Array
    mean_util: jax.Array
    mean_queue_len: jax.Array
    mean_running: jax.Array
    mean_net_load: jax.Array
    mean_carbon_gkwh: jax.Array
    mean_price_usd_kwh: jax.Array
    mean_throttle: jax.Array
    # extremes
    max_facility_w: jax.Array
    max_queue_len: jax.Array
    n_steps: jax.Array


def _telem_zero() -> TelemetrySummary:
    z = jnp.float32(0.0)
    return TelemetrySummary(*([z] * len(TelemetrySummary._fields)))


def _telem_update(acc: TelemetrySummary, out: StepOut) -> TelemetrySummary:
    # mean_* fields hold running sums until _telem_finalize divides by n
    return TelemetrySummary(
        completed=acc.completed + out.completed_now,
        energy_kwh=acc.energy_kwh + out.energy_kwh_step,
        carbon_kg=acc.carbon_kg + out.carbon_kg_step,
        cost_usd=acc.cost_usd + out.cost_usd_step,
        reward=acc.reward + out.reward,
        mean_facility_w=acc.mean_facility_w + out.facility_w,
        mean_it_w=acc.mean_it_w + out.it_w,
        mean_pue=acc.mean_pue + out.pue,
        mean_util=acc.mean_util + out.util,
        mean_queue_len=acc.mean_queue_len + out.queue_len,
        mean_running=acc.mean_running + out.running,
        mean_net_load=acc.mean_net_load + out.net_load,
        mean_carbon_gkwh=acc.mean_carbon_gkwh + out.carbon_gkwh,
        mean_price_usd_kwh=acc.mean_price_usd_kwh + out.price_usd_kwh,
        mean_throttle=acc.mean_throttle + out.throttle,
        max_facility_w=jnp.maximum(acc.max_facility_w, out.facility_w),
        max_queue_len=jnp.maximum(acc.max_queue_len, out.queue_len),
        n_steps=acc.n_steps + 1.0,
    )


def _telem_finalize(acc: TelemetrySummary) -> TelemetrySummary:
    n = jnp.maximum(acc.n_steps, 1.0)
    return acc._replace(**{
        f: getattr(acc, f) / n
        for f in TelemetrySummary._fields if f.startswith("mean_")
    })


def run_episode(
    cfg: SimConfig,
    statics: Statics,
    state: SimState,
    n_steps: int,
    scheduler: str | Policy = "fcfs",
    *,
    telemetry_every: int = 1,
    summary_only: bool = False,
    **kw,
) -> Tuple[SimState, StepOut | TelemetrySummary]:
    """Scan `n_steps` of the twin under a non-RL policy.

    ``scheduler`` may be a policy name or a traced ``placement.Policy``
    (policy-as-data): jit a wrapper taking the Policy as an argument and
    the whole selection x placement grid shares ONE compiled executable.

    Telemetry modes (both static, so each compiles once):
      - default: stacked per-step ``StepOut`` — O(n_steps * 16) memory;
      - ``telemetry_every=k``: one ``TelemetrySummary`` per k-step window
        (stacked, length ``n_steps // k``) — O(n_steps/k) memory;
      - ``summary_only=True``: a single episode-wide ``TelemetrySummary``
        accumulated in the scan carry — O(1) memory in ``n_steps``.
    """
    step = make_step(cfg, statics, scheduler, **kw)

    def body(s, _):
        return step(s, jnp.int32(-1))

    def accum_body(carry, _):
        s, acc = carry
        s, out = step(s, jnp.int32(-1))
        return (s, _telem_update(acc, out)), None

    if summary_only:
        if telemetry_every > 1:
            raise ValueError(
                "summary_only=True is episode-wide; it conflicts with "
                f"telemetry_every={telemetry_every} (pick one)"
            )
        (fs, acc), _ = jax.lax.scan(
            accum_body, (state, _telem_zero()), None, length=n_steps
        )
        return fs, _telem_finalize(acc)

    if telemetry_every <= 1:
        return jax.lax.scan(body, state, None, length=n_steps)

    if n_steps % telemetry_every:
        raise ValueError(
            f"n_steps={n_steps} not divisible by telemetry_every={telemetry_every}"
        )

    def window(s, _):
        (s, acc), _ = jax.lax.scan(
            accum_body, (s, _telem_zero()), None, length=telemetry_every
        )
        return s, _telem_finalize(acc)

    return jax.lax.scan(window, state, None,
                        length=n_steps // telemetry_every)


def summary(state: SimState) -> dict:
    # one device->host transfer (the per-field float() path issued ~16
    # separate D2H copies; fleet_summary already batches the same way)
    s = jax.device_get(state)
    n = max(float(s.n_completed), 1.0)
    return {
        "t_end_s": float(s.t),
        "completed": float(s.n_completed),
        "killed_by_failures": float(s.n_killed),
        "energy_kwh": float(s.energy_kwh),
        "it_energy_kwh": float(s.it_energy_kwh),
        "loss_energy_kwh": float(s.loss_energy_kwh),
        "cooling_energy_kwh": float(s.cool_energy_kwh),
        "carbon_kg": float(s.carbon_kg),
        "elec_cost_usd": float(s.elec_cost_usd),
        "mean_power_w": float(s.sum_power_w) / max(float(s.n_steps), 1.0),
        "mean_wait_s": float(s.sum_wait) / n,
        "mean_slowdown": float(s.sum_slowdown) / n,
        "gflops_per_watt": (
            float(s.flops_integral) / 3600.0 / 1000.0
            / max(float(s.energy_kwh), 1e-9)
        ),
        "avg_pue": (
            float(s.energy_kwh) / max(float(s.it_energy_kwh), 1e-9)
        ),
    }
