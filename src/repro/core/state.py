"""Simulator state: fixed-shape pytrees so the whole datacenter twin is a
pure `step(state, action) -> state` function under jit/vmap/scan.

Job lifecycle: EMPTY -> QUEUED -> RUNNING -> DONE (slot then reusable),
plus the terminal FAILED state for jobs whose retry budget is exhausted
(``cfg.max_job_retries``; see ``core.faults``).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sim import SimConfig
from repro.scenarios.scenario import Scenario, default_scenario

EMPTY, QUEUED, RUNNING, DONE, FAILED = 0, 1, 2, 3, 4
NRES = 3  # cpu cores, gpus, mem_gb


class Statics(NamedTuple):
    """Per-node constants + telemetry bank; NOT carried through the scan.

    The telemetry bank comes in two layouts:

    - unbatched — ``cpu_trace``/``gpu_trace`` are (J, Q) and ``net_tx`` is
      (J,): one workload, ``SimState.workload`` is ignored;
    - banked — a leading workload axis W ((W, J, Q) / (W, J)): ONE shared
      bank serves every vmapped replica/env, and each ``SimState`` selects
      its slice through the traced ``workload`` id. Trace lookups
      (``core.power.job_utilization``, ``core.network``) gather through the
      id, so per-env state stays O(sim), not O(bank).
    """

    capacity: jax.Array        # (NRES, N)
    node_type: jax.Array       # (N,) int32
    idle_w: jax.Array          # (N,)
    cpu_dyn_w: jax.Array       # (N,)
    gpu_dyn_w: jax.Array       # (N,)
    node_max_w: jax.Array      # (N,)
    peak_gflops: jax.Array     # (N,)
    # thermal twin topology (core.thermal): which rack each node sits in,
    # each rack's steady-state thermal resistance [degC/W] derived from the
    # design delta-T at nameplate, and the rack IT nameplate itself
    node_rack: jax.Array       # (N,) int32 in [0, R)
    rack_r_th: jax.Array       # (R,) degC per W of rack heat
    rack_cap_w: jax.Array      # (R,) sum of member node_max_w
    # telemetry bank: per-job utilization profiles at trace-quanta resolution
    cpu_trace: jax.Array       # (J, Q) in [0,1], or (W, J, Q) banked
    gpu_trace: jax.Array       # (J, Q) / (W, J, Q)
    net_tx: jax.Array          # (J,) GB/s per job, or (W, J) banked
    # grid context: carbon/price/wetbulb signals + power-cap events
    scenario: Scenario


class SimState(NamedTuple):
    t: jax.Array               # scalar f32 seconds
    key: jax.Array             # PRNG key
    # nodes
    free: jax.Array            # (NRES, N)
    node_up: jax.Array         # (N,) f32 {0,1}
    repair_t: jax.Array        # (N,) time at which a down node returns
    # job table
    jstate: jax.Array          # (J,) int32
    submit_t: jax.Array        # (J,)
    start_t: jax.Array         # (J,)
    end_t: jax.Array           # (J,)
    dur_est: jax.Array         # (J,) requested walltime [s]
    work_left: jax.Array       # (J,) remaining work [s of unimpeded progress]
    n_nodes: jax.Array         # (J,) int32
    req: jax.Array             # (NRES, J) per-node demand
    part: jax.Array            # (J,) int32 partition tag = node-type index a
    #                            job belongs to; -1 = any (no partition)
    priority: jax.Array        # (J,)
    placement: jax.Array       # (J, K) int32 node ids; -1 = unused slot
    n_failures: jax.Array      # (J,) int32 restarts due to node failures
    # accumulators
    energy_kwh: jax.Array      # facility-side
    it_energy_kwh: jax.Array
    loss_energy_kwh: jax.Array  # rectification+conversion losses
    cool_energy_kwh: jax.Array
    carbon_kg: jax.Array
    elec_cost_usd: jax.Array   # facility energy x price signal
    flops_integral: jax.Array  # GFLOP delivered (utilization-weighted)
    n_completed: jax.Array
    n_killed: jax.Array
    sum_wait: jax.Array
    sum_slowdown: jax.Array
    sum_power_w: jax.Array     # for mean power
    n_steps: jax.Array
    # thermal twin carry (core.thermal): per-rack outlet temps (first-order
    # RC lag) + episode accumulators. Present even with thermal_enabled
    # off — the pytree structure must not depend on the model flag — but
    # then never written after init.
    rack_outlet_c: jax.Array   # (R,)
    thermal_throttle_s: jax.Array  # seconds with any rack derated
    peak_rack_c: jax.Array     # running max outlet temp
    # resilience twin carry (core.faults): event-sampled absolute failure
    # times (inf with faults off — exact macro breakpoints, zero per-tick
    # PRNG draws), per-job checkpoint intervals, the current
    # degradation-ladder level, and lost-work accounting. Present even
    # with resilience off (pytree structure is flag-independent) but then
    # never written after init.
    next_fail_t: jax.Array     # (N,) absolute next node-fault time [s]
    rack_fail_t: jax.Array     # (R,) absolute next rack-fault time [s]
    ckpt_interval: jax.Array   # (J,) checkpoint period [s]; <=0 = none
    degrade_level: jax.Array   # scalar int32 ladder level (0..4)
    lost_node_s: jax.Array     # node-seconds of killed/evicted progress
    n_failed: jax.Array        # jobs gone terminal FAILED
    # serving twin carry (core.serving): fluid request mass per attempt
    # tier, backoff-retry buckets with absolute re-injection times, the
    # autoscaled inference pool (wake clock is an absolute time — an
    # exact macro breakpoint), and SLO accounting accumulators. Present
    # even with serving off (pytree structure is flag-independent) but
    # then never written after init.
    srv_queue: jax.Array       # (B+1,) queued mass per attempt tier
    srv_inflight: jax.Array    # in-service request mass
    srv_retry_q: jax.Array     # (B+1,) mass waiting out backoff per tier
    srv_retry_t: jax.Array     # (B+1,) absolute re-injection times (inf)
    srv_active: jax.Array      # awake serving nodes
    srv_wake_n: jax.Array      # nodes mid-wake
    srv_wake_t: jax.Array      # absolute wake completion time (inf)
    srv_target: jax.Array      # autoscale target (RL action)
    srv_admit_thresh: jax.Array  # admitted queue fraction (RL action)
    srv_arrived: jax.Array     # request-mass accumulators
    srv_completed: jax.Array
    srv_shed: jax.Array        # terminal: queue-cap overflow
    srv_dropped: jax.Array     # terminal: retry budget exhausted
    srv_retried: jax.Array
    srv_slo_viol: jax.Array    # completed mass over the SLO
    srv_lat_sum: jax.Array     # mass-weighted latency integral [req*s]
    srv_lat_hist: jax.Array    # (8,) completion mass per log-2 SLO bucket
    # which workload this replica runs: index into a banked Statics trace
    # bank ((W, J, Q) leading axis); ignored when the bank is unbatched.
    # Scalar int32 — O(1) per env, vs. the O(J*Q) per-env bank copy the
    # pre-bank-indexed env carried.
    workload: jax.Array


def build_statics(
    cfg: SimConfig,
    trace_bank: Dict[str, Any] | None = None,
    scenario: Scenario | None = None,
) -> Statics:
    """Expand per-type node constants into per-node arrays."""
    caps, types, idle, cdyn, gdyn, nmax, gflops = [], [], [], [], [], [], []
    for ti, t in enumerate(cfg.node_types):
        for _ in range(t.count):
            caps.append([t.cpu_cores, t.gpus, t.mem_gb])
            types.append(ti)
            idle.append(t.idle_w + t.gpus * t.gpu_idle_w)
            cdyn.append(t.cpu_dyn_w)
            gdyn.append(t.gpus * t.gpu_dyn_w)
            nmax.append(t.idle_w + t.gpus * t.gpu_idle_w + t.cpu_dyn_w + t.gpus * t.gpu_dyn_w)
            gflops.append(t.peak_gflops)
    J = cfg.max_jobs
    if trace_bank is None:
        q = 8
        trace_bank = {
            "cpu": np.zeros((J, q), np.float32),
            "gpu": np.zeros((J, q), np.float32),
            "net_tx": np.zeros((J,), np.float32),
        }
    # rack topology: consecutive index blocks (nodes are emitted type-major,
    # so racks are type-homogeneous except at type boundaries); R_th per
    # rack from the design delta-T at the rack's IT nameplate
    node_rack = np.arange(cfg.n_nodes, dtype=np.int32) // cfg.nodes_per_rack
    rack_cap = np.zeros((cfg.n_racks,), np.float32)
    np.add.at(rack_cap, node_rack, np.array(nmax, np.float32))
    rack_r_th = cfg.rack_dt_full_load_c / np.maximum(rack_cap, 1.0)
    return Statics(
        capacity=jnp.asarray(np.array(caps, np.float32).T),
        node_type=jnp.asarray(np.array(types, np.int32)),
        idle_w=jnp.asarray(np.array(idle, np.float32)),
        cpu_dyn_w=jnp.asarray(np.array(cdyn, np.float32)),
        gpu_dyn_w=jnp.asarray(np.array(gdyn, np.float32)),
        node_max_w=jnp.asarray(np.array(nmax, np.float32)),
        peak_gflops=jnp.asarray(np.array(gflops, np.float32)),
        node_rack=jnp.asarray(node_rack),
        rack_r_th=jnp.asarray(rack_r_th),
        rack_cap_w=jnp.asarray(rack_cap),
        cpu_trace=jnp.asarray(trace_bank["cpu"], jnp.float32),
        gpu_trace=jnp.asarray(trace_bank["gpu"], jnp.float32),
        net_tx=jnp.asarray(trace_bank["net_tx"], jnp.float32),
        scenario=scenario if scenario is not None else default_scenario(cfg),
    )


def init_state(cfg: SimConfig, statics: Statics, key: jax.Array) -> SimState:
    from repro.core.thermal import supply_temp
    from repro.scenarios.signals import eval_signal

    N = cfg.n_nodes
    J = cfg.max_jobs
    K = cfg.max_nodes_per_job
    f = jnp.float32
    zJ = jnp.zeros((J,), f)
    # racks start at the cooling supply temperature (the idle steady state
    # sans heat); the RC update pulls them toward the loaded steady state
    supply0 = supply_temp(cfg, eval_signal(statics.scenario.wetbulb, f(0.0)))
    # event-sampled fault clocks: absolute exponential first-failure times.
    # Python-gated on the MTBF knobs so fault-free configs consume zero
    # PRNG (the stored key — and thus every downstream draw — is unchanged
    # vs. pre-resilience builds).
    next_fail = jnp.full((N,), jnp.inf, f)
    rack_fail = jnp.full((cfg.n_racks,), jnp.inf, f)
    if cfg.node_mtbf_hours > 0:
        key, kn = jax.random.split(key)
        next_fail = jax.random.exponential(kn, (N,)) * f(
            cfg.node_mtbf_hours * 3600.0)
    if cfg.rack_mtbf_hours > 0:
        key, kr = jax.random.split(key)
        rack_fail = jax.random.exponential(kr, (cfg.n_racks,)) * f(
            cfg.rack_mtbf_hours * 3600.0)
    return SimState(
        t=f(0.0),
        key=key,
        free=statics.capacity,
        node_up=jnp.ones((N,), f),
        repair_t=jnp.zeros((N,), f),
        jstate=jnp.zeros((J,), jnp.int32),
        submit_t=zJ,
        start_t=zJ,
        end_t=zJ,
        dur_est=zJ,
        work_left=zJ,
        n_nodes=jnp.zeros((J,), jnp.int32),
        req=jnp.zeros((NRES, J), f),
        part=-jnp.ones((J,), jnp.int32),
        priority=zJ,
        placement=-jnp.ones((J, K), jnp.int32),
        n_failures=jnp.zeros((J,), jnp.int32),
        energy_kwh=f(0.0),
        it_energy_kwh=f(0.0),
        loss_energy_kwh=f(0.0),
        cool_energy_kwh=f(0.0),
        carbon_kg=f(0.0),
        elec_cost_usd=f(0.0),
        flops_integral=f(0.0),
        n_completed=f(0.0),
        n_killed=f(0.0),
        sum_wait=f(0.0),
        sum_slowdown=f(0.0),
        sum_power_w=f(0.0),
        n_steps=f(0.0),
        rack_outlet_c=supply0 * jnp.ones((cfg.n_racks,), f),
        thermal_throttle_s=f(0.0),
        peak_rack_c=supply0,
        next_fail_t=next_fail,
        rack_fail_t=rack_fail,
        ckpt_interval=jnp.full((J,), f(cfg.ckpt_interval_s)),
        degrade_level=jnp.int32(0),
        lost_node_s=f(0.0),
        n_failed=f(0.0),
        srv_queue=jnp.zeros((cfg.serving_max_retries + 1,), f),
        srv_inflight=f(0.0),
        srv_retry_q=jnp.zeros((cfg.serving_max_retries + 1,), f),
        srv_retry_t=jnp.full((cfg.serving_max_retries + 1,), jnp.inf, f),
        srv_active=f(float(cfg.serving_nodes)),
        srv_wake_n=f(0.0),
        srv_wake_t=f(jnp.inf),
        srv_target=f(float(cfg.serving_nodes)),
        srv_admit_thresh=f(cfg.serving_admit_thresh),
        srv_arrived=f(0.0),
        srv_completed=f(0.0),
        srv_shed=f(0.0),
        srv_dropped=f(0.0),
        srv_retried=f(0.0),
        srv_slo_viol=f(0.0),
        srv_lat_sum=f(0.0),
        srv_lat_hist=jnp.zeros((8,), f),
        workload=jnp.int32(0),
    )


def load_jobs(state: SimState, jobs: Dict[str, np.ndarray],
              *, validate: str = "strict") -> SimState:
    """Install a workload (from the trace loader or synthesizer) into the
    job table. ``jobs`` fields: submit_t, dur, n_nodes, req (NRES, J'),
    priority, optionally ``part`` (int32 node-type index per job;
    -1 = any — the tag the ``partition`` placement enforces), and
    optionally ``ckpt_interval`` (per-job checkpoint period [s] overriding
    ``cfg.ckpt_interval_s``; <=0 = no checkpoints); J' <= max_jobs.

    The jobs dict is validated (``data.validate.validate_jobs``) before
    touching the table: a NaN duration or negative request would
    otherwise corrupt every downstream accumulator silently. ``validate``
    is ``"strict"`` (default; raises ``TraceValidationError`` naming the
    offending job indices), ``"repair"`` (drops bad jobs), or ``"off"``.
    Traced inputs (e.g. a jobs dict built inside jit) skip validation —
    host-level checks cannot see tracer values.
    """
    traced = any(
        isinstance(v, jax.core.Tracer) for v in jax.tree.leaves(jobs))
    if validate != "off" and not traced:
        from repro.data.validate import validate_jobs

        jobs, _ = validate_jobs(jobs, mode=validate)
    J = state.jstate.shape[0]
    n = len(jobs["submit_t"])
    assert n <= J, f"workload has {n} jobs > max_jobs {J}"
    sl = slice(0, n)
    if "ckpt_interval" in jobs:
        state = state._replace(ckpt_interval=state.ckpt_interval.at[sl].set(
            jnp.asarray(jobs["ckpt_interval"], jnp.float32)))
    return state._replace(
        jstate=state.jstate.at[sl].set(QUEUED),
        submit_t=state.submit_t.at[sl].set(jnp.asarray(jobs["submit_t"], jnp.float32)),
        dur_est=state.dur_est.at[sl].set(jnp.asarray(jobs["dur"], jnp.float32)),
        work_left=state.work_left.at[sl].set(jnp.asarray(jobs["dur"], jnp.float32)),
        n_nodes=state.n_nodes.at[sl].set(jnp.asarray(jobs["n_nodes"], jnp.int32)),
        req=state.req.at[:, sl].set(jnp.asarray(jobs["req"], jnp.float32)),
        part=state.part.at[sl].set(jnp.asarray(
            jobs.get("part", -np.ones(n)), jnp.int32)),
        priority=state.priority.at[sl].set(
            jnp.asarray(jobs.get("priority", np.zeros(n)), jnp.float32)
        ),
    )
