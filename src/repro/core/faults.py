"""Hierarchical, macro-compatible fault engine (docs/resilience.md).

Replaces the old inline per-tick Bernoulli failure sweep with
*event-sampled* fault clocks: ``SimState.next_fail_t`` (per node) and
``SimState.rack_fail_t`` (per rack — a cooling-loop/PDU fault downs the
whole rack at once) hold ABSOLUTE exponential next-failure times, redrawn
only when they fire. Scenario-scheduled grid brownouts / maintenance
windows (``scenarios.events.OutageSchedule``) add deterministic forced
outages and degradation levels on top.

Why event-sampled: every fault is now an exact, predictable breakpoint
(``next_fault_event``) that ``core.sim.quiet_horizon`` folds into the
macro-stepping segment bound, and the PRNG key advances ONLY on ticks
where a clock actually fires — so fast-forwarded quiet ticks consume
zero randomness and ``macro=True`` stays bit-identical (state + PRNG
stream) to per-tick stepping with faults on. The old Bernoulli sweep had
to be replayed per tick during fast-forward, forfeiting the macro
speedup exactly when faults were enabled; it also handed
``jax.random.bernoulli`` an unclamped ``dt/mtbf`` probability that
exceeded 1 for coarse ``dt`` against short MTBFs. Both problems vanish
with the clock formulation (an exponential inter-arrival time is valid
at any ``dt``).

Job resilience semantics on a kill (``apply_faults``):

- restart from the last simulated checkpoint: ``work_left`` rewinds to
  ``dur_est - ckpt_kept`` (progress floored to the checkpoint grid), not
  all the way to ``dur_est``; the periodic checkpoint-write cost is
  charged continuously as a progress drag (``ckpt_drag``, consumed by
  the accounting tail) so power burns at full rate while wall-clock
  progress slows;
- retry budget: a job killed more than ``cfg.max_job_retries`` times
  goes terminal ``FAILED`` (0 = unbounded, the legacy rule);
- requeue backoff: retried jobs wait ``requeue_backoff_s * mult**(n-1)``
  before re-eligibility, implemented by advancing ``submit_t`` — which
  reuses the arrival-breakpoint and ``queued_mask`` machinery untouched;
- lost-work accounting: ``lost_node_s`` integrates the node-seconds of
  progress destroyed by kills (since-last-checkpoint for retries, the
  whole job for terminal failures) — the goodput-vs-throughput gap
  surfaced by ``summary()``.

The graceful-degradation ladder (throttle -> dispatch-gate -> drain ->
checkpoint-evict) is a scalar level: the max of the RL-schedulable
``SimState.degrade_level`` and any active outage window's forced level.
Levels >= ``LVL_THROTTLE`` clock-throttle dynamic power and progress,
>= ``LVL_GATE`` block new dispatch (via ``make_step``'s dispatch view),
and ``LVL_EVICT`` checkpoint-evicts running jobs (requeued with progress
intact — the graceful alternative to losing since-checkpoint work when
the thermal/power emergency kills nodes for real).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.sim import SimConfig
from repro.core.state import FAILED, NRES, QUEUED, RUNNING, SimState, Statics
from repro.scenarios.events import (
    next_outage_event,
    outage_down,
    outage_level_at,
)

# graceful-degradation ladder levels (ordered: each includes the previous)
LVL_NORMAL, LVL_THROTTLE, LVL_GATE, LVL_DRAIN, LVL_EVICT = 0, 1, 2, 3, 4

_INF = jnp.float32(jnp.inf)


def effective_level(cfg: SimConfig, state: SimState,
                    statics: Statics) -> jax.Array:
    """Current ladder level (int32 scalar): the max of the schedulable
    ``state.degrade_level`` and any active outage window's forced level.
    Within a quiet macro segment this is constant — outage edges are
    breakpoints and ``degrade_level`` only changes at decision ticks."""
    lvl = state.degrade_level if cfg.degrade_enabled else jnp.int32(0)
    if cfg.outages_enabled:
        lvl = jnp.maximum(
            lvl, outage_level_at(statics.scenario.outages, state.t))
    return lvl


def degrade_clock(cfg: SimConfig, lvl: jax.Array) -> jax.Array:
    """Clock fraction the ladder imposes on dynamic power + progress:
    1.0 below THROTTLE, ``degrade_throttle_frac`` at THROTTLE/GATE,
    the DVFS floor at DRAIN and above (run out the checkpoints, burn as
    little as possible)."""
    return jnp.where(
        lvl >= LVL_DRAIN, jnp.float32(cfg.throttle_floor),
        jnp.where(lvl >= LVL_THROTTLE,
                  jnp.float32(cfg.degrade_throttle_frac), jnp.float32(1.0)))


def ckpt_kept(state: SimState, prog: jax.Array) -> jax.Array:
    """(J,) work surviving a kill: progress floored to the job's
    checkpoint grid (0 when the job never checkpoints — the legacy
    restart-from-zero rule)."""
    iv = state.ckpt_interval
    return jnp.where(iv > 0.0,
                     jnp.floor(prog / jnp.maximum(iv, 1e-9)) * iv, 0.0)


def ckpt_drag(cfg: SimConfig, state: SimState) -> jax.Array:
    """(J,) progress-rate multiplier charging the periodic checkpoint
    write: of every ``interval + overhead`` seconds of wall clock, only
    ``interval`` advance the job — power keeps burning throughout, so
    energy-per-completed-job rises with checkpoint frequency."""
    iv = state.ckpt_interval
    ov = jnp.float32(cfg.ckpt_overhead_s)
    return jnp.where(iv > 0.0, iv / (iv + ov), 1.0)


def release_jobs(free: jax.Array, state: SimState,
                 mask: jax.Array) -> jax.Array:
    """Add back resources of jobs in `mask` (J,) to the free pool.

    Routed through ``power.scatter_add_nodes``: small configs get the
    dense one-hot contraction (under vmap the XLA scatter-add runs a
    generic per-env scatter loop on CPU, while the contraction is one
    batched matmul — this sits on the RL-rollout hot path, every
    completion sweep of every sub-step of every env)."""
    from repro.core.power import scatter_add_nodes

    place = state.placement
    valid = (place >= 0) & mask[:, None]
    amounts = state.req[:, :, None] * valid[None, :, :]      # (R,J,K)
    ids = jnp.where(valid, place, -1)
    return scatter_add_nodes(ids.reshape(-1), amounts.reshape(NRES, -1),
                             free.shape[1], base=free)


def next_fault_event(cfg: SimConfig, state: SimState, statics: Statics,
                     t: jax.Array) -> jax.Array:
    """Earliest fault breakpoint strictly after ``t`` (``inf`` when
    none): the next node/rack clock crossing or outage-window edge.
    ``apply_faults`` keeps every clock strictly in the future (fires
    redraw, absorbed fires included), so the ``> t`` guard never hides a
    pending event — this is what makes faults exact macro breakpoints."""
    nxt = _INF
    if cfg.node_mtbf_hours > 0:
        nxt = jnp.minimum(nxt, jnp.min(jnp.where(
            state.next_fail_t > t, state.next_fail_t, _INF)))
    if cfg.rack_mtbf_hours > 0:
        nxt = jnp.minimum(nxt, jnp.min(jnp.where(
            state.rack_fail_t > t, state.rack_fail_t, _INF)))
    if cfg.outages_enabled:
        nxt = jnp.minimum(
            nxt, next_outage_event(statics.scenario.outages, t))
    return nxt


def _where_key(pred, new, old):
    """Select between PRNG keys (typed or raw uint32) with a predicate."""
    if jnp.issubdtype(jnp.result_type(old), jax.dtypes.prng_key):
        return jax.random.wrap_key_data(
            jnp.where(pred, jax.random.key_data(new),
                      jax.random.key_data(old)),
            impl=jax.random.key_impl(old))
    return jnp.where(pred, new, old)


def apply_faults(
    cfg: SimConfig, state: SimState, statics: Statics
) -> Tuple[SimState, jax.Array, jax.Array]:
    """One fault tick: fire due clocks, apply forced outages, repair,
    kill/evict/requeue jobs. Returns ``(state, killed_now, lost_now)``
    where ``killed_now`` counts jobs killed by node loss this tick and
    ``lost_now`` the node-seconds of progress destroyed.

    Invariants the macro engine relies on (tests/test_faults.py):

    - every clock in the returned state is strictly future (fires are
      redrawn past their repair, absorbed fires on already-down nodes
      included), so ``next_fault_event`` sees every pending event;
    - the PRNG key advances ONLY when a clock fires (forced outages and
      repairs are deterministic), so quiet ticks consume zero randomness;
    - on a tick with no crossing, no repair due and no window edge, the
      whole update is a fixpoint — fast-forwarding past such ticks is
      exact. Mid-window repairs are impossible by construction: a down
      node inside an active maintenance window has ``repair_t`` maxed to
      the window end at the window-start breakpoint, so nodes never flap
      up inside a window (which would be an unpredictable breakpoint).
    """
    t = state.t
    f32 = jnp.float32
    N = state.node_up.shape[0]
    R = state.rack_fail_t.shape[0]
    up = state.node_up > 0.5
    node_on = cfg.node_mtbf_hours > 0
    rack_on = cfg.rack_mtbf_hours > 0

    # --- deterministic outage context (no RNG)
    if cfg.outages_enabled:
        forced, forced_end = outage_down(
            statics.scenario.outages, t, statics.node_rack)
    else:
        forced = jnp.zeros((N,), bool)
        forced_end = jnp.zeros((N,), f32)
    lvl = effective_level(cfg, state, statics)

    # --- event-sampled clock crossings + redraws. Fires on already-down
    # nodes are "absorbed": the node stays down, its repair may extend,
    # and the clock still redraws — keeping next_fail_t always future.
    node_cross = (t >= state.next_fail_t) if node_on \
        else jnp.zeros((N,), bool)
    rack_fire = (t >= state.rack_fail_t) if rack_on \
        else jnp.zeros((R,), bool)

    key = state.key
    next_fail_t, rack_fail_t = state.next_fail_t, state.rack_fail_t
    repair_draw = rack_repair_draw = None
    if node_on or rack_on:
        any_fire = jnp.any(node_cross) | jnp.any(rack_fire)
        nk, *ks = jax.random.split(state.key,
                                   1 + 2 * (int(node_on) + int(rack_on)))
        ks = iter(ks)
        if node_on:
            repair_draw = jax.random.exponential(next(ks), (N,)) * f32(
                cfg.node_repair_hours * 3600.0)
            fail_draw = jax.random.exponential(next(ks), (N,)) * f32(
                cfg.node_mtbf_hours * 3600.0)
            next_fail_t = jnp.where(
                node_cross, t + repair_draw + fail_draw, state.next_fail_t)
        if rack_on:
            rack_repair_draw = jax.random.exponential(next(ks), (R,)) * f32(
                cfg.rack_repair_hours * 3600.0)
            rack_fail_draw = jax.random.exponential(next(ks), (R,)) * f32(
                cfg.rack_mtbf_hours * 3600.0)
            rack_fail_t = jnp.where(
                rack_fire, t + rack_repair_draw + rack_fail_draw,
                state.rack_fail_t)
        key = _where_key(any_fire, nk, state.key)

    member_fire = rack_fire[statics.node_rack] if rack_on \
        else jnp.zeros((N,), bool)

    # --- repair times: max over the firing causes, merged with the
    # node's standing repair if it is already down (stale repair_t of UP
    # nodes must not leak in). Forced windows extend ALL down members to
    # at least the window end, so no node flaps up mid-window.
    old_eff = jnp.where(up, 0.0, state.repair_t)
    cand = jnp.zeros((N,), f32)
    if node_on:
        cand = jnp.where(node_cross, t + repair_draw, cand)
    if rack_on:
        cand = jnp.maximum(cand, jnp.where(
            member_fire, t + rack_repair_draw[statics.node_rack], 0.0))
    if cfg.outages_enabled:
        cand = jnp.maximum(cand, jnp.where(forced, forced_end, 0.0))
    repair_t = jnp.where(cand > 0.0, jnp.maximum(old_eff, cand),
                         state.repair_t)

    # --- downs first, then repairs (the legacy ordering)
    down_mask = node_cross | member_fire | forced
    newly_down = down_mask & up
    node_up = jnp.where(down_mask, 0.0, state.node_up)
    repaired = (node_up < 0.5) & (t >= repair_t)
    node_up = jnp.where(repaired, 1.0, node_up)

    # --- kill running jobs touching newly-downed nodes; checkpoint-evict
    # the rest when the ladder says so
    place = state.placement
    valid = place >= 0
    on_down = jnp.any(
        jnp.where(valid, newly_down[jnp.where(valid, place, 0)], False),
        axis=1,
    ) & (state.jstate == RUNNING)
    if cfg.degrade_enabled or cfg.outages_enabled:
        evict = (state.jstate == RUNNING) & ~on_down & (lvl >= LVL_EVICT)
    else:
        evict = jnp.zeros_like(on_down)
    free = release_jobs(state.free, state, on_down | evict)

    # --- checkpoint-restart accounting: killed jobs rewind to their last
    # checkpoint (the since-checkpoint slice is lost work); evicted jobs
    # take a final on-demand checkpoint and keep all progress
    prog = jnp.maximum(state.dur_est - state.work_left, 0.0)
    kept = ckpt_kept(state, prog)
    work_left = jnp.where(on_down, state.dur_est - kept, state.work_left)

    # --- retry budget + terminal FAILED
    n_fail_new = state.n_failures + on_down.astype(jnp.int32)
    if cfg.max_job_retries > 0:
        exhausted = on_down & (n_fail_new > cfg.max_job_retries)
    else:
        exhausted = jnp.zeros_like(on_down)
    requeue = (on_down & ~exhausted) | evict
    jstate = jnp.where(exhausted, FAILED,
                       jnp.where(requeue, QUEUED, state.jstate))

    # --- requeue backoff: advancing submit_t reuses the arrival
    # breakpoint + queued_mask machinery untouched. Python-gated: with
    # backoff off, submit_t (and thus wait-time statistics) keep the
    # legacy original-submission baseline.
    submit_t = state.submit_t
    if cfg.requeue_backoff_s > 0:
        backoff = f32(cfg.requeue_backoff_s) * jnp.power(
            f32(cfg.requeue_backoff_mult),
            jnp.maximum(n_fail_new - 1, 0).astype(f32))
        submit_t = jnp.where(on_down & ~exhausted, t + backoff, submit_t)

    # --- scrub per-job fields so a requeued job is indistinguishable
    # from a freshly queued one (stale start_t was the audit finding)
    start_t = jnp.where(requeue | exhausted, 0.0, state.start_t)
    end_t = jnp.where(exhausted, t, state.end_t)
    placement = jnp.where((on_down | evict | exhausted)[:, None], -1, place)

    # --- lost-work accounting (goodput vs throughput): retries lose the
    # since-checkpoint slice, terminal failures the whole job, graceful
    # evictions nothing
    lost = jnp.where(on_down, prog - kept, 0.0)
    lost = jnp.where(exhausted, prog, lost)
    lost_now = jnp.sum(lost * state.n_nodes.astype(f32))
    killed_now = jnp.sum(on_down).astype(f32)

    state = state._replace(
        key=key, node_up=node_up, repair_t=repair_t, free=free,
        jstate=jstate, submit_t=submit_t, start_t=start_t, end_t=end_t,
        work_left=work_left, placement=placement,
        n_failures=n_fail_new,
        next_fail_t=next_fail_t, rack_fail_t=rack_fail_t,
        n_killed=state.n_killed + killed_now,
        n_failed=state.n_failed + jnp.sum(exhausted),
        lost_node_s=state.lost_node_s + lost_now,
    )
    return state, killed_now, lost_now
