"""Node-placement strategies — stage (b) of the two-stage policy engine.

Selection (``repro.core.schedulers``) answers *which job* to dispatch;
placement answers *which nodes* host it. Every strategy shares one
signature::

    place(state, statics, job) -> (row (K,) int32 node ids, feasible bool)

Strategies (RAPS/Slurm-style, [Maiterth et al. 2025] policy grids):

- ``first_fit``  lowest-index feasible nodes (the sort-free cumsum path).
- ``best_fit``   pack: feasible nodes with the LEAST remaining free
                 capacity first — consolidates load, keeps whole nodes
                 empty for large jobs (and for powering down).
- ``spread``     balance: feasible nodes with the MOST remaining free
                 capacity first — spreads heat/network load.
- ``partition``  TX-GAIA partition semantics: a job tagged with node-type
                 ``state.part[job]`` may only land on nodes of that type
                 (tag -1 = any). First-fit order within the partition.
- ``green``      sustainability: score nodes by (idle + dynamic) watts per
                 peak GFLOP/s so placement prefers energy-efficient
                 hardware; ties (homogeneous clusters) fall back to index
                 order.

Policy-as-data: ``PLACE_IDS`` maps names to int32 ids, ``place_job``
resolves a *traced* id via ``lax.switch``, and ``Policy`` bundles a
(select_id, place_id) pair — the unit ``run_fleet`` vmaps over so a
policy x scenario grid runs in ONE compiled call (zero recompiles).

Score-based strategies use ``lax.top_k`` on the negated score — O(N log K)
instead of a full argsort — and ``top_k`` breaks ties by lowest index, so
every strategy degenerates to ``first_fit`` ordering when its scores are
constant (property-tested in ``tests/test_placement.py``).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import schedulers as sched
from repro.core.state import SimState, Statics


def partition_mask(state: SimState, statics: Statics,
                   job: jax.Array) -> jax.Array:
    """(N,) bool: nodes whose type matches the job's partition tag
    (tag < 0 = untagged job, any node allowed). Per-job form of the
    shared ``schedulers.partition_ok`` rule."""
    return sched.partition_ok(state.part[job], statics.node_type)


def _score_place(
    state: SimState,
    job: jax.Array,
    score: jax.Array,
    mask: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Choose `n_nodes[job]` feasible nodes with the LOWEST score (ties by
    lowest index — `lax.top_k` keeps first occurrences, so a constant
    score reproduces first-fit ordering exactly)."""
    K = state.placement.shape[1]
    N = state.free.shape[1]
    ok = sched.feasible_nodes(state, job)
    if mask is not None:
        ok = ok & mask
    n_req = state.n_nodes[job]
    key = jnp.where(ok, score, jnp.inf)
    kk = min(K, N)
    _, idx = jax.lax.top_k(-key, kk)
    idx = idx.astype(jnp.int32)
    if kk < K:
        idx = jnp.concatenate([idx, -jnp.ones((K - kk,), jnp.int32)])
    slots = jnp.arange(K)
    row = jnp.where(slots < n_req, idx, -1)
    enough = jnp.sum(ok) >= n_req
    return jnp.where(enough, row, -1), enough


def _free_frac(state: SimState, statics: Statics) -> jax.Array:
    """(N,) mean free fraction across resources — the remaining-capacity
    score shared by best_fit (ascending) and spread (descending)."""
    return jnp.mean(
        state.free / jnp.maximum(statics.capacity, 1e-6), axis=0)


def watts_per_gflop(statics: Statics) -> jax.Array:
    """(N,) full-load watts per peak GFLOP/s — the `green` node score."""
    return statics.node_max_w / jnp.maximum(statics.peak_gflops, 1.0)


def place_first_fit(state: SimState, statics: Statics, job: jax.Array):
    return sched.first_fit(state, job, state.placement.shape[1])


def place_best_fit(state: SimState, statics: Statics, job: jax.Array):
    return _score_place(state, job, _free_frac(state, statics))


def place_spread(state: SimState, statics: Statics, job: jax.Array):
    return _score_place(state, job, -_free_frac(state, statics))


def place_partition(state: SimState, statics: Statics, job: jax.Array):
    return _score_place(state, job, jnp.zeros_like(statics.idle_w),
                        mask=partition_mask(state, statics, job))


def place_green(state: SimState, statics: Statics, job: jax.Array):
    return _score_place(state, job, watts_per_gflop(statics))


PLACEMENTS: Dict[str, object] = {
    "first_fit": place_first_fit,
    "best_fit": place_best_fit,
    "spread": place_spread,
    "partition": place_partition,
    "green": place_green,
}

# policy-as-data ids: position in PLACEMENTS (insertion-ordered) — the
# branch order of the `place_job` lax.switch
PLACE_IDS = {name: i for i, name in enumerate(PLACEMENTS)}

# Per-strategy node-eligibility masks BEYOND the free pool, as batched
# (state, statics) -> (J, N) functions; None = every node eligible.
# Selection (EASY's no-doomed-pick guarantee) and RL observations resolve
# masking through this registry, so a future masking strategy (racks,
# reservations, ...) needs exactly one entry here.
PLACEMENT_MASKS: Dict[str, object] = {
    "first_fit": None,
    "best_fit": None,
    "spread": None,
    "partition": sched.partition_mask_all,
    "green": None,
}
assert set(PLACEMENT_MASKS) == set(PLACEMENTS)


def placement_node_mask(state: SimState, statics: Statics,
                        place_id: jax.Array) -> jax.Array:
    """(J, N) node eligibility for a *traced* placement id: the masks of
    all masking strategies, each gated on ``place_id`` (non-masking ids
    resolve to all-True)."""
    J = state.jstate.shape[0]
    N = state.free.shape[1]
    mask = jnp.ones((J, N), bool)
    for name, fn in PLACEMENT_MASKS.items():
        if fn is None:
            continue
        use = place_id == PLACE_IDS[name]
        mask = mask & (fn(state, statics) | jnp.logical_not(use))
    return mask


def place_job(state: SimState, statics: Statics, job: jax.Array,
              place_id: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Resolve a *traced* int32 placement id via ``lax.switch`` — every
    strategy lives in ONE compiled step, so sweeping the placement axis
    costs zero recompiles."""
    branches = tuple(PLACEMENTS.values())
    return jax.lax.switch(place_id, branches, state, statics, job)


def feasible_under(name: str, state: SimState, statics: Statics,
                   job: jax.Array) -> jax.Array:
    """(N,) bool: nodes the named placement backend would consider for
    `job` right now (free-pool feasibility plus the backend's registered
    mask). Used by ``SchedEnv`` so RL observations reflect the active
    backend."""
    ok = sched.feasible_nodes(state, job)
    mask_fn = PLACEMENT_MASKS[name]
    if mask_fn is not None:
        ok = ok & mask_fn(state, statics)[job]
    return ok


# --------------------------------------------------------------- policies
class Policy(NamedTuple):
    """Policy-as-data: a (selection, placement) pair of traced int32 ids.

    Passed *as an argument* through ``run_episode``/``run_fleet`` (never
    closed over), a Policy changes scheduling behavior without touching
    the compiled step — the full selection x placement grid is one jit
    cache entry.
    """

    select: jax.Array          # int32 id into schedulers.SELECT_IDS
    place: jax.Array           # int32 id into PLACE_IDS


def make_policy(select: str = "fcfs", place: str = "first_fit") -> Policy:
    if select not in sched.SELECT_IDS:
        raise KeyError(f"unknown selection {select!r}; "
                       f"one of {list(sched.SELECT_IDS)}")
    if place not in PLACE_IDS:
        raise KeyError(f"unknown placement {place!r}; "
                       f"one of {list(PLACE_IDS)}")
    return Policy(select=jnp.int32(sched.SELECT_IDS[select]),
                  place=jnp.int32(PLACE_IDS[place]))


def stack_policies(policies: Sequence[Policy]) -> Policy:
    """Stack Policies leaf-wise -> leading replica axis (the policy analog
    of ``scenarios.stack_scenarios``)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *policies)


def policy_grid(
    selects: Sequence[str], places: Sequence[str]
) -> Tuple[Sequence[str], Policy]:
    """Cross selections x placements -> (names, batched Policy)."""
    names = [f"{s}+{p}" for s in selects for p in places]
    pols = [make_policy(s, p) for s in selects for p in places]
    return names, stack_policies(pols)
