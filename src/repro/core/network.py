"""Inter-job network congestion model (Lassen-style bytes-in/out coupling,
paper refs [7],[14]): aggregate running-job traffic vs. bisection bandwidth
gives a global contention factor that slows every communicating job's
progress — which in turn stretches runtimes and energy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.sim import SimConfig
from repro.core.state import RUNNING, SimState, Statics


def congestion_slowdown(cfg: SimConfig, state: SimState, statics: Statics):
    """Returns (per-job progress rate in (0,1], network load fraction)."""
    running = (state.jstate == RUNNING).astype(jnp.float32)
    # banked (W, J) traffic table: gather this replica's row through the
    # traced workload id (see Statics docstring)
    net_tx = (statics.net_tx if statics.net_tx.ndim == 1
              else statics.net_tx[state.workload])
    # jobs spanning n nodes inject n * net_tx GB/s into the fabric
    tx = net_tx * state.n_nodes.astype(jnp.float32) * running
    load = jnp.sum(tx) / jnp.maximum(cfg.bisection_gbps, 1e-6)
    over = jnp.maximum(load - cfg.congestion_knee, 0.0)
    factor = 1.0 + over ** cfg.congestion_exp
    # only network-active jobs are slowed; CPU-bound jobs keep full rate
    slowed = 1.0 / factor
    rate = jnp.where(net_tx > 0, slowed, 1.0)
    return jnp.where(running > 0, rate, 0.0), load
