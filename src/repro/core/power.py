"""RAPS power chain: job utilization -> node IT power -> rectification /
voltage-conversion losses -> cooling (COP model) -> facility power, plus
carbon intensity and GFLOPS/W.

The per-node aggregation is the simulator's compute hot-spot (it runs every
step for every vectorized environment); ``repro.kernels.node_power``
provides the Pallas TPU kernels — including the fused placement-scatter +
power-chain pass (``power_scatter_pallas``) that turns the job table into
per-node IT power in one kernel — with oracles in ``kernels.ref`` used
here on CPU.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.sim import SimConfig
from repro.core.state import RUNNING, NRES, SimState, Statics
from repro.kernels.ref import node_power_ref
from repro.scenarios.signals import eval_signal


class PowerOut(NamedTuple):
    node_it_w: jax.Array      # (N,)
    node_input_w: jax.Array   # (N,) after rectifier+conversion losses
    it_w: jax.Array           # scalar
    input_w: jax.Array
    cooling_w: jax.Array
    facility_w: jax.Array
    pue: jax.Array
    gflops: jax.Array         # utilization-weighted delivered GFLOP/s


def job_utilization(cfg: SimConfig, state: SimState, statics: Statics):
    """Per-job cpu/gpu utilization at current sim time from the telemetry
    bank (quanta-averaged, as RAPS replays traces).

    With a banked (W, J, Q) trace (see ``Statics``), the lookup gathers
    through the traced ``state.workload`` id — one J-element gather per
    step, identical cost to the unbatched path, and the bank itself is
    never copied per env (the lightweight-state rollout engine's key
    invariant)."""
    running = (state.jstate == RUNNING).astype(jnp.float32)
    age = jnp.maximum(state.t - state.start_t, 0.0)
    q = statics.cpu_trace.shape[-1]
    qi = jnp.clip((age / cfg.trace_quanta).astype(jnp.int32), 0, q - 1)
    if statics.cpu_trace.ndim == 3:
        j = jnp.arange(state.jstate.shape[0])
        cpu = statics.cpu_trace[state.workload, j, qi]
        gpu = statics.gpu_trace[state.workload, j, qi]
    else:
        cpu = jnp.take_along_axis(statics.cpu_trace, qi[:, None], axis=1)[:, 0]
        gpu = jnp.take_along_axis(statics.gpu_trace, qi[:, None], axis=1)[:, 0]
    return cpu * running, gpu * running


# Dense one-hot budget for job->node reductions: vmapped XLA scatter-adds
# are slow on CPU (generic scatter loop per env), while a (slots, N)
# one-hot contraction runs as one batched matmul — the same trick the
# Pallas power-scatter kernel plays on the MXU. Used whenever the one-hot
# stays under this many elements (~0.5 MB f32); bigger configs (tx_gaia)
# keep the memory-free scatter.
DENSE_SCATTER_ELEMS = 131072


def use_dense_scatter(n_slots: int, n_nodes: int) -> bool:
    return n_slots * n_nodes <= DENSE_SCATTER_ELEMS


def node_onehot(place_flat: jax.Array, n_nodes: int) -> jax.Array:
    """(slots, N) one-hot of placement node ids; invalid slots (id < 0)
    match no node, so they drop out of the contraction exactly like the
    scatter's ``mode="drop"``."""
    return (place_flat[:, None] == jnp.arange(n_nodes)[None, :]
            ).astype(jnp.float32)


def scatter_add_nodes(ids: jax.Array, amounts: jax.Array, n_nodes: int,
                      base: jax.Array | None = None) -> jax.Array:
    """The job-table -> per-node reduction shared by the power chain
    (``node_loads``) and the release path (``sim._release``): add
    ``amounts`` (..., S) at node ``ids`` (S,) onto ``base`` (..., n_nodes)
    (zeros when None); ids < 0 drop. Under the ``use_dense_scatter``
    budget this is the dense one-hot contraction at ``Precision.HIGHEST``
    (exact f32 — TPU bf16 / GPU TF32 matmul defaults would round, and the
    result feeds free-pool feasibility checks); larger configs keep the
    memory-free XLA scatter-add."""
    if use_dense_scatter(ids.shape[0], n_nodes):
        dense = jnp.matmul(amounts, node_onehot(ids, n_nodes),
                           precision=jax.lax.Precision.HIGHEST)
        return dense if base is None else base + dense
    if base is None:
        base = jnp.zeros(amounts.shape[:-1] + (n_nodes,), amounts.dtype)
    safe = jnp.where(ids >= 0, ids, 0)
    return base.at[..., safe].add(
        jnp.where(ids >= 0, amounts, 0.0), mode="drop")


def placement_amounts(state: SimState, cpu_util: jax.Array,
                      gpu_util: jax.Array):
    """Flattened per-placement-slot absolute utilized resources.

    Returns (place_flat (J*K,) int32, cpu_abs (J*K,), gpu_abs (J*K,)) —
    the job-table form the fused power-scatter kernel consumes directly
    (invalid slots carry place=-1 and zero amounts).
    """
    place = state.placement                       # (J,K)
    w = (place >= 0).astype(jnp.float32)
    cpu_abs = (state.req[0][:, None] * cpu_util[:, None]) * w
    gpu_abs = (state.req[1][:, None] * gpu_util[:, None]) * w
    return place.reshape(-1), cpu_abs.reshape(-1), gpu_abs.reshape(-1)


def node_loads(cfg: SimConfig, state: SimState, statics: Statics,
               cpu_util: jax.Array, gpu_util: jax.Array):
    """Scatter per-job utilized resources onto nodes.

    Returns (cpu_load, gpu_load) as *fractions of node capacity* in [0,1].
    """
    N = statics.capacity.shape[1]
    place = state.placement                       # (J,K)
    w = (place >= 0).astype(jnp.float32)
    # utilized absolute resources contributed per placement slot
    cpu_abs = (state.req[0][:, None] * cpu_util[:, None]) * w
    gpu_abs = (state.req[1][:, None] * gpu_util[:, None]) * w
    loads = scatter_add_nodes(
        place.reshape(-1),
        jnp.stack([cpu_abs.reshape(-1), gpu_abs.reshape(-1)]), N)
    cpu_node, gpu_node = loads[0], loads[1]
    cpu_frac = jnp.clip(cpu_node / jnp.maximum(statics.capacity[0], 1e-6), 0, 1)
    gpu_frac = jnp.clip(gpu_node / jnp.maximum(statics.capacity[1], 1e-6), 0, 1)
    return cpu_frac, gpu_frac


# NOTE: the legacy parametric shims `wetbulb_c` / `carbon_intensity` that
# used to live here are gone — `scenarios.default_scenario(cfg)` builds the
# identical sinusoids as Signals and the sim reads `statics.scenario.*`
# (tests/test_scenarios.py pins the equivalence against the closed forms).


def finish_power(cfg: SimConfig, state: SimState, statics: Statics,
                 node_it: jax.Array, node_input: jax.Array,
                 cpu_frac: jax.Array, gpu_frac: jax.Array) -> PowerOut:
    """Per-node IT/input power -> facility totals, cooling (COP model),
    PUE and delivered GFLOP/s. Shared by every power path (eager, Pallas
    kernel, and the macro-step fast tick) so the chain stays bit-identical
    across them."""
    it_w = jnp.sum(node_it)
    input_w = jnp.sum(node_input)
    wb = eval_signal(statics.scenario.wetbulb, state.t)
    cop = jnp.maximum(
        cfg.cop_base + cfg.cop_wetbulb_coef * (wb - cfg.wetbulb_ref_c),
        cfg.cop_min,
    )
    cooling_w = input_w / cop
    facility_w = input_w + cooling_w
    # PUE is undefined at zero IT load (every node down / idle-slept):
    # report the 1.0 ideal instead of facility_w / 1 W blowing up to ~1e5
    pue = jnp.where(it_w > 1.0, facility_w / jnp.maximum(it_w, 1.0), 1.0)
    gflops = jnp.sum(
        statics.peak_gflops * jnp.maximum(cpu_frac, gpu_frac) * state.node_up
    )
    return PowerOut(node_it, node_input, it_w, input_w, cooling_w,
                    facility_w, pue, gflops)


def power_from_fracs(cfg: SimConfig, state: SimState, statics: Statics,
                     cpu_frac: jax.Array, gpu_frac: jax.Array) -> PowerOut:
    """Per-node load fractions -> full power chain (the eager oracle math:
    IT power, rectifier-efficiency parabola, conversion losses)."""
    it = statics.idle_w + cpu_frac * statics.cpu_dyn_w + gpu_frac * statics.gpu_dyn_w
    it = it * state.node_up
    load_frac = jnp.clip(it / jnp.maximum(statics.node_max_w, 1.0), 0.0, 1.2)
    eta = jnp.clip(
        cfg.rect_eff_peak - cfg.rect_eff_curv * jnp.square(load_frac - cfg.rect_eff_load),
        0.5, 1.0,
    )
    node_it, node_input = it, it / (eta * cfg.conv_eff)
    return finish_power(cfg, state, statics, node_it, node_input,
                        cpu_frac, gpu_frac)


def compute_power(cfg: SimConfig, state: SimState, statics: Statics,
                  *, use_kernel: bool = False) -> PowerOut:
    cpu_util, gpu_util = job_utilization(cfg, state, statics)

    if use_kernel:
        # fused path: job table -> per-node IT power in ONE Pallas pass
        # (placement scatter + power chain; no (N,) load intermediates)
        from repro.kernels import ops as kops

        place_flat, cpu_abs, gpu_abs = placement_amounts(
            state, cpu_util, gpu_util)
        node_it, node_input, cpu_frac, gpu_frac = kops.power_scatter(
            place_flat, cpu_abs, gpu_abs, statics.capacity[0],
            statics.capacity[1], statics.idle_w, statics.cpu_dyn_w,
            statics.gpu_dyn_w, state.node_up, statics.node_max_w,
            rect_peak=cfg.rect_eff_peak, rect_load=cfg.rect_eff_load,
            rect_curv=cfg.rect_eff_curv, conv_eff=cfg.conv_eff,
        )
        return finish_power(cfg, state, statics, node_it, node_input,
                            cpu_frac, gpu_frac)

    cpu_frac, gpu_frac = node_loads(cfg, state, statics, cpu_util, gpu_util)
    return power_from_fracs(cfg, state, statics, cpu_frac, gpu_frac)
