"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract roofline inputs (FLOPs, bytes, per-collective traffic, memory) —
no array is ever allocated (ShapeDtypeStruct in, AOT compile only).

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/artifacts
  ... --set seq_parallel=0 --set microbatches=2 --tag nosp   (hillclimb knobs)
"""

# The VERY FIRST lines, before any other import (jax locks the device count
# on first init): 512 host platform devices for the production meshes.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
import traceback
from dataclasses import replace

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, arch_names, get_arch, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import batch_specs, count_params_analytic
from repro.models.model import decode_step as _decode_step
from repro.optim import default_optimizer_for, get_optimizer
from repro.sharding.ctx import make_ctx
from repro.sharding.specs import batch_pspecs, param_pspecs
from repro.train.state import abstract_train_state, train_state_pspecs
from repro.train.train_step import make_train_step
from repro.utils.hlo import parse_collectives

# v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def _sharded(tree_specs, tree_pspecs, mesh):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)
        ),
        tree_specs, tree_pspecs,
    )


def _ns(tree_pspecs, mesh):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), tree_pspecs)


def _cast_tree(tree, dtype):
    def one(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, dtype,
                                        sharding=getattr(s, "sharding", None))
        return s
    return jax.tree.map(one, tree)


def _lower_and_compile(cfg, shape, mesh, ctx, optimizer, microbatches):
    """Lower+compile one step for (cfg, shape) on mesh. Returns (compiled,
    lower_s, compile_s)."""
    t0 = time.time()
    with mesh:
        if shape.mode == "train":
            state_specs = abstract_train_state(cfg, optimizer)
            state_ps = train_state_pspecs(cfg, ctx, optimizer, mesh)
            b_specs = batch_specs(cfg, shape)
            b_ps = batch_pspecs(cfg, shape, ctx)
            step = make_train_step(cfg, optimizer, ctx, microbatches=microbatches)
            jitted = jax.jit(
                step,
                in_shardings=(_ns(state_ps, mesh), _ns(b_ps, mesh)),
                out_shardings=(_ns(state_ps, mesh), None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(
                _sharded(state_specs, state_ps, mesh),
                _sharded(b_specs, b_ps, mesh),
            )
        elif shape.mode == "prefill":
            from repro.models.spec import model_param_specs
            from repro.models.model import prefill

            p_specs = _cast_tree(model_param_specs(cfg), jnp.bfloat16)
            p_ps = param_pspecs(cfg, ctx, mesh)
            b_specs = batch_specs(cfg, shape)
            b_ps = batch_pspecs(cfg, shape, ctx)

            def step(params, batch):
                return prefill(params, batch, cfg, ctx,
                               cache_seq_len=shape.seq_len)

            jitted = jax.jit(step, in_shardings=(_ns(p_ps, mesh), _ns(b_ps, mesh)))
            lowered = jitted.lower(
                _sharded(p_specs, p_ps, mesh), _sharded(b_specs, b_ps, mesh)
            )
        else:  # decode
            from repro.models.spec import model_param_specs

            p_specs = _cast_tree(model_param_specs(cfg), jnp.bfloat16)
            p_ps = param_pspecs(cfg, ctx, mesh)
            b_specs = batch_specs(cfg, shape)
            b_ps = batch_pspecs(cfg, shape, ctx)

            def step(params, cache, tokens, cache_len):
                return _decode_step(params, cache, tokens, cache_len, cfg, ctx)

            jitted = jax.jit(
                step,
                in_shardings=(
                    _ns(p_ps, mesh), _ns(b_ps["cache"], mesh),
                    _ns(b_ps["tokens"], mesh), NamedSharding(mesh, P()),
                ),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                _sharded(p_specs, p_ps, mesh),
                _sharded(b_specs["cache"], b_ps["cache"], mesh),
                _sharded(b_specs["tokens"], b_ps["tokens"], mesh),
                jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=NamedSharding(mesh, P())),
            )
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def _probe_costs(cfg, shape, mesh, ctx, optimizer, microbatches):
    """Layer-delta cost probes: compile fully-UNROLLED variants at L=0,
    L=period (and L=period+tail when a tail exists), then scale the
    per-superblock delta by n_repeats. Avoids XLA cost-analysis' while-body
    undercounting (bodies visited once, not x trip count).
    """
    from repro.models.spec import layout

    period, n_repeats, n_tail = layout(cfg)
    probe_ctx = ctx.with_(
        force_unroll=True,
        attention_impl="full",      # no inner loops; analysis-only
        logit_chunk=shape.seq_len,  # single loss chunk -> 1-trip map
    )
    probe_cfg_base = replace(
        cfg, ssm=replace(cfg.ssm, chunk=min(shape.seq_len, 4096))
    )

    def costs_at(L):
        c = replace(probe_cfg_base, n_layers=L)
        # probes always use microbatches=1: gradient accumulation wraps the
        # body in a while loop (cost-analysis blind spot); per-step FLOPs at
        # full batch are identical, weight-gather bytes are under-counted by
        # the microbatch factor (noted in EXPERIMENTS.md).
        compiled, _, t = _lower_and_compile(
            c, shape, mesh, probe_ctx, optimizer, 1
        )
        cost = compiled.cost_analysis() or {}
        coll = parse_collectives(compiled.as_text())
        loops = compiled.as_text().count(" while(")
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll.total_bytes),
            "coll_by_kind": dict(coll.bytes_by_kind),
            "loops": loops,
            "compile_s": t,
        }

    c0 = costs_at(0)
    c1 = costs_at(period)
    c2 = costs_at(period + n_tail) if n_tail else c1

    def scale(key):
        return (
            c0[key]
            + n_repeats * (c1[key] - c0[key])
            + (c2[key] - c1[key])
        )

    kinds = set(c0["coll_by_kind"]) | set(c1["coll_by_kind"]) | set(c2["coll_by_kind"])
    coll_by_kind = {
        k: (
            c0["coll_by_kind"].get(k, 0)
            + n_repeats * (c1["coll_by_kind"].get(k, 0) - c0["coll_by_kind"].get(k, 0))
            + (c2["coll_by_kind"].get(k, 0) - c1["coll_by_kind"].get(k, 0))
        )
        for k in kinds
    }
    return {
        "flops_per_dev": scale("flops"),
        "bytes_per_dev": scale("bytes"),
        "collective_bytes_per_dev": scale("coll"),
        "collective_bytes_by_kind": coll_by_kind,
        "residual_loops_in_probe": max(c0["loops"], c1["loops"], c2["loops"]),
        "probe_compile_s": c0["compile_s"] + c1["compile_s"] + c2["compile_s"],
        "probe_points": {"L0": c0, "L_period": c1,
                         **({"L_period_tail": c2} if n_tail else {})},
    }


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    ctx_overrides=None,
    microbatches: int = 1,
    optimizer_name: str = "",
    verbose: bool = True,
    probes: bool = True,
):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "SKIP", "reason": why}

    multi = mesh_kind == "multi"
    if mesh_kind.startswith("custom:"):
        # e.g. 'custom:32,8' -> single-pod (data=32, model=8) mesh
        d, m = (int(x) for x in mesh_kind.split(":")[1].split(","))
        mesh = jax.make_mesh(
            (d, m), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2,
            devices=jax.devices()[: d * m],
        )
        multi = False
        tp_size = m
    else:
        mesh = make_production_mesh(multi_pod=multi)
        tp_size = 16
    n_chips = mesh.devices.size
    dp_total = n_chips // tp_size

    kw = dict(ctx_overrides or {})
    if shape.mode == "decode" and shape.global_batch < dp_total:
        kw.setdefault("decode_kv_shard", "seq2d")
    kw.setdefault("attention_impl", "chunked")
    kw.setdefault("dp_size", dp_total)
    kw.setdefault("tp_size", tp_size)
    ctx = make_ctx(multi, **kw)

    n_params = count_params_analytic(cfg)
    n_active = count_params_analytic(cfg, active_only=True)
    opt_name = optimizer_name or default_optimizer_for(n_params)
    optimizer = get_optimizer(opt_name)

    # phase 1: realistic compile (scan-over-layers, chunked attention) —
    # proves sharding coherence and per-device memory fit
    compiled, t_lower, t_compile = _lower_and_compile(
        cfg, shape, mesh, ctx, optimizer, microbatches
    )
    mem = compiled.memory_analysis()
    raw_cost = compiled.cost_analysis() or {}
    raw_coll = parse_collectives(compiled.as_text())

    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2.0 * n_active * tokens

    # phase 2: layer-delta cost probes (single-pod roofline terms)
    probe = None
    if probes:
        probe = _probe_costs(cfg, shape, mesh, ctx, optimizer, microbatches)

    flops_dev = probe["flops_per_dev"] if probe else float(raw_cost.get("flops", 0))
    bytes_dev = probe["bytes_per_dev"] if probe else float(raw_cost.get("bytes accessed", 0))
    coll_dev = probe["collective_bytes_per_dev"] if probe else float(raw_coll.total_bytes)

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "status": "OK",
        "n_chips": int(n_chips),
        "optimizer": opt_name,
        "params_b": n_params / 1e9,
        "active_params_b": n_active / 1e9,
        "tokens_per_step": float(tokens),
        "model_flops_total": model_flops,
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll_dev,
        "collectives": {
            "bytes_by_kind": (probe or {}).get(
                "collective_bytes_by_kind", raw_coll.bytes_by_kind
            ),
            "raw_scan_body_bytes_by_kind": raw_coll.bytes_by_kind,
            "raw_scan_body_count_by_kind": raw_coll.count_by_kind,
        },
        **terms,
        "dominant": dominant,
        "model_flops_ratio": (
            model_flops / (flops_dev * n_chips) if flops_dev else 0.0
        ),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        },
        "raw_cost_analysis": {
            "flops": float(raw_cost.get("flops", 0.0)),
            "bytes_accessed": float(raw_cost.get("bytes accessed", 0.0)),
        },
        "probe": probe,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "ctx": {k: v for k, v in (ctx_overrides or {}).items()},
        "microbatches": microbatches,
    }
    if verbose:
        print(
            f"[{arch} x {shape_name} x {mesh_kind}] OK "
            f"flops/dev={flops_dev:.3e} bytes/dev={bytes_dev:.3e} "
            f"coll/dev={coll_dev:.3e} dominant={dominant} "
            f"mfr={result['model_flops_ratio']:.3f} "
            f"mem(arg)={mem.argument_size_in_bytes/2**30:.2f}GiB "
            f"mem(temp)={mem.temp_size_in_bytes/2**30:.2f}GiB "
            f"compile={t_compile:.0f}s",
            flush=True,
        )
    return result


def parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("0", "1") and k not in ("scan_unroll", "logit_chunk",
                                         "block_q", "block_k"):
            v = bool(int(v))
        elif v.isdigit():
            v = int(v)
        elif v in ("True", "False"):
            v = v == "True"
        out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    help="single | multi | both | custom:<data>,<model>")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/artifacts")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", dest="overrides",
                    help="ShardCtx overrides, e.g. --set seq_parallel=0")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip layer-delta cost probes (multi-pod pass only "
                    "needs the realistic compile)")
    args = ap.parse_args()

    archs = arch_names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    overrides = parse_overrides(args.overrides)

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                tag = f"__{args.tag}" if args.tag else ""
                fname = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh}{tag}.json"
                )
                if args.skip_existing and os.path.exists(fname):
                    print(f"[{arch} x {shape} x {mesh}] exists, skipping")
                    continue
                try:
                    res = run_cell(
                        arch, shape, mesh,
                        ctx_overrides=overrides,
                        microbatches=args.microbatches,
                        optimizer_name=args.optimizer,
                        probes=not args.no_probes and mesh != "multi",
                    )
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "FAIL", "error": repr(e)}
                    failures.append((arch, shape, mesh))
                with open(fname, "w") as f:
                    json.dump(res, f, indent=1)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete.")


if __name__ == "__main__":
    main()
