"""Batched serving driver: prefill a batch of prompts, decode N tokens,
report tokens/s. (Reduced configs run on CPU; the production mesh path is
exercised by the dry-run.)

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import init_params
from repro.train.serve_step import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--iters", type=int, default=5,
                    help="timed decode repetitions (median reported)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = init_params(cfg, jax.random.key(args.seed))
    key = jax.random.key(args.seed + 1)
    prompt = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32
    )
    extras = {}
    if cfg.n_vision_tokens:
        extras["vision"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.n_vision_tokens, cfg.d_model))
    if cfg.enc_dec:
        extras["audio"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.n_audio_frames, cfg.d_model))

    gen = jax.jit(
        lambda p, t, k: generate(
            cfg, p, t, args.gen, temperature=args.temperature, key=k,
            extras=extras or None,
        )
    )
    out = gen(params, prompt, key)       # compile
    out.block_until_ready()
    # one-shot timings of a jitted decode are dominated by dispatch
    # jitter: repeat and report the median (with the p10/p90 spread)
    times = []
    for _ in range(max(args.iters, 1)):
        t0 = time.perf_counter()
        out = gen(params, prompt, key)
        out.block_until_ready()
        times.append(time.perf_counter() - t0)
    med, p10, p90 = (float(v) for v in
                     np.percentile(np.asarray(times), [50, 10, 90]))
    toks = args.batch * args.gen
    print(f"arch={cfg.name} generated {toks} tokens/iter over "
          f"{len(times)} iters: median {med:.3f}s ({toks/med:,.1f} tok/s, "
          f"p10-p90 {toks/p90:,.1f}-{toks/p10:,.1f} tok/s); "
          f"sample: {out[0, :16].tolist()}")
    return out


if __name__ == "__main__":
    main()
