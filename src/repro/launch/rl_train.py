"""RL scheduler training driver (the paper's Fig. 2 pipeline): build the
TX-GAIA (or tiny) twin, wrap it in the Gym-style env, train PPO, write the
reward history + a power trace under the learned policy.

  PYTHONPATH=src python -m repro.launch.rl_train --cluster tiny \
      --iterations 30 --out experiments/rl
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sim import tiny_cluster, tx_gaia
from repro.data import synth_workload
from repro.envs import SchedEnv
from repro.rl import PPOConfig, ppo_train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", default="tiny", choices=["tiny", "tx-gaia"])
    ap.add_argument("--iterations", type=int, default=30)
    ap.add_argument("--n-envs", type=int, default=8)
    ap.add_argument("--rollout", type=int, default=32)
    ap.add_argument("--episode-steps", type=int, default=32)
    ap.add_argument("--n-jobs", type=int, default=40)
    ap.add_argument("--horizon", type=float, default=1800.0)
    ap.add_argument("--n-workloads", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--out", default="")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    if args.cluster == "tiny":
        cfg = tiny_cluster(sched_max_candidates=4)
    else:
        cfg = tx_gaia(max_jobs=256, max_nodes_per_job=16)

    wls = [
        synth_workload(cfg, args.n_jobs, args.horizon, seed=args.seed + s)
        for s in range(args.n_workloads)
    ]
    env = SchedEnv(cfg, wls, episode_steps=args.episode_steps,
                   sim_steps_per_action=15)
    print(f"cluster={cfg.name} nodes={cfg.n_nodes} obs={env.obs_dim} "
          f"actions={env.n_actions}")

    ppo_cfg = PPOConfig(n_envs=args.n_envs, rollout_len=args.rollout,
                        lr=args.lr)
    history = []

    def log(it, stats):
        history.append({"iteration": it, **stats})
        print(f"it {it:3d} ep_return={stats['mean_episode_return']:8.2f} "
              f"reward={stats['mean_reward']:7.3f} "
              f"kl={stats['approx_kl']:.4f}")

    params, hist = ppo_train(
        env, cfg=ppo_cfg, n_iterations=args.iterations, seed=args.seed,
        log=log, checkpoint_dir=args.ckpt or None, resume=args.resume,
    )

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        with open(os.path.join(args.out, "ppo_history.json"), "w") as f:
            json.dump(history, f, indent=1)
        # paper Fig 2 (bottom-right): power trace under the learned policy
        from repro.rl.policy import ActorCritic

        policy = ActorCritic(env.obs_dim, env.n_actions)
        st, obs = env.reset(jax.random.key(123))

        def step(carry, _):
            st, obs, key = carry
            key, k = jax.random.split(key)
            logits, _ = policy.apply(params, obs)
            action = jnp.argmax(logits)
            st, obs, r, d, info = env.step(st, action)
            return (st, obs, key), (info["facility_w"], r)

        (_, _, _), (pw, rw) = jax.lax.scan(
            step, (st, obs, jax.random.key(7)), None,
            length=args.episode_steps,
        )
        np.save(os.path.join(args.out, "power_trace_rl.npy"), np.asarray(pw))
        print(f"wrote {args.out}/ppo_history.json and power_trace_rl.npy")
    return params, history


if __name__ == "__main__":
    main()
