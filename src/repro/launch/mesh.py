"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis composes
with 'data' for gradient reduction (crosses DCN once per step) and with
FSDP sharding; 'model' (TP/SP/EP) stays inside the ICI domain.

A FUNCTION, not a module-level constant: importing this module must not
touch jax device state (smoke tests run on 1 CPU device).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under dryrun.py (sets xla_force_host_platform_device_count)"
        )
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        devices=devices[:n],
    )


def make_mesh_for(n_devices: Optional[int] = None, *,
                  model_axis: int = 1):
    """Small-scale mesh for tests/examples on whatever devices exist."""
    devices = jax.devices()
    n = n_devices or len(devices)
    data = n // model_axis
    return jax.make_mesh(
        (data, model_axis), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
        devices=devices[:n],
    )


def make_fleet_mesh(n_devices: Optional[int] = None, *,
                    axis: str = "replica"):
    """1-D mesh over the available devices for device-sharded fleet sweeps
    (``core.fleet.run_fleet(..., mesh=...)``) and shard_map PPO
    (``rl.distributed``): the replica/env axis partitions across ``axis``
    and everything else replicates. Works on the pinned jax floor
    (``axis_types`` is a newer keyword, so it is applied best-effort)."""
    devices = jax.devices()
    n = n_devices or len(devices)
    if n > len(devices):
        raise RuntimeError(
            f"need {n} devices for a fleet mesh, have {len(devices)} — "
            "force host devices via "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    try:
        return jax.make_mesh(
            (n,), (axis,),
            axis_types=(jax.sharding.AxisType.Auto,),
            devices=devices[:n])
    except (AttributeError, TypeError):
        return jax.make_mesh((n,), (axis,), devices=devices[:n])
