"""LM training driver.

Runs any ``--arch`` (full or ``--reduced``) on whatever devices exist:
builds a (data, model) mesh, FSDP+TP+SP shards the state, streams the
deterministic synthetic corpus, checkpoints asynchronously and resumes
exactly (seekable data + monotone step dirs). The end-to-end ~100M-model
example (examples/train_lm.py) drives this module.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
      --steps 200 --batch 8 --seq 256 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs import get_arch, reduced
from repro.data.synth_lm import lm_batch_at
from repro.launch.mesh import make_mesh_for
from repro.models import count_params_analytic
from repro.optim import cosine_warmup, default_optimizer_for, get_optimizer
from repro.sharding.ctx import ShardCtx, make_ctx, UNSHARDED
from repro.train.state import create_train_state, train_state_pspecs
from repro.train.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", default=None, choices=[None, "int8"])
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    n_params = count_params_analytic(cfg)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"(reduced={args.reduced})")

    opt_name = default_optimizer_for(n_params)
    optimizer = get_optimizer(
        opt_name, lr=cosine_warmup(args.lr, args.warmup, args.steps)
    )

    n_dev = len(jax.devices())
    use_mesh = n_dev > 1
    if use_mesh:
        mesh = make_mesh_for(model_axis=args.model_axis)
        ctx = make_ctx(False, tp_size=args.model_axis,
                       dp_size=n_dev // args.model_axis)
    else:
        mesh = None
        ctx = UNSHARDED

    state = create_train_state(cfg, optimizer, jax.random.key(args.seed))
    start = 0
    if args.ckpt and args.resume:
        s0 = latest_step(args.ckpt)
        if s0 is not None:
            state = restore(args.ckpt, s0, state)
            start = int(state["step"])
            print(f"resumed from step {start}")

    step_fn = make_train_step(
        cfg, optimizer, ctx, microbatches=args.microbatches,
        compress=args.compress,
    )
    if use_mesh:
        from repro.sharding.specs import batch_pspecs
        from repro.configs.base import ShapeConfig
        from jax.sharding import NamedSharding

        shape = ShapeConfig("cli", args.seq, args.batch, "train")
        state_ps = train_state_pspecs(cfg, ctx, optimizer, mesh)
        b_ps = batch_pspecs(cfg, shape, ctx)
        ns = lambda t: jax.tree.map(lambda p: NamedSharding(mesh, p), t)
        jitted = jax.jit(step_fn, in_shardings=(ns(state_ps), ns(b_ps)),
                         out_shardings=(ns(state_ps), None),
                         donate_argnums=(0,))
        state = jax.device_put(state, ns(state_ps))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0,))

    ckpt = AsyncCheckpointer(args.ckpt) if args.ckpt else None
    extras = {}
    if cfg.n_vision_tokens:
        extras["vision"] = (cfg.n_vision_tokens, cfg.d_model)
    if cfg.enc_dec:
        extras["audio"] = (cfg.n_audio_frames, cfg.d_model)

    history = []
    t0 = time.time()
    tokens_done = 0
    for step in range(start, args.steps):
        batch = lm_batch_at(
            step, vocab=cfg.vocab, batch=args.batch, seq_len=args.seq,
            seed=args.seed, extras=extras or None,
        )
        state, metrics = jitted(state, batch)
        tokens_done += args.batch * args.seq
        if (step + 1) % args.log_every == 0 or step == start:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            m.update(step=step, tok_per_s=tokens_done / max(dt, 1e-9))
            history.append(m)
            print(f"step {step:5d} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.2f} tok/s={m['tok_per_s']:,.0f}"
                  + (" SKIPPED" if m["skipped"] else ""))
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step, state)
    if ckpt:
        ckpt.wait()
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=1)
    return history


if __name__ == "__main__":
    main()
