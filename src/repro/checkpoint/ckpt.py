"""Fault-tolerant checkpointing.

Design (orbax is unavailable offline; built from scratch):
- a checkpoint is a directory ``step_<N>/`` holding one ``.npy`` per pytree
  leaf (flattened path names) + ``manifest.json`` (tree structure, shapes,
  dtypes, mesh shape, config fingerprint, step);
- writes go to ``step_<N>.tmp`` then ``os.rename`` -> crash-atomic: a
  partially-written checkpoint is never visible;
- ``AsyncCheckpointer`` offloads serialization to a background thread
  (training continues; ``wait()`` joins before the next save);
- restore is *resharding*: leaves are read on host and ``jax.device_put``
  with the *current* mesh's shardings — so a job checkpointed on a
  (16,16) mesh restarts unchanged on (2,16,16) or a single host
  (elastic scaling / shrink-to-recover after node failures);
- ``latest_step`` + monotonically-numbered directories give restart-from-
  latest semantics after preemption; older checkpoints are GC'd with
  ``keep`` retention.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.utils.errors import CheckpointError

MANIFEST = "manifest.json"


def _is_key_array(leaf) -> bool:
    dt = getattr(leaf, "dtype", None)
    return dt is not None and jax.dtypes.issubdtype(dt, jax.dtypes.prng_key)


def _leaf_files(tree) -> Dict[str, Any]:
    from repro.utils.tree import tree_map_with_path_names

    leaves: Dict[str, Any] = {}

    def visit(name, leaf):
        leaves[name.replace("/", "__") or "leaf"] = leaf
        return leaf

    tree_map_with_path_names(visit, tree)
    return leaves


def save(
    directory: str,
    step: int,
    tree: Any,
    *,
    extra_meta: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> str:
    """Synchronous atomic checkpoint write. Returns final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _leaf_files(tree)
    meta = {"step": int(step), "leaves": {}, "extra": extra_meta or {}}
    for name, leaf in leaves.items():
        entry = {}
        if _is_key_array(leaf):
            # typed PRNG keys serialize as their uint32 key data; the impl
            # name in the manifest lets restore re-wrap them exactly
            entry["prng_impl"] = str(jax.random.key_impl(leaf))
            leaf = jax.random.key_data(leaf)
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16, fp8, ...)
            dtype_name = arr.dtype.name
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        np.save(os.path.join(tmp, name + ".npy"), arr)
        entry.update({"shape": list(arr.shape), "dtype": dtype_name})
        meta["leaves"][name] = entry
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(meta, f)
    # chaos hook: widen the pre-rename window so the crash harness
    # (utils/chaos.py) can reliably land SIGKILLs mid-write and prove
    # the tmp-then-rename protocol never exposes a torn checkpoint
    slow = os.environ.get("REPRO_CHAOS_SLOW_SAVE")
    if slow:
        time.sleep(float(slow))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    """Newest complete checkpoint step in ``directory`` (None if none).

    Also sweeps stale ``step_<N>.tmp`` directories left by a crash
    mid-write — they are by construction incomplete (the atomic rename
    never happened), so deleting them is always safe. Don't scan a
    directory a live ``AsyncCheckpointer`` is writing into from another
    process: the sweep could reap its in-flight tmp dir.
    """
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and d.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
        elif d.startswith("step_") and \
                os.path.exists(os.path.join(directory, d, MANIFEST)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def read_meta(directory: str, step: int) -> Dict[str, Any]:
    """Load a checkpoint's manifest; typed errors on missing/corrupt."""
    path = os.path.join(directory, f"step_{step:010d}", MANIFEST)
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        raise CheckpointError(
            f"no checkpoint manifest at {path} — directory missing or "
            "write never completed", field="manifest.json") from None
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointError(
            f"corrupt checkpoint manifest {path}: {e}",
            field="manifest.json") from None


def restore(directory: str, step: int, like: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of `like`. When `shardings` (a matching
    pytree of NamedSharding) is given, leaves are device_put with them —
    this is where elastic resharding happens. Missing/corrupt manifests,
    missing leaf files and shape mismatches raise a typed
    :class:`~repro.utils.errors.CheckpointError` naming the artifact."""
    path = os.path.join(directory, f"step_{step:010d}")
    meta = read_meta(directory, step)

    from repro.utils.tree import tree_map_with_path_names

    def load(name, leaf):
        fname = name.replace("/", "__") or "leaf"
        try:
            arr = np.load(os.path.join(path, fname + ".npy"))
        except FileNotFoundError:
            raise CheckpointError(
                f"checkpoint {path} is missing leaf file {fname}.npy "
                "(manifest/leaf mismatch)", field=fname) from None
        except ValueError as e:
            raise CheckpointError(
                f"checkpoint leaf {path}/{fname}.npy is corrupt: {e}",
                field=fname) from None
        entry = meta["leaves"].get(fname, {})
        want_dtype = entry.get("dtype", str(arr.dtype))
        if str(arr.dtype) != want_dtype:
            # ml_dtypes saved as raw uint payloads
            arr = arr.view(jax.numpy.dtype(want_dtype))
        if entry.get("prng_impl"):
            key = jax.random.wrap_key_data(
                jax.numpy.asarray(arr), impl=entry["prng_impl"])
            expect = tuple(getattr(leaf, "shape", key.shape))
            if tuple(key.shape) != expect:
                raise CheckpointError(
                    f"checkpoint leaf {name} shape {key.shape} != "
                    f"expected {expect}", field=fname)
            return key
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise CheckpointError(
                f"checkpoint leaf {name} shape {arr.shape} != expected "
                f"{expect}", field=fname)
        return arr

    host_tree = tree_map_with_path_names(load, like)
    if shardings is None:
        return jax.tree.map(
            lambda a: a if _is_key_array(a) else jax.numpy.asarray(a),
            host_tree)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, s), host_tree, shardings
    )


def save_sharded(directory: str, step: int, tree: Any, **kw) -> str:
    """Gather-to-host save (the multi-host version writes per-host shards;
    single-process here, so this is the host round-trip path)."""
    host = jax.tree.map(
        lambda x: x if _is_key_array(x) else np.asarray(jax.device_get(x)),
        tree)
    return save(directory, step, host, **kw)


def restore_sharded(directory: str, step: int, like: Any, shardings: Any) -> Any:
    return restore(directory, step, like, shardings=shardings)


class AsyncCheckpointer:
    """Background-thread checkpoint writer (compute/IO overlap)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree: Any, **kw) -> None:
        self.wait()
        host = jax.tree.map(
            lambda x: x if _is_key_array(x)
            else np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = save(self.directory, step, host,
                                  keep=self.keep, **kw)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
