"""Mid-episode snapshot/resume for ``run_episode`` / ``run_fleet``.

Long trace replays and fleet sweeps die to preemption hours in; this
module makes them durable without touching the traced step. The episode
is cut into host-level *segments* of ``round(snapshot_every_s/cfg.dt)``
ticks, each executed by ``sim.run_segment`` — the exact
``summary_only``/``macro`` program bodies threading a RAW (un-finalized)
``TelemetrySummary`` accumulator — and after every segment a
crash-atomic checkpoint (``checkpoint.ckpt``: tmp-then-rename) captures

    {"state": SimState (PRNG key via key_data), "acc": raw accumulator}

plus a run *fingerprint* in the manifest (digests of cfg, scheduler/
policies, statics, the caller's workload table, the initial PRNG stream,
``n_steps`` and forwarded kwargs). Resume recomputes the fingerprint
from the caller's arguments and refuses — with a typed
:class:`~repro.utils.errors.CheckpointError` naming the diverging
component — to splice a snapshot into a different run.

Bit-identity guarantee (pinned by ``tests/test_snapshot.py`` and the
chaos harness): kill at ANY snapshot boundary, resume, and the final
``SimState`` (every leaf, PRNG stream included), ``TelemetrySummary``
and ``summary()`` dict are bit-identical to the same run left
uninterrupted — segment edges clamp the macro fast-forward exactly like
``telemetry_every`` window edges (PR 5's contract), per-tick scans split
associatively at tick boundaries, finalization (the mean_*/n division)
happens once at the end, and fleet PRNG keys are split/folded ONCE per
run then carried through snapshots. The device mesh is deliberately NOT
fingerprinted: sharded fleets are bit-identical to vmapped ones, so a
sweep may resume on a different device count (elastic restart).
"""

from __future__ import annotations

import hashlib
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.utils.errors import CheckpointError, ConfigError

FINGERPRINT_SCHEMA = 1


def _digest(s: str) -> str:
    return hashlib.sha256(s.encode()).hexdigest()[:16]


def _tree_digest(tree: Any) -> str:
    """Order-stable digest over a pytree's leaf names, dtypes and bytes."""
    from repro.utils.tree import tree_map_with_path_names

    h = hashlib.sha256()

    def visit(name, leaf):
        x = leaf
        if ckpt._is_key_array(x):
            x = jax.random.key_data(x)
        arr = np.asarray(jax.device_get(x))
        h.update(name.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
        return leaf

    tree_map_with_path_names(visit, tree)
    return h.hexdigest()[:16]


def _sched_token(scheduler) -> str:
    if isinstance(scheduler, str):
        return f"name:{scheduler}"
    # placement.Policy (possibly batched): ids are concrete at the host level
    sel = np.asarray(jax.device_get(scheduler.select)).tolist()
    plc = np.asarray(jax.device_get(scheduler.place)).tolist()
    return f"policy:{sel}/{plc}"


# SimState fields that define the WORKLOAD a run was started with — the
# job table installed by load_jobs plus the banked-trace selector.
_WORKLOAD_FIELDS = ("submit_t", "dur_est", "n_nodes", "req", "part",
                    "priority", "ckpt_interval", "workload")


def run_fingerprint(
    kind: str,
    cfg,
    scheduler,
    statics,
    state,
    n_steps: int,
    kw: Dict[str, Any],
    *,
    extra: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Component-wise fingerprint of a (fleet) episode's launch arguments.

    Computed from the CALLER's arguments both at run start and at resume
    — never from the evolving snapshot — so every component is a pure
    function of "what run did you ask for". Kept component-wise (not one
    rolled-up hash) so a mismatch can name the part that diverged.
    """
    fp = {
        "schema": FINGERPRINT_SCHEMA,
        "kind": kind,
        "cfg": _digest(repr(cfg)),
        "scheduler": _digest(_sched_token(scheduler)),
        "statics": _tree_digest(statics),
        "workload": _tree_digest(
            {f: getattr(state, f) for f in _WORKLOAD_FIELDS}),
        "prng": _tree_digest({"key": state.key}),
        "n_steps": int(n_steps),
        "kw": _digest(repr(tuple(sorted((k, repr(v)) for k, v in kw.items())))),
    }
    fp.update(extra or {})
    return fp


def check_fingerprint(saved: Dict[str, Any], want: Dict[str, Any],
                      directory: str) -> None:
    """Raise a loud, component-naming ``CheckpointError`` on mismatch."""
    bad = sorted(
        k for k in set(saved) | set(want) if saved.get(k) != want.get(k))
    if bad:
        detail = "; ".join(
            f"{k}: checkpoint={saved.get(k)!r} vs current={want.get(k)!r}"
            for k in bad)
        raise CheckpointError(
            f"snapshot in {directory} belongs to a different run — "
            f"mismatched fingerprint component(s) {bad} ({detail}). "
            "Pass the same cfg/scheduler/statics/workload/seed/n_steps "
            "the snapshot was written with, or point resume_from at the "
            "right directory.", field=",".join(bad))


def _restore_latest(directory: str, like: Dict[str, Any],
                    want_fp: Dict[str, Any]):
    """(tree, ticks) from the newest snapshot, or (None, 0) if none yet.

    An empty/missing directory is NOT an error: a run killed before its
    first snapshot legitimately resumes from t=0.
    """
    step = ckpt.latest_step(directory)
    if step is None:
        return None, 0
    meta = ckpt.read_meta(directory, step)
    extra = meta.get("extra", {})
    check_fingerprint(extra.get("fingerprint", {}), want_fp, directory)
    tree = ckpt.restore(directory, step, like)
    return tree, int(extra["ticks"])


# Single-episode segment under jit — scheduler strings ride the static
# cache; Policy schedulers are traced data (policy is not None wins).
@partial(jax.jit,
         static_argnames=("cfg", "n_ticks", "sched_name", "kw_items",
                          "macro"))
def _episode_segment(cfg, statics, state, acc, policy, n_ticks, sched_name,
                     kw_items, macro):
    from repro.core.sim import run_segment

    who = sched_name if policy is None else policy
    return run_segment(cfg, statics, state, acc, n_ticks, who, macro=macro,
                       **dict(kw_items))


def _snapshot_plan(cfg, n_steps: int, snapshot_every_s, telemetry_every: int,
                   summary_only: bool, macro: bool) -> int:
    """Validate the mode and return the segment length in ticks."""
    if telemetry_every > 1 or not (summary_only or macro):
        raise ConfigError(
            "snapshot/resume needs an episode-wide summary so the "
            "telemetry accumulator can ride in the checkpoint: pass "
            "summary_only=True (or macro=True) and telemetry_every<=1; "
            f"got summary_only={summary_only}, macro={macro}, "
            f"telemetry_every={telemetry_every}")
    if snapshot_every_s is None or not np.isfinite(snapshot_every_s):
        return int(n_steps)
    if snapshot_every_s <= 0:
        raise ConfigError(
            f"snapshot_every_s must be positive (or None/inf to snapshot "
            f"only at episode end), got {snapshot_every_s}")
    return max(1, int(round(float(snapshot_every_s) / float(cfg.dt))))


def run_episode_snapshotted(
    cfg,
    statics,
    state,
    n_steps: int,
    scheduler,
    *,
    telemetry_every: int,
    summary_only: bool,
    macro: bool,
    snapshot_every_s,
    snapshot_dir: Optional[str],
    resume_from: Optional[str],
    snapshot_keep: int,
    kw: Dict[str, Any],
):
    """Host-level segmented drive of one episode (see module docstring)."""
    from repro.core import sim
    from repro.utils import invariants

    if isinstance(state.t, jax.core.Tracer):
        raise ConfigError(
            "snapshotting is host-level orchestration (it writes files "
            "between segments); call run_episode eagerly, not under "
            "jit/vmap — wrap only the snapshot-free path in jit")
    seg_ticks = _snapshot_plan(cfg, n_steps, snapshot_every_s,
                               telemetry_every, summary_only, macro)
    if snapshot_dir is None:
        snapshot_dir = resume_from
    fp = run_fingerprint("episode", cfg, scheduler, statics, state,
                         n_steps, kw)
    acc = sim._telem_zero(cfg.resilience_on, cfg.serving_on)
    ticks = 0
    if resume_from is not None:
        tree, ticks = _restore_latest(
            resume_from, {"state": state, "acc": acc}, fp)
        if tree is not None:
            state, acc = tree["state"], tree["acc"]

    sched_name = scheduler if isinstance(scheduler, str) else None
    policy = None if isinstance(scheduler, str) else scheduler
    kw_items = tuple(sorted(kw.items()))
    # with the checkify harness on, drive segments eagerly so the
    # per-committed-step invariant suite runs exactly as in run_episode
    eager_check = invariants.enabled()
    while ticks < n_steps:
        n = int(min(seg_ticks, n_steps - ticks))
        if eager_check:
            state, acc = sim.run_segment(
                cfg, statics, state, acc, n, scheduler, macro=macro, **kw)
        else:
            state, acc = _episode_segment(
                cfg, statics, state, acc, policy, n, sched_name, kw_items,
                macro)
        ticks += n
        if snapshot_dir is not None:
            ckpt.save(snapshot_dir, ticks, {"state": state, "acc": acc},
                      extra_meta={"ticks": ticks, "fingerprint": fp},
                      keep=snapshot_keep)
    return state, sim._telem_finalize(acc)


def run_fleet_snapshotted(
    cfg,
    statics,
    scenarios,
    policies,
    state,
    keys,
    n_steps: int,
    scheduler: str,
    kw: Dict[str, Any],
    *,
    mesh,
    mesh_axis: str,
    snapshot_every_s,
    snapshot_dir: Optional[str],
    resume_from: Optional[str],
    snapshot_keep: int,
):
    """Segmented fleet sweep: one snapshot covers the whole replica batch.

    ``state`` arrives replica-batched with ``keys`` already derived by
    ``run_fleet``'s normal split/fold_in schedule; they are installed
    into ``state.key`` HERE, once, so segments (and resumed runs) never
    re-derive them — the per-replica streams are bit-identical to the
    single-call fleet.
    """
    from repro.core import fleet, sim

    seg_ticks = _snapshot_plan(
        cfg, n_steps, snapshot_every_s, kw.get("telemetry_every", 1),
        kw.get("summary_only", False), kw.get("macro", False))
    if snapshot_dir is None:
        snapshot_dir = resume_from
    state = state._replace(key=keys)
    R = int(jnp.shape(state.t)[0])
    fp = run_fingerprint(
        "fleet", cfg, scheduler, statics, state, n_steps, kw,
        extra={
            "replicas": R,
            "scenarios": _tree_digest(scenarios),
            "policies": "none" if policies is None
            else _tree_digest(policies),
        })
    z = sim._telem_zero(cfg.resilience_on, cfg.serving_on)
    acc = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (R,) + jnp.shape(a)), z)
    ticks = 0
    if resume_from is not None:
        tree, ticks = _restore_latest(
            resume_from, {"state": state, "acc": acc}, fp)
        if tree is not None:
            state, acc = tree["state"], tree["acc"]

    kw_items = tuple(sorted(kw.items()))
    while ticks < n_steps:
        n = int(min(seg_ticks, n_steps - ticks))
        if mesh is not None:
            state, acc = fleet._fleet_segment_sharded(
                cfg, statics, scenarios, policies, state, acc, n,
                scheduler, kw_items, mesh, mesh_axis)
        else:
            state, acc = fleet._fleet_segment(
                cfg, statics, scenarios, policies, state, acc, n,
                scheduler, kw_items)
        ticks += n
        if snapshot_dir is not None:
            ckpt.save(snapshot_dir, ticks, {"state": state, "acc": acc},
                      extra_meta={"ticks": ticks, "fingerprint": fp},
                      keep=snapshot_keep)
    return state, jax.vmap(sim._telem_finalize)(acc)


__all__ = [
    "run_fingerprint",
    "check_fingerprint",
    "run_episode_snapshotted",
    "run_fleet_snapshotted",
]
