from repro.checkpoint.ckpt import (
    AsyncCheckpointer,
    latest_step,
    restore,
    restore_sharded,
    save,
    save_sharded,
)
