from repro.checkpoint.ckpt import (
    AsyncCheckpointer,
    latest_step,
    read_meta,
    restore,
    restore_sharded,
    save,
    save_sharded,
)
from repro.checkpoint.episode import (
    check_fingerprint,
    run_episode_snapshotted,
    run_fingerprint,
    run_fleet_snapshotted,
)
