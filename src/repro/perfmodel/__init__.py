from repro.perfmodel.constants import V5E
from repro.perfmodel.roofline import analytic_roofline
from repro.perfmodel.workload_gen import (
    lm_jobs_workload,
    lm_training_job,
    serving_profile,
)
