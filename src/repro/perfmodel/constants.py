"""Hardware constants for the analytic performance model and roofline."""

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str
    peak_flops_bf16: float     # FLOP/s
    hbm_bw: float              # bytes/s
    ici_bw: float              # bytes/s per link
    hbm_bytes: float
    idle_w: float
    dyn_w: float               # extra W at full utilization


V5E = Chip(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16 * 2**30,
    idle_w=90.0,
    dyn_w=130.0,
)
