"""Analytic (Calculon-style) performance model over the assigned archs.

Mirrors the structure of ``repro.models`` layer-by-layer: matmul FLOPs from
exact parameter shapes, attention FLOPs from (causal/windowed) context
length, SSM/LSTM recurrence FLOPs from state sizes; HBM bytes from the
FSDP/TP sharding layout (param gathers, optimizer state, saved residual
stream, logits chunks, KV caches); collective bytes from the parallelism
plan (FSDP gathers + grad reduction + TP/SP boundary collectives + MoE
all-to-all).

Two consumers:
- §Roofline cross-check column (vs the compiled-probe numbers), and
- the workload generator (the paper's "synthetic workloads from
  performance modeling tools" — job duration & power for the RAPS twin).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import (
    ATTN,
    ATTN_LOCAL,
    CROSS,
    MAMBA,
    MLSTM,
    SLSTM,
    ModelConfig,
    ShapeConfig,
)
from repro.models import spec as S
from repro.perfmodel.constants import V5E, Chip


@dataclass
class RooflineEstimate:
    flops_per_dev: float
    bytes_per_dev: float
    collective_bytes_per_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    step_s: float
    dominant: str
    util: float                 # compute_s / step_s
    chip_power_w: float

    def terms(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s}


def _layer_param_count(cfg: ModelConfig, p: int, active: bool) -> float:
    import numpy as np

    specs = S.layer_specs(cfg, p)
    total = 0.0
    for name, sds in specs.items():
        n = float(np.prod(sds.shape))
        if name.startswith("e_w") and active:
            n *= cfg.moe.top_k / max(cfg.moe.n_experts, 1)
        total += n
    return total


def _attn_ctx(cfg: ModelConfig, kind: str, shape: ShapeConfig) -> float:
    """Mean attended context length per query token."""
    s = shape.seq_len
    window = cfg.swa_window if (
        kind == ATTN_LOCAL or (cfg.block_pattern is None and cfg.swa_window)
    ) else 0
    if shape.mode == "decode":
        full = min(s, window) if window else s
        return float(full)
    ctx = s / 2.0
    if window:
        ctx = min(ctx, float(window))
    return ctx


def _layer_flops_per_token(cfg: ModelConfig, p: int, shape: ShapeConfig) -> float:
    """Forward FLOPs per token for layer position p."""
    kind = S.layer_kind_at(cfg, p)
    f = 2.0 * _layer_param_count(cfg, p, active=True)   # matmuls: 2*N
    if kind in (ATTN, ATTN_LOCAL, CROSS):
        ctx = _attn_ctx(cfg, kind, shape)
        f += 4.0 * cfg.n_heads * cfg.hd * ctx           # qk^T + pv
        if kind == CROSS:
            f += 4.0 * cfg.n_heads * cfg.hd * cfg.n_vision_tokens
    if cfg.enc_dec:
        f += 4.0 * cfg.n_heads * cfg.hd * cfg.n_audio_frames
    if kind == MAMBA:
        di, ds = S.d_inner(cfg), cfg.ssm.d_state
        f += 10.0 * di * ds                             # scan update + y
    if kind in (MLSTM,):
        di = S.d_inner(cfg)
        nh = cfg.n_heads
        dh = di // nh
        f += 8.0 * nh * dh * dh                         # state update + read
    if kind == SLSTM:
        f += 12.0 * cfg.d_model
    return f


def analytic_roofline(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    n_chips: int = 256,
    tp: int = 16,
    chip: Chip = V5E,
    remat: bool = True,
    efficiency: float = 0.6,
) -> RooflineEstimate:
    dp = n_chips // tp
    mode = shape.mode
    tokens = shape.global_batch * (1 if mode == "decode" else shape.seq_len)

    fwd = sum(
        _layer_flops_per_token(cfg, p, shape) for p in range(cfg.n_layers)
    )
    # embedding + head
    fwd += 2.0 * cfg.d_model * cfg.vocab
    if cfg.enc_dec and mode != "decode":
        enc_tokens_ratio = cfg.n_audio_frames / max(shape.seq_len, 1)
        fwd *= (1 + 0.5 * enc_tokens_ratio)  # encoder ~ half the stack depth

    # fwd already counts 2*N per token; train = fwd + bwd (2x fwd) +
    # remat recompute (1x fwd) => 4x fwd total (the "8*N*D" of 6*N*D fame)
    if mode == "train":
        total_flops = fwd * tokens * (4.0 if remat else 3.0)
    else:
        total_flops = fwd * tokens

    flops_dev = total_flops / n_chips

    # ---- HBM bytes per device
    n_params = cfg.param_count()
    p_bytes = 0.0
    if mode == "train":
        # ZeRO-3: fp32 shard rw + 2x bf16 gathered use (fwd+bwd) + grads
        opt_mult = 12.0 if n_params < 100e9 else 4.5    # adamw vs adafactor
        p_bytes += n_params * (4.0 + opt_mult) / n_chips
        p_bytes += 2.0 * n_params * 2.0 / tp            # gathered bf16 reads
        # saved residual stream (sequence-parallel sharded)
        act = (shape.global_batch / dp) * shape.seq_len * cfg.d_model * 2.0
        p_bytes += cfg.n_layers * act / tp * 3.0        # save + 2 reads
        # logits chunks
        p_bytes += (shape.global_batch / dp) * shape.seq_len * cfg.vocab * 4.0 / tp
    else:
        p_bytes += n_params * 2.0 / n_chips * (2.0 if mode == "prefill" else 1.0)
        if mode == "decode":
            # read the whole KV cache (+ recurrent states) once per token
            kv = 0.0
            for p in range(cfg.n_layers):
                kind = S.layer_kind_at(cfg, p)
                if kind in (ATTN, ATTN_LOCAL, CROSS):
                    sc = min(shape.seq_len, cfg.swa_window) if (
                        kind == ATTN_LOCAL or
                        (cfg.block_pattern is None and cfg.swa_window)
                    ) else shape.seq_len
                    kv += 2.0 * sc * cfg.n_kv_heads * cfg.hd * 2.0
                if kind == MAMBA:
                    kv += S.d_inner(cfg) * cfg.ssm.d_state * 4.0 * 2.0
                if kind in (MLSTM,):
                    kv += S.d_inner(cfg) * (S.d_inner(cfg) // cfg.n_heads) * 4.0
            p_bytes += shape.global_batch * kv / n_chips
        else:
            act = (shape.global_batch / dp) * shape.seq_len * cfg.d_model * 2.0
            p_bytes += cfg.n_layers * act / tp * 2.0

    # ---- collective bytes per device
    coll = 0.0
    if mode == "train":
        coll += 2.0 * n_params * 2.0 / tp               # FSDP all-gather x2
        coll += n_params * 4.0 / tp                     # grad reduce (dp)
        # TP/SP boundary: ~4 (B,S,D) reshards per layer
        bsd = (shape.global_batch / dp) * shape.seq_len * cfg.d_model * 2.0
        coll += 4.0 * cfg.n_layers * bsd / tp
        if cfg.moe.n_experts:
            moe_layers = cfg.n_layers // cfg.moe.period
            coll += (2.0 * moe_layers * bsd * cfg.moe.top_k * 1.25) / tp
    elif mode == "prefill":
        coll += n_params * 2.0 / tp
        bsd = (shape.global_batch / dp) * shape.seq_len * cfg.d_model * 2.0
        coll += 2.0 * cfg.n_layers * bsd / tp
    else:
        coll += n_params * 2.0 / tp                     # weight gathers
        bd = shape.global_batch * cfg.d_model * 2.0 / dp
        coll += 3.0 * cfg.n_layers * bd

    compute_s = flops_dev / (chip.peak_flops_bf16 * efficiency)
    memory_s = p_bytes / chip.hbm_bw
    collective_s = coll / chip.ici_bw
    step_s = max(compute_s, memory_s, collective_s)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    util = compute_s / max(step_s, 1e-12)
    power = chip.idle_w + util * chip.dyn_w
    return RooflineEstimate(
        flops_per_dev=flops_dev,
        bytes_per_dev=p_bytes,
        collective_bytes_per_dev=coll,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        step_s=step_s,
        dominant=dominant,
        util=util,
        chip_power_w=power,
    )
