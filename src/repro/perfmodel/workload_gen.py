"""Synthetic datacenter workloads from the performance model — the paper's
"can generate synthetic workloads using performance modeling tools, such as
Calculon [11]" path, and its "virtual benchmarking of speculative systems":
LM training/serving jobs over the assigned architectures become RAPS jobs
with durations, utilizations and network traffic derived analytically.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, get_arch
from repro.configs.sim import SimConfig
from repro.perfmodel.constants import V5E
from repro.perfmodel.roofline import analytic_roofline


def lm_training_job(
    arch: str,
    shape_name: str = "train_4k",
    *,
    n_chips: int = 256,
    chips_per_node: int = 4,
    token_budget: float = 2e9,
) -> Dict[str, float]:
    """One LM job: duration + utilization from the roofline estimate."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    est = analytic_roofline(cfg, shape, n_chips=n_chips)
    tokens_per_step = shape.global_batch * (
        1 if shape.mode == "decode" else shape.seq_len
    )
    steps = token_budget / max(tokens_per_step, 1)
    duration_s = steps * est.step_s
    n_nodes = max(n_chips // chips_per_node, 1)
    return {
        "arch": arch,
        "shape": shape_name,
        "n_nodes": n_nodes,
        "duration_s": duration_s,
        "gpu_util": est.util,                # accelerator busy fraction
        "cpu_util": 0.25 + 0.1 * est.util,   # host input pipeline
        "net_tx_gbps": est.collective_bytes_per_dev
        * chips_per_node / max(est.step_s, 1e-9) / 1e9,
        "chip_power_w": est.chip_power_w,
        "step_s": est.step_s,
        "dominant": est.dominant,
    }


def serving_profile(
    arch: str = "gemma3-1b",
    *,
    n_chips: int = 16,
    chips_per_node: int = 4,
    gen_tokens: int = 256,
) -> Dict[str, float]:
    """Serving-twin knobs for one LM deployment from the roofline model.

    Derives the per-request prefill/decode split, the end-to-end service
    time, and the per-node power profile for ``core.serving`` from the
    analytic estimates: a request is one prefill step plus ``gen_tokens``
    decode steps on an ``n_chips`` slice. Returns kwargs consumable by
    ``SimConfig`` (``tiny_cluster(**serving_profile(...),
    serving_enabled=True, serving_nodes=...)``).
    """
    pf = analytic_roofline(get_arch(arch), SHAPES["prefill_32k"],
                           n_chips=n_chips)
    dc = analytic_roofline(get_arch(arch), SHAPES["decode_32k"],
                           n_chips=n_chips)
    prefill_s = pf.step_s
    decode_s = gen_tokens * dc.step_s
    service_s = prefill_s + decode_s
    n_nodes = max(n_chips // chips_per_node, 1)
    return {
        "serving_service_s": service_s,
        "serving_prefill_frac": prefill_s / max(service_s, 1e-12),
        "serving_prefill_util": min(pf.util, 1.0),
        "serving_decode_util": min(dc.util, 1.0),
        # batched decode: the deployment serves global_batch concurrent
        # streams, split across the slice's nodes
        "serving_concurrency": SHAPES["decode_32k"].global_batch
        / n_nodes,
        "serving_node_idle_w": chips_per_node * V5E.idle_w,
        "serving_node_dyn_w": chips_per_node * V5E.dyn_w,
    }


def lm_jobs_workload(
    cfg: SimConfig,
    archs: List[str],
    *,
    horizon_s: float = 7200.0,
    n_jobs: int = 32,
    seed: int = 0,
    chips_per_node: int = 4,
) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    """A RAPS workload of LM jobs (mixed archs/scales) for the twin.

    Returns (jobs, trace bank) exactly like ``synth_trace.synth_workload``.
    """
    rng = np.random.default_rng(seed)
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    submit, dur, n_nodes, gpu_u, cpu_u, net = [], [], [], [], [], []
    for j in range(n_jobs):
        arch = archs[int(rng.integers(0, len(archs)))]
        shape = shapes[int(rng.integers(0, len(shapes)))]
        chips = int(2 ** rng.integers(2, 7))  # 4..64 chips
        tokens = float(10 ** rng.uniform(7.5, 9.5))
        job = lm_training_job(arch, shape, n_chips=max(chips, 16),
                              chips_per_node=chips_per_node,
                              token_budget=tokens)
        submit.append(rng.uniform(0, horizon_s * 0.7))
        dur.append(min(max(job["duration_s"], 60.0), horizon_s))
        n_nodes.append(min(max(chips // chips_per_node, 1),
                           cfg.max_nodes_per_job))
        gpu_u.append(min(job["gpu_util"], 1.0))
        cpu_u.append(min(job["cpu_util"], 1.0))
        net.append(min(job["net_tx_gbps"], 100.0))
    submit = np.sort(np.array(submit, np.float32))
    dur = np.array(dur, np.float32)
    n_nodes = np.array(n_nodes, np.int32)

    gpu_type = cfg.node_types[0]
    req = np.stack([
        np.full(n_jobs, max(gpu_type.cpu_cores // 2, 1), np.float32),
        np.full(n_jobs, gpu_type.gpus, np.float32),
        np.full(n_jobs, gpu_type.mem_gb / 2, np.float32),
    ])
    Q = max(int(np.ceil(dur.max() / cfg.trace_quanta)) + 1, 8)
    Jmax = cfg.max_jobs
    bank = {
        "cpu": np.zeros((Jmax, Q), np.float32),
        "gpu": np.zeros((Jmax, Q), np.float32),
        "net_tx": np.zeros((Jmax,), np.float32),
    }
    t = np.arange(Q)[None, :] * cfg.trace_quanta
    ramp = np.clip(t / 120.0, 0, 1)
    for j in range(n_jobs):
        # training power fluctuates step-to-step (the paper's "large power
        # swings" motivation): square-wave-ish modulation around the mean
        wob = 0.06 * np.sign(np.sin(2 * np.pi * t[0] / 37.0))
        bank["gpu"][j] = np.clip((gpu_u[j] + wob) * ramp[0], 0, 1)
        bank["cpu"][j] = np.clip(cpu_u[j] * ramp[0], 0, 1)
        bank["net_tx"][j] = net[j]
    jobs = {
        "submit_t": submit,
        "dur": dur,
        "n_nodes": n_nodes,
        "req": req,
        "priority": submit,
    }
    return jobs, bank
