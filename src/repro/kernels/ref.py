"""Pure-jnp oracles for every Pallas kernel (and shared model math).

These are the correctness references the kernel tests sweep against, and
the XLA fallback paths the models use on CPU / in the dry-run.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# flash attention oracle: small, fully materialized
def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,Sq,H,hd); k,v: (B,Sk,Kv,hd). Returns (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits /= jnp.sqrt(jnp.float32(hd))
    dpos = (jnp.arange(sq)[:, None] + (sk - sq)) - jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= dpos >= 0
    if window > 0:
        mask &= dpos < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# selective scan (mamba) oracle
def selective_scan_ref(x, dt, A, B, C, *, chunk: int = 64):
    """Chunked associative selective scan.

    x:  (Ba, S, di)   gated input
    dt: (Ba, S, di)   positive step sizes (already softplus'd)
    A:  (di, ds)      negative state matrix (A = -exp(A_log))
    B:  (Ba, S, ds)   input mix
    C:  (Ba, S, ds)   output mix
    returns y: (Ba, S, di), final_state: (Ba, di, ds)

    Recurrence: s_t = exp(dt_t * A) * s_{t-1} + dt_t * B_t * x_t
                y_t = sum_ds (s_t * C_t)
    """
    ba, s, di = x.shape
    ds = A.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        # dt=0 on padded steps -> decay=1, contribution=0: state unchanged
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s_orig, s = s, s + pad
    nchunks = s // chunk

    xr = x.reshape(ba, nchunks, chunk, di)
    dtr = dt.reshape(ba, nchunks, chunk, di)
    Br = B.reshape(ba, nchunks, chunk, ds)
    Cr = C.reshape(ba, nchunks, chunk, ds)

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_body(state, inp):
        xc, dtc, Bc, Cc = inp  # (Ba, chunk, ...)
        a = jnp.exp(dtc[..., None] * A)                        # (Ba,c,di,ds)
        b = (dtc * xc)[..., None] * Bc[:, :, None, :]          # (Ba,c,di,ds)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        st = a_cum * state[:, None] + b_cum                    # (Ba,c,di,ds)
        y = jnp.einsum("bcds,bcs->bcd", st, Cc)
        return st[:, -1], y

    def scan_body(state, inp):
        state, y = chunk_body(state, inp)
        return state, y

    s0 = jnp.zeros((ba, di, ds), x.dtype)
    final, ys = jax.lax.scan(
        scan_body,
        s0,
        (
            xr.transpose(1, 0, 2, 3),
            dtr.transpose(1, 0, 2, 3),
            Br.transpose(1, 0, 2, 3),
            Cr.transpose(1, 0, 2, 3),
        ),
    )
    y = ys.transpose(1, 0, 2, 3).reshape(ba, s, di)
    return y[:, :s_orig], final


def selective_scan_step_ref(state, x, dt, A, B, C):
    """Single decode step. state: (Ba,di,ds); x,dt: (Ba,di); B,C: (Ba,ds)."""
    a = jnp.exp(dt[..., None] * A)
    state = a * state + (dt * x)[..., None] * B[:, None, :]
    y = jnp.einsum("bds,bs->bd", state, C)
    return y, state


# ---------------------------------------------------------------------------
# node power chain oracle (the simulator's per-step hot loop, which runs for
# every node of every vmapped environment): IT power from utilization
# fractions -> rectifier-efficiency parabola -> conversion loss.
def node_power_ref(
    cpu_frac,         # (..., N) utilized fraction of node CPU capacity
    gpu_frac,         # (..., N)
    idle_w,           # (N,)
    cpu_dyn_w,        # (N,)
    gpu_dyn_w,        # (N,)
    node_up,          # (..., N) 1.0 if node is healthy
    node_max_w,       # (N,)
    *,
    rect_peak: float,
    rect_load: float,
    rect_curv: float,
    conv_eff: float,
):
    """Returns (node_it_w, node_input_w) with the leading env batch dims of
    cpu_frac. eta(load) = clip(peak - curv*(load - peak_load)^2, 0.5, 1)."""
    it = idle_w + cpu_frac * cpu_dyn_w + gpu_frac * gpu_dyn_w
    it = it * node_up
    load_frac = jnp.clip(it / jnp.maximum(node_max_w, 1.0), 0.0, 1.2)
    eta_rect = jnp.clip(
        rect_peak - rect_curv * jnp.square(load_frac - rect_load), 0.5, 1.0
    )
    input_w = it / (eta_rect * conv_eff)
    return it, input_w


def rack_thermal_ref(
    node_heat_w,      # (N,) per-node input power (all of it becomes heat)
    node_rack,        # (N,) int32 rack id per node, in [0, R)
    rack_outlet_c,    # (R,) current outlet temperatures
    supply_c,         # scalar cooling supply temperature
    rack_r_th,        # (R,) degC per W of rack heat
    *,
    alpha: float,     # per-tick RC relaxation factor 1 - exp(-dt/tau)
):
    """Fused rack-heat scatter + first-order RC outlet-temp update oracle.

    T' = T + alpha * (supply + heat * R_th - T). The node->rack reduction
    uses the same one-hot matmul as the Pallas kernel (not segment_sum) so
    both paths accumulate in the identical order and agree bitwise on CPU.
    Returns (new_outlet_c, rack_heat_w), each (R,).
    """
    r = rack_outlet_c.shape[0]
    onehot = (node_rack[:, None] == jnp.arange(r, dtype=jnp.int32)[None, :])
    heat = jnp.dot(node_heat_w[None, :].astype(jnp.float32),
                   onehot.astype(jnp.float32),
                   preferred_element_type=jnp.float32)[0]
    t_ss = supply_c + heat * rack_r_th
    new_t = rack_outlet_c + jnp.float32(alpha) * (t_ss - rack_outlet_c)
    return new_t, heat


def power_scatter_ref(
    place_flat,       # (J*K,) int32 node ids, -1 = unused placement slot
    cpu_abs,          # (J*K,) absolute utilized cpu cores per slot
    gpu_abs,          # (J*K,) absolute utilized gpus per slot
    cap_cpu,          # (N,) node cpu capacity
    cap_gpu,          # (N,)
    idle_w,           # (N,)
    cpu_dyn_w,        # (N,)
    gpu_dyn_w,        # (N,)
    node_up,          # (N,) 1.0 if node is healthy
    node_max_w,       # (N,)
    *,
    rect_peak: float,
    rect_load: float,
    rect_curv: float,
    conv_eff: float,
):
    """Fused placement-scatter + power-chain oracle: job table -> per-node
    IT/input power and load fractions in one logical pass.

    Returns (node_it_w, node_input_w, cpu_frac, gpu_frac), each (N,).
    """
    N = idle_w.shape[0]
    safe = jnp.where(place_flat >= 0, place_flat, 0)   # invalid slots add 0
    cpu_node = jnp.zeros((N,), jnp.float32).at[safe].add(cpu_abs, mode="drop")
    gpu_node = jnp.zeros((N,), jnp.float32).at[safe].add(gpu_abs, mode="drop")
    cpu_frac = jnp.clip(cpu_node / jnp.maximum(cap_cpu, 1e-6), 0.0, 1.0)
    gpu_frac = jnp.clip(gpu_node / jnp.maximum(cap_gpu, 1e-6), 0.0, 1.0)
    it, input_w = node_power_ref(
        cpu_frac, gpu_frac, idle_w, cpu_dyn_w, gpu_dyn_w, node_up,
        node_max_w, rect_peak=rect_peak, rect_load=rect_load,
        rect_curv=rect_curv, conv_eff=conv_eff,
    )
    return it, input_w, cpu_frac, gpu_frac
