"""Per-rack thermal-update Pallas kernel (the cooling loop's hot pass).

With thermals enabled the twin folds a node->rack heat reduction plus a
first-order RC temperature relaxation into every simulation tick — and the
macro engine re-runs it once per fast-forwarded tick, so it sits on the
same per-tick critical path as the power chain. This kernel fuses the
scatter and the RC update into one VMEM pass (grid = rack blocks): each
rack block builds its heat from the (N,) node table via a one-hot
contraction on the MXU — the same trick as
``node_power.power_scatter_pallas`` — and relaxes its temperatures without
materializing the (R,) heat intermediate in HBM.

Validated against ``ref.rack_thermal_ref`` (bitwise on CPU: both paths
reduce through the identical one-hot matmul).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rack_thermal_kernel(
    heat_ref, rack_ref,          # (Np,) node heat + rack ids, full
    sup_ref,                     # (1,) supply temperature
    t_ref, rth_ref,              # (br,) per-rack blocks
    newt_ref, rheat_ref,         # (br,) outputs
    *,
    block_r: int,
    alpha: float,
):
    j = pl.program_id(0)
    ids = j * block_r + jax.lax.broadcasted_iota(jnp.int32, (1, block_r), 1)
    onehot = (rack_ref[...][:, None] == ids).astype(jnp.float32)   # (Np, br)
    heat = jnp.dot(heat_ref[...][None, :].astype(jnp.float32), onehot,
                   preferred_element_type=jnp.float32)[0]
    t = t_ref[...].astype(jnp.float32)
    t_ss = sup_ref[0] + heat * rth_ref[...]
    new_t = t + jnp.float32(alpha) * (t_ss - t)
    newt_ref[...] = new_t.astype(newt_ref.dtype)
    rheat_ref[...] = heat.astype(rheat_ref.dtype)


def rack_thermal_pallas(
    node_heat_w: jax.Array,    # (N,) per-node input power
    node_rack: jax.Array,      # (N,) int32 rack ids
    rack_outlet_c: jax.Array,  # (R,)
    supply_c: jax.Array,       # scalar
    rack_r_th: jax.Array,      # (R,)
    *,
    alpha: float,
    block_r: int = 128,
    interpret: bool = True,
):
    """Returns (new_outlet_c, rack_heat_w), each (R,). vmap adds a leading
    grid dim, so vectorized replicas batch for free."""
    n = node_heat_w.shape[0]
    r = rack_outlet_c.shape[0]
    block_r = min(block_r, r)
    pad_r = (-r) % block_r
    if pad_r:
        padR = lambda a: jnp.pad(a, (0, pad_r))
        rack_outlet_c, rack_r_th = padR(rack_outlet_c), padR(rack_r_th)
    pad_n = (-n) % 128                   # lane-align the node table
    if pad_n:
        # padded nodes get rack id -1 -> match no one-hot column, heat 0
        node_heat_w = jnp.pad(node_heat_w, (0, pad_n))
        node_rack = jnp.pad(node_rack, (0, pad_n), constant_values=-1)
    nb = (r + pad_r) // block_r

    kernel = functools.partial(_rack_thermal_kernel, block_r=block_r,
                               alpha=alpha)
    full = pl.BlockSpec((n + pad_n,), lambda j: (0,))
    blk = pl.BlockSpec((block_r,), lambda j: (j,))
    new_t, rheat = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[full, full, pl.BlockSpec((1,), lambda j: (0,)), blk, blk],
        out_specs=[blk, blk],
        out_shape=[jax.ShapeDtypeStruct((r + pad_r,), jnp.float32)] * 2,
        interpret=interpret,
    )(node_heat_w, node_rack, jnp.reshape(supply_c, (1,)).astype(jnp.float32),
      rack_outlet_c, rack_r_th)
    return new_t[:r], rheat[:r]
