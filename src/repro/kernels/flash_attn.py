"""Flash attention forward kernel (Pallas, TPU target).

TPU adaptation of the FlashAttention online-softmax contraction:
- grid = (batch*kv_heads*rep, num_q_blocks, num_kv_blocks); the last grid
  axis is sequential on TPU, so the (m, l, acc) running statistics live in
  VMEM scratch that persists across KV blocks;
- BlockSpecs tile Q/K/V into (block_q x head_dim)/(block_k x head_dim)
  VMEM tiles (head_dim = 64..256 = MXU-friendly lane counts; block sizes
  default 512/1024 so a (bq x bk) f32 score tile ~2 MB fits VMEM);
- GQA without materializing repeated KV: the KV index_map folds the
  query-group factor (kv head = bh // rep);
- causal + sliding-window masks are applied per-tile from absolute
  positions (the fully-masked-tile case is ``pl.when``-skipped).

Gradients: ``ops.flash_attention`` wraps this with jax.custom_vjp whose
backward is the jnp chunked-online-softmax reference (same math, XLA),
keeping training differentiable everywhere while the TPU forward uses the
kernel. Validated against ``ref.attention_ref`` in interpret mode.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(
    q_ref, k_ref, v_ref,            # (1, bq, hd), (1, bk, hd), (1, bk, hd)
    o_ref,                          # (1, bq, hd)
    acc_ref, m_ref, l_ref,          # VMEM scratch
    *,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    sm_scale: float,
    q_off: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q_pos = qi * block_q + q_off + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        k_pos = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        d = q_pos - k_pos
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                                   # (bq, bk)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= d >= 0
        if window > 0:
            mask &= d < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # tile-level skip: tiles entirely above the causal diagonal do no
        # work (the TPU grid still visits them; compute is gated)
        live = (kj * block_k) <= (qi * block_q + q_off + block_q - 1)
        if window > 0:
            live &= (kj + 1) * block_k > (qi * block_q + q_off - window)
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(kj == nk - 1)
    def _out():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,       # (B, Sq, H, hd)
    k: jax.Array,       # (B, Sk, Kv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k

    # layout: fold heads into the leading grid axis
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, sk, hd)

    kernel = functools.partial(
        _fwd_kernel, causal=causal, window=window,
        block_q=block_q, block_k=block_k, sm_scale=1.0 / math.sqrt(hd),
        q_off=sk - sq,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, i, j, rep=rep: (bh // rep, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, i, j, rep=rep: (bh // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            # (bq, hd) f32 accumulator + (bq,) running max / denom in VMEM
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
