"""Selective-scan (Mamba) Pallas kernel, TPU target.

TPU adaptation of the CUDA selective-scan: instead of a warp-level scan,
the sequence is chunked; the grid is (batch, d_inner blocks, chunks) with
the innermost axis sequential, carrying the (bdi, d_state) SSM state in
VMEM scratch across chunks. The channel dimension is tiled to lanes
(bdi = 512 default, multiple of 128); d_state (16) rides the sublane dim.
Within a chunk the recurrence s_t = exp(dt*A)*s + dt*B*x runs as a
``fori_loop`` over time steps entirely in VMEM/registers — no HBM traffic
for intermediate states, one HBM read per input element and one write per
output element (the memory-bound optimum for this op).

Validated against ``ref.selective_scan_ref`` (chunked associative scan).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref,   # (1,L,bdi),(1,L,bdi),(bdi,ds),(1,L,ds),(1,L,ds)
    y_ref, sf_ref,                        # (1,L,bdi), (1,bdi,ds) final state
    s_ref,                                # VMEM scratch (bdi, ds) f32
    *,
    chunk: int,
):
    cj = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(cj == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    a = a_ref[...]                                     # (bdi, ds)

    def body(t, s):
        xt = x_ref[0, t, :].astype(jnp.float32)        # (bdi,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)      # (bdi,)
        bt = b_ref[0, t, :].astype(jnp.float32)        # (ds,)
        ct = c_ref[0, t, :].astype(jnp.float32)        # (ds,)
        decay = jnp.exp(dtt[:, None] * a)              # (bdi, ds)
        s = decay * s + (dtt * xt)[:, None] * bt[None, :]
        y = jnp.sum(s * ct[None, :], axis=1)           # (bdi,)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return s

    s = jax.lax.fori_loop(0, chunk, body, s_ref[...])
    s_ref[...] = s

    @pl.when(cj == nc - 1)
    def _final():
        sf_ref[0, ...] = s_ref[...]


def selective_scan_pallas(
    x: jax.Array,        # (Ba, S, di) f32
    dt: jax.Array,       # (Ba, S, di)
    A: jax.Array,        # (di, ds)
    B: jax.Array,        # (Ba, S, ds)
    C: jax.Array,        # (Ba, S, ds)
    *,
    chunk: int = 64,
    block_di: int = 512,
    interpret: bool = True,
):
    ba, s, di = x.shape
    ds = A.shape[-1]
    chunk = min(chunk, s)
    block_di = min(block_di, di)
    assert di % block_di == 0
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s_orig, s = s, s + pad
    nc, ndi = s // chunk, di // block_di

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    y, sf = pl.pallas_call(
        kernel,
        grid=(ba, ndi, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((block_di, ds), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, block_di, ds), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ba, s, di), x.dtype),
            jax.ShapeDtypeStruct((ba, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_di, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y[:, :s_orig], sf
