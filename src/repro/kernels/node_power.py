"""Node power-chain Pallas kernel (the simulator's per-step hot loop).

For batched-RL rollouts the twin evaluates the power chain for every node
of every vectorized environment every step: (E, N) utilization fractions
-> IT power -> rectifier-efficiency parabola -> conversion loss. Fused
into a single VMEM pass (grid = (E, node blocks)): six input streams are
read once from HBM, two outputs written once — no intermediate arrays,
which is the memory-bound optimum (the XLA path materializes the eta and
load_frac temporaries).

Validated against ``ref.node_power_ref``. ``power_scatter_pallas`` goes
one step further and fuses the job-table placement scatter into the same
pass (oracle: ``ref.power_scatter_ref``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _power_kernel(
    cpu_ref, gpu_ref, up_ref,            # (1, bn)
    idle_ref, cdyn_ref, gdyn_ref, maxw_ref,   # (bn,)
    it_ref, inp_ref,                     # (1, bn)
    *,
    rect_peak: float,
    rect_load: float,
    rect_curv: float,
    conv_eff: float,
):
    cpu = cpu_ref[0].astype(jnp.float32)
    gpu = gpu_ref[0].astype(jnp.float32)
    up = up_ref[0].astype(jnp.float32)
    it = (idle_ref[...] + cpu * cdyn_ref[...] + gpu * gdyn_ref[...]) * up
    load = jnp.clip(it / jnp.maximum(maxw_ref[...], 1.0), 0.0, 1.2)
    eta = jnp.clip(rect_peak - rect_curv * jnp.square(load - rect_load), 0.5, 1.0)
    it_ref[0, ...] = it.astype(it_ref.dtype)
    inp_ref[0, ...] = (it / (eta * conv_eff)).astype(inp_ref.dtype)


def node_power_pallas(
    cpu_frac: jax.Array,      # (E, N)
    gpu_frac: jax.Array,      # (E, N)
    idle_w: jax.Array,        # (N,)
    cpu_dyn_w: jax.Array,
    gpu_dyn_w: jax.Array,
    node_up: jax.Array,       # (E, N)
    node_max_w: jax.Array,    # (N,)
    *,
    rect_peak: float,
    rect_load: float,
    rect_curv: float,
    conv_eff: float,
    block_n: int = 512,
    interpret: bool = True,
):
    squeeze = cpu_frac.ndim == 1
    if squeeze:
        cpu_frac, gpu_frac, node_up = (
            cpu_frac[None], gpu_frac[None], node_up[None]
        )
    e, n = cpu_frac.shape
    block_n = min(block_n, n)
    # pad N to a block multiple (node_max_w padding of 1 avoids div-by-0)
    pad = (-n) % block_n
    if pad:
        padE = lambda a: jnp.pad(a, ((0, 0), (0, pad)))
        padN = lambda a, v=0.0: jnp.pad(a, (0, pad), constant_values=v)
        cpu_frac, gpu_frac, node_up = padE(cpu_frac), padE(gpu_frac), padE(node_up)
        idle_w, cpu_dyn_w, gpu_dyn_w = padN(idle_w), padN(cpu_dyn_w), padN(gpu_dyn_w)
        node_max_w = padN(node_max_w, 1.0)
    nb = (n + pad) // block_n

    kernel = functools.partial(
        _power_kernel, rect_peak=rect_peak, rect_load=rect_load,
        rect_curv=rect_curv, conv_eff=conv_eff,
    )
    it, inp = pl.pallas_call(
        kernel,
        grid=(e, nb),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((e, n + pad), jnp.float32),
        ],
        interpret=interpret,
    )(cpu_frac, gpu_frac, node_up, idle_w, cpu_dyn_w, gpu_dyn_w, node_max_w)
    it, inp = it[:, :n], inp[:, :n]
    if squeeze:
        it, inp = it[0], inp[0]
    return it, inp


# ---------------------------------------------------------------------------
# fused placement-scatter + power chain: job table -> per-node IT power in
# one pass. The host-side scatter-add (node_loads) materialized two (N,)
# load arrays in HBM before the power kernel could run; here each node
# block builds its loads from the (J*K,) placement table via a one-hot
# contraction on the MXU and applies the power chain without leaving VMEM.
def _power_scatter_kernel(
    place_ref, cabs_ref, gabs_ref,                 # (JK,)
    capc_ref, capg_ref, idle_ref, cdyn_ref, gdyn_ref, up_ref, maxw_ref,  # (bn,)
    it_ref, inp_ref, cf_ref, gf_ref,               # (bn,)
    *,
    block_n: int,
    rect_peak: float,
    rect_load: float,
    rect_curv: float,
    conv_eff: float,
):
    j = pl.program_id(0)
    ids = j * block_n + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    onehot = (place_ref[...][:, None] == ids).astype(jnp.float32)  # (JK, bn)
    cpu_node = jnp.dot(cabs_ref[...][None, :], onehot,
                       preferred_element_type=jnp.float32)[0]
    gpu_node = jnp.dot(gabs_ref[...][None, :], onehot,
                       preferred_element_type=jnp.float32)[0]
    cf = jnp.clip(cpu_node / jnp.maximum(capc_ref[...], 1e-6), 0.0, 1.0)
    gf = jnp.clip(gpu_node / jnp.maximum(capg_ref[...], 1e-6), 0.0, 1.0)
    it = (idle_ref[...] + cf * cdyn_ref[...] + gf * gdyn_ref[...]) * up_ref[...]
    load = jnp.clip(it / jnp.maximum(maxw_ref[...], 1.0), 0.0, 1.2)
    eta = jnp.clip(rect_peak - rect_curv * jnp.square(load - rect_load), 0.5, 1.0)
    it_ref[...] = it.astype(it_ref.dtype)
    inp_ref[...] = (it / (eta * conv_eff)).astype(inp_ref.dtype)
    cf_ref[...] = cf.astype(cf_ref.dtype)
    gf_ref[...] = gf.astype(gf_ref.dtype)


def power_scatter_pallas(
    place_flat: jax.Array,    # (JK,) int32 node ids; -1 = unused slot
    cpu_abs: jax.Array,       # (JK,) utilized cpu cores per slot
    gpu_abs: jax.Array,       # (JK,)
    cap_cpu: jax.Array,       # (N,)
    cap_gpu: jax.Array,       # (N,)
    idle_w: jax.Array,        # (N,)
    cpu_dyn_w: jax.Array,
    gpu_dyn_w: jax.Array,
    node_up: jax.Array,       # (N,)
    node_max_w: jax.Array,    # (N,)
    *,
    rect_peak: float,
    rect_load: float,
    rect_curv: float,
    conv_eff: float,
    block_n: int = 128,
    interpret: bool = True,
):
    """Returns (node_it_w, node_input_w, cpu_frac, gpu_frac), each (N,).

    Validated against ``ref.power_scatter_ref``. vmap adds a leading grid
    dim, so the vectorized twin batches replicas for free.
    """
    n = idle_w.shape[0]
    jk = place_flat.shape[0]
    block_n = min(block_n, n)
    pad_n = (-n) % block_n
    if pad_n:
        padN = lambda a, v=0.0: jnp.pad(a, (0, pad_n), constant_values=v)
        cap_cpu, cap_gpu = padN(cap_cpu), padN(cap_gpu)
        idle_w, cpu_dyn_w, gpu_dyn_w = (
            padN(idle_w), padN(cpu_dyn_w), padN(gpu_dyn_w))
        node_up, node_max_w = padN(node_up), padN(node_max_w, 1.0)
    pad_jk = (-jk) % 128                 # lane-align the placement table
    if pad_jk:
        place_flat = jnp.pad(place_flat, (0, pad_jk), constant_values=-1)
        cpu_abs = jnp.pad(cpu_abs, (0, pad_jk))
        gpu_abs = jnp.pad(gpu_abs, (0, pad_jk))
    nb = (n + pad_n) // block_n

    kernel = functools.partial(
        _power_scatter_kernel, block_n=block_n, rect_peak=rect_peak,
        rect_load=rect_load, rect_curv=rect_curv, conv_eff=conv_eff,
    )
    full = pl.BlockSpec((jk + pad_jk,), lambda j: (0,))
    blk = pl.BlockSpec((block_n,), lambda j: (j,))
    outs = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[full, full, full] + [blk] * 7,
        out_specs=[blk] * 4,
        out_shape=[jax.ShapeDtypeStruct((n + pad_n,), jnp.float32)] * 4,
        interpret=interpret,
    )(place_flat, cpu_abs, gpu_abs, cap_cpu, cap_gpu, idle_w, cpu_dyn_w,
      gpu_dyn_w, node_up, node_max_w)
    return tuple(o[:n] for o in outs)
