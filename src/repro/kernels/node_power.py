"""Node power-chain Pallas kernel (the simulator's per-step hot loop).

For batched-RL rollouts the twin evaluates the power chain for every node
of every vectorized environment every step: (E, N) utilization fractions
-> IT power -> rectifier-efficiency parabola -> conversion loss. Fused
into a single VMEM pass (grid = (E, node blocks)): six input streams are
read once from HBM, two outputs written once — no intermediate arrays,
which is the memory-bound optimum (the XLA path materializes the eta and
load_frac temporaries).

Validated against ``ref.node_power_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _power_kernel(
    cpu_ref, gpu_ref, up_ref,            # (1, bn)
    idle_ref, cdyn_ref, gdyn_ref, maxw_ref,   # (bn,)
    it_ref, inp_ref,                     # (1, bn)
    *,
    rect_peak: float,
    rect_load: float,
    rect_curv: float,
    conv_eff: float,
):
    cpu = cpu_ref[0].astype(jnp.float32)
    gpu = gpu_ref[0].astype(jnp.float32)
    up = up_ref[0].astype(jnp.float32)
    it = (idle_ref[...] + cpu * cdyn_ref[...] + gpu * gdyn_ref[...]) * up
    load = jnp.clip(it / jnp.maximum(maxw_ref[...], 1.0), 0.0, 1.2)
    eta = jnp.clip(rect_peak - rect_curv * jnp.square(load - rect_load), 0.5, 1.0)
    it_ref[0, ...] = it.astype(it_ref.dtype)
    inp_ref[0, ...] = (it / (eta * conv_eff)).astype(inp_ref.dtype)


def node_power_pallas(
    cpu_frac: jax.Array,      # (E, N)
    gpu_frac: jax.Array,      # (E, N)
    idle_w: jax.Array,        # (N,)
    cpu_dyn_w: jax.Array,
    gpu_dyn_w: jax.Array,
    node_up: jax.Array,       # (E, N)
    node_max_w: jax.Array,    # (N,)
    *,
    rect_peak: float,
    rect_load: float,
    rect_curv: float,
    conv_eff: float,
    block_n: int = 512,
    interpret: bool = True,
):
    squeeze = cpu_frac.ndim == 1
    if squeeze:
        cpu_frac, gpu_frac, node_up = (
            cpu_frac[None], gpu_frac[None], node_up[None]
        )
    e, n = cpu_frac.shape
    block_n = min(block_n, n)
    # pad N to a block multiple (node_max_w padding of 1 avoids div-by-0)
    pad = (-n) % block_n
    if pad:
        padE = lambda a: jnp.pad(a, ((0, 0), (0, pad)))
        padN = lambda a, v=0.0: jnp.pad(a, (0, pad), constant_values=v)
        cpu_frac, gpu_frac, node_up = padE(cpu_frac), padE(gpu_frac), padE(node_up)
        idle_w, cpu_dyn_w, gpu_dyn_w = padN(idle_w), padN(cpu_dyn_w), padN(gpu_dyn_w)
        node_max_w = padN(node_max_w, 1.0)
    nb = (n + pad) // block_n

    kernel = functools.partial(
        _power_kernel, rect_peak=rect_peak, rect_load=rect_load,
        rect_curv=rect_curv, conv_eff=conv_eff,
    )
    it, inp = pl.pallas_call(
        kernel,
        grid=(e, nb),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((e, n + pad), jnp.float32),
        ],
        interpret=interpret,
    )(cpu_frac, gpu_frac, node_up, idle_w, cpu_dyn_w, gpu_dyn_w, node_max_w)
    it, inp = it[:, :n], inp[:, :n]
    if squeeze:
        it, inp = it[0], inp[0]
    return it, inp
