"""Jit'd public wrappers around the Pallas kernels.

- ``interpret`` defaults to True off-TPU (this container is CPU-only; on a
  real TPU set REPRO_PALLAS_INTERPRET=0 or pass interpret=False).
- ``flash_attention`` is differentiable: forward = Pallas kernel, backward
  = jax.vjp through the jnp chunked-online-softmax reference (identical
  math; the TPU backward kernel is an optimization left to ops parity).
"""

from __future__ import annotations

import functools
import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attn import flash_attention_fwd
from repro.kernels.mamba_scan import selective_scan_pallas
from repro.kernels.node_power import node_power_pallas, power_scatter_pallas
from repro.kernels.rack_thermal import rack_thermal_pallas


def _default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, window=0, block_q=512, block_k=1024):
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_default_interpret(),
    )


def _fa_fwd(q, k, v, causal, window, block_q, block_k):
    out = flash_attention(q, k, v, causal, window, block_q, block_k)
    return out, (q, k, v)


def _fa_bwd(causal, window, block_q, block_k, res, g):
    q, k, v = res
    from repro.models.layers import attention_chunked

    def f(q, k, v):
        return attention_chunked(
            q, k, v, causal=causal, window=window,
            block_q=block_q, block_k=block_k,
        )

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(5,))
def selective_scan(x, dt, A, B, C, chunk=64):
    return selective_scan_pallas(
        x, dt, A, B, C, chunk=chunk, interpret=_default_interpret()
    )


def _ss_fwd(x, dt, A, B, C, chunk):
    out = selective_scan(x, dt, A, B, C, chunk)
    return out, (x, dt, A, B, C)


def _ss_bwd(chunk, res, g):
    x, dt, A, B, C = res
    gy, gs = g

    def f(x, dt, A, B, C):
        return _ref.selective_scan_ref(x, dt, A, B, C, chunk=chunk)

    _, vjp = jax.vjp(f, x, dt, A, B, C)
    return vjp((gy, gs))


selective_scan.defvjp(_ss_fwd, _ss_bwd)


# ---------------------------------------------------------------------------
def node_power(cpu_frac, gpu_frac, idle_w, cpu_dyn_w, gpu_dyn_w, node_up,
               node_max_w, *, rect_peak, rect_load, rect_curv, conv_eff):
    return node_power_pallas(
        cpu_frac, gpu_frac, idle_w, cpu_dyn_w, gpu_dyn_w, node_up, node_max_w,
        rect_peak=rect_peak, rect_load=rect_load, rect_curv=rect_curv,
        conv_eff=conv_eff, interpret=_default_interpret(),
    )


def power_scatter(place_flat, cpu_abs, gpu_abs, cap_cpu, cap_gpu, idle_w,
                  cpu_dyn_w, gpu_dyn_w, node_up, node_max_w, *,
                  rect_peak, rect_load, rect_curv, conv_eff):
    """Fused placement-scatter + power chain (job table -> per-node power).
    Returns (node_it_w, node_input_w, cpu_frac, gpu_frac)."""
    return power_scatter_pallas(
        place_flat, cpu_abs, gpu_abs, cap_cpu, cap_gpu, idle_w, cpu_dyn_w,
        gpu_dyn_w, node_up, node_max_w,
        rect_peak=rect_peak, rect_load=rect_load, rect_curv=rect_curv,
        conv_eff=conv_eff, interpret=_default_interpret(),
    )


def rack_thermal(node_heat_w, node_rack, rack_outlet_c, supply_c, rack_r_th,
                 *, alpha):
    """Fused rack-heat scatter + RC outlet-temp update (core.thermal).
    Returns (new_outlet_c, rack_heat_w)."""
    return rack_thermal_pallas(
        node_heat_w, node_rack, rack_outlet_c, supply_c, rack_r_th,
        alpha=alpha, interpret=_default_interpret(),
    )
