"""Scheduled demand-response events: time-windowed facility power caps.

A ``CapSchedule`` holds up to E events, each ``[start_t, end_t)`` with a
facility-power cap in watts, plus a standing base cap. ``power_cap_at``
returns the effective cap at time t (the tightest of base + active events),
with 0.0 meaning "uncapped" — matching the legacy ``cfg.power_cap_w``
convention consumed by the DVFS throttle in ``core/sim.py``.

Fixed shape (E is padded, inactive slots have cap 0) so schedules vmap
across fleet replicas.

Cap-window edges are one of the deterministic breakpoint types the
macro-stepping engine stops at (``core.sim.quiet_horizon`` via
``next_cap_event``); with the thermal twin enabled, predicted
rack-temperature trip crossings join them (``core.thermal.
thermal_crossing_horizon``) — see docs/thermal.md for the breakpoint
semantics.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

_INF = jnp.float32(jnp.inf)


class CapSchedule(NamedTuple):
    start_t: jax.Array     # (E,) event window start [s]
    end_t: jax.Array       # (E,) event window end [s] (exclusive)
    cap_w: jax.Array       # (E,) facility cap during event [W]; 0 = padding
    base_cap_w: jax.Array  # scalar standing cap [W]; 0 = uncapped


def no_cap(base_cap_w: float = 0.0, n_events: int = 1) -> CapSchedule:
    """Schedule with no events (only the standing base cap, if any)."""
    E = max(n_events, 1)
    z = jnp.zeros((E,), jnp.float32)
    return CapSchedule(start_t=z, end_t=z, cap_w=z,
                       base_cap_w=jnp.float32(base_cap_w))


def cap_events(
    starts: Sequence[float],
    ends: Sequence[float],
    caps_w: Sequence[float],
    base_cap_w: float = 0.0,
    *,
    n_events: int | None = None,
) -> CapSchedule:
    """Build a schedule from parallel event lists, padded to ``n_events``."""
    s = np.asarray(starts, np.float32).reshape(-1)
    e = np.asarray(ends, np.float32).reshape(-1)
    c = np.asarray(caps_w, np.float32).reshape(-1)
    if not (s.shape == e.shape == c.shape):
        raise ValueError("starts/ends/caps_w must have equal lengths")
    if np.any(e < s):
        raise ValueError("event end_t before start_t")
    E = max(n_events or s.size, s.size, 1)
    pad = E - s.size
    if pad:
        s = np.concatenate([s, np.zeros(pad, np.float32)])
        e = np.concatenate([e, np.zeros(pad, np.float32)])
        c = np.concatenate([c, np.zeros(pad, np.float32)])
    return CapSchedule(start_t=jnp.asarray(s), end_t=jnp.asarray(e),
                       cap_w=jnp.asarray(c), base_cap_w=jnp.float32(base_cap_w))


def next_cap_event(sched: CapSchedule, t: jax.Array) -> jax.Array:
    """Earliest cap-schedule breakpoint strictly after ``t`` (``inf`` when
    none): an event window opening or closing. The standing base cap has
    no breakpoints and padding slots (``cap_w == 0``) never produce one.
    The macro-stepping engine treats these as segment boundaries so a
    fast-forwarded segment never straddles a cap change."""
    edges = jnp.concatenate([sched.start_t, sched.end_t])
    live = jnp.concatenate([sched.cap_w > 0.0, sched.cap_w > 0.0])
    edges = jnp.where(live & (edges > t), edges, _INF)
    return jnp.min(edges)


def power_cap_at(sched: CapSchedule, t: jax.Array) -> jax.Array:
    """Effective facility cap [W] at time t; 0.0 when uncapped."""
    active = (t >= sched.start_t) & (t < sched.end_t) & (sched.cap_w > 0.0)
    event_cap = jnp.min(jnp.where(active, sched.cap_w, _INF))
    base = jnp.where(sched.base_cap_w > 0.0, sched.base_cap_w, _INF)
    cap = jnp.minimum(event_cap, base)
    return jnp.where(jnp.isfinite(cap), cap, 0.0)
