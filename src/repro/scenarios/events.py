"""Scheduled demand-response events: time-windowed facility power caps.

A ``CapSchedule`` holds up to E events, each ``[start_t, end_t)`` with a
facility-power cap in watts, plus a standing base cap. ``power_cap_at``
returns the effective cap at time t (the tightest of base + active events),
with 0.0 meaning "uncapped" — matching the legacy ``cfg.power_cap_w``
convention consumed by the DVFS throttle in ``core/sim.py``.

Fixed shape (E is padded, inactive slots have cap 0) so schedules vmap
across fleet replicas.

Cap-window edges are one of the deterministic breakpoint types the
macro-stepping engine stops at (``core.sim.quiet_horizon`` via
``next_cap_event``); with the thermal twin enabled, predicted
rack-temperature trip crossings join them (``core.thermal.
thermal_crossing_horizon``) — see docs/thermal.md for the breakpoint
semantics.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

_INF = jnp.float32(jnp.inf)


class CapSchedule(NamedTuple):
    start_t: jax.Array     # (E,) event window start [s]
    end_t: jax.Array       # (E,) event window end [s] (exclusive)
    cap_w: jax.Array       # (E,) facility cap during event [W]; 0 = padding
    base_cap_w: jax.Array  # scalar standing cap [W]; 0 = uncapped


def no_cap(base_cap_w: float = 0.0, n_events: int = 1) -> CapSchedule:
    """Schedule with no events (only the standing base cap, if any)."""
    E = max(n_events, 1)
    z = jnp.zeros((E,), jnp.float32)
    return CapSchedule(start_t=z, end_t=z, cap_w=z,
                       base_cap_w=jnp.float32(base_cap_w))


def cap_events(
    starts: Sequence[float],
    ends: Sequence[float],
    caps_w: Sequence[float],
    base_cap_w: float = 0.0,
    *,
    n_events: int | None = None,
) -> CapSchedule:
    """Build a schedule from parallel event lists, padded to ``n_events``."""
    s = np.asarray(starts, np.float32).reshape(-1)
    e = np.asarray(ends, np.float32).reshape(-1)
    c = np.asarray(caps_w, np.float32).reshape(-1)
    if not (s.shape == e.shape == c.shape):
        raise ValueError("starts/ends/caps_w must have equal lengths")
    if np.any(e < s):
        raise ValueError("event end_t before start_t")
    E = max(n_events or s.size, s.size, 1)
    pad = E - s.size
    if pad:
        s = np.concatenate([s, np.zeros(pad, np.float32)])
        e = np.concatenate([e, np.zeros(pad, np.float32)])
        c = np.concatenate([c, np.zeros(pad, np.float32)])
    return CapSchedule(start_t=jnp.asarray(s), end_t=jnp.asarray(e),
                       cap_w=jnp.asarray(c), base_cap_w=jnp.float32(base_cap_w))


def next_cap_event(sched: CapSchedule, t: jax.Array) -> jax.Array:
    """Earliest cap-schedule breakpoint strictly after ``t`` (``inf`` when
    none): an event window opening or closing. The standing base cap has
    no breakpoints and padding slots (``cap_w == 0``) never produce one.
    The macro-stepping engine treats these as segment boundaries so a
    fast-forwarded segment never straddles a cap change."""
    edges = jnp.concatenate([sched.start_t, sched.end_t])
    live = jnp.concatenate([sched.cap_w > 0.0, sched.cap_w > 0.0])
    edges = jnp.where(live & (edges > t), edges, _INF)
    return jnp.min(edges)


def power_cap_at(sched: CapSchedule, t: jax.Array) -> jax.Array:
    """Effective facility cap [W] at time t; 0.0 when uncapped."""
    active = (t >= sched.start_t) & (t < sched.end_t) & (sched.cap_w > 0.0)
    event_cap = jnp.min(jnp.where(active, sched.cap_w, _INF))
    base = jnp.where(sched.base_cap_w > 0.0, sched.base_cap_w, _INF)
    cap = jnp.minimum(event_cap, base)
    return jnp.where(jnp.isfinite(cap), cap, 0.0)


class OutageSchedule(NamedTuple):
    """Grid brownout/outage + maintenance windows (docs/resilience.md).

    Up to E windows ``[start_t, end_t)``. Each carries a forced
    degradation-ladder level (``core.faults``: 0 none, 1 throttle,
    2 dispatch-gate, 3 drain, 4 checkpoint-evict) and optionally a rack id
    to take down outright (cooling-loop/PDU maintenance; -1 = no rack).
    A slot with ``force_level == 0`` and ``down_rack == -1`` is padding.
    Window edges are exact macro breakpoints via ``next_outage_event``."""

    start_t: jax.Array      # (E,) window start [s]
    end_t: jax.Array        # (E,) window end [s] (exclusive)
    force_level: jax.Array  # (E,) int32 forced ladder level; 0 = none
    down_rack: jax.Array    # (E,) int32 rack taken down; -1 = none


def no_outages(n_events: int = 1) -> OutageSchedule:
    """Schedule with no outage/maintenance windows (all padding)."""
    E = max(n_events, 1)
    z = jnp.zeros((E,), jnp.float32)
    return OutageSchedule(start_t=z, end_t=z,
                          force_level=jnp.zeros((E,), jnp.int32),
                          down_rack=jnp.full((E,), -1, jnp.int32))


def outage_events(
    starts: Sequence[float],
    ends: Sequence[float],
    *,
    levels: Sequence[int] | None = None,
    down_racks: Sequence[int] | None = None,
    n_events: int | None = None,
) -> OutageSchedule:
    """Build an outage schedule from parallel window lists, padded to
    ``n_events``. ``levels`` defaults to 0 (no forced ladder level) and
    ``down_racks`` to -1 (no rack outage) — at least one must make each
    window non-trivial or it is padding."""
    s = np.asarray(starts, np.float32).reshape(-1)
    e = np.asarray(ends, np.float32).reshape(-1)
    lv = (np.zeros_like(s, np.int32) if levels is None
          else np.asarray(levels, np.int32).reshape(-1))
    dr = (np.full_like(lv, -1) if down_racks is None
          else np.asarray(down_racks, np.int32).reshape(-1))
    if not (s.shape == e.shape == lv.shape == dr.shape):
        raise ValueError("starts/ends/levels/down_racks lengths differ")
    if np.any(e < s):
        raise ValueError("outage end_t before start_t")
    if np.any((lv < 0) | (lv > 4)):
        raise ValueError("force_level must be in [0, 4]")
    E = max(n_events or s.size, s.size, 1)
    pad = E - s.size
    if pad:
        s = np.concatenate([s, np.zeros(pad, np.float32)])
        e = np.concatenate([e, np.zeros(pad, np.float32)])
        lv = np.concatenate([lv, np.zeros(pad, np.int32)])
        dr = np.concatenate([dr, np.full(pad, -1, np.int32)])
    return OutageSchedule(start_t=jnp.asarray(s), end_t=jnp.asarray(e),
                          force_level=jnp.asarray(lv),
                          down_rack=jnp.asarray(dr))


def _outage_live(sched: OutageSchedule) -> jax.Array:
    return (sched.force_level > 0) | (sched.down_rack >= 0)


def next_outage_event(sched: OutageSchedule, t: jax.Array) -> jax.Array:
    """Earliest outage-window edge strictly after ``t`` (``inf`` when
    none) — same breakpoint contract as ``next_cap_event``."""
    live = _outage_live(sched)
    edges = jnp.concatenate([sched.start_t, sched.end_t])
    live2 = jnp.concatenate([live, live])
    edges = jnp.where(live2 & (edges > t), edges, _INF)
    return jnp.min(edges)


def outage_level_at(sched: OutageSchedule, t: jax.Array) -> jax.Array:
    """Highest forced degradation-ladder level among windows active at t
    (int32 scalar; 0 when none)."""
    active = (t >= sched.start_t) & (t < sched.end_t) & _outage_live(sched)
    return jnp.max(jnp.where(active, sched.force_level, 0))


class BurstSchedule(NamedTuple):
    """Traffic-burst (flash-crowd) windows for the serving twin
    (docs/serving.md).

    Up to E windows ``[start_t, end_t)``; each scales the
    ``Scenario.traffic`` request-rate signal by ``mult`` while active
    (largest multiplier wins when windows overlap; 1.0 outside any
    window). A slot with ``mult <= 0`` is padding. Window edges are
    exact macro breakpoints via ``next_burst_event``."""

    start_t: jax.Array  # (E,) window start [s]
    end_t: jax.Array    # (E,) window end [s] (exclusive)
    mult: jax.Array     # (E,) traffic multiplier; <= 0 = padding


def no_bursts(n_events: int = 1) -> BurstSchedule:
    """Schedule with no burst windows (all padding)."""
    E = max(n_events, 1)
    z = jnp.zeros((E,), jnp.float32)
    return BurstSchedule(start_t=z, end_t=z, mult=z)


def burst_events(
    starts: Sequence[float],
    ends: Sequence[float],
    mults: Sequence[float],
    *,
    n_events: int | None = None,
) -> BurstSchedule:
    """Build a burst schedule from parallel window lists, padded to
    ``n_events``. Multipliers must be positive (use < 1 for planned
    traffic dips, > 1 for flash crowds)."""
    s = np.asarray(starts, np.float32).reshape(-1)
    e = np.asarray(ends, np.float32).reshape(-1)
    m = np.asarray(mults, np.float32).reshape(-1)
    if not (s.shape == e.shape == m.shape):
        raise ValueError("starts/ends/mults must have equal lengths")
    if np.any(e < s):
        raise ValueError("burst end_t before start_t")
    if np.any(m <= 0.0):
        raise ValueError("burst mult must be positive")
    E = max(n_events or s.size, s.size, 1)
    pad = E - s.size
    if pad:
        s = np.concatenate([s, np.zeros(pad, np.float32)])
        e = np.concatenate([e, np.zeros(pad, np.float32)])
        m = np.concatenate([m, np.zeros(pad, np.float32)])
    return BurstSchedule(start_t=jnp.asarray(s), end_t=jnp.asarray(e),
                         mult=jnp.asarray(m))


def next_burst_event(sched: BurstSchedule, t: jax.Array) -> jax.Array:
    """Earliest burst-window edge strictly after ``t`` (``inf`` when
    none) — same breakpoint contract as ``next_cap_event``."""
    live = sched.mult > 0.0
    edges = jnp.concatenate([sched.start_t, sched.end_t])
    live2 = jnp.concatenate([live, live])
    edges = jnp.where(live2 & (edges > t), edges, _INF)
    return jnp.min(edges)


def burst_mult_at(sched: BurstSchedule, t: jax.Array) -> jax.Array:
    """Traffic multiplier at time t: the largest among active windows,
    1.0 when none is active."""
    active = (t >= sched.start_t) & (t < sched.end_t) & (sched.mult > 0.0)
    peak = jnp.max(jnp.where(active, sched.mult, 0.0))
    return jnp.where(jnp.any(active), peak, jnp.float32(1.0))


def outage_down(
    sched: OutageSchedule, t: jax.Array, node_rack: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Per-node maintenance outage at time t: ``(forced, until)`` where
    ``forced`` is a (N,) bool mask of nodes whose rack is taken down by an
    active window and ``until`` the (N,) latest ``end_t`` among the windows
    downing each node (0 where not forced) — the deterministic repair
    time for maintenance faults."""
    active = (t >= sched.start_t) & (t < sched.end_t) & (sched.down_rack >= 0)
    # (N, E): window e downs node n
    hit = active[None, :] & (node_rack[:, None] == sched.down_rack[None, :])
    forced = jnp.any(hit, axis=1)
    until = jnp.max(jnp.where(hit, sched.end_t[None, :], 0.0), axis=1)
    return forced, until
