"""Jit-able time-series grid signals.

A ``Signal`` is a fixed-shape pytree that evaluates to a scalar at any sim
time ``t`` under jit/vmap/scan. Two families share one representation so a
single compiled ``step`` serves both:

  * parametric — sinusoid (mean, amp, period, phase) plus an optional
    deterministic multi-harmonic "weather noise" term (no PRNG key needed,
    so evaluation stays a pure function of ``t``);
  * trace — a sampled array linearly interpolated at ``t`` (edge-hold
    outside the sampled range), for replaying real grid-operator data.

``use_trace`` selects the family at evaluation time, which keeps the pytree
structure identical across scenarios — the property that lets a fleet of
replicas with heterogeneous scenarios run in one ``vmap``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Signal(NamedTuple):
    """Scalar time series; evaluate with ``eval_signal(sig, t)``."""

    mean: jax.Array        # parametric: offset
    amp: jax.Array         # parametric: sinusoid amplitude
    period_s: jax.Array    # parametric: sinusoid period [s]
    phase: jax.Array       # parametric: phase [rad]
    noise_amp: jax.Array   # parametric: amplitude of harmonic noise
    noise_seed: jax.Array  # parametric: phase-offset seed for the noise
    values: jax.Array      # trace: (T,) samples, T >= 2
    t0: jax.Array          # trace: time of values[0] [s]
    dt: jax.Array          # trace: sample spacing [s]
    use_trace: jax.Array   # {0., 1.}: trace vs parametric family


def _f(x) -> jax.Array:
    return jnp.asarray(x, jnp.float32)


def sinusoid(
    mean: float,
    amp: float = 0.0,
    period_s: float = 86_400.0,
    phase: float = 0.0,
    *,
    noise_amp: float = 0.0,
    noise_seed: float = 0.0,
) -> Signal:
    """``mean + amp * sin(2*pi*t/period + phase) [+ noise]``."""
    return Signal(
        mean=_f(mean), amp=_f(amp), period_s=_f(period_s), phase=_f(phase),
        noise_amp=_f(noise_amp), noise_seed=_f(noise_seed),
        values=jnp.zeros((2,), jnp.float32), t0=_f(0.0), dt=_f(1.0),
        use_trace=_f(0.0),
    )


def constant(value: float) -> Signal:
    return sinusoid(value, 0.0)


def from_trace(values, dt: float, t0: float = 0.0) -> Signal:
    """Sampled trace, linearly interpolated; edge-hold outside [t0, t_end]."""
    v = np.asarray(values, np.float32).reshape(-1)
    if v.size == 0:
        raise ValueError("trace signal needs at least one sample")
    if v.size == 1:
        v = np.repeat(v, 2)
    return Signal(
        mean=_f(float(v.mean())), amp=_f(0.0), period_s=_f(86_400.0),
        phase=_f(0.0), noise_amp=_f(0.0), noise_seed=_f(0.0),
        values=jnp.asarray(v), t0=_f(t0), dt=_f(dt), use_trace=_f(1.0),
    )


# incommensurate harmonic multipliers: noise never repeats within a period
_NOISE_HARMONICS = (2.718, 5.196, 9.424, 17.03)


def _harmonic_noise(sig: Signal, t: jax.Array) -> jax.Array:
    """Deterministic O(1)-amplitude wander, a cheap stand-in for weather /
    grid-mix stochasticity that keeps eval a pure function of t."""
    h = jnp.asarray(_NOISE_HARMONICS, jnp.float32)
    w = 2.0 * jnp.pi * h / jnp.maximum(sig.period_s, 1e-6)
    # golden-angle phase spread; seed shifts all phases together
    ph = sig.noise_seed * (1.0 + jnp.arange(h.shape[0], dtype=jnp.float32)) * 2.39996
    return jnp.sum(jnp.sin(w * t + ph)) / jnp.sqrt(jnp.float32(len(_NOISE_HARMONICS)))


def eval_signal(sig: Signal, t: jax.Array) -> jax.Array:
    """Evaluate ``sig`` at time ``t`` (scalar f32). Pure & jit/vmap-safe."""
    x = 2.0 * jnp.pi * t / jnp.maximum(sig.period_s, 1e-6) + sig.phase
    para = sig.mean + sig.amp * jnp.sin(x) + sig.noise_amp * _harmonic_noise(sig, t)

    T = sig.values.shape[0]
    u = (t - sig.t0) / jnp.maximum(sig.dt, 1e-6)
    i0 = jnp.clip(jnp.floor(u).astype(jnp.int32), 0, T - 2)
    frac = jnp.clip(u - i0.astype(jnp.float32), 0.0, 1.0)
    trace = sig.values[i0] * (1.0 - frac) + sig.values[i0 + 1] * frac

    return jnp.where(sig.use_trace > 0.5, trace, para)


def _sin_antideriv(amp: jax.Array, w: jax.Array, phase: jax.Array,
                   t: jax.Array) -> jax.Array:
    """Antiderivative of ``amp * sin(w t + phase)`` at ``t``."""
    return -amp * jnp.cos(w * t + phase) / jnp.maximum(w, 1e-12)


def _trace_antideriv(sig: Signal, t: jax.Array) -> jax.Array:
    """Antiderivative (w.r.t. ``sig.t0``) of the edge-held piecewise-linear
    trace interpolant at ``t`` — exact via a prefix sum of trapezoids."""
    v = sig.values
    T = v.shape[0]
    dt = jnp.maximum(sig.dt, 1e-6)
    # cumulative trapezoid areas up to each sample (in units of dt)
    csum = jnp.concatenate(
        [jnp.zeros((1,), v.dtype), jnp.cumsum(0.5 * (v[:-1] + v[1:]))])
    u = (t - sig.t0) / dt
    uc = jnp.clip(u, 0.0, jnp.float32(T - 1))
    i0 = jnp.clip(jnp.floor(uc).astype(jnp.int32), 0, T - 2)
    frac = uc - i0.astype(jnp.float32)
    seg = v[i0] * frac + 0.5 * (v[i0 + 1] - v[i0]) * frac * frac
    inside = dt * (csum[i0] + seg)
    # edge-hold tails: v[0] before the sampled range, v[-1] after it
    before = v[0] * jnp.minimum(t - sig.t0, 0.0)
    after = v[-1] * jnp.maximum(u - jnp.float32(T - 1), 0.0) * dt
    return inside + before + after


def integrate_signal(sig: Signal, t0: jax.Array, t1: jax.Array) -> jax.Array:
    """Exact ``∫_{t0}^{t1} sig(t) dt`` — segment-integrated accounting.

    Closed form for the parametric family (sinusoid + harmonic noise are
    sums of sines), and prefix-sum trapezoids for the trace family (the
    interpolant is piecewise linear with edge-hold, so its integral is
    exact up to float rounding). Pure & jit/vmap-safe; ``t1 < t0`` yields
    the negated integral, matching the Riemann convention.

    This is the analysis-side companion of the macro-stepping engine
    (``core.sim.make_macro_step``): the engine itself evaluates signals on
    the tick grid so its accounting is bit-comparable to the per-tick
    path even through the *nonlinear* COP/throttle consumers, while this
    integral provides the continuous reference for validation and for
    window statistics (e.g. mean carbon over a replay hour).
    """
    t0 = jnp.asarray(t0, jnp.float32)
    t1 = jnp.asarray(t1, jnp.float32)
    w = 2.0 * jnp.pi / jnp.maximum(sig.period_s, 1e-6)

    def para_F(t):
        base = sig.mean * t + _sin_antideriv(sig.amp, w, sig.phase, t)
        h = jnp.asarray(_NOISE_HARMONICS, jnp.float32)
        wh = w * h
        ph = (sig.noise_seed
              * (1.0 + jnp.arange(h.shape[0], dtype=jnp.float32)) * 2.39996)
        scale = sig.noise_amp / jnp.sqrt(jnp.float32(len(_NOISE_HARMONICS)))
        return base + jnp.sum(_sin_antideriv(scale, wh, ph, t))

    para = para_F(t1) - para_F(t0)
    trace = _trace_antideriv(sig, t1) - _trace_antideriv(sig, t0)
    return jnp.where(sig.use_trace > 0.5, trace, para)


def mean_signal(sig: Signal, t0: jax.Array, t1: jax.Array) -> jax.Array:
    """Exact time-average of ``sig`` over ``[t0, t1]`` (the point value
    for a degenerate zero-width window)."""
    span = jnp.asarray(t1, jnp.float32) - jnp.asarray(t0, jnp.float32)
    avg = integrate_signal(sig, t0, t1) / jnp.where(span == 0.0, 1.0, span)
    return jnp.where(span == 0.0, eval_signal(sig, t0), avg)


def signal_bounds(sig: Signal) -> tuple[jax.Array, jax.Array]:
    """Conservative (lo, hi) envelope of ``sig`` over ALL time.

    Parametric family: ``mean ∓ (|amp| + noise_amp * H)`` where H bounds the
    harmonic-noise sum (4 unit sines / sqrt(4) -> |noise| <= 2). Trace
    family: exact min/max of the samples (the edge-held linear interpolant
    never leaves their hull). Pure & jit-safe — used by the macro-stepping
    engine to bound thermal steady states (``core.thermal``).
    """
    h_max = jnp.float32(len(_NOISE_HARMONICS)) / jnp.sqrt(
        jnp.float32(len(_NOISE_HARMONICS)))
    swing = jnp.abs(sig.amp) + jnp.abs(sig.noise_amp) * h_max
    para_lo, para_hi = sig.mean - swing, sig.mean + swing
    tr_lo, tr_hi = jnp.min(sig.values), jnp.max(sig.values)
    tr = sig.use_trace > 0.5
    return (jnp.where(tr, tr_lo, para_lo), jnp.where(tr, tr_hi, para_hi))


def to_trace(sig: Signal, horizon_s: float, dt: float) -> Signal:
    """Materialize any signal onto a uniform grid (useful for stacking
    scenarios whose parametric/trace families differ in cost, or for
    exporting a parametric scenario as CSV)."""
    n = max(int(np.ceil(horizon_s / dt)) + 1, 2)
    ts = jnp.arange(n, dtype=jnp.float32) * dt
    vals = jax.vmap(lambda t: eval_signal(sig, t))(ts)
    return from_trace(np.asarray(vals), dt, 0.0)
