"""Grid-signal scenario engine: pluggable, jit-able time-varying carbon /
price / weather signals and demand-response power-cap events for the twin."""

from repro.scenarios.events import (
    CapSchedule,
    cap_events,
    next_cap_event,
    no_cap,
    power_cap_at,
)
from repro.scenarios.scenario import (
    SCENARIOS,
    Scenario,
    carbon_trace,
    default_scenario,
    demand_response,
    heatwave,
    n_replicas,
    sample_scenarios,
    solar_heavy,
    stack_scenarios,
    thermal_stress,
)
from repro.scenarios.signals import (
    Signal,
    constant,
    eval_signal,
    from_trace,
    integrate_signal,
    mean_signal,
    signal_bounds,
    sinusoid,
    to_trace,
)
