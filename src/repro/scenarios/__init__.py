"""Grid-signal scenario engine: pluggable, jit-able time-varying carbon /
price / weather signals and demand-response power-cap events for the twin."""

from repro.scenarios.events import (
    BurstSchedule,
    CapSchedule,
    OutageSchedule,
    burst_events,
    burst_mult_at,
    cap_events,
    next_burst_event,
    next_cap_event,
    next_outage_event,
    no_bursts,
    no_cap,
    no_outages,
    outage_down,
    outage_events,
    outage_level_at,
    power_cap_at,
)
from repro.scenarios.scenario import (
    SCENARIOS,
    Scenario,
    carbon_trace,
    default_scenario,
    demand_response,
    diurnal_serving,
    heatwave,
    n_replicas,
    resilience_drill,
    sample_scenarios,
    solar_heavy,
    stack_scenarios,
    thermal_stress,
)
from repro.scenarios.signals import (
    Signal,
    constant,
    eval_signal,
    from_trace,
    integrate_signal,
    mean_signal,
    signal_bounds,
    sinusoid,
    to_trace,
)
