"""Scenario = the grid context a datacenter replica runs under.

Bundles the three environmental signals (carbon intensity [gCO2/kWh],
electricity price [$/kWh], wetbulb temperature [degC]) with a
demand-response power-cap schedule. The bundle is a fixed-shape pytree:
``Statics`` carries it into the compiled ``step``, and a batched Scenario
(leading replica axis on every leaf) drives ``core.fleet.run_fleet``.

``default_scenario(cfg)`` reproduces the legacy hard-coded sinusoids from
``core/power.py`` exactly, so all pre-scenario behavior is unchanged.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sim import SimConfig
from repro.scenarios.events import (
    BurstSchedule,
    CapSchedule,
    OutageSchedule,
    burst_events,
    cap_events,
    no_bursts,
    no_cap,
    no_outages,
    outage_events,
)
from repro.scenarios.signals import Signal, constant, from_trace, sinusoid

# class-level defaults shared by every scenario without outage/burst
# windows or serving traffic: one padding slot / a zero-rate signal, so
# legacy builders need no changes and all fixed-shape invariants (vmap
# across replicas) hold by construction
_NO_OUTAGES = no_outages()
_NO_TRAFFIC = constant(0.0)
_NO_BURSTS = no_bursts()


class Scenario(NamedTuple):
    carbon: Signal        # grid carbon intensity [gCO2/kWh]
    price: Signal         # electricity price [$/kWh]
    wetbulb: Signal       # outdoor wetbulb [degC] (drives cooling COP)
    power_cap: CapSchedule
    outages: OutageSchedule = _NO_OUTAGES
    traffic: Signal = _NO_TRAFFIC      # serving request rate [req/s]
    bursts: BurstSchedule = _NO_BURSTS  # flash-crowd traffic multipliers


# ---------------------------------------------------------------- builders
def default_scenario(cfg: SimConfig) -> Scenario:
    """The legacy diurnal grid: carbon peaks at midnight (no solar),
    wetbulb peaks mid-afternoon, price peaks in the evening; standing power
    cap from ``cfg.power_cap_w``."""
    return Scenario(
        # mean + amp*cos(2*pi*t/day): identical to the old carbon_intensity()
        carbon=sinusoid(cfg.carbon_mean, cfg.carbon_amp, cfg.day_seconds,
                        phase=math.pi / 2),
        # evening peak at ~18:00
        price=sinusoid(cfg.price_mean_usd_kwh, cfg.price_amp_usd_kwh,
                       cfg.day_seconds, phase=-math.pi),
        # mean - amp*cos(2*pi*t/day): identical to the old wetbulb_c()
        wetbulb=sinusoid(cfg.wetbulb_mean_c, cfg.wetbulb_amp_c,
                         cfg.day_seconds, phase=-math.pi / 2),
        power_cap=no_cap(cfg.power_cap_w),
    )


def solar_heavy(cfg: SimConfig, *, depth: float = 0.75) -> Scenario:
    """Deep midday solar trough: large carbon swing + duck-curve pricing."""
    base = default_scenario(cfg)
    return base._replace(
        carbon=sinusoid(cfg.carbon_mean, cfg.carbon_mean * depth * 0.9,
                        cfg.day_seconds, phase=math.pi / 2, noise_amp=12.0),
        price=sinusoid(cfg.price_mean_usd_kwh, cfg.price_mean_usd_kwh * 0.7,
                       cfg.day_seconds, phase=-math.pi, noise_amp=0.004),
    )


def demand_response(
    cfg: SimConfig,
    *,
    cap_w: float,
    event_start_s: float = 17.0 * 3600.0,
    event_len_s: float = 3.0 * 3600.0,
    n_days: int = 1,
    n_events: int | None = None,
) -> Scenario:
    """Default grid + a daily evening-peak curtailment window."""
    starts = [event_start_s + d * cfg.day_seconds for d in range(n_days)]
    ends = [s + event_len_s for s in starts]
    return default_scenario(cfg)._replace(
        power_cap=cap_events(starts, ends, [cap_w] * n_days,
                             base_cap_w=cfg.power_cap_w, n_events=n_events),
    )


def heatwave(cfg: SimConfig, *, delta_c: float = 8.0) -> Scenario:
    """Elevated wetbulb (worse cooling COP) + stressed-grid carbon/price."""
    base = default_scenario(cfg)
    return base._replace(
        wetbulb=sinusoid(cfg.wetbulb_mean_c + delta_c, cfg.wetbulb_amp_c,
                         cfg.day_seconds, phase=-math.pi / 2, noise_amp=0.8),
        carbon=sinusoid(cfg.carbon_mean * 1.2, cfg.carbon_amp,
                        cfg.day_seconds, phase=math.pi / 2),
        price=sinusoid(cfg.price_mean_usd_kwh * 1.5, cfg.price_amp_usd_kwh * 2,
                       cfg.day_seconds, phase=-math.pi),
    )


def thermal_stress(
    cfg: SimConfig,
    *,
    delta_c: float = 10.0,
    cap_frac: float = 0.7,
    event_start_s: float = 13.0 * 3600.0,
    event_len_s: float = 4.0 * 3600.0,
) -> Scenario:
    """The thermal-twin stress case: a heatwave (high wetbulb -> high
    supply temperature -> racks ride the throttle/trip thresholds) PLUS an
    afternoon demand-response window landing on the wetbulb peak — the
    regime where cooling lag, temperature-triggered throttling and the
    power cap all interact (``cfg.thermal_enabled`` turns the rack RC loop
    on; this scenario merely supplies the weather/grid that exercises it).
    """
    base = heatwave(cfg, delta_c=delta_c)
    cap_w = cfg.nameplate_it_w * 1.3 * cap_frac
    return base._replace(
        power_cap=cap_events([event_start_s],
                             [event_start_s + event_len_s], [cap_w],
                             base_cap_w=cfg.power_cap_w),
    )


def carbon_trace(cfg: SimConfig, values, dt: float, t0: float = 0.0) -> Scenario:
    """Default grid with carbon replaced by a sampled trace (e.g. a grid
    operator's 5-minute marginal-intensity feed)."""
    return default_scenario(cfg)._replace(carbon=from_trace(values, dt, t0))


def resilience_drill(
    cfg: SimConfig,
    *,
    maint_rack: int = 0,
    maint_start_s: float = 2.0 * 3600.0,
    maint_len_s: float = 1.0 * 3600.0,
    brownout_start_s: float = 17.0 * 3600.0,
    brownout_len_s: float = 2.0 * 3600.0,
    brownout_level: int = 2,
) -> Scenario:
    """The fault-engine drill (docs/resilience.md): a morning maintenance
    window taking one rack down (correlated PDU/cooling-loop outage) plus
    an evening grid brownout forcing the degradation ladder to
    ``brownout_level`` (default 2 = dispatch-gate). Pair with
    ``cfg.outages_enabled=True`` and nonzero MTBFs for random faults on
    top of the scheduled ones."""
    return default_scenario(cfg)._replace(
        outages=outage_events(
            [maint_start_s, brownout_start_s],
            [maint_start_s + maint_len_s, brownout_start_s + brownout_len_s],
            levels=[0, brownout_level],
            down_racks=[maint_rack, -1],
        ),
    )


def diurnal_serving(
    cfg: SimConfig,
    *,
    peak_rps: float = 40.0,
    base_frac: float = 0.25,
    burst_mult: float = 2.5,
    burst_start_s: float = 13.0 * 3600.0,
    burst_len_s: float = 1.0 * 3600.0,
    period_s: float | None = None,
) -> Scenario:
    """Online-inference traffic for the serving twin (docs/serving.md):
    a diurnal request-rate sinusoid — night trough at ``base_frac *
    peak_rps``, peak mid-day, phase-aligned with the wetbulb peak so the
    traffic maximum lands on the worst cooling hour — plus one
    flash-crowd window multiplying the rate by ``burst_mult``. Pair with
    ``cfg.serving_enabled=True`` and a nonzero ``serving_nodes`` pool;
    ``period_s`` shrinks the diurnal cycle for short test episodes."""
    period = cfg.day_seconds if period_s is None else period_s
    mean = 0.5 * (1.0 + base_frac) * peak_rps
    amp = 0.5 * (1.0 - base_frac) * peak_rps
    return default_scenario(cfg)._replace(
        # mean - amp*cos(2*pi*t/period): trough at t=0, peak mid-cycle
        traffic=sinusoid(mean, amp, period, phase=-math.pi / 2),
        bursts=burst_events([burst_start_s],
                            [burst_start_s + burst_len_s], [burst_mult]),
    )


SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "default": default_scenario,
    "solar_heavy": solar_heavy,
    "demand_response": demand_response,
    "heatwave": heatwave,
    "thermal_stress": thermal_stress,
    "resilience_drill": resilience_drill,
    "diurnal_serving": diurnal_serving,
}


def _nonneg_price(mean: float, amp: float, period_s: float, phase: float) -> Signal:
    """Price sinusoid with the trough clamped non-negative (no paying the
    agent to burn energy unless a trace says so explicitly)."""
    return sinusoid(mean, min(amp, 0.95 * mean), period_s, phase)


# ------------------------------------------------------------- fleet utils
def _pad_trace(sig: Signal, T: int) -> Signal:
    t = sig.values.shape[0]
    if t == T:
        return sig
    pad = jnp.broadcast_to(sig.values[-1:], (T - t,))  # edge-hold
    return sig._replace(values=jnp.concatenate([sig.values, pad]))


def _pad_events(sched: CapSchedule, E: int) -> CapSchedule:
    e = sched.start_t.shape[0]
    if e == E:
        return sched
    z = jnp.zeros((E - e,), jnp.float32)
    return CapSchedule(
        start_t=jnp.concatenate([sched.start_t, z]),
        end_t=jnp.concatenate([sched.end_t, z]),
        cap_w=jnp.concatenate([sched.cap_w, z]),
        base_cap_w=sched.base_cap_w,
    )


def _pad_outages(sched: OutageSchedule, E: int) -> OutageSchedule:
    e = sched.start_t.shape[0]
    if e == E:
        return sched
    z = jnp.zeros((E - e,), jnp.float32)
    return OutageSchedule(
        start_t=jnp.concatenate([sched.start_t, z]),
        end_t=jnp.concatenate([sched.end_t, z]),
        force_level=jnp.concatenate(
            [sched.force_level, jnp.zeros((E - e,), jnp.int32)]),
        down_rack=jnp.concatenate(
            [sched.down_rack, jnp.full((E - e,), -1, jnp.int32)]),
    )


def _pad_bursts(sched: BurstSchedule, E: int) -> BurstSchedule:
    e = sched.start_t.shape[0]
    if e == E:
        return sched
    z = jnp.zeros((E - e,), jnp.float32)
    return BurstSchedule(
        start_t=jnp.concatenate([sched.start_t, z]),
        end_t=jnp.concatenate([sched.end_t, z]),
        mult=jnp.concatenate([sched.mult, z]),
    )


def stack_scenarios(scenarios: Sequence[Scenario]) -> Scenario:
    """Stack scenarios into one batched pytree (leading replica axis).

    Trace arrays are edge-hold padded to a common length and cap schedules
    to a common event count, so heterogeneous scenarios share one shape.
    """
    if not scenarios:
        raise ValueError("need at least one scenario")
    T = max(s.values.shape[0] for sc in scenarios
            for s in (sc.carbon, sc.price, sc.wetbulb, sc.traffic))
    E = max(sc.power_cap.start_t.shape[0] for sc in scenarios)
    Eo = max(sc.outages.start_t.shape[0] for sc in scenarios)
    Eb = max(sc.bursts.start_t.shape[0] for sc in scenarios)
    norm = [
        Scenario(
            carbon=_pad_trace(sc.carbon, T),
            price=_pad_trace(sc.price, T),
            wetbulb=_pad_trace(sc.wetbulb, T),
            power_cap=_pad_events(sc.power_cap, E),
            outages=_pad_outages(sc.outages, Eo),
            traffic=_pad_trace(sc.traffic, T),
            bursts=_pad_bursts(sc.bursts, Eb),
        )
        for sc in scenarios
    ]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *norm)


def n_replicas(scenarios: Scenario) -> int:
    """Replica count of a batched (stacked) Scenario."""
    return int(scenarios.carbon.mean.shape[0])


def sample_scenarios(
    cfg: SimConfig,
    n: int,
    seed: int = 0,
    *,
    p_demand_response: float = 0.3,
    cap_frac_range=(0.5, 0.9),
) -> Scenario:
    """Randomized scenario sweep: jittered carbon/price/wetbulb parameters,
    a fraction of replicas with an evening demand-response event. Returns a
    batched Scenario for ``run_fleet``. Host-side numpy randomness."""
    rng = np.random.default_rng(seed)
    # rough facility scale for cap sizing: nameplate IT + overheads
    nameplate = cfg.nameplate_it_w * 1.3
    out = []
    for i in range(n):
        sc = default_scenario(cfg)._replace(
            carbon=sinusoid(
                cfg.carbon_mean * rng.uniform(0.7, 1.3),
                cfg.carbon_amp * rng.uniform(0.5, 1.8),
                cfg.day_seconds, phase=math.pi / 2 + rng.uniform(-0.4, 0.4),
                noise_amp=rng.uniform(0.0, 25.0), noise_seed=float(i + 1),
            ),
            price=_nonneg_price(
                cfg.price_mean_usd_kwh * rng.uniform(0.6, 1.6),
                cfg.price_amp_usd_kwh * rng.uniform(0.5, 2.0),
                cfg.day_seconds, phase=-math.pi + rng.uniform(-0.5, 0.5),
            ),
            wetbulb=sinusoid(
                cfg.wetbulb_mean_c + rng.uniform(-4.0, 8.0),
                cfg.wetbulb_amp_c * rng.uniform(0.5, 1.5),
                cfg.day_seconds, phase=-math.pi / 2,
            ),
        )
        if rng.random() < p_demand_response:
            start = rng.uniform(0.5, 20.0) * 3600.0
            sc = sc._replace(power_cap=cap_events(
                [start], [start + rng.uniform(0.5, 4.0) * 3600.0],
                [nameplate * rng.uniform(*cap_frac_range)],
                base_cap_w=cfg.power_cap_w,
            ))
        out.append(sc)
    return stack_scenarios(out)
