"""Serving steps: prefill (prompt -> cache) and decode (one token/step),
with greedy/temperature sampling. Both lower cleanly onto the production
mesh: KV caches are sharded (batch -> dp, sequence -> tp) so decode
attention runs as distributed flash-decode (see models/layers.py).
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, prefill
from repro.sharding.ctx import ShardCtx, UNSHARDED


def make_prefill_step(cfg: ModelConfig, ctx: ShardCtx = UNSHARDED,
                      *, cache_seq_len: Optional[int] = None) -> Callable:
    def prefill_step(params, batch):
        return prefill(params, batch, cfg, ctx, cache_seq_len=cache_seq_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig, ctx: ShardCtx = UNSHARDED,
                     *, temperature: float = 0.0) -> Callable:
    def step(params, cache, tokens, cache_len, key=None):
        logits, cache = decode_step(params, cache, tokens, cache_len, cfg, ctx)
        if temperature > 0.0 and key is not None:
            nxt = jax.random.categorical(key, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32)[:, None], logits, cache

    return step


def generate(
    cfg: ModelConfig,
    params,
    prompt: jax.Array,            # (B, S)
    n_tokens: int,
    *,
    ctx: ShardCtx = UNSHARDED,
    cache_seq_len: Optional[int] = None,
    temperature: float = 0.0,
    key: Optional[jax.Array] = None,
    extras: Optional[dict] = None,
) -> jax.Array:
    """Simple generation driver (prefill + scan of decode steps)."""
    B, S = prompt.shape
    cache_seq_len = cache_seq_len or (S + n_tokens)
    batch = {"tokens": prompt, **(extras or {})}
    logits, cache = prefill(params, batch, cfg, ctx, cache_seq_len=cache_seq_len)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    dstep = make_decode_step(cfg, ctx, temperature=temperature)

    def body(carry, i):
        tok, cache, key = carry
        if key is not None:
            key, sub = jax.random.split(key)
        else:
            sub = None
        nxt, _, cache = dstep(params, cache, tok, S + i, sub)
        return (nxt, cache, key), nxt[:, 0]

    (_, _, _), toks = jax.lax.scan(
        body, (first, cache, key), jnp.arange(n_tokens - 1)
    )
    return jnp.concatenate([first, toks.T], axis=1)
