from repro.train.state import TrainState, abstract_train_state, create_train_state
from repro.train.train_step import make_train_step
from repro.train.serve_step import make_decode_step, make_prefill_step
