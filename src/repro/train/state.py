"""Train state (params + optimizer state + step) and its sharding specs."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import init_params, model_param_specs
from repro.sharding.ctx import ShardCtx
from repro.sharding.specs import param_pspecs

TrainState = Dict[str, Any]   # {"params", "opt", "step"}


def create_train_state(cfg: ModelConfig, optimizer, rng: jax.Array) -> TrainState:
    params = init_params(cfg, rng)
    return {"params": params, "opt": optimizer.init(params), "step": jnp.int32(0)}


def abstract_train_state(cfg: ModelConfig, optimizer) -> TrainState:
    """ShapeDtypeStruct mirror — used by the dry-run (never allocated)."""
    specs = model_param_specs(cfg)
    opt = jax.eval_shape(lambda s: optimizer.init(s), specs)
    return {
        "params": specs,
        "opt": opt,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def train_state_pspecs(cfg: ModelConfig, ctx: ShardCtx, optimizer, mesh=None):
    from jax.sharding import PartitionSpec as P

    p_specs = param_pspecs(cfg, ctx, mesh)
    opt_specs = optimizer.state_pspecs(model_param_specs(cfg), p_specs)
    return {"params": p_specs, "opt": opt_specs, "step": P()}
