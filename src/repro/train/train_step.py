"""The pjit-able training step: loss -> grads -> (clip, compress, guard)
-> optimizer update.

Features (each a hillclimb/robustness knob):
- gradient (micro)accumulation: batch split into M microbatches scanned
  sequentially — caps activation memory at 1/M for the same global batch;
- global-norm clipping;
- non-finite guard: a step whose gradients contain inf/nan is *skipped*
  (params/opt unchanged, step still advances) — blast containment for
  straggler-induced partial batches or loss spikes at scale;
- optional int8 gradient compression with error feedback (halves/quarters
  DCN all-reduce bytes on the pod axis; see optim/compress.py).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward_train
from repro.optim.base import clip_by_global_norm
from repro.sharding.ctx import ShardCtx, UNSHARDED
from repro.utils.tree import all_finite


def make_train_step(
    cfg: ModelConfig,
    optimizer,
    ctx: ShardCtx = UNSHARDED,
    *,
    microbatches: int = 1,
    grad_clip: float = 1.0,
    compress: Optional[str] = None,     # None | 'int8'
) -> Callable:
    def loss_fn(params, batch):
        if ctx.cast_params_bf16 and cfg.dtype == "bfloat16":
            # cast-then-gather: the bf16 cast happens on the fp32 *shard*,
            # so FSDP all-gathers move half the bytes (and the gathered
            # per-layer weights live in VMEM/HBM at half size). Autodiff
            # through the cast still accumulates fp32 master grads.
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p,
                params,
            )
        return forward_train(params, batch, cfg, ctx)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def reshape(x):
            b = x.shape[0]
            assert b % microbatches == 0
            y = x.reshape((microbatches, b // microbatches) + x.shape[1:])
            return ctx.constrain(y, None, "dp") if y.ndim >= 2 else y

        mb = jax.tree.map(reshape, batch)

        def acc(carry, mb_i):
            g_acc, l_acc = carry
            (loss, metrics), g = grad_fn(params, mb_i)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, l_acc + loss), metrics

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g_sum, l_sum), metrics = jax.lax.scan(acc, (g0, jnp.float32(0)), mb)
        grads = jax.tree.map(lambda g: g / microbatches, g_sum)
        metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        return l_sum / microbatches, metrics, grads

    def train_step(state: Dict[str, Any], batch) -> tuple:
        params, opt_state, step = state["params"], state["opt"], state["step"]
        loss, metrics, grads = compute_grads(params, batch)

        if compress == "int8":
            from repro.optim.compress import quantize_dequantize

            grads = quantize_dequantize(grads)

        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        good = all_finite(grads) & jnp.isfinite(loss)

        new_params, new_opt = optimizer.update(grads, opt_state, params, step)
        params = jax.tree.map(
            lambda n, o: jnp.where(good, n, o), new_params, params
        )
        opt_state = jax.tree.map(
            lambda n, o: jnp.where(good, n, o), new_opt, opt_state
        )
        new_state = {"params": params, "opt": opt_state, "step": step + 1}
        out_metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "skipped": (~good).astype(jnp.float32),
            **metrics,
        }
        return new_state, out_metrics

    return train_step
