"""Model assembly: embedding -> superblock-scanned decoder stack -> chunked
LM loss; plus prefill and single-token decode with explicit caches.

All entry points are pure functions of (params, batch/cache, cfg, ctx) so
they jit/pjit cleanly; ``cache_specs``/``batch_specs`` mirror the runtime
pytrees with ShapeDtypeStructs for the allocation-free dry-run.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ATTN,
    ATTN_LOCAL,
    CROSS,
    MAMBA,
    MLSTM,
    SLSTM,
    ModelConfig,
    ShapeConfig,
)
from repro.models import spec as S
from repro.models.blocks import block_decode, block_parallel
from repro.models.layers import rms_norm
from repro.models.mamba import mamba_cache_spec
from repro.models.xlstm import mlstm_cache_spec, slstm_cache_spec
from repro.sharding.ctx import ShardCtx, UNSHARDED

from repro.models.init import init_params  # re-export  # noqa: F401
from repro.models.spec import count_params_analytic  # re-export  # noqa: F401


def compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def embed(params, tokens: jax.Array, cfg: ModelConfig, ctx: ShardCtx) -> jax.Array:
    x = jnp.take(params["emb"], tokens, axis=0).astype(compute_dtype(cfg))
    return ctx.constrain(x, "dp", "sp", None)


def head_weights(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["emb"].T
    return params["head"]


def scan_layers(body, carry, xs, ctx: ShardCtx, length: int):
    """lax.scan over stacked layers — or a fully-unrolled python loop when
    ctx.force_unroll (used by the dry-run cost probes: XLA's cost analysis
    does not multiply while-body FLOPs by the trip count)."""
    if not ctx.force_unroll:
        return jax.lax.scan(body, carry, xs, unroll=ctx.scan_unroll)
    ys = []
    for r in range(length):
        x_r = jax.tree.map(lambda a: a[r], xs)
        carry, y = body(carry, x_r)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


# ---------------------------------------------------------------------------
# stack runners
def _period_meta(cfg: ModelConfig):
    period, R, n_tail = S.layout(cfg)
    kinds = [S.layer_kind_at(cfg, p) for p in range(period)]
    moes = [cfg.is_moe_layer(p) for p in range(period)]
    return period, R, n_tail, kinds, moes


def encoder_forward(params, frames: jax.Array, cfg: ModelConfig, ctx: ShardCtx):
    """Whisper-style encoder over precomputed frame embeddings (stub frontend)."""
    x = frames.astype(compute_dtype(cfg))
    x = ctx.constrain(x, "dp", "sp", None)
    positions = jnp.arange(frames.shape[1])

    def layer(x, p):
        x, _, _ = block_parallel(
            p, x, ATTN, False, cfg, ctx, positions=positions, causal=False
        )
        return x, None

    body = layer
    if ctx.remat == "block":
        body = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    n_enc = params["layers"]["ln1"].shape[0]
    x, _ = scan_layers(body, x, params["layers"], ctx, n_enc)
    return rms_norm(x, params["final_ln"], cfg.norm_eps)


def run_stack(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ShardCtx,
    positions: jax.Array,
    memory: Optional[jax.Array] = None,
    causal: bool = True,
):
    period, R, n_tail, kinds, moes = _period_meta(cfg)
    aux = jnp.float32(0.0)
    has_xa = cfg.enc_dec

    if R > 0:
        xs = (params["body"], params.get("xattn_body")) if has_xa else (params["body"],)

        def one_layer(p):
            def f(layer_params, xa_p, x):
                return block_parallel(
                    layer_params, x, kinds[p], moes[p], cfg, ctx,
                    positions=positions, memory=memory, xa_params=xa_p,
                    causal=causal,
                )[:2]

            if ctx.remat == "layer":
                # per-layer checkpoint: backward re-gathers one layer's
                # FSDP shards at a time instead of a whole superblock's
                f = jax.checkpoint(
                    f, policy=jax.checkpoint_policies.nothing_saveable
                )
            return f

        layer_fns = [one_layer(p) for p in range(period)]

        def superblock(carry, inp):
            x, aux = carry
            if has_xa:
                p_list, xa_list = inp
            else:
                (p_list,) = inp
                xa_list = None
            for p in range(period):
                x, a = layer_fns[p](
                    p_list[p], xa_list[p] if xa_list is not None else None, x
                )
                aux = aux + a
            return (x, aux), None

        body = superblock
        if ctx.remat == "block":
            body = jax.checkpoint(
                superblock, policy=jax.checkpoint_policies.nothing_saveable
            )
        (x, aux), _ = scan_layers(body, (x, aux), xs, ctx, R)

    for j in range(n_tail):
        li = R * period + j
        x, a, _ = block_parallel(
            params["tail"][j], x, S.layer_kind_at(cfg, li), cfg.is_moe_layer(li),
            cfg, ctx, positions=positions, memory=memory,
            xa_params=(params["xattn_tail"][j] if has_xa else None),
            causal=causal,
        )
        aux = aux + a
    return x, aux


# ---------------------------------------------------------------------------
# training forward / loss
def lm_loss_chunked(
    xf: jax.Array,        # (B,S,D) final hidden states
    w: jax.Array,         # (D,V)
    labels: jax.Array,    # (B,S) int; -1 = ignore
    ctx: ShardCtx,
) -> Tuple[jax.Array, jax.Array]:
    B, Sq, D = xf.shape
    cs = min(ctx.logit_chunk, Sq)
    assert Sq % cs == 0
    n = Sq // cs
    xr = xf.reshape(B, n, cs, D).transpose(1, 0, 2, 3)
    lr = labels.reshape(B, n, cs).transpose(1, 0, 2)

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def one(args):
        xc, lc = args
        logits = (xc @ w.astype(xc.dtype)).astype(jnp.float32)   # (B,cs,V)
        logits = ctx.constrain(logits, "dp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lc, 0)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        valid = (lc >= 0).astype(jnp.float32)
        return jnp.sum((lse - ll) * valid), jnp.sum(valid)

    losses, counts = jax.lax.map(one, (xr, lr))
    return jnp.sum(losses), jnp.sum(counts)


def forward_train(
    params, batch: Dict[str, jax.Array], cfg: ModelConfig, ctx: ShardCtx = UNSHARDED,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens, labels = batch["tokens"], batch["labels"]
    x = embed(params, tokens, cfg, ctx)
    positions = jnp.arange(tokens.shape[1])

    memory = None
    if cfg.enc_dec:
        memory = encoder_forward(params["encoder"], batch["audio"], cfg, ctx)
    elif cfg.n_vision_tokens:
        memory = ctx.constrain(
            batch["vision"].astype(compute_dtype(cfg)), "dp", None, None
        )

    x, aux = run_stack(params, x, cfg, ctx, positions, memory=memory)
    xf = rms_norm(x, params["final_ln"], cfg.norm_eps)
    total, count = lm_loss_chunked(xf, head_weights(params, cfg), labels, ctx)
    loss = total / jnp.maximum(count, 1.0)
    full = loss + cfg.moe.load_balance_coef * aux / max(cfg.n_layers, 1)
    return full, {"loss": loss, "moe_aux": aux, "tokens": count}


# ---------------------------------------------------------------------------
# caches
def _attn_cache_len(cfg: ModelConfig, kind: str, seq_len: int) -> int:
    window = cfg.swa_window if (
        kind == ATTN_LOCAL or (cfg.block_pattern is None and cfg.swa_window)
    ) else 0
    return min(seq_len, window) if window else seq_len


def layer_cache_spec(
    cfg: ModelConfig, layer_idx: int, batch: int, seq_len: int, dtype
) -> Dict[str, Any]:
    kind = S.layer_kind_at(cfg, layer_idx)
    Kv, hd = cfg.n_kv_heads, cfg.hd
    spec: Dict[str, Any] = {}
    if kind in (ATTN, ATTN_LOCAL, CROSS):
        sc = _attn_cache_len(cfg, kind, seq_len)
        spec["k"] = jax.ShapeDtypeStruct((batch, sc, Kv, hd), dtype)
        spec["v"] = jax.ShapeDtypeStruct((batch, sc, Kv, hd), dtype)
    if kind == CROSS:
        spec["xk"] = jax.ShapeDtypeStruct((batch, cfg.n_vision_tokens, Kv, hd), dtype)
        spec["xv"] = jax.ShapeDtypeStruct((batch, cfg.n_vision_tokens, Kv, hd), dtype)
    if kind == MAMBA:
        spec.update(mamba_cache_spec(cfg, batch, dtype))
    if kind == MLSTM:
        spec.update(mlstm_cache_spec(cfg, batch))
    if kind == SLSTM:
        spec.update(slstm_cache_spec(cfg, batch))
    if cfg.enc_dec:
        spec["xk"] = jax.ShapeDtypeStruct((batch, cfg.n_audio_frames, Kv, hd), dtype)
        spec["xv"] = jax.ShapeDtypeStruct((batch, cfg.n_audio_frames, Kv, hd), dtype)
    return spec


def _stack_specs(tree, n):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype), tree
    )


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    dtype = compute_dtype(cfg)
    period, R, n_tail = S.layout(cfg)
    out: Dict[str, Any] = {"body": [], "tail": []}
    if R > 0:
        out["body"] = [
            _stack_specs(layer_cache_spec(cfg, p, batch, seq_len, dtype), R)
            for p in range(period)
        ]
    out["tail"] = [
        layer_cache_spec(cfg, R * period + j, batch, seq_len, dtype)
        for j in range(n_tail)
    ]
    return out


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_specs(cfg, batch, seq_len)
    )


# ---------------------------------------------------------------------------
# prefill: run the parallel stack, return (last-token logits, populated cache)
def prefill(
    params, batch: Dict[str, jax.Array], cfg: ModelConfig,
    ctx: ShardCtx = UNSHARDED, *, cache_seq_len: Optional[int] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    cache_seq_len = cache_seq_len or Sq
    dtype = compute_dtype(cfg)
    period, R, n_tail, kinds, moes = _period_meta(cfg)

    x = embed(params, tokens, cfg, ctx)
    positions = jnp.arange(Sq)
    memory = None
    if cfg.enc_dec:
        memory = encoder_forward(params["encoder"], batch["audio"], cfg, ctx)
    elif cfg.n_vision_tokens:
        memory = batch["vision"].astype(dtype)

    has_xa = cfg.enc_dec
    aux = jnp.float32(0.0)
    new_body = []
    if R > 0:
        xs = (params["body"], params.get("xattn_body")) if has_xa else (params["body"],)

        def superblock(carry, inp):
            x = carry
            if has_xa:
                p_list, xa_list = inp
            else:
                (p_list,) = inp
                xa_list = None
            caches = []
            for p in range(period):
                x, _, kv = block_parallel(
                    p_list[p], x, kinds[p], moes[p], cfg, ctx,
                    positions=positions, memory=memory,
                    xa_params=(xa_list[p] if xa_list is not None else None),
                    return_kv=True,
                )
                caches.append(_kv_cache_entry(dict(kv or {}), p, kinds[p], cfg,
                                              B, cache_seq_len, dtype))
            return x, caches

        x, body_caches = scan_layers(superblock, x, xs, ctx, R)
        new_body = body_caches
    new_tail = []
    for j in range(n_tail):
        li = R * period + j
        x, _, kv = block_parallel(
            params["tail"][j], x, S.layer_kind_at(cfg, li), cfg.is_moe_layer(li),
            cfg, ctx, positions=positions, memory=memory,
            xa_params=(params["xattn_tail"][j] if has_xa else None),
            return_kv=True,
        )
        new_tail.append(_kv_cache_entry(dict(kv or {}), li,
                                        S.layer_kind_at(cfg, li), cfg, B,
                                        cache_seq_len, dtype))

    xf = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = (xf[:, -1] @ head_weights(params, cfg).astype(dtype)).astype(jnp.float32)
    logits = ctx.constrain(logits, "dp", "tp")
    return logits, {"body": new_body, "tail": new_tail}


def _kv_cache_entry(kv, layer_idx, kind, cfg, B, cache_seq_len, dtype):
    """Build a layer's decode-cache entry from its parallel-pass outputs
    (attention KV, cross KV, and recurrent final states)."""
    spec = layer_cache_spec(cfg, layer_idx, B, cache_seq_len, dtype)
    out = {}
    for name, sds in spec.items():
        if name in kv:
            src = kv[name].astype(sds.dtype)
            if name in ("k", "v"):
                sc = sds.shape[1]
                full = src.shape[1]
                if full >= sc:
                    # ring-buffer layout: abs position P lives in slot P % sc
                    src = jnp.roll(src[:, -sc:], full % sc, axis=1)
                else:
                    src = jax.lax.dynamic_update_slice(
                        jnp.zeros(sds.shape, sds.dtype), src, (0, 0, 0, 0)
                    )
            out[name] = src
        else:
            out[name] = jnp.zeros(sds.shape, sds.dtype)
    return out


# ---------------------------------------------------------------------------
# decode
def decode_step(
    params,
    cache: Dict[str, Any],
    tokens: jax.Array,        # (B, 1)
    cache_len: jax.Array,     # scalar int32: #tokens already in cache
    cfg: ModelConfig,
    ctx: ShardCtx = UNSHARDED,
) -> Tuple[jax.Array, Dict[str, Any]]:
    period, R, n_tail, kinds, moes = _period_meta(cfg)
    x = embed(params, tokens, cfg, ctx)
    x = ctx.constrain(x, "dp", None, None)
    has_xa = cfg.enc_dec

    new_cache: Dict[str, Any] = {"body": [], "tail": []}
    if R > 0:
        xs = (
            (params["body"], cache["body"], params.get("xattn_body"))
            if has_xa
            else (params["body"], cache["body"])
        )

        def superblock(x, inp):
            if has_xa:
                p_list, c_list, xa_list = inp
            else:
                p_list, c_list = inp
                xa_list = None
            new_cs = []
            for p in range(period):
                x, nc = block_decode(
                    p_list[p], x, c_list[p], cache_len, kinds[p], moes[p], cfg, ctx,
                    xa_params=(xa_list[p] if xa_list is not None else None),
                )
                new_cs.append(nc)
            return x, new_cs

        x, new_body = scan_layers(superblock, x, xs, ctx, R)
        new_cache["body"] = new_body

    for j in range(n_tail):
        li = R * period + j
        x, nc = block_decode(
            params["tail"][j], x, cache["tail"][j], cache_len,
            S.layer_kind_at(cfg, li), cfg.is_moe_layer(li), cfg, ctx,
            xa_params=(params["xattn_tail"][j] if has_xa else None),
        )
        new_cache["tail"].append(nc)

    xf = rms_norm(x, params["final_ln"], cfg.norm_eps)
    w = head_weights(params, cfg)
    logits = (xf[:, 0] @ w.astype(xf.dtype)).astype(jnp.float32)
    logits = ctx.constrain(logits, "dp", "tp")
    return logits, new_cache


# ---------------------------------------------------------------------------
# batch specs (dry-run inputs)
def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, Sq = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dtype = compute_dtype(cfg)
    if shape.mode == "train" or shape.mode == "prefill":
        out = {
            "tokens": jax.ShapeDtypeStruct((B, Sq), i32),
        }
        if shape.mode == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, Sq), i32)
        if cfg.n_vision_tokens:
            out["vision"] = jax.ShapeDtypeStruct((B, cfg.n_vision_tokens, cfg.d_model), dtype)
        if cfg.enc_dec:
            out["audio"] = jax.ShapeDtypeStruct((B, cfg.n_audio_frames, cfg.d_model), dtype)
        return out
    # decode: one token + cache of seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "cache": cache_specs(cfg, B, Sq),
        "cache_len": jax.ShapeDtypeStruct((), i32),
    }
