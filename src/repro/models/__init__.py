from repro.models.model import (
    batch_specs,
    cache_specs,
    count_params_analytic,
    decode_step,
    forward_train,
    init_cache,
    init_params,
    prefill,
)
from repro.models.spec import model_param_specs
