"""Core neural layers: RMSNorm, RoPE, GQA attention (full / sliding-window /
chunked-online-softmax / decode-with-cache), and gated MLPs.

Attention memory policy
-----------------------
Full S x S score materialization is only allowed for short sequences
(<= ``FULL_ATTN_MAX_SEQ``). Longer sequences use a flash-style chunked
online-softmax written in pure JAX (lax.scan over KV chunks with a
``jax.checkpoint``-wrapped body so the backward pass recomputes scores
instead of storing them). The Pallas kernel in ``repro.kernels.flash_attn``
implements the same contraction for TPU; ``impl='pallas'`` routes to it.

Decode attention reads the whole KV cache with the *sequence axis sharded
over the model mesh axis*; softmax over the sharded axis makes the SPMD
partitioner emit the distributed flash-decode (partial max/sum all-reduce)
pattern automatically.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

FULL_ATTN_MAX_SEQ = 8192
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 1024
NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions: (..., S) int -> cos/sin (..., S, head_dim//2) fp32."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, half) or (S, half)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    if cos.ndim == 2:  # (S, half) -> broadcast over batch and heads
        cos_, sin_ = cos[None, :, None, :], sin[None, :, None, :]
    else:              # (B, S, half)
        cos_, sin_ = cos[:, :, None, :], sin[:, :, None, :]
    out1 = x1 * cos_ - x2 * sin_
    out2 = x2 * cos_ + x1 * sin_
    return jnp.concatenate([out1, out2], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# GQA helpers
def repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Kv, hd) -> (B, S, Kv*n_rep, hd)."""
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, hd)).reshape(
        b, s, kv * n_rep, hd
    )


def _band_mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: int):
    """(Sq, Sk) boolean mask. window > 0 limits lookback (SWA)."""
    d = q_pos[:, None] - k_pos[None, :]
    m = jnp.ones(d.shape, bool)
    if causal:
        m &= d >= 0
    if window > 0:
        m &= d < window
    return m


def attention_full(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: Optional[int] = None,
) -> jax.Array:
    """Materialized-scores attention. q: (B,Sq,H,hd), k/v: (B,Sk,Kv,hd).
    Causal convention: queries align with the END of the key sequence."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    if q_offset is None:
        q_offset = k.shape[1] - sq
    k = repeat_kv(k, h // kv)
    v = repeat_kv(v, h // kv)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    if causal or window:
        q_pos = jnp.arange(sq) + q_offset
        k_pos = jnp.arange(k.shape[1])
        mask = _band_mask(q_pos, k_pos, causal, window)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Flash-style online-softmax attention in pure JAX (O(S*block) memory).

    Backward recomputes per-chunk scores (jax.checkpoint on the inner body).
    Sliding windows skip KV chunks entirely outside the band.
    """
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    n_rep = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    q_off = sk - sq  # causal: queries align with the end of the keys
    kv_len = sk
    # pad to block multiples; padded KV is masked out, padded Q sliced away
    sq_orig = sq
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        sq += pad_q
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        sk += pad_k
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    k = k.reshape(b, nk, block_k, kvh, hd)
    v = v.reshape(b, nk, block_k, kvh, hd)
    qb = q.reshape(b, nq, block_q, h, hd)

    # Sliding window: each q block only ever touches a *static-width* band of
    # KV blocks; slice it out with a traced start (exact FLOP savings — the
    # XLA analogue of the Pallas kernel's block skipping). Causal-only runs
    # over all KV blocks with masking (2x FLOP overhead on the XLA path; the
    # TPU kernel skips above-diagonal blocks).
    if window > 0:
        nbk = min(nk, (window + block_q + block_k - 1) // block_k + 1)
    else:
        nbk = nk

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def kv_step(carry, kj, vj, k_pos, qi_blk, q_posb):
        (m, l, o) = carry
        kj = repeat_kv(kj, n_rep)
        vj = repeat_kv(vj, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi_blk.astype(jnp.float32), kj.astype(jnp.float32))
        s = s * scale
        d = q_posb[:, None] - k_pos[None, :]
        mask = k_pos[None, :] < kv_len          # padded keys masked
        if causal:
            mask &= d >= 0
        if window > 0:
            mask &= d < window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vj.astype(jnp.float32)
        )
        return (m_new, l_new, o_new)

    def q_block(qi, qi_idx):
        q_posb = qi_idx * block_q + jnp.arange(block_q) + q_off
        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        o0 = jnp.zeros((b, h, block_q, hd), jnp.float32)
        if window > 0:
            # first KV block of the band (block units), clamped to fit
            lo_b = jnp.clip(
                (qi_idx * block_q + q_off - window) // block_k, 0, nk - nbk
            )
            kb = jax.lax.dynamic_slice_in_dim(k, lo_b, nbk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, lo_b, nbk, axis=1)
        else:
            lo_b = jnp.int32(0)
            kb, vb = k, v

        def body(carry, j):
            kj = jax.lax.dynamic_index_in_dim(kb, j, axis=1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vb, j, axis=1, keepdims=False)
            k_pos = (lo_b + j) * block_k + jnp.arange(block_k)
            new_carry = kv_step(carry, kj, vj, k_pos, qi, q_posb)
            if causal:
                # skip blocks entirely above the diagonal (mask-only; the
                # einsum still runs — see note above)
                take = (lo_b + j) * block_k <= qi_idx * block_q + q_off + block_q - 1
                new_carry = jax.tree.map(
                    lambda n, c: jnp.where(take, n, c), new_carry, carry
                )
            return new_carry, None

        (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), jnp.arange(nbk))
        out = o / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3)  # (b, block_q, h, hd)

    outs = jax.lax.map(
        lambda args: q_block(*args),
        (qb.transpose(1, 0, 2, 3, 4), jnp.arange(nq)),
    )  # (nq, b, block_q, h, hd)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)
    return out[:, :sq_orig].astype(q.dtype)


def attention(
    q, k, v, *, causal=True, window=0, impl="auto",
    block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
) -> jax.Array:
    """Dispatching attention entry point (training / prefill)."""
    sq, sk = q.shape[1], k.shape[1]
    if impl == "pallas":
        from repro.kernels import ops as kops

        return kops.flash_attention(
            q, k, v, causal=causal, window=window,
            block_q=block_q, block_k=block_k,
        )
    if impl == "full" or (impl == "auto" and max(sq, sk) <= FULL_ATTN_MAX_SEQ):
        return attention_full(q, k, v, causal=causal, window=window)
    return attention_chunked(
        q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k
    )


def decode_attention(
    q: jax.Array,          # (B, 1, H, hd)
    k_cache: jax.Array,    # (B, S, Kv, hd)  (seq axis may be mesh-sharded)
    v_cache: jax.Array,
    cache_len: jax.Array,  # scalar or (B,) number of valid cache positions
) -> jax.Array:
    """Single-token attention over a (possibly sharded) KV cache.

    Written so the softmax reductions run over the cache sequence axis:
    when that axis is sharded over the 'model' mesh axis, XLA's SPMD
    partitioner turns max/sum into cross-shard all-reduces = distributed
    flash-decode.
    """
    b, _, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    n_rep = h // kv
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qf = q[:, 0].astype(jnp.float32)                       # (B, H, hd)
    kf = k_cache.astype(jnp.float32)                       # (B, S, Kv, hd)
    # GQA without materializing repeated KV: fold rep into head grouping.
    qg = qf.reshape(b, kv, n_rep, hd)
    logits = jnp.einsum("bgrd,bsgd->bgrs", qg, kf) * scale  # (B, Kv, rep, S)
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.broadcast_to(jnp.atleast_1d(cache_len)[:, None], (b, s))
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    probs = p / jnp.maximum(l, 1e-30)
    out = jnp.einsum("bgrs,bsgd->bgrd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
def mlp_apply(params, x: jax.Array, *, gated: bool, eps: float) -> jax.Array:
    h = rms_norm(x, params["ln2"], eps)
    dt = x.dtype
    wi = params["wi"].astype(dt)
    wo = params["wo2"].astype(dt)
    if gated:
        wg = params["wg"].astype(dt)
        a = jax.nn.silu(h @ wg) * (h @ wi)
    else:
        a = jax.nn.gelu(h @ wi)
    return a @ wo
