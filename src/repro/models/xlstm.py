"""xLSTM blocks: chunkwise-parallel mLSTM (matrix memory) and strictly
sequential sLSTM (scalar memory, recurrent gate mixing), both with
exp-input-gate stabilization (running max exponent m).

mLSTM parallel form is GLA-style: per chunk, an intra-chunk decay-masked
attention plus an inter-chunk contribution from the carried (C, n, m)
state; the same recurrence is used step-wise for decode.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import spec as S
from repro.models.layers import rms_norm
from repro.sharding.ctx import ShardCtx

NEG = -1e30


def _heads(x, nh):
    b, s, d = x.shape
    return x.reshape(b, s, nh, d // nh)


# ---------------------------------------------------------------------------
# mLSTM
def _mlstm_gates(params, xc, dt):
    logi = (xc @ params["gi"].astype(dt)).astype(jnp.float32)     # (B,S,nh)
    logf = jax.nn.log_sigmoid(
        (xc @ params["gf"].astype(dt)).astype(jnp.float32)
    )
    return logi, logf


def mlstm_apply(
    params: Dict[str, Any],
    x: jax.Array,                # (B,S,D)
    cfg: ModelConfig,
    ctx: ShardCtx,
    *,
    return_state: bool = False,
):
    from repro.models.mamba import _causal_conv

    B, Sq, D = x.shape
    di = S.d_inner(cfg)
    nh = cfg.n_heads
    dh = di // nh
    dt = x.dtype
    L = min(cfg.ssm.chunk, Sq)
    pad = (-Sq) % L
    Sq_orig = Sq
    Sq = Sq + pad
    nc = Sq // L

    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    xm, z = jnp.split(h @ params["up"].astype(dt), 2, axis=-1)     # (B,S,di)
    xc = jax.nn.silu(_causal_conv(xm, params["conv_w"], params["conv_b"]))
    q = _heads(xc @ params["wq"].astype(dt), nh).astype(jnp.float32)
    k = _heads(xc @ params["wk"].astype(dt), nh).astype(jnp.float32) / jnp.sqrt(
        jnp.float32(dh)
    )
    v = _heads(xm @ params["wv"].astype(dt), nh).astype(jnp.float32)
    logi, logf = _mlstm_gates(params, xc, dt)
    if pad:
        # masked padding: no input (logi=-inf), no decay (logf=0) -> the
        # carried state is untouched by padded steps
        padT = lambda a, v=0.0: jnp.pad(
            a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
            constant_values=v)
        q, k, v = padT(q), padT(k), padT(v)
        logi = padT(logi, NEG)
        logf = padT(logf, 0.0)

    # chunk everything: (B, nc, L, ...)
    def ch(a):
        return a.reshape(B, nc, L, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    qc, kc, vc = ch(q), ch(k), ch(v)              # (nc,B,L,nh,dh)
    lic, lfc = ch(logi), ch(logf)                 # (nc,B,L,nh)

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk(carry, inp):
        C, n, m = carry                            # (B,nh,dh,dh),(B,nh,dh),(B,nh)
        qi, ki, vi, li, lf = inp
        Fl = jnp.cumsum(lf, axis=1)                # (B,L,nh) within-chunk decay
        Fc = Fl[:, -1]                             # (B,nh)
        # intra-chunk log-weights w[t,s] = Fl_t - Fl_s + li_s  (s<=t)
        w = Fl[:, :, None, :] - Fl[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(tri[None, :, :, None], w, NEG)     # (B,L,L,nh)
        m_intra = jnp.max(w, axis=2)                     # (B,L,nh)
        m_inter = Fl + m[:, None, :]                     # carry exponent
        m_new = jnp.maximum(m_intra, m_inter)            # (B,L,nh)
        # intra attention
        qk = jnp.einsum("blhd,bshd->blsh", qi, ki)       # (B,L,L,nh)
        p = jnp.exp(w - m_new[:, :, None, :]) * qk
        num_intra = jnp.einsum("blsh,bshd->blhd", p, vi)
        den_intra = jnp.sum(p, axis=2)                   # (B,L,nh)
        # inter (carried state)
        scale_inter = jnp.exp(m_inter - m_new)           # (B,L,nh)
        num_inter = jnp.einsum("blhd,bhde->blhe", qi, C) * scale_inter[..., None]
        den_inter = jnp.einsum("blhd,bhd->blh", qi, n) * scale_inter
        num = num_intra + num_inter
        den = den_intra + den_inter
        hpre = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        # carry update to end of chunk
        m_endc = jnp.maximum(
            m + Fc, jnp.max(Fc[:, None] - Fl + li, axis=1)
        )                                               # (B,nh)
        dec_old = jnp.exp(m + Fc - m_endc)              # (B,nh)
        wk_end = jnp.exp(Fc[:, None] - Fl + li - m_endc[:, None])  # (B,L,nh)
        C_new = C * dec_old[..., None, None] + jnp.einsum(
            "blhd,blhe,blh->bhde", ki, vi, wk_end
        )
        n_new = n * dec_old[..., None] + jnp.einsum("blhd,blh->bhd", ki, wk_end)
        return (C_new, n_new, m_endc), hpre

    C0 = jnp.zeros((B, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, nh, dh), jnp.float32)
    m0 = jnp.full((B, nh), NEG, jnp.float32)
    (Cf, nf, mf), hs = jax.lax.scan(chunk, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    hseq = hs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, di)[:, :Sq_orig].astype(dt)
    hseq = rms_norm(hseq, params["ln_inner"], cfg.norm_eps)
    out = (hseq * jax.nn.silu(z)) @ params["down"].astype(dt)
    if return_state:
        state = {
            "C": Cf, "n": nf, "m": mf,
            "conv": xm[:, -3:].astype(jnp.float32),
        }
        return out, state
    return out


def mlstm_cache_spec(cfg: ModelConfig, batch: int):
    di = S.d_inner(cfg)
    nh = cfg.n_heads
    dh = di // nh
    return {
        "C": jax.ShapeDtypeStruct((batch, nh, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, nh, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, 3, di), jnp.float32),
    }


def mlstm_decode(params, x, cache, cfg: ModelConfig, ctx: ShardCtx):
    B, _, D = x.shape
    di = S.d_inner(cfg)
    nh = cfg.n_heads
    dh = di // nh
    dt = x.dtype

    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    xm, z = jnp.split(h @ params["up"].astype(dt), 2, axis=-1)    # (B,1,di)
    conv_in = jnp.concatenate([cache["conv"].astype(dt), xm], axis=1)
    w = params["conv_w"].astype(dt)
    xc = jax.nn.silu(
        jnp.einsum("bcd,cd->bd", conv_in, w) + params["conv_b"].astype(dt)
    )                                                             # (B,di)
    q = (xc @ params["wq"].astype(dt)).reshape(B, nh, dh).astype(jnp.float32)
    k = (xc @ params["wk"].astype(dt)).reshape(B, nh, dh).astype(jnp.float32)
    k = k / jnp.sqrt(jnp.float32(dh))
    v = (xm[:, 0] @ params["wv"].astype(dt)).reshape(B, nh, dh).astype(jnp.float32)
    li = (xc @ params["gi"].astype(dt)).astype(jnp.float32)       # (B,nh)
    lf = jax.nn.log_sigmoid((xc @ params["gf"].astype(dt)).astype(jnp.float32))

    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(lf + m, li)
    a = jnp.exp(lf + m - m_new)
    b = jnp.exp(li - m_new)
    C_new = C * a[..., None, None] + b[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n_new = n * a[..., None] + b[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    den = jnp.einsum("bhd,bhd->bh", q, n_new)
    hvec = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    hvec = hvec.reshape(B, di).astype(dt)
    hvec = rms_norm(hvec, params["ln_inner"], cfg.norm_eps)
    out = (hvec[:, None, :] * jax.nn.silu(z)) @ params["down"].astype(dt)
    new_cache = {"C": C_new, "n": n_new, "m": m_new, "conv": conv_in[:, 1:].astype(jnp.float32)}
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
def _slstm_cell(params, gates_x, carry, nh, dh):
    """One step. gates_x: (B,4D) precomputed x@W+b; carry: (c,n,h,m)."""
    c, n, hprev, m = carry
    B = gates_x.shape[0]
    D = nh * dh
    rec = jnp.einsum(
        "bhd,hde->bhe", hprev.reshape(B, nh, dh), params["r"].astype(jnp.float32)
    ).reshape(B, 4 * D)
    g = gates_x + rec
    ip, fp, zp, op = jnp.split(g, 4, axis=-1)
    log_i = ip
    log_f = jax.nn.log_sigmoid(fp)
    m_new = jnp.maximum(log_f + m, log_i)
    a = jnp.exp(log_f + m - m_new)
    b = jnp.exp(log_i - m_new)
    zt = jnp.tanh(zp)
    c_new = a * c + b * zt
    n_new = a * n + b
    h_new = jax.nn.sigmoid(op) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_apply(params, x, cfg: ModelConfig, ctx: ShardCtx, *,
                return_state: bool = False):
    B, Sq, D = x.shape
    nh = cfg.n_heads
    dh = D // nh
    dt = x.dtype
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    gates_x = (h @ params["w"].astype(dt)).astype(jnp.float32) + params["b"].astype(
        jnp.float32
    )                                                             # (B,S,4D)

    def step(carry, gx):
        new = _slstm_cell(params, gx, carry, nh, dh)
        return new, new[2]

    zeros = jnp.zeros((B, D), jnp.float32)
    carry0 = (zeros, zeros, zeros, jnp.full((B, D), NEG, jnp.float32))
    (cf, nf, hf, mf), hs = jax.lax.scan(step, carry0, gates_x.transpose(1, 0, 2))
    hseq = hs.transpose(1, 0, 2).astype(dt)                       # (B,S,D)
    hseq = rms_norm(hseq, params["ln_inner"], cfg.norm_eps)
    u = hseq @ params["up"].astype(dt)
    u1, u2 = jnp.split(u, 2, axis=-1)
    out = (jax.nn.silu(u1) * u2) @ params["down"].astype(dt)
    if return_state:
        return out, {"c": cf, "n": nf, "h": hf, "m": mf}
    return out


def slstm_cache_spec(cfg: ModelConfig, batch: int):
    D = cfg.d_model
    f32 = jnp.float32
    return {
        "c": jax.ShapeDtypeStruct((batch, D), f32),
        "n": jax.ShapeDtypeStruct((batch, D), f32),
        "h": jax.ShapeDtypeStruct((batch, D), f32),
        "m": jax.ShapeDtypeStruct((batch, D), f32),
    }


def slstm_decode(params, x, cache, cfg: ModelConfig, ctx: ShardCtx):
    B, _, D = x.shape
    nh, dh = cfg.n_heads, D // cfg.n_heads
    dt = x.dtype
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    gx = (h[:, 0] @ params["w"].astype(dt)).astype(jnp.float32) + params["b"].astype(
        jnp.float32
    )
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, hn, m = _slstm_cell(params, gx, carry, nh, dh)
    hvec = rms_norm(hn.astype(dt), params["ln_inner"], cfg.norm_eps)
    u = hvec @ params["up"].astype(dt)
    u1, u2 = jnp.split(u, 2, axis=-1)
    out = ((jax.nn.silu(u1) * u2) @ params["down"].astype(dt))[:, None, :]
    return out, {"c": c, "n": n, "h": hn, "m": m}
