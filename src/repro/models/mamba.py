"""Mamba (selective SSM) mixer block.

Parallel (train/prefill) path: chunked associative selective scan — either
the pure-jnp oracle (`kernels.ref.selective_scan_ref`) or the Pallas TPU
kernel (`kernels.ops.selective_scan`). Decode path: O(1) recurrent update
carrying (conv_state, ssm_state).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import spec as S
from repro.models.layers import rms_norm
from repro.sharding.ctx import ShardCtx


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 init: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv along seq. x: (B,S,di); w: (dc,di); b: (di,)."""
    dc = w.shape[0]
    if init is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = init.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(dc):
        out = out + xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def mamba_apply(
    params: Dict[str, Any],
    x: jax.Array,                    # (B, S, D)
    cfg: ModelConfig,
    ctx: ShardCtx,
    *,
    impl: str = "xla",
    return_state: bool = False,
):
    B, Sq, D = x.shape
    di = S.d_inner(cfg)
    ds = cfg.ssm.d_state
    dr = S.dt_rank(cfg)
    dt_ = x.dtype

    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    xz = h @ params["in_proj"].astype(dt_)                  # (B,S,2di)
    xb_raw, z = jnp.split(xz, 2, axis=-1)
    xb_raw = ctx.constrain(xb_raw, "dp", None, "tp")
    xb = jax.nn.silu(_causal_conv(xb_raw, params["conv_w"], params["conv_b"]))

    proj = xb @ params["x_proj"].astype(dt_)                # (B,S,dr+2ds)
    dt_low, Bc, Cc = jnp.split(proj, [dr, dr + ds], axis=-1)
    dtv = jax.nn.softplus(
        dt_low @ params["dt_w"].astype(dt_) + params["dt_b"].astype(dt_)
    )                                                       # (B,S,di)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))       # (di,ds)

    if impl == "pallas":
        from repro.kernels import ops as kops

        y, final = kops.selective_scan(
            xb.astype(jnp.float32), dtv.astype(jnp.float32), A,
            Bc.astype(jnp.float32), Cc.astype(jnp.float32), chunk=cfg.ssm.chunk,
        )
    else:
        from repro.kernels.ref import selective_scan_ref

        y, final = selective_scan_ref(
            xb.astype(jnp.float32), dtv.astype(jnp.float32), A,
            Bc.astype(jnp.float32), Cc.astype(jnp.float32), chunk=cfg.ssm.chunk,
        )
    y = y.astype(dt_) + xb * params["D_skip"].astype(dt_)
    y = y * jax.nn.silu(z)
    y = ctx.constrain(y, "dp", None, "tp")
    out = y @ params["out_proj"].astype(dt_)
    if return_state:
        dc = cfg.ssm.d_conv
        state = {
            "conv": xb_raw[:, -(dc - 1):].astype(jnp.float32),
            "ssm": final.astype(jnp.float32),
        }
        return out, state
    return out


def mamba_cache_spec(cfg: ModelConfig, batch: int, dtype=None) -> Dict[str, Any]:
    di = S.d_inner(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm.d_conv - 1, di), jnp.float32),
        "ssm": jax.ShapeDtypeStruct((batch, di, cfg.ssm.d_state), jnp.float32),
    }


def mamba_decode(
    params: Dict[str, Any],
    x: jax.Array,                    # (B, 1, D)
    cache: Dict[str, jax.Array],
    cfg: ModelConfig,
    ctx: ShardCtx,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    from repro.kernels.ref import selective_scan_step_ref

    B, _, D = x.shape
    dr = S.dt_rank(cfg)
    ds = cfg.ssm.d_state
    dt_ = x.dtype

    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    xz = h @ params["in_proj"].astype(dt_)
    xb, z = jnp.split(xz, 2, axis=-1)                        # (B,1,di)
    conv_in = jnp.concatenate([cache["conv"].astype(dt_), xb], axis=1)
    w = params["conv_w"].astype(dt_)                         # (dc, di)
    xc = jnp.einsum("bcd,cd->bd", conv_in, w) + params["conv_b"].astype(dt_)
    xc = jax.nn.silu(xc)                                     # (B, di)

    proj = xc @ params["x_proj"].astype(dt_)
    dt_low, Bc, Cc = jnp.split(proj, [dr, dr + ds], axis=-1)
    dtv = jax.nn.softplus(
        dt_low @ params["dt_w"].astype(dt_) + params["dt_b"].astype(dt_)
    )
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, new_ssm = selective_scan_step_ref(
        cache["ssm"], xc.astype(jnp.float32), dtv.astype(jnp.float32), A,
        Bc.astype(jnp.float32), Cc.astype(jnp.float32),
    )
    y = y.astype(dt_) + xc * params["D_skip"].astype(dt_)
    y = (y[:, None, :] * jax.nn.silu(z)) @ params["out_proj"].astype(dt_)
    new_cache = {"conv": conv_in[:, 1:].astype(jnp.float32), "ssm": new_ssm}
    return y, new_cache
