"""Single source of truth for parameter shapes.

``model_param_specs(cfg)`` returns a pytree of ``jax.ShapeDtypeStruct`` that
is consumed by (a) random init (``repro.models.init``), (b) the analytic
parameter counter (MODEL_FLOPS for the roofline), and (c) the multi-pod
dry-run, which lowers against specs without allocating anything.

Layer layout
------------
Layers are grouped into *superblocks* of ``period`` layers (the LCM of the
block pattern length and the MoE period), so heterogeneous stacks (jamba,
gemma, xlstm, VLM) scan over identical superblocks. Params of position ``p``
inside the superblock are stacked over the ``n_repeats`` superblocks
(leading axis R); any remainder layers live unstacked under ``tail``.

Every layer = mixer (attn / attn_local / cross / mamba / mlstm / slstm)
+ optional FFN (dense or MoE). ``d_ff == 0`` (xlstm) means no FFN — the
cells carry their own up/down projections.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    ATTN,
    ATTN_LOCAL,
    CROSS,
    MAMBA,
    MLSTM,
    SLSTM,
    ModelConfig,
)

PARAM_DTYPE = jnp.float32     # master dtype; compute casts to cfg.dtype


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def superblock_period(cfg: ModelConfig) -> int:
    p = len(cfg.block_pattern) if cfg.block_pattern else 1
    if cfg.moe.n_experts > 0:
        p = _lcm(p, cfg.moe.period)
    if cfg.cross_attn_period:
        p = _lcm(p, cfg.cross_attn_period)
    return p


def layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(period, n_repeats, n_tail) of the decoder stack."""
    period = superblock_period(cfg)
    n_repeats = cfg.n_layers // period
    n_tail = cfg.n_layers - n_repeats * period
    return period, n_repeats, n_tail


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def slstm_ff(cfg: ModelConfig) -> int:
    return int(round(cfg.d_model * 4 / 3 / 64)) * 64 or 64


def layer_kind_at(cfg: ModelConfig, layer_idx: int) -> str:
    kind = cfg.layer_kind(layer_idx)
    if cfg.cross_attn_period and (layer_idx % cfg.cross_attn_period) == (
        cfg.cross_attn_period - 1
    ):
        kind = CROSS
    return kind


def _sds(*shape, dtype=PARAM_DTYPE):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def mixer_specs(cfg: ModelConfig, kind: str, *, causal: bool = True) -> Dict[str, Any]:
    D, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s: Dict[str, Any] = {"ln1": _sds(D)}
    if kind in (ATTN, ATTN_LOCAL, CROSS):
        s.update(
            wq=_sds(D, H * hd),
            wk=_sds(D, Kv * hd),
            wv=_sds(D, Kv * hd),
            wo=_sds(H * hd, D),
        )
        if cfg.qk_norm:
            s.update(qn=_sds(hd), kn=_sds(hd))
        if kind == CROSS:
            s.update(
                lnx=_sds(D),
                xq=_sds(D, H * hd),
                xk=_sds(D, Kv * hd),
                xv=_sds(D, Kv * hd),
                xo=_sds(H * hd, D),
                xgate=_sds(1),
            )
    elif kind == MAMBA:
        di, ds, dc, dr = d_inner(cfg), cfg.ssm.d_state, cfg.ssm.d_conv, dt_rank(cfg)
        s.update(
            in_proj=_sds(D, 2 * di),
            conv_w=_sds(dc, di),
            conv_b=_sds(di),
            x_proj=_sds(di, dr + 2 * ds),
            dt_w=_sds(dr, di),
            dt_b=_sds(di),
            A_log=_sds(di, ds),
            D_skip=_sds(di),
            out_proj=_sds(di, D),
        )
    elif kind == MLSTM:
        di = d_inner(cfg)
        nh = cfg.n_heads
        s.update(
            up=_sds(D, 2 * di),
            conv_w=_sds(4, di),
            conv_b=_sds(di),
            wq=_sds(di, di),
            wk=_sds(di, di),
            wv=_sds(di, di),
            gi=_sds(di, nh),
            gf=_sds(di, nh),
            ln_inner=_sds(di),
            down=_sds(di, D),
        )
    elif kind == SLSTM:
        D4 = 4 * D
        nh = cfg.n_heads
        dh = D // nh
        ff = slstm_ff(cfg)
        s.update(
            w=_sds(D, D4),
            r=_sds(nh, dh, 4 * dh),
            b=_sds(D4),
            ln_inner=_sds(D),
            up=_sds(D, 2 * ff),
            down=_sds(ff, D),
        )
    else:
        raise ValueError(f"unknown mixer kind {kind}")
    return s


def ffn_specs(cfg: ModelConfig, is_moe: bool) -> Dict[str, Any]:
    D, F = cfg.d_model, cfg.d_ff
    if F == 0:
        return {}
    s: Dict[str, Any] = {"ln2": _sds(D)}
    if is_moe:
        E = cfg.moe.n_experts
        s.update(
            router=_sds(D, E),
            e_wg=_sds(E, D, F),
            e_wi=_sds(E, D, F),
            e_wo=_sds(E, F, D),
        )
    else:
        s.update(wi=_sds(D, F), wo2=_sds(F, D))
        if mlp_gated(cfg):
            s["wg"] = _sds(D, F)
    return s


def mlp_gated(cfg: ModelConfig) -> bool:
    return cfg.family != "audio"   # whisper uses plain GELU MLPs


def layer_specs(cfg: ModelConfig, layer_idx: int, *, decoder: bool = True) -> Dict[str, Any]:
    kind = layer_kind_at(cfg, layer_idx) if decoder else ATTN
    s = dict(mixer_specs(cfg, kind))
    s.update(ffn_specs(cfg, decoder and cfg.is_moe_layer(layer_idx)))
    return s


def _stack(spec_tree, n: int):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype), spec_tree
    )


def model_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    period, n_repeats, n_tail = layout(cfg)
    specs: Dict[str, Any] = {
        "emb": _sds(cfg.vocab, cfg.d_model),
        "final_ln": _sds(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        specs["head"] = _sds(cfg.d_model, cfg.vocab)
    # decoder body: one spec per position in the superblock, stacked R times
    if n_repeats > 0:
        specs["body"] = [
            _stack(layer_specs(cfg, p), n_repeats) for p in range(period)
        ]
    else:
        specs["body"] = []
    specs["tail"] = [
        layer_specs(cfg, n_repeats * period + j) for j in range(n_tail)
    ]
    if cfg.enc_dec:
        enc_layer = dict(mixer_specs(cfg, ATTN))
        enc_layer.update(ffn_specs(cfg, False))
        specs["encoder"] = {
            "layers": _stack(enc_layer, cfg.n_enc_layers),
            "final_ln": _sds(cfg.d_model),
        }
        # decoder layers gain cross-attention onto encoder memory
        xa = {
            "lnx": _sds(cfg.d_model),
            "xq": _sds(cfg.d_model, cfg.n_heads * cfg.hd),
            "xk": _sds(cfg.d_model, cfg.n_kv_heads * cfg.hd),
            "xv": _sds(cfg.d_model, cfg.n_kv_heads * cfg.hd),
            "xo": _sds(cfg.n_heads * cfg.hd, cfg.d_model),
        }
        if n_repeats > 0:
            specs["xattn_body"] = [_stack(xa, n_repeats) for _ in range(period)]
        specs["xattn_tail"] = [dict(xa) for _ in range(n_tail)]
    return specs


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact element count of model_param_specs; MoE experts scaled by
    top_k/n_experts when ``active_only`` (for MODEL_FLOPS = 6*N_active*D)."""
    specs = model_param_specs(cfg)
    total = 0

    def visit(path: str, leaf):
        nonlocal total
        n = int(np.prod(leaf.shape))
        if active_only and ("/e_w" in path or path.endswith(("e_wg", "e_wi", "e_wo"))):
            n = n * cfg.moe.top_k // max(cfg.moe.n_experts, 1)
        total += n

    from repro.utils.tree import tree_map_with_path_names

    tree_map_with_path_names(visit, specs)
    return total
