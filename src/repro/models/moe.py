"""Top-k Mixture-of-Experts FFN with *grouped* scatter dispatch.

The GSPMD-canonical design (Mesh-TF Switch / flaxformer / MaxText): tokens
are reshaped into G groups (G = the data-parallel degree), routing ranks
and capacity are computed *within* each group, so every dispatch step is
local to its shard — no global cumsum, no replicated (E, C, D) buffers
(the naive global-capacity layout makes XLA replicate the whole expert
batch on every device: ~dp-times the FLOPs and tens of GiB of temps).

Expert compute sharding:
- E % |tp| == 0 (jamba 16e): experts sharded over 'model' (EP) — GSPMD
  inserts the canonical all-to-all on the grouped buffer;
- otherwise (mixtral/grok 8e): d_ff sharded over 'model' (TP-in-expert),
  groups stay on 'data' — no cross-shard token movement at all.

Tokens beyond an expert's per-group capacity are dropped (standard
capacity-factor semantics; cf is a config knob).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.sharding.ctx import ShardCtx


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _n_groups(ctx: ShardCtx, n_tokens_rows: int) -> int:
    g = max(ctx.dp_size, 1)
    while g > 1 and n_tokens_rows % g != 0:
        g //= 2
    return max(g, 1)


def moe_apply(
    params,
    x: jax.Array,             # (B, S, D)
    cfg: ModelConfig,
    ctx: ShardCtx,
    *,
    capacity_factor: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balancing loss scalar)."""
    if capacity_factor is None:
        capacity_factor = cfg.moe.capacity_factor
    B, Sq, D = x.shape
    E, K = cfg.moe.n_experts, cfg.moe.top_k
    dt = x.dtype
    ep = ctx.enabled and ctx.expert_parallel and E % max(ctx.tp_size, 1) == 0

    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    T = B * Sq
    G = _n_groups(ctx, B) if Sq > 1 else _n_groups(ctx, B)
    # group along the batch axis so groups align with the dp sharding
    Tg = T // G
    hf = h.reshape(G, Tg, D)
    hf = ctx.constrain(hf, "dp", None, None)

    logits = (hf.astype(jnp.float32)) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (G, Tg, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)         # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # load-balance aux loss (Switch): E * mean_e(f_e * p_e)
    pe = jnp.mean(probs, axis=(0, 1))
    fe = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    )
    aux = E * jnp.sum(pe * fe)

    # rank of each (token, k) slot within its expert, LOCAL to the group
    flat_e = expert_idx.reshape(G, Tg * K)                  # (G, TgK)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # (G, TgK, E)
    ranks_all = jnp.cumsum(onehot, axis=1) - onehot
    rank = jnp.take_along_axis(
        ranks_all, flat_e[..., None], axis=2
    )[..., 0]                                               # (G, TgK)

    C = round_up(int(capacity_factor * Tg * K / E) or 1, 8)
    keep = rank < C
    safe_rank = jnp.where(keep, rank, 0)

    # batched scatter into the grouped (G, E, C, D) buffer. The buffer is
    # kept E-REPLICATED across 'model' (sharded only on G->dp): the scatter
    # is then entirely local. Sharding E (or C) here makes GSPMD realize
    # dispatch/combine as fp32 all-reduces of the full (G, TgK, D) token
    # tensor over 'model' — measured 1.7e12 B/dev/step on jamba (see
    # EXPERIMENTS.md §Perf iteration 2).
    hk = jnp.repeat(hf, K, axis=1)                          # (G, TgK, D)
    contrib = jnp.where(keep[..., None], hk, 0).astype(dt)
    gidx = jnp.arange(G)[:, None]
    buf = jnp.zeros((G, E, C, D), dt).at[gidx, flat_e, safe_rank].add(
        contrib, mode="drop"
    )
    local_spec = P(ctx.axis("dp") if ctx.enabled else None, None, None, None)
    buf = ctx.constrain_raw(buf, local_spec)

    # expert FFN (SwiGLU). EP: each tp-rank slices its experts (free — buf
    # is E-replicated) and computes them; the combine all-gathers the
    # (G_loc, E, C, D) buffer over 'model' once. Non-EP: d_ff is tp-sharded
    # and the contraction psums the same-sized buffer instead.
    if ctx.enabled and ep:
        buf = ctx.constrain_raw(buf, P(ctx.axis("dp"), ctx.tp, None, None))
    e_wg = params["e_wg"].astype(dt)
    e_wi = params["e_wi"].astype(dt)
    e_wo = params["e_wo"].astype(dt)
    act = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, e_wg)) * jnp.einsum(
        "gecd,edf->gecf", buf, e_wi
    )
    out_buf = jnp.einsum("gecf,efd->gecd", act, e_wo)
    out_buf = ctx.constrain_raw(out_buf, local_spec)

    # gather back + weight by gates (local: out_buf is E-replicated again)
    y = out_buf[gidx, flat_e, safe_rank]                    # (G, TgK, D)
    gates = (gate_vals.reshape(G, Tg * K) * keep).astype(dt)
    y = y * gates[..., None]
    y = jnp.sum(y.reshape(G, Tg, K, D), axis=2)
    y = ctx.constrain(y, "dp", None, None)
    return y.reshape(B, Sq, D), aux.astype(jnp.float32)
