"""Per-layer block application: mixer (attn/local/cross/mamba/mlstm/slstm)
+ optional FFN (dense or MoE), for both the parallel (train/prefill) and
single-token (decode) paths.

Every function is pure; caches are explicit pytrees.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_LOCAL, CROSS, MAMBA, MLSTM, SLSTM, ModelConfig
from repro.models import spec as S
from repro.models.layers import (
    apply_rope,
    attention,
    decode_attention,
    mlp_apply,
    rms_norm,
    rope_cos_sin,
)
from repro.models.mamba import mamba_apply, mamba_decode
from repro.models.moe import moe_apply
from repro.models.xlstm import (
    mlstm_apply,
    mlstm_decode,
    slstm_apply,
    slstm_decode,
)
from repro.sharding.ctx import ShardCtx


def _qkv(params, h, cfg: ModelConfig, prefix=""):
    dt = h.dtype
    B, Sq, _ = h.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (h @ params[prefix + "q"].astype(dt)).reshape(B, Sq, H, hd)
    k = (h @ params[prefix + "k"].astype(dt)).reshape(B, Sq, Kv, hd)
    v = (h @ params[prefix + "v"].astype(dt)).reshape(B, Sq, Kv, hd)
    return q, k, v


def _maybe_qk_norm(params, q, k, cfg: ModelConfig):
    if cfg.qk_norm and "qn" in params:
        q = rms_norm(q, params["qn"], cfg.norm_eps)
        k = rms_norm(k, params["kn"], cfg.norm_eps)
    return q, k


def self_attention_parallel(
    params, x, cfg: ModelConfig, ctx: ShardCtx, *, positions, window, causal=True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (attn output, kv dict for cache construction)."""
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    q, k, v = _qkv(params, h, cfg, prefix="w")
    q, k = _maybe_qk_norm(params, q, k, cfg)
    cos, sin = rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if ctx.enabled:
        from jax.sharding import PartitionSpec as P

        dp = ctx.axis("dp")
        q = ctx.constrain_raw(q, P(dp, None, ctx.heads_axis(cfg.n_heads), None))
        kv_ax = ctx.heads_axis(cfg.n_kv_heads)
        k = ctx.constrain_raw(k, P(dp, None, kv_ax, None))
        v = ctx.constrain_raw(v, P(dp, None, kv_ax, None))
    o = attention(
        q, k, v, causal=causal, window=window,
        impl=ctx.attention_impl, block_q=ctx.block_q, block_k=ctx.block_k,
    )
    o = o.reshape(*o.shape[:2], cfg.n_heads * cfg.hd)
    out = o @ params["wo"].astype(x.dtype)
    return out, {"k": k, "v": v}


def cross_attention_parallel(params, x, memory, cfg, ctx, *, prefix="x",
                             gate: Optional[jax.Array] = None):
    """memory: (B, M, D) encoder/vision states; returns (out, kv)."""
    dt = x.dtype
    B, M, _ = memory.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, params["lnx"], cfg.norm_eps)
    q = (h @ params[prefix + "q"].astype(dt)).reshape(*h.shape[:2], H, hd)
    k = (memory @ params[prefix + "k"].astype(dt)).reshape(B, M, Kv, hd)
    v = (memory @ params[prefix + "v"].astype(dt)).reshape(B, M, Kv, hd)
    o = attention(q, k, v, causal=False, window=0, impl=ctx.attention_impl,
                  block_q=ctx.block_q, block_k=ctx.block_k)
    o = o.reshape(*o.shape[:2], H * hd)
    out = o @ params[prefix + "o"].astype(dt)
    if gate is not None:
        out = out * jnp.tanh(gate.astype(dt))
    return out, {"k": k, "v": v}


def ffn_parallel(params, x, cfg: ModelConfig, ctx: ShardCtx, is_moe: bool):
    if "ln2" not in params:
        return x, jnp.float32(0.0)
    if is_moe:
        y, aux = moe_apply(params, x, cfg, ctx)
    else:
        y = mlp_apply(params, x, gated=S.mlp_gated(cfg), eps=cfg.norm_eps)
        aux = jnp.float32(0.0)
    x = ctx.constrain(x + y, "dp", "sp", None)
    return x, aux


def block_parallel(
    params: Dict[str, Any],
    x: jax.Array,
    kind: str,
    is_moe: bool,
    cfg: ModelConfig,
    ctx: ShardCtx,
    *,
    positions: jax.Array,
    memory: Optional[jax.Array] = None,     # vision / encoder states
    xa_params: Optional[Dict[str, Any]] = None,  # enc-dec cross-attn params
    causal: bool = True,
    return_kv: bool = False,
):
    """One transformer layer. Returns (x, aux, kv_or_None)."""
    kv = None
    if kind in (ATTN, ATTN_LOCAL):
        window = cfg.swa_window if (kind == ATTN_LOCAL or
                                    (cfg.block_pattern is None and cfg.swa_window)) else 0
        o, kv = self_attention_parallel(
            params, x, cfg, ctx, positions=positions, window=window, causal=causal
        )
        x = ctx.constrain(x + o, "dp", "sp", None)
    elif kind == CROSS:
        o, kv_self = self_attention_parallel(
            params, x, cfg, ctx, positions=positions, window=0, causal=causal
        )
        x = ctx.constrain(x + o, "dp", "sp", None)
        xo, kv_x = cross_attention_parallel(
            params, x, memory, cfg, ctx, gate=params.get("xgate")
        )
        x = ctx.constrain(x + xo, "dp", "sp", None)
        kv = {**kv_self, "xk": kv_x["k"], "xv": kv_x["v"]}
    elif kind == MAMBA:
        if return_kv:
            o, kv = mamba_apply(params, x, cfg, ctx, impl="xla", return_state=True)
        else:
            o = mamba_apply(params, x, cfg, ctx, impl="xla")
        x = ctx.constrain(x + o, "dp", "sp", None)
    elif kind == MLSTM:
        if return_kv:
            o, kv = mlstm_apply(params, x, cfg, ctx, return_state=True)
        else:
            o = mlstm_apply(params, x, cfg, ctx)
        x = ctx.constrain(x + o, "dp", "sp", None)
    elif kind == SLSTM:
        if return_kv:
            o, kv = slstm_apply(params, x, cfg, ctx, return_state=True)
        else:
            o = slstm_apply(params, x, cfg, ctx)
        x = ctx.constrain(x + o, "dp", "sp", None)
    else:
        raise ValueError(kind)

    # encoder-decoder cross attention (whisper decoder): every layer
    if xa_params is not None and memory is not None and kind != CROSS:
        xo, kvx = cross_attention_parallel(xa_params, x, memory, cfg, ctx)
        x = ctx.constrain(x + xo, "dp", "sp", None)
        if kv is None:
            kv = {}
        kv = {**(kv or {}), "xk": kvx["k"], "xv": kvx["v"]}

    x, aux = ffn_parallel(params, x, cfg, ctx, is_moe)
    return x, aux, (kv if return_kv else None)


# ---------------------------------------------------------------------------
# decode path
def attn_decode(
    params, x, cache, cache_len, cfg: ModelConfig, ctx: ShardCtx, *, window: int
):
    """x: (B,1,D). cache: {'k','v'} (B, S_c, Kv, hd) + implicit ring for SWA."""
    B = x.shape[0]
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    q, k, v = _qkv(params, h, cfg, prefix="w")
    q, k = _maybe_qk_norm(params, q, k, cfg)
    pos = jnp.atleast_1d(cache_len)                     # (1,)
    cos, sin = rope_cos_sin(pos, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    s_cache = cache["k"].shape[1]
    slot = cache_len % s_cache if window else jnp.minimum(cache_len, s_cache - 1)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                           (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                           (0, slot, 0, 0))
    kv_spec = ctx.kv_cache_pspec()
    k_cache = ctx.constrain_raw(k_cache, kv_spec)
    v_cache = ctx.constrain_raw(v_cache, kv_spec)
    valid = jnp.minimum(cache_len + 1, s_cache) * jnp.ones((B,), jnp.int32)
    o = decode_attention(q, k_cache, v_cache, valid)
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd)
    out = o @ params["wo"].astype(x.dtype)
    return out, {"k": k_cache, "v": v_cache}


def cross_decode(params, x, cache, cfg, ctx, *, prefix="x", gate=None):
    B = x.shape[0]
    h = rms_norm(x, params["lnx"], cfg.norm_eps)
    q = (h @ params[prefix + "q"].astype(x.dtype)).reshape(B, 1, cfg.n_heads, cfg.hd)
    m = cache[prefix + "k"].shape[1]
    o = decode_attention(
        q, cache[prefix + "k"], cache[prefix + "v"],
        m * jnp.ones((B,), jnp.int32),
    )
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd)
    out = o @ params[prefix + "o"].astype(x.dtype)
    if gate is not None:
        out = out * jnp.tanh(gate.astype(x.dtype))
    return out


def block_decode(
    params: Dict[str, Any],
    x: jax.Array,                 # (B,1,D)
    cache: Dict[str, Any],
    cache_len: jax.Array,
    kind: str,
    is_moe: bool,
    cfg: ModelConfig,
    ctx: ShardCtx,
    *,
    xa_params: Optional[Dict[str, Any]] = None,
):
    new_cache = dict(cache)
    if kind in (ATTN, ATTN_LOCAL):
        window = cfg.swa_window if (kind == ATTN_LOCAL or
                                    (cfg.block_pattern is None and cfg.swa_window)) else 0
        o, kv = attn_decode(params, x, cache, cache_len, cfg, ctx, window=window)
        new_cache.update(kv)
        x = x + o
    elif kind == CROSS:
        o, kv = attn_decode(params, x, cache, cache_len, cfg, ctx, window=0)
        new_cache.update(kv)
        x = x + o
        x = x + cross_decode(params, x, cache, cfg, ctx, gate=params.get("xgate"))
    elif kind == MAMBA:
        o, mc = mamba_decode(params, x, cache, cfg, ctx)
        new_cache.update(mc)
        x = x + o
    elif kind == MLSTM:
        o, mc = mlstm_decode(params, x, cache, cfg, ctx)
        new_cache.update(mc)
        x = x + o
    elif kind == SLSTM:
        o, mc = slstm_decode(params, x, cache, cfg, ctx)
        new_cache.update(mc)
        x = x + o
    else:
        raise ValueError(kind)

    if xa_params is not None and kind != CROSS:
        x = x + cross_decode(xa_params, x, cache, cfg, ctx)

    x, _ = ffn_parallel(params, x, cfg, ctx, is_moe)
    return x, new_cache
