"""Random parameter initialization, materializing ``spec.model_param_specs``.

Init rules (name-pattern driven, fan-in scaled normal unless noted):
- norms (ln*, *_norm, qn, kn, ln_inner, final_ln): ones
- biases (*_b, b): zeros; dt_b: mamba softplus-inverse-uniform
- A_log: log of 1..d_state broadcast (S4D-real init); D_skip: ones
- xgate: zeros (cross-attn starts disabled, llama-vision style)
- everything else: truncated-normal(std = 1/sqrt(fan_in))
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import spec as S
from repro.utils.tree import tree_map_with_path_names


def _init_leaf(key, name: str, sds: jax.ShapeDtypeStruct):
    base = name.rsplit("/", 1)[-1]
    shape, dtype = sds.shape, sds.dtype
    if base in ("ln1", "ln2", "lnx", "ln_inner", "final_ln", "qn", "kn", "D_skip"):
        return jnp.ones(shape, dtype)
    if base in ("conv_b", "b", "xgate"):
        return jnp.zeros(shape, dtype)
    if base == "dt_b":
        # inverse-softplus of dt in [1e-3, 1e-1] (mamba reference init)
        u = jax.random.uniform(key, shape, jnp.float32, 1e-3, 1e-1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(dtype)
    if base == "A_log":
        ds = shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), shape)
        return jnp.log(a).astype(dtype)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def init_params(cfg: ModelConfig, rng: jax.Array) -> Dict[str, Any]:
    specs = S.model_param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs)
    keys = jax.random.split(rng, len(leaves))
    # walk with names; pair spec leaf with its key by flatten order
    names = []
    tree_map_with_path_names(lambda n, l: names.append(n) or l, specs)
    out_leaves = [
        _init_leaf(k, n, s) for k, n, s in zip(keys, names, leaves)
    ]
    return jax.tree.unflatten(treedef, out_leaves)
