"""Crash-chaos harness: SIGKILL long runs at random points and prove
resume is bit-identical.

The durable-twin contract (docs/robustness.md) is that a replay or PPO
run killed at ANY instant — including mid-checkpoint-write — resumes
from the latest complete snapshot and finishes with the SAME bits as a
run that was never interrupted. This module is the executable form of
that claim:

- ``chaos_run`` launches a worker subprocess, SIGKILLs it after a
  randomized delay, relaunches with resume enabled, and repeats until a
  launch survives to completion. Delays are drawn from a seeded RNG so
  failures replay exactly.
- Worker roles (``python -m repro.utils.chaos replay|ppo``) run a
  snapshotted replay episode / checkpointed PPO training and write
  their final stats as JSON — full ``repr`` floats plus tree digests,
  so comparison is bitwise, not approximate.
- ``python -m repro.utils.chaos smoke`` is the self-contained CI entry:
  reference run (uninterrupted) -> chaos-killed run -> assert equal.

Set ``REPRO_CHAOS_SLOW_SAVE=<seconds>`` to stretch the window between a
checkpoint's tmp-dir write and its atomic rename; with kills landing in
that window the harness also proves torn writes are invisible
(``ckpt.latest_step`` sweeps stale ``*.tmp`` dirs, resume sees only
complete snapshots).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import random
import signal
import subprocess
import sys
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence


@dataclass
class ChaosResult:
    """Outcome of one ``chaos_run`` kill-loop."""

    n_kills: int
    attempts: List[Dict[str, object]] = field(default_factory=list)
    stats_path: Optional[str] = None

    def stats(self) -> Dict[str, object]:
        with open(self.stats_path) as f:
            return json.load(f)


def chaos_run(
    cmd: Sequence[str],
    *,
    kills: int = 3,
    min_delay_s: float = 0.5,
    max_delay_s: float = 6.0,
    seed: int = 0,
    env: Optional[Dict[str, str]] = None,
    timeout_s: float = 600.0,
    stats_path: Optional[str] = None,
) -> ChaosResult:
    """Run ``cmd`` under the kill-loop.

    The first ``kills`` launches are SIGKILLed after a seeded-random
    delay in ``[min_delay_s, max_delay_s]`` (a launch that finishes
    before its kill timer simply counts as done early); after the kill
    budget is spent the final launch runs to completion.  ``cmd`` must
    be idempotent-with-resume: each relaunch picks up from whatever
    snapshots the previous one left behind.  Raises ``RuntimeError`` if
    the surviving launch exits non-zero or overruns ``timeout_s``.
    """
    rng = random.Random(seed)
    run_env = dict(os.environ)
    if env:
        run_env.update(env)
    result = ChaosResult(n_kills=0, stats_path=stats_path)
    attempt = 0
    while True:
        is_final = result.n_kills >= kills
        delay = None if is_final else rng.uniform(min_delay_s, max_delay_s)
        t0 = time.monotonic()
        proc = subprocess.Popen(
            list(cmd), env=run_env,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
        try:
            out, _ = proc.communicate(
                timeout=delay if delay is not None else timeout_s)
            rc = proc.returncode
            killed = False
        except subprocess.TimeoutExpired:
            if is_final:
                proc.kill()
                proc.communicate()
                raise RuntimeError(
                    f"chaos worker overran {timeout_s}s on the final "
                    f"(uninterrupted) launch: {' '.join(cmd)}")
            proc.send_signal(signal.SIGKILL)
            out, _ = proc.communicate()
            rc = proc.returncode
            killed = True
            result.n_kills += 1
        result.attempts.append({
            "attempt": attempt, "killed": killed, "returncode": rc,
            "delay_s": delay, "wall_s": round(time.monotonic() - t0, 3)})
        attempt += 1
        if not killed:
            if rc != 0:
                tail = out.decode(errors="replace")[-2000:]
                raise RuntimeError(
                    f"chaos worker exited {rc}:\n{tail}")
            return result


def tree_digest_hex(tree) -> str:
    """Order-stable sha256 over every leaf's name, dtype, shape and raw
    bytes (typed PRNG keys via their key data) — the bit-identity token
    the workers write into their stats JSON."""
    import jax
    import numpy as np

    from repro.utils.tree import tree_map_with_path_names

    h = hashlib.sha256()

    def leaf(name, x):
        if x is None:
            h.update(f"{name}:none".encode())
            return x
        if jax.dtypes.issubdtype(
                getattr(x, "dtype", None) or np.float32,
                jax.dtypes.prng_key):
            x = jax.random.key_data(x)
        a = np.asarray(jax.device_get(x))
        h.update(f"{name}:{a.dtype}:{a.shape}".encode())
        h.update(a.tobytes())
        return x

    tree_map_with_path_names(leaf, tree)
    return h.hexdigest()


# ---------------------------------------------------------------------------
# worker roles (subprocess entry points)
# ---------------------------------------------------------------------------


def _replay_worker(args) -> None:
    import jax

    from repro.configs.sim import tiny_cluster
    from repro.core import (build_statics, init_state, load_jobs,
                            run_episode, summary)
    from repro.data import synth_workload

    cfg = tiny_cluster(node_mtbf_hours=0.3, serving_enabled=True,
                       serving_nodes=4)
    jobs, bank = synth_workload(cfg, 32, 1200.0, seed=args.seed)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(args.seed)),
                      jobs)
    snap = None if args.snapshot_every_s <= 0 else args.snapshot_every_s
    fs, telem = run_episode(
        cfg, statics, state, args.n_steps, "fcfs", macro=True,
        snapshot_every_s=snap,
        resume_from=args.dir if args.dir else None,
        snapshot_dir=args.dir if args.dir else None,
        snapshot_keep=args.keep)
    stats = {
        "role": "replay",
        "state_digest": tree_digest_hex(fs),
        "telem_digest": tree_digest_hex(telem),
        "summary": {k: repr(float(v)) for k, v in summary(fs).items()},
    }
    with open(args.out, "w") as f:
        json.dump(stats, f, indent=1)


def _ppo_worker(args) -> None:
    from repro.configs.sim import tiny_cluster
    from repro.data import synth_workload
    from repro.envs import SchedEnv
    from repro.rl import PPOConfig, ppo_train

    cfg = tiny_cluster(sched_max_candidates=4)
    wls = [synth_workload(cfg, 24, 900.0, seed=s) for s in range(2)]
    env = SchedEnv(cfg, wls, episode_steps=8, sim_steps_per_action=5)
    pcfg = PPOConfig(n_envs=4, rollout_len=8, n_epochs=2, n_minibatches=2)
    params, hist = ppo_train(
        env, cfg=pcfg, n_iterations=args.iters, seed=args.seed,
        checkpoint_dir=args.dir, checkpoint_every=args.ckpt_every,
        resume=bool(args.dir))
    stats = {
        "role": "ppo",
        "params_digest": tree_digest_hex(params),
        "history_tail": {k: repr(v) for k, v in (hist[-1] if hist else
                                                 {}).items()},
    }
    with open(args.out, "w") as f:
        json.dump(stats, f, indent=1)


def _worker_cmd(role: str, workdir: str, out: str, *,
                seed: int = 0, n_steps: int = 400,
                snapshot_every_s: float = 60.0, iters: int = 6,
                ckpt_every: int = 2) -> List[str]:
    cmd = [sys.executable, "-m", "repro.utils.chaos", role,
           "--dir", workdir, "--out", out, "--seed", str(seed)]
    if role == "replay":
        cmd += ["--n-steps", str(n_steps),
                "--snapshot-every-s", str(snapshot_every_s)]
    else:
        cmd += ["--iters", str(iters), "--ckpt-every", str(ckpt_every)]
    return cmd


def chaos_smoke(role: str, tmpdir: str, *, kills: int = 2, seed: int = 0,
                slow_save_s: float = 0.0, **worker_kw) -> Dict[str, object]:
    """Reference (uninterrupted) run vs chaos-killed run; raises
    ``AssertionError`` on any stats mismatch. Returns the chaos stats."""
    ref_dir = os.path.join(tmpdir, f"{role}_ref")
    ref_out = os.path.join(tmpdir, f"{role}_ref.json")
    chaos_dir = os.path.join(tmpdir, f"{role}_chaos")
    chaos_out = os.path.join(tmpdir, f"{role}_chaos.json")

    ref = subprocess.run(
        _worker_cmd(role, ref_dir, ref_out, seed=seed, **worker_kw),
        capture_output=True)
    if ref.returncode != 0:
        raise RuntimeError("reference run failed:\n"
                           + ref.stdout.decode(errors="replace")[-2000:]
                           + ref.stderr.decode(errors="replace")[-2000:])
    env = ({"REPRO_CHAOS_SLOW_SAVE": str(slow_save_s)}
           if slow_save_s > 0 else None)
    res = chaos_run(
        _worker_cmd(role, chaos_dir, chaos_out, seed=seed, **worker_kw),
        kills=kills, seed=seed, env=env, stats_path=chaos_out)
    with open(ref_out) as f:
        want = json.load(f)
    got = res.stats()
    if want != got:
        diff = {k: (want.get(k), got.get(k))
                for k in set(want) | set(got) if want.get(k) != got.get(k)}
        raise AssertionError(
            f"chaos {role}: killed+resumed stats differ from "
            f"uninterrupted run after {res.n_kills} kill(s): {diff}")
    return {"role": role, "n_kills": res.n_kills,
            "attempts": res.attempts}


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.utils.chaos",
                                 description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="role", required=True)

    rp = sub.add_parser("replay", help="snapshotted replay worker")
    rp.add_argument("--dir", required=True)
    rp.add_argument("--out", required=True)
    rp.add_argument("--seed", type=int, default=0)
    rp.add_argument("--n-steps", type=int, default=400)
    rp.add_argument("--snapshot-every-s", type=float, default=60.0)
    rp.add_argument("--keep", type=int, default=3)

    pp = sub.add_parser("ppo", help="checkpointed PPO worker")
    pp.add_argument("--dir", required=True)
    pp.add_argument("--out", required=True)
    pp.add_argument("--seed", type=int, default=0)
    pp.add_argument("--iters", type=int, default=6)
    pp.add_argument("--ckpt-every", type=int, default=2)

    sm = sub.add_parser("smoke", help="CI kill-loop: replay + ppo")
    sm.add_argument("--tmpdir", default=None)
    sm.add_argument("--kills", type=int, default=2)
    sm.add_argument("--seed", type=int, default=0)
    sm.add_argument("--slow-save-s", type=float, default=0.0)
    sm.add_argument("--roles", default="replay,ppo")

    args = ap.parse_args(argv)
    if args.role == "replay":
        _replay_worker(args)
    elif args.role == "ppo":
        _ppo_worker(args)
    else:
        import tempfile

        tmpdir = args.tmpdir or tempfile.mkdtemp(prefix="repro_chaos_")
        for role in args.roles.split(","):
            out = chaos_smoke(role.strip(), tmpdir, kills=args.kills,
                              seed=args.seed, slow_save_s=args.slow_save_s)
            print(f"[chaos] {role}: OK after {out['n_kills']} kill(s); "
                  f"attempts={len(out['attempts'])}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
