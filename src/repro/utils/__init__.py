from repro.utils.errors import (
    CheckpointError,
    ConfigError,
    ReproError,
    SignalValidationError,
    TraceValidationError,
)
from repro.utils.registry import Registry
from repro.utils.tree import tree_bytes, tree_count, tree_map_with_path_names
