"""Parse collective-communication bytes out of lowered/compiled HLO text.

``compiled.cost_analysis()`` does not report collective traffic, so the
roofline's collective term is derived here: we scan the (stable)HLO /
HLO text for ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` ops and sum their operand bytes.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

# dtype name -> bytes per element, for both HLO and stableHLO spellings.
_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "i8": 1, "ui8": 1,
    "s16": 2, "u16": 2, "i16": 2, "ui16": 2,
    "s32": 4, "u32": 4, "i32": 4, "ui32": 4,
    "s64": 8, "u64": 8, "i64": 8, "ui64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

# HLO: bf16[8,128,4096]{2,1,0}   stableHLO: tensor<8x128x4096xbf16>
_HLO_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
)
# stableHLO spellings
_STABLEHLO_OPS = {
    "stablehlo.all_gather": "all-gather",
    "stablehlo.all_reduce": "all-reduce",
    "stablehlo.reduce_scatter": "reduce-scatter",
    "stablehlo.all_to_all": "all-to-all",
    "stablehlo.collective_permute": "collective-permute",
    "stablehlo.collective_broadcast": "collective-broadcast",
}
_TENSOR_RE = re.compile(r"tensor<([0-9x]*)x?(\w+)>")


@dataclass
class CollectiveStats:
    """Bytes moved per collective kind, summed over all ops in the module."""

    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return int(sum(self.bytes_by_kind.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.count_by_kind.values()))

    def add(self, kind: str, nbytes: int) -> None:
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0) + int(nbytes)
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + 1

    def summary(self) -> str:
        parts = [
            f"{k}: n={self.count_by_kind[k]} bytes={self.bytes_by_kind[k]:,}"
            for k in sorted(self.bytes_by_kind)
        ]
        return "; ".join(parts) if parts else "none"


def _hlo_line_bytes(line: str) -> int:
    """Sum the bytes of the *result* shape(s) on an HLO op line.

    For collectives, result size == operand size (all-gather result is the
    gathered size; we count the line's first (result) shape which is the
    amount of data materialized by the op on each participant).
    """
    total = 0
    # Result shape(s) are on the LHS before '=' when present; fall back to
    # first shape on the line.
    lhs = line.split("=", 1)[0] if "=" in line else line
    matches = _HLO_SHAPE_RE.findall(lhs) or _HLO_SHAPE_RE.findall(line)
    for dtype, dims in matches:
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Extract collective traffic from HLO or stableHLO module text."""
    stats = CollectiveStats()
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not line or line.startswith(("//", "#")):
            continue
        # HLO form:  %x = bf16[...] all-gather(...)
        matched_kind = None
        for kind in _COLLECTIVE_OPS:
            # Avoid matching 'all-reduce-scatter' fragments: exact op token.
            if re.search(rf"(?<![\w-]){re.escape(kind)}(?:-start|-done)?\(", line):
                matched_kind = kind
                break
        if matched_kind is not None:
            if f"{matched_kind}-done(" in line:
                continue  # counted at -start
            stats.add(matched_kind, _hlo_line_bytes(line))
            continue
        # stableHLO form: %x = "stablehlo.all_gather"(...) ... -> tensor<..>
        for op, kind in _STABLEHLO_OPS.items():
            if op in line:
                total = 0
                for dims, dtype in _TENSOR_RE.findall(line.split("->")[-1]):
                    if dtype not in _DTYPE_BYTES:
                        continue
                    n = 1
                    if dims:
                        for d in dims.split("x"):
                            if d:
                                n *= int(d)
                    total += n * _DTYPE_BYTES[dtype]
                stats.add(kind, total)
                break
    return stats
