"""Typed error taxonomy for the simulator's operational layer.

Every loud failure in the ingestion / checkpoint / entry-point surface
raises one of these instead of a bare ``ValueError``/``KeyError``, so
callers (chaos harness, launch scripts, CI gates) can discriminate
*what* went wrong without string-matching messages:

    ReproError                      root of the taxonomy
    ├── ConfigError                 bad arguments to sim/fleet/env/rl
    │                               entry points (user-facing API misuse)
    ├── TraceValidationError        corrupt SuperCloud trace / jobs dict
    ├── SignalValidationError       corrupt grid-signal CSV feed
    └── CheckpointError             missing/corrupt/mismatched checkpoint

Each concrete class ALSO inherits ``ValueError`` so the long tail of
existing ``pytest.raises(ValueError)`` pins and user ``except
ValueError`` handlers keep working — the taxonomy is additive, never a
behavioural break.

Validation errors carry the machine-readable report that produced them
(``err.report``, an ``IngestionReport`` from :mod:`repro.data.validate`)
so strict-mode failures are as inspectable as repair-mode returns.
"""

from __future__ import annotations


class ReproError(Exception):
    """Root of the repro error taxonomy."""


class ConfigError(ReproError, ValueError):
    """Invalid arguments to a sim/fleet/env/rl entry point."""


class _ValidationError(ReproError, ValueError):
    """Shared base for ingestion errors; carries the offending report."""

    def __init__(self, message: str, *, report=None):
        super().__init__(message)
        self.report = report


class TraceValidationError(_ValidationError):
    """A SuperCloud trace CSV or jobs dict failed structural validation."""


class SignalValidationError(_ValidationError):
    """A grid-signal CSV feed failed structural validation."""


class CheckpointError(ReproError, ValueError):
    """A checkpoint is missing, corrupt, or belongs to a different run.

    ``field`` names the manifest entry (or filesystem artifact) that
    failed, so resume tooling can report *which* part of the fingerprint
    diverged rather than a generic "checkpoint bad".
    """

    def __init__(self, message: str, *, field: str | None = None):
        super().__init__(message)
        self.field = field


__all__ = [
    "ReproError",
    "ConfigError",
    "TraceValidationError",
    "SignalValidationError",
    "CheckpointError",
]
