"""checkify-based machine-invariant harness (docs/resilience.md).

``check_state(cfg, statics, state)`` asserts the conservation laws and
sanity bounds every subsystem of the twin must preserve — resource
conservation, placement/jstate consistency, finite power/thermal
carries, bounded rack temperatures, non-negative accounting. The checks
are ``jax.experimental.checkify.check`` calls, so they work in two
modes:

- **eager** (un-jitted arrays): each check raises ``JaxRuntimeError``
  immediately on violation — how ``core.fleet.run_fleet`` audits final
  states after the compiled sweep;
- **functionalized** (inside jit/scan/while_loop/vmap): the caller wraps
  the whole computation with ``checkify.checkify`` and throws the
  returned error afterwards — how ``core.sim.run_episode`` runs the
  suite on every committed step without breaking compilation.

The harness is gated by the ``REPRO_CHECKIFY`` environment variable
(read at call time, so a test can flip it): unset/``0`` means zero
checks compiled in — the production program is untouched. CI hard-
enables it for the whole test matrix (``.github/workflows/ci.yml``), so
every PR executes the invariant suite across all tier-1 episodes.
"""

from __future__ import annotations

import os

import jax.numpy as jnp

from repro.configs.sim import SimConfig
from repro.core.state import FAILED, RUNNING, SimState, Statics

# float slop for resource conservation: free pools are add/subtract
# chains of f32 req vectors, so allow a few ulps of drift per resource
_EPS = 1e-3


def enabled() -> bool:
    """Whether the invariant harness is on (``REPRO_CHECKIFY``); read at
    call time so tests can enable/disable it per case."""
    return os.environ.get("REPRO_CHECKIFY", "0") not in ("", "0")


def check_state(cfg: SimConfig, statics: Statics, state: SimState) -> None:
    """Assert the machine invariants of one (possibly batched) SimState.

    Every check broadcasts over leading batch axes, so the same suite
    audits a single episode state and a fleet's stacked final states.
    Must run either eagerly or under ``checkify.checkify`` — a bare jit
    of a function calling this raises at trace time by design (checks
    would otherwise be silently dropped).
    """
    from jax.experimental import checkify

    # --- resource conservation: the free pool never exceeds capacity
    # (releases are balanced by allocations) and never goes negative
    # (allocations never oversubscribe)
    checkify.check(
        jnp.all(state.free <= statics.capacity + _EPS),
        "resource conservation violated: free pool exceeds capacity "
        "(double release)")
    checkify.check(
        jnp.all(state.free >= -_EPS),
        "resource conservation violated: negative free pool "
        "(oversubscription)")

    # --- placement/jstate consistency: exactly the RUNNING jobs hold
    # placement rows; queued/done/failed/empty slots are scrubbed to -1
    has_nodes = jnp.any(state.placement >= 0, axis=-1)
    checkify.check(
        jnp.all(has_nodes == (state.jstate == RUNNING)),
        "placement/jstate inconsistency: a non-RUNNING job holds nodes "
        "or a RUNNING job holds none")
    checkify.check(
        jnp.all((state.jstate >= 0) & (state.jstate <= FAILED)),
        "jstate outside the EMPTY..FAILED lifecycle")

    # --- node liveness is boolean; down nodes carry a repair time
    # NB: check messages are .format() templates — no literal braces
    checkify.check(
        jnp.all((state.node_up == 0.0) | (state.node_up == 1.0)),
        "node_up not boolean-valued (0.0 or 1.0)")

    # --- no NaN/Inf in the power/energy accumulators or progress state
    finite_acc = (
        jnp.isfinite(state.energy_kwh) & jnp.isfinite(state.it_energy_kwh)
        & jnp.isfinite(state.cool_energy_kwh) & jnp.isfinite(state.carbon_kg)
        & jnp.isfinite(state.elec_cost_usd) & jnp.isfinite(state.sum_power_w)
        & jnp.isfinite(state.lost_node_s)
    )
    checkify.check(jnp.all(finite_acc),
                   "NaN/Inf in power/energy/lost-work accumulators")
    checkify.check(jnp.all(jnp.isfinite(state.work_left)),
                   "NaN/Inf in per-job work_left")

    # --- thermal carry: rack outlet temps finite and physically bounded
    # (a runaway RC update or bad supply signal shows up here first)
    checkify.check(
        jnp.all(jnp.isfinite(state.rack_outlet_c))
        & jnp.all(state.rack_outlet_c < 250.0)
        & jnp.all(state.rack_outlet_c > -60.0),
        "rack outlet temperature NaN/Inf or outside (-60, 250) degC")

    # --- resilience accounting is monotone non-negative
    checkify.check(
        jnp.all(state.lost_node_s >= 0.0) & jnp.all(state.n_failed >= 0.0)
        & jnp.all(state.n_killed >= 0.0),
        "negative resilience accounting (lost_node_s/n_failed/n_killed)")
    checkify.check(
        jnp.all(state.n_failures >= 0),
        "negative per-job failure count")

    # --- serving twin: queue depths, SLO accumulators, and the request
    # conservation ledger (only compiled in when the twin is on — the
    # fields exist regardless, but are frozen zeros otherwise)
    if cfg.serving_on:
        checkify.check(
            jnp.all(state.srv_queue >= -_EPS)
            & jnp.all(state.srv_retry_q >= -_EPS)
            & jnp.all(state.srv_inflight >= -_EPS),
            "negative serving queue depth (srv_queue/srv_retry_q/"
            "srv_inflight)")
        acc = (state.srv_arrived, state.srv_completed, state.srv_shed,
               state.srv_dropped, state.srv_retried, state.srv_slo_viol,
               state.srv_lat_sum)
        fin = jnp.bool_(True)
        nonneg = jnp.bool_(True)
        for a in acc:
            fin = fin & jnp.all(jnp.isfinite(a))
            nonneg = nonneg & jnp.all(a >= 0.0)
        checkify.check(fin, "NaN/Inf in serving SLO accumulators")
        checkify.check(nonneg, "negative serving SLO accumulator")
        # conservation: every arrived request is in a queue, a retry
        # bucket, in flight, completed, shed, or terminally dropped
        tol = 1e-3 * state.srv_arrived + 1e-2
        held = (jnp.sum(state.srv_queue, axis=-1)
                + jnp.sum(state.srv_retry_q, axis=-1)
                + state.srv_inflight)
        checkify.check(
            jnp.all(state.srv_inflight + state.srv_completed
                    <= state.srv_arrived + tol),
            "serving in-flight + completed exceeds arrivals "
            "(admission leak)")
        checkify.check(
            jnp.all(jnp.abs(
                state.srv_arrived
                - (held + state.srv_completed + state.srv_shed
                   + state.srv_dropped)) <= tol),
            "serving request conservation violated: arrived != held + "
            "completed + shed + dropped")
        # retries are bounded by the per-request budget
        checkify.check(
            jnp.all(state.srv_retried
                    <= cfg.serving_max_retries * state.srv_arrived + tol),
            "serving retries exceed the per-request retry budget")
