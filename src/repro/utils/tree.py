"""Pytree helpers shared across the stack."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of elements in a pytree of arrays."""
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree) if hasattr(x, "shape")))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays (uses dtype itemsize)."""
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return int(total)


def tree_map_with_path_names(fn, tree):
    """tree_map where fn receives ('/'-joined key path, leaf)."""

    def _name(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(lambda path, leaf: fn(_name(path), leaf), tree)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def all_finite(tree) -> jax.Array:
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)]
    return jnp.all(jnp.stack(leaves))
