from repro.optim.adamw import AdamW
from repro.optim.adafactor import Adafactor
from repro.optim.schedules import constant, cosine_warmup
from repro.optim.base import Optimizer, clip_by_global_norm


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return AdamW(**kw)
    if name == "adafactor":
        return Adafactor(**kw)
    raise KeyError(f"unknown optimizer {name}")


def default_optimizer_for(n_params: int) -> str:
    """Adafactor for >=100B-param models: fp32 Adam moments would not fit
    256 x 16 GB HBM (see DESIGN.md); AdamW otherwise."""
    return "adafactor" if n_params >= 100e9 else "adamw"
