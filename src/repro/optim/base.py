"""Minimal functional optimizer interface (optax is not available offline;
the substrate is built here per the reproduction scope)."""

from __future__ import annotations

from typing import Any, Callable, Protocol

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


class Optimizer(Protocol):
    def init(self, params) -> Any: ...

    def update(self, grads, state, params, step) -> tuple:  # (new_params, new_state)
        ...

    def state_pspecs(self, param_specs, param_pspecs) -> Any: ...


def clip_by_global_norm(grads, max_norm: float):
    from repro.utils.tree import global_norm

    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm
