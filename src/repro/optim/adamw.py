"""AdamW with decoupled weight decay; fp32 moments mirroring the params."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

from repro.optim.base import Schedule


@dataclass(frozen=True)
class AdamW:
    lr: Union[float, Schedule] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def init(self, params) -> Any:
        zeros = lambda tree: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), tree
        )
        return {"m": zeros(params), "v": zeros(params)}

    def update(self, grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / (1 - b1**t)
            vh = v / (1 - b2**t)
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:  # no decay on norms/biases
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m, v

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda x: x[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda x: x[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda x: x[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    def state_pspecs(self, param_specs, param_pspecs):
        return {"m": param_pspecs, "v": param_pspecs}
