"""Adafactor (Shazeer & Stern 2018): factored second moments, no first
moment — ~4 bytes/param of optimizer state, which is what lets the 314B /
398B MoE configs train on a 256-chip v5e pod (see DESIGN.md memory budget).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.base import Schedule


@dataclass(frozen=True)
class Adafactor:
    lr: Union[float, Schedule] = 1e-2
    decay: float = 0.8           # t^-decay second-moment decay schedule
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def init(self, params) -> Any:
        def make(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(make, params, is_leaf=None)}

    def update(self, grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-self.decay)
        lr = self._lr(step)

        def upd(g, f, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if p.ndim >= 2:
                vr = beta2 * f["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * f["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), self.eps)
                v = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                new_f = {"vr": vr, "vc": vc}
            else:
                v = beta2 * f["v"] + (1 - beta2) * g2
                new_f = {"v": v}
            u = g / jnp.sqrt(jnp.maximum(v, self.eps))
            # update clipping (RMS <= threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            delta = u
            if self.weight_decay and p.ndim >= 2:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_f)

        flat = jax.tree.map(upd, grads, state["f"], params)
        is_pair = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda x: x[0], flat, is_leaf=is_pair)
        new_f = jax.tree.map(lambda x: x[1], flat, is_leaf=is_pair)
        return new_params, {"f": new_f}

    def state_pspecs(self, param_specs, param_pspecs):
        def make(sds, spec):
            axes = list(spec) + [None] * (len(sds.shape) - len(spec))
            if len(sds.shape) >= 2:
                return {"vr": P(*axes[:-1]), "vc": P(*(axes[:-2] + axes[-1:]))}
            return {"v": P(*axes)}

        return {"f": jax.tree.map(make, param_specs, param_pspecs)}
