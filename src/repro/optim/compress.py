"""Gradient compression.

``quantize_dequantize``: per-tensor symmetric int8 quantization with
deterministic rounding — applied before the optimizer it emulates an int8
all-reduce's precision loss (tested for convergence impact in
tests/test_optim.py).

``compressed_psum``: the *real* mechanism for shard_map data parallelism
(used by the distributed PPO trainer): quantize local grads to int8,
psum the int8 payload (4x fewer bytes on the wire than fp32), dequantize
with the max of the per-shard scales, and carry the quantization error
into the next step (error feedback, Seide et al. 2014) so the bias does
not accumulate.

Usage note (shard_map VMA semantics): mark replicated params shard-varying
before taking local grads — ``jax.lax.pcast(p, axis, to="varying")`` —
otherwise shard_map's AD inserts its own psum and the reduction happens
twice (tests/test_multidevice.py shows the pattern).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def _q(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_dequantize(grads: Any) -> Any:
    def one(g):
        gf = g.astype(jnp.float32)
        q, scale = _q(gf)
        return (q.astype(jnp.float32) * scale).astype(g.dtype)

    return jax.tree.map(one, grads)


def compressed_psum(grads: Any, axis_name: str,
                    error: Optional[Any] = None) -> Tuple[Any, Any]:
    """int8 all-reduce with error feedback inside shard_map.

    Returns (mean_grads, new_error). Wire bytes: 1/4 of fp32 psum (+ one
    scalar scale per tensor).
    """
    n = jax.lax.psum(1.0, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + (e if e is not None else 0.0)
        q, scale = _q(gf)
        # a shared scale is required for int8 summation to be exact:
        # use the max scale across shards (one scalar all-reduce)
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        out = (summed.astype(jnp.float32) * scale) / n
        new_e = gf - q.astype(jnp.float32) * scale
        return out.astype(g.dtype), new_e

    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    pairs = jax.tree.map(one, grads, error)
    is_pair = lambda x: isinstance(x, tuple)
    out = jax.tree.map(lambda x: x[0], pairs, is_leaf=is_pair)
    new_error = jax.tree.map(lambda x: x[1], pairs, is_leaf=is_pair)
    return out, new_error
