"""whisper-small [audio] — encoder-decoder transformer backbone; conv audio
frontend is a STUB (input_specs() supplies precomputed 1500-frame embeddings).
[arXiv:2212.04356; unverified]

Adaptation note (DESIGN.md §Arch-applicability): the backbone uses RoPE in
place of Whisper's learned absolute positions so the assigned 32k-decoder
shapes are well-defined; parameter counts are otherwise faithful.
"""

from repro.configs.base import ARCHS, ModelConfig


@ARCHS.register("whisper-small")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        n_layers=12,                  # decoder layers
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab=51865,
        enc_dec=True,
        n_enc_layers=12,
        n_audio_frames=1500,
        tie_embeddings=True,
        source="arXiv:2212.04356; unverified",
    )
