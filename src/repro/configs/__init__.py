"""Architecture & simulator configs.

Importing this package registers every assigned architecture into
``repro.configs.base.ARCHS``; select one with ``--arch <id>``.
"""

from repro.configs.base import (
    ARCHS,
    SHAPES,
    SMOKE_DECODE_SHAPE,
    SMOKE_SHAPE,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    arch_names,
    get_arch,
    reduced,
    shape_applicable,
)

# Register all assigned architectures (one module per arch id).
from repro.configs import (  # noqa: F401  (import side effects)
    gemma3_1b,
    granite_3_8b,
    grok_1_314b,
    internlm2_20b,
    jamba_1_5_large_398b,
    llama_3_2_vision_11b,
    mixtral_8x22b,
    qwen3_4b,
    whisper_small,
    xlstm_125m,
)
from repro.configs.sim import SimConfig, NodeType, tx_gaia, tiny_cluster
