"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (ratio 3:1 mLSTM:sLSTM), d_ff=0
(projections live inside the cells). [arXiv:2405.04517; unverified]
"""

from repro.configs.base import ARCHS, MLSTM, SLSTM, ModelConfig, SSMConfig


@ARCHS.register("xlstm-125m")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        head_dim=192,
        d_ff=0,                      # per assigned config: blocks are self-contained
        vocab=50304,
        block_pattern=(MLSTM, MLSTM, MLSTM, SLSTM),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=256),
        source="arXiv:2405.04517; unverified",
    )
