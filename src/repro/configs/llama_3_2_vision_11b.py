"""llama-3.2-vision-11b [vlm] — cross-attention image layers every 5th layer;
vision frontend is a STUB (precomputed patch embeddings from input_specs()).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""

from repro.configs.base import ARCHS, ModelConfig


@ARCHS.register("llama-3.2-vision-11b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=128256,
        rope_theta=5e5,
        cross_attn_period=5,          # a cross-attn layer after every 5th layer
        n_vision_tokens=1601,         # one 560px tile -> 1600 patches + CLS
        source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    )
