"""granite-3-8b [dense] — GQA kv=8. [hf:ibm-granite/granite-3.0-2b-base; hf]"""

from repro.configs.base import ARCHS, ModelConfig


@ARCHS.register("granite-3-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab=49155,
        rope_theta=1e4,
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-2b-base; hf",
    )
