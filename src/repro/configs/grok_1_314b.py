"""grok-1-314b [moe] — 8 experts top-2, GQA kv=8. [hf:xai-org/grok-1; unverified]"""

from repro.configs.base import ARCHS, ModelConfig, MoEConfig


@ARCHS.register("grok-1-314b")
def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab=131072,
        rope_theta=1e4,
        moe=MoEConfig(n_experts=8, top_k=2, period=1),
        source="hf:xai-org/grok-1; unverified",
    )
