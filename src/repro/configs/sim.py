"""Datacenter (digital-twin) configuration.

``tx_gaia()`` models the MIT SuperCloud TX-GAIA system used by the paper:
448 GPU nodes (2x Xeon Gold 6248, 2x V100-32GB SXM2) plus Xeon-Platinum CPU
nodes, multi-tenant, with CPU telemetry at 10 s quanta and GPU telemetry at
100 ms (Samsi et al., HPEC'21).

Power-chain parameters follow RAPS: node IT power -> AC-DC rectification
efficiency curve eta(load) -> DC-DC voltage-conversion efficiency -> plus
cooling power (PUE model). All knobs are plain floats so the whole sim is
jit-able.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class NodeType:
    name: str
    count: int
    cpu_cores: int
    gpus: int
    mem_gb: float
    idle_w: float          # chassis idle (fans, board, DIMMs)
    cpu_dyn_w: float       # max additional W at 100% CPU util (whole node)
    gpu_idle_w: float      # per-GPU idle
    gpu_dyn_w: float       # per-GPU max additional W at 100% util
    peak_gflops: float     # per-node peak GFLOP/s (for GFLOPS/W stats)


@dataclass(frozen=True)
class SimConfig:
    name: str
    node_types: Tuple[NodeType, ...]
    # capacity limits for the fixed-shape job table
    max_jobs: int = 512            # max resident (queued+running) jobs
    max_nodes_per_job: int = 64
    # time discretization
    dt: float = 1.0                # simulator step [s]
    trace_quanta: float = 10.0     # telemetry averaging quantum [s]
    # power chain (RAPS-style)
    rect_eff_peak: float = 0.965   # peak rectifier efficiency
    rect_eff_load: float = 0.55    # load fraction at which peak occurs
    rect_eff_curv: float = 0.12    # curvature of the efficiency parabola
    conv_eff: float = 0.975        # DC-DC voltage conversion efficiency
    # cooling: P_cool = P_IT / COP(wetbulb); PUE emerges from the chain
    cop_base: float = 5.2
    cop_wetbulb_coef: float = -0.08   # COP drop per degC wetbulb above ref
    wetbulb_ref_c: float = 18.0
    wetbulb_mean_c: float = 16.0
    wetbulb_amp_c: float = 6.0        # diurnal amplitude
    # carbon intensity (diurnal, gCO2/kWh)
    carbon_mean: float = 380.0
    carbon_amp: float = 120.0
    day_seconds: float = 86_400.0
    # electricity price (diurnal, $/kWh; evening peak)
    price_mean_usd_kwh: float = 0.11
    price_amp_usd_kwh: float = 0.04
    # network (inter-job congestion; Lassen-style bytes in/out coupling)
    bisection_gbps: float = 2_400.0   # system bisection bandwidth
    congestion_exp: float = 1.5       # slowdown = (1 + load^exp) beyond knee
    congestion_knee: float = 0.7      # utilization where contention kicks in
    # failures (sustainability studies under faults; docs/resilience.md)
    node_mtbf_hours: float = 0.0      # 0 = node failures off
    node_repair_hours: float = 4.0
    # correlated failure domains: a rack fault (cooling loop / PDU) downs
    # every node in the rack at once. 0 = rack faults off.
    rack_mtbf_hours: float = 0.0
    rack_repair_hours: float = 2.0
    # job resilience semantics: killed jobs restart from their last
    # simulated checkpoint (0 = restart from zero work, the legacy rule);
    # each checkpoint write costs ckpt_overhead_s of runtime at full power.
    ckpt_interval_s: float = 0.0
    ckpt_overhead_s: float = 0.0
    # retry budget: a job killed more than max_job_retries times goes
    # terminal FAILED (0 = unbounded retries, the legacy rule). Requeued
    # jobs wait requeue_backoff_s * mult**(n_failures-1) before eligible.
    max_job_retries: int = 0
    requeue_backoff_s: float = 0.0
    requeue_backoff_mult: float = 2.0
    # scenario-driven grid outages / maintenance windows (Scenario.outages)
    outages_enabled: bool = False
    # graceful-degradation ladder (throttle -> gate -> drain -> evict) as
    # a schedulable action (SchedEnv) / forced by outage brownout levels
    degrade_enabled: bool = False
    degrade_throttle_frac: float = 0.7
    # demand response (DCFlex-style): cap facility power by DVFS-throttling
    # running jobs (linear power/progress model). 0 = uncapped.
    power_cap_w: float = 0.0
    throttle_floor: float = 0.3       # never clock below 30%
    # thermal twin (per-rack RC cooling loop; docs/thermal.md). Python bool
    # so thermal-off compiles the legacy static-COP chain bit-identically.
    thermal_enabled: bool = False
    nodes_per_rack: int = 32
    rack_tau_s: float = 600.0          # first-order outlet-temp lag [s]
    rack_dt_full_load_c: float = 20.0  # design outlet-supply delta at rack
    #                                    nameplate IT power (sets R_th)
    cooling_approach_c: float = 4.0    # supply-air approach over wetbulb
    cooling_supply_min_c: float = 14.0 # plant never supplies below this
    throttle_start_c: float = 55.0     # outlet temp where derating begins
    throttle_full_c: float = 75.0      # outlet temp where derating saturates
    thermal_throttle_floor: float = 0.4
    thermal_trip_c: float = 65.0       # racks above this accept no NEW jobs
    # COP(wetbulb, IT load): plants run closest to design efficiency near
    # their rated load — part-load COP drops (ISO chiller part-load curves)
    cop_load_coef: float = 1.2         # COP gain per unit IT-load fraction
    cop_load_ref: float = 0.5          # load fraction of the nominal COP
    cop_min: float = 1.5
    # online-inference serving twin (core/serving.py; docs/serving.md):
    # a pool of serving_nodes inference nodes — disjoint from the batch
    # fleet, power injected into the shared plant chain — serves a fluid
    # request mass driven by Scenario.traffic. Python-bool + pool-size
    # gate (``serving_on``) so serving-off compiles the legacy program
    # bit-identically.
    serving_enabled: bool = False
    serving_nodes: int = 0             # inference pool size (not in n_nodes)
    serving_concurrency: float = 8.0   # concurrent requests per awake node
    serving_service_s: float = 4.0     # per-request service time at clock 1.0
    serving_prefill_frac: float = 0.15  # fraction of service_s in prefill
    serving_prefill_util: float = 0.9   # accelerator util during prefill
    serving_decode_util: float = 0.45   # accelerator util during decode
    serving_node_idle_w: float = 300.0  # awake-but-idle node power
    serving_node_dyn_w: float = 700.0   # extra W at full util + occupancy
    serving_sleep_w: float = 30.0       # asleep node power (SPARS knob)
    serving_wake_s: float = 120.0       # sleep -> serving wake latency
    serving_queue_cap: float = 512.0    # hard admission-queue bound [req]
    serving_admit_thresh: float = 0.9   # initial admitted queue fraction
    serving_timeout_s: float = 30.0     # queue-reach timeout; 0 = off
    serving_slo_s: float = 10.0         # SLO latency target [s]
    serving_max_retries: int = 3        # retry budget (backoff tiers)
    serving_backoff_s: float = 4.0      # base retry backoff [s]
    serving_backoff_mult: float = 2.0
    serving_backoff_cap_s: float = 60.0
    serving_scale_step: float = 1.0     # autoscale action increment [nodes]
    # RL / scheduling
    sched_max_candidates: int = 8     # jobs visible to the RL agent per step
    backfill_reserve: int = 1         # EASY: #head jobs that get reservations
    seed: int = 0

    @property
    def n_nodes(self) -> int:
        return sum(t.count for t in self.node_types)

    @property
    def resilience_on(self) -> bool:
        """Python-bool gate for the fault engine: False compiles the
        legacy fault-free program bit-identically (no extra state reads,
        no PRNG consumption, no horizon terms)."""
        return (self.node_mtbf_hours > 0 or self.rack_mtbf_hours > 0
                or self.outages_enabled or self.degrade_enabled)

    @property
    def serving_on(self) -> bool:
        """Python-bool gate for the serving twin: False compiles the
        legacy batch-only program bit-identically (no serving state
        writes, no horizon terms, no extra obs/actions)."""
        return self.serving_enabled and self.serving_nodes > 0

    @property
    def n_types(self) -> int:
        return len(self.node_types)

    @property
    def n_racks(self) -> int:
        return -(-self.n_nodes // self.nodes_per_rack)

    @property
    def nameplate_it_w(self) -> float:
        """All-nodes-at-full-load IT power (sum of per-node node_max_w);
        the reference scale for sizing demand-response caps."""
        return sum(
            t.count * (t.idle_w + t.gpus * t.gpu_idle_w + t.cpu_dyn_w
                       + t.gpus * t.gpu_dyn_w)
            for t in self.node_types
        )


def partition_type_indices(cfg: SimConfig) -> Tuple[int, int]:
    """(first GPU-bearing type index, first CPU-only type index) — THE
    partition-tag fallback rule, shared by the workload loaders
    (``data.synth_trace`` / ``data.trace_io``). -1 = the config has no
    type of that kind, so jobs get tag -1 (any node)."""
    gpu_ti = next((i for i, t in enumerate(cfg.node_types) if t.gpus > 0),
                  -1)
    cpu_ti = next((i for i, t in enumerate(cfg.node_types) if t.gpus == 0),
                  -1)
    return gpu_ti, cpu_ti


def tx_gaia(**overrides) -> SimConfig:
    """MIT SuperCloud TX-GAIA twin (GPU partition + CPU partition)."""
    types = (
        NodeType(
            name="txg-v100",
            count=448,
            cpu_cores=40,            # 2x Xeon Gold 6248
            gpus=2,                  # 2x V100-32GB SXM2
            mem_gb=384.0,
            idle_w=240.0,
            cpu_dyn_w=260.0,         # 2x 125W TDP + DIMM activity
            gpu_idle_w=55.0,
            gpu_dyn_w=245.0,         # 300W SXM2 TDP - idle
            peak_gflops=2 * 7_800.0 + 2_300.0,  # 2x V100 fp64+tensor mix + CPUs
        ),
        NodeType(
            name="xeon-p8",
            count=224,
            cpu_cores=48,            # 2x Xeon Platinum 8260
            gpus=0,
            mem_gb=192.0,
            idle_w=160.0,
            cpu_dyn_w=330.0,
            gpu_idle_w=0.0,
            gpu_dyn_w=0.0,
            peak_gflops=3_300.0,
        ),
    )
    return SimConfig(name="tx-gaia", node_types=types, **overrides)


def tiny_cluster(**overrides) -> SimConfig:
    """Small heterogeneous cluster for tests/examples (fast to simulate)."""
    types = (
        NodeType("gpu", 8, 16, 2, 128.0, 100.0, 120.0, 30.0, 240.0, 16_000.0),
        NodeType("cpu", 8, 32, 0, 64.0, 80.0, 200.0, 0.0, 0.0, 2_000.0),
    )
    kw = dict(max_jobs=64, max_nodes_per_job=4, sched_max_candidates=4)
    kw.update(overrides)
    return SimConfig(name="tiny", node_types=types, **kw)
