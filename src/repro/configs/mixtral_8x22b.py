"""mixtral-8x22b [moe] — 8 experts top-2, GQA kv=8, SWA. [arXiv:2401.04088; hf]"""

from repro.configs.base import ARCHS, ModelConfig, MoEConfig


@ARCHS.register("mixtral-8x22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=32768,
        rope_theta=1e6,
        swa_window=4096,          # per assigned config note: SWA
        moe=MoEConfig(n_experts=8, top_k=2, period=1),
        source="arXiv:2401.04088; hf",
    )
