"""qwen3-4b [dense] — qk_norm, GQA kv=8. [hf:Qwen/Qwen3-8B; hf]"""

from repro.configs.base import ARCHS, ModelConfig


@ARCHS.register("qwen3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,            # qwen3 uses explicit head_dim=128 (> d_model/H)
        d_ff=9728,
        vocab=151936,
        rope_theta=1e6,
        qk_norm=True,
        tie_embeddings=True,
        source="hf:Qwen/Qwen3-8B; hf",
    )
