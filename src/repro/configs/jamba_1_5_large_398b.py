"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]
"""

from repro.configs.base import ARCHS, ATTN, MAMBA, ModelConfig, MoEConfig, SSMConfig


@ARCHS.register("jamba-1.5-large-398b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab=65536,
        rope_theta=1e4,
        # Jamba block: 8 layers, 1 attention : 7 mamba (attn at position 3).
        block_pattern=(MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA, MAMBA),
        moe=MoEConfig(n_experts=16, top_k=2, period=2),  # MoE every 2nd layer
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=256),
        source="arXiv:2403.19887; hf",
    )
