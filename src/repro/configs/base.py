"""Model / shape / parallelism configuration dataclasses.

Every assigned architecture file (``src/repro/configs/<id>.py``) builds a
:class:`ModelConfig`; the four assigned input shapes are :data:`SHAPES`.
``reduced()`` derives the CPU-smoke-test variant of any config (same block
structure, tiny widths).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

from repro.utils.registry import Registry

# ---------------------------------------------------------------------------
# Block kinds (per-layer). Hybrid archs interleave these.
ATTN = "attn"            # self-attention (GQA; optional sliding window)
ATTN_LOCAL = "attn_local"  # sliding-window self-attention
MAMBA = "mamba"          # selective-state-space block
SLSTM = "slstm"          # xLSTM sLSTM block
MLSTM = "mlstm"          # xLSTM mLSTM block
CROSS = "cross"          # cross-attention (VLM / enc-dec decoder)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    # every `period` layers are MoE (1 = all layers MoE); jamba uses 2.
    period: int = 1
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    capacity_factor: float = 1.25   # tokens kept per expert = cf * T*k/E


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256     # chunked-scan block size (Pallas tile)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # attention details
    rope_theta: float = 1e4
    qk_norm: bool = False
    swa_window: int = 0              # 0 = full attention
    # per-layer pattern; None -> all ATTN. Entry i gives layer i's kind
    # (cycled if shorter than n_layers).
    block_pattern: Optional[Tuple[str, ...]] = None
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # VLM: a cross-attention layer after every `cross_attn_period` layers.
    cross_attn_period: int = 0
    n_vision_tokens: int = 0         # stub frontend: precomputed patch embeds
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_audio_frames: int = 0          # stub frontend: precomputed frame embeds
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 524_288
    dtype: str = "bfloat16"
    # notes for DESIGN/EXPERIMENTS tables
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kind(self, i: int) -> str:
        if self.block_pattern is None:
            return ATTN
        return self.block_pattern[i % len(self.block_pattern)]

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.layer_kind(i) for i in range(self.n_layers))

    def is_moe_layer(self, i: int) -> bool:
        if self.moe.n_experts == 0:
            return False
        return (i % self.moe.period) == (self.moe.period - 1)

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode without a dense KV scan.

        SSM/hybrid archs and sliding-window-attention archs qualify; pure
        full-attention archs do not (long_500k is SKIPped for them).
        """
        kinds = set(self.layer_kinds())
        if kinds & {MAMBA, SLSTM, MLSTM}:
            return True
        if self.swa_window > 0:
            if self.block_pattern is None:
                return True  # every attention layer is windowed (mixtral)
            # gemma-style local:global mix: eligible if globals are a
            # minority (their caches still bound memory, not compute)
            n_global = sum(1 for k in self.layer_kinds() if k == ATTN)
            return n_global * 4 <= self.n_layers
        return False

    # ---------------- parameter counting (exact, matches init) -------------
    def param_count(self) -> int:
        """Exact parameter count of the model as initialized by repro.models."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


# ---------------------------------------------------------------------------
# Input shapes (assigned): seq_len x global_batch, plus mode.
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode skipped (see DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# Reduced (smoke-test) variants: same structure, tiny widths.
def reduced(cfg: ModelConfig) -> ModelConfig:
    """CPU-runnable config of the same family; keeps the block pattern."""
    n_layers = cfg.n_layers
    if cfg.block_pattern is not None:
        # keep at least one full pattern period
        n_layers = min(max(len(cfg.block_pattern), 2), 8)
    else:
        n_layers = 2
    moe = cfg.moe
    if moe.n_experts > 0:
        # capacity = E/k removes token dropping -> deterministic smoke tests
        moe = replace(moe, n_experts=4, top_k=min(2, moe.top_k or 1),
                      capacity_factor=4.0)
    kw = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 1,
        head_dim=16,
        d_ff=(128 if cfg.d_ff else 0),
        vocab=512,
        moe=moe,
        ssm=replace(cfg.ssm, d_state=8, d_conv=4, expand=2, chunk=16),
        max_seq_len=1024,
        dtype="float32",
    )
    if cfg.n_vision_tokens:
        kw["n_vision_tokens"] = 16
    if cfg.enc_dec:
        kw["n_enc_layers"] = 2
        kw["n_audio_frames"] = 24
    if cfg.cross_attn_period:
        kw["cross_attn_period"] = 2
    return replace(cfg, **kw)


SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")
SMOKE_DECODE_SHAPE = ShapeConfig("smoke_decode", 64, 2, "decode")


# ---------------------------------------------------------------------------
# Arch registry: populated by the per-arch modules in repro/configs/.
ARCHS: Registry[ModelConfig] = Registry("arch")


def get_arch(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (ensures registrations ran)

    return ARCHS.get(name)()


def arch_names():
    import repro.configs  # noqa: F401

    return ARCHS.names()


def to_dict(cfg) -> dict:
    if dataclasses.is_dataclass(cfg):
        return dataclasses.asdict(cfg)
    return dict(cfg)
