"""gemma3-1b [dense] — 5:1 local:global attention, MQA (kv=1), 262k vocab.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.configs.base import ARCHS, ATTN, ATTN_LOCAL, ModelConfig


@ARCHS.register("gemma3-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab=262144,
        rope_theta=1e6,
        qk_norm=True,
        swa_window=512,
        # 5 local : 1 global, repeating.
        block_pattern=(ATTN_LOCAL,) * 5 + (ATTN,),
        tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt; unverified",
    )
