"""OpenAI-Gym-style (pure functional) scheduling environment over the twin.

Action space: Discrete(k+1) — dispatch queue-candidate i in [0,k), or k =
no-op. Observations: fixed-size float vector of global datacenter features
+ per-candidate job features. Reward: the sim's energy/carbon/throughput
mix (paper: "the reward function combines energy consumption, carbon
footprint, and job throughput").

The env is a pytree-in/pytree-out (reset, step) pair -> vmap over
thousands of parallel datacenters, lax.scan over time, shard_map across
the mesh for distributed PPO. The sharded path is live, not aspirational:
``rl.distributed.distributed_ppo_train(env, launch.mesh.make_fleet_mesh())``
splits the ``n_envs`` replicas across devices with the same
replica-axis PartitionSpecs ``core.fleet.run_fleet(mesh=...)`` uses —
because ``EnvState`` is sim-state only (shared ``Statics`` stays
replicated, see below), each shard's rollout moves O(local envs x
sim-state) and only PPO gradients cross the wire.

Lightweight-state design (the RL-rollout hot path):

- ``EnvState`` is just ``(sim, step_count)``. The trace bank, node tables
  and scenario live in ONE shared ``Statics`` closed over by ``step``;
  the bank is stacked (W, J, Q) and each env selects its workload through
  the traced ``sim.workload`` int32 (``core.power`` gathers through it).
  Auto-reset therefore moves O(sim-state) per env — the previous design
  carried a full per-env ``Statics`` copy, so every vmapped env duplicated
  its (J, Q) bank slice and every reset paid the bank gather.
- ``step`` runs ONE dispatch sub-step (the agent's action) followed by
  ``sim_steps_per_action - 1`` idle sub-steps compiled WITHOUT the
  selection/placement stages (``make_step(..., "none")``) — bit-equivalent
  to the old always-dispatch scan whose non-zero sub-steps forced a no-op
  through the full candidate-ranking + placement pipeline.
- With ``macro=True`` (default) the idle sub-steps are ONE macro advance
  (``core.sim.make_macro_step``) clamped to the agent-decision boundary:
  quiet ticks between events fast-forward with exact segment accounting
  instead of running the completion/power machinery per tick (the
  scanned per-tick path is the degenerate every-tick-is-an-event case,
  kept under ``macro=False`` as the equivalence oracle).
- ``observe`` is fused: the per-node-type Python loop is a one-hot
  reduction, invariants (nameplate, capacity maxima, type one-hots,
  placement one-hot) are precomputed at construction, and candidate
  placement feasibility resolves the backend mask once per observation
  instead of once per candidate.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sim import SimConfig
from repro.core import faults as flt
from repro.core import placement as plc
from repro.core import schedulers as sched
from repro.core import thermal
from repro.core.sim import make_macro_step, make_step
from repro.data.bank import stack_workloads
from repro.scenarios import Scenario, eval_signal, power_cap_at
from repro.core.state import (
    QUEUED,
    RUNNING,
    SimState,
    Statics,
    build_statics,
    init_state,
    load_jobs,
)

# The observation layout — the single spec ``observe`` and ``obs_dim`` are
# both derived from, so the two cannot drift (the old ``10 + ...``
# hardcoding silently desynced when a global feature was added/removed).
GLOBAL_FEATURES = (
    "sin_day", "cos_day", "carbon", "price", "cap_frac",
    "queued_frac", "running_frac", "nodes_up_frac", "day_frac",
    "episode_progress",
)
# thermal-twin features, appended to the globals ONLY when
# ``cfg.thermal_enabled`` (the layout — and thus any pinned obs — is
# unchanged with the cooling loop off): hottest/mean rack outlet as a
# fraction of the dispatch trip threshold, the worst rack clock, and the
# fraction of racks currently refusing new jobs
THERMAL_FEATURES = ("rack_hot_frac", "rack_mean_frac",
                    "throttle_min", "tripped_frac")
# resilience-twin features, appended ONLY when ``cfg.resilience_on``
# (faults/outages/ladder off -> layout and pinned obs unchanged): the
# active degradation rung, fault-kill and terminal-failure counts as
# fractions of the job table, and lost node-seconds normalized by a
# node-day of fleet capacity
RESILIENCE_FEATURES = ("degrade_frac", "killed_frac",
                       "failed_frac", "lost_frac")
# serving-twin features, appended ONLY when ``cfg.serving_on`` (serving
# off -> layout and pinned obs unchanged): pool load (queue + in-flight
# over total buffering), queue depth vs the shed cap, the fluid latency
# estimate in SLO units, awake/waking pool fractions, and the current
# (schedulable) admission threshold
SERVING_FEATURES = ("srv_util", "srv_queue_frac", "srv_latency_slo",
                    "srv_active_frac", "srv_waking_frac",
                    "srv_admit_thresh")
# per-node-type features: free fraction of each resource
TYPE_FEATURES = ("cpu_free", "gpu_free", "mem_free")
CANDIDATE_FEATURES = (
    "valid", "wait_h", "dur_h", "n_nodes",
    "req_cpu", "req_gpu", "energy_proxy", "feasible_frac",
)


class EnvState(NamedTuple):
    """Per-env rollout state: the sim (which carries the traced workload
    id) plus the episode step counter — NO per-env Statics/bank copy."""

    sim: SimState
    step_count: jax.Array


class SchedEnv:
    """Constructed from a *bank* of workloads (numpy); reset samples one."""

    def __init__(
        self,
        cfg: SimConfig,
        workloads,                    # list of (jobs, bank) tuples
        *,
        episode_steps: int = 512,
        sim_steps_per_action: int = 15,
        reward_weights=(1.0, 1.0, 1.0, 0.05),
        scenario: Scenario | None = None,
        placement: str = "first_fit",
        macro: bool = True,
    ):
        self.cfg = cfg
        self.reward_weights = tuple(reward_weights)
        if placement not in plc.PLACEMENTS:
            raise KeyError(f"unknown placement {placement}")
        self.placement = placement
        # one-hot placement-backend encoding appended to the global obs so
        # one trained policy can condition on (and transfer across) the
        # placement stage it schedules against
        self._place_onehot = jnp.zeros((len(plc.PLACEMENTS),), jnp.float32
                                       ).at[plc.PLACE_IDS[placement]].set(1.0)
        self.episode_steps = episode_steps
        self.k = cfg.sched_max_candidates
        # with the degradation ladder schedulable, 5 extra actions set
        # state.degrade_level to rung 0..4 (NORMAL..EVICT) before the
        # dispatch sub-step runs; layout is k dispatches, k = no-op,
        # k+1+r = "set rung r" (off -> Discrete(k+1), unchanged); with
        # serving on, 4 more actions follow the ladder block: autoscale
        # the pool target down/up by serving_scale_step and nudge the
        # admission threshold down/up by 0.05
        self.n_actions = (self.k + 1 + (5 if cfg.degrade_enabled else 0)
                         + (4 if cfg.serving_on else 0))
        self.sim_steps_per_action = sim_steps_per_action

        # ONE shared Statics: stacked (W, J, Q) trace bank + stacked job
        # tables; envs select their workload via the traced sim.workload id
        jobs, bank = stack_workloads(cfg, workloads)
        self._jobs = {name: jnp.asarray(a) for name, a in jobs.items()}
        self.n_workloads = len(workloads)
        self._statics = build_statics(cfg, bank, scenario=scenario)

        # step functions are built ONCE (the old per-call make_step rebuilt
        # the closures on every Python invocation): one dispatching step
        # for the agent's action, one dispatch-free step for the idle
        # sub-steps between actions
        self._step_rl = make_step(cfg, self._statics, "rl",
                                  placement=placement,
                                  reward_weights=reward_weights)
        self._step_idle = make_step(cfg, self._statics, "none",
                                    reward_weights=reward_weights)
        # macro idle advance: ONE event-driven fast-forward between agent
        # decisions instead of N-1 scanned per-tick idle sub-steps
        self.macro = macro
        self._macro_idle = make_macro_step(
            cfg, self._statics, "none", reward_weights=reward_weights,
            update=lambda acc, out, _inc: self._acc_of(acc, out),
        ) if macro else None

        # observation invariants (constant per env instance)
        st = self._statics
        self._nameplate = jnp.maximum(jnp.sum(st.node_max_w), 1.0)
        self._cap_max = jnp.maximum(
            jnp.max(st.capacity, axis=1, keepdims=True), 1e-6)   # (NRES, 1)
        self._type_onehot = (
            st.node_type[None, :] == jnp.arange(cfg.n_types)[:, None]
        ).astype(jnp.float32)                                    # (T, N)
        self._cap_type = jnp.sum(
            st.capacity[:, None, :] * self._type_onehot[None], axis=-1
        )                                                        # (NRES, T)
        self._mask_fn = plc.PLACEMENT_MASKS[placement]
        self.obs_dim = int(self._obs_spec())

    @property
    def statics(self) -> Statics:
        """The single shared Statics (banked trace, node tables, scenario)."""
        return self._statics

    # ------------------------------------------------------------------ api
    def reset(self, key: jax.Array) -> Tuple[EnvState, jax.Array]:
        kw, ks = jax.random.split(key)
        w = jax.random.randint(kw, (), 0, self.n_workloads)
        sim = init_state(self.cfg, self._statics, ks)
        n = self._jobs["n_valid"][w]
        J = self.cfg.max_jobs
        idx = jnp.arange(J)
        valid = idx < n
        part = self._jobs.get("part")
        sim = sim._replace(
            workload=w.astype(jnp.int32),
            jstate=jnp.where(valid, QUEUED, 0).astype(jnp.int32),
            submit_t=self._jobs["submit_t"][w],
            dur_est=self._jobs["dur"][w],
            work_left=self._jobs["dur"][w],
            n_nodes=jnp.where(valid, self._jobs["n_nodes"][w], 0).astype(jnp.int32),
            req=self._jobs["req"][w],
            part=(sim.part if part is None
                  else jnp.where(valid, part[w], -1).astype(jnp.int32)),
            priority=self._jobs["priority"][w],
        )
        st = EnvState(sim=sim, step_count=jnp.int32(0))
        return st, self.observe(st)

    @staticmethod
    def _acc_of(acc, out):
        return {
            "reward": acc["reward"] + out.reward,
            "completed": acc["completed"] + out.completed_now,
            "energy_kwh": acc["energy_kwh"] + out.energy_kwh_step,
            "carbon_kg": acc["carbon_kg"] + out.carbon_kg_step,
            "facility_w": out.facility_w,
            "queue_len": out.queue_len,
        }

    def step(
        self, st: EnvState, action: jax.Array
    ) -> Tuple[EnvState, jax.Array, jax.Array, jax.Array, Dict[str, jax.Array]]:
        acc_of = self._acc_of

        # sub-step 0 dispatches the agent's action; the remaining
        # sub-steps advance the twin with the dispatch stage compiled OUT
        # (a bit-equivalent split: the old path forced a no-op action
        # through candidate ranking + placement on every sub-step).
        # Reductions accumulate in the scan carry (constant memory).
        action = jnp.asarray(action, jnp.int32)
        sim0 = st.sim
        if self.cfg.degrade_enabled:
            # ladder actions: a > k sets the degradation rung (held until
            # changed) and dispatches nothing this decision
            is_lvl = action > self.k
            if self.cfg.serving_on:
                is_lvl = is_lvl & (action <= self.k + 5)
            rung = jnp.clip(action - self.k - 1, 0, flt.LVL_EVICT)
            sim0 = sim0._replace(degrade_level=jnp.where(
                is_lvl, rung, sim0.degrade_level).astype(jnp.int32))
            action = jnp.where(is_lvl, self.k, action)
        if self.cfg.serving_on:
            # serving actions trail the ladder block: 0/1 scale the pool
            # target down/up, 2/3 nudge the admission threshold down/up;
            # the new target/threshold is held until changed and the
            # decision dispatches nothing (quiet updates are bitwise
            # no-ops so a non-serving action leaves the fields untouched)
            base = self.k + (5 if self.cfg.degrade_enabled else 0)
            is_srv = action > base
            code = action - base - 1
            stepn = jnp.float32(self.cfg.serving_scale_step)
            tgt2 = jnp.clip(
                sim0.srv_target
                + jnp.where(code == 1, stepn, 0.0)
                - jnp.where(code == 0, stepn, 0.0),
                0.0, float(self.cfg.serving_nodes))
            th2 = jnp.clip(
                sim0.srv_admit_thresh
                + 0.05 * (jnp.where(code == 3, 1.0, 0.0)
                          - jnp.where(code == 2, 1.0, 0.0)),
                0.05, 1.0)
            sim0 = sim0._replace(
                srv_target=jnp.where(is_srv, tgt2, sim0.srv_target),
                srv_admit_thresh=jnp.where(
                    is_srv, th2, sim0.srv_admit_thresh))
            action = jnp.where(is_srv, self.k, action)
        sim, out = self._step_rl(sim0, action)
        z = jnp.float32(0.0)
        acc = acc_of({"reward": z, "completed": z, "energy_kwh": z,
                      "carbon_kg": z, "facility_w": z, "queue_len": z}, out)

        n_idle = self.sim_steps_per_action - 1
        if self.macro and n_idle > 0:
            # one macro advance clamped to the agent-decision boundary:
            # full steps only on event ticks, quiet ticks fast-forwarded
            def idle(c):
                s, a, ticks = c
                s, a, took = self._macro_idle(s, a, n_idle - ticks)
                return (s, a, ticks + took)

            sim, acc, _ = jax.lax.while_loop(
                lambda c: c[2] < n_idle, idle, (sim, acc, jnp.int32(0)))
        else:
            def sub(carry, _):
                s, a = carry
                s, o = self._step_idle(s, jnp.int32(-1))
                return (s, acc_of(a, o)), None

            (sim, acc), _ = jax.lax.scan(
                sub, (sim, acc), None, length=n_idle,
            )
        reward = acc["reward"]
        st = EnvState(sim=sim, step_count=st.step_count + 1)
        done = st.step_count >= self.episode_steps
        info = {
            "facility_w": acc["facility_w"],
            "queue_len": acc["queue_len"],
            "completed": acc["completed"],
            "energy_kwh": acc["energy_kwh"],
            "carbon_kg": acc["carbon_kg"],
        }
        return st, self.observe(st), reward, done, info

    # ------------------------------------------------------------ features
    def _obs_spec(self) -> int:
        thermal = len(THERMAL_FEATURES) if self.cfg.thermal_enabled else 0
        resil = len(RESILIENCE_FEATURES) if self.cfg.resilience_on else 0
        srv = len(SERVING_FEATURES) if self.cfg.serving_on else 0
        return (len(GLOBAL_FEATURES) + thermal + resil + srv
                + len(plc.PLACEMENTS)
                + len(TYPE_FEATURES) * self.cfg.n_types
                + len(CANDIDATE_FEATURES) * self.k)

    def observe(self, st: EnvState) -> jax.Array:
        cfg, sim, statics = self.cfg, st.sim, self._statics
        day = 2 * jnp.pi * sim.t / cfg.day_seconds
        queued = jnp.sum(sched.queued_mask(sim)).astype(jnp.float32)
        running = jnp.sum(sim.jstate == RUNNING).astype(jnp.float32)
        scn = statics.scenario
        co2 = eval_signal(scn.carbon, sim.t) / max(cfg.carbon_mean, 1.0)
        price = eval_signal(scn.price, sim.t) / max(cfg.price_mean_usd_kwh, 1e-6)
        # cap as a fraction of nameplate node power; 1 = effectively uncapped
        cap_w = power_cap_at(scn.power_cap, sim.t)
        cap_frac = jnp.where(
            cap_w > 0, jnp.minimum(cap_w / self._nameplate, 1.0), 1.0)
        glob = dict(
            sin_day=jnp.sin(day), cos_day=jnp.cos(day), carbon=co2,
            price=price, cap_frac=cap_frac,
            queued_frac=queued / cfg.max_jobs,
            running_frac=running / cfg.max_jobs,
            nodes_up_frac=jnp.sum(sim.node_up) / cfg.n_nodes,
            day_frac=sim.t / cfg.day_seconds,
            episode_progress=(st.step_count.astype(jnp.float32)
                              / max(self.episode_steps, 1)),
        )
        assert tuple(glob) == GLOBAL_FEATURES
        glob = jnp.stack([glob[name] for name in GLOBAL_FEATURES])

        if cfg.thermal_enabled:
            # rack temps + throttle state so the policy can learn
            # thermally-aware dispatch (place away from hot racks, hold
            # jobs through trip windows)
            trip = max(cfg.thermal_trip_c, 1e-6)
            th_r = thermal.rack_throttle(cfg, sim.rack_outlet_c)   # (R,)
            therm = dict(
                rack_hot_frac=jnp.max(sim.rack_outlet_c) / trip,
                rack_mean_frac=jnp.mean(sim.rack_outlet_c) / trip,
                throttle_min=jnp.min(th_r),
                tripped_frac=jnp.mean(
                    (sim.rack_outlet_c >= cfg.thermal_trip_c
                     ).astype(jnp.float32)),
            )
            assert tuple(therm) == THERMAL_FEATURES
            glob = jnp.concatenate(
                [glob, jnp.stack([therm[n] for n in THERMAL_FEATURES])])

        if cfg.resilience_on:
            # fault/lost-work state so the policy can learn resilience-
            # aware control (drain ahead of maintenance windows, hold the
            # ladder rung through brownouts, requeue-aware dispatch)
            resil = dict(
                degrade_frac=(flt.effective_level(cfg, sim, statics)
                              .astype(jnp.float32) / float(flt.LVL_EVICT)),
                killed_frac=sim.n_killed / cfg.max_jobs,
                failed_frac=sim.n_failed / cfg.max_jobs,
                lost_frac=sim.lost_node_s
                / (cfg.n_nodes * cfg.day_seconds),
            )
            assert tuple(resil) == RESILIENCE_FEATURES
            glob = jnp.concatenate(
                [glob, jnp.stack([resil[n] for n in RESILIENCE_FEATURES])])

        if cfg.serving_on:
            # serving-pool state so the policy can learn overload control
            # (wake capacity ahead of the diurnal peak, tighten admission
            # under backlog, sleep the pool through the trough)
            conc_cap = sim.srv_active * cfg.serving_concurrency
            q_tot = jnp.sum(sim.srv_queue)
            svc = max(cfg.serving_service_s, 1e-9)
            w_est = (q_tot / jnp.maximum(conc_cap / svc, 1e-9)) + svc
            srv = dict(
                srv_util=(sim.srv_inflight + q_tot)
                / jnp.maximum(conc_cap + cfg.serving_queue_cap, 1e-9),
                srv_queue_frac=q_tot / max(cfg.serving_queue_cap, 1e-9),
                srv_latency_slo=jnp.minimum(
                    w_est / max(cfg.serving_slo_s, 1e-9), 10.0),
                srv_active_frac=sim.srv_active
                / max(cfg.serving_nodes, 1),
                srv_waking_frac=sim.srv_wake_n
                / max(cfg.serving_nodes, 1),
                srv_admit_thresh=sim.srv_admit_thresh,
            )
            assert tuple(srv) == SERVING_FEATURES
            glob = jnp.concatenate(
                [glob, jnp.stack([srv[n] for n in SERVING_FEATURES])])

        # per-node-type free fractions, fused: the python per-(type,
        # resource) loop of scalar reductions becomes one one-hot
        # contraction (values unchanged: the masks are exact {0,1} floats)
        free_up = sim.free * sim.node_up                         # (NRES, N)
        free_type = jnp.sum(
            free_up[:, None, :] * self._type_onehot[None], axis=-1
        )                                                        # (NRES, T)
        per_type = (free_type / jnp.maximum(self._cap_type, 1e-6)
                    ).T.reshape(-1)             # type-major, resource-minor

        cands = sched.rl_candidates(cfg, sim)               # (k,)
        safe = jnp.maximum(cands, 0)
        valid = (cands >= 0).astype(jnp.float32)
        wait = jnp.maximum(sim.t - sim.submit_t[safe], 0.0) / 3600.0
        dur = sim.dur_est[safe] / 3600.0
        nn = sim.n_nodes[safe].astype(jnp.float32) / cfg.max_nodes_per_job
        reqf = sim.req[:, safe] / self._cap_max              # (NRES, k)
        # estimated energy proxy: nodes * dur * mean gpu util request
        eproxy = nn * dur
        # feasibility under the ACTIVE placement backend (e.g. partition
        # masks out wrong-type nodes), so the agent sees what placement
        # will actually accept; the backend mask is resolved ONCE per
        # observation, not once per candidate
        ok = jax.vmap(lambda j: sched.feasible_nodes(sim, j))(safe)  # (k, N)
        if self._mask_fn is not None:
            ok = ok & self._mask_fn(sim, statics)[safe]
        if cfg.thermal_enabled:
            # tripped racks refuse dispatch (core.sim applies the same
            # gate through _dispatch_view) — show the agent the truth
            ok = ok & thermal.node_trip_ok(cfg, sim, statics)[None, :]
        feasible = jnp.sum(ok, axis=1).astype(jnp.float32) / cfg.n_nodes
        cand = dict(
            valid=valid, wait_h=wait * valid, dur_h=dur * valid,
            n_nodes=nn * valid, req_cpu=reqf[0] * valid,
            req_gpu=reqf[1] * valid, energy_proxy=eproxy * valid,
            feasible_frac=feasible * valid,
        )
        assert tuple(cand) == CANDIDATE_FEATURES
        cand_feats = jnp.concatenate(
            [cand[name] for name in CANDIDATE_FEATURES])
        return jnp.concatenate(
            [glob, self._place_onehot, per_type, cand_feats]
        ).astype(jnp.float32)
