"""OpenAI-Gym-style (pure functional) scheduling environment over the twin.

Action space: Discrete(k+1) — dispatch queue-candidate i in [0,k), or k =
no-op. Observations: fixed-size float vector of global datacenter features
+ per-candidate job features. Reward: the sim's energy/carbon/throughput
mix (paper: "the reward function combines energy consumption, carbon
footprint, and job throughput").

The env is a pytree-in/pytree-out (reset, step) pair -> vmap over
thousands of parallel datacenters, lax.scan over time, shard_map across
the mesh for distributed PPO.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sim import SimConfig
from repro.core import placement as plc
from repro.core import schedulers as sched
from repro.core.sim import make_step
from repro.scenarios import Scenario, eval_signal, power_cap_at
from repro.core.state import (
    QUEUED,
    RUNNING,
    SimState,
    Statics,
    build_statics,
    init_state,
    load_jobs,
)


class EnvState(NamedTuple):
    sim: SimState
    statics: Statics          # per-env (workload bank slice)
    step_count: jax.Array


class SchedEnv:
    """Constructed from a *bank* of workloads (numpy); reset samples one."""

    def __init__(
        self,
        cfg: SimConfig,
        workloads,                    # list of (jobs, bank) tuples
        *,
        episode_steps: int = 512,
        sim_steps_per_action: int = 15,
        reward_weights=(1.0, 1.0, 1.0, 0.05),
        scenario: Scenario | None = None,
        placement: str = "first_fit",
    ):
        self.cfg = cfg
        self.reward_weights = tuple(reward_weights)
        if placement not in plc.PLACEMENTS:
            raise KeyError(f"unknown placement {placement}")
        self.placement = placement
        # one-hot placement-backend encoding appended to the global obs so
        # one trained policy can condition on (and transfer across) the
        # placement stage it schedules against
        self._place_onehot = jnp.zeros((len(plc.PLACEMENTS),), jnp.float32
                                       ).at[plc.PLACE_IDS[placement]].set(1.0)
        self.episode_steps = episode_steps
        self.k = cfg.sched_max_candidates
        self.n_actions = self.k + 1
        self.sim_steps_per_action = sim_steps_per_action

        # stack the workload bank (pad Q to common length)
        qmax = max(b["cpu"].shape[1] for _, b in workloads)
        J = cfg.max_jobs

        def padQ(a):
            out = np.zeros((J, qmax), np.float32)
            out[:, : a.shape[1]] = a
            # hold last value so long jobs keep their final utilization
            out[:, a.shape[1]:] = a[:, -1:]
            return out

        self._banks = {
            "cpu": jnp.asarray(np.stack([padQ(b["cpu"]) for _, b in workloads])),
            "gpu": jnp.asarray(np.stack([padQ(b["gpu"]) for _, b in workloads])),
            "net": jnp.asarray(np.stack([b["net_tx"] for _, b in workloads])),
        }

        def padJ(jobs):
            out = {}
            n = len(jobs["submit_t"])
            for name, arr in jobs.items():
                if name == "is_gpu":
                    continue
                arr = np.asarray(arr)
                shape = (3, J) if name == "req" else (J,) + arr.shape[1:]
                buf = np.zeros(shape, arr.dtype)
                if name == "req":
                    buf[:, :n] = arr
                else:
                    buf[:n] = arr
                out[name] = buf
            out["n_valid"] = np.int32(n)
            return out

        padded = [padJ(j) for j, _ in workloads]
        self._jobs = {
            name: jnp.asarray(np.stack([p[name] for p in padded]))
            for name in padded[0]
        }
        self.n_workloads = len(workloads)
        # node constants + grid scenario (default: legacy diurnal sinusoids)
        self._base_statics = build_statics(cfg, scenario=scenario)
        # validate weights eagerly (step() builds the real step fn per call)
        make_step(cfg, self._base_statics, "rl", placement=placement,
                  reward_weights=reward_weights)
        self.obs_dim = int(self._obs_spec())

    # ------------------------------------------------------------------ api
    def reset(self, key: jax.Array) -> Tuple[EnvState, jax.Array]:
        kw, ks = jax.random.split(key)
        w = jax.random.randint(kw, (), 0, self.n_workloads)
        statics = self._base_statics._replace(
            cpu_trace=self._banks["cpu"][w],
            gpu_trace=self._banks["gpu"][w],
            net_tx=self._banks["net"][w],
        )
        sim = init_state(self.cfg, statics, ks)
        n = self._jobs["n_valid"][w]
        J = self.cfg.max_jobs
        idx = jnp.arange(J)
        valid = idx < n
        part = self._jobs.get("part")
        sim = sim._replace(
            jstate=jnp.where(valid, QUEUED, 0).astype(jnp.int32),
            submit_t=self._jobs["submit_t"][w],
            dur_est=self._jobs["dur"][w],
            work_left=self._jobs["dur"][w],
            n_nodes=jnp.where(valid, self._jobs["n_nodes"][w], 0).astype(jnp.int32),
            req=self._jobs["req"][w],
            part=(sim.part if part is None
                  else jnp.where(valid, part[w], -1).astype(jnp.int32)),
            priority=self._jobs["priority"][w],
        )
        st = EnvState(sim=sim, statics=statics, step_count=jnp.int32(0))
        return st, self.observe(st)

    def step(
        self, st: EnvState, action: jax.Array
    ) -> Tuple[EnvState, jax.Array, jax.Array, jax.Array, Dict[str, jax.Array]]:
        step_fn = make_step(
            self.cfg, st.statics, "rl", placement=self.placement,
            reward_weights=self.reward_weights,
        )

        # accumulate the reductions in the scan carry (constant memory)
        # instead of stacking a full StepOut per sub-step and reducing after
        def sub(carry, i):
            s, acc = carry
            a = jnp.where(i == 0, action, jnp.int32(self.n_actions - 1))
            s, out = step_fn(s, a)
            acc = {
                "reward": acc["reward"] + out.reward,
                "completed": acc["completed"] + out.completed_now,
                "energy_kwh": acc["energy_kwh"] + out.energy_kwh_step,
                "carbon_kg": acc["carbon_kg"] + out.carbon_kg_step,
                "facility_w": out.facility_w,
                "queue_len": out.queue_len,
            }
            return (s, acc), None

        z = jnp.float32(0.0)
        acc0 = {"reward": z, "completed": z, "energy_kwh": z,
                "carbon_kg": z, "facility_w": z, "queue_len": z}
        (sim, acc), _ = jax.lax.scan(
            sub, (st.sim, acc0), jnp.arange(self.sim_steps_per_action),
        )
        reward = acc["reward"]
        st = EnvState(sim=sim, statics=st.statics, step_count=st.step_count + 1)
        done = st.step_count >= self.episode_steps
        info = {
            "facility_w": acc["facility_w"],
            "queue_len": acc["queue_len"],
            "completed": acc["completed"],
            "energy_kwh": acc["energy_kwh"],
            "carbon_kg": acc["carbon_kg"],
        }
        return st, self.observe(st), reward, done, info

    # ------------------------------------------------------------ features
    def _obs_spec(self) -> int:
        n_types = self.cfg.n_types
        return 10 + len(plc.PLACEMENTS) + 3 * n_types + 8 * self.k

    def observe(self, st: EnvState) -> jax.Array:
        cfg, sim, statics = self.cfg, st.sim, st.statics
        day = 2 * jnp.pi * sim.t / cfg.day_seconds
        queued = jnp.sum(sched.queued_mask(sim)).astype(jnp.float32)
        running = jnp.sum(sim.jstate == RUNNING).astype(jnp.float32)
        scn = statics.scenario
        co2 = eval_signal(scn.carbon, sim.t) / max(cfg.carbon_mean, 1.0)
        price = eval_signal(scn.price, sim.t) / max(cfg.price_mean_usd_kwh, 1e-6)
        # cap as a fraction of nameplate node power; 1 = effectively uncapped
        cap_w = power_cap_at(scn.power_cap, sim.t)
        nameplate = jnp.maximum(jnp.sum(statics.node_max_w), 1.0)
        cap_frac = jnp.where(cap_w > 0, jnp.minimum(cap_w / nameplate, 1.0), 1.0)
        glob = jnp.stack([
            jnp.sin(day), jnp.cos(day), co2, price, cap_frac,
            queued / cfg.max_jobs, running / cfg.max_jobs,
            jnp.sum(sim.node_up) / cfg.n_nodes,
            sim.t / cfg.day_seconds,
            st.step_count.astype(jnp.float32) / max(self.episode_steps, 1),
        ])
        # per-node-type free fractions (cpu, gpu, mem)
        per_type = []
        for ti in range(cfg.n_types):
            m = (statics.node_type == ti).astype(jnp.float32)
            for r in range(3):
                cap = jnp.sum(statics.capacity[r] * m)
                free = jnp.sum(sim.free[r] * m * sim.node_up)
                per_type.append(free / jnp.maximum(cap, 1e-6))
        per_type = jnp.stack(per_type)

        cands = sched.rl_candidates(cfg, sim)               # (k,)
        safe = jnp.maximum(cands, 0)
        valid = (cands >= 0).astype(jnp.float32)
        wait = jnp.maximum(sim.t - sim.submit_t[safe], 0.0) / 3600.0
        dur = sim.dur_est[safe] / 3600.0
        nn = sim.n_nodes[safe].astype(jnp.float32) / cfg.max_nodes_per_job
        reqf = sim.req[:, safe] / jnp.maximum(
            jnp.max(statics.capacity, axis=1, keepdims=True), 1e-6
        )                                                    # (3,k)
        # estimated energy proxy: nodes * dur * mean gpu util request
        eproxy = nn * dur
        # feasibility under the ACTIVE placement backend (e.g. partition
        # masks out wrong-type nodes), so the agent sees what placement
        # will actually accept
        feasible = jax.vmap(
            lambda j: jnp.sum(
                plc.feasible_under(self.placement, sim, statics, j))
        )(safe).astype(jnp.float32) / cfg.n_nodes
        cand_feats = jnp.concatenate([
            valid, wait * valid, dur * valid, nn * valid,
            reqf[0] * valid, reqf[1] * valid, eproxy * valid, feasible * valid,
        ])
        return jnp.concatenate(
            [glob, self._place_onehot, per_type, cand_feats]
        ).astype(jnp.float32)
