from repro.envs.sched_env import EnvState, SchedEnv
