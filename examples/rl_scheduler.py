"""End-to-end driver (the paper's central experiment, Fig. 2): train a PPO
agent to schedule jobs on the datacenter twin for an energy/carbon/
throughput reward, then compare the learned policy against the classical
schedulers.

  PYTHONPATH=src python examples/rl_scheduler.py            # ~5 min CPU
  PYTHONPATH=src python examples/rl_scheduler.py --fast     # smoke
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sim import tiny_cluster
from repro.core import build_statics, init_state, load_jobs, run_episode, summary
from repro.data import synth_workload
from repro.envs import SchedEnv
from repro.rl import ActorCritic, PPOConfig, ppo_train


def evaluate_policy(env, policy, params, key, episodes=4):
    """Greedy rollout of the learned policy; returns per-episode stats."""
    totals = []
    for e in range(episodes):
        st, obs = env.reset(jax.random.fold_in(key, e))
        ret, energy, carbon, done_jobs = 0.0, 0.0, 0.0, 0.0
        for _ in range(env.episode_steps):
            logits, _ = policy.apply(params, obs)
            st, obs, r, d, info = env.step(st, jnp.argmax(logits))
            ret += float(r)
            energy += float(info["energy_kwh"])
            carbon += float(info["carbon_kg"])
            done_jobs += float(info["completed"])
        totals.append((ret, energy, carbon, done_jobs))
    return np.mean(totals, axis=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--iterations", type=int, default=40)
    args = ap.parse_args()
    iters = 4 if args.fast else args.iterations

    cfg = tiny_cluster(sched_max_candidates=4)
    wls = [synth_workload(cfg, 40, 1500.0, seed=s) for s in range(4)]
    env = SchedEnv(cfg, wls, episode_steps=24, sim_steps_per_action=15)
    print(f"env: obs={env.obs_dim} actions={env.n_actions} "
          f"({cfg.n_nodes}-node twin)")

    hist_rewards = []
    params, hist = ppo_train(
        env,
        cfg=PPOConfig(n_envs=8, rollout_len=24, lr=3e-4),
        n_iterations=iters,
        log=lambda it, s: (
            hist_rewards.append(s["mean_episode_return"]),
            print(f"  it {it:3d} episodic_return={s['mean_episode_return']:8.2f}"),
        ),
    )
    first = np.mean(hist_rewards[:3])
    last = np.mean(hist_rewards[-3:])
    print(f"\nPPO reward: first3={first:.2f} -> last3={last:.2f} "
          f"({'improved' if last > first else 'no improvement yet'})")

    # learned policy vs classical schedulers on the same workload
    policy = ActorCritic(env.obs_dim, env.n_actions)
    ret, energy, carbon, jobs_done = evaluate_policy(
        env, policy, params, jax.random.key(99))
    print(f"\nRL policy   : jobs={jobs_done:5.1f} energy={energy:7.2f} kWh "
          f"carbon={carbon:6.2f} kg")

    jobs, bank = wls[0]
    statics = build_statics(cfg, bank)
    st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    horizon = env.episode_steps * env.sim_steps_per_action
    for sched in ("fcfs", "sjf", "easy"):
        fs, _ = jax.jit(
            lambda s, sc=sched: run_episode(cfg, statics, s, horizon, sc)
        )(st)
        s = summary(fs)
        print(f"{sched:12s}: jobs={s['completed']:5.1f} "
              f"energy={s['energy_kwh']:7.2f} kWh "
              f"carbon={s['carbon_kg']:6.2f} kg")


if __name__ == "__main__":
    main()
