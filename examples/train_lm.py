"""End-to-end LM training example: a ~100M-param member of the assigned
xlstm family for a few hundred steps on the synthetic corpus, with async
checkpointing and exact resume.

  PYTHONPATH=src python examples/train_lm.py                # ~100M, 300 steps
  PYTHONPATH=src python examples/train_lm.py --tiny         # CI-speed
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.tiny:
        argv = ["--arch", "xlstm-125m", "--reduced", "--steps", "30",
                "--batch", "4", "--seq", "64", "--ckpt", args.ckpt,
                "--ckpt-every", "10", "--log-every", "5"]
    else:
        # full xlstm-125m (the ~100M-class assigned arch) on CPU
        argv = ["--arch", "xlstm-125m", "--steps", str(args.steps),
                "--batch", "4", "--seq", "256", "--ckpt", args.ckpt,
                "--ckpt-every", "50", "--log-every", "10"]
    history = train_mod.main(argv)
    losses = [h["loss"] for h in history]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'decreasing' if losses[-1] < losses[0] else 'check config'})")


if __name__ == "__main__":
    main()
