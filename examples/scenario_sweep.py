"""Policy x scenario sweep: ONE jitted call simulates a fleet of
datacenter replicas crossing scheduling policies (selection x placement,
policy-as-data — zero recompiles across the grid) with heterogeneous grid
scenarios — parametric diurnal carbon, trace-driven carbon (synthetic
grid-operator feed), demand-response power-cap events, heatwaves — and
compares sustainability outcomes per (policy, scenario) cell.

  PYTHONPATH=src python examples/scenario_sweep.py [--steps 1200]
      [--selects fcfs,sjf] [--places first_fit,green]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.sim import tiny_cluster
from repro.core import (
    build_statics,
    fleet_summary,
    init_state,
    load_jobs,
    policy_grid,
    policy_scenario_grid,
    run_fleet,
)
from repro.data import synth_grid_trace, synth_workload
from repro.scenarios import (
    carbon_trace,
    default_scenario,
    demand_response,
    heatwave,
    solar_heavy,
)


def build_scenarios(cfg, horizon_s):
    """5 scenario families (>= 3 distinct kinds: parametric carbon,
    trace-driven carbon, scheduled power-cap event)."""
    values, dt = synth_grid_trace("carbon", horizon_s * 4, dt=60.0, seed=1)
    nameplate = 1.3 * cfg.nameplate_it_w
    return [
        ("diurnal", default_scenario(cfg)),
        ("solar_heavy", solar_heavy(cfg)),
        ("carbon_trace", carbon_trace(cfg, values, dt)),
        ("demand_response", demand_response(
            cfg, cap_w=0.45 * nameplate, event_start_s=horizon_s * 0.3,
            event_len_s=horizon_s * 0.3)),
        ("heatwave", heatwave(cfg)),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1200)
    ap.add_argument("--selects", default="fcfs,sjf,easy",
                    help="comma-separated job-selection policies")
    ap.add_argument("--places", default="first_fit,green",
                    help="comma-separated node-placement strategies")
    args = ap.parse_args()

    cfg = tiny_cluster()
    horizon = args.steps * cfg.dt
    jobs, bank = synth_workload(cfg, 32, horizon * 0.75, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)

    scn_items = build_scenarios(cfg, horizon)
    scn_names = [n for n, _ in scn_items]
    selects = [s.strip() for s in args.selects.split(",") if s.strip()]
    places = [p.strip() for p in args.places.split(",") if p.strip()]
    pol_names, grid = policy_grid(selects, places)
    # cross the policy grid with the scenario axis: replica i runs policy
    # i // S under scenario i % S, all inside ONE compiled vmapped call —
    # policies are traced (select_id, place_id) data, so the grid costs a
    # single XLA compile no matter how many cells it has
    pols, scns = policy_scenario_grid(grid, [s for _, s in scn_items])
    R = len(pol_names) * len(scn_names)
    print(f"fleet: {len(pol_names)} policies x {len(scn_names)} scenarios "
          f"= {R} replicas x {args.steps} steps, one jitted vmap+scan call")
    # summary_only: windowed reductions in the scan carry — fleet memory is
    # O(replicas), independent of --steps (full per-step traces: drop it)
    finals, tel = run_fleet(cfg, statics, state, args.steps,
                            scenarios=scns, policies=pols, summary_only=True)
    rows = fleet_summary(finals)
    cell = [(p, s) for p in pol_names for s in scn_names]

    print(f"\n{'policy':22s} {'scenario':16s} {'energy_kwh':>11s} "
          f"{'carbon_kg':>10s} {'cost_usd':>9s} {'completed':>9s} "
          f"{'peak_kw':>8s}")
    peak_w = np.asarray(tel.max_facility_w)
    for i, (p, s) in enumerate(cell):
        r = rows[i]
        print(f"{p:22s} {s:16s} {r['energy_kwh']:11.3f} "
              f"{r['carbon_kg']:10.3f} {r['elec_cost_usd']:9.4f} "
              f"{r['completed']:9.1f} {peak_w[i] / 1e3:8.2f}")


if __name__ == "__main__":
    main()
