"""Scenario sweep: one jitted call simulates a fleet of datacenter
replicas under heterogeneous grid scenarios — parametric diurnal carbon,
trace-driven carbon (synthetic grid-operator feed), demand-response
power-cap events, heatwaves — and compares sustainability outcomes.

  PYTHONPATH=src python examples/scenario_sweep.py [--replicas 64]
      [--steps 1200] [--scheduler fcfs]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.sim import tiny_cluster
from repro.core import build_statics, fleet_summary, init_state, load_jobs, run_fleet
from repro.data import synth_grid_trace, synth_workload
from repro.scenarios import (
    carbon_trace,
    default_scenario,
    demand_response,
    heatwave,
    solar_heavy,
    stack_scenarios,
)


def build_scenarios(cfg, n, horizon_s):
    """n replicas cycling over 5 scenario families (>= 3 distinct kinds:
    parametric carbon, trace-driven carbon, scheduled power-cap event)."""
    values, dt = synth_grid_trace("carbon", horizon_s * 4, dt=60.0, seed=1)
    nameplate = 1.3 * cfg.nameplate_it_w
    families = [
        ("diurnal", lambda: default_scenario(cfg)),
        ("solar_heavy", lambda: solar_heavy(cfg)),
        ("carbon_trace", lambda: carbon_trace(cfg, values, dt)),
        ("demand_response", lambda: demand_response(
            cfg, cap_w=0.45 * nameplate, event_start_s=horizon_s * 0.3,
            event_len_s=horizon_s * 0.3)),
        ("heatwave", lambda: heatwave(cfg)),
    ]
    names = [families[i % len(families)][0] for i in range(n)]
    scns = [families[i % len(families)][1]() for i in range(n)]
    return names, stack_scenarios(scns)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=64)
    ap.add_argument("--steps", type=int, default=1200)
    ap.add_argument("--scheduler", default="fcfs")
    args = ap.parse_args()

    cfg = tiny_cluster()
    horizon = args.steps * cfg.dt
    jobs, bank = synth_workload(cfg, 32, horizon * 0.75, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)

    names, scns = build_scenarios(cfg, args.replicas, horizon)
    print(f"fleet: {args.replicas} replicas x {args.steps} steps, "
          f"scheduler={args.scheduler}, one jitted vmap+scan call")
    # summary_only: windowed reductions in the scan carry — fleet memory is
    # O(replicas), independent of --steps (full per-step traces: drop it)
    finals, tel = run_fleet(cfg, statics, state, args.steps, args.scheduler,
                            scenarios=scns, summary_only=True)
    rows = fleet_summary(finals)

    print(f"\n{'scenario':16s} {'n':>3s} {'energy_kwh':>11s} {'carbon_kg':>10s} "
          f"{'cost_usd':>9s} {'completed':>9s} {'peak_kw':>8s}")
    peak_w = np.asarray(tel.max_facility_w)
    for fam in dict.fromkeys(names):
        idx = [i for i, n in enumerate(names) if n == fam]
        print(f"{fam:16s} {len(idx):3d} "
              f"{np.mean([rows[i]['energy_kwh'] for i in idx]):11.3f} "
              f"{np.mean([rows[i]['carbon_kg'] for i in idx]):10.3f} "
              f"{np.mean([rows[i]['elec_cost_usd'] for i in idx]):9.4f} "
              f"{np.mean([rows[i]['completed'] for i in idx]):9.1f} "
              f"{np.mean(peak_w[idx]) / 1e3:8.2f}")


if __name__ == "__main__":
    main()
