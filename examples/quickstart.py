"""Quickstart: build the MIT-SuperCloud-style digital twin, replay a
workload, print RAPS-style runtime stats (paper Fig. 2 top-left).

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.sim import tx_gaia
from repro.core import build_statics, init_state, load_jobs, run_episode, summary
from repro.data import synth_workload


def main():
    # TX-GAIA twin: 448 dual-V100 nodes + 224 CPU nodes, multi-tenant
    cfg = tx_gaia(max_jobs=256, max_nodes_per_job=16)
    jobs, bank = synth_workload(cfg, n_jobs=200, horizon_s=3600.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)

    print(f"twin: {cfg.name} ({cfg.n_nodes} nodes), 200 jobs, 1h horizon")
    final, outs = jax.jit(
        lambda s: run_episode(cfg, statics, s, 3600, "replay")
    )(state)

    s = summary(final)
    print("\n--- simulation runtime stats (dt=1s, trace quanta=10s) ---")
    for k, v in s.items():
        print(f"  {k:22s} {v:,.3f}")
    p = outs.facility_w
    print(f"  peak facility power    {float(p.max())/1e3:,.1f} kW")
    print(f"  min facility power     {float(p.min())/1e3:,.1f} kW")
    print(f"  power swing            {float(p.max()-p.min())/1e3:,.1f} kW "
          "(the utility-scale swing problem motivating the paper)")


if __name__ == "__main__":
    main()
