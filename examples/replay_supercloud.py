"""Trace replay + rescheduling of a SuperCloud-schema dataset.

Writes a synthetic dataset in the MIT SuperCloud CSV schema (the real one
is not downloadable offline), parses it with the schema-faithful loader,
replays the recorded schedule, then re-schedules the same jobs under
FCFS / SJF / EASY-backfill and compares sustainability metrics — the
paper's core "tool to study optimal scheduling policies" workflow.

  PYTHONPATH=src python examples/replay_supercloud.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.sim import tx_gaia
from repro.core import build_statics, init_state, load_jobs, run_episode, summary
from repro.data import load_supercloud, write_supercloud_csvs


def main():
    cfg = tx_gaia(max_jobs=128, max_nodes_per_job=8)
    path = tempfile.mkdtemp(prefix="supercloud_")
    write_supercloud_csvs(path, cfg, n_jobs=96, horizon_s=1800.0, seed=42)
    print(f"synthetic SuperCloud dataset at {path}:")
    for f in sorted(os.listdir(path)):
        print(f"  {f} ({os.path.getsize(os.path.join(path, f)):,} bytes)")

    jobs, bank = load_supercloud(path, cfg)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)

    print(f"\n{'policy':10s} {'completed':>9s} {'energy kWh':>11s} "
          f"{'carbon kg':>9s} {'slowdown':>8s} {'wait s':>8s} {'PUE':>6s}")
    for sched in ("replay", "fcfs", "sjf", "easy", "priority"):
        fs, _ = jax.jit(
            lambda s, sc=sched: run_episode(cfg, statics, s, 5400, sc)
        )(state)
        s = summary(fs)
        print(f"{sched:10s} {s['completed']:9.0f} {s['energy_kwh']:11.1f} "
              f"{s['carbon_kg']:9.2f} {s['mean_slowdown']:8.2f} "
              f"{s['mean_wait_s']:8.0f} {s['avg_pue']:6.3f}")


if __name__ == "__main__":
    main()
