"""Virtual benchmarking of a speculative system (paper: "ExaDigiT can
create a virtual cloud system ... virtual prototyping of hardware/software
and virtual benchmarking of speculative systems").

The analytic performance model (Calculon-analogue) turns the assigned LM
architectures into datacenter jobs; the twin then answers a what-if:
how do energy, carbon and throughput change if the cooling plant degrades
(higher wet-bulb) or the rectifiers are upgraded?

  PYTHONPATH=src python examples/virtual_cloud.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.sim import tx_gaia
from repro.core import build_statics, init_state, load_jobs, run_episode, summary
from repro.perfmodel import lm_jobs_workload, lm_training_job


def main():
    print("=== LM jobs from the performance model (Calculon-analogue) ===")
    for arch in ("qwen3-4b", "mixtral-8x22b", "gemma3-1b"):
        j = lm_training_job(arch, "train_4k", n_chips=64, token_budget=5e8)
        print(f"  {arch:15s} step={j['step_s']*1e3:7.1f} ms "
              f"dur={j['duration_s']/60:6.1f} min util={j['gpu_util']:.2f} "
              f"net={j['net_tx_gbps']:6.1f} GB/s bound={j['dominant']}")

    cfg = tx_gaia(max_jobs=64, max_nodes_per_job=16)
    jobs, bank = lm_jobs_workload(
        cfg, ["qwen3-4b", "mixtral-8x22b", "gemma3-1b", "granite-3-8b"],
        n_jobs=32, horizon_s=3600.0, seed=7,
    )

    scenarios = {
        "baseline": {},
        "hot day (+8C wetbulb)": {"wetbulb_mean_c": 24.0},
        "smart rectifiers": {"rect_eff_peak": 0.985, "rect_eff_curv": 0.04},
        "degraded network": {"bisection_gbps": 200.0, "congestion_knee": 0.2},
        "demand response 300kW": {"power_cap_w": 300_000.0},
    }
    print("\n=== what-if scenarios on the twin (same workload) ===")
    print(f"{'scenario':24s} {'energy kWh':>10s} {'carbon kg':>9s} "
          f"{'PUE':>6s} {'completed':>9s}")
    for name, overrides in scenarios.items():
        c = tx_gaia(max_jobs=64, max_nodes_per_job=16, **overrides)
        statics = build_statics(c, bank)
        st = load_jobs(init_state(c, statics, jax.random.key(0)), jobs)
        fs, _ = jax.jit(lambda s, c=c, st_=statics:
                        run_episode(c, st_, s, 5400, "easy"))(st)
        s = summary(fs)
        print(f"{name:24s} {s['energy_kwh']:10.1f} {s['carbon_kg']:9.2f} "
              f"{s['avg_pue']:6.3f} {s['completed']:9.0f}")


if __name__ == "__main__":
    main()
