import faulthandler
import os
import sys

import pytest

# Tests run on the single real CPU device (the 512-device override is ONLY
# for the dry-run); keep any user XLA_FLAGS out of the test environment.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Global per-test hang guard (CI sets REPRO_TEST_TIMEOUT; see ci.yml). A
# test that exceeds the budget dumps every thread's traceback and kills
# the process — a loud diagnosable failure instead of a 6-hour stuck job.
# Implemented with faulthandler so it needs no pytest-timeout plugin.
_TEST_TIMEOUT = float(os.environ.get("REPRO_TEST_TIMEOUT", "0") or 0)


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_protocol(item, nextitem):
    if _TEST_TIMEOUT > 0:
        faulthandler.dump_traceback_later(_TEST_TIMEOUT, exit=True)
    yield
    if _TEST_TIMEOUT > 0:
        faulthandler.cancel_dump_traceback_later()
