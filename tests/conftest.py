import os
import sys

# Tests run on the single real CPU device (the 512-device override is ONLY
# for the dry-run); keep any user XLA_FLAGS out of the test environment.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
