"""Use hypothesis when installed; otherwise run property tests on a
DETERMINISTIC example grid instead of skipping them.

The old stub skipped every ``@given`` test when hypothesis was absent, so
environments without the dependency silently lost the whole property
suite (11 skips). The fallback here keeps the property tests *executing*:
each strategy knows how to draw deterministic examples from a seeded RNG,
and ``given`` expands into ``pytest.mark.parametrize`` over a fixed draw
count — less adversarial than hypothesis' shrinking search, but the
invariants stay enforced everywhere.

Set ``REPRO_REQUIRE_HYPOTHESIS=1`` (CI does) to hard-fail when the real
library is missing rather than degrade to the fallback grid.
"""

import os

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised when dep absent
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
        raise ImportError(
            "REPRO_REQUIRE_HYPOTHESIS is set but hypothesis is not "
            "installed — install the [test] extra (pip install -e .[test])")
    HAS_HYPOTHESIS = False

    import random

    _FALLBACK_EXAMPLES = 8   # draws per @given test in fallback mode

    class _Strategy:
        """Minimal stand-in: a deterministic draw function."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _StrategyFactory:
        """The subset of hypothesis.strategies the suite uses."""

        @staticmethod
        def integers(min_value=0, max_value=1 << 16):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            return _Strategy(lambda rng: [
                elements.example(rng)
                for _ in range(rng.randint(min_size, max_size))])

        def __getattr__(self, name):   # unknown strategy -> loud failure
            raise AttributeError(
                f"fallback strategies don't implement st.{name}; install "
                "hypothesis or add it to _hypothesis_compat")

    st = _StrategyFactory()

    def settings(*_a, **_k):
        """Fallback ignores example-count/deadline tuning."""
        return lambda f: f

    def given(**strategies):
        """Expand into a parametrize over a deterministic example grid.
        Draws are seeded from the test name, so the grid is stable across
        runs and machines (reproducible failures, cacheable results)."""
        if not strategies:
            raise TypeError("fallback given() supports keyword strategies "
                            "only (all in-repo usages are kwargs-style)")
        names = tuple(strategies)

        def deco(f):
            rng = random.Random(f"{f.__module__}.{f.__name__}")
            cases = [
                # pytest wants bare values (not 1-tuples) for one argname
                (strategies[names[0]].example(rng) if len(names) == 1
                 else tuple(strategies[n].example(rng) for n in names))
                for _ in range(_FALLBACK_EXAMPLES)
            ]
            return pytest.mark.parametrize(",".join(names), cases)(f)

        return deco
