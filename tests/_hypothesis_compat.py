"""Import hypothesis if available; otherwise expose stubs that skip only
the property-based tests so the rest of the suite still runs."""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised when dep absent
    HAS_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy constructor call at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass

            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub

        return deco
