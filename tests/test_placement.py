"""Two-stage policy engine tests: placement strategies, partition
correctness, policy-as-data (traced lax.switch) equivalence with the eager
per-policy paths, the single-compile policy grid, and the EASY
heterogeneity fixes (head-feasible shadow releases, fits-now backfill).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.sim import NodeType, SimConfig, tiny_cluster
from repro.core import (
    PLACEMENTS,
    QUEUED,
    RUNNING,
    SCHEDULERS,
    build_statics,
    fleet_summary,
    init_state,
    load_jobs,
    make_policy,
    make_step,
    policy_grid,
    policy_scenario_grid,
    run_episode,
    run_fleet,
)
from repro.core import placement as plc
from repro.core import schedulers as sched
from repro.data import synth_workload


def _setup(cfg=None, seed=0, n_jobs=24, horizon=600.0):
    cfg = cfg or tiny_cluster()
    jobs, bank = synth_workload(cfg, n_jobs, horizon, seed=seed)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(seed)), jobs)
    return cfg, statics, state


def _homogeneous(n_nodes=12, **kw):
    types = (NodeType("n", n_nodes, 16, 2, 128.0, 100.0, 120.0, 30.0, 240.0,
                      16_000.0),)
    base = dict(max_jobs=32, max_nodes_per_job=4, sched_max_candidates=4)
    base.update(kw)
    return SimConfig(name="homog", node_types=types, **base)


# ------------------------------------------------- reduction to first_fit
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), job=st.integers(0, 15))
def test_property_green_reduces_to_first_fit_on_homogeneous(seed, job):
    """On a one-type cluster the green score is constant, so (even with a
    churned free pool) green must reproduce first_fit ordering exactly."""
    cfg, statics, state = _setup(_homogeneous(), seed=seed % 5, n_jobs=16)
    key = jax.random.key(seed)
    state = state._replace(
        free=state.free * jax.random.uniform(key, state.free.shape))
    j = jnp.int32(job)
    row_ff, ok_ff = plc.place_first_fit(state, statics, j)
    row_g, ok_g = plc.place_green(state, statics, j)
    np.testing.assert_array_equal(np.asarray(row_ff), np.asarray(row_g))
    assert bool(ok_ff) == bool(ok_g)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), job=st.integers(0, 15))
def test_property_bestfit_spread_reduce_to_first_fit_on_uniform(seed, job):
    """With a uniform free pool (fresh cluster) every node scores equally,
    so best_fit and spread tie-break to first_fit's index order."""
    cfg, statics, state = _setup(_homogeneous(), seed=seed % 5, n_jobs=16)
    j = jnp.int32(job)
    row_ff, ok_ff = plc.place_first_fit(state, statics, j)
    for fn in (plc.place_best_fit, plc.place_spread, plc.place_partition):
        row, ok = fn(state, statics, j)
        np.testing.assert_array_equal(
            np.asarray(row_ff), np.asarray(row), err_msg=fn.__name__)
        assert bool(ok_ff) == bool(ok), fn.__name__
    # NB: partition included above because a fresh homogeneous cluster has
    # a single type, so every tag is either matched or -1


def test_best_fit_packs_spread_balances():
    cfg, statics, state = _setup(_homogeneous(n_nodes=4), n_jobs=8)
    # node 1 is half-loaded, others empty -> best_fit must top it up,
    # spread must avoid it
    free = state.free.at[:, 1].multiply(0.5)
    state = state._replace(
        free=free, n_nodes=state.n_nodes.at[0].set(1),
        req=state.req.at[:, 0].set(jnp.array([2.0, 0.0, 4.0])))
    j = jnp.int32(0)
    row_bf, _ = plc.place_best_fit(state, statics, j)
    row_sp, _ = plc.place_spread(state, statics, j)
    assert int(row_bf[0]) == 1
    assert int(row_sp[0]) != 1


def test_green_prefers_efficient_hardware():
    """Inefficient type listed FIRST: first_fit grabs it, green skips to
    the low-W-per-GFLOP nodes."""
    types = (
        NodeType("hot", 4, 32, 0, 64.0, 200.0, 400.0, 0.0, 0.0, 1_000.0),
        NodeType("cool", 4, 32, 0, 64.0, 80.0, 120.0, 0.0, 0.0, 4_000.0),
    )
    cfg = SimConfig(name="het", node_types=types, max_jobs=8,
                    max_nodes_per_job=4)
    statics = build_statics(cfg)
    state = init_state(cfg, statics, jax.random.key(0))
    jobs = {
        "submit_t": np.zeros(1, np.float32), "dur": np.full(1, 60.0, np.float32),
        "n_nodes": np.array([2], np.int32),
        "req": np.array([[4.0], [0.0], [8.0]], np.float32),
        "priority": np.zeros(1, np.float32),
    }
    state = load_jobs(state, jobs)
    row_g, ok = plc.place_green(state, statics, jnp.int32(0))
    assert bool(ok)
    picked = np.asarray(row_g)[:2]
    assert (np.asarray(statics.node_type)[picked] == 1).all(), picked
    row_ff, _ = plc.place_first_fit(state, statics, jnp.int32(0))
    assert (np.asarray(statics.node_type)[np.asarray(row_ff)[:2]] == 0).all()


# ------------------------------------------------------------- partition
def test_partition_mask_and_any_tag():
    cfg, statics, state = _setup()
    gpu_job = int(np.flatnonzero(np.asarray(state.part) == 0)[0])
    mask = np.asarray(plc.partition_mask(state, statics, jnp.int32(gpu_job)))
    np.testing.assert_array_equal(mask, np.asarray(statics.node_type) == 0)
    # tag -1 = any node
    state2 = state._replace(part=state.part.at[gpu_job].set(-1))
    mask2 = np.asarray(plc.partition_mask(state2, statics, jnp.int32(gpu_job)))
    assert mask2.all()


def test_partition_never_places_cpu_job_on_gpu_node():
    """Acceptance: under `partition` placement a CPU-partition job is never
    placed on a GPU node (and vice versa), checked at every step of an
    episode over the synth workload whose tags rode load_jobs end-to-end."""
    cfg, statics, state = _setup(n_jobs=24, horizon=400.0)
    ntype = np.asarray(statics.node_type)
    step = jax.jit(make_step(cfg, statics, "fcfs", placement="partition"))
    s = state
    placed_any = 0
    for _ in range(300):
        s, _ = step(s, jnp.int32(-1))
        js = np.asarray(s.jstate)
        place = np.asarray(s.placement)
        part = np.asarray(s.part)
        for j in np.flatnonzero(js == RUNNING):
            nodes = place[j][place[j] >= 0]
            placed_any += len(nodes)
            if part[j] >= 0:
                assert (ntype[nodes] == part[j]).all(), (j, part[j], nodes)
    assert placed_any > 0, "episode never placed anything — vacuous test"


def test_synth_partition_tags_end_to_end():
    """synth_workload -> load_jobs carries the partition tag: GPU jobs tag
    the GPU type, CPU jobs the CPU type."""
    cfg, statics, state = _setup()
    jobs, _ = synth_workload(cfg, 24, 600.0, seed=0)
    part = np.asarray(state.part)[:24]
    np.testing.assert_array_equal(
        part, np.where(jobs["is_gpu"], 0, cfg.n_types - 1))
    assert (np.asarray(state.part)[24:] == -1).all()   # unloaded slots: any


# ---------------------------------------------- policy-as-data equivalence
def test_traced_engine_bit_equivalent_to_eager_paths():
    cfg, statics, state = _setup(n_jobs=24, horizon=300.0)
    traced = jax.jit(
        lambda pol, st: run_episode(cfg, statics, st, 80, pol))
    for sel in SCHEDULERS:
        for pl in PLACEMENTS:
            fs_e, out_e = jax.jit(
                lambda st, sel=sel, pl=pl: run_episode(
                    cfg, statics, st, 80, sel, placement=pl))(state)
            fs_t, out_t = traced(make_policy(sel, pl), state)
            tag = f"{sel}+{pl}"
            np.testing.assert_array_equal(
                np.asarray(fs_e.jstate), np.asarray(fs_t.jstate), err_msg=tag)
            np.testing.assert_array_equal(
                np.asarray(fs_e.placement), np.asarray(fs_t.placement),
                err_msg=tag)
            np.testing.assert_allclose(
                float(fs_e.energy_kwh), float(fs_t.energy_kwh),
                rtol=1e-6, err_msg=tag)
            np.testing.assert_allclose(
                np.asarray(out_e.reward), np.asarray(out_t.reward),
                rtol=1e-5, atol=1e-6, err_msg=tag)


def test_policy_grid_is_single_compile():
    """Acceptance: sweeping the FULL selection x placement grid through a
    jitted runner adds exactly ONE jit-cache entry."""
    cfg, statics, state = _setup()
    run = jax.jit(lambda pol, st: run_episode(
        cfg, statics, st, 30, pol, summary_only=True))
    names, grid = policy_grid(list(SCHEDULERS), list(PLACEMENTS))
    assert len(names) == len(SCHEDULERS) * len(PLACEMENTS)
    for i in range(len(names)):
        pol = jax.tree.map(lambda a: a[i], grid)
        fs, tel = run(pol, state)
    assert run._cache_size() == 1


def test_run_fleet_policy_by_scenario_grid():
    """Acceptance: >=3 policies x >=2 scenarios in ONE vmapped call with
    per-replica telemetry."""
    from repro.scenarios import default_scenario, heatwave

    cfg, statics, state = _setup()
    pols, scns = policy_scenario_grid(
        [("fcfs", "first_fit"), ("sjf", "best_fit"), ("easy", "green")],
        [default_scenario(cfg), heatwave(cfg)],
    )
    fs, tel = run_fleet(cfg, statics, state, 60, scenarios=scns,
                        policies=pols, summary_only=True)
    R = 3 * 2
    assert np.shape(tel.energy_kwh) == (R,)
    assert np.shape(fs.t) == (R,)
    rows = fleet_summary(fs)
    assert len(rows) == R and all(np.isfinite(r["energy_kwh"]) for r in rows)
    # heatwave replicas (odd indices) burn more cooling energy than their
    # default-scenario twins under the same policy
    e = np.asarray(tel.energy_kwh)
    assert (e[1::2] > e[0::2]).all()


def test_run_fleet_mismatched_axes_is_loud():
    from repro.scenarios import default_scenario

    cfg, statics, state = _setup()
    _, grid = policy_grid(["fcfs", "sjf"], ["first_fit"])
    with pytest.raises(ValueError, match="policy_scenario_grid"):
        run_fleet(cfg, statics, state, 10, policies=grid,
                  scenarios=[default_scenario(cfg)] * 3)
    # scheduler name + policies together would silently ignore one — loud
    with pytest.raises(ValueError, match="exactly one"):
        run_fleet(cfg, statics, state, 10, "easy", policies=grid)


def test_make_policy_unknown_names_are_loud():
    with pytest.raises(KeyError):
        make_policy("nope", "first_fit")
    with pytest.raises(KeyError):
        make_policy("fcfs", "nope")
    cfg = tiny_cluster()
    statics = build_statics(cfg)
    with pytest.raises(KeyError):
        make_step(cfg, statics, "fcfs", placement="nope")
    # a Policy carries its own placement id — combining with placement=
    # would silently drop one, so it must be loud
    with pytest.raises(ValueError, match="exactly one"):
        make_step(cfg, statics, make_policy("fcfs", "first_fit"),
                  placement="green")


# ------------------------------------------------- EASY heterogeneity fixes
def _easy_fixture():
    """tiny cluster: nodes 0-7 GPU type, 8-15 CPU type (K=4)."""
    cfg = tiny_cluster()
    statics = build_statics(cfg)
    state = init_state(cfg, statics, jax.random.key(0))
    jobs = {
        "submit_t": np.zeros(3, np.float32),
        "dur": np.array([1000.0, 100.0, 500.0], np.float32),
        "n_nodes": np.array([4, 4, 2], np.int32),
        # job0 gpu-hungry, job1 cpu-only, job2 (head) needs gpus
        "req": np.array([[4.0, 4.0, 4.0],
                         [2.0, 0.0, 1.0],
                         [8.0, 8.0, 8.0]], np.float32),
        "priority": np.zeros(3, np.float32),
    }
    state = load_jobs(state, jobs)
    # job0 RUNNING on gpu nodes 0-3, job1 RUNNING on cpu nodes 8-11
    place = state.placement
    place = place.at[0].set(jnp.array([0, 1, 2, 3], jnp.int32))
    place = place.at[1].set(jnp.array([8, 9, 10, 11], jnp.int32))
    free = state.free
    # all 8 GPU nodes have their GPUs taken (0-3 by job0; 4-7 by "others")
    free = free.at[1, :8].set(0.0)
    state = state._replace(
        jstate=state.jstate.at[:2].set(RUNNING),
        start_t=state.start_t.at[:2].set(0.0),
        placement=place, free=free, t=jnp.float32(10.0),
    )
    return cfg, statics, state


def test_shadow_time_ignores_releases_head_cannot_use():
    """The CPU job (job1) ends at t=100 and releases 4 CPU nodes — useless
    to the GPU head (job2, needs 2 GPUs/node). Shadow must wait for the
    GPU job's release at t=1000, not credit the CPU nodes (the pre-fix
    code returned 100 here)."""
    cfg, statics, state = _easy_fixture()
    t_sh = float(sched.shadow_time(cfg, state, statics, jnp.int32(2)))
    assert t_sh == pytest.approx(1000.0), t_sh


def test_easy_backfill_candidates_must_fit_now():
    """Head blocked on a node-exclusive cluster; the earlier-submitted
    backfill candidate doesn't fit NOW (2 nodes wanted, 1 free) while a
    later 1-node job does — EASY must pick the one that fits instead of
    wasting the dispatch attempt (the pre-fix code picked the 2-node
    job and the wavefront slot became a no-op)."""
    cfg = SimConfig(
        name="uniform",
        node_types=(NodeType("n", 8, 16, 0, 64.0, 100.0, 200.0, 0.0, 0.0,
                             1000.0),),
        max_jobs=16, max_nodes_per_job=8, sched_max_candidates=4,
    )
    statics = build_statics(cfg)
    jobs = {
        "submit_t": np.array([0.0, 1.0, 2.0, 3.0], np.float32),
        "dur": np.array([1000.0, 1000.0, 30.0, 30.0], np.float32),
        "n_nodes": np.array([7, 8, 2, 1], np.int32),
        "req": np.tile(np.array([[16.0], [0.0], [1.0]], np.float32), (1, 4)),
        "priority": np.zeros(4, np.float32),
    }
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    step = jax.jit(make_step(cfg, statics, "fcfs"))
    # step past every submit time: job0 starts, head (job1) stays blocked,
    # jobs 2/3 become eligible backfill candidates
    for _ in range(4):
        state, _ = step(state, jnp.int32(-1))
    assert int(state.jstate[0]) == RUNNING and int(state.jstate[1]) == QUEUED
    fits = np.asarray(sched.fits_now_mask(state))
    assert not fits[2] and fits[3]
    pick = int(sched.select_easy(cfg, state, statics))
    assert pick == 3, pick


def test_easy_respects_partition_placement():
    """Under the `partition` placement, EASY must not select a head that
    fits by raw resources but sits in the wrong partition (placement would
    reject it and the dispatch attempt would no-op) — it should treat the
    head as blocked and backfill a feasible job instead."""
    cfg = tiny_cluster()            # nodes 0-7 GPU type, 8-15 CPU type
    statics = build_statics(cfg)
    jobs = {
        "submit_t": np.array([0.0, 0.0], np.float32),
        "dur": np.array([600.0, 30.0], np.float32),
        "n_nodes": np.array([2, 1], np.int32),
        # job0: CPU-partition head (cores only — fits GPU nodes by raw
        # resources); job1: GPU-partition job that genuinely fits now
        "req": np.array([[4.0, 4.0], [0.0, 1.0], [8.0, 8.0]], np.float32),
        "priority": np.zeros(2, np.float32),
        "part": np.array([1, 0], np.int32),
    }
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    # every CPU node's cores are taken by (unmodeled) tenants
    state = state._replace(
        free=state.free.at[0, 8:].set(0.0), t=jnp.float32(1.0))
    mask = sched.partition_mask_all(state, statics)
    assert int(sched.select_easy(cfg, state, statics, mask)) == 1
    # without the mask the old behavior selected the doomed head
    assert int(sched.select_easy(cfg, state, statics)) == 0
    # end-to-end: one step under easy+partition starts the GPU job
    step = jax.jit(make_step(cfg, statics, "easy", placement="partition"))
    s, _ = step(state, jnp.int32(-1))
    assert int(s.jstate[1]) == RUNNING and int(s.jstate[0]) == QUEUED


def test_run_fleet_accepts_policy_instances():
    """Regression: Policy is itself a tuple — the policies list must accept
    Policy objects, not just (select, place) name tuples."""
    cfg, statics, state = _setup()
    fs, tel = run_fleet(
        cfg, statics, state, 20,
        policies=[make_policy("fcfs", "first_fit"),
                  ("sjf", "green")],          # mixed forms
        summary_only=True)
    assert np.shape(tel.energy_kwh) == (2,)
    pols, scns = policy_scenario_grid(
        [make_policy("fcfs", "first_fit"), ("sjf", "green")],
        [statics.scenario])
    assert np.shape(pols.select) == (2,)
    # ...and the batched Policy that policy_grid returns composes directly
    names, grid = policy_grid(["fcfs", "sjf"], ["first_fit"])
    pols2, _ = policy_scenario_grid(grid, [statics.scenario] * 2)
    assert np.shape(pols2.select) == (len(names) * 2,)
    np.testing.assert_array_equal(
        np.asarray(pols2.select), np.repeat(np.asarray(grid.select), 2))
    # ...as does a batched Scenario (the input run_fleet's mismatch error
    # tells users to cross with)
    from repro.scenarios import sample_scenarios

    batched_scns = sample_scenarios(cfg, 3, seed=0)
    pols3, scns3 = policy_scenario_grid(grid, batched_scns)
    from repro.scenarios.scenario import n_replicas

    assert np.shape(pols3.select) == (len(names) * 3,)
    assert n_replicas(scns3) == len(names) * 3


def test_easy_still_backfills_feasible_candidates():
    """Regression guard: the fits-now mask must not stop normal backfill
    (the PR2-era scenario where a 1-node job jumps a blocked 8-node head)."""
    cfg = SimConfig(
        name="uniform",
        node_types=(NodeType("n", 8, 16, 0, 64.0, 100.0, 200.0, 0.0, 0.0,
                             1000.0),),
        max_jobs=16, max_nodes_per_job=8, sched_max_candidates=4,
    )
    statics = build_statics(cfg)
    jobs = {
        "submit_t": np.array([0.0, 1.0, 2.0], np.float32),
        "dur": np.array([1000.0, 1000.0, 30.0], np.float32),
        "n_nodes": np.array([7, 8, 1], np.int32),
        "req": np.tile(np.array([[16.0], [0.0], [1.0]], np.float32), (1, 3)),
        "priority": np.zeros(3, np.float32),
    }
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    step = jax.jit(make_step(cfg, statics, "easy"))
    s = state
    for _ in range(20):
        s, _ = step(s, jnp.int32(-1))
    js = np.asarray(s.jstate)[:3]
    assert js[0] == RUNNING and js[2] == RUNNING and js[1] == QUEUED
