"""Per-architecture smoke tests: every assigned arch, reduced config, one
forward/train step + one decode step on CPU; asserts shapes & finiteness.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_names, get_arch, reduced
from repro.models import (
    count_params_analytic,
    decode_step,
    forward_train,
    init_cache,
    init_params,
)
from repro.optim import AdamW
from repro.train.train_step import make_train_step

ARCHS = arch_names()

# published sizes (total params, billions) — exactness of the config files
EXPECTED_B = {
    "mixtral-8x22b": (130, 150),
    "grok-1-314b": (290, 330),
    "qwen3-4b": (3.5, 4.5),
    "granite-3-8b": (7.5, 9),
    "internlm2-20b": (18, 22),
    "gemma3-1b": (0.8, 1.3),
    "jamba-1.5-large-398b": (370, 420),
    "xlstm-125m": (0.1, 0.3),
    "llama-3.2-vision-11b": (9, 12),
    "whisper-small": (0.2, 0.3),
}


def _batch(cfg, B=2, S=64):
    batch = {
        "tokens": jnp.ones((B, S), jnp.int32),
        "labels": jnp.concatenate(
            [jnp.ones((B, S - 1), jnp.int32), -jnp.ones((B, 1), jnp.int32)],
            axis=1,
        ),
    }
    if cfg.n_vision_tokens:
        batch["vision"] = 0.02 * jnp.ones((B, cfg.n_vision_tokens, cfg.d_model))
    if cfg.enc_dec:
        batch["audio"] = 0.02 * jnp.ones((B, cfg.n_audio_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_published(arch):
    lo, hi = EXPECTED_B[arch]
    n = count_params_analytic(get_arch(arch)) / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced(get_arch(arch))
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = forward_train(params, batch, cfg)
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) == 2 * 63  # -1 labels ignored

    opt = AdamW(lr=1e-3)
    state = {"params": params, "opt": opt.init(params), "step": jnp.int32(0)}
    step = jax.jit(make_train_step(cfg, opt))
    state2, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(state2["step"]) == 1
    assert float(m["skipped"]) == 0.0
    # params actually changed
    d = jax.tree.leaves(
        jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     state2["params"], params)
    )
    assert max(d) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = reduced(get_arch(arch))
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 64
    cache = init_cache(cfg, B, S)
    logits, new_cache = decode_step(
        params, cache, jnp.ones((B, 1), jnp.int32), jnp.int32(5), cfg
    )
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)
