"""Pallas kernel sweeps: shapes x dtypes x masks vs the pure-jnp oracles
(interpret mode on CPU; same kernels run compiled on TPU).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _mk_qkv(b, sq, sk, h, kv, hd, dtype):
    q = jnp.asarray(RNG.normal(size=(b, sq, h, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, sk, kv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, sk, kv, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,sk,h,kv,hd,causal,window,bq,bk",
    [
        (2, 128, 128, 4, 2, 16, True, 0, 32, 64),
        (1, 256, 256, 4, 4, 32, True, 64, 64, 64),
        (2, 64, 128, 2, 1, 16, True, 0, 32, 32),
        (1, 64, 64, 8, 8, 64, False, 0, 64, 64),
        (1, 512, 512, 2, 2, 16, True, 128, 128, 128),
    ],
)
def test_flash_attention_sweep(b, sq, sk, h, kv, hd, causal, window, bq, bk, dtype):
    q, k, v = _mk_qkv(b, sq, sk, h, kv, hd, dtype)
    out = ops.flash_attention(q, k, v, causal, window, bq, bk)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_grads_match_reference():
    q, k, v = _mk_qkv(2, 128, 128, 4, 2, 16, jnp.float32)

    def loss_k(fn, *args):
        return (fn(*args) ** 2).sum()

    g1 = jax.grad(lambda q, k, v: loss_k(
        lambda *a: ops.flash_attention(*a, True, 32, 32, 64), q, k, v
    ), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: loss_k(
        lambda *a: ref.attention_ref(*a, causal=True, window=32), q, k, v
    ), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


@pytest.mark.parametrize("ba,s,di,ds,chunk", [
    (2, 64, 128, 8, 16),
    (1, 128, 512, 16, 64),
    (3, 32, 256, 4, 32),
])
def test_selective_scan_sweep(ba, s, di, ds, chunk):
    x = jnp.asarray(RNG.normal(size=(ba, s, di)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (ba, s, di)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (di, ds)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(ba, s, ds)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(ba, s, ds)), jnp.float32)
    y, sf = ops.selective_scan(x, dt, A, B, C, chunk)
    y2, sf2 = ref.selective_scan_ref(x, dt, A, B, C, chunk=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sf2), atol=1e-4, rtol=1e-4)


def test_selective_scan_matches_sequential():
    """The chunked oracle itself must equal a naive per-step recurrence."""
    ba, s, di, ds = 1, 16, 8, 4
    x = jnp.asarray(RNG.normal(size=(ba, s, di)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (ba, s, di)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (di, ds)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(ba, s, ds)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(ba, s, ds)), jnp.float32)
    y, sf = ref.selective_scan_ref(x, dt, A, B, C, chunk=4)
    st = jnp.zeros((ba, di, ds))
    ys = []
    for t in range(s):
        yt, st = ref.selective_scan_step_ref(st, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(st), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("e,n,block", [(1, 64, 32), (4, 100, 64), (2, 672, 512)])
def test_node_power_sweep(e, n, block):
    cpu = jnp.asarray(RNG.uniform(0, 1, (e, n)), jnp.float32)
    gpu = jnp.asarray(RNG.uniform(0, 1, (e, n)), jnp.float32)
    up = jnp.asarray(RNG.integers(0, 2, (e, n)), jnp.float32)
    idle = jnp.asarray(RNG.uniform(80, 300, (n,)), jnp.float32)
    cd = jnp.asarray(RNG.uniform(100, 400, (n,)), jnp.float32)
    gd = jnp.asarray(RNG.uniform(0, 600, (n,)), jnp.float32)
    mx = idle + cd + gd
    kw = dict(rect_peak=0.965, rect_load=0.55, rect_curv=0.12, conv_eff=0.975)
    from repro.kernels.node_power import node_power_pallas

    it, inp = node_power_pallas(cpu, gpu, idle, cd, gd, up, mx,
                                block_n=block, **kw)
    it2, inp2 = ref.node_power_ref(cpu, gpu, idle, cd, gd, up, mx, **kw)
    np.testing.assert_allclose(np.asarray(it), np.asarray(it2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(inp), np.asarray(inp2), rtol=1e-5)
