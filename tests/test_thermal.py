"""Thermal-twin differential-oracle suite (docs/thermal.md).

Three implementations of the rack RC cooling loop are pinned against each
other:

- a pure-NumPy float64 oracle (`_np_thermal_oracle`) built from an
  INDEPENDENT formulation (np.add.at segment-sum scatter, not the one-hot
  contraction) — compared at documented float32-accumulation tolerance;
- the eager jnp reference (`kernels.ref.rack_thermal_ref`);
- the fused Pallas kernel (`kernels.rack_thermal`) — compared against the
  reference BITWISE on CPU (both share the one-hot-matmul reduction, so
  interpret-mode Pallas executes the identical float program).

On top of the kernel-level harness: macro-vs-per-tick bit-identity with
the cooling loop enabled (the tentpole guarantee — thermal breakpoints
extend the event-horizon engine without breaking exactness), the
steady-state envelope / crossing-horizon / cooling-energy /
throttle-monotonicity invariants as property tests, and the PUE
zero-IT-load pin.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from _hypothesis_compat import given, settings, st

from repro.configs.sim import tiny_cluster
from repro.core import (
    build_statics,
    init_state,
    load_jobs,
    make_step,
    run_episode,
    summary,
)
from repro.core import thermal as thm
from repro.core.power import compute_power
from repro.data import synth_workload
from repro.kernels import ops as kops
from repro.kernels.ref import rack_thermal_ref

# a config whose racks genuinely ride the throttle ramp AND cross the
# dispatch trip inside a short episode (verified: peak outlet ~24 C)
_STRESS = dict(thermal_enabled=True, rack_tau_s=120.0, thermal_trip_c=22.0,
               throttle_start_c=20.0, throttle_full_c=30.0)


def _stress_setup(seed=8, n_jobs=24):
    cfg = tiny_cluster(**_STRESS)
    jobs, bank = synth_workload(cfg, n_jobs, 600.0, seed=seed)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    return cfg, statics, state


# ------------------------------------------------------- numpy oracle
def _np_thermal_oracle(heat_seq, node_rack, t0, supply_seq, r_th, alpha):
    """Independent float64 reference: per-tick np.add.at scatter of node
    heat onto racks, then the explicit RC relaxation. Returns the (K, R)
    outlet-temperature trajectory."""
    T = np.asarray(t0, np.float64).copy()
    r_th = np.asarray(r_th, np.float64)
    out = []
    for heat, sup in zip(heat_seq, supply_seq):
        rack_heat = np.zeros(T.shape[0], np.float64)
        np.add.at(rack_heat, np.asarray(node_rack), np.asarray(heat, np.float64))
        T = T + alpha * (sup + rack_heat * r_th - T)
        out.append(T.copy())
    return np.stack(out)


def _rand_case(rng, n, r):
    heat = (rng.random(n, dtype=np.float32) * 800.0).astype(np.float32)
    rack = (rng.integers(0, r, n)).astype(np.int32)
    t0 = (18.0 + rng.random(r) * 10.0).astype(np.float32)
    r_th = (rng.random(r) * 1e-3 + 1e-4).astype(np.float32)
    return heat, rack, t0, r_th


@pytest.mark.parametrize("n,r", [(16, 1), (100, 7), (512, 16), (672, 21)])
def test_rack_thermal_kernel_bitwise_vs_ref(n, r):
    """Pallas kernel vs eager reference: BITWISE on CPU — same one-hot
    contraction, same RC arithmetic, interpret-mode Pallas runs the
    identical float program (padding lanes must be exactly inert)."""
    rng = np.random.default_rng(n * 31 + r)
    heat, rack, t0, r_th = _rand_case(rng, n, r)
    sup = jnp.float32(16.5)
    alpha = 0.117
    ref_t, ref_h = jax.jit(
        lambda h, t: rack_thermal_ref(h, rack, t, sup, r_th, alpha=alpha)
    )(heat, t0)
    ker_t, ker_h = jax.jit(
        lambda h, t: kops.rack_thermal(h, rack, t, sup, r_th, alpha=alpha)
    )(heat, t0)
    np.testing.assert_array_equal(np.asarray(ref_t), np.asarray(ker_t))
    np.testing.assert_array_equal(np.asarray(ref_h), np.asarray(ker_h))


@pytest.mark.parametrize("n,r,ticks", [(64, 4, 200), (256, 8, 120)])
def test_numpy_oracle_pins_scanned_paths(n, r, ticks):
    """The float64 NumPy oracle pins BOTH jitted scan paths (reference and
    Pallas) over a long trajectory. Tolerance (not bitwise) is the
    documented bound: the oracle sums in a different order and in float64;
    the RC update is a contraction so float32 drift stays ~1e-5 relative.
    The two jnp paths must still agree with EACH OTHER bitwise."""
    rng = np.random.default_rng(7 * n + ticks)
    _, rack, t0, r_th = _rand_case(rng, n, r)
    heat_seq = (rng.random((ticks, n), dtype=np.float32) * 600.0)
    supply_seq = (16.0 + 4.0 * np.sin(np.arange(ticks) / 30.0)).astype(np.float32)
    alpha = 0.035

    def scan_with(fn):
        def body(T, inp):
            h, s = inp
            T, _ = fn(h, rack, T, s, r_th, alpha=alpha)
            return T, T
        _, traj = jax.lax.scan(body, jnp.asarray(t0),
                               (jnp.asarray(heat_seq), jnp.asarray(supply_seq)))
        return traj

    traj_ref = np.asarray(jax.jit(lambda: scan_with(rack_thermal_ref))())
    traj_ker = np.asarray(jax.jit(lambda: scan_with(kops.rack_thermal))())
    np.testing.assert_array_equal(np.asarray(traj_ref), np.asarray(traj_ker))

    traj_np = _np_thermal_oracle(heat_seq, rack, t0, supply_seq, r_th, alpha)
    np.testing.assert_allclose(np.asarray(traj_ref), traj_np,
                               rtol=1e-5, atol=1e-4)


def test_sim_tail_matches_kernel_tail():
    """make_step(use_thermal_kernel=True) must track the reference-tail
    episode within float tolerance: the kernel is a drop-in inside the
    SAME tail, but inside the fused step XLA is free to reassociate the
    reference one-hot dot with its neighbors, so episode-level equality is
    the documented ~1e-5 bound (the standalone kernel-vs-ref comparison
    above stays bitwise)."""
    cfg, statics, state = _stress_setup()
    step_r = make_step(cfg, statics, "fcfs")
    step_k = make_step(cfg, statics, "fcfs", use_thermal_kernel=True)

    def run(step, s):
        def body(s, _):
            s, out = step(s, jnp.int32(-1))
            return s, out.rack_max_c
        return jax.lax.scan(body, s, None, length=300)

    fs_r, tr_r = jax.jit(lambda s: run(step_r, s))(state)
    fs_k, tr_k = jax.jit(lambda s: run(step_k, s))(state)
    np.testing.assert_allclose(np.asarray(tr_r), np.asarray(tr_k),
                               rtol=1e-5, atol=1e-5)
    for f in fs_r._fields:
        a, b = getattr(fs_r, f), getattr(fs_k, f)
        if f == "key":
            a, b = jax.random.key_data(a), jax.random.key_data(b)
        a, b = np.asarray(a), np.asarray(b)
        if np.issubdtype(a.dtype, np.floating):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5,
                                       err_msg=f"field {f}")
        else:
            np.testing.assert_array_equal(a, b, err_msg=f"field {f}")


# ------------------------------------------- macro-stepping exactness
@pytest.mark.parametrize("scheduler", ["fcfs", "easy"])
def test_macro_bit_identical_with_thermals(scheduler):
    """The tentpole acceptance bar: with the cooling loop ON (racks
    crossing the dispatch trip mid-episode), macro=True matches per-tick
    stepping bit-for-bit — state, accumulators, rack temps, PRNG stream."""
    cfg, statics, state = _stress_setup()
    fs, tel = jax.jit(lambda s: run_episode(
        cfg, statics, s, 1500, scheduler, summary_only=True))(state)
    fs2, tel2 = jax.jit(lambda s: run_episode(
        cfg, statics, s, 1500, scheduler, macro=True))(state)
    # the episode genuinely crossed the trip threshold
    assert float(fs.peak_rack_c) >= cfg.thermal_trip_c
    assert float(fs.thermal_throttle_s) > 0.0
    for f in fs._fields:
        a, b = getattr(fs, f), getattr(fs2, f)
        if f == "key":
            a, b = jax.random.key_data(a), jax.random.key_data(b)
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"state field {f} diverged under macro with thermals")
    for f in tel._fields:
        if f == "macro_steps":
            continue
        np.testing.assert_allclose(
            np.asarray(getattr(tel, f)), np.asarray(getattr(tel2, f)),
            rtol=1e-6, atol=1e-9, err_msg=f"telemetry {f}")
    # the engine still fast-forwards despite the extra breakpoint type
    assert float(tel2.macro_steps) < 1500


def test_thermal_telemetry_surfaces():
    cfg, statics, state = _stress_setup()
    fs, outs = jax.jit(lambda s: run_episode(
        cfg, statics, s, 600, "fcfs"))(state)
    # peak tracker == max over the per-tick telemetry
    np.testing.assert_allclose(float(fs.peak_rack_c),
                               float(jnp.max(outs.rack_max_c)), rtol=1e-6)
    _, tel = jax.jit(lambda s: run_episode(
        cfg, statics, s, 600, "fcfs", summary_only=True))(state)
    s = summary(fs, tel)
    assert s["peak_rack_outlet_c"] >= cfg.cooling_supply_min_c
    assert s["thermal_throttle_s"] >= 0.0
    assert s["mean_cop"] >= cfg.cop_min


# ----------------------------------------------------- property tests
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 400))
def test_throttle_monotone_in_temperature(seed):
    """rack_throttle is monotone non-increasing in outlet temperature and
    bounded in [thermal_throttle_floor, 1]."""
    cfg = tiny_cluster(**_STRESS)
    rng = np.random.default_rng(seed)
    t1 = (10.0 + rng.random(16) * 60.0).astype(np.float32)
    t2 = t1 + (rng.random(16) * 20.0).astype(np.float32)   # t2 >= t1
    th1 = np.asarray(thm.rack_throttle(cfg, jnp.asarray(t1)))
    th2 = np.asarray(thm.rack_throttle(cfg, jnp.asarray(t2)))
    assert (th2 <= th1 + 1e-7).all()
    for th in (th1, th2):
        assert (th >= cfg.thermal_throttle_floor - 1e-7).all()
        assert (th <= 1.0 + 1e-7).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 5))
def test_temps_bounded_by_steady_state_envelope(seed):
    """Every rack temperature stays inside the box spanned by its initial
    value and the extreme steady states (wetbulb bounds x zero-to-max
    heat) — the contraction property thermal_crossing_horizon builds on."""
    from repro.scenarios.signals import signal_bounds

    cfg = tiny_cluster(**_STRESS)
    jobs, bank = synth_workload(cfg, 24, 600.0, seed=seed)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(seed)), jobs)
    t0 = np.asarray(state.rack_outlet_c)
    fs, outs = jax.jit(lambda s: run_episode(
        cfg, statics, s, 800, "fcfs"))(state)
    wb_lo, wb_hi = signal_bounds(statics.scenario.wetbulb)
    sup_lo = float(thm.supply_temp(cfg, wb_lo))
    sup_hi = float(thm.supply_temp(cfg, wb_hi))
    heat_hi = np.asarray(statics.rack_cap_w) * 1.2 / (0.5 * cfg.conv_eff)
    ss_hi = sup_hi + heat_hi * np.asarray(statics.rack_r_th)
    lo = min(sup_lo, float(t0.min())) - 1e-3
    hi = max(float(ss_hi.max()), float(t0.max())) + 1e-3
    assert lo <= float(jnp.min(fs.rack_outlet_c))
    assert float(fs.peak_rack_c) <= hi
    assert float(jnp.max(outs.rack_max_c)) <= hi


def _check_crossing_horizon(seed, warm):
    """Property: within thermal_crossing_horizon ticks, NO rack crosses
    the dispatch trip threshold in either direction — macro-stepping may
    fast-forward that far without changing dispatch eligibility."""
    cfg, statics, state = _stress_setup(seed=seed)
    step = make_step(cfg, statics, "fcfs")
    if warm:
        def wbody(s, _):
            s, _o = step(s, jnp.int32(-1))
            return s, None
        state, _ = jax.lax.scan(wbody, state, None, length=warm)
    k = int(thm.thermal_crossing_horizon(cfg, statics, state, 256))
    assert 0 <= k <= 256
    if k == 0:
        return
    hot0 = np.asarray(state.rack_outlet_c) >= cfg.thermal_trip_c

    def body(s, _):
        s, _o = step(s, jnp.int32(-1))
        changed = jnp.any(
            (s.rack_outlet_c >= cfg.thermal_trip_c) != jnp.asarray(hot0))
        return s, changed
    _, changed = jax.jit(lambda s: jax.lax.scan(
        body, s, None, length=k))(state)
    assert not bool(np.asarray(changed).any()), (
        f"trip crossing inside predicted horizon k={k} "
        f"(seed={seed}, warm={warm})")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 4), warm=st.integers(0, 600))
def test_crossing_horizon_never_overshoots(seed, warm):
    _check_crossing_horizon(seed, warm)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 3))
def test_cooling_energy_conservation(seed):
    """The integrated cooling accumulator equals the per-tick cooling
    power implied by (facility_w, cop): cooling = facility / (1 + cop)
    holds exactly through the cap throttle (both scale by r)."""
    cfg = tiny_cluster(**_STRESS)
    jobs, bank = synth_workload(cfg, 24, 600.0, seed=seed)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    fs, outs = jax.jit(lambda s: run_episode(
        cfg, statics, s, 700, "fcfs"))(state)
    cool_w = np.asarray(outs.facility_w) / (1.0 + np.asarray(outs.cop))
    kwh = float(np.sum(cool_w) * cfg.dt / 3600.0 / 1000.0)
    np.testing.assert_allclose(float(fs.cool_energy_kwh), kwh, rtol=1e-4)
    # and the energy ledger still closes: facility = it + losses + cooling
    total = (float(fs.it_energy_kwh) + float(fs.loss_energy_kwh)
             + float(fs.cool_energy_kwh))
    np.testing.assert_allclose(float(fs.energy_kwh), total, rtol=1e-4)


# ------------------------------------------------------------ PUE edge
def test_pue_defined_at_zero_it_load():
    """compute_power at zero IT load (every node down): PUE reports the
    1.0 ideal instead of facility/1W garbage (the old max(it,1) edge)."""
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 4, 300.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    state = state._replace(node_up=jnp.zeros_like(state.node_up))
    p = jax.jit(lambda s: compute_power(cfg, s, statics))(state)
    assert float(p.it_w) == 0.0
    assert float(p.pue) == 1.0
    # and an episode from that state keeps PUE finite and >= 1 everywhere
    _, outs = jax.jit(lambda s: run_episode(cfg, statics, s, 50, "none"))(state)
    pue = np.asarray(outs.pue)
    assert np.isfinite(pue).all() and (pue >= 1.0 - 1e-6).all()
