"""Macro-stepping engine equivalence suite.

The contract (docs/performance.md "Macro-stepping"): a macro episode is
the per-tick episode with quiet ticks fast-forwarded —

- job/queue state (jstate, placement, free pool, times, counters, PRNG
  stream) is EXACT: on dense-scatter-budget configs every accumulator is
  bit-identical too, because fast ticks run the same compiled power chain
  and the same accounting tail;
- on large configs (chunked count-matrix power path) and for telemetry
  reductions whose fusion context differs between the two compiled
  programs (net_load's cross-job sum), energy/cost/carbon accounting is
  pinned within float-accumulation tolerance instead;
- the predicted ``quiet_horizon`` never overshoots the next event.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.configs.sim import tiny_cluster, tx_gaia
from repro.core import (
    build_statics,
    init_state,
    load_jobs,
    make_step,
    quiet_horizon,
    run_episode,
    run_fleet,
    summary,
)
from repro.core.placement import PLACEMENTS, make_policy
from repro.core.schedulers import SCHEDULERS, queued_mask
from repro.data import synth_workload
from repro.envs import SchedEnv
from repro.scenarios import demand_response

# SimState accumulator leaves that integrate power/price/carbon terms —
# the documented-tolerance set on non-shared power paths
_ACCUM = ("energy_kwh", "it_energy_kwh", "loss_energy_kwh",
          "cool_energy_kwh", "carbon_kg", "elec_cost_usd",
          "flops_integral", "sum_power_w")


def _run_both(cfg, statics, state, n_steps, scheduler, **kw):
    fs, tel = jax.jit(lambda s: run_episode(
        cfg, statics, s, n_steps, scheduler, summary_only=True, **kw))(state)
    fs2, tel2 = jax.jit(lambda s: run_episode(
        cfg, statics, s, n_steps, scheduler, macro=True, **kw))(state)
    return fs, tel, fs2, tel2


def _assert_equiv(fs, tel, fs2, tel2, *, exact_accum=True):
    for f in fs._fields:
        a, b = getattr(fs, f), getattr(fs2, f)
        if f == "key":
            a, b = jax.random.key_data(a), jax.random.key_data(b)
        if not exact_accum and f in _ACCUM:
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6,
                err_msg=f"accumulator {f} beyond float tolerance")
            continue
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"job/queue state field {f} diverged under macro")
    for f in tel._fields:
        if f == "macro_steps":     # differs BY DESIGN (the skip accounting)
            continue
        np.testing.assert_allclose(
            np.asarray(getattr(tel, f)), np.asarray(getattr(tel2, f)),
            rtol=1e-6, atol=1e-9,
            err_msg=f"telemetry {f} beyond float tolerance")


def test_macro_actually_skips():
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 16, 900.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    _, _, fs2, tel2 = _run_both(cfg, statics, state, 900, "fcfs")
    assert float(tel2.n_steps) == 900
    # the engine must have fast-forwarded most of the episode, and the
    # skip accounting must surface through summary()
    assert float(tel2.macro_steps) < 0.25 * 900
    s = summary(fs2, tel2)
    assert s["ticks_simulated"] == 900
    assert s["macro_skip_ratio"] > 4.0


def test_macro_bitwise_fcfs_small():
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 32, 900.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    _assert_equiv(*_run_both(cfg, statics, state, 900, "fcfs"))


def test_macro_tx_gaia_replay_slice():
    """(a) TX-GAIA replay slice — the non-shared (chunked gemm) power
    path: job/queue state exact, accumulators within tolerance."""
    cfg = tx_gaia(max_jobs=64, max_nodes_per_job=4)
    jobs, bank = synth_workload(cfg, 30, 600.0, seed=5)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    fs, tel, fs2, tel2 = _run_both(cfg, statics, state, 600, "replay")
    _assert_equiv(fs, tel, fs2, tel2, exact_accum=False)
    assert float(fs2.n_completed) > 0          # the slice must do real work
    assert float(tel2.macro_steps) < float(tel2.n_steps)


def test_macro_dr_cap_crossing_breakpoints():
    """(b) a CapSchedule DR event inside the episode: fast-forwarded
    segments stop at both breakpoints and the throttle accounting stays
    bit-identical (shared power path)."""
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 32, 900.0, seed=1)
    scn = demand_response(cfg, cap_w=4000.0, event_start_s=200.0,
                          event_len_s=300.0)
    statics = build_statics(cfg, bank, scenario=scn)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    fs, tel, fs2, tel2 = _run_both(cfg, statics, state, 900, "fcfs")
    _assert_equiv(fs, tel, fs2, tel2)
    # the episode genuinely crossed the cap window (throttle engaged)
    assert float(tel.mean_throttle) < 1.0


def test_macro_with_failures():
    """(c) stochastic failures: fault clocks are event-sampled
    (exponential next-failure/next-repair times drawn at commit points),
    so crossings are exact breakpoints in the quiet horizon — the PRNG
    stream, kill counts and requeues are bit-identical AND the engine
    still fast-forwards between faults (the per-tick Bernoulli engine
    forced macro back to tick-by-tick whenever MTBF was finite)."""
    cfg = tiny_cluster(node_mtbf_hours=0.3)
    jobs, bank = synth_workload(cfg, 32, 900.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    fs, tel, fs2, tel2 = _run_both(cfg, statics, state, 900, "fcfs")
    _assert_equiv(fs, tel, fs2, tel2)
    assert float(fs.n_killed) > 0              # failures actually fired
    # faults on no longer disables fast-forwarding
    assert float(tel2.macro_steps) < 0.5 * 900


def test_macro_policy_grid_equivalence():
    """(d) every selection x placement combo through the policy-as-data
    path (two compiled executables total: per-tick + macro)."""
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 40, 600.0, seed=3)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    run_p = jax.jit(lambda s, pol: run_episode(
        cfg, statics, s, 400, pol, summary_only=True))
    run_m = jax.jit(lambda s, pol: run_episode(
        cfg, statics, s, 400, pol, macro=True))
    for sel in SCHEDULERS:
        for pl in PLACEMENTS:
            pol = make_policy(sel, pl)
            fs, tel = run_p(state, pol)
            fs2, tel2 = run_m(state, pol)
            try:
                _assert_equiv(fs, tel, fs2, tel2)
            except AssertionError as e:
                raise AssertionError(f"policy ({sel}, {pl}): {e}") from e


def test_macro_telemetry_windows_tick_aligned():
    """telemetry_every windows clamp the horizon, so windowed summaries
    match the per-tick ones window by window."""
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 24, 900.0, seed=4)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    fs, wins = jax.jit(lambda s: run_episode(
        cfg, statics, s, 900, "fcfs", telemetry_every=90))(state)
    fs2, wins2 = jax.jit(lambda s: run_episode(
        cfg, statics, s, 900, "fcfs", telemetry_every=90, macro=True))(state)
    assert np.shape(wins2.n_steps) == (10,)
    np.testing.assert_array_equal(np.asarray(wins2.n_steps),
                                  np.full(10, 90.0))
    for f in wins._fields:
        if f == "macro_steps":
            continue
        np.testing.assert_allclose(
            np.asarray(getattr(wins, f)), np.asarray(getattr(wins2, f)),
            rtol=1e-6, atol=1e-9, err_msg=f"window telemetry {f}")
    for f in fs._fields:
        a, b = getattr(fs, f), getattr(fs2, f)
        if f == "key":
            a, b = jax.random.key_data(a), jax.random.key_data(b)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_summary_accepts_windowed_telemetry():
    """summary(state, telemetry) must also digest the windowed
    (leading-window-axis) TelemetrySummary of telemetry_every runs —
    summing windows recovers the episode skip accounting."""
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 16, 600.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    fs, wins = jax.jit(lambda s: run_episode(
        cfg, statics, s, 600, "fcfs", telemetry_every=200, macro=True))(state)
    s = summary(fs, wins)
    assert s["ticks_simulated"] == 600
    assert s["macro_steps_taken"] == float(np.sum(np.asarray(wins.macro_steps)))
    assert s["macro_skip_ratio"] > 1.0


def test_macro_fleet_threads_through_run_fleet():
    from repro.scenarios import sample_scenarios

    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 24, 600.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    scns = sample_scenarios(cfg, 4, seed=1)
    fs, _ = run_fleet(cfg, statics, state, 300, "fcfs", scenarios=scns,
                      summary_only=True)
    fs2, tel2 = run_fleet(cfg, statics, state, 300, "fcfs", scenarios=scns,
                          summary_only=True, macro=True)
    for f in fs._fields:
        a, b = getattr(fs, f), getattr(fs2, f)
        if f == "key":
            a, b = jax.random.key_data(a), jax.random.key_data(b)
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"fleet field {f} diverged under macro")
    # every replica fast-forwards independently
    assert (np.asarray(tel2.macro_steps) < 300).all()


def test_macro_rejects_stacked_stepout_silently_summarizes():
    """macro=True cannot stack per-step StepOut; it returns the
    episode-wide summary instead (documented) and still errors loudly on
    the conflicting summary_only+telemetry_every combination."""
    from repro.core.sim import TelemetrySummary

    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 8, 300.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    _, out = jax.jit(lambda s: run_episode(
        cfg, statics, s, 50, "fcfs", macro=True))(state)
    assert isinstance(out, TelemetrySummary)
    with pytest.raises(ValueError):
        run_episode(cfg, statics, state, 50, "fcfs", macro=True,
                    summary_only=True, telemetry_every=10)


def test_sched_env_macro_matches_scanned_idle_path():
    """The env's macro idle advance is bit-equivalent to the scanned
    per-tick idle sub-steps (rewards, infos, obs, final sim state)."""
    cfg = tiny_cluster(sched_max_candidates=4)
    wls = [synth_workload(cfg, 24, 900.0, seed=s) for s in range(2)]
    env_m = SchedEnv(cfg, wls, episode_steps=8, sim_steps_per_action=7,
                     macro=True)
    env_s = SchedEnv(cfg, wls, episode_steps=8, sim_steps_per_action=7,
                     macro=False)
    st_m, obs_m = env_m.reset(jax.random.key(3))
    st_s, obs_s = env_s.reset(jax.random.key(3))
    np.testing.assert_array_equal(np.asarray(obs_m), np.asarray(obs_s))
    step_m, step_s = jax.jit(env_m.step), jax.jit(env_s.step)
    for a in (0, 2, 4, 1, 0, 3):
        st_m, obs_m, r_m, d_m, info_m = step_m(st_m, jnp.int32(a))
        st_s, obs_s, r_s, d_s, info_s = step_s(st_s, jnp.int32(a))
        np.testing.assert_array_equal(np.asarray(r_m), np.asarray(r_s))
        np.testing.assert_array_equal(np.asarray(obs_m), np.asarray(obs_s))
        for k in info_m:
            np.testing.assert_array_equal(
                np.asarray(info_m[k]), np.asarray(info_s[k]),
                err_msg=f"info[{k}]")
    for f in st_m.sim._fields:
        a, b = getattr(st_m.sim, f), getattr(st_s.sim, f)
        if f == "key":
            a, b = jax.random.key_data(a), jax.random.key_data(b)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"sim.{f}")


# --------------------------------------------------------------------------
def _quiet_probe_state(seed, warm_ticks):
    """Advance a fresh episode per-tick to a (likely mid-segment) state."""
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 24, 900.0, seed=seed)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(seed)), jobs)
    step = make_step(cfg, statics, "fcfs")
    if warm_ticks:
        def body(s, _):
            s, _out = step(s, jnp.int32(-1))
            return s, None
        state, _ = jax.lax.scan(body, state, None, length=warm_ticks)
    return cfg, statics, state, step


def _machine_signature(state):
    """Everything that must stay frozen across quiet ticks."""
    return jax.device_get((state.jstate, state.placement, state.free,
                           state.node_up, state.n_completed, state.n_killed,
                           jnp.sum(queued_mask(state))))


def _check_horizon_never_overshoots(seed, warm):
    """Property: advancing the predicted horizon per-tick changes NO
    machine state — arrivals, dispatches, completions, failures and
    repairs all lie strictly beyond it (and after k-1 ticks the state is
    still quiet: its 1-tick horizon check passes again by induction)."""
    cfg, statics, state, step = _quiet_probe_state(seed, warm)
    k = int(quiet_horizon(cfg, statics, state, "fcfs", max_ticks=256))
    if k == 0:
        return
    before = _machine_signature(state)

    def body(s, _):
        s, _out = step(s, jnp.int32(-1))
        return s, None
    advanced, _ = jax.lax.scan(body, state, None, length=k)
    after = _machine_signature(advanced)
    for x, y, name in zip(before, after,
                          ("jstate", "placement", "free", "node_up",
                           "n_completed", "n_killed", "queued")):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{name} changed within quiet_horizon={k} "
                    f"(seed={seed}, warm={warm})")


if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 7), warm=st.integers(0, 220))
    def test_quiet_horizon_never_overshoots(seed, warm):
        _check_horizon_never_overshoots(seed, warm)
else:
    # without hypothesis, still exercise the property on a fixed spread of
    # (workload seed, warm-up depth) pairs instead of skipping
    @pytest.mark.parametrize(
        "seed,warm",
        [(0, 0), (1, 50), (2, 120), (3, 220), (4, 33), (5, 77),
         (6, 150), (7, 201)])
    def test_quiet_horizon_never_overshoots(seed, warm):
        _check_horizon_never_overshoots(seed, warm)


def test_macro_full_resilience_stack():
    """(c') the whole resilience twin at once — node + rack fault clocks,
    a scheduled maintenance window downing a rack, a brownout forcing the
    degradation ladder, checkpoint/restart with write overhead and retry
    budgets: per-tick and macro stay bit-identical (state AND PRNG
    stream) and the engine still skips quiet stretches."""
    from repro.scenarios import resilience_drill

    cfg = tiny_cluster(node_mtbf_hours=0.5, node_repair_hours=0.2,
                       rack_mtbf_hours=1.5, rack_repair_hours=0.3,
                       ckpt_interval_s=240.0, ckpt_overhead_s=20.0,
                       max_job_retries=2, requeue_backoff_s=60.0,
                       outages_enabled=True, degrade_enabled=True)
    scn = resilience_drill(cfg, maint_rack=0, maint_start_s=500.0,
                           maint_len_s=400.0, brownout_start_s=1400.0,
                           brownout_len_s=300.0, brownout_level=2)
    jobs, bank = synth_workload(cfg, 32, 1500.0, seed=11)
    statics = build_statics(cfg, bank, scenario=scn)
    state = load_jobs(init_state(cfg, statics, jax.random.key(2)), jobs)
    fs, tel, fs2, tel2 = _run_both(cfg, statics, state, 2000, "fcfs")
    _assert_equiv(fs, tel, fs2, tel2)
    assert float(fs.n_killed) > 0
    assert float(fs.lost_node_s) > 0
    assert float(tel2.macro_steps) < 0.5 * 2000
    s = summary(fs2, tel2)
    assert s["goodput_frac"] < 1.0 and s["lost_node_seconds"] > 0


def test_macro_full_serving_stack():
    """(e) the whole serving twin at once — diurnal traffic with a burst
    window, admission control, load shedding, timeout/backoff retries
    with terminal drops, and an autoscale wake in flight from t=0:
    per-tick and macro stay bit-identical (every SimState field incl.
    the PRNG stream, and all telemetry) and the engine still skips the
    quiet trough stretches."""
    from repro.scenarios import diurnal_serving

    cfg = tiny_cluster(serving_enabled=True, serving_nodes=4,
                       serving_concurrency=4.0, serving_service_s=3.0,
                       serving_queue_cap=60.0, serving_timeout_s=20.0,
                       serving_slo_s=6.0, serving_wake_s=90.0,
                       serving_max_retries=2, serving_backoff_s=5.0)
    scn = diurnal_serving(cfg, peak_rps=8.0, base_frac=0.05,
                          period_s=1800.0, burst_start_s=600.0,
                          burst_len_s=200.0, burst_mult=4.0)
    jobs, bank = synth_workload(cfg, 24, 900.0, seed=7)
    statics = build_statics(cfg, bank, scenario=scn)
    state = load_jobs(init_state(cfg, statics, jax.random.key(1)), jobs)
    # start the pool half-asleep with target = full pool: apply_serving
    # opens a wake batch on tick 0, so the wake-completion breakpoint is
    # genuinely exercised
    state = state._replace(srv_active=jnp.float32(2.0))
    fs, tel, fs2, tel2 = _run_both(cfg, statics, state, 1800, "fcfs")
    _assert_equiv(fs, tel, fs2, tel2)
    # every rung of the overload ladder actually fired
    assert float(fs.srv_shed) > 0
    assert float(fs.srv_retried) > 0
    assert float(fs.srv_dropped) > 0
    assert float(fs.srv_completed) > 0
    assert float(fs.srv_active) == cfg.serving_nodes     # wake completed
    assert float(tel2.macro_steps) < 0.85 * 1800         # still skips


def test_quiet_horizon_visible_queue_blocks():
    """A dispatch-visible queued job pins the conservative horizon to 0
    unless the caller proves the queue unservable."""
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 8, 100.0, seed=0)
    jobs["submit_t"][:] = 0.0
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    state = state._replace(t=jnp.float32(1.0))
    assert int(quiet_horizon(cfg, statics, state, "fcfs")) == 0
    assert int(quiet_horizon(cfg, statics, state, "fcfs",
                             assume_undispatchable=True)) > 0
    # the no-dispatch mode never blocks on queue visibility
    assert int(quiet_horizon(cfg, statics, state, "none")) > 0


# --------------------------------------------------------------------------
def test_bench_compare_tool(tmp_path, capsys):
    """run.py --compare: per-row speedup table, non-zero exit only on
    >20% regressions."""
    import json

    from benchmarks.run import compare_artifacts, main

    a = {"rows": [{"name": "x", "us_per_call": 100.0, "derived": ""},
                  {"name": "y", "us_per_call": 50.0, "derived": ""},
                  {"name": "gone", "us_per_call": 10.0, "derived": ""}]}
    b = {"rows": [{"name": "x", "us_per_call": 90.0, "derived": ""},
                  {"name": "y", "us_per_call": 49.0, "derived": ""},
                  {"name": "new", "us_per_call": float("nan"),
                   "derived": "FAILED"}]}
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    pa.write_text(json.dumps(a))
    pb.write_text(json.dumps(b))
    assert compare_artifacts(str(pa), str(pb)) == 0
    main(["--compare", str(pa), str(pb)])       # no SystemExit: no regression
    capsys.readouterr()

    b["rows"][0]["us_per_call"] = 121.0         # x regresses >20%
    pb.write_text(json.dumps(b))
    assert compare_artifacts(str(pa), str(pb)) == 1
    with pytest.raises(SystemExit):
        main(["--compare", str(pa), str(pb)])
    out = capsys.readouterr().out
    assert "REGRESSION" in out
