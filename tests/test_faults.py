"""Resilience-twin suite (docs/resilience.md): a NumPy differential
oracle for the event-sampled fault engine's deterministic semantics
(who goes down, who gets killed, checkpoint-restart math, retry budgets,
backoff, lost-work accounting), plus the macro invariants the engine
promises (clocks strictly future, quiet ticks are RNG-free fixpoints,
no mid-window repair flaps) and seed determinism under vmap/run_fleet.

The RNG only decides the *redraw values* of fired clocks; everything
else is a pure function of the pre-tick state, so the oracle pins exact
equality on all job/node bookkeeping while checking redraws only for
the strictly-future property."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.sim import tiny_cluster
from repro.core import (
    FAILED,
    LVL_DRAIN,
    LVL_EVICT,
    LVL_GATE,
    LVL_NORMAL,
    LVL_THROTTLE,
    QUEUED,
    RUNNING,
    apply_faults,
    build_statics,
    effective_level,
    init_state,
    load_jobs,
    next_fault_event,
    run_episode,
    run_fleet,
    summary,
)
from repro.core import faults as flt
from repro.core.state import SimState
from repro.data import synth_workload
from repro.scenarios import (
    default_scenario,
    next_outage_event,
    outage_down,
    outage_events,
    outage_level_at,
    resilience_drill,
)

_RESIL = dict(node_mtbf_hours=0.5, node_repair_hours=0.1,
              rack_mtbf_hours=2.0, rack_repair_hours=0.2)


def _setup(seed=0, n_jobs=24, horizon=1200.0, scenario=None, **cfg_kw):
    cfg = tiny_cluster(**cfg_kw)
    jobs, bank = synth_workload(cfg, n_jobs, horizon, seed=seed)
    statics = build_statics(cfg, bank, scenario=scenario)
    state = load_jobs(init_state(cfg, statics, jax.random.key(seed)), jobs)
    return cfg, statics, state, jobs


def _run_until_running(cfg, statics, state, scheduler="fcfs", max_t=600):
    """Advance per-tick until at least one job is RUNNING."""
    from repro.core import make_step
    step = jax.jit(make_step(cfg, statics, scheduler))
    for _ in range(max_t):
        state, _ = step(state, jnp.int32(-1))
        if int(jnp.sum(state.jstate == RUNNING)) > 0:
            return state
    raise AssertionError("no job ever started")


# ------------------------------------------------------ differential oracle
def _oracle_kill(cfg, state, down_nodes):
    """NumPy model of apply_faults' job bookkeeping given the set of
    newly-downed nodes: returns expected (jstate, work_left, submit_t,
    lost_node_s_delta) — the deterministic core of the engine."""
    place = np.asarray(state.placement)
    jstate = np.asarray(state.jstate).copy()
    dur = np.asarray(state.dur_est)
    wl = np.asarray(state.work_left).copy()
    iv = np.asarray(state.ckpt_interval)
    sub = np.asarray(state.submit_t).copy()
    nfail = np.asarray(state.n_failures).copy()
    t = float(state.t)

    on_down = np.zeros(jstate.shape, bool)
    for j in range(jstate.shape[0]):
        if jstate[j] != RUNNING:
            continue
        nodes = place[j][place[j] >= 0]
        on_down[j] = np.isin(nodes, down_nodes).any()

    prog = np.maximum(dur - wl, 0.0)
    kept = np.where(iv > 0, np.floor(prog / np.maximum(iv, 1e-9)) * iv, 0.0)
    nfail_new = nfail + on_down.astype(np.int32)
    if cfg.max_job_retries > 0:
        exhausted = on_down & (nfail_new > cfg.max_job_retries)
    else:
        exhausted = np.zeros_like(on_down)
    wl = np.where(on_down, dur - kept, wl)
    jstate = np.where(exhausted, FAILED, np.where(on_down, QUEUED, jstate))
    if cfg.requeue_backoff_s > 0:
        backoff = cfg.requeue_backoff_s * (
            cfg.requeue_backoff_mult ** np.maximum(nfail_new - 1, 0))
        sub = np.where(on_down & ~exhausted, t + backoff, sub)
    lost = np.where(on_down, prog - kept, 0.0)
    lost = np.where(exhausted, prog, lost)
    lost_total = float(np.sum(lost * np.asarray(state.n_nodes, np.float64)))
    return on_down, jstate, wl, sub, nfail_new, exhausted, lost_total


def _fire_rack(cfg, statics, state, rack=0):
    """Arm the rack-0 clock to fire on the next apply_faults call."""
    return state._replace(
        rack_fail_t=state.rack_fail_t.at[rack].set(state.t),
        # keep node clocks quiet so the rack is the only cause
        next_fail_t=jnp.full_like(state.next_fail_t, jnp.inf),
    )


def test_rack_fault_downs_whole_rack_oracle():
    """A cooling-loop/PDU fault downs every node of the rack at once and
    kills exactly the jobs touching it — bookkeeping matches the NumPy
    oracle field by field."""
    cfg, statics, state, _ = _setup(
        **_RESIL, ckpt_interval_s=120.0, ckpt_overhead_s=10.0,
        max_job_retries=3, requeue_backoff_s=30.0)
    state = _run_until_running(cfg, statics, state)
    state = _fire_rack(cfg, statics, state, rack=0)

    rack_nodes = np.flatnonzero(np.asarray(statics.node_rack) == 0)
    was_up = np.asarray(state.node_up)[rack_nodes] > 0.5
    exp = _oracle_kill(cfg, state, rack_nodes[was_up])
    on_down, jstate, wl, sub, nfail, exhausted, lost_total = exp

    new, killed_now, lost_now = apply_faults(cfg, state, statics)
    # the whole rack is down
    assert (np.asarray(new.node_up)[rack_nodes] == 0.0).all()
    # job bookkeeping matches the oracle exactly
    np.testing.assert_array_equal(np.asarray(new.jstate), jstate)
    np.testing.assert_array_equal(np.asarray(new.work_left), wl)
    np.testing.assert_array_equal(np.asarray(new.submit_t), sub)
    np.testing.assert_array_equal(np.asarray(new.n_failures), nfail)
    assert float(killed_now) == float(on_down.sum())
    np.testing.assert_allclose(float(lost_now), lost_total, rtol=1e-5)
    # killed jobs rewound to the checkpoint grid, not to zero progress
    prog = np.maximum(np.asarray(state.dur_est) - np.asarray(state.work_left),
                      0.0)
    rewound = on_down & (prog >= 120.0)
    if rewound.any():
        assert (np.asarray(new.work_left)[rewound]
                < np.asarray(new.dur_est)[rewound]).all()
    # fired rack clock redrawn strictly future
    assert float(new.rack_fail_t[0]) > float(state.t)


def test_quiet_tick_is_rng_free_fixpoint():
    """With every clock in the future and no outage edge, apply_faults is
    a no-op INCLUDING the PRNG key — the property that makes quiet-tick
    fast-forwarding exact."""
    cfg, statics, state, _ = _setup(**_RESIL)
    state = state._replace(
        next_fail_t=jnp.full_like(state.next_fail_t, 1e9),
        rack_fail_t=jnp.full_like(state.rack_fail_t, 1e9))
    new, killed, lost = apply_faults(cfg, state, statics)
    assert float(killed) == 0.0 and float(lost) == 0.0
    for f in SimState._fields:
        a, b = getattr(state, f), getattr(new, f)
        if f == "key":
            a, b = jax.random.key_data(a), jax.random.key_data(b)
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"quiet tick mutated {f}")


def test_clocks_always_strictly_future():
    """After any apply_faults call every finite clock is strictly > t
    (absorbed fires included), so next_fault_event never hides a pending
    event from the macro horizon."""
    cfg, statics, state, _ = _setup(**_RESIL)
    # fire a node clock on an already-down node (absorbed fire)
    state = state._replace(
        node_up=state.node_up.at[0].set(0.0),
        repair_t=state.repair_t.at[0].set(float(state.t) + 500.0),
        next_fail_t=state.next_fail_t.at[0].set(state.t),
        rack_fail_t=state.rack_fail_t.at[0].set(state.t))
    new, _, _ = apply_faults(cfg, state, statics)
    assert (np.asarray(new.next_fail_t) > float(state.t)).all()
    assert (np.asarray(new.rack_fail_t) > float(state.t)).all()
    # node 0 stayed down (absorbed), and its standing repair survives
    assert float(new.node_up[0]) == 0.0
    assert float(new.repair_t[0]) >= float(state.t) + 500.0
    nxt = float(next_fault_event(cfg, new, statics, new.t))
    assert nxt > float(new.t)


def test_retry_budget_terminal_failed():
    """A job past its retry budget goes terminal FAILED: all progress
    lost, placement scrubbed, never requeued."""
    cfg, statics, state, _ = _setup(
        **_RESIL, max_job_retries=1, ckpt_interval_s=0.0)
    state = _run_until_running(cfg, statics, state)
    running = np.flatnonzero(np.asarray(state.jstate) == RUNNING)
    j = int(running[0])
    # already at the budget: next kill exhausts it
    state = state._replace(
        n_failures=state.n_failures.at[j].set(cfg.max_job_retries))
    node = int(np.asarray(state.placement)[j][0])
    state = state._replace(
        next_fail_t=jnp.full_like(state.next_fail_t, jnp.inf
                                  ).at[node].set(state.t),
        rack_fail_t=jnp.full_like(state.rack_fail_t, jnp.inf))
    new, _, lost_now = apply_faults(cfg, state, statics)
    assert int(new.jstate[j]) == FAILED
    assert (np.asarray(new.placement)[j] == -1).all()
    assert float(new.end_t[j]) == float(state.t)
    assert float(new.n_failed) == float(state.n_failed) + 1
    prog = float(state.dur_est[j] - state.work_left[j])
    nn = float(state.n_nodes[j])
    # terminal failures lose ALL progress (no checkpointing here)
    assert float(lost_now) >= prog * nn - 1e-3


def test_requeue_backoff_schedule():
    """Backoff grows geometrically with the kill count and reuses the
    arrival machinery (submit_t advances); with backoff disabled the
    legacy wait-stat baseline is untouched."""
    for backoff_s in (0.0, 45.0):
        cfg, statics, state, _ = _setup(
            **_RESIL, requeue_backoff_s=backoff_s, requeue_backoff_mult=3.0)
        state = _run_until_running(cfg, statics, state)
        j = int(np.flatnonzero(np.asarray(state.jstate) == RUNNING)[0])
        state = state._replace(n_failures=state.n_failures.at[j].set(2))
        node = int(np.asarray(state.placement)[j][0])
        state = state._replace(
            next_fail_t=jnp.full_like(state.next_fail_t, jnp.inf
                                      ).at[node].set(state.t),
            rack_fail_t=jnp.full_like(state.rack_fail_t, jnp.inf))
        old_sub = float(state.submit_t[j])
        new, _, _ = apply_faults(cfg, state, statics)
        assert int(new.jstate[j]) == QUEUED
        if backoff_s > 0:
            # third kill -> backoff_s * mult**2
            assert float(new.submit_t[j]) == pytest.approx(
                float(state.t) + backoff_s * 9.0)
        else:
            assert float(new.submit_t[j]) == old_sub


def test_killed_and_requeued_equals_freshly_queued():
    """Satellite (b): after a kill with no checkpoint, the per-job record
    is indistinguishable from a freshly queued job — no stale start_t,
    placement, or partial progress leaks into the next dispatch."""
    cfg, statics, state, _ = _setup(**_RESIL, ckpt_interval_s=0.0)
    state = _run_until_running(cfg, statics, state)
    fresh = np.asarray(state.jstate) == QUEUED
    j = int(np.flatnonzero(np.asarray(state.jstate) == RUNNING)[0])
    node = int(np.asarray(state.placement)[j][0])
    state = state._replace(
        next_fail_t=jnp.full_like(state.next_fail_t, jnp.inf
                                  ).at[node].set(state.t),
        rack_fail_t=jnp.full_like(state.rack_fail_t, jnp.inf))
    new, _, _ = apply_faults(cfg, state, statics)
    assert int(new.jstate[j]) == QUEUED
    assert float(new.start_t[j]) == 0.0
    assert (np.asarray(new.placement)[j] == -1).all()
    # full rewind without checkpoints: looks exactly like never-started
    assert float(new.work_left[j]) == float(new.dur_est[j])
    # the invariant fresh QUEUED jobs satisfy holds for the requeued one
    if fresh.any():
        k = int(np.flatnonzero(fresh)[0])
        assert float(new.start_t[k]) == float(new.start_t[j]) == 0.0
        assert (np.asarray(new.placement)[k] == -1).all()


@settings(max_examples=25, deadline=None)
@given(prog=st.floats(0.0, 1e5), iv=st.floats(0.0, 5e3),
       ov=st.floats(0.0, 500.0))
def test_property_ckpt_math(prog, iv, ov):
    """Checkpoint floor/drag vs the closed form, any (prog, iv, ov)."""
    cfg = tiny_cluster(ckpt_interval_s=iv, ckpt_overhead_s=ov)
    statics = build_statics(cfg)
    state = init_state(cfg, statics, jax.random.key(0))
    kept = np.asarray(flt.ckpt_kept(
        state, jnp.full_like(state.work_left, np.float32(prog))))
    drag = np.asarray(flt.ckpt_drag(cfg, state))
    p32 = np.float32(prog)
    if iv > 0:
        iv32 = np.float32(iv)
        assert (kept <= p32 + 1e-3).all()          # never invents work
        assert (kept >= p32 - iv32 - 1e-3).all()   # loses < one interval
        assert (0.0 < drag).all() and (drag <= 1.0).all()
    else:
        assert (kept == 0.0).all()
        assert (drag == 1.0).all()


# -------------------------------------------------------- outage schedules
def test_outage_schedule_oracle():
    """outage_level_at / outage_down / next_outage_event vs a brute-force
    NumPy sweep over a two-window schedule."""
    sched = outage_events([100.0, 400.0], [250.0, 600.0],
                          levels=[2, 0], down_racks=[-1, 1])
    node_rack = jnp.asarray([0, 0, 1, 1], jnp.int32)
    for t in np.arange(0.0, 700.0, 25.0):
        lvl = int(outage_level_at(sched, jnp.float32(t)))
        exp_lvl = 2 if 100.0 <= t < 250.0 else 0
        assert lvl == exp_lvl, t
        forced, until = outage_down(sched, jnp.float32(t), node_rack)
        in_w2 = 400.0 <= t < 600.0
        np.testing.assert_array_equal(
            np.asarray(forced), [False, False, in_w2, in_w2], err_msg=str(t))
        if in_w2:
            assert (np.asarray(until)[2:] == 600.0).all()
        nxt = float(next_outage_event(sched, jnp.float32(t)))
        edges = [e for e in (100.0, 250.0, 400.0, 600.0) if e > t]
        assert nxt == (min(edges) if edges else np.inf)


def test_no_mid_window_repair_flap():
    """A node that was already down entering a maintenance window has its
    repair extended to the window end — it can never flap up inside the
    window (an unpredictable breakpoint the macro engine couldn't see)."""
    cfg, statics, state, _ = _setup(
        **_RESIL, outages_enabled=True,
        scenario=None)
    scn = default_scenario(cfg)._replace(
        outages=outage_events([100.0], [500.0], levels=[0], down_racks=[0]))
    statics = statics._replace(scenario=scn)
    # node 0 (rack 0) already down with a repair due INSIDE the window
    state = state._replace(
        t=jnp.float32(100.0),
        node_up=state.node_up.at[0].set(0.0),
        repair_t=state.repair_t.at[0].set(150.0),
        next_fail_t=jnp.full_like(state.next_fail_t, jnp.inf),
        rack_fail_t=jnp.full_like(state.rack_fail_t, jnp.inf))
    new, _, _ = apply_faults(cfg, state, statics)
    rack0 = np.flatnonzero(np.asarray(statics.node_rack) == 0)
    assert (np.asarray(new.node_up)[rack0] == 0.0).all()
    assert (np.asarray(new.repair_t)[rack0] >= 500.0).all()


# ------------------------------------------------------- degradation ladder
def test_degrade_clock_ladder():
    cfg = tiny_cluster(degrade_enabled=True, degrade_throttle_frac=0.6)
    vals = [float(flt.degrade_clock(cfg, jnp.int32(l)))
            for l in (LVL_NORMAL, LVL_THROTTLE, LVL_GATE, LVL_DRAIN,
                      LVL_EVICT)]
    assert vals[0] == 1.0
    assert vals[1] == vals[2] == pytest.approx(0.6)
    assert vals[3] == vals[4] == pytest.approx(cfg.throttle_floor)
    # effective level is the max of schedulable rung and outage forcing
    statics = build_statics(cfg)
    state = init_state(cfg, statics, jax.random.key(0))
    state = state._replace(degrade_level=jnp.int32(LVL_DRAIN))
    assert int(effective_level(cfg, state, statics)) == LVL_DRAIN


def test_gate_blocks_dispatch_and_evict_keeps_progress():
    """>= GATE: no new job starts; EVICT: running jobs checkpoint-evict
    to QUEUED with progress intact and ZERO lost work."""
    cfg, statics, state, _ = _setup(degrade_enabled=True)
    gated = state._replace(degrade_level=jnp.int32(LVL_GATE))
    fs, _ = jax.jit(lambda s: run_episode(
        cfg, statics, s, 200, "fcfs", summary_only=True))(gated)
    assert int(jnp.sum(fs.jstate == RUNNING)) == 0
    assert float(jnp.sum(fs.jstate == 3)) == 0.0       # nothing completed

    # eviction after some real progress
    cfg2, statics2, state2, _ = _setup(degrade_enabled=True)
    state2 = _run_until_running(cfg2, statics2, state2)
    state2 = state2._replace(degrade_level=jnp.int32(LVL_EVICT))
    j = int(np.flatnonzero(np.asarray(state2.jstate) == RUNNING)[0])
    wl_before = float(state2.work_left[j])
    new, killed, lost = apply_faults(cfg2, state2, statics2)
    assert int(new.jstate[j]) == QUEUED
    assert float(new.work_left[j]) == wl_before         # progress kept
    assert float(killed) == 0.0 and float(lost) == 0.0  # graceful
    assert (np.asarray(new.placement)[j] == -1).all()


def test_degrade_throttle_cuts_power_and_progress():
    """THROTTLE clocks dynamic power: facility power under LVL_THROTTLE
    is strictly below normal while jobs run, and completions are slower."""
    cfg, statics, state, _ = _setup(degrade_enabled=True,
                                    degrade_throttle_frac=0.5)
    run = jax.jit(lambda s: run_episode(
        cfg, statics, s, 800, "fcfs", summary_only=True))
    fs_n, tel_n = run(state)
    fs_t, tel_t = run(state._replace(degrade_level=jnp.int32(LVL_THROTTLE)))
    assert float(fs_t.energy_kwh) < float(fs_n.energy_kwh)
    assert float(fs_t.n_completed) <= float(fs_n.n_completed)


# ------------------------------------------------- determinism & fleet runs
def test_seed_determinism_and_vmap_consistency():
    """Same seed -> bit-identical faults through run_episode AND through
    the vmapped run_fleet path; replicas with split keys diverge."""
    cfg, statics, state, _ = _setup(**_RESIL, n_jobs=16, horizon=600.0)
    run = jax.jit(lambda s: run_episode(
        cfg, statics, s, 900, "fcfs", summary_only=True))
    fs1, _ = run(state)
    fs2, _ = run(state)
    np.testing.assert_array_equal(np.asarray(fs1.node_up),
                                  np.asarray(fs2.node_up))
    assert float(fs1.n_killed) == float(fs2.n_killed)

    scns = [default_scenario(cfg)] * 3
    fstates, _ = run_fleet(cfg, statics, state, 900, "fcfs",
                           scenarios=scns, summary_only=True)
    fstates2, _ = run_fleet(cfg, statics, state, 900, "fcfs",
                            scenarios=scns, summary_only=True)
    np.testing.assert_array_equal(np.asarray(fstates.n_killed),
                                  np.asarray(fstates2.n_killed))
    np.testing.assert_array_equal(np.asarray(fstates.node_up),
                                  np.asarray(fstates2.node_up))


def test_goodput_accounting_in_summary():
    cfg, statics, state, _ = _setup(
        **_RESIL, ckpt_interval_s=120.0, ckpt_overhead_s=10.0,
        n_jobs=16, horizon=600.0)
    fs, tel = jax.jit(lambda s: run_episode(
        cfg, statics, s, 2000, "fcfs", summary_only=True))(state)
    s = summary(fs, tel)
    assert s["lost_node_seconds"] >= 0.0
    assert 0.0 <= s["goodput_frac"] <= 1.0
    if s["lost_node_seconds"] > 0:
        assert s["goodput_frac"] < 1.0


def test_resilience_off_is_legacy_bit_path():
    """With every resilience knob off the step program never calls the
    fault engine: final states match a config that never knew about it
    (the new SimState fields stay at their inert defaults)."""
    cfg, statics, state, _ = _setup()
    assert not cfg.resilience_on
    fs, _ = jax.jit(lambda s: run_episode(
        cfg, statics, s, 400, "fcfs", summary_only=True))(state)
    assert float(fs.n_killed) == 0.0
    assert float(fs.lost_node_s) == 0.0
    assert float(fs.n_failed) == 0.0
    assert (np.asarray(fs.node_up) == 1.0).all()
    assert np.isinf(np.asarray(fs.next_fail_t)).all()


def test_sched_env_resilience_obs_and_ladder_actions():
    """SchedEnv grows the resilience feature block and 5 ladder actions
    only when the knobs are on; a ladder action sets the rung, which
    gates dispatch at >= GATE."""
    from repro.envs.sched_env import RESILIENCE_FEATURES, SchedEnv

    cfg_off = tiny_cluster()
    cfg_on = tiny_cluster(**_RESIL, degrade_enabled=True)
    jobs, bank = synth_workload(cfg_on, 16, 600.0, seed=0)
    env_off = SchedEnv(cfg_off, [(jobs, bank)], episode_steps=8)
    env_on = SchedEnv(cfg_on, [(jobs, bank)], episode_steps=8)
    assert env_on.n_actions == env_off.n_actions + 5
    assert env_on.obs_dim == env_off.obs_dim + len(RESILIENCE_FEATURES)

    st, obs = env_on.reset(jax.random.key(0))
    assert obs.shape == (env_on.obs_dim,)
    # action k+1+GATE sets the rung; it persists on the state
    a_gate = env_on.k + 1 + LVL_GATE
    st2, obs2, r, done, info = env_on.step(st, jnp.int32(a_gate))
    assert int(st2.sim.degrade_level) == LVL_GATE
    assert int(jnp.sum(st2.sim.jstate == RUNNING)) == 0
    # a dispatch action leaves the rung untouched
    st3, *_ = env_on.step(st2, jnp.int32(env_on.k))
    assert int(st3.sim.degrade_level) == LVL_GATE
    # back to NORMAL
    st4, *_ = env_on.step(st3, jnp.int32(env_on.k + 1 + LVL_NORMAL))
    assert int(st4.sim.degrade_level) == LVL_NORMAL


def test_resilience_drill_scenario_registered():
    from repro.scenarios import SCENARIOS
    assert "resilience_drill" in SCENARIOS
    cfg = tiny_cluster(outages_enabled=True)
    scn = resilience_drill(cfg)
    assert scn.outages.start_t.shape == (2,)
