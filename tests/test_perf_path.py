"""PR2 hot-path rebuild: equivalence + constant-memory telemetry tests.

- sort-free cumsum placement must be BIT-equivalent to the legacy argsort
  ``first_fit`` over random states (property test);
- ``lax.top_k`` RL candidates must match the argsort prefix;
- the fused power-scatter Pallas kernel must match the two-pass
  scatter + node-power oracle;
- windowed / episode-wide telemetry accumulators must match reductions of
  the full per-step StepOut stack.
"""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.sim import tiny_cluster
from repro.core import (
    build_statics,
    init_state,
    load_jobs,
    run_episode,
    run_fleet,
)
from repro.core import schedulers as sched
from repro.core.power import compute_power, placement_amounts, job_utilization
from repro.data import synth_workload
from repro.kernels import ref


def _setup(seed=0, n_jobs=24, horizon=900.0):
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, n_jobs, horizon, seed=seed)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(seed)), jobs)
    return cfg, statics, state


def _random_states(state, n, seed):
    keys = jax.random.split(jax.random.key(seed), n)

    def perturb(s, key):
        k1, k2, k3 = jax.random.split(key, 3)
        jstate = jnp.where(
            jax.random.bernoulli(k3, 0.3, s.jstate.shape),
            0, s.jstate)
        return s._replace(
            free=s.free * jax.random.uniform(k1, s.free.shape),
            t=jax.random.uniform(k2, (), minval=0.0, maxval=900.0),
            jstate=jstate,
        )

    return jax.vmap(perturb, in_axes=(None, 0))(state, keys)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), job=st.integers(0, 23))
def test_property_cumsum_placement_equals_argsort(seed, job):
    cfg, _, state = _setup(seed=seed % 7)
    states = _random_states(state, 16, seed)
    K = cfg.max_nodes_per_job
    row_new, ok_new = jax.vmap(
        lambda s: sched.first_fit(s, jnp.int32(job), K))(states)
    row_old, ok_old = jax.vmap(
        lambda s: sched.first_fit_argsort(s, jnp.int32(job), K))(states)
    np.testing.assert_array_equal(np.asarray(row_new), np.asarray(row_old))
    np.testing.assert_array_equal(np.asarray(ok_new), np.asarray(ok_old))


def test_cumsum_placement_edge_cases():
    cfg, _, state = _setup()
    K = cfg.max_nodes_per_job
    # more nodes requested than exist -> infeasible, all -1
    s = state._replace(n_nodes=state.n_nodes.at[0].set(cfg.n_nodes + 1))
    row, ok = sched.first_fit(s, jnp.int32(0), K)
    assert not bool(ok) and (np.asarray(row) == -1).all()
    # zero-node request -> feasible, empty row (matches argsort path)
    s = state._replace(n_nodes=state.n_nodes.at[0].set(0))
    row, ok = sched.first_fit(s, jnp.int32(0), K)
    row2, ok2 = sched.first_fit_argsort(s, jnp.int32(0), K)
    assert bool(ok) == bool(ok2)
    np.testing.assert_array_equal(np.asarray(row), np.asarray(row2))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_property_topk_candidates_match_argsort(seed):
    cfg, _, state = _setup(seed=seed % 5)
    state = _random_states(state, 1, seed)
    state = jax.tree.map(lambda a: a[0], state)
    k = cfg.sched_max_candidates
    got = np.asarray(sched.rl_candidates(cfg, state))
    m = np.asarray(sched.queued_mask(state))
    score = np.where(m, np.asarray(state.submit_t), sched.BIG)
    idx = np.argsort(score, kind="stable")[:k]
    want = np.where(m[idx], idx, -1)
    np.testing.assert_array_equal(got, want)


def test_fused_power_scatter_matches_two_pass():
    cfg, statics, state = _setup()
    s, _ = jax.jit(lambda s: run_episode(cfg, statics, s, 80, "fcfs"))(state)
    p_ref = compute_power(cfg, s, statics, use_kernel=False)
    p_fused = compute_power(cfg, s, statics, use_kernel=True)
    for name, a, b in zip(p_ref._fields, p_ref, p_fused):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, err_msg=name)


def test_power_scatter_ref_matches_pallas_kernel():
    from repro.kernels.node_power import power_scatter_pallas

    rng = np.random.default_rng(0)
    N, JK = 100, 192
    place = rng.integers(-1, N, JK).astype(np.int32)
    cabs = (rng.uniform(0, 8, JK) * (place >= 0)).astype(np.float32)
    gabs = (rng.uniform(0, 2, JK) * (place >= 0)).astype(np.float32)
    capc = rng.uniform(8, 48, N).astype(np.float32)
    capg = rng.uniform(1, 4, N).astype(np.float32)
    idle = rng.uniform(80, 300, N).astype(np.float32)
    cd = rng.uniform(100, 400, N).astype(np.float32)
    gd = rng.uniform(0, 600, N).astype(np.float32)
    up = rng.integers(0, 2, N).astype(np.float32)
    mx = idle + cd + gd
    kw = dict(rect_peak=0.965, rect_load=0.55, rect_curv=0.12,
              conv_eff=0.975)
    got = power_scatter_pallas(place, cabs, gabs, capc, capg, idle, cd, gd,
                               up, mx, block_n=64, **kw)
    want = ref.power_scatter_ref(place, cabs, gabs, capc, capg, idle, cd,
                                 gd, up, mx, **kw)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5)


def test_placement_amounts_zeroes_invalid_slots():
    cfg, statics, state = _setup()
    s, _ = jax.jit(lambda s: run_episode(cfg, statics, s, 50, "fcfs"))(state)
    cpu_u, gpu_u = job_utilization(cfg, s, statics)
    place, cabs, gabs = placement_amounts(s, cpu_u, gpu_u)
    invalid = np.asarray(place) < 0
    assert (np.asarray(cabs)[invalid] == 0).all()
    assert (np.asarray(gabs)[invalid] == 0).all()


# ---------------------------------------------------------------------------
def test_telemetry_summary_only_matches_full_stack():
    cfg, statics, state = _setup()
    fs, outs = jax.jit(
        lambda s: run_episode(cfg, statics, s, 200, "fcfs"))(state)
    fs2, tel = jax.jit(
        lambda s: run_episode(cfg, statics, s, 200, "fcfs",
                              summary_only=True))(state)
    # identical final state either way
    np.testing.assert_allclose(float(fs.energy_kwh), float(fs2.energy_kwh))
    np.testing.assert_allclose(float(fs.n_completed), float(fs2.n_completed))
    o = jax.device_get(outs)
    np.testing.assert_allclose(
        float(tel.energy_kwh), o.energy_kwh_step.sum(), rtol=1e-5)
    np.testing.assert_allclose(
        float(tel.carbon_kg), o.carbon_kg_step.sum(), rtol=1e-5)
    np.testing.assert_allclose(
        float(tel.completed), o.completed_now.sum(), rtol=1e-6)
    np.testing.assert_allclose(float(tel.reward), o.reward.sum(),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        float(tel.mean_facility_w), o.facility_w.mean(), rtol=1e-5)
    np.testing.assert_allclose(
        float(tel.mean_pue), o.pue.mean(), rtol=1e-5)
    np.testing.assert_allclose(
        float(tel.max_facility_w), o.facility_w.max(), rtol=1e-6)
    np.testing.assert_allclose(
        float(tel.max_queue_len), o.queue_len.max(), rtol=1e-6)
    assert float(tel.n_steps) == 200


def test_telemetry_windows_match_full_stack():
    cfg, statics, state = _setup()
    every = 25
    fs, outs = jax.jit(
        lambda s: run_episode(cfg, statics, s, 200, "fcfs"))(state)
    fs2, wins = jax.jit(
        lambda s: run_episode(cfg, statics, s, 200, "fcfs",
                              telemetry_every=every))(state)
    np.testing.assert_allclose(float(fs.t), float(fs2.t))
    o = jax.device_get(outs)
    n_win = 200 // every
    assert np.shape(wins.mean_facility_w) == (n_win,)
    np.testing.assert_allclose(
        np.asarray(wins.mean_facility_w),
        o.facility_w.reshape(n_win, every).mean(1), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(wins.energy_kwh),
        o.energy_kwh_step.reshape(n_win, every).sum(1), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(wins.max_queue_len),
        o.queue_len.reshape(n_win, every).max(1), rtol=1e-6)
    np.testing.assert_allclose(
        float(np.asarray(wins.completed).sum()), float(fs.n_completed))


def test_telemetry_every_must_divide_n_steps():
    cfg, statics, state = _setup()
    import pytest

    with pytest.raises(ValueError):
        run_episode(cfg, statics, state, 201, "fcfs", telemetry_every=25)
    # episode-wide summary conflicts with windowing — must be loud
    with pytest.raises(ValueError):
        run_episode(cfg, statics, state, 200, "fcfs", telemetry_every=25,
                    summary_only=True)


def test_fleet_summary_only_constant_size_and_chaining():
    from repro.scenarios import sample_scenarios

    cfg, statics, state = _setup()
    scns = sample_scenarios(cfg, 4, seed=1)
    fs, outs = run_fleet(cfg, statics, state, 60, "fcfs", scenarios=scns)
    fs2, tel = run_fleet(cfg, statics, state, 60, "fcfs", scenarios=scns,
                         summary_only=True)
    # O(R) telemetry, not O(R*T)
    assert np.shape(tel.energy_kwh) == (4,)
    assert np.shape(outs.energy_kwh_step) == (4, 60)
    np.testing.assert_allclose(
        np.asarray(tel.energy_kwh),
        np.asarray(outs.energy_kwh_step).sum(1), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(fs.energy_kwh), np.asarray(fs2.energy_kwh), rtol=1e-6)
    # chained sweep: batched final states feed straight back in
    fs3, _ = run_fleet(cfg, statics, fs2, 60, "fcfs", scenarios=scns,
                       summary_only=True)
    assert (np.asarray(fs3.t) >= np.asarray(fs.t)).all()
