"""Serving-path consistency: prefill(k tokens) -> decode(token k) must match
prefill(k+1 tokens) logits — across attention, SWA-ring, SSM and LSTM
cache types.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import get_arch, reduced
from repro.models import init_params, prefill
from repro.models.model import decode_step

CASES = [
    ("qwen3-4b", {}),                    # dense GQA + qk-norm
    ("gemma3-1b", {"swa_window": 16}),   # local:global + small ring buffer
    ("mixtral-8x22b", {"swa_window": 24}),  # MoE + SWA
    ("jamba-1.5-large-398b", {}),        # mamba + attn + moe
    ("xlstm-125m", {}),                  # mlstm + slstm states
    ("whisper-small", {}),               # enc-dec cross attention
    ("llama-3.2-vision-11b", {}),        # VLM cross-attn layers
]


@pytest.mark.parametrize("arch,overrides", CASES)
def test_prefill_then_decode_matches_longer_prefill(arch, overrides):
    cfg = reduced(get_arch(arch))
    if overrides:
        cfg = replace(cfg, **overrides)
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 48
    key = jax.random.key(1)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab, jnp.int32)
    extras = {}
    if cfg.n_vision_tokens:
        extras["vision"] = 0.02 * jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model))
    if cfg.enc_dec:
        extras["audio"] = 0.02 * jax.random.normal(
            key, (B, cfg.n_audio_frames, cfg.d_model))

    # ground truth: prefill on S+1 tokens -> last-token logits
    want, _ = prefill(params, {"tokens": toks, **extras}, cfg,
                      cache_seq_len=S + 1)

    # prefill S tokens, then decode token S
    _, cache = prefill(params, {"tokens": toks[:, :S], **extras}, cfg,
                       cache_seq_len=S + 1)
    got, _ = decode_step(params, cache, toks[:, S:S + 1], jnp.int32(S), cfg)

    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-2, rtol=2e-2
    )
    # argmax agreement (the metric that matters for greedy decoding)
    agree = (np.argmax(np.asarray(got), -1) == np.argmax(np.asarray(want), -1))
    assert agree.all()
