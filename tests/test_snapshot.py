"""Durable-twin contract: snapshotted runs match vanilla bit-for-bit,
kill-at-any-snapshot + resume matches the uninterrupted run, and
mismatched resumes fail loudly with typed errors."""

import math
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.sim import tiny_cluster
from repro.core import (
    build_statics,
    init_state,
    load_jobs,
    run_episode,
    summary,
)
from repro.core.fleet import run_fleet
from repro.scenarios.scenario import stack_scenarios
from repro.data import synth_workload
from repro.utils.errors import CheckpointError, ConfigError

N_STEPS = 400

_VARIANTS = {
    "base": {},
    "thermal": {"thermal_enabled": True},
    "faults+serving": {"node_mtbf_hours": 0.3, "serving_enabled": True,
                       "serving_nodes": 4},
}
_cache = {}


def _setup(variant):
    if variant not in _cache:
        cfg = tiny_cluster(**_VARIANTS[variant])
        jobs, bank = synth_workload(cfg, 32, 1200.0, seed=0)
        statics = build_statics(cfg, bank)
        _cache[variant] = (cfg, statics, jobs)
    cfg, statics, jobs = _cache[variant]
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    return cfg, statics, state


_ref_cache = {}


def _reference(variant, macro):
    """Uninterrupted snapshotless run (memoized per variant)."""
    if (variant, macro) not in _ref_cache:
        cfg, statics, state = _setup(variant)
        _ref_cache[variant, macro] = run_episode(
            cfg, statics, state, N_STEPS, "fcfs", macro=macro,
            summary_only=not macro)
    return _ref_cache[variant, macro]


def _assert_tree_equal(a, b, what, allow=()):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb), f"{what}: leaf count {len(fa)} vs {len(fb)}"
    for (pa, x), (_, y) in zip(fa, fb):
        name = jax.tree_util.keystr(pa)
        if jax.dtypes.issubdtype(getattr(x, "dtype", np.dtype(np.float32)),
                                 jax.dtypes.prng_key):
            x, y = jax.random.key_data(x), jax.random.key_data(y)
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            if any(tok in name for tok in allow):
                continue
            raise AssertionError(f"{what}: leaf {name} differs")


def _kill_after_first_snapshot(snapshot_dir):
    snaps = sorted(os.listdir(snapshot_dir))
    assert len(snaps) > 1, "need >1 snapshot to simulate a mid-run kill"
    for s in snaps[1:]:
        shutil.rmtree(os.path.join(snapshot_dir, s))


def test_per_tick_snapshotting_is_invisible(tmp_path):
    """summary_only + per-tick stepping: snapshotted == vanilla, bitwise
    (state, telemetry and the summary dict)."""
    cfg, statics, state = _setup("base")
    fs0, t0 = _reference("base", macro=False)
    fs1, t1 = run_episode(cfg, statics, state, N_STEPS, "fcfs",
                          summary_only=True, snapshot_every_s=120.0,
                          snapshot_dir=str(tmp_path))
    _assert_tree_equal(fs0, fs1, "SimState")
    _assert_tree_equal(t0, t1, "TelemetrySummary")
    assert summary(fs0) == summary(fs1)


def test_macro_snapshotting_state_bitwise(tmp_path):
    """Macro engine: snapshot boundaries clamp fast-forward exactly like
    telemetry windows, so the SimState stays bitwise; only the
    macro_steps skip accounting may differ."""
    cfg, statics, state = _setup("base")
    fs0, t0 = _reference("base", macro=True)
    fs1, t1 = run_episode(cfg, statics, state, N_STEPS, "fcfs", macro=True,
                          snapshot_every_s=150.0, snapshot_dir=str(tmp_path))
    _assert_tree_equal(fs0, fs1, "SimState")
    _assert_tree_equal(t0, t1, "TelemetrySummary", allow=("macro_steps",))
    assert summary(fs0) == summary(fs1)


@settings(max_examples=6, deadline=None)
@given(variant=st.sampled_from(sorted(_VARIANTS)), macro=st.booleans())
def test_kill_and_resume_is_bit_identical(variant, macro, tmp_path_factory):
    """The acceptance pin: kill after any snapshot, resume from latest,
    final SimState (incl. PRNG key data), TelemetrySummary and summary()
    dict are bit-identical to the uninterrupted snapshotted run — across
    the thermal x faults x serving matrix, per-tick and macro engines."""
    tmp = tmp_path_factory.mktemp(f"snap_{variant.replace('+', '_')}_{macro}")
    cfg, statics, state = _setup(variant)
    kw = dict(macro=macro) if macro else dict(summary_only=True)
    fs1, t1 = run_episode(cfg, statics, state, N_STEPS, "fcfs",
                          snapshot_every_s=120.0, snapshot_dir=str(tmp),
                          snapshot_keep=99, **kw)
    _kill_after_first_snapshot(str(tmp))
    cfg, statics, state = _setup(variant)
    fs2, t2 = run_episode(cfg, statics, state, N_STEPS, "fcfs",
                          snapshot_every_s=120.0, resume_from=str(tmp), **kw)
    _assert_tree_equal(fs1, fs2, f"SimState[{variant}, macro={macro}]")
    _assert_tree_equal(t1, t2, f"TelemetrySummary[{variant}, macro={macro}]")
    assert summary(fs1) == summary(fs2)


def test_resume_from_empty_dir_runs_from_scratch(tmp_path):
    """A kill BEFORE the first snapshot leaves nothing on disk; resume
    must silently start from t=0 and still match the full run."""
    cfg, statics, state = _setup("base")
    fs0, t0 = _reference("base", macro=False)
    fs1, t1 = run_episode(cfg, statics, state, N_STEPS, "fcfs",
                          summary_only=True, resume_from=str(tmp_path))
    _assert_tree_equal(fs0, fs1, "SimState")
    _assert_tree_equal(t0, t1, "TelemetrySummary")


def test_infinite_interval_snapshots_once_at_end(tmp_path):
    """snapshot_every_s=inf never cuts the episode: one segment, one
    final snapshot, results bitwise-equal to vanilla."""
    cfg, statics, state = _setup("base")
    fs0, t0 = _reference("base", macro=False)
    fs1, t1 = run_episode(cfg, statics, state, N_STEPS, "fcfs",
                          summary_only=True, snapshot_every_s=math.inf,
                          snapshot_dir=str(tmp_path))
    _assert_tree_equal(fs0, fs1, "SimState")
    _assert_tree_equal(t0, t1, "TelemetrySummary")
    assert sorted(os.listdir(tmp_path)) == [f"step_{N_STEPS:010d}"]


def test_fleet_kill_and_resume(tmp_path):
    """Fleet snapshots cover the whole replica batch (keys installed), so
    a killed sweep resumes to the exact per-replica results."""
    cfg, statics, state = _setup("base")
    scens = stack_scenarios([statics.scenario] * 3)
    fs0, t0 = run_fleet(cfg, statics, state, N_STEPS, "fcfs",
                        scenarios=scens, summary_only=True)
    cfg, statics, state = _setup("base")
    fs1, t1 = run_fleet(cfg, statics, state, N_STEPS, "fcfs",
                        scenarios=scens, summary_only=True,
                        snapshot_every_s=120.0, snapshot_dir=str(tmp_path),
                        snapshot_keep=99)
    _assert_tree_equal(fs0, fs1, "fleet SimState vs vanilla")
    _assert_tree_equal(t0, t1, "fleet telem vs vanilla")
    _kill_after_first_snapshot(str(tmp_path))
    cfg, statics, state = _setup("base")
    fs2, t2 = run_fleet(cfg, statics, state, N_STEPS, "fcfs",
                        scenarios=scens, summary_only=True,
                        snapshot_every_s=120.0, resume_from=str(tmp_path))
    _assert_tree_equal(fs1, fs2, "fleet SimState killed+resumed")
    _assert_tree_equal(t1, t2, "fleet telem killed+resumed")


def test_fingerprint_mismatch_raises_typed_error(tmp_path):
    """Resuming with a different scheduler/workload/config names the
    mismatched component(s) in a CheckpointError (a ValueError, so legacy
    call sites still catch it)."""
    cfg, statics, state = _setup("base")
    run_episode(cfg, statics, state, N_STEPS, "fcfs", summary_only=True,
                snapshot_every_s=120.0, snapshot_dir=str(tmp_path))
    cfg, statics, state = _setup("base")
    with pytest.raises(CheckpointError, match="scheduler"):
        run_episode(cfg, statics, state, N_STEPS, "sjf", summary_only=True,
                    resume_from=str(tmp_path))
    with pytest.raises(ValueError, match="n_steps"):
        cfg, statics, state = _setup("base")
        run_episode(cfg, statics, state, N_STEPS + 1, "fcfs",
                    summary_only=True, resume_from=str(tmp_path))


def test_snapshot_kwargs_validated():
    """Snapshotting needs an episode-wide accumulator (summary_only or
    macro) and a positive interval — both misuses are loud ConfigErrors
    with an actionable message."""
    cfg, statics, state = _setup("base")
    with pytest.raises(ConfigError, match="summary_only"):
        run_episode(cfg, statics, state, N_STEPS, "fcfs",
                    snapshot_every_s=120.0, snapshot_dir="/tmp/nope")
    with pytest.raises(ConfigError, match="positive"):
        run_episode(cfg, statics, state, N_STEPS, "fcfs", summary_only=True,
                    snapshot_every_s=0.0, snapshot_dir="/tmp/nope")


def test_ppo_exact_resume(tmp_path):
    """ppo_train checkpoints the FULL training state; interrupting after
    iteration k and resuming reproduces the uninterrupted run's params
    and history tail bit-for-bit."""
    from repro.envs import SchedEnv
    from repro.rl import PPOConfig, ppo_train

    cfg = tiny_cluster(sched_max_candidates=4)
    wls = [synth_workload(cfg, 24, 900.0, seed=s) for s in range(2)]
    env = SchedEnv(cfg, wls, episode_steps=8, sim_steps_per_action=5)
    pcfg = PPOConfig(n_envs=4, rollout_len=8, n_epochs=2, n_minibatches=2)

    d_full, d_cut = str(tmp_path / "full"), str(tmp_path / "cut")
    p_full, h_full = ppo_train(env, cfg=pcfg, n_iterations=6,
                               checkpoint_dir=d_full, checkpoint_every=2)
    ppo_train(env, cfg=pcfg, n_iterations=4, checkpoint_dir=d_cut,
              checkpoint_every=2)
    p_res, h_res = ppo_train(env, cfg=pcfg, n_iterations=6,
                             checkpoint_dir=d_cut, checkpoint_every=2,
                             resume=True)
    _assert_tree_equal(p_full, p_res, "PPO params")
    assert h_full[4:] == h_res

    with pytest.raises(CheckpointError, match="seed"):
        ppo_train(env, cfg=pcfg, n_iterations=6, seed=1,
                  checkpoint_dir=d_cut, checkpoint_every=2, resume=True)
