"""Scenario engine tests: signal families, power-cap events, fleet runner."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sim import tiny_cluster
from repro.core import (
    build_statics,
    init_state,
    load_jobs,
    run_episode,
    run_fleet,
    summary,
)
from repro.data import load_signal_csv, synth_grid_trace, synth_workload, write_signal_csv
from repro.scenarios import (
    cap_events,
    default_scenario,
    demand_response,
    eval_signal,
    from_trace,
    heatwave,
    no_cap,
    power_cap_at,
    sample_scenarios,
    sinusoid,
    stack_scenarios,
)


def _setup(seed=0, n_jobs=24, horizon=600.0, **cfg_kw):
    cfg = tiny_cluster(**cfg_kw)
    jobs, bank = synth_workload(cfg, n_jobs, horizon, seed=seed)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(seed)), jobs)
    return cfg, statics, state


# ----------------------------------------------------------------- signals
def test_default_scenario_matches_legacy_sinusoids():
    """Pins default_scenario to the closed-form diurnal sinusoids that the
    removed ``core.power.carbon_intensity`` / ``wetbulb_c`` shims encoded
    (carbon peaks at midnight, wetbulb mid-afternoon)."""
    cfg = tiny_cluster()
    scn = default_scenario(cfg)
    for t in np.linspace(0.0, 2 * cfg.day_seconds, 29, dtype=np.float32):
        t = jnp.float32(t)
        phase = 2 * np.pi * (float(t) / cfg.day_seconds)
        legacy_carbon = cfg.carbon_mean - cfg.carbon_amp * np.sin(
            phase - np.pi / 2)
        legacy_wetbulb = cfg.wetbulb_mean_c + cfg.wetbulb_amp_c * np.sin(
            phase - np.pi / 2)
        np.testing.assert_allclose(
            eval_signal(scn.carbon, t), legacy_carbon, rtol=2e-5, atol=1e-3)
        np.testing.assert_allclose(
            eval_signal(scn.wetbulb, t), legacy_wetbulb, rtol=2e-5, atol=1e-3)


def test_legacy_power_shims_removed():
    """The parametric shims are formally gone from core.power — scenarios
    are the single source of grid signals."""
    from repro.core import power

    assert not hasattr(power, "carbon_intensity")
    assert not hasattr(power, "wetbulb_c")


def test_trace_signal_equals_parametric_at_sample_points():
    para = sinusoid(380.0, 120.0, 86_400.0, phase=np.pi / 2)
    dt = 300.0
    ts = np.arange(0, 86_400.0 + dt, dt, dtype=np.float32)
    vals = np.asarray([eval_signal(para, jnp.float32(t)) for t in ts])
    trace = from_trace(vals, dt)
    for t in ts[:: 17]:
        np.testing.assert_allclose(
            eval_signal(trace, jnp.float32(t)),
            eval_signal(para, jnp.float32(t)), rtol=1e-5, atol=1e-2)
    # between samples: linear interp stays within neighbor bounds
    mid = jnp.float32(ts[3] + dt / 2)
    lo, hi = sorted([vals[3], vals[4]])
    assert lo - 1e-3 <= float(eval_signal(trace, mid)) <= hi + 1e-3


def test_trace_signal_edge_hold():
    trace = from_trace([1.0, 2.0, 3.0], dt=10.0)
    assert float(eval_signal(trace, jnp.float32(-100.0))) == 1.0
    assert float(eval_signal(trace, jnp.float32(1e6))) == 3.0


# ------------------------------------------------------------------ events
def test_power_cap_event_activation_and_deactivation():
    sched = cap_events([100.0, 200.0], [300.0, 250.0], [5000.0, 3000.0],
                       base_cap_w=0.0)
    t = lambda x: jnp.float32(x)
    assert float(power_cap_at(sched, t(50.0))) == 0.0      # before: uncapped
    assert float(power_cap_at(sched, t(150.0))) == 5000.0  # first event
    assert float(power_cap_at(sched, t(220.0))) == 3000.0  # overlap: tightest
    assert float(power_cap_at(sched, t(260.0))) == 5000.0  # second ended
    assert float(power_cap_at(sched, t(300.0))) == 0.0     # end exclusive


def test_power_cap_base_combines_with_events():
    sched = cap_events([100.0], [200.0], [5000.0], base_cap_w=4000.0)
    assert float(power_cap_at(sched, jnp.float32(50.0))) == 4000.0
    assert float(power_cap_at(sched, jnp.float32(150.0))) == 4000.0
    sched = cap_events([100.0], [200.0], [3000.0], base_cap_w=4000.0)
    assert float(power_cap_at(sched, jnp.float32(150.0))) == 3000.0
    assert float(power_cap_at(no_cap(), jnp.float32(0.0))) == 0.0


def test_cap_event_throttles_mid_episode_only():
    cfg, statics, state = _setup()
    base, t0, t1 = statics, 120.0, 300.0
    fs_u, outs_u = jax.jit(
        lambda s: run_episode(cfg, base, s, 500, "fcfs"))(state)
    cap = float(jnp.max(outs_u.facility_w)) * 0.7
    scn = default_scenario(cfg)._replace(
        power_cap=cap_events([t0], [t1], [cap]))
    capped = base._replace(scenario=scn)
    fs_c, outs_c = jax.jit(
        lambda s: run_episode(cfg, capped, s, 500, "fcfs"))(state)

    tgrid = np.arange(1, 501, dtype=np.float32) * cfg.dt
    inside = (tgrid >= t0) & (tgrid < t1)
    fac = np.asarray(outs_c.facility_w)
    assert (np.asarray(outs_c.power_cap_w)[inside] == np.float32(cap)).all()
    assert (fac[inside] <= cap * 1.02).all()
    assert (np.asarray(outs_c.throttle)[inside] <= 1.0).all()
    # before the event both runs are bit-identical
    np.testing.assert_allclose(fac[tgrid < t0],
                               np.asarray(outs_u.facility_w)[tgrid < t0])
    # event really bound at least once
    assert float(np.asarray(outs_c.throttle)[inside].min()) < 1.0


# ------------------------------------------------------------------- fleet
def test_run_fleet_matches_independent_episodes():
    cfg, statics, state = _setup()
    scns = [
        default_scenario(cfg),
        demand_response(cfg, cap_w=3000.0, event_start_s=60.0,
                        event_len_s=240.0),
        heatwave(cfg),
    ]
    finals, outs = run_fleet(cfg, statics, state, 400, "fcfs",
                             scenarios=scns)
    assert finals.t.shape == (3,) and outs.facility_w.shape == (3, 400)

    keys = jax.random.split(state.key, 3)
    for i, scn in enumerate(scns):
        st_i = statics._replace(scenario=scn)
        fs, out = jax.jit(
            lambda s, st_i=st_i: run_episode(cfg, st_i, s, 400, "fcfs")
        )(state._replace(key=keys[i]))
        np.testing.assert_allclose(
            np.asarray(outs.facility_w[i]), np.asarray(out.facility_w),
            rtol=1e-6)
        for field in ("energy_kwh", "carbon_kg", "elec_cost_usd",
                      "n_completed"):
            np.testing.assert_allclose(
                float(getattr(finals, field)[i]), float(getattr(fs, field)),
                rtol=1e-6, err_msg=field)


def test_run_fleet_64_replicas_3_scenario_kinds_one_call():
    """Acceptance: >= 64 replicas, parametric + trace + scheduled-cap
    scenarios, one jitted call."""
    cfg, statics, state = _setup(n_jobs=16, horizon=300.0)
    values, dt = synth_grid_trace("carbon", 1200.0, dt=60.0, seed=2)
    kinds = [
        lambda i: default_scenario(cfg),
        lambda i: default_scenario(cfg)._replace(
            carbon=from_trace(values, dt)),
        lambda i: demand_response(cfg, cap_w=2500.0 + 10 * i,
                                  event_start_s=50.0, event_len_s=150.0),
    ]
    scns = stack_scenarios([kinds[i % 3](i) for i in range(64)])
    finals, outs = run_fleet(cfg, statics, state, 300, "fcfs",
                             scenarios=scns)
    assert finals.t.shape == (64,)
    assert np.isfinite(np.asarray(outs.facility_w)).all()
    e = np.asarray(finals.energy_kwh)
    # compare whole kind-triples only (64 = 21 triples + 1 leftover)
    n = 63
    # demand-response replicas must differ from uncapped ones
    assert not np.allclose(e[0:n:3], e[2:n:3])
    # carbon differs between parametric and trace carbon at equal energy
    np.testing.assert_allclose(e[0:n:3], e[1:n:3], rtol=1e-5)
    assert not np.allclose(np.asarray(finals.carbon_kg)[0:n:3],
                           np.asarray(finals.carbon_kg)[1:n:3])


def test_sample_scenarios_shapes_and_fleet():
    cfg, statics, state = _setup(n_jobs=8, horizon=200.0)
    scns = sample_scenarios(cfg, 8, seed=5)
    assert scns.carbon.mean.shape == (8,)
    finals, _ = run_fleet(cfg, statics, state, 50, "fcfs", scenarios=scns)
    assert np.isfinite(np.asarray(finals.energy_kwh)).all()


# ----------------------------------------------------------- cost accounting
def test_electricity_cost_accounting():
    cfg, statics, state = _setup()
    fs, outs = jax.jit(lambda s: run_episode(cfg, statics, s, 300, "fcfs"))(state)
    total = float(jnp.sum(outs.cost_usd_step))
    assert abs(total - float(fs.elec_cost_usd)) < 1e-4
    assert total > 0.0
    assert "elec_cost_usd" in summary(fs)
    # price signal telemetry is the configured diurnal price
    p = np.asarray(outs.price_usd_kwh)
    assert (p > 0).all() and p.std() > 0


# -------------------------------------------------------------------- IO
def test_signal_csv_roundtrip(tmp_path):
    values, dt = synth_grid_trace("price", 7200.0, dt=300.0, seed=3)
    path = write_signal_csv(os.path.join(tmp_path, "price.csv"), values, dt)
    sig = load_signal_csv(path)
    for i in (0, 5, len(values) - 1):
        np.testing.assert_allclose(
            float(eval_signal(sig, jnp.float32(i * dt))), values[i],
            rtol=1e-4)


def test_synth_grid_trace_kinds():
    for kind, lo, hi in (("carbon", 40.0, 900.0), ("price", 0.005, 2.0),
                         ("wetbulb", -20.0, 45.0)):
        v, dt = synth_grid_trace(kind, 86_400.0, seed=1)
        assert v.dtype == np.float32 and dt == 300.0
        assert np.isfinite(v).all() and (v >= lo).all() and (v <= hi).all()


# ------------------------------------------------------- signal integrals
def test_integrate_signal_sinusoid_closed_form():
    from repro.scenarios import integrate_signal, mean_signal

    sig = sinusoid(380.0, 120.0, 86_400.0, phase=1.1, noise_amp=25.0,
                   noise_seed=3.0)
    t0, t1 = 1234.5, 40_000.0
    ts = np.linspace(t0, t1, 200_001)
    vals = jax.vmap(lambda t: eval_signal(sig, t))(jnp.asarray(ts, jnp.float32))
    numeric = np.trapezoid(np.asarray(vals, np.float64), ts)
    analytic = float(integrate_signal(sig, t0, t1))
    np.testing.assert_allclose(analytic, numeric, rtol=2e-6)
    np.testing.assert_allclose(float(mean_signal(sig, t0, t1)),
                               numeric / (t1 - t0), rtol=2e-6)
    # orientation: reversed bounds negate
    assert float(integrate_signal(sig, t1, t0)) == -analytic


def test_integrate_signal_trace_prefix_sums_exact():
    from repro.scenarios import integrate_signal

    v = np.random.default_rng(0).uniform(100, 500, 37)
    sig = from_trace(v, dt=300.0, t0=500.0)
    # spans both edge-hold tails AND the interior
    t0, t1 = -100.0, 500.0 + 36 * 300.0 + 700.0
    ts = np.linspace(t0, t1, 400_001)
    vals = jax.vmap(lambda t: eval_signal(sig, t))(jnp.asarray(ts, jnp.float32))
    numeric = np.trapezoid(np.asarray(vals, np.float64), ts)
    np.testing.assert_allclose(float(integrate_signal(sig, t0, t1)),
                               numeric, rtol=2e-6)
    # interior-only: piecewise-linear integral is exact, not approximate —
    # compare against the dense trapezoid of the raw samples
    full = float(integrate_signal(sig, 500.0, 500.0 + 36 * 300.0))
    np.testing.assert_allclose(full, np.trapezoid(v) * 300.0, rtol=1e-6)


def test_next_cap_event_breakpoints():
    from repro.scenarios import next_cap_event

    sched = cap_events([100.0, 400.0], [200.0, 500.0], [5e3, 6e3],
                       base_cap_w=7e3, n_events=4)   # padded slots inert
    assert float(next_cap_event(sched, 0.0)) == 100.0
    assert float(next_cap_event(sched, 100.0)) == 200.0
    assert float(next_cap_event(sched, 250.0)) == 400.0
    assert float(next_cap_event(sched, 450.0)) == 500.0
    assert not np.isfinite(float(next_cap_event(sched, 500.0)))


# ------------------------------------------------------------------- envs
def test_sched_env_exposes_grid_signals_in_obs():
    from repro.envs import SchedEnv

    cfg = tiny_cluster(sched_max_candidates=4)
    wls = [synth_workload(cfg, 16, 600.0, seed=s) for s in range(2)]
    scn = demand_response(cfg, cap_w=3000.0, event_start_s=0.0,
                          event_len_s=1e6)
    env = SchedEnv(cfg, wls, episode_steps=4, sim_steps_per_action=5,
                   scenario=scn)
    st, obs = env.reset(jax.random.key(0))
    assert obs.shape == (env.obs_dim,)
    assert np.isfinite(np.asarray(obs)).all()
    # obs[4] is the cap fraction: capped env reads < 1
    assert float(obs[4]) < 1.0
    env_u = SchedEnv(cfg, wls, episode_steps=4, sim_steps_per_action=5)
    _, obs_u = env_u.reset(jax.random.key(0))
    assert float(obs_u[4]) == 1.0
    st2, obs2, r, done, info = jax.jit(env.step)(st, jnp.int32(0))
    assert np.isfinite(float(r))
