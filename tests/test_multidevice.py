"""Sharded-path tests. jax locks the device count at first init, so these
run in a subprocess with xla_force_host_platform_device_count=8.

Skip guards are per-test CAPABILITY probes (hasattr on the exact APIs a
test drives), not a module-wide version gate: the old blanket skip
silently benched every test here whenever ANY newer API was missing, even
the ones (mesh + NamedSharding jit) the pinned jax floor runs fine.
"""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

def _has_shard_map_compat() -> bool:
    # what sharding.specs.shard_map_compat needs: the public API or the
    # jax.experimental fallback (run with check_rep=False there)
    if hasattr(jax, "shard_map"):
        return True
    try:
        from jax.experimental.shard_map import shard_map  # noqa: F401
        return True
    except ImportError:
        return False


_CAPS = {
    "make_mesh": hasattr(jax, "make_mesh"),
    "shard_map": hasattr(jax, "shard_map"),
    "pcast": hasattr(jax.lax, "pcast"),
    "shard_map_compat": _has_shard_map_compat(),
}


def _requires(*caps):
    missing = [c for c in caps if not _CAPS[c]]
    return pytest.mark.skipif(
        bool(missing), reason=f"jax lacks {'/'.join(missing) or 'nothing'}")


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# prepended to every subprocess: build a mesh on any supported jax —
# axis_types is a newer keyword, explicit sharding mode works without it
_MESH_HELPER = """
import jax

def mk_mesh(shape, names):
    try:
        at = (jax.sharding.AxisType.Auto,) * len(shape)
        return jax.make_mesh(shape, names, axis_types=at)
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, names)
"""


def _run_sub(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-c", _MESH_HELPER + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@_requires("make_mesh")
def test_sharded_train_step_matches_unsharded():
    """FSDP+TP on a (2,4) mesh must produce the same loss trajectory as the
    single-device run (numerical tolerance)."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_arch, reduced
        from repro.configs.base import ShapeConfig
        from repro.data.synth_lm import lm_batch_at
        from repro.models import init_params
        from repro.optim import AdamW
        from repro.sharding.ctx import make_ctx, UNSHARDED
        from repro.sharding.specs import batch_pspecs
        from repro.train.state import train_state_pspecs
        from repro.train.train_step import make_train_step

        cfg = reduced(get_arch("qwen3-4b"))
        opt = AdamW(lr=1e-3)
        params = init_params(cfg, jax.random.key(0))
        state0 = {"params": params, "opt": opt.init(params), "step": jnp.int32(0)}
        data = lambda i: lm_batch_at(i, vocab=cfg.vocab, batch=8, seq_len=64)

        # unsharded reference
        stepu = jax.jit(make_train_step(cfg, opt))
        su = state0
        ref = []
        for i in range(3):
            su, m = stepu(su, data(i))
            ref.append(float(m["loss"]))

        # sharded
        mesh = mk_mesh((2, 4), ("data", "model"))
        ctx = make_ctx(False, tp_size=4, dp_size=2)
        shape = ShapeConfig("t", 64, 8, "train")
        sps = train_state_pspecs(cfg, ctx, opt, mesh)
        bps = batch_pspecs(cfg, shape, ctx)
        ns = lambda t: jax.tree.map(lambda p: NamedSharding(mesh, p), t)
        with mesh:
            steps = jax.jit(make_train_step(cfg, opt, ctx),
                            in_shardings=(ns(sps), ns(bps)),
                            out_shardings=(ns(sps), None))
            ss = jax.device_put(state0, ns(sps))
            got = []
            for i in range(3):
                ss, m = steps(ss, jax.device_put(data(i), ns(bps)))
                got.append(float(m["loss"]))
        np.testing.assert_allclose(ref, got, rtol=2e-3, atol=2e-3)
        print("LOSSES", ref, got)
    """)
    assert "LOSSES" in out


@_requires("make_mesh")
def test_elastic_checkpoint_restore_across_mesh_shapes():
    """Checkpoint written from a (2,4) mesh restores onto (8,1) and (1,1)
    (elastic scaling / shrink-to-recover)."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding
        from repro.checkpoint import restore, save
        from repro.configs import get_arch, reduced
        from repro.models import init_params
        from repro.optim import AdamW
        from repro.sharding.ctx import make_ctx
        from repro.train.state import train_state_pspecs

        cfg = reduced(get_arch("granite-3-8b"))
        opt = AdamW()
        params = init_params(cfg, jax.random.key(1))
        state = {"params": params, "opt": opt.init(params), "step": jnp.int32(3)}
        d = tempfile.mkdtemp()

        mesh1 = mk_mesh((2, 4), ("data", "model"))
        ctx1 = make_ctx(False, tp_size=4)
        ns1 = jax.tree.map(lambda p: NamedSharding(mesh1, p),
                           train_state_pspecs(cfg, ctx1, opt, mesh1))
        sharded = jax.device_put(state, ns1)
        save(d, 3, sharded)

        mesh2 = mk_mesh((8, 1), ("data", "model"))
        ctx2 = make_ctx(False, tp_size=1)
        ns2 = jax.tree.map(lambda p: NamedSharding(mesh2, p),
                           train_state_pspecs(cfg, ctx2, opt, mesh2))
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored = restore(d, 3, like, shardings=ns2)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))
        print("ELASTIC OK")
    """)
    assert "ELASTIC OK" in out


@_requires("make_mesh")
def test_fleet_with_thermals_shards_across_devices():
    """run_fleet with the cooling loop enabled, replica axis device-put
    across all 8 host devices: the sharded sweep must match the
    single-device run replica by replica (rack temps, throttle seconds and
    the standard accounting all thread through vmap + sharding)."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.sim import tiny_cluster
        from repro.core import build_statics, init_state, load_jobs, run_fleet
        from repro.data import synth_workload
        from repro.scenarios import sample_scenarios

        cfg = tiny_cluster(thermal_enabled=True, rack_tau_s=120.0,
                           thermal_trip_c=22.0, throttle_start_c=20.0,
                           throttle_full_c=30.0)
        jobs, bank = synth_workload(cfg, 24, 600.0, seed=0)
        statics = build_statics(cfg, bank)
        state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
        scns = sample_scenarios(cfg, 8, seed=3)

        fs_ref, tel_ref = run_fleet(cfg, statics, state, 400, "fcfs",
                                    scenarios=scns, summary_only=True)

        mesh = mk_mesh((8,), ("replica",))
        shard = lambda t: jax.device_put(
            t, jax.tree.map(lambda _: NamedSharding(mesh, P("replica")), t))
        fs_sh, tel_sh = run_fleet(cfg, statics, state, 400, "fcfs",
                                  scenarios=shard(scns), summary_only=True)

        hot = np.asarray(fs_ref.peak_rack_c) >= cfg.thermal_trip_c
        assert hot.any(), "no replica crossed the trip threshold"
        for f in fs_ref._fields:
            a, b = getattr(fs_ref, f), getattr(fs_sh, f)
            if f == "key":
                a, b = jax.random.key_data(a), jax.random.key_data(b)
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6,
                err_msg=f"fleet field {f} diverged under sharding")
        print("FLEET_THERMAL OK")
    """)
    assert "FLEET_THERMAL OK" in out


@_requires("make_mesh", "shard_map_compat")
def test_distributed_ppo_module_trains():
    """repro.rl.distributed: shard_map PPO on a SchedEnv fleet with int8
    grad all-reduce, scanned outer loop, ppo_train-shaped history. Runs on
    the jax floor through sharding.specs.shard_map_compat (formerly gated
    on the public jax.shard_map/pcast APIs and skipped everywhere)."""
    out = _run_sub("""
        import jax
        from repro.configs.sim import tiny_cluster
        from repro.data import synth_workload
        from repro.envs import SchedEnv
        from repro.launch.mesh import make_fleet_mesh
        from repro.rl.distributed import distributed_ppo_train
        from repro.rl.ppo import PPOConfig

        cfg = tiny_cluster(sched_max_candidates=4)
        wls = [synth_workload(cfg, 16, 600.0, seed=s) for s in range(2)]
        env = SchedEnv(cfg, wls, episode_steps=6, sim_steps_per_action=5)
        mesh = make_fleet_mesh(8)   # axis defaults to the mesh's own name
        params, hist = distributed_ppo_train(
            env, mesh, cfg=PPOConfig(n_envs=8, rollout_len=6, n_epochs=1,
                                     n_minibatches=1),
            n_iterations=3, compress=True, sync_every=2)
        assert len(hist) == 3
        import numpy as np
        # same per-iteration stat interface as ppo_train (+ total loss)
        for k in ("loss", "mean_reward", "mean_episode_return",
                  "mean_episode_len", "mean_value", "pg_loss", "v_loss",
                  "entropy", "approx_kl"):
            assert all(np.isfinite(h[k]) for h in hist), k
        print("DIST_PPO OK")
    """)
    assert "DIST_PPO OK" in out


@_requires("make_mesh", "shard_map_compat")
def test_distributed_ppo_with_compressed_psum():
    """shard_map DP PPO gradient step with int8-compressed all-reduce."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import compressed_psum
        from repro.rl.policy import ActorCritic
        from repro.sharding.specs import pcast_varying, shard_map_compat

        mesh = mk_mesh((8,), ("data",))
        pol = ActorCritic(16, 4)
        params = pol.init(jax.random.key(0))
        obs = jax.random.normal(jax.random.key(1), (64, 16))
        tgt = jax.random.normal(jax.random.key(2), (64,))

        def local_grads(params, obs, tgt):
            def loss(p):
                return jnp.mean((pol.apply(p, obs)[1] - tgt) ** 2)
            return jax.grad(loss)(params)

        def step_local(params, obs, tgt):
            # mark params shard-varying so jax.grad stays LOCAL (otherwise
            # shard_map AD inserts its own psum and we'd reduce twice; on
            # the jax floor pcast_varying is a no-op and check_rep=False
            # inside shard_map_compat has the same effect)
            params = pcast_varying(params, "data")
            g = local_grads(params, obs, tgt)
            g, _ = compressed_psum(g, "data")
            return g

        step = shard_map_compat(
            step_local, mesh,
            in_specs=(jax.tree.map(lambda _: P(), params), P("data"),
                      P("data")),
            out_specs=jax.tree.map(lambda _: P(), params))
        g_c = step(params, obs, tgt)
        g_ref = local_grads(params, obs, tgt)  # full-batch reference
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(g_c), jax.tree.leaves(g_ref)))
        print("ERR", err)
        assert err < 0.05
    """)
    assert "ERR" in out


@_requires("make_mesh", "shard_map_compat")
def test_sharded_fleet_bit_identical_to_vmapped():
    """run_fleet(mesh=...) vs the vmapped path, macro engine ON with
    thermals AND faults enabled: final states (including the PRNG
    streams), telemetry and fleet_summary must match BITWISE — the shard
    boundary only changes which device hosts each replica's while-loop,
    never a single op in it (the split/fold_in key schedule runs on the
    host before the compiled call, shared by both paths)."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.sim import tiny_cluster
        from repro.core import (build_statics, fleet_summary, init_state,
                                load_jobs, run_fleet)
        from repro.data import synth_workload
        from repro.launch.mesh import make_fleet_mesh
        from repro.scenarios import sample_scenarios

        cfg = tiny_cluster(thermal_enabled=True, node_mtbf_hours=0.5,
                           node_repair_hours=0.2, rack_mtbf_hours=1.5,
                           rack_repair_hours=0.3, ckpt_interval_s=240.0,
                           ckpt_overhead_s=20.0, max_job_retries=3)
        jobs, bank = synth_workload(cfg, 32, 900.0, seed=0)
        statics = build_statics(cfg, bank)
        st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
        scns = sample_scenarios(cfg, 8, seed=7)

        sv, tv = run_fleet(cfg, statics, st, 400, "fcfs", scenarios=scns,
                           macro=True, summary_only=True)
        mesh = make_fleet_mesh(8)
        ss, ts = run_fleet(cfg, statics, st, 400, "fcfs", scenarios=scns,
                           macro=True, summary_only=True, mesh=mesh)

        assert float(jnp.sum(sv.n_killed)) > 0, "faults never fired"
        for f in sv._fields:
            a, b = getattr(sv, f), getattr(ss, f)
            if f == "key":   # the per-replica PRNG streams themselves
                a, b = jax.random.key_data(a), jax.random.key_data(b)
            assert np.array_equal(np.asarray(a), np.asarray(b)), \\
                f"state field {f} not bit-identical under sharding"
        for f in tv._fields:
            assert np.array_equal(np.asarray(getattr(tv, f)),
                                  np.asarray(getattr(ts, f))), \\
                f"telemetry field {f} not bit-identical under sharding"
        for dv, ds in zip(fleet_summary(sv, tv), fleet_summary(ss, ts)):
            assert dv == ds
        print("SHARDED_BITWISE OK")
    """)
    assert "SHARDED_BITWISE OK" in out


@_requires("make_mesh", "shard_map_compat")
def test_sharded_fleet_uneven_replicas_loud_error():
    """R not divisible by the mesh size must raise before tracing — a
    silent pad would fabricate replicas whose summaries pollute sweep
    statistics."""
    out = _run_sub("""
        import jax
        from repro.configs.sim import tiny_cluster
        from repro.core import build_statics, init_state, load_jobs, run_fleet
        from repro.data import synth_workload
        from repro.launch.mesh import make_fleet_mesh
        from repro.scenarios import sample_scenarios

        cfg = tiny_cluster()
        jobs, bank = synth_workload(cfg, 8, 300.0, seed=0)
        statics = build_statics(cfg, bank)
        st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
        mesh = make_fleet_mesh(8)
        try:
            run_fleet(cfg, statics, st, 10, "fcfs",
                      scenarios=sample_scenarios(cfg, 6, seed=3), mesh=mesh)
        except ValueError as e:
            assert "6 replicas" in str(e) and "8" in str(e), e
            print("UNEVEN_LOUD OK")
        else:
            raise SystemExit("6 replicas across 8 devices did not raise")

        # wrong axis name is equally loud
        try:
            run_fleet(cfg, statics, st, 10, "fcfs",
                      scenarios=sample_scenarios(cfg, 8, seed=3),
                      mesh=mesh, mesh_axis="data")
        except ValueError as e:
            assert "data" in str(e), e
            print("AXIS_LOUD OK")
        else:
            raise SystemExit("bogus mesh_axis did not raise")
    """)
    assert "UNEVEN_LOUD OK" in out and "AXIS_LOUD OK" in out
