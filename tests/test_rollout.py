"""Bank-indexed lightweight-state rollout engine (PR4).

Equivalence: the refactor moved the trace bank out of the per-env state
(shared banked Statics + traced workload id), split the idle sub-steps
off the dispatching step, and fused the observation path — all of which
must be *behavior-preserving*. ``benchmarks.bench_rl._HeavyEnv`` re-creates
the pre-PR4 layout (per-env Statics copy, dispatch through every
sub-step, loop-based observe) around the same twin, so old-vs-new runs
executable in one process; a hardcoded reward trace pinned from the
actual pre-PR4 code guards against both drifting together.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.sim import tiny_cluster
from repro.core.fleet import fleet_summary, run_fleet
from repro.core.state import build_statics, init_state, load_jobs
from repro.data import stack_workloads, synth_workload
from repro.envs import EnvState, SchedEnv
from repro.envs.sched_env import (
    CANDIDATE_FEATURES,
    GLOBAL_FEATURES,
    TYPE_FEATURES,
)

from benchmarks.bench_rl import _HeavyEnv

# rewards of the scripted episode below, recorded by running the PRE-PR4
# SchedEnv (per-env Statics, per-call make_step, always-dispatch sub-steps)
# with the same seeds/actions — the anchor that pins "identical rewards
# across the bank-indexed refactor" to the actual old code, not merely to
# the in-repo legacy emulation
SCRIPTED_ACTIONS = (0, 1, 4, 2, 0, 3, 4, 1)
PRE_PR_REWARDS = (
    -0.4411873519420624, -0.44118732213974, -0.45492321252822876,
    -0.45492321252822876, -0.46987271308898926, -0.46987268328666687,
    -0.4805428087711334, -0.48304271697998047,
)


@pytest.fixture(scope="module")
def env():
    cfg = tiny_cluster(sched_max_candidates=4)
    wls = [synth_workload(cfg, 24, 900.0, seed=s) for s in range(2)]
    return SchedEnv(cfg, wls, episode_steps=8, sim_steps_per_action=5)


def test_scripted_rollout_pins_pre_pr_rewards(env):
    st, _ = env.reset(jax.random.key(0))
    step = jax.jit(env.step)
    rewards = []
    for a in SCRIPTED_ACTIONS:
        st, _, r, _, _ = step(st, jnp.int32(a))
        rewards.append(float(r))
    # exact on the authoring platform, but the dense one-hot contraction's
    # dot accumulation order is backend-dependent — a tight tolerance keeps
    # the anchor meaningful (semantic drift would be orders larger) without
    # pinning XLA's reduction order; bitwise old-vs-new is covered by
    # test_scripted_rollout_matches_legacy_layout, which shares kernels
    np.testing.assert_allclose(rewards, np.asarray(PRE_PR_REWARDS),
                               rtol=1e-6, atol=1e-7)


def test_scripted_rollout_matches_legacy_layout(env):
    """Same seed + same actions -> bitwise-identical rewards, observations
    and final sim state between the new engine and the pre-PR4 layout."""
    heavy = _HeavyEnv(env)
    st_n, obs_n = env.reset(jax.random.key(0))
    st_h, obs_h = heavy.reset(jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(obs_n), np.asarray(obs_h))
    step_n, step_h = jax.jit(env.step), jax.jit(heavy.step)
    for a in SCRIPTED_ACTIONS:
        st_n, obs_n, r_n, d_n, _ = step_n(st_n, jnp.int32(a))
        st_h, obs_h, r_h, d_h, _ = step_h(st_h, jnp.int32(a))
        np.testing.assert_array_equal(np.asarray(r_n), np.asarray(r_h))
        np.testing.assert_array_equal(np.asarray(obs_n), np.asarray(obs_h))
        assert bool(d_n) == bool(d_h)
    for f in st_n.sim._fields:
        if f == "workload":      # legacy keeps the id in its statics copy
            continue
        a, b = getattr(st_n.sim, f), getattr(st_h.sim, f)
        if f == "key":
            a, b = jax.random.key_data(a), jax.random.key_data(b)
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"sim.{f} diverged across the bank-indexed refactor")


def test_observe_matches_legacy_features(env):
    """Fused observe() (one-hot type reduction, precomputed invariants,
    hoisted placement mask) is bit-equivalent to the loop-based original —
    checked on fresh and mid-episode states, and for a masking placement
    backend (partition)."""
    for placement in ("first_fit", "partition"):
        e = SchedEnv(env.cfg,
                     [synth_workload(env.cfg, 24, 900.0, seed=s)
                      for s in range(2)],
                     episode_steps=8, sim_steps_per_action=5,
                     placement=placement)
        heavy = _HeavyEnv(e)
        st, obs = e.reset(jax.random.key(3))
        st_h, obs_h = heavy.reset(jax.random.key(3))
        np.testing.assert_array_equal(np.asarray(obs), np.asarray(obs_h))
        for a in (0, 2, 1):
            st, obs, *_ = e.step(st, jnp.int32(a))
            st_h, obs_h, *_ = heavy.step(st_h, jnp.int32(a))
            np.testing.assert_array_equal(np.asarray(obs), np.asarray(obs_h))


def test_env_state_is_lightweight(env):
    """EnvState carries NO per-env trace bank: just the sim + counter."""
    assert EnvState._fields == ("sim", "step_count")
    n_envs = 8
    sts, _ = jax.vmap(env.reset)(jax.random.split(jax.random.key(0), n_envs))
    bank = env.statics
    assert bank.cpu_trace.ndim == 3          # shared (W, J, Q) bank
    bank_slice_bytes = (bank.cpu_trace.nbytes + bank.gpu_trace.nbytes
                        + bank.net_tx.nbytes) // env.n_workloads

    def nbytes(leaf):
        if jnp.issubdtype(leaf.dtype, jax.dtypes.prng_key):
            leaf = jax.random.key_data(leaf)
        return leaf.nbytes

    state_bytes = sum(nbytes(leaf) for leaf in jax.tree.leaves(sts))
    per_env = state_bytes / n_envs
    # the old layout carried >= one bank slice per env; the new state is a
    # small multiple of the sim state and far below a single slice
    assert per_env < bank_slice_bytes, (per_env, bank_slice_bytes)
    # and no leaf of the batched state has the bank's (J, Q) trailing shape
    J, Q = bank.cpu_trace.shape[1:]
    for leaf in jax.tree.leaves(sts):
        assert leaf.shape[1:] != (J, Q)
    # the workload selector is a scalar int32 per env
    assert sts.sim.workload.shape == (n_envs,)
    assert sts.sim.workload.dtype == jnp.int32


def test_step_function_built_once(env, monkeypatch):
    """SchedEnv.step must not rebuild the step closure per call."""
    import repro.envs.sched_env as mod

    def boom(*a, **kw):
        raise AssertionError("make_step called after __init__")

    monkeypatch.setattr(mod, "make_step", boom)
    st, _ = env.reset(jax.random.key(0))
    env.step(st, jnp.int32(0))               # uses the cached step fns


def test_obs_spec_derived_from_shared_feature_spec(env):
    from repro.core import placement as plc

    want = (len(GLOBAL_FEATURES) + len(plc.PLACEMENTS)
            + len(TYPE_FEATURES) * env.cfg.n_types
            + len(CANDIDATE_FEATURES) * env.k)
    assert env.obs_dim == want
    _, obs = env.reset(jax.random.key(0))
    assert obs.shape == (want,)


# ----------------------------------------------------------- fleet x bank
def test_fleet_workload_axis_matches_unbatched_runs():
    """run_fleet(workloads=ids) over one banked Statics reproduces the
    per-workload unbatched runs exactly."""
    cfg = tiny_cluster()
    wls = [synth_workload(cfg, 24, 900.0, seed=s) for s in range(2)]
    jobs, bank = stack_workloads(cfg, wls)
    statics = build_statics(cfg, bank)
    # both replicas replay workload 0's JOB TABLE but workload-id-selected
    # telemetry, so any energy difference comes from the bank indexing
    st = load_jobs(init_state(cfg, statics, jax.random.key(0)), wls[0][0])
    fs, _ = run_fleet(cfg, statics, st, 400, "fcfs", workloads=[0, 1],
                      scenarios=[statics.scenario] * 2, summary_only=True)
    rows = fleet_summary(fs)
    assert rows[0]["energy_kwh"] != rows[1]["energy_kwh"]

    for w in (0, 1):
        st2d = build_statics(cfg, {
            "cpu": np.asarray(bank["cpu"][w]),
            "gpu": np.asarray(bank["gpu"][w]),
            "net_tx": np.asarray(bank["net_tx"][w]),
        })
        st0 = load_jobs(init_state(cfg, st2d, jax.random.key(0)), wls[0][0])
        fs1, _ = run_fleet(cfg, st2d, st0, 400, "fcfs", summary_only=True)
        ref = fleet_summary(fs1)[0]
        assert ref["energy_kwh"] == pytest.approx(
            rows[w]["energy_kwh"], rel=1e-6)


def test_fleet_workload_axis_validation():
    cfg = tiny_cluster()
    wls = [synth_workload(cfg, 16, 600.0, seed=s) for s in range(2)]
    _, bank = stack_workloads(cfg, wls)
    banked = build_statics(cfg, bank)
    flat = build_statics(cfg, wls[0][1])
    st = load_jobs(init_state(cfg, banked, jax.random.key(0)), wls[0][0])
    with pytest.raises(ValueError, match="banked"):
        run_fleet(cfg, flat, st, 10, "fcfs", workloads=[0])
    with pytest.raises(ValueError, match="one bank id per replica"):
        run_fleet(cfg, banked, st, 10, "fcfs", workloads=[0, 1, 0])


# ------------------------------------------------------------------- ppo
def test_ppo_scanned_loop_matches_unfused_and_reports_ep_len(env):
    """The lax.scan-chunked outer loop (one device_get per window) yields
    the same history as per-iteration syncing, and surfaces the
    once-dead episode-length stat."""
    from repro.rl import PPOConfig, ppo_train

    kw = dict(cfg=PPOConfig(n_envs=2, rollout_len=4, n_epochs=1,
                            n_minibatches=1),
              n_iterations=3, seed=7)
    _, h_fused = ppo_train(env, sync_every=3, **kw)
    _, h_steps = ppo_train(env, sync_every=1, **kw)
    assert len(h_fused) == len(h_steps) == 3
    for a, b in zip(h_fused, h_steps):
        assert set(a) == set(b)
        for k in a:
            assert a[k] == pytest.approx(b[k], rel=1e-5), k
    assert all("mean_episode_len" in h and np.isfinite(h["mean_episode_len"])
               for h in h_fused)
