"""Simulator invariants (unit + hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401
from _hypothesis_compat import given, settings, st

from repro.configs.sim import tiny_cluster
from repro.core import (
    QUEUED,
    RUNNING,
    build_statics,
    init_state,
    load_jobs,
    make_step,
    run_episode,
    summary,
)
from repro.data import synth_workload


def _setup(seed=0, n_jobs=32, horizon=1200.0, **cfg_kw):
    cfg = tiny_cluster(**cfg_kw)
    jobs, bank = synth_workload(cfg, n_jobs, horizon, seed=seed)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(seed)), jobs)
    return cfg, statics, state, jobs


def test_resources_never_oversubscribed():
    cfg, statics, state, _ = _setup()
    step = make_step(cfg, statics, "fcfs")

    s = state
    for _ in range(300):
        s, _ = jax.jit(step)(s, jnp.int32(-1))
    free = np.asarray(s.free)
    cap = np.asarray(statics.capacity)
    assert (free >= -1e-3).all(), "negative free resources"
    assert (free <= cap + 1e-3).all(), "free exceeds capacity"


def test_energy_accounting_consistent():
    cfg, statics, state, _ = _setup()
    fs, outs = jax.jit(
        lambda s: run_episode(cfg, statics, s, 600, "fcfs")
    )(state)
    # facility energy equals the per-step integral
    total = float(jnp.sum(outs.energy_kwh_step))
    assert abs(total - float(fs.energy_kwh)) < 1e-3
    # facility = IT + losses + cooling
    parts = (float(fs.it_energy_kwh) + float(fs.loss_energy_kwh)
             + float(fs.cool_energy_kwh))
    assert abs(parts - total) / max(total, 1e-9) < 1e-3
    # PUE sane
    s = summary(fs)
    assert 1.0 < s["avg_pue"] < 2.0


def test_idle_datacenter_power_is_idle_only():
    cfg = tiny_cluster()
    statics = build_statics(cfg)
    state = init_state(cfg, statics, jax.random.key(0))
    fs, outs = jax.jit(lambda s: run_episode(cfg, statics, s, 10, "fcfs"))(state)
    expect_it = float(jnp.sum(statics.idle_w))
    np.testing.assert_allclose(np.asarray(outs.it_w), expect_it, rtol=1e-5)


def test_completed_jobs_eventually_all_finish():
    cfg, statics, state, jobs = _setup(n_jobs=16, horizon=600.0)
    fs, _ = jax.jit(lambda s: run_episode(cfg, statics, s, 8000, "fcfs"))(state)
    assert float(fs.n_completed) == 16


def test_failures_requeue_and_stats():
    cfg, statics, state, _ = _setup(node_mtbf_hours=0.05, node_repair_hours=0.01)
    fs, _ = jax.jit(lambda s: run_episode(cfg, statics, s, 3000, "fcfs"))(state)
    assert float(fs.n_killed) > 0, "MTBF 3 min should kill some jobs"
    # killed jobs are requeued and eventually complete or remain queued —
    # never lost
    states = np.asarray(fs.jstate)
    assert (states <= 3).all()


def test_sjf_improves_mean_wait_over_fcfs_on_bimodal_load():
    cfg, statics, state, _ = _setup(n_jobs=40, horizon=300.0, seed=3)
    r = {}
    for sched in ("fcfs", "sjf"):
        fs, _ = jax.jit(
            lambda s, sched=sched: run_episode(cfg, statics, s, 4000, sched)
        )(state)
        r[sched] = summary(fs)
    assert r["sjf"]["mean_slowdown"] <= r["fcfs"]["mean_slowdown"] * 1.05


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), sched=st.sampled_from(["fcfs", "sjf", "easy"]))
def test_property_invariants_random_workloads(seed, sched):
    cfg, statics, state, _ = _setup(seed=seed, n_jobs=24, horizon=900.0)
    fs, outs = jax.jit(
        lambda s: run_episode(cfg, statics, s, 500, sched)
    )(state)
    # power within physical bounds
    pmax = float(jnp.sum(statics.node_max_w)) * 1.4 / 0.9 + 1.0
    assert float(jnp.max(outs.facility_w)) <= pmax
    assert float(jnp.min(outs.facility_w)) >= 0.0
    # job-state machine: no job both running and done; counts conserved
    js = np.asarray(fs.jstate)
    assert ((js >= 0) & (js <= 3)).all()
    # completions monotone: completed_now never negative
    assert float(jnp.min(outs.completed_now)) >= 0.0
    # free resources bounded
    assert (np.asarray(fs.free) >= -1e-3).all()
