"""The checkify invariant harness (utils.invariants): env gating, clean
passes in eager / jit-functionalized / batched modes, and detection of
each corruption class the suite guards."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.checkify import JaxRuntimeError

from repro.configs.sim import tiny_cluster
from repro.core import build_statics, init_state, load_jobs, run_episode
from repro.data import synth_workload
from repro.utils import invariants


def _setup(seed=0, **cfg_kw):
    cfg = tiny_cluster(**cfg_kw)
    jobs, bank = synth_workload(cfg, 16, 600.0, seed=seed)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(seed)), jobs)
    return cfg, statics, state


def test_env_gating(monkeypatch):
    monkeypatch.delenv("REPRO_CHECKIFY", raising=False)
    assert not invariants.enabled()
    monkeypatch.setenv("REPRO_CHECKIFY", "0")
    assert not invariants.enabled()
    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    assert invariants.enabled()


def test_clean_state_passes_eagerly():
    cfg, statics, state = _setup()
    invariants.check_state(cfg, statics, state)   # must not raise


@pytest.mark.parametrize("corrupt,label", [
    (lambda s: s._replace(free=s.free + 100.0), "free exceeds capacity"),
    (lambda s: s._replace(free=s.free - 1.0), "negative free"),
    (lambda s: s._replace(jstate=s.jstate.at[0].set(9)), "bad jstate"),
    (lambda s: s._replace(node_up=s.node_up.at[0].set(0.5)), "node_up"),
    (lambda s: s._replace(energy_kwh=jnp.float32(jnp.nan)), "NaN energy"),
    (lambda s: s._replace(rack_outlet_c=s.rack_outlet_c + 1e4), "thermal"),
    (lambda s: s._replace(lost_node_s=jnp.float32(-1.0)), "lost work"),
    (lambda s: s._replace(placement=s.placement.at[0, 0].set(0)),
     "placement without RUNNING"),
])
def test_corruption_detected(corrupt, label):
    cfg, statics, state = _setup()
    with pytest.raises(JaxRuntimeError):
        invariants.check_state(cfg, statics, corrupt(state))


def test_batched_state_checked():
    """The suite broadcasts over a leading replica axis — one corrupt
    replica in a batch is enough to fail (the run_fleet audit path)."""
    cfg, statics, state = _setup()
    batched = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (3,) + jnp.shape(a)), state)
    invariants.check_state(cfg, statics, batched)
    bad = batched._replace(free=batched.free.at[1].add(50.0))
    with pytest.raises(JaxRuntimeError):
        invariants.check_state(cfg, statics, bad)


def test_run_episode_checkified_clean(monkeypatch):
    """REPRO_CHECKIFY=1: the per-step suite rides inside the compiled
    episode via checkify functionalization — per-tick AND macro — and a
    healthy run passes."""
    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    cfg, statics, state = _setup(node_mtbf_hours=0.5, node_repair_hours=0.1)
    run_episode(cfg, statics, state, 300, "fcfs", summary_only=True)
    run_episode(cfg, statics, state, 300, "fcfs", summary_only=True,
                macro=True)


def test_run_episode_checkified_catches_corruption(monkeypatch):
    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    cfg, statics, state = _setup()
    bad = state._replace(free=state.free + 100.0)
    with pytest.raises(JaxRuntimeError):
        run_episode(cfg, statics, bad, 10, "fcfs", summary_only=True)


def test_run_fleet_posthoc_audit(monkeypatch):
    """REPRO_CHECKIFY=1 run_fleet audits every replica's final state."""
    from repro.core import run_fleet
    from repro.scenarios import default_scenario

    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    cfg, statics, state = _setup(node_mtbf_hours=0.5, node_repair_hours=0.1)
    run_fleet(cfg, statics, state, 200, "fcfs",
              scenarios=[default_scenario(cfg)] * 2, summary_only=True)


def test_disabled_means_zero_overhead_program(monkeypatch):
    """With the gate off, run_episode takes the plain (non-checkified)
    path — the invariant suite costs nothing unless asked for."""
    monkeypatch.delenv("REPRO_CHECKIFY", raising=False)
    cfg, statics, state = _setup()
    bad = state._replace(free=state.free + 100.0)
    # corrupt state sails through: no checks compiled in
    run_episode(cfg, statics, bad, 5, "fcfs", summary_only=True)
