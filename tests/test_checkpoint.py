"""Checkpoint substrate: atomic write, restore, resume-from-latest, async."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore,
    save,
)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)),
                   "b": jnp.zeros((4,), jnp.bfloat16)},
        "step": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out = restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_and_gc(tmp_path):
    t = _tree()
    for s in (1, 5, 9, 12):
        save(str(tmp_path), s, t, keep=2)
    assert latest_step(str(tmp_path)) == 12
    kept = sorted(os.listdir(tmp_path))
    assert len([d for d in kept if d.startswith("step_")]) == 2


def test_no_tmp_dir_left_behind(tmp_path):
    save(str(tmp_path), 3, _tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape"):
        restore(str(tmp_path), 1, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    t = _tree()
    ck.save(4, t)
    ck.wait()
    assert latest_step(str(tmp_path)) == 4


def test_train_resume_is_exact(tmp_path):
    """Fault-tolerance: kill-and-resume reproduces the uninterrupted run
    exactly (deterministic data + seekable pipeline + checkpoint)."""
    from repro.configs import get_arch, reduced
    from repro.data.synth_lm import lm_batch_at
    from repro.models import init_params
    from repro.optim import AdamW
    from repro.train.train_step import make_train_step

    cfg = reduced(get_arch("qwen3-4b"))
    opt = AdamW(lr=1e-3)
    params = init_params(cfg, jax.random.key(0))
    state = {"params": params, "opt": opt.init(params), "step": jnp.int32(0)}
    step_fn = jax.jit(make_train_step(cfg, opt))

    def data(i):
        return lm_batch_at(i, vocab=cfg.vocab, batch=2, seq_len=32)

    # uninterrupted: 6 steps
    s = state
    for i in range(6):
        s, _ = step_fn(s, data(i))
    ref_loss = None
    _, m = step_fn(s, data(6))
    ref_loss = float(m["loss"])

    # interrupted at step 3
    s2 = state
    for i in range(3):
        s2, _ = step_fn(s2, data(i))
    save(str(tmp_path), 3, s2)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s2)
    s3 = restore(str(tmp_path), 3, like)
    s3 = jax.tree.map(jnp.asarray, s3)
    for i in range(3, 6):
        s3, _ = step_fn(s3, data(i))
    _, m2 = step_fn(s3, data(6))
    assert abs(float(m2["loss"]) - ref_loss) < 1e-6
