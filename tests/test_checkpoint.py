"""Checkpoint substrate: atomic write, restore, resume-from-latest, async."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore,
    save,
)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)),
                   "b": jnp.zeros((4,), jnp.bfloat16)},
        "step": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out = restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_and_gc(tmp_path):
    t = _tree()
    for s in (1, 5, 9, 12):
        save(str(tmp_path), s, t, keep=2)
    assert latest_step(str(tmp_path)) == 12
    kept = sorted(os.listdir(tmp_path))
    assert len([d for d in kept if d.startswith("step_")]) == 2


def test_no_tmp_dir_left_behind(tmp_path):
    save(str(tmp_path), 3, _tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape"):
        restore(str(tmp_path), 1, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    t = _tree()
    ck.save(4, t)
    ck.wait()
    assert latest_step(str(tmp_path)) == 4


def test_train_resume_is_exact(tmp_path):
    """Fault-tolerance: kill-and-resume reproduces the uninterrupted run
    exactly (deterministic data + seekable pipeline + checkpoint)."""
    from repro.configs import get_arch, reduced
    from repro.data.synth_lm import lm_batch_at
    from repro.models import init_params
    from repro.optim import AdamW
    from repro.train.train_step import make_train_step

    cfg = reduced(get_arch("qwen3-4b"))
    opt = AdamW(lr=1e-3)
    params = init_params(cfg, jax.random.key(0))
    state = {"params": params, "opt": opt.init(params), "step": jnp.int32(0)}
    step_fn = jax.jit(make_train_step(cfg, opt))

    def data(i):
        return lm_batch_at(i, vocab=cfg.vocab, batch=2, seq_len=32)

    # uninterrupted: 6 steps
    s = state
    for i in range(6):
        s, _ = step_fn(s, data(i))
    ref_loss = None
    _, m = step_fn(s, data(6))
    ref_loss = float(m["loss"])

    # interrupted at step 3
    s2 = state
    for i in range(3):
        s2, _ = step_fn(s2, data(i))
    save(str(tmp_path), 3, s2)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s2)
    s3 = restore(str(tmp_path), 3, like)
    s3 = jax.tree.map(jnp.asarray, s3)
    for i in range(3, 6):
        s3, _ = step_fn(s3, data(i))
    _, m2 = step_fn(s3, data(6))
    assert abs(float(m2["loss"]) - ref_loss) < 1e-6


def test_typed_prng_key_roundtrip(tmp_path):
    """Typed PRNG key leaves survive save/restore exactly (impl recorded
    in the manifest, key data re-wrapped on restore) — the property that
    makes snapshot/resume of a mid-episode SimState bit-identical."""
    k = jax.random.key(42)
    t = {"key": k, "keys": jax.random.split(k, 4)}
    save(str(tmp_path), 0, t)
    out = restore(str(tmp_path), 0, t)
    assert jax.dtypes.issubdtype(out["key"].dtype, jax.dtypes.prng_key)
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(out["key"])),
        np.asarray(jax.random.key_data(k)))
    # and the restored key produces the same stream
    np.testing.assert_array_equal(
        np.asarray(jax.random.uniform(out["key"], (3,))),
        np.asarray(jax.random.uniform(k, (3,))))


def test_missing_manifest_raises_checkpoint_error(tmp_path):
    from repro.utils.errors import CheckpointError

    with pytest.raises(CheckpointError, match="manifest"):
        restore(str(tmp_path), 9, {"w": jnp.zeros((2,))})


def test_corrupt_manifest_raises_checkpoint_error(tmp_path):
    from repro.utils.errors import CheckpointError

    save(str(tmp_path), 1, {"w": jnp.zeros((2,))})
    with open(tmp_path / "step_0000000001" / "manifest.json", "w") as f:
        f.write("{not json")
    with pytest.raises(CheckpointError, match="manifest"):
        restore(str(tmp_path), 1, {"w": jnp.zeros((2,))})


def test_manifest_leaf_mismatch_raises_checkpoint_error(tmp_path):
    """A leaf present in the template but absent from the snapshot is a
    manifest/leaf mismatch, not a silent zero-fill."""
    from repro.utils.errors import CheckpointError

    save(str(tmp_path), 1, {"w": jnp.zeros((2,))})
    with pytest.raises(CheckpointError, match="mismatch"):
        restore(str(tmp_path), 1,
                {"w": jnp.zeros((2,)), "extra": jnp.zeros((3,))})


def test_stale_tmp_dir_is_invisible_and_swept(tmp_path):
    """A SIGKILL mid-write leaves step_<N>.tmp behind; latest_step must
    never report it as a resumable snapshot and sweeps it."""
    save(str(tmp_path), 2, {"w": jnp.zeros((2,))})
    stale = tmp_path / "step_0000000007.tmp"
    stale.mkdir()
    (stale / "w.npy").write_bytes(b"torn write")
    assert latest_step(str(tmp_path)) == 2
    assert not stale.exists(), "stale tmp dir should be swept"
