"""Crash-chaos harness: SIGKILL real subprocess runs (including
mid-checkpoint-write) and assert killed+resumed == uninterrupted."""

import json
import os
import sys
import textwrap

import pytest

from repro.utils.chaos import ChaosResult, chaos_run, chaos_smoke


def test_chaos_run_kill_loop_semantics(tmp_path):
    """The kill-loop on a trivial resumable worker: each launch appends
    one line then either dies or finishes; the loop must deliver exactly
    the configured kills and a clean final run."""
    marker = tmp_path / "progress.txt"
    script = tmp_path / "worker.py"
    script.write_text(textwrap.dedent(f"""
        import sys, time
        with open({str(marker)!r}, "a") as f:
            f.write("attempt\\n")
        time.sleep(30)   # long enough that every kill window hits
        sys.exit(0)
    """))
    # 1 kill, then the final launch must survive -> but this worker
    # sleeps 30s, so give the final attempt a small timeout and expect
    # the loud overrun error (proves the final run is NOT killed quietly)
    with pytest.raises(RuntimeError, match="overran"):
        chaos_run([sys.executable, str(script)], kills=1, min_delay_s=0.2,
                  max_delay_s=0.4, seed=1, timeout_s=2.0)
    assert marker.read_text().count("attempt") == 2  # killed + final


def test_chaos_run_reports_nonzero_exit(tmp_path):
    script = tmp_path / "bad.py"
    script.write_text("import sys; print('boom'); sys.exit(3)")
    with pytest.raises(RuntimeError, match="exited 3"):
        chaos_run([sys.executable, str(script)], kills=0)


def test_chaos_result_stats_roundtrip(tmp_path):
    p = tmp_path / "s.json"
    p.write_text(json.dumps({"a": 1}))
    assert ChaosResult(n_kills=0, stats_path=str(p)).stats() == {"a": 1}


@pytest.mark.slow
def test_replay_survives_sigkill_mid_write(tmp_path):
    """End-to-end: a macro replay (faults + serving ON) is SIGKILLed at
    randomized points with the checkpoint rename window stretched so
    kills land mid-write; the resumed run's final SimState digest,
    telemetry digest and summary() reprs equal the uninterrupted run's."""
    out = chaos_smoke("replay", str(tmp_path), kills=1, seed=0,
                      slow_save_s=0.2, n_steps=400, snapshot_every_s=60.0)
    assert out["n_kills"] == 1
    assert out["attempts"][-1]["killed"] is False


@pytest.mark.slow
def test_ppo_survives_sigkill(tmp_path):
    """Same contract for PPO training: kill mid-run, resume from the
    latest iteration checkpoint, final params digest + history tail are
    bit-identical to the uninterrupted run."""
    out = chaos_smoke("ppo", str(tmp_path), kills=1, seed=0,
                      iters=6, ckpt_every=2)
    assert out["attempts"][-1]["returncode"] == 0
