"""Demand-response (power-cap / DVFS throttle) policy tests — the DCFlex
scenario the paper motivates: cap facility power, stretch job runtimes."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sim import tiny_cluster
from repro.core import build_statics, init_state, load_jobs, run_episode, summary
from repro.data import synth_workload


def _run(cap):
    cfg = tiny_cluster(power_cap_w=cap)
    jobs, bank = synth_workload(cfg, 24, 600.0, seed=8)
    statics = build_statics(cfg, bank)
    st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    fs, outs = jax.jit(lambda s: run_episode(cfg, statics, s, 2500, "fcfs"))(st)
    return cfg, fs, outs


def test_power_cap_respected():
    cfg_u, fs_u, outs_u = _run(0.0)
    peak_uncapped = float(jnp.max(outs_u.facility_w))
    cap = peak_uncapped * 0.8
    cfg_c, fs_c, outs_c = _run(cap)
    assert float(jnp.max(outs_c.facility_w)) <= cap * 1.02


def test_power_cap_stretches_work():
    _, fs_u, _ = _run(0.0)
    _, fs_c, _ = _run(float(fs_u.sum_power_w / fs_u.n_steps) * 0.85)
    # same horizon, throttled datacenter completes fewer (or equal) jobs
    assert float(fs_c.n_completed) <= float(fs_u.n_completed)
    # but consumed less energy
    assert float(fs_c.energy_kwh) < float(fs_u.energy_kwh)


def test_throttle_floor_keeps_progress():
    cfg, fs, outs = _run(1.0)  # absurd 1 W cap -> floor kicks in
    # throttle floor (30%) still lets jobs progress
    assert float(fs.n_completed) > 0
