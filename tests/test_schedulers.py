"""Scheduler policy unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.sim import tiny_cluster
from repro.core import build_statics, init_state, load_jobs, run_episode, summary
from repro.core import schedulers as sched
from repro.core.state import QUEUED


def _state_with(cfg, submit, dur, n_nodes, prio=None):
    statics = build_statics(cfg)
    state = init_state(cfg, statics, jax.random.key(0))
    n = len(submit)
    jobs = {
        "submit_t": np.asarray(submit, np.float32),
        "dur": np.asarray(dur, np.float32),
        "n_nodes": np.asarray(n_nodes, np.int32),
        "req": np.tile(np.array([[4.0], [0.0], [8.0]], np.float32), (1, n)),
        "priority": np.asarray(prio if prio is not None else submit, np.float32),
    }
    return statics, load_jobs(state, jobs)._replace(t=jnp.float32(100.0))


def test_fcfs_picks_earliest_submitted():
    cfg = tiny_cluster()
    statics, state = _state_with(cfg, [5.0, 1.0, 3.0], [60, 60, 60], [1, 1, 1])
    assert int(sched.select_fcfs(cfg, state, statics)) == 1


def test_sjf_picks_shortest():
    cfg = tiny_cluster()
    statics, state = _state_with(cfg, [1, 2, 3], [500, 50, 100], [1, 1, 1])
    assert int(sched.select_sjf(cfg, state, statics)) == 1


def test_priority_picks_highest():
    cfg = tiny_cluster()
    statics, state = _state_with(cfg, [1, 2, 3], [10, 10, 10], [1, 1, 1],
                                 prio=[0.0, 9.0, 4.0])
    assert int(sched.select_priority(cfg, state, statics)) == 1


def test_replay_waits_for_recorded_start():
    cfg = tiny_cluster()
    statics, state = _state_with(cfg, [0.0, 0.0], [60, 60], [1, 1],
                                 prio=[500.0, 50.0])  # recorded starts
    # t=100: only job 1 (start 50) is due
    assert int(sched.select_replay(cfg, state, statics)) == 1


def test_first_fit_respects_capacity():
    cfg = tiny_cluster()
    _, state = _state_with(cfg, [0.0], [60], [3])
    row, ok = sched.first_fit(state, jnp.int32(0), cfg.max_nodes_per_job)
    assert bool(ok)
    row = np.asarray(row)
    assert (row[:3] >= 0).all() and (row[3:] == -1).all()
    assert len(set(row[:3].tolist())) == 3  # distinct nodes


def test_first_fit_infeasible_when_too_large():
    cfg = tiny_cluster()
    _, state = _state_with(cfg, [0.0], [60], [cfg.max_nodes_per_job])
    # request more nodes than exist with gpu=0 requirement -> feasible count
    state = state._replace(n_nodes=state.n_nodes.at[0].set(cfg.n_nodes + 1))
    _, ok = sched.first_fit(state, jnp.int32(0), cfg.max_nodes_per_job)
    assert not bool(ok)


def test_easy_backfills_short_job_past_blocked_head():
    """Node-exclusive jobs: job0 holds 7/8 nodes; the head wants all 8 and
    must wait; a short 1-node job backfills into the free node under EASY
    but NOT under plain FCFS."""
    from repro.configs.sim import NodeType, SimConfig
    from repro.core.sim import make_step

    cfg = SimConfig(
        name="uniform",
        node_types=(NodeType("n", 8, 16, 0, 64.0, 100.0, 200.0, 0.0, 0.0,
                             1000.0),),
        max_jobs=16, max_nodes_per_job=8, sched_max_candidates=4,
    )
    statics = build_statics(cfg)
    jobs = {
        "submit_t": np.array([0.0, 1.0, 2.0], np.float32),
        "dur": np.array([1000.0, 1000.0, 30.0], np.float32),
        "n_nodes": np.array([7, 8, 1], np.int32),
        # 16 cores/node = node-exclusive
        "req": np.tile(np.array([[16.0], [0.0], [1.0]], np.float32), (1, 3)),
        "priority": np.zeros(3, np.float32),
    }
    results = {}
    for sched_name in ("easy", "fcfs"):
        state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
        step = jax.jit(make_step(cfg, statics, sched_name))
        s = state
        for _ in range(20):
            s, _ = step(s, jnp.int32(-1))
        results[sched_name] = np.asarray(s.jstate)[:3]
    assert results["easy"][0] == 2     # job0 running
    assert results["easy"][1] == 1     # head blocked (reserved)
    assert results["easy"][2] == 2     # short job backfilled
    assert results["fcfs"][2] == 1     # FCFS head-of-line blocks it


@settings(max_examples=15, deadline=None)
@given(
    submit=st.lists(st.floats(0, 500), min_size=3, max_size=12),
    durs=st.lists(st.floats(10, 800), min_size=3, max_size=12),
)
def test_property_selection_always_valid(submit, durs):
    n = min(len(submit), len(durs))
    cfg = tiny_cluster()
    statics, state = _state_with(cfg, submit[:n], durs[:n], [1] * n)
    for name, fn in sched.SCHEDULERS.items():
        j = int(fn(cfg, state, statics))
        queued = np.asarray(sched.queued_mask(state))
        if j >= 0:
            assert queued[j], f"{name} picked a non-queued job"
        else:
            if name not in ("replay",):  # replay may legitimately wait
                assert not queued.any(), f"{name} returned -1 with queued jobs"


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 30))
def test_property_rl_candidates_are_queued_fcfs_prefix(seed):
    from repro.data import synth_workload

    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 24, 600.0, seed=seed)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    state = state._replace(t=jnp.float32(300.0))
    cands = np.asarray(sched.rl_candidates(cfg, state))
    queued = np.asarray(sched.queued_mask(state))
    subs = np.asarray(state.submit_t)
    valid = cands[cands >= 0]
    assert queued[valid].all()
    # FCFS-ordered
    assert (np.diff(subs[valid]) >= -1e-6).all()
