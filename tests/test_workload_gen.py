"""Activation suite for ``perfmodel.workload_gen`` — the paper's
"generate synthetic workloads using performance modeling tools" path.

Pins (a) finite, positive roofline-derived durations/utilizations for
every (arch, applicable shape) cell in the zoo, (b) the full round-trip
``lm_jobs_workload`` -> ``load_jobs`` -> ``run_episode`` on a reduced
config, and (c) the ``serving_profile`` bridge into the serving twin.
"""

import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.base import SHAPES, arch_names, get_arch, shape_applicable
from repro.configs.sim import tiny_cluster
from repro.core import build_statics, init_state, load_jobs, run_episode
from repro.perfmodel import lm_jobs_workload, lm_training_job, serving_profile


@pytest.mark.parametrize("arch", arch_names())
def test_roofline_jobs_finite_positive_all_archs(arch):
    cfg = get_arch(arch)
    for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
        ok, why = shape_applicable(cfg, SHAPES[shape_name])
        if not ok:
            continue
        job = lm_training_job(arch, shape_name, n_chips=16,
                              token_budget=1e8)
        for key in ("duration_s", "gpu_util", "cpu_util", "net_tx_gbps",
                    "chip_power_w", "step_s"):
            v = job[key]
            assert np.isfinite(v), f"{arch}/{shape_name}: {key} not finite"
            assert v > 0, f"{arch}/{shape_name}: {key} not positive"
        assert 0 < job["gpu_util"] <= 1.0 + 1e-6
        assert job["n_nodes"] >= 1


def test_lm_jobs_workload_roundtrips_through_twin():
    cfg = tiny_cluster(max_jobs=64)
    jobs, bank = lm_jobs_workload(
        cfg, ["gemma3-1b", "qwen3-4b", "xlstm-125m"],
        n_jobs=12, horizon_s=900.0, seed=3)
    assert np.all(np.isfinite(jobs["dur"])) and np.all(jobs["dur"] > 0)
    assert np.all(jobs["n_nodes"] >= 1)
    assert np.all(np.diff(jobs["submit_t"]) >= 0)   # sorted arrivals
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    fs, tel = jax.jit(lambda s: run_episode(
        cfg, statics, s, 600, "fcfs", summary_only=True))(state)
    assert float(fs.n_completed) + float(np.sum(np.asarray(
        fs.jstate == 2))) >= 0          # episode ran without NaN traps
    assert np.isfinite(float(fs.energy_kwh)) and float(fs.energy_kwh) > 0
    assert float(tel.n_steps) == 600


def test_serving_profile_bridges_to_config():
    prof = serving_profile("gemma3-1b", n_chips=16, gen_tokens=128)
    for k, v in prof.items():
        assert np.isfinite(v) and v > 0, f"{k} not finite-positive"
    assert 0 < prof["serving_prefill_frac"] < 1
    assert prof["serving_prefill_util"] <= 1.0
    assert prof["serving_decode_util"] <= 1.0
    # decode dominates an autoregressive request end to end
    assert prof["serving_service_s"] > 0
    cfg = tiny_cluster(serving_enabled=True, serving_nodes=4, **prof)
    assert cfg.serving_on
    assert cfg.serving_service_s == prof["serving_service_s"]
