"""Data pipelines: SuperCloud-schema round trip; deterministic LM batches."""

import numpy as np
import pytest

from repro.configs.sim import tiny_cluster
from repro.data import (
    lm_batch_at,
    load_supercloud,
    synth_workload,
    write_supercloud_csvs,
)


def test_supercloud_schema_roundtrip(tmp_path):
    cfg = tiny_cluster()
    path = write_supercloud_csvs(str(tmp_path), cfg, n_jobs=12,
                                 horizon_s=600.0, seed=1)
    jobs, bank = load_supercloud(path, cfg)
    assert len(jobs["submit_t"]) == 12
    assert jobs["req"].shape[0] == 3
    assert (jobs["dur"] > 0).all()
    # telemetry parsed into [0,1] bands
    assert bank["cpu"].max() <= 1.0 and bank["cpu"].min() >= 0.0
    assert bank["gpu"].max() <= 1.0
    # gpu jobs got gpu telemetry
    gpu_jobs = jobs["req"][1] > 0
    assert bank["gpu"][: len(gpu_jobs)][gpu_jobs].max() > 0


def test_replay_priorities_carry_recorded_starts(tmp_path):
    cfg = tiny_cluster()
    path = write_supercloud_csvs(str(tmp_path), cfg, n_jobs=8,
                                 horizon_s=600.0)
    jobs, _ = load_supercloud(path, cfg)
    assert (jobs["priority"] >= jobs["submit_t"]).all()


def test_synth_workload_respects_capacity_schema():
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 20, 900.0, seed=0)
    gpu_cap = cfg.node_types[0].gpus
    assert (jobs["req"][1] <= gpu_cap).all()
    assert jobs["n_nodes"].max() <= cfg.max_nodes_per_job
    assert bank["cpu"].shape[0] == cfg.max_jobs


def test_lm_batches_deterministic_and_host_sharded():
    a = lm_batch_at(5, vocab=512, batch=8, seq_len=16, seed=3)
    b = lm_batch_at(5, vocab=512, batch=8, seq_len=16, seed=3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    # host shards partition the batch deterministically
    h0 = lm_batch_at(5, vocab=512, batch=8, seq_len=16, seed=3,
                     host_id=0, n_hosts=2)
    h1 = lm_batch_at(5, vocab=512, batch=8, seq_len=16, seed=3,
                     host_id=1, n_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(h0["tokens"]), np.asarray(h1["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(a["tokens"][:, 1:]),
                                  np.asarray(a["labels"][:, :-1]))
