"""Data pipelines: SuperCloud-schema round trip; deterministic LM batches."""

import numpy as np
import pytest

from repro.configs.sim import tiny_cluster
from repro.data import (
    lm_batch_at,
    load_supercloud,
    synth_workload,
    write_supercloud_csvs,
)


def test_supercloud_schema_roundtrip(tmp_path):
    cfg = tiny_cluster()
    path = write_supercloud_csvs(str(tmp_path), cfg, n_jobs=12,
                                 horizon_s=600.0, seed=1)
    jobs, bank = load_supercloud(path, cfg)
    assert len(jobs["submit_t"]) == 12
    assert jobs["req"].shape[0] == 3
    assert (jobs["dur"] > 0).all()
    # telemetry parsed into [0,1] bands
    assert bank["cpu"].max() <= 1.0 and bank["cpu"].min() >= 0.0
    assert bank["gpu"].max() <= 1.0
    # gpu jobs got gpu telemetry
    gpu_jobs = jobs["req"][1] > 0
    assert bank["gpu"][: len(gpu_jobs)][gpu_jobs].max() > 0


def test_replay_priorities_carry_recorded_starts(tmp_path):
    cfg = tiny_cluster()
    path = write_supercloud_csvs(str(tmp_path), cfg, n_jobs=8,
                                 horizon_s=600.0)
    jobs, _ = load_supercloud(path, cfg)
    assert (jobs["priority"] >= jobs["submit_t"]).all()


def test_synth_workload_respects_capacity_schema():
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 20, 900.0, seed=0)
    gpu_cap = cfg.node_types[0].gpus
    assert (jobs["req"][1] <= gpu_cap).all()
    assert jobs["n_nodes"].max() <= cfg.max_nodes_per_job
    assert bank["cpu"].shape[0] == cfg.max_jobs


def test_lm_batches_deterministic_and_host_sharded():
    a = lm_batch_at(5, vocab=512, batch=8, seq_len=16, seed=3)
    b = lm_batch_at(5, vocab=512, batch=8, seq_len=16, seed=3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    # host shards partition the batch deterministically
    h0 = lm_batch_at(5, vocab=512, batch=8, seq_len=16, seed=3,
                     host_id=0, n_hosts=2)
    h1 = lm_batch_at(5, vocab=512, batch=8, seq_len=16, seed=3,
                     host_id=1, n_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(h0["tokens"]), np.asarray(h1["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(a["tokens"][:, 1:]),
                                  np.asarray(a["labels"][:, :-1]))


# ---------------------------------------------------------------------------
# validated ingestion (durable-twin PR): corruption fuzz + repair accounting
# ---------------------------------------------------------------------------

import csv
import os
import random

from _hypothesis_compat import given, settings, st


def _corrupt_sched_csv(path, seed, n_corrupt):
    """Corrupt n_corrupt random data rows in scheduler-log.csv; returns
    the set of corrupted (0-based) row indices."""
    fname = os.path.join(path, "scheduler-log.csv")
    with open(fname) as f:
        rows = list(csv.reader(f))
    header, data = rows[0], rows[1:]
    rng = random.Random(seed)
    mutations = [
        lambda r: r.__setitem__(1, "nan"),              # non_finite
        lambda r: r.__setitem__(3, "-10"),              # end < start
        lambda r: r.__setitem__(4, "0"),                # bad_node_count
        lambda r: r.__setitem__(5, "-4"),               # negative_request
        lambda r: r.__setitem__(0, data[0][0]),         # duplicate_job_id
        lambda r: r.__setitem__(2, "forty"),            # unparseable
    ]
    idx = rng.sample(range(1, len(data)), min(n_corrupt, len(data) - 1))
    for i in idx:
        rng.choice(mutations)(data[i])
    with open(fname, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(data)
    return set(idx)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), n_corrupt=st.integers(1, 6))
def test_repair_report_accounts_every_dropped_row(seed, n_corrupt,
                                                  tmp_path_factory):
    """Fuzz: corrupt random scheduler rows; repair mode must quarantine
    EXACTLY the corrupted rows, the report must account every input row
    (n_input == n_ok + n_quarantined), and strict mode must refuse the
    same file with the report attached."""
    from repro.data import write_supercloud_csvs
    from repro.utils.errors import TraceValidationError

    cfg = tiny_cluster()
    tmp = tmp_path_factory.mktemp(f"fuzz_{seed}_{n_corrupt}")
    path = write_supercloud_csvs(str(tmp), cfg, n_jobs=12, horizon_s=600.0,
                                 seed=seed % 97)
    corrupted = _corrupt_sched_csv(path, seed, n_corrupt)

    jobs, bank, reports = load_supercloud(path, cfg, validate="repair",
                                          return_report=True)
    rep = reports["scheduler"]
    assert rep.n_input == rep.n_ok + rep.n_quarantined
    assert {q["row"] for q in rep.quarantined} == corrupted
    assert len(jobs["submit_t"]) == 12 - len(corrupted)
    # kept jobs still satisfy the schema the simulator needs
    assert (jobs["dur"] > 0).all()
    assert np.isfinite(jobs["submit_t"]).all()

    with pytest.raises(TraceValidationError) as ei:
        load_supercloud(path, cfg, validate="strict")
    assert ei.value.report is not None
    assert ei.value.report.n_quarantined == len(corrupted)


def test_validate_off_skips_checks(tmp_path):
    """validate='off' is the escape hatch for pre-cleaned traces: no
    report rows, parse-only behavior (clean input loads identically)."""
    cfg = tiny_cluster()
    path = write_supercloud_csvs(str(tmp_path), cfg, n_jobs=8,
                                 horizon_s=600.0, seed=3)
    a, _ = load_supercloud(path, cfg, validate="off")
    b, _ = load_supercloud(path, cfg, validate="repair")
    np.testing.assert_array_equal(a["submit_t"], b["submit_t"])


def test_jobs_dict_validation_drops_coherently():
    """validate_jobs repair drops a bad job from EVERY column (req is
    (NRES, J)-shaped, so a ragged drop would silently misalign jobs)."""
    from repro.data import validate_jobs

    jobs = {
        "submit_t": np.array([0.0, 5.0, np.nan, 10.0]),
        "dur": np.array([10.0, -3.0, 10.0, 10.0]),
        "n_nodes": np.array([1, 1, 1, 2]),
        "req": np.arange(12, dtype=np.float64).reshape(3, 4),
        "priority": np.zeros(4),
    }
    out, rep = validate_jobs(jobs, mode="repair")
    assert rep.n_quarantined == 2 and rep.n_ok == 2
    assert out["req"].shape == (3, 2)
    np.testing.assert_array_equal(out["submit_t"], [0.0, 10.0])
    np.testing.assert_array_equal(out["req"][0], [0.0, 3.0])

    from repro.utils.errors import TraceValidationError

    with pytest.raises(TraceValidationError, match="non_finite"):
        validate_jobs(jobs, mode="strict")


def test_signal_nan_no_longer_propagates_silently(tmp_path):
    """Regression: a NaN sample in a grid-signal CSV used to flow
    straight into the carbon/price interpolation (every downstream
    energy integral turned NaN). Strict mode now refuses the file;
    repair interpolates over the gap and reports the repaired rows."""
    from repro.data.grid_signals import load_signal_csv
    from repro.utils.errors import SignalValidationError

    fname = tmp_path / "carbon.csv"
    with open(fname, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["timestamp_s", "value"])
        for i, v in enumerate([100.0, 120.0, "nan", 160.0, 180.0]):
            w.writerow([i * 900, v])

    with pytest.raises(SignalValidationError, match="non_finite"):
        load_signal_csv(str(fname), validate="strict")

    sig, rep = load_signal_csv(str(fname), validate="repair",
                               return_report=True)
    assert rep.n_quarantined == 1
    vals = np.asarray(sig.values)
    assert np.isfinite(vals).all(), "repair must leave no NaN behind"
    assert abs(float(vals[2]) - 140.0) < 1e-6  # linear gap fill


def test_signal_structural_errors_raise_in_repair_mode(tmp_path):
    """Non-monotone / non-uniform timestamps have no sound row-wise
    repair — they raise a typed error in every mode, naming the row."""
    from repro.data.grid_signals import load_signal_csv
    from repro.utils.errors import SignalValidationError

    fname = tmp_path / "price.csv"
    with open(fname, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["timestamp_s", "value"])
        for t, v in [(0, 1.0), (900, 2.0), (800, 3.0)]:
            w.writerow([t, v])
    with pytest.raises(SignalValidationError, match="increasing"):
        load_signal_csv(str(fname), validate="repair")
