"""CLI driver smoke tests (train/serve/rl_train mains with tiny configs)."""

import json
import os

import numpy as np
import pytest


def test_train_cli_runs_and_writes_metrics(tmp_path):
    from repro.launch import train as train_mod

    out = str(tmp_path / "metrics.json")
    hist = train_mod.main([
        "--arch", "xlstm-125m", "--reduced", "--steps", "6",
        "--batch", "2", "--seq", "32", "--log-every", "2",
        "--ckpt", str(tmp_path / "ck"), "--ckpt-every", "3",
        "--metrics-out", out,
    ])
    assert len(hist) >= 2
    assert np.isfinite(hist[-1]["loss"])
    assert os.path.exists(out) and json.load(open(out))
    # checkpoint written and resumable
    hist2 = train_mod.main([
        "--arch", "xlstm-125m", "--reduced", "--steps", "8",
        "--batch", "2", "--seq", "32", "--log-every", "2",
        "--ckpt", str(tmp_path / "ck"), "--resume",
    ])
    assert hist2[0]["step"] >= 5  # resumed past the checkpoint


def test_serve_cli_generates(capsys):
    from repro.launch import serve as serve_mod

    out = serve_mod.main([
        "--arch", "gemma3-1b", "--reduced", "--batch", "2",
        "--prompt-len", "16", "--gen", "4",
    ])
    assert out.shape == (2, 4)
    assert "tok/s" in capsys.readouterr().out


def test_rl_train_cli(tmp_path):
    from repro.launch import rl_train as rl_mod

    params, hist = rl_mod.main([
        "--cluster", "tiny", "--iterations", "2", "--n-envs", "4",
        "--rollout", "8", "--episode-steps", "6", "--n-jobs", "16",
        "--n-workloads", "2", "--out", str(tmp_path),
    ])
    assert len(hist) == 2
    assert os.path.exists(tmp_path / "ppo_history.json")
    assert os.path.exists(tmp_path / "power_trace_rl.npy")
    pw = np.load(tmp_path / "power_trace_rl.npy")
    assert (pw > 0).all()
