"""Optimizer + compression substrate tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import Adafactor, AdamW
from repro.optim.compress import compressed_psum, quantize_dequantize


@pytest.mark.parametrize(
    "opt",
    [AdamW(lr=0.1),
     # adafactor's RMS-clipped updates oscillate at fixed lr; decay it
     Adafactor(lr=lambda s: 0.5 / (1.0 + 0.05 * s.astype(jnp.float32)))],
)
def test_optimizers_converge_on_quadratic(opt):
    params = {"w": jnp.array([5.0, -3.0, 2.0]), "b": jnp.array([[1.0, -1.0],
                                                                [2.0, 0.5]])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for step in range(200):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params, jnp.int32(step))
    assert float(loss(params)) < 1e-2


def test_adamw_state_pspecs_mirror_params():
    from jax.sharding import PartitionSpec as P

    opt = AdamW()
    specs = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    ps = {"w": P("data", "model")}
    out = opt.state_pspecs(specs, ps)
    assert out["m"]["w"] == P("data", "model")


def test_adafactor_factored_state_shapes_and_pspecs():
    from jax.sharding import PartitionSpec as P

    opt = Adafactor()
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    state = opt.init(params)
    assert state["f"]["w"]["vr"].shape == (8,)
    assert state["f"]["w"]["vc"].shape == (4,)
    assert state["f"]["b"]["v"].shape == (4,)
    ps = opt.state_pspecs(
        {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
         "b": jax.ShapeDtypeStruct((4,), jnp.float32)},
        {"w": P("data", "model"), "b": P()},
    )
    assert ps["f"]["w"]["vr"] == P("data")
    assert ps["f"]["w"]["vc"] == P("model")


def test_quantize_dequantize_error_bounded():
    g = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)))}
    out = quantize_dequantize(g)
    err = float(jnp.max(jnp.abs(out["a"] - g["a"])))
    scale = float(jnp.max(jnp.abs(g["a"]))) / 127.0
    assert err <= scale * 0.51 + 1e-9


def test_compressed_psum_matches_mean_within_quantization():
    """int8 psum across a vmapped axis ~= the true mean."""
    rng = np.random.default_rng(1)
    gs = jnp.asarray(rng.normal(size=(4, 32)))  # 4 shards

    def f(g):
        out, err = compressed_psum({"g": g}, "i")
        return out["g"], err["g"]

    out, err = jax.vmap(f, axis_name="i")(gs)
    true_mean = jnp.mean(gs, axis=0)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(true_mean),
                               atol=float(jnp.max(jnp.abs(gs))) / 127 + 1e-6)
    # every shard agrees on the reduced value
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]), atol=0)


def test_error_feedback_reduces_bias_over_steps():
    """With error feedback, the running SUM of compressed grads converges to
    the running sum of true grads (bias does not accumulate)."""
    rng = np.random.default_rng(2)
    g_true = jnp.asarray(rng.normal(size=(8, 16)) * 0.1)
    err = None
    acc_c = jnp.zeros((16,))
    acc_t = jnp.zeros((16,))
    for i in range(8):
        def f(g, e):
            out, ne = compressed_psum({"g": g}, "i",
                                      error={"g": e} if e is not None else None)
            return out["g"], ne["g"]
        gs = jnp.stack([g_true[i]] * 2)
        es = err if err is not None else None
        out, ne = jax.vmap(f, axis_name="i")(
            gs, es if es is not None else jnp.zeros_like(gs))
        err = ne
        acc_c = acc_c + out[0]
        acc_t = acc_t + g_true[i]
    assert float(jnp.max(jnp.abs(acc_c - acc_t))) < 0.02
