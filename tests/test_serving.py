"""Serving-twin unit + property suite (docs/serving.md).

Covers the overload ladder's conservation ledger (shed + dropped +
completed + held == arrived), the capped-backoff retry schedule, the
monotonicity of shedding in traffic scale, the SLO summary columns, the
SchedEnv serving obs/action surface, and the checkify invariants.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.sim import tiny_cluster
from repro.core import build_statics, init_state, load_jobs, run_episode
from repro.core.serving import retry_backoff
from repro.core.sim import summary
from repro.data import synth_workload
from repro.envs import SchedEnv
from repro.envs.sched_env import SERVING_FEATURES
from repro.scenarios import diurnal_serving


def _serving_cfg(**kw):
    base = dict(serving_enabled=True, serving_nodes=4,
                serving_concurrency=4.0, serving_service_s=3.0,
                serving_queue_cap=60.0, serving_timeout_s=20.0,
                serving_slo_s=6.0, serving_max_retries=2,
                serving_backoff_s=5.0)
    base.update(kw)
    return tiny_cluster(**base)


def _run(cfg, scn, n_steps=900, state_fn=None):
    statics = build_statics(cfg, scenario=scn)
    state = init_state(cfg, statics, jax.random.key(0))
    if state_fn is not None:
        state = state_fn(state)
    fs, tel = jax.jit(lambda s: run_episode(
        cfg, statics, s, n_steps, "fcfs", summary_only=True))(state)
    return fs, tel


def test_request_conservation_under_overload():
    """Every arrived request is accounted for: still queued (admission or
    retry buckets), in flight, completed, shed, or terminally dropped —
    and the overload is heavy enough that every ladder rung fires."""
    cfg = _serving_cfg()
    scn = diurnal_serving(cfg, peak_rps=30.0, period_s=1800.0,
                          burst_start_s=300.0, burst_len_s=200.0,
                          burst_mult=3.0)
    fs, _ = _run(cfg, scn)
    held = (float(jnp.sum(fs.srv_queue)) + float(jnp.sum(fs.srv_retry_q))
            + float(fs.srv_inflight))
    arrived = float(fs.srv_arrived)
    out = (float(fs.srv_completed) + float(fs.srv_shed)
           + float(fs.srv_dropped))
    assert arrived > 0
    np.testing.assert_allclose(held + out, arrived,
                               rtol=1e-5, atol=1e-2)
    assert float(fs.srv_shed) > 0
    assert float(fs.srv_retried) > 0
    assert float(fs.srv_dropped) > 0
    assert float(fs.srv_completed) > 0


def test_retry_backoff_increasing_then_capped():
    cfg = tiny_cluster(serving_backoff_s=4.0, serving_backoff_mult=2.0,
                       serving_backoff_cap_s=60.0, serving_max_retries=8)
    waits = [float(retry_backoff(cfg, a)) for a in range(1, 10)]
    # 4, 8, 16, 32, 60, 60, ... strictly increasing until the cap
    for a, b in zip(waits, waits[1:]):
        assert b >= a
        if a < 60.0:
            assert b > a
    assert max(waits) == 60.0
    assert waits[0] == 4.0


def test_shedding_monotone_in_traffic_scale():
    """Scaling the whole traffic signal up never reduces shed mass."""
    shed = []
    for peak in (6.0, 15.0, 40.0):
        cfg = _serving_cfg()
        scn = diurnal_serving(cfg, peak_rps=peak, period_s=1800.0,
                              burst_start_s=300.0, burst_len_s=200.0,
                              burst_mult=2.0)
        fs, _ = _run(cfg, scn)
        shed.append(float(fs.srv_shed))
    assert shed[0] <= shed[1] <= shed[2]
    assert shed[2] > shed[0]


def test_summary_slo_columns():
    cfg = _serving_cfg(serving_queue_cap=200.0, serving_timeout_s=40.0)
    scn = diurnal_serving(cfg, peak_rps=8.0, period_s=1800.0,
                          burst_start_s=600.0, burst_len_s=200.0,
                          burst_mult=3.0)
    fs, tel = _run(cfg, scn, n_steps=1800)
    s = summary(fs, tel)
    assert s["srv_arrived"] > 0 and s["srv_completed"] > 0
    assert 0.0 <= s["srv_slo_violation_frac"] <= 1.0
    assert s["srv_goodput_requests"] <= s["srv_completed"]
    assert s["srv_mean_latency_s"] > 0
    # latency quantiles come from the log-2 histogram, in SLO units
    assert s["srv_p50_latency_x_slo"] <= s["srv_p99_latency_x_slo"]
    assert s["srv_p99_latency_x_slo"] <= 16.0


def test_serving_off_summary_zeros_and_layout():
    """serving off -> all serving columns are exact zeros and the env
    obs layout is unchanged (no serving features appended)."""
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 8, 300.0, seed=0)
    statics = build_statics(cfg, bank)
    state = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    fs, tel = jax.jit(lambda s: run_episode(
        cfg, statics, s, 300, "fcfs", summary_only=True))(state)
    s = summary(fs, tel)
    assert s["srv_arrived"] == 0.0 and s["srv_shed"] == 0.0


def test_env_serving_obs_and_actions():
    cfg = _serving_cfg(sched_max_candidates=4, serving_scale_step=1.0)
    cfg_off = tiny_cluster(sched_max_candidates=4)
    scn = diurnal_serving(cfg, peak_rps=10.0, period_s=1800.0)
    wls = [synth_workload(cfg, 16, 900.0, seed=0)]
    env = SchedEnv(cfg, wls, episode_steps=8, sim_steps_per_action=5,
                   scenario=scn)
    env_off = SchedEnv(cfg_off, wls, episode_steps=8,
                       sim_steps_per_action=5)
    # obs grows by exactly the serving feature block; 4 extra actions
    assert env.obs_dim == env_off.obs_dim + len(SERVING_FEATURES)
    assert env.n_actions == env_off.n_actions + 4

    st, obs = env.reset(jax.random.key(0))
    assert obs.shape == (env.obs_dim,)
    assert np.all(np.isfinite(np.asarray(obs)))

    k = env.k
    # scale-down action lowers the pool target by one step
    st2, *_ = jax.jit(env.step)(st, jnp.int32(k + 1))
    assert float(st2.sim.srv_target) == cfg.serving_nodes - 1
    # threshold-up action raises the admission threshold by 0.05
    st3, *_ = jax.jit(env.step)(st, jnp.int32(k + 4))
    np.testing.assert_allclose(float(st3.sim.srv_admit_thresh),
                               min(cfg.serving_admit_thresh + 0.05, 1.0),
                               rtol=1e-6)
    # a dispatch/no-op action leaves both knobs untouched
    st4, *_ = jax.jit(env.step)(st, jnp.int32(k))
    assert float(st4.sim.srv_target) == cfg.serving_nodes
    assert float(st4.sim.srv_admit_thresh) == pytest.approx(
        cfg.serving_admit_thresh)


def test_serving_invariants_checkify(monkeypatch):
    """The REPRO_CHECKIFY suite passes on a hot serving episode and
    catches a corrupted ledger."""
    from jax.experimental import checkify

    from repro.utils import invariants

    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    assert invariants.enabled()
    cfg = _serving_cfg()
    scn = diurnal_serving(cfg, peak_rps=25.0, period_s=1800.0,
                          burst_start_s=300.0, burst_len_s=200.0,
                          burst_mult=3.0)
    statics = build_statics(cfg, scenario=scn)
    state = init_state(cfg, statics, jax.random.key(0))
    fs, _ = jax.jit(lambda s: run_episode(
        cfg, statics, s, 600, "fcfs", summary_only=True))(state)

    def audit(s):
        invariants.check_state(cfg, statics, s)
        return jnp.float32(0.0)

    err, _ = checkify.checkify(audit)(fs)
    err.throw()                                   # clean state passes
    bad = fs._replace(srv_completed=fs.srv_completed
                      + fs.srv_arrived + 1e3)     # break conservation
    err, _ = checkify.checkify(audit)(bad)
    with pytest.raises(Exception, match="conservation|exceeds"):
        err.throw()
