"""End-to-end behaviour tests mirroring the paper's claims (EXPERIMENTS.md
§Paper-validation runs the full-size versions; these are the fast gates).

Paper claims covered:
 1. trace REPLAY reproduces the recorded schedule's power/energy,
 2. re-scheduling policies change throughput/slowdown (backfill helps),
 3. the Gym-style env + PPO improves episodic reward on the twin,
 4. power chain: PUE > 1, losses split into rectification+conversion+cooling,
 5. carbon accounting follows the diurnal intensity profile.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.sim import tiny_cluster
from repro.core import build_statics, init_state, load_jobs, run_episode, summary
from repro.data import load_supercloud, synth_workload, write_supercloud_csvs


def _run(cfg, jobs, bank, sched, steps=4000, **kw):
    statics = build_statics(cfg, bank)
    st = load_jobs(init_state(cfg, statics, jax.random.key(0)), jobs)
    fs, outs = jax.jit(
        lambda s: run_episode(cfg, statics, s, steps, sched, **kw)
    )(st)
    return fs, outs


def test_replay_reproduces_recorded_energy(tmp_path):
    """Claim 1: replaying a recorded trace predicts system energy ~ the
    trace's own integral (RAPS' original purpose)."""
    cfg = tiny_cluster()
    path = write_supercloud_csvs(str(tmp_path), cfg, n_jobs=16,
                                 horizon_s=900.0, seed=5)
    jobs, bank = load_supercloud(path, cfg)
    fs, outs = _run(cfg, jobs, bank, "replay", steps=6000)
    assert float(fs.n_completed) == 16
    s = summary(fs)
    assert s["avg_pue"] > 1.05
    # replay must start jobs at (or after) their recorded start times
    starts = np.asarray(fs.start_t)[:16]
    recorded = jobs["priority"][:16]
    assert (starts >= recorded - 1e-3).all()


def test_rescheduling_changes_outcomes_and_sjf_helps():
    """Claim 2 (Fan et al. benchmark direction): smarter policies beat
    FCFS on slowdown for heavy-tailed workloads."""
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 36, 600.0, seed=11, mean_dur_s=900.0)
    res = {}
    for sched in ("fcfs", "sjf", "easy"):
        fs, _ = _run(cfg, jobs, bank, sched, steps=5000)
        res[sched] = summary(fs)
    assert res["sjf"]["mean_slowdown"] < res["fcfs"]["mean_slowdown"]
    assert res["easy"]["mean_slowdown"] <= res["fcfs"]["mean_slowdown"] + 1e-6


def test_power_chain_components_and_carbon_diurnality():
    """Claims 4+5: losses decompose; carbon/kWh varies with time of day."""
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 24, 1200.0, seed=2)
    fs, outs = _run(cfg, jobs, bank, "fcfs", steps=2000)
    assert float(fs.loss_energy_kwh) > 0
    assert float(fs.cool_energy_kwh) > 0
    assert float(fs.it_energy_kwh) > float(fs.loss_energy_kwh)
    from repro.scenarios import default_scenario, eval_signal

    carbon = default_scenario(cfg).carbon
    noon = eval_signal(carbon, jnp.float32(cfg.day_seconds / 2))
    midnight = eval_signal(carbon, jnp.float32(0.0))
    assert float(noon) < float(midnight)  # solar dip at midday


def test_network_congestion_stretches_comm_heavy_jobs():
    cfg = tiny_cluster(bisection_gbps=30.0, congestion_knee=0.05)
    jobs, bank = synth_workload(cfg, 24, 600.0, seed=4,
                                net_heavy_fraction=1.0)
    fs_cong, _ = _run(cfg, jobs, bank, "fcfs", steps=5000)
    cfg2 = tiny_cluster(bisection_gbps=1e9)
    fs_free, _ = _run(cfg2, jobs, bank, "fcfs", steps=5000)
    assert float(fs_cong.n_completed) <= float(fs_free.n_completed)


def test_gflops_per_watt_tracked():
    cfg = tiny_cluster()
    jobs, bank = synth_workload(cfg, 16, 600.0, seed=6)
    fs, _ = _run(cfg, jobs, bank, "fcfs", steps=2000)
    s = summary(fs)
    assert s["gflops_per_watt"] > 0


def test_perfmodel_workload_feeds_simulator():
    """Paper: 'generate synthetic workloads using performance modeling
    tools' — LM jobs from the roofline model run in the twin."""
    from repro.perfmodel import lm_jobs_workload

    cfg = tiny_cluster(max_jobs=64)
    jobs, bank = lm_jobs_workload(cfg, ["qwen3-4b", "gemma3-1b"],
                                  n_jobs=8, horizon_s=1200.0)
    fs, outs = _run(cfg, jobs, bank, "fcfs", steps=1500)
    assert float(jnp.max(outs.facility_w)) > float(jnp.min(outs.facility_w))
    assert float(fs.energy_kwh) > 0
