"""RL stack: env API contracts, GAE math, PPO end-to-end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.sim import tiny_cluster
from repro.data import synth_workload
from repro.envs import SchedEnv
from repro.rl import ActorCritic, PPOConfig, ppo_train
from repro.rl.gae import gae


@pytest.fixture(scope="module")
def env():
    cfg = tiny_cluster(sched_max_candidates=4)
    wls = [synth_workload(cfg, 24, 900.0, seed=s) for s in range(2)]
    return SchedEnv(cfg, wls, episode_steps=8, sim_steps_per_action=5)


def test_env_reset_and_step_contract(env):
    st, obs = env.reset(jax.random.key(0))
    assert obs.shape == (env.obs_dim,)
    assert np.all(np.isfinite(np.asarray(obs)))
    for a in range(env.n_actions):
        st2, obs2, r, done, info = env.step(st, jnp.int32(a))
        assert obs2.shape == (env.obs_dim,)
        assert np.isfinite(float(r))
        assert info["facility_w"] > 0


def test_env_vmaps(env):
    keys = jax.random.split(jax.random.key(0), 4)
    sts, obs = jax.vmap(env.reset)(keys)
    assert obs.shape == (4, env.obs_dim)
    sts2, obs2, r, d, _ = jax.vmap(env.step)(sts, jnp.zeros(4, jnp.int32))
    assert r.shape == (4,)


def test_dispatch_action_starts_job(env):
    st, obs = env.reset(jax.random.key(1))
    # action 0 = dispatch first queue candidate (feasible at t=0 for tiny)
    st2, *_ = env.step(st, jnp.int32(0))
    running_before = int(jnp.sum(st.sim.jstate == 2))
    running_after = int(jnp.sum(st2.sim.jstate == 2))
    assert running_after >= running_before


def test_gae_matches_manual_computation():
    rewards = jnp.array([[1.0], [1.0], [1.0]])
    values = jnp.array([[0.5], [0.5], [0.5]])
    dones = jnp.zeros((3, 1))
    last = jnp.array([0.5])
    adv, ret = gae(rewards, values, dones, last, gamma=0.9, lam=0.8)
    # manual reverse recursion
    a2 = 1.0 + 0.9 * 0.5 - 0.5
    a1 = (1.0 + 0.9 * 0.5 - 0.5) + 0.9 * 0.8 * a2
    a0 = (1.0 + 0.9 * 0.5 - 0.5) + 0.9 * 0.8 * a1
    np.testing.assert_allclose(np.asarray(adv[:, 0]), [a0, a1, a2], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ret), np.asarray(adv + values))


def test_gae_resets_at_episode_boundary():
    rewards = jnp.ones((3, 1))
    values = jnp.zeros((3, 1))
    dones = jnp.array([[0.0], [1.0], [0.0]])
    adv, _ = gae(rewards, values, dones, jnp.array([10.0]), gamma=1.0, lam=1.0)
    # step 1 is terminal: its advantage must not bootstrap step 2's value
    assert float(adv[1, 0]) == 1.0


def test_policy_shapes_and_grads():
    pol = ActorCritic(12, 5)
    params = pol.init(jax.random.key(0))
    obs = jnp.ones((7, 12))
    logits, value = pol.apply(params, obs)
    assert logits.shape == (7, 5) and value.shape == (7,)
    g = jax.grad(lambda p: pol.apply(p, obs)[0].sum())(params)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(g))


def test_ppo_trains_and_checkpoints(env, tmp_path):
    params, hist = ppo_train(
        env, cfg=PPOConfig(n_envs=4, rollout_len=8, n_epochs=2,
                           n_minibatches=2),
        n_iterations=3, checkpoint_dir=str(tmp_path), checkpoint_every=2,
    )
    assert len(hist) == 3
    assert all(np.isfinite(h["mean_reward"]) for h in hist)
    from repro.checkpoint import latest_step

    assert latest_step(str(tmp_path)) is not None
