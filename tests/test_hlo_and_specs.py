"""HLO collective parser + sharding-spec unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.sharding.ctx import make_ctx
from repro.sharding.specs import cache_pspecs, param_pspecs
from repro.utils.hlo import parse_collectives

HLO_SAMPLE = """
HloModule jit_step
%fused (a: bf16[8,128]) -> bf16[8,128] { ... }
%ag = bf16[16,4096]{1,0} all-gather(%x), replica_groups={{0,1}}
%ar = f32[256]{0} all-reduce(%y), to_apply=%add
%rs = f32[32,16]{1,0} reduce-scatter(%z), dimensions={0}
%a2a = bf16[4,64]{1,0} all-to-all(%w), dimensions={0}
%cp = u8[1024]{0} collective-permute(%v), source_target_pairs={{0,1}}
%ars = f32[256]{0} all-reduce-start(%y2), to_apply=%add
%ard = f32[256]{0} all-reduce-done(%ars)
"""


def test_parse_collectives_counts_and_bytes():
    stats = parse_collectives(HLO_SAMPLE)
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.bytes_by_kind["all-gather"] == 16 * 4096 * 2
    assert stats.bytes_by_kind["all-reduce"] == 256 * 4 * 2  # incl. -start
    assert stats.count_by_kind["all-reduce"] == 2
    assert stats.bytes_by_kind["reduce-scatter"] == 32 * 16 * 4
    assert stats.bytes_by_kind["all-to-all"] == 4 * 64 * 2
    assert stats.bytes_by_kind["collective-permute"] == 1024
    # -done lines are not double counted
    assert stats.total_count == 6


def test_parse_collectives_ignores_non_collective_lines():
    stats = parse_collectives("%x = f32[8] add(%a, %b)\n%y = call()")
    assert stats.total_bytes == 0


def _mesh_sizes():
    return {"data": 16, "model": 16}


@pytest.mark.parametrize("arch", ["qwen3-4b", "mixtral-8x22b",
                                  "jamba-1.5-large-398b", "gemma3-1b"])
def test_param_pspecs_divide_evenly(arch):
    """Every sharded dim must divide by the mesh axes product — the specs
    builder drops shardings that don't divide."""
    cfg = get_arch(arch)
    ctx = make_ctx(False)
    from repro.models.spec import model_param_specs
    from repro.utils.tree import tree_map_with_path_names

    specs = model_param_specs(cfg)
    pspecs = param_pspecs(cfg, ctx)
    sizes = _mesh_sizes()

    def check(name, sds):
        spec = ref_specs[name]
        for dim, ax in zip(sds.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert dim % total == 0, f"{name}: {dim} % {total}"
        return sds

    ref_specs = {}
    tree_map_with_path_names(lambda n, p: ref_specs.__setitem__(n, p) or p,
                             pspecs)
    tree_map_with_path_names(check, specs)


def test_expert_parallel_only_when_divisible():
    ctx = make_ctx(False)
    jam = param_pspecs(get_arch("jamba-1.5-large-398b"), ctx)   # 16 experts
    mix = param_pspecs(get_arch("mixtral-8x22b"), ctx)          # 8 experts
    from repro.utils.tree import tree_map_with_path_names

    found = {}

    def grab(tag):
        def f(n, p):
            if n.endswith("e_wg"):
                found.setdefault(tag, p)
            return p
        return f

    tree_map_with_path_names(grab("jamba"), jam)
    tree_map_with_path_names(grab("mixtral"), mix)
    assert found["jamba"][1] == "model"      # stacked: (None, E='model', ...)
    assert found["mixtral"][1] is None       # experts not sharded


def test_cache_pspecs_structure_matches_cache():
    from repro.models.model import cache_specs

    cfg = get_arch("jamba-1.5-large-398b")
    ctx = make_ctx(False)
    ps = cache_pspecs(cfg, ctx)
    specs = cache_specs(cfg, 8, 64)
    assert jax.tree.structure(ps) == jax.tree.structure(
        jax.tree.map(lambda s: P(), specs))
